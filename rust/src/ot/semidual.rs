//! Smoothed *semi-dual* OT (extension).
//!
//! Blondel, Seguy & Rolet (2018) also derive a semi-dual in which the
//! column marginals `Tᵀ1 = b` are kept as hard constraints and only α
//! remains as a free variable:
//!
//! ```text
//! max_α αᵀa + Σ_j b_j·σ_j(α),
//! σ_j(α) = min over the inner column problem with Σ_i t_ij = b_j.
//! ```
//!
//! For the quadratic regularizer (ρ = 0) the inner problem per column is
//!
//! ```text
//! max_{t ≥ 0, 1ᵀt = b_j}  (α − c_j)ᵀ t − (γ/2)‖t‖²
//! ```
//!
//! whose solution is the classic water-filling / simplex projection
//! `t = [ (α − c_j)/γ − ν ]₊` with `ν` chosen so the mass is `b_j`.
//! This module implements that solver; it serves as an ablation
//! reference whose plan satisfies the column marginals *exactly* (the
//! relaxed dual only approaches them as γ → 0).

use super::dual::{DualOracle, OracleStats, OtProblem};
use super::regularizer::{AnyRegularizer, Regularizer};
use super::solve::SolveOptions;
use crate::err;
use crate::error::Result;
use crate::pool::{fixed_chunk_ranges, ParallelCtx};
use crate::simd::{sub_into, Dispatch, SimdMode};
use crate::solvers::lbfgs::{Lbfgs, LbfgsOptions};
use crate::solvers::{StepStatus, StopReason};
use std::ops::Range;

/// Solve the inner water-filling problem: maximize `fᵀt − (γ/2)‖t‖²`
/// over `t ≥ 0, Σt = mass`. Returns `(t, value)`.
pub fn waterfill(f: &[f64], gamma: f64, mass: f64) -> (Vec<f64>, f64) {
    // t_i = [f_i/γ − ν]₊ with Σ t = mass. Solve for ν by sorting.
    let m = f.len();
    let mut s: Vec<f64> = f.iter().map(|&v| v / gamma).collect();
    let mut sorted = s.clone();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap()); // descending
    let mut cum = 0.0;
    let mut nu = 0.0;
    let mut k = m;
    for (idx, &v) in sorted.iter().enumerate() {
        cum += v;
        let cand = (cum - mass) / (idx + 1) as f64;
        // ν must satisfy sorted[idx] > ν ≥ sorted[idx+1] (support size idx+1).
        let next = if idx + 1 < m { sorted[idx + 1] } else { f64::NEG_INFINITY };
        if (next..v).contains(&cand) {
            nu = cand;
            k = idx + 1;
            break;
        }
    }
    if k == m {
        // All coordinates active.
        let total: f64 = sorted.iter().sum();
        nu = (total - mass) / m as f64;
    }
    for v in s.iter_mut() {
        *v = (*v - nu).max(0.0);
    }
    let value: f64 = f
        .iter()
        .zip(&s)
        .map(|(&fi, &ti)| fi * ti - 0.5 * gamma * ti * ti)
        .sum();
    (s, value)
}

/// Per-chunk scratch for the column-parallel semi-dual evaluation.
struct SemiChunk {
    /// Partial `Σ_j t_j` gradient contribution (length m).
    grad: Vec<f64>,
    /// `α − c_j` staging buffer (length m).
    fcol: Vec<f64>,
    /// Cost-column staging for the factored backend (unused — empty —
    /// when the cost is dense).
    colbuf: Vec<f64>,
    /// Partial `Σ_j val_j`.
    semid: f64,
}

/// Negated semi-dual oracle over α (quadratic regularizer). The inner
/// column problems are independent, so chunks of columns solve in
/// parallel on `threads` workers; partials combine in fixed chunk order,
/// keeping results bit-identical for every thread count.
pub struct SemiDualOracle<'a> {
    prob: &'a OtProblem,
    gamma: f64,
    ctx: ParallelCtx,
    ranges: Vec<Range<usize>>,
    slots: Vec<SemiChunk>,
    /// SIMD backend for the `α − c_j` column staging (element-wise, so
    /// bit-identical on every backend; only the wall clock moves — the
    /// sort-based water-filling itself stays scalar).
    dispatch: Dispatch,
    stats: OracleStats,
    /// Cooperative cancellation, polled once per column chunk.
    cancel: Option<crate::fault::CancelToken>,
}

impl<'a> SemiDualOracle<'a> {
    pub fn new(prob: &'a OtProblem, gamma: f64) -> Self {
        Self::with_threads(prob, gamma, 1)
    }

    /// Create with `threads` intra-evaluation workers (1 = serial) on a
    /// fresh [`ParallelCtx`] owned by this oracle.
    pub fn with_threads(prob: &'a OtProblem, gamma: f64, threads: usize) -> Self {
        Self::build(prob, gamma, ParallelCtx::new(threads), SimdMode::Auto)
    }

    /// Create over a caller-provided long-lived parallel context: the
    /// inner column problems run on its persistent parked workers, so
    /// repeated solves reuse one worker set instead of forking per
    /// evaluation. SIMD policy is `Auto` (`GRPOT_SIMD` overrides).
    #[deprecated(note = "use `semidual::solve` with `SolveOptions::ctx`")]
    pub fn with_ctx(prob: &'a OtProblem, gamma: f64, ctx: ParallelCtx) -> Self {
        Self::build(prob, gamma, ctx, SimdMode::Auto)
    }

    /// Caller-provided context with an explicit SIMD policy.
    #[deprecated(note = "use `semidual::solve` with `SolveOptions::ctx`/`simd`")]
    pub fn with_ctx_simd(
        prob: &'a OtProblem,
        gamma: f64,
        ctx: ParallelCtx,
        simd: SimdMode,
    ) -> Self {
        Self::build(prob, gamma, ctx, simd)
    }

    /// The one real constructor every public entry funnels into.
    pub(crate) fn build(
        prob: &'a OtProblem,
        gamma: f64,
        ctx: ParallelCtx,
        simd: SimdMode,
    ) -> Self {
        assert!(gamma > 0.0);
        let m = prob.m();
        let ranges = fixed_chunk_ranges(prob.n());
        let slots = (0..ranges.len())
            .map(|_| SemiChunk {
                grad: vec![0.0; m],
                fcol: vec![0.0; m],
                colbuf: Vec::new(),
                semid: 0.0,
            })
            .collect();
        SemiDualOracle {
            prob,
            gamma,
            ctx,
            ranges,
            slots,
            dispatch: Dispatch::resolve(simd),
            stats: OracleStats::default(),
            cancel: None,
        }
    }

    /// Arm (or disarm) sub-eval cancellation: the token is polled once
    /// per column chunk at one relaxed load.
    #[allow(dead_code)]
    pub(crate) fn set_cancel(&mut self, cancel: Option<crate::fault::CancelToken>) {
        self.cancel = cancel;
    }
}

impl DualOracle for SemiDualOracle<'_> {
    fn shape(&self) -> (usize, usize) {
        (self.prob.m(), 0)
    }

    fn eval(&mut self, alpha: &[f64], grad: &mut [f64]) -> f64 {
        let m = self.prob.m();
        let n = self.prob.n();
        assert_eq!(alpha.len(), m);
        // ∇(−D) = −a + Σ_j t_j(α); value = −(αᵀa + Σ_j value_j − αᵀ t_j).
        for (g, &ai) in grad.iter_mut().zip(&self.prob.a) {
            *g = -ai;
        }
        // Derivation: the Lagrangian dual over α of
        // min_T ⟨T,C⟩ + γ/2‖T‖² s.t. Tᵀ1=b, T≥0 with relaxed T1=a is
        //   D(α) = αᵀa + Σ_j min_{t≥0,1ᵀt=b_j} (c_j − α)ᵀ t + γ/2‖t‖²
        //        = αᵀa − Σ_j max_{t≥0,1ᵀt=b_j} (α − c_j)ᵀ t − γ/2‖t‖²,
        // and by Danskin ∇D = a − Σ_j t_j ⇒ ∇(−D) = −a + Σ_j t_j.
        // The inner column problems are independent: chunks solve
        // concurrently and partials combine in fixed chunk order.
        let prob = self.prob;
        let gamma = self.gamma;
        let dispatch = self.dispatch;
        let cancel = self.cancel.as_ref();
        self.ctx.map_chunks(&self.ranges, &mut self.slots, |_, range, slot| {
            let SemiChunk { grad, fcol, colbuf, semid } = slot;
            *semid = 0.0;
            for v in grad.iter_mut() {
                *v = 0.0;
            }
            // Sub-eval cancellation checkpoint (one relaxed load per
            // chunk); a cancelled chunk merges as zeros.
            if cancel.is_some_and(|t| t.is_cancelled()) {
                return;
            }
            for j in range {
                let c_j = prob.cost_col(j, colbuf);
                sub_into(dispatch, fcol, alpha, c_j);
                let (t, val) = waterfill(fcol, gamma, prob.b[j]);
                *semid += val;
                for (g, &ti) in grad.iter_mut().zip(&t) {
                    *g += ti;
                }
            }
        });
        let mut semid = crate::linalg::dot(alpha, &self.prob.a);
        for slot in &self.slots {
            semid -= slot.semid;
            for (g, &pi) in grad.iter_mut().zip(&slot.grad) {
                *g += pi;
            }
        }
        self.stats.record_eval(n as u64);
        -semid
    }

    fn stats(&self) -> &OracleStats {
        &self.stats
    }

    fn simd_dispatch(&self) -> Option<Dispatch> {
        Some(self.dispatch)
    }

    fn parallel_ctx(&self) -> Option<&ParallelCtx> {
        Some(&self.ctx)
    }
}

/// Per-chunk scratch for the generic semi-dual evaluation.
struct SemiRegChunk {
    /// Partial `Σ_j t_j` gradient contribution (length m).
    grad: Vec<f64>,
    /// `α − c_j` staging buffer (length m).
    fcol: Vec<f64>,
    /// Inner-solution buffer for `max_omega` (length m).
    tbuf: Vec<f64>,
    /// Cost-column staging for the factored backend (unused — empty —
    /// when the cost is dense).
    colbuf: Vec<f64>,
    /// Partial `Σ_j val_j`.
    semid: f64,
}

/// Negated semi-dual oracle over α for *any* regularizer whose
/// [`Regularizer::max_omega`] is implemented (squared ℓ2, negative
/// entropy). Mirrors [`SemiDualOracle`] exactly — same fixed chunk
/// grid, same staging (`fcol[i] = α_i − c_ij`, bitwise equal to the
/// SIMD `sub_into` since element-wise IEEE subtraction is exact), same
/// ordered reduction, same [`OracleStats`] accounting — so routing the
/// quadratic regularizer through the trait is byte-identical to the
/// legacy oracle.
pub struct SemiRegOracle<'a, R: Regularizer> {
    prob: &'a OtProblem,
    reg: R,
    ctx: ParallelCtx,
    ranges: Vec<Range<usize>>,
    slots: Vec<SemiRegChunk>,
    stats: OracleStats,
    /// Cooperative cancellation, polled once per column chunk.
    cancel: Option<crate::fault::CancelToken>,
}

impl<'a, R: Regularizer> SemiRegOracle<'a, R> {
    /// Panics if `reg` does not support the semi-dual (no `max_omega`).
    pub fn new(prob: &'a OtProblem, reg: R, ctx: ParallelCtx) -> Self {
        assert!(
            reg.supports_semidual(),
            "regularizer '{}' has no semi-dual inner maximization",
            reg.name()
        );
        let m = prob.m();
        let ranges = fixed_chunk_ranges(prob.n());
        let slots = (0..ranges.len())
            .map(|_| SemiRegChunk {
                grad: vec![0.0; m],
                fcol: vec![0.0; m],
                tbuf: vec![0.0; m],
                colbuf: Vec::new(),
                semid: 0.0,
            })
            .collect();
        SemiRegOracle { prob, reg, ctx, ranges, slots, stats: OracleStats::default(), cancel: None }
    }

    /// Arm (or disarm) sub-eval cancellation: the token is polled once
    /// per column chunk at one relaxed load.
    pub(crate) fn set_cancel(&mut self, cancel: Option<crate::fault::CancelToken>) {
        self.cancel = cancel;
    }

    pub fn regularizer(&self) -> &R {
        &self.reg
    }
}

impl<R: Regularizer> DualOracle for SemiRegOracle<'_, R> {
    fn shape(&self) -> (usize, usize) {
        (self.prob.m(), 0)
    }

    fn eval(&mut self, alpha: &[f64], grad: &mut [f64]) -> f64 {
        let m = self.prob.m();
        let n = self.prob.n();
        assert_eq!(alpha.len(), m);
        for (g, &ai) in grad.iter_mut().zip(&self.prob.a) {
            *g = -ai;
        }
        let prob = self.prob;
        let reg = &self.reg;
        let cancel = self.cancel.as_ref();
        self.ctx.map_chunks(&self.ranges, &mut self.slots, |_, range, slot| {
            let SemiRegChunk { grad, fcol, tbuf, colbuf, semid } = slot;
            *semid = 0.0;
            for v in grad.iter_mut() {
                *v = 0.0;
            }
            // Sub-eval cancellation checkpoint (one relaxed load per
            // chunk); a cancelled chunk merges as zeros.
            if cancel.is_some_and(|t| t.is_cancelled()) {
                return;
            }
            for j in range {
                let c_j = prob.cost_col(j, colbuf);
                for (fi, (&ai, &ci)) in fcol.iter_mut().zip(alpha.iter().zip(c_j)) {
                    *fi = ai - ci;
                }
                let val = reg
                    .max_omega(fcol, prob.b[j], tbuf)
                    .expect("constructor checked semi-dual support");
                *semid += val;
                for (g, &ti) in grad.iter_mut().zip(tbuf.iter()) {
                    *g += ti;
                }
            }
        });
        let mut semid = crate::linalg::dot(alpha, &self.prob.a);
        for slot in &self.slots {
            semid -= slot.semid;
            for (g, &pi) in grad.iter_mut().zip(&slot.grad) {
                *g += pi;
            }
        }
        self.stats.record_eval(n as u64);
        -semid
    }

    fn stats(&self) -> &OracleStats {
        &self.stats
    }

    fn parallel_ctx(&self) -> Option<&ParallelCtx> {
        Some(&self.ctx)
    }
}

/// Result of the semi-dual solve.
pub struct SemiDualResult {
    pub alpha: Vec<f64>,
    pub objective: f64,
    pub plan: crate::linalg::Mat,
    pub iterations: usize,
}

/// The unified semi-dual entry: solve `max_α αᵀa + Σ_j b_j σ_j(α)`
/// under `opts` for any regularizer with a semi-dual inner
/// maximization.
///
/// * Squared ℓ2: byte-identical to [`solve_semidual`] at the same
///   γ/L-BFGS options (the trait path stages and water-fills in the
///   exact legacy order).
/// * Negative entropy: the inner problem is a stabilized softmax —
///   the plan's columns hit the marginals `b` exactly by construction.
/// * Group lasso couples rows *within* a group across the column
///   simplex, so no separable `max_omega` exists: requesting it is a
///   structured error, not a panic.
///
/// `opts.warm_start`, when set, is the initial `α` (length m);
/// `opts.simd` is ignored (the generic staging loop is scalar and
/// bitwise equal to the SIMD staging); `opts.rho`/`opts.r`/
/// `opts.use_working_set` do not apply to the semi-dual.
pub fn solve(prob: &OtProblem, opts: &SolveOptions) -> Result<SemiDualResult> {
    let kind = opts.resolve_regularizer()?;
    if !kind.supports_semidual() {
        return Err(err!(
            "regularizer '{}' has no semi-dual (group coupling breaks column separability); \
             use squared_l2 or negentropy, or solve the full dual instead",
            kind.name()
        ));
    }
    let reg = AnyRegularizer::build(kind, opts.gamma, opts.rho, &prob.groups)?;
    let m = prob.m();
    let n = prob.n();
    let x0 = match &opts.warm_start {
        Some(a0) if a0.len() != m => {
            return Err(err!(
                "warm-start iterate has length {}, the semi-dual needs m = {}",
                a0.len(),
                m
            ))
        }
        Some(a0) => a0.clone(),
        None => vec![0.0; m],
    };
    let start = std::time::Instant::now();
    let ctx = opts.make_ctx();
    let pool_at_start =
        if opts.observer.is_some() { Some(ctx.pool_stats()) } else { None };
    let _solve_span = crate::obs::Span::start_full(crate::obs::names::SOLVE, opts.trace_id);
    let mut oracle = SemiRegOracle::new(prob, &reg, ctx.clone());
    oracle.set_cancel(opts.cancel.clone());
    let mut solver = Lbfgs::new(x0, opts.lbfgs.clone(), &mut oracle);
    // Stepped (not `run`) so cancellation and failpoints get a
    // checkpoint between iterations; without a token this is the same
    // call sequence and the results stay byte-identical.
    let stop = loop {
        if opts.cancel.as_ref().is_some_and(|t| t.is_cancelled()) {
            break StopReason::Cancelled;
        }
        crate::fault::check(crate::fault::sites::ORACLE_EVAL)?;
        match solver.step(&mut oracle) {
            StepStatus::Continue => {}
            StepStatus::Stopped(reason) => break reason,
        }
    };
    if stop == StopReason::Cancelled {
        return Err(err!(
            "solve cancelled after {} semi-dual iterations (deadline passed or caller cancelled)",
            solver.iterations()
        ));
    }
    let iterations = solver.iterations();
    let (alpha, f) = solver.into_solution();
    if let Some(hook) = &opts.observer {
        // The semi-dual has no screening or working set, so the report
        // carries the eval counters and pool utilization only.
        let stats = oracle.stats();
        hook.emit(&crate::obs::SolveReport {
            method: format!("semidual+{}", reg.name()),
            trace_id: opts.trace_id,
            stop: stop.name(),
            iterations,
            outer_rounds: 0,
            evals: stats.evals,
            line_search_evals: stats.evals.saturating_sub(iterations as u64 + 1),
            grads_computed: stats.grads_computed,
            grads_skipped: stats.grads_skipped,
            ub_checks: stats.ub_checks,
            ws_hits: stats.ws_hits,
            tiles_built: stats.tiles_built,
            skipped_group_fraction: crate::obs::report::skipped_fraction(
                stats.grads_computed,
                stats.grads_skipped,
            ),
            simd_backend: "scalar",
            rounds: Vec::new(),
            pool: match pool_at_start {
                Some(at_start) => ctx.pool_stats().since(&at_start),
                None => crate::obs::PoolUtilization::default(),
            },
            wall_time_s: start.elapsed().as_secs_f64(),
        });
    }
    let mut plan = crate::linalg::Mat::zeros(m, n);
    let mut fcol = vec![0.0; m];
    let mut t = vec![0.0; m];
    let mut colbuf = Vec::new();
    for j in 0..n {
        let c_j = prob.cost_col(j, &mut colbuf);
        for i in 0..m {
            fcol[i] = alpha[i] - c_j[i];
        }
        reg.max_omega(&fcol, prob.b[j], &mut t)
            .expect("support checked above");
        for i in 0..m {
            plan[(i, j)] = t[i];
        }
    }
    Ok(SemiDualResult { alpha, objective: -f, plan, iterations })
}

/// Solve the quadratic semi-dual with L-BFGS and recover the plan.
pub fn solve_semidual(prob: &OtProblem, gamma: f64, opts: &LbfgsOptions) -> SemiDualResult {
    solve_semidual_inner(prob, gamma, opts, &ParallelCtx::new(1), SimdMode::Auto)
}

/// [`solve_semidual`] with `threads` intra-solve oracle workers —
/// bit-identical to the serial solve for every thread count.
#[deprecated(note = "use `semidual::solve` with `SolveOptions::threads`")]
pub fn solve_semidual_threads(
    prob: &OtProblem,
    gamma: f64,
    opts: &LbfgsOptions,
    threads: usize,
) -> SemiDualResult {
    solve_semidual_inner(prob, gamma, opts, &ParallelCtx::new(threads), SimdMode::Auto)
}

/// [`solve_semidual`] with an explicit SIMD policy
/// (`SimdMode::Scalar` forces the scalar staging loop) — byte-equal
/// results on every backend; `tests/simd_equivalence.rs` asserts it.
#[deprecated(note = "use `semidual::solve` with `SolveOptions::threads`/`simd`")]
pub fn solve_semidual_simd(
    prob: &OtProblem,
    gamma: f64,
    opts: &LbfgsOptions,
    threads: usize,
    simd: SimdMode,
) -> SemiDualResult {
    solve_semidual_inner(prob, gamma, opts, &ParallelCtx::new(threads), simd)
}

/// [`solve_semidual`] over a caller-provided long-lived parallel
/// context — one parked worker set across warm/repeat solves.
#[deprecated(note = "use `semidual::solve` with `SolveOptions::ctx`")]
pub fn solve_semidual_ctx(
    prob: &OtProblem,
    gamma: f64,
    opts: &LbfgsOptions,
    ctx: &ParallelCtx,
) -> SemiDualResult {
    solve_semidual_inner(prob, gamma, opts, ctx, SimdMode::Auto)
}

/// [`solve_semidual_ctx`] with an explicit SIMD policy.
#[deprecated(note = "use `semidual::solve` with `SolveOptions::ctx`/`simd`")]
pub fn solve_semidual_ctx_simd(
    prob: &OtProblem,
    gamma: f64,
    opts: &LbfgsOptions,
    ctx: &ParallelCtx,
    simd: SimdMode,
) -> SemiDualResult {
    solve_semidual_inner(prob, gamma, opts, ctx, simd)
}

/// The legacy quadratic path every shim funnels into (kept alongside
/// [`solve`] so `tests/simd_equivalence.rs` and
/// `tests/parallel_determinism.rs` pin its trajectory unmodified).
fn solve_semidual_inner(
    prob: &OtProblem,
    gamma: f64,
    opts: &LbfgsOptions,
    ctx: &ParallelCtx,
    simd: SimdMode,
) -> SemiDualResult {
    let m = prob.m();
    let n = prob.n();
    let mut oracle = SemiDualOracle::build(prob, gamma, ctx.clone(), simd);
    let mut solver = Lbfgs::new(vec![0.0; m], opts.clone(), &mut oracle);
    solver.run(&mut oracle);
    let iterations = solver.iterations();
    let (alpha, f) = solver.into_solution();
    let mut plan = crate::linalg::Mat::zeros(m, n);
    let mut fcol = vec![0.0; m];
    let mut colbuf = Vec::new();
    for j in 0..n {
        let c_j = prob.cost_col(j, &mut colbuf);
        for i in 0..m {
            fcol[i] = alpha[i] - c_j[i];
        }
        let (t, _) = waterfill(&fcol, gamma, prob.b[j]);
        for i in 0..m {
            plan[(i, j)] = t[i];
        }
    }
    SemiDualResult { alpha, objective: -f, plan, iterations }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;

    #[test]
    fn waterfill_respects_constraints() {
        let mut rng = Pcg64::new(4);
        for _ in 0..200 {
            let m = 1 + rng.below(12);
            let f: Vec<f64> = (0..m).map(|_| rng.uniform(-2.0, 2.0)).collect();
            let mass = rng.uniform(0.01, 2.0);
            let gamma = rng.uniform(0.05, 3.0);
            let (t, _) = waterfill(&f, gamma, mass);
            assert!(t.iter().all(|&v| v >= 0.0));
            let s: f64 = t.iter().sum();
            assert!((s - mass).abs() < 1e-9, "mass {s} != {mass}");
        }
    }

    #[test]
    fn waterfill_is_optimal_vs_random_feasible() {
        let mut rng = Pcg64::new(9);
        let f = vec![1.0, -0.5, 0.3, 0.0];
        let gamma = 0.7;
        let mass = 1.0;
        let (t, val) = waterfill(&f, gamma, mass);
        let obj = |t: &[f64]| -> f64 {
            f.iter().zip(t).map(|(&a, &b)| a * b).sum::<f64>()
                - 0.5 * gamma * t.iter().map(|v| v * v).sum::<f64>()
        };
        assert!((obj(&t) - val).abs() < 1e-12);
        for _ in 0..500 {
            // Random point on the simplex·mass.
            let mut cand: Vec<f64> = (0..4).map(|_| rng.exp1()).collect();
            let s: f64 = cand.iter().sum();
            cand.iter_mut().for_each(|v| *v *= mass / s);
            assert!(obj(&cand) <= val + 1e-9);
        }
    }

    #[test]
    fn semidual_plan_hits_column_marginals_exactly() {
        let mut rng = Pcg64::new(11);
        let cost = Mat::from_fn(6, 4, |_, _| rng.uniform(0.0, 1.0));
        let prob = super::super::dual::OtProblem::from_parts(
            vec![1.0 / 6.0; 6],
            vec![0.25; 4],
            &cost,
            &[0, 0, 1, 1, 2, 2],
        );
        let res = solve_semidual(&prob, 0.1, &LbfgsOptions::default());
        let cs = res.plan.col_sums();
        for (&got, &want) in cs.iter().zip(&prob.b) {
            assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        }
        // Row marginals approach a as the solve converges.
        let rs = res.plan.row_sums();
        let err: f64 = rs.iter().zip(&prob.a).map(|(&r, &a)| (r - a).abs()).sum();
        assert!(err < 0.05, "row marginal error {err}");
    }
}
