//! L3 coordinator: the system around the solver.
//!
//! * [`config`] — typed experiment configuration (JSON files / CLI).
//! * [`registry`] — dataset registry: name + params → [`crate::data::DomainPair`].
//! * [`sweep`] — the hyperparameter sweep scheduler: (γ × ρ × method)
//!   jobs over a thread pool, per-job metrics, paper-style gain
//!   aggregation.
//! * [`metrics`] — process-wide counters/timers/gauges/histograms with
//!   JSON snapshots (latency percentiles included).
//! * [`service`] — a line-delimited-JSON TCP OT service + client: submit
//!   solve requests against named datasets, get distances and plan
//!   statistics back. Execution is delegated to the [`crate::serve`]
//!   engine (admission control with deadlines and backpressure,
//!   micro-batching, warm-start dual cache). Python never runs here;
//!   artifacts built by `make artifacts` are loaded through
//!   `crate::runtime` (requires the `xla` cargo feature) when a request
//!   selects the `xla-origin` backend.

pub mod config;
pub mod metrics;
pub mod registry;
pub mod service;
pub mod sweep;
