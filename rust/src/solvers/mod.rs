//! Gradient-based solvers (substrate — scipy/L-BFGS-B is what the paper
//! used; we implement L-BFGS with a strong-Wolfe line search from
//! scratch, plus a first-order reference solver for tests).
//!
//! Solvers talk to problems through [`crate::ot::dual::DualOracle`], so
//! the dense baseline, the screening method and the XLA-backed oracle
//! all share the same optimization loop — a requirement for the paper's
//! Theorem 2 (identical trajectories) to be observable.

pub mod gd;
pub mod lbfgs;
pub mod linesearch;

/// Why a solver stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// `‖∇f‖∞ ≤ gtol`.
    GradTol,
    /// Relative objective decrease below `ftol`.
    FTol,
    /// Iteration budget exhausted.
    MaxIters,
    /// The line search could not find an acceptable step (typically
    /// means we are at numerical convergence).
    LineSearchFailed,
    /// A [`crate::fault::CancelToken`] fired (deadline or explicit
    /// cancel); the iterate is valid but unconverged.
    Cancelled,
}

impl StopReason {
    /// Stable machine-readable label (telemetry, `SolveReport`).
    pub fn name(&self) -> &'static str {
        match self {
            StopReason::GradTol => "grad_tol",
            StopReason::FTol => "ftol",
            StopReason::MaxIters => "max_iters",
            StopReason::LineSearchFailed => "line_search_failed",
            StopReason::Cancelled => "cancelled",
        }
    }

    /// Whether the stop indicates numerical convergence — the gate for
    /// seeding the warm-start cache. `MaxIters` and `Cancelled` results
    /// are valid iterates but must never seed other solves' caches.
    pub fn converged(&self) -> bool {
        matches!(
            self,
            StopReason::GradTol | StopReason::FTol | StopReason::LineSearchFailed
        )
    }
}

/// Outcome of one solver step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepStatus {
    Continue,
    Stopped(StopReason),
}
