//! Figure 2: processing-time gain vs number of classes on the
//! controlled synthetic dataset (g = 10, n = m = |L|·g).
//!
//! Paper shape: gain > 1 everywhere and growing with |L| (up to 6.8× at
//! |L| = 1280 on the authors' Xeon). Full mode sweeps |L| up to 320 by
//! default (set `GRPOT_FIG2_MAX_L` to go higher on a big box).

mod common;

use common::*;
use grpot::data::synthetic;

fn main() {
    banner("fig2: gain vs #classes");
    let max_l: usize = std::env::var("GRPOT_FIG2_MAX_L")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(size3(10, 40, 320));
    let class_counts: Vec<usize> = [10usize, 20, 40, 80, 160, 320, 640, 1280]
        .into_iter()
        .filter(|&l| l <= max_l)
        .collect();
    let gammas = gamma_grid();
    let rhos = rho_grid();

    let g = size3(3, 10, 10);
    let mut blocks = Vec::new();
    for &l in &class_counts {
        let pair = synthetic::controlled_classes(l, g, 0xF162);
        let prob = problem_of(&pair);
        println!("|L|={l} (m=n={}) …", prob.m());
        let rows = gain_sweep(&prob, &gammas, &rhos, 10);
        for r in &rows {
            println!(
                "  gamma={:<8} gain={:.2}x skip_rate={:.3}",
                r.gamma, r.gain, r.skip_rate
            );
            assert!(r.objectives_match, "Theorem 2 violated at |L|={l}");
        }
        blocks.push((format!("L={l}"), rows));
    }
    emit_gain_table(
        "Fig. 2 — processing-time gain vs number of classes (synthetic, g=10)",
        "fig2_synthetic_classes",
        &blocks,
    );

    // Shape check: the best per-|L| gain should not shrink as |L| grows.
    let best_gain = |rows: &[GainRow]| rows.iter().map(|r| r.gain).fold(0.0f64, f64::max);
    let first = best_gain(&blocks.first().unwrap().1);
    let last = best_gain(&blocks.last().unwrap().1);
    println!(
        "best gain at |L|={}: {first:.2}x → at |L|={}: {last:.2}x",
        class_counts[0],
        class_counts[class_counts.len() - 1]
    );
    if last < first {
        println!("WARNING: gain did not grow with |L| (expected paper shape)");
    }
}
