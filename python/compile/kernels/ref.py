"""Pure-jnp oracle for the grouped soft-threshold gradient (Eq. 5).

This is the correctness reference the Pallas kernel is validated against
(pytest + hypothesis). It mirrors the Rust implementation in
``rust/src/ot/dual.rs`` exactly:

    f        = alpha ⊕ beta − C                       (m × n)
    z_{l,j}  = ‖[f_[l,:,j]]₊‖₂                        (L × n)
    T_[l]    = [1 − tau/z]₊ · [f_[l]]₊ / lambda_quad
    psi_j    = Σ_l [z_{l,j} − tau]₊² / (2·lambda_quad)

Uniform groups (m = L·g, contiguous) use a reshape; ragged groups go
through segment reductions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def grad_psi_uniform(alpha, beta, cost, num_groups: int, group_size: int, tau, lambda_quad):
    """Plan T = ∇ψ for uniform contiguous groups.

    Returns ``(t, z)`` with ``t: (m, n)`` and ``z: (L, n)``.
    Shapes: alpha (m,), beta (n,), cost (m, n); m == num_groups*group_size.
    """
    m, n = cost.shape
    assert m == num_groups * group_size, "uniform group shape mismatch"
    f = alpha[:, None] + beta[None, :] - cost
    fp = jnp.maximum(f, 0.0)
    fp_g = fp.reshape(num_groups, group_size, n)
    z = jnp.sqrt(jnp.sum(fp_g * fp_g, axis=1))  # (L, n)
    safe_z = jnp.where(z > 0.0, z, 1.0)
    scale = jnp.where(z > tau, (z - tau) / (lambda_quad * safe_z), 0.0)  # (L, n)
    t = (fp_g * scale[:, None, :]).reshape(m, n)
    return t, z


def grad_psi_ragged(alpha, beta, cost, group_ids, num_groups: int, tau, lambda_quad):
    """Ragged-group variant: ``group_ids`` maps each source row to its group.

    Returns ``(t, z)`` with ``z: (L, n)``.
    """
    f = alpha[:, None] + beta[None, :] - cost
    fp = jnp.maximum(f, 0.0)
    zsq = jax.ops.segment_sum(fp * fp, group_ids, num_segments=num_groups)
    z = jnp.sqrt(zsq)  # (L, n)
    safe_z = jnp.where(z > 0.0, z, 1.0)
    scale = jnp.where(z > tau, (z - tau) / (lambda_quad * safe_z), 0.0)
    t = fp * scale[group_ids, :]
    return t, z


def psi_from_z(z, tau, lambda_quad):
    """Σ over all (l, j) of [z − tau]₊² / (2 λ_quad)."""
    slack = jnp.maximum(z - tau, 0.0)
    return jnp.sum(slack * slack) / (2.0 * lambda_quad)


def dual_obj_grad_ref(alpha, beta, a, b, cost, num_groups, group_size, tau, lambda_quad):
    """Negated dual objective and its gradient — the L2 reference.

    Returns ``(neg_obj, grad_alpha, grad_beta)`` matching the Rust
    ``eval_dense`` convention (gradient of the NEGATED dual).
    """
    t, z = grad_psi_uniform(alpha, beta, cost, num_groups, group_size, tau, lambda_quad)
    psi = psi_from_z(z, tau, lambda_quad)
    dual = jnp.dot(alpha, a) + jnp.dot(beta, b) - psi
    grad_alpha = jnp.sum(t, axis=1) - a
    grad_beta = jnp.sum(t, axis=0) - b
    return -dual, grad_alpha, grad_beta
