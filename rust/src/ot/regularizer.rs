//! Pluggable regularizers for the OT dual/semi-dual oracles.
//!
//! The paper's machinery is specific to the group-sparse (ℓ1ℓ2 + ½‖·‖²)
//! regularizer, but the oracle structure is not: every smooth relaxed
//! dual evaluation reduces to, per column `j`, the conjugate pair
//!
//! ```text
//! ψ_j   = Ω*(f_j)          (value),      f_j = α + β_j·1 − c_j
//! t_j   = ∇Ω*(f_j)         (gradient = transported mass)
//! ```
//!
//! and every semi-dual evaluation to the constrained inner maximization
//! `max {⟨f, t⟩ − Ω(t) : t ≥ 0, 1ᵀt = b_j}` (Blondel, Seguy & Rolet
//! 2018's `delta_Omega` / `max_Omega` pair). The [`Regularizer`] trait
//! captures exactly that interface; [`GroupLasso`] is the paper's
//! regularizer moved behind it, [`SquaredL2`] and [`NegEntropy`] are the
//! two classic smooth alternatives.
//!
//! Safe screening is regularizer-specific: the paper's Eq. 6/7 bounds
//! hold for the group-lasso conjugate only. The [`ScreeningRule`] trait
//! isolates that arithmetic, so the paper's safe-skip bound becomes one
//! implementation of a generic screening interface
//! ([`GroupLassoRule`], consumed by
//! [`crate::ot::screening::ScreeningOracle`]); regularizers without a
//! rule simply run dense.
//!
//! **Byte-identity contract.** [`GroupLasso::delta_omega`] performs the
//! same floating-point operations in the same order as the scalar
//! reference kernel [`crate::ot::dual::group_grad_contrib`], and
//! [`DenseRegOracle`] stages/reduces per-chunk partials in the same
//! ascending order as the dense evaluator — so a group-lasso solve
//! through the trait is bit-identical to the pre-trait path (asserted by
//! `tests/regularizer_equivalence.rs`). The production group-lasso path
//! (SIMD kernels, screening, packed tiles) is untouched and stays the
//! default.

use super::dual::{DualOracle, DualParams, KernelConsts, OracleStats, OtProblem};
use super::semidual::waterfill;
use crate::err;
use crate::error::Result;
use crate::groups::GroupStructure;
use crate::linalg::Mat;
use crate::pool::{fixed_chunk_ranges, ParallelCtx};
use std::ops::Range;

/// Which regularizer a solve uses — the wire/CLI/config-level selector
/// (`grpot solve --reg`, the serve request's `regularizer` field,
/// `SweepConfig`). Parsing mirrors [`crate::coordinator::config::Method`]:
/// unknown names are a structured error, never a panic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RegKind {
    /// The paper's group-sparse regularizer: ½λ‖t‖² + τ Σ_l ‖t_[l]‖₂.
    /// The only kind with a safe-screening rule; the production path.
    #[default]
    GroupLasso,
    /// Squared ℓ2: (γ/2)‖t‖² (Blondel et al. 2018). Smooth dual and an
    /// exact-marginal semi-dual (water-filling inner problem).
    SquaredL2,
    /// Negative entropy: γ Σ t ln t (Cuturi 2013's smoothing). Smooth
    /// dual and a softmax semi-dual.
    NegEntropy,
}

impl RegKind {
    pub fn name(&self) -> &'static str {
        match self {
            RegKind::GroupLasso => "group_lasso",
            RegKind::SquaredL2 => "squared_l2",
            RegKind::NegEntropy => "negentropy",
        }
    }

    pub fn parse(s: &str) -> Result<RegKind> {
        match s {
            "group_lasso" | "group-lasso" | "grouplasso" | "gl" => Ok(RegKind::GroupLasso),
            "squared_l2" | "squared-l2" | "l2" => Ok(RegKind::SquaredL2),
            "negentropy" | "neg_entropy" | "entropy" => Ok(RegKind::NegEntropy),
            other => Err(err!(
                "unknown regularizer '{other}' (expected group_lasso|squared_l2|negentropy)"
            )),
        }
    }

    /// The default kind when a [`crate::ot::solve::SolveOptions`] leaves
    /// `regularizer` unset: `GRPOT_REG` if present (a bad value is a
    /// structured error), else [`RegKind::GroupLasso`]. Like
    /// `GRPOT_SIMD`, the env var replaces only the unset default — an
    /// explicit selection always wins, so the legacy (pre-trait) entry
    /// points, which pin the group-lasso kind, can never be re-routed
    /// by the environment.
    pub fn env_default() -> Result<RegKind> {
        match std::env::var("GRPOT_REG") {
            Ok(s) => RegKind::parse(&s),
            Err(_) => Ok(RegKind::GroupLasso),
        }
    }

    /// Whether the kind has a safe-screening rule (Eq. 6/7 bounds).
    pub fn supports_screening(&self) -> bool {
        matches!(self, RegKind::GroupLasso)
    }

    /// Whether the kind has a semi-dual inner solver (`max_omega`).
    pub fn supports_semidual(&self) -> bool {
        !matches!(self, RegKind::GroupLasso)
    }
}

/// Safe-screening bound arithmetic for one regularizer — the generic
/// interface the paper's Eq. 6 (upper) and Eq. 7 (lower) bounds
/// implement. A (group `l`, column `j`) pair whose
/// `upper_bound ≤ threshold` is provably zero and may be skipped; one
/// whose `lower_bound > threshold` is provably active and may bypass
/// the check (working-set membership). Implementations must be pure
/// functions of their scalar inputs so the screened walk stays
/// bit-deterministic.
pub trait ScreeningRule: Sync {
    /// The activation threshold the bounds are compared against (τ for
    /// the group lasso).
    fn threshold(&self) -> f64;

    /// Upper bound on `z_{l,j}` from the snapshot norm and the positive
    /// iterate deltas (Eq. 6): sound whenever it is ≥ the exact z.
    fn upper_bound(&self, snap_z: f64, da_pos: f64, sqrt_g: f64, db_pos: f64) -> f64;

    /// Lower bound on `z_{l,j}` from the snapshot k̃/õ norms and the
    /// iterate deltas (Eq. 7): sound whenever it is ≤ the exact z.
    #[allow(clippy::too_many_arguments)]
    fn lower_bound(
        &self,
        snap_k: f64,
        snap_o: f64,
        da_nrm: f64,
        da_neg: f64,
        sqrt_g: f64,
        db_abs: f64,
        db_neg: f64,
    ) -> f64;
}

/// The paper's bounds (Lemmas 1–6) as a [`ScreeningRule`]. The bodies
/// are the exact expressions the screened oracle inlined before the
/// refactor — same operations, same order, so screening decisions are
/// byte-identical.
#[derive(Clone, Copy, Debug)]
pub struct GroupLassoRule {
    /// The group-sparsity threshold τ = γρ.
    pub tau: f64,
}

impl ScreeningRule for GroupLassoRule {
    #[inline]
    fn threshold(&self) -> f64 {
        self.tau
    }

    #[inline]
    fn upper_bound(&self, snap_z: f64, da_pos: f64, sqrt_g: f64, db_pos: f64) -> f64 {
        snap_z + da_pos + sqrt_g * db_pos
    }

    #[inline]
    fn lower_bound(
        &self,
        snap_k: f64,
        snap_o: f64,
        da_nrm: f64,
        da_neg: f64,
        sqrt_g: f64,
        db_abs: f64,
        db_neg: f64,
    ) -> f64 {
        snap_k - da_nrm - sqrt_g * db_abs - snap_o - da_neg - sqrt_g * db_neg
    }
}

/// The conjugate value/gradient interface every dual oracle needs, plus
/// the semi-dual inner maximization where one exists.
///
/// Contract:
/// * `delta_omega(f, grad)` returns `(Ω*(f), 1ᵀ∇Ω*(f))` for one column
///   slack vector `f = α + β_j·1 − c_j` and **accumulates** `∇Ω*(f)`
///   into `grad` (callers pass per-chunk partial gradients).
/// * `max_omega(f, mass, t)` solves
///   `max {⟨f, t⟩ − Ω(t) : t ≥ 0, 1ᵀt = mass}`, writes the maximizer
///   into `t` and returns the value — `None` when the regularizer has
///   no semi-dual solver.
/// * `grad_units` is the per-column unit of the `grads_computed`
///   counter: the group lasso counts per (group, column) pair like the
///   dense baseline; scalar regularizers count per column.
pub trait Regularizer: Sync {
    fn name(&self) -> &'static str;

    /// Conjugate value and mass for one column; accumulates the
    /// conjugate gradient (= transported mass per source point) into
    /// `grad`. Returns `(psi, mass)`.
    fn delta_omega(&self, f: &[f64], grad: &mut [f64]) -> (f64, f64);

    /// Semi-dual inner maximization under the exact column marginal;
    /// `None` when unsupported.
    fn max_omega(&self, _f: &[f64], _mass: f64, _t: &mut [f64]) -> Option<f64> {
        None
    }

    /// How many `grads_computed` units one `delta_omega` call accounts
    /// for (see trait docs).
    fn grad_units(&self) -> u64 {
        1
    }

    fn supports_semidual(&self) -> bool {
        false
    }

    /// The safe-screening rule, when the conjugate admits one.
    fn screening(&self) -> Option<&dyn ScreeningRule> {
        None
    }
}

/// The paper's group-sparse regularizer behind the trait: the exact
/// scalar arithmetic of [`crate::ot::dual::group_grad_contrib`] on a
/// materialized column (two passes per group, positive-part norm, skip
/// at `z² ≤ τ²`).
pub struct GroupLasso {
    consts: KernelConsts,
    groups: GroupStructure,
    rule: GroupLassoRule,
}

impl GroupLasso {
    pub fn new(params: &DualParams, groups: &GroupStructure) -> Self {
        params.validate();
        let consts = KernelConsts::new(params);
        GroupLasso { rule: GroupLassoRule { tau: consts.tau }, consts, groups: groups.clone() }
    }
}

impl Regularizer for GroupLasso {
    fn name(&self) -> &'static str {
        RegKind::GroupLasso.name()
    }

    fn delta_omega(&self, f: &[f64], grad: &mut [f64]) -> (f64, f64) {
        let mut psi = 0.0;
        let mut col_mass = 0.0;
        for l in 0..self.groups.num_groups() {
            let range = self.groups.range(l);
            // Pass 1: z² = ‖[f_[l]]₊‖² — identical expression order to
            // the fused kernel (fp recomputed in pass 2; max(f, 0) is
            // exact, so the value is bitwise the staged one).
            let mut zsq = 0.0;
            for i in range.clone() {
                let v = f[i];
                let fp = if v > 0.0 { v } else { 0.0 };
                zsq += fp * fp;
            }
            if zsq <= self.consts.tau_sq {
                continue;
            }
            let z = zsq.sqrt();
            let slack = z - self.consts.tau;
            let scale = slack * self.consts.inv_lq / z;
            let mut mass = 0.0;
            for i in range {
                let v = f[i];
                let fp = if v > 0.0 { v } else { 0.0 };
                let t = scale * fp;
                grad[i] += t;
                mass += t;
            }
            psi += slack * slack * self.consts.half_inv_lq;
            col_mass += mass;
        }
        (psi, col_mass)
    }

    fn grad_units(&self) -> u64 {
        self.groups.num_groups() as u64
    }

    fn screening(&self) -> Option<&dyn ScreeningRule> {
        Some(&self.rule)
    }
}

/// Squared-ℓ2 regularizer Ω(t) = (γ/2)‖t‖²: conjugate
/// Ω*(f) = ‖[f]₊‖²/(2γ), ∇Ω*(f) = [f]₊/γ; the semi-dual inner problem
/// is the water-filling / simplex projection already used by the
/// quadratic semi-dual solver.
pub struct SquaredL2 {
    gamma: f64,
}

impl SquaredL2 {
    pub fn new(gamma: f64) -> Result<Self> {
        if !(gamma.is_finite() && gamma > 0.0) {
            return Err(err!("squared_l2 needs gamma > 0, got {gamma}"));
        }
        Ok(SquaredL2 { gamma })
    }
}

impl Regularizer for SquaredL2 {
    fn name(&self) -> &'static str {
        RegKind::SquaredL2.name()
    }

    fn delta_omega(&self, f: &[f64], grad: &mut [f64]) -> (f64, f64) {
        let inv_g = 1.0 / self.gamma;
        let mut sq = 0.0;
        let mut mass = 0.0;
        for (gi, &v) in grad.iter_mut().zip(f) {
            if v > 0.0 {
                sq += v * v;
                let t = v * inv_g;
                *gi += t;
                mass += t;
            }
        }
        (0.5 * sq * inv_g, mass)
    }

    fn max_omega(&self, f: &[f64], mass: f64, t: &mut [f64]) -> Option<f64> {
        let (tv, val) = waterfill(f, self.gamma, mass);
        t.copy_from_slice(&tv);
        Some(val)
    }

    fn supports_semidual(&self) -> bool {
        true
    }
}

/// Negative-entropy regularizer Ω(t) = γ Σ t ln t: conjugate
/// Ω*(f) = γ Σ exp(f/γ − 1) with ∇Ω*(f) = exp(f/γ − 1) (Blondel et
/// al.'s `delta_Omega`); the semi-dual inner maximizer is the softmax
/// `t = mass·softmax(f/γ)` with value `mass·(max + γ(ln s − ln mass))`
/// computed in max-shifted (overflow-safe) form. The *dual* conjugate
/// is evaluated unshifted — faithful to the reference formulas — so
/// extremely large `f/γ` can overflow to `inf`; keep γ away from 0 on
/// the full-dual path (the semi-dual path is stabilized).
pub struct NegEntropy {
    gamma: f64,
}

impl NegEntropy {
    pub fn new(gamma: f64) -> Result<Self> {
        if !(gamma.is_finite() && gamma > 0.0) {
            return Err(err!("negentropy needs gamma > 0, got {gamma}"));
        }
        Ok(NegEntropy { gamma })
    }
}

impl Regularizer for NegEntropy {
    fn name(&self) -> &'static str {
        RegKind::NegEntropy.name()
    }

    fn delta_omega(&self, f: &[f64], grad: &mut [f64]) -> (f64, f64) {
        let inv_g = 1.0 / self.gamma;
        let mut mass = 0.0;
        for (gi, &v) in grad.iter_mut().zip(f) {
            let t = (v * inv_g - 1.0).exp();
            *gi += t;
            mass += t;
        }
        (self.gamma * mass, mass)
    }

    fn max_omega(&self, f: &[f64], mass: f64, t: &mut [f64]) -> Option<f64> {
        let mut mx = f64::NEG_INFINITY;
        for &v in f {
            mx = mx.max(v);
        }
        let inv_g = 1.0 / self.gamma;
        let mut s = 0.0;
        for (ti, &v) in t.iter_mut().zip(f) {
            let e = ((v - mx) * inv_g).exp();
            *ti = e;
            s += e;
        }
        let scale = mass / s;
        for ti in t.iter_mut() {
            *ti *= scale;
        }
        Some(mass * (mx + self.gamma * (s.ln() - mass.ln())))
    }

    fn supports_semidual(&self) -> bool {
        true
    }
}

// `&R` works wherever `R` does — oracles borrow shared regularizers.
impl<R: Regularizer + ?Sized> Regularizer for &R {
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn delta_omega(&self, f: &[f64], grad: &mut [f64]) -> (f64, f64) {
        (**self).delta_omega(f, grad)
    }
    fn max_omega(&self, f: &[f64], mass: f64, t: &mut [f64]) -> Option<f64> {
        (**self).max_omega(f, mass, t)
    }
    fn grad_units(&self) -> u64 {
        (**self).grad_units()
    }
    fn supports_semidual(&self) -> bool {
        (**self).supports_semidual()
    }
    fn screening(&self) -> Option<&dyn ScreeningRule> {
        (**self).screening()
    }
}

/// Enum dispatch over the shipped regularizers — what the solve entry
/// points instantiate from a [`RegKind`] (no boxing on the hot path;
/// the match disappears behind the per-column call).
pub enum AnyRegularizer {
    GroupLasso(GroupLasso),
    SquaredL2(SquaredL2),
    NegEntropy(NegEntropy),
}

impl AnyRegularizer {
    /// Instantiate `kind` for a problem's group structure and (γ, ρ).
    /// Scalar regularizers use γ only; ρ is the group-lasso balance.
    pub fn build(kind: RegKind, gamma: f64, rho: f64, groups: &GroupStructure) -> Result<Self> {
        if !(gamma.is_finite() && gamma > 0.0) {
            return Err(err!("regularizer '{}' needs gamma > 0, got {gamma}", kind.name()));
        }
        Ok(match kind {
            RegKind::GroupLasso => {
                if !(rho.is_finite() && (0.0..1.0).contains(&rho)) {
                    return Err(err!("group_lasso needs rho in [0, 1), got {rho}"));
                }
                AnyRegularizer::GroupLasso(GroupLasso::new(&DualParams::new(gamma, rho), groups))
            }
            RegKind::SquaredL2 => AnyRegularizer::SquaredL2(SquaredL2::new(gamma)?),
            RegKind::NegEntropy => AnyRegularizer::NegEntropy(NegEntropy::new(gamma)?),
        })
    }
}

impl Regularizer for AnyRegularizer {
    fn name(&self) -> &'static str {
        match self {
            AnyRegularizer::GroupLasso(r) => r.name(),
            AnyRegularizer::SquaredL2(r) => r.name(),
            AnyRegularizer::NegEntropy(r) => r.name(),
        }
    }

    fn delta_omega(&self, f: &[f64], grad: &mut [f64]) -> (f64, f64) {
        match self {
            AnyRegularizer::GroupLasso(r) => r.delta_omega(f, grad),
            AnyRegularizer::SquaredL2(r) => r.delta_omega(f, grad),
            AnyRegularizer::NegEntropy(r) => r.delta_omega(f, grad),
        }
    }

    fn max_omega(&self, f: &[f64], mass: f64, t: &mut [f64]) -> Option<f64> {
        match self {
            AnyRegularizer::GroupLasso(r) => r.max_omega(f, mass, t),
            AnyRegularizer::SquaredL2(r) => r.max_omega(f, mass, t),
            AnyRegularizer::NegEntropy(r) => r.max_omega(f, mass, t),
        }
    }

    fn grad_units(&self) -> u64 {
        match self {
            AnyRegularizer::GroupLasso(r) => r.grad_units(),
            AnyRegularizer::SquaredL2(r) => r.grad_units(),
            AnyRegularizer::NegEntropy(r) => r.grad_units(),
        }
    }

    fn supports_semidual(&self) -> bool {
        match self {
            AnyRegularizer::GroupLasso(r) => r.supports_semidual(),
            AnyRegularizer::SquaredL2(r) => r.supports_semidual(),
            AnyRegularizer::NegEntropy(r) => r.supports_semidual(),
        }
    }

    fn screening(&self) -> Option<&dyn ScreeningRule> {
        match self {
            AnyRegularizer::GroupLasso(r) => r.screening(),
            AnyRegularizer::SquaredL2(r) => r.screening(),
            AnyRegularizer::NegEntropy(r) => r.screening(),
        }
    }
}

/// Per-chunk scratch for [`DenseRegOracle`].
struct RegChunk {
    /// Partial ∇α contribution (length m).
    grad_alpha: Vec<f64>,
    /// Per-column transported mass (∂/∂β_j), length = chunk width.
    col_mass: Vec<f64>,
    /// `α + β_j·1 − c_j` staging buffer (length m).
    fcol: Vec<f64>,
    /// Cost-column staging for the factored backend (empty and unused
    /// when the cost is dense — `cost_col` returns the resident row).
    colbuf: Vec<f64>,
    /// Partial Σ_j ψ_j, folded in ascending column order.
    psi: f64,
    /// `grads_computed` units this chunk contributed.
    grads: u64,
}

/// Dense negated-dual oracle over any [`Regularizer`] — the generic
/// counterpart of [`crate::ot::origin::OriginOracle`]. Column chunks
/// evaluate in parallel on the context's persistent parked workers and
/// partials combine in fixed ascending chunk order, so results are
/// bit-identical for every thread count; for [`GroupLasso`] the whole
/// evaluation is additionally bit-identical to the specialized dense
/// evaluator (same per-element arithmetic, same accumulation order).
/// The walk is scalar — regularizer-specific SIMD stays with the
/// specialized group-lasso kernels.
pub struct DenseRegOracle<'a, R: Regularizer> {
    prob: &'a OtProblem,
    reg: R,
    ctx: ParallelCtx,
    ranges: Vec<Range<usize>>,
    slots: Vec<RegChunk>,
    stats: OracleStats,
    /// Cooperative cancellation, polled once per column chunk (one
    /// relaxed load). `None` skips the poll; an armed-but-uncancelled
    /// token is bitwise transparent.
    cancel: Option<crate::fault::CancelToken>,
}

impl<'a, R: Regularizer> DenseRegOracle<'a, R> {
    pub fn new(prob: &'a OtProblem, reg: R, ctx: ParallelCtx) -> Self {
        let m = prob.m();
        let ranges = fixed_chunk_ranges(prob.n());
        let slots = ranges
            .iter()
            .map(|r| RegChunk {
                grad_alpha: vec![0.0; m],
                col_mass: vec![0.0; r.len()],
                fcol: vec![0.0; m],
                colbuf: Vec::new(),
                psi: 0.0,
                grads: 0,
            })
            .collect();
        DenseRegOracle { prob, reg, ctx, ranges, slots, stats: OracleStats::default(), cancel: None }
    }

    /// Arm (or disarm) sub-eval cancellation: the token is polled once
    /// per column chunk at one relaxed load.
    pub(crate) fn set_cancel(&mut self, cancel: Option<crate::fault::CancelToken>) {
        self.cancel = cancel;
    }

    pub fn regularizer(&self) -> &R {
        &self.reg
    }
}

impl<R: Regularizer> DualOracle for DenseRegOracle<'_, R> {
    fn shape(&self) -> (usize, usize) {
        (self.prob.m(), self.prob.n())
    }

    fn eval(&mut self, x: &[f64], grad: &mut [f64]) -> f64 {
        let m = self.prob.m();
        let n = self.prob.n();
        debug_assert_eq!(x.len(), m + n);
        debug_assert_eq!(grad.len(), m + n);
        let (alpha, beta) = x.split_at(m);
        for (gi, &ai) in grad[..m].iter_mut().zip(&self.prob.a) {
            *gi = -ai;
        }
        for (gj, &bj) in grad[m..].iter_mut().zip(&self.prob.b) {
            *gj = -bj;
        }
        let (grad_alpha, grad_beta) = grad.split_at_mut(m);

        let prob = self.prob;
        let reg = &self.reg;
        let units = reg.grad_units();
        let cancel = self.cancel.as_ref();
        self.ctx.map_chunks(&self.ranges, &mut self.slots, |_, range, slot| {
            let RegChunk { grad_alpha, col_mass, fcol, colbuf, psi, grads } = slot;
            *psi = 0.0;
            *grads = 0;
            for v in grad_alpha.iter_mut() {
                *v = 0.0;
            }
            for v in col_mass.iter_mut() {
                *v = 0.0;
            }
            // Sub-eval cancellation checkpoint: one relaxed load per
            // chunk; a cancelled chunk merges as zeros.
            if cancel.is_some_and(|t| t.is_cancelled()) {
                return;
            }
            for (k, j) in range.enumerate() {
                let c_j = prob.cost_col(j, colbuf);
                let beta_j = beta[j];
                for ((fi, &ai), &ci) in fcol.iter_mut().zip(alpha).zip(c_j) {
                    *fi = ai + beta_j - ci;
                }
                let (p, mass) = reg.delta_omega(fcol, grad_alpha);
                *psi += p;
                col_mass[k] = mass;
                *grads += units;
            }
        });

        // Ordered reduction, ascending chunks — the determinism (and,
        // for the group lasso, byte-identity) anchor.
        let mut psi_total = 0.0;
        let mut grads = 0u64;
        for (slot, range) in self.slots.iter().zip(&self.ranges) {
            psi_total += slot.psi;
            grads += slot.grads;
            for (g, &p) in grad_alpha.iter_mut().zip(&slot.grad_alpha) {
                *g += p;
            }
            for (k, j) in range.clone().enumerate() {
                grad_beta[j] += slot.col_mass[k];
            }
        }
        self.stats.grads_computed += grads;
        self.stats.record_eval(grads);

        let dual = crate::linalg::dot(alpha, &self.prob.a)
            + crate::linalg::dot(beta, &self.prob.b)
            - psi_total;
        -dual
    }

    fn stats(&self) -> &OracleStats {
        &self.stats
    }

    fn parallel_ctx(&self) -> Option<&ParallelCtx> {
        Some(&self.ctx)
    }
}

/// Recover the transport plan at a full-dual solution `x = [α; β]` for
/// any regularizer: column `j` of the plan is `∇Ω*(α + β_j·1 − c_j)`.
/// (For the group lasso this reproduces the specialized
/// [`crate::ot::plan`] recovery.)
pub fn recover_plan_reg(prob: &OtProblem, reg: &dyn Regularizer, x: &[f64]) -> Mat {
    let m = prob.m();
    let n = prob.n();
    assert_eq!(x.len(), m + n);
    let (alpha, beta) = x.split_at(m);
    let mut plan = Mat::zeros(m, n);
    let mut fcol = vec![0.0; m];
    let mut tcol = vec![0.0; m];
    let mut colbuf = Vec::new();
    for j in 0..n {
        let c_j = prob.cost_col(j, &mut colbuf);
        for i in 0..m {
            fcol[i] = alpha[i] + beta[j] - c_j[i];
        }
        for v in tcol.iter_mut() {
            *v = 0.0;
        }
        reg.delta_omega(&fcol, &mut tcol);
        for (i, &t) in tcol.iter().enumerate() {
            plan[(i, j)] = t;
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::dual::eval_dense;
    use crate::rng::Pcg64;

    fn random_problem(seed: u64, l: usize, g: usize, n: usize) -> OtProblem {
        let mut rng = Pcg64::new(seed);
        let m = l * g;
        let cost = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
        let labels: Vec<usize> = (0..m).map(|i| i / g).collect();
        OtProblem::from_parts(vec![1.0 / m as f64; m], vec![1.0 / n as f64; n], &cost, &labels)
    }

    #[test]
    fn regkind_parse_roundtrip_and_errors() {
        for k in [RegKind::GroupLasso, RegKind::SquaredL2, RegKind::NegEntropy] {
            assert_eq!(RegKind::parse(k.name()).unwrap(), k);
        }
        assert_eq!(RegKind::parse("l2").unwrap(), RegKind::SquaredL2);
        assert_eq!(RegKind::parse("entropy").unwrap(), RegKind::NegEntropy);
        let e = RegKind::parse("bogus").unwrap_err();
        assert!(e.0.contains("unknown regularizer"), "{e}");
        assert!(RegKind::GroupLasso.supports_screening());
        assert!(!RegKind::SquaredL2.supports_screening());
        assert!(RegKind::NegEntropy.supports_semidual());
        assert!(!RegKind::GroupLasso.supports_semidual());
    }

    #[test]
    fn build_rejects_bad_hyperparameters() {
        let prob = random_problem(1, 2, 2, 3);
        for kind in [RegKind::GroupLasso, RegKind::SquaredL2, RegKind::NegEntropy] {
            assert!(AnyRegularizer::build(kind, 0.0, 0.5, &prob.groups).is_err());
            assert!(AnyRegularizer::build(kind, f64::NAN, 0.5, &prob.groups).is_err());
        }
        assert!(AnyRegularizer::build(RegKind::GroupLasso, 1.0, 1.0, &prob.groups).is_err());
        assert!(AnyRegularizer::build(RegKind::GroupLasso, 1.0, 0.0, &prob.groups).is_ok());
    }

    /// The anchor test: a group-lasso trait evaluation is bitwise equal
    /// to the specialized dense evaluator at arbitrary points.
    #[test]
    fn group_lasso_trait_eval_matches_dense_bitwise() {
        let prob = random_problem(7, 4, 3, 23);
        for (gamma, rho) in [(0.1, 0.3), (1.0, 0.5), (8.0, 0.8)] {
            let params = DualParams::new(gamma, rho);
            let reg = GroupLasso::new(&params, &prob.groups);
            for threads in [1usize, 4] {
                let mut oracle = DenseRegOracle::new(&prob, &reg, ParallelCtx::new(threads));
                let mut rng = Pcg64::new(0xF00D);
                let mut x = vec![0.0; prob.dim()];
                for _ in 0..6 {
                    for v in x.iter_mut() {
                        *v += rng.uniform(-0.3, 0.35);
                    }
                    let mut g1 = vec![0.0; prob.dim()];
                    let f1 = oracle.eval(&x, &mut g1);
                    let mut g2 = vec![0.0; prob.dim()];
                    let (f2, n2) = eval_dense(&prob, &params, &x, &mut g2);
                    assert_eq!(f1, f2, "objective γ={gamma} ρ={rho} threads={threads}");
                    assert_eq!(g1, g2, "gradient γ={gamma} ρ={rho} threads={threads}");
                    assert_eq!(oracle.stats().per_eval_grads.last(), Some(&n2));
                }
            }
        }
    }

    fn finite_diff_check(reg: &dyn Regularizer, seed: u64) {
        // ψ and ∇ψ from delta_omega must be consistent: central
        // differences of the value against the returned gradient.
        let mut rng = Pcg64::new(seed);
        let m = 7;
        let f: Vec<f64> = (0..m).map(|_| rng.uniform(-0.8, 0.9)).collect();
        let val_of = |f: &[f64]| {
            let mut sink = vec![0.0; m];
            reg.delta_omega(f, &mut sink).0
        };
        let mut grad = vec![0.0; m];
        reg.delta_omega(&f, &mut grad);
        let h = 1e-6;
        for i in 0..m {
            let mut fp = f.clone();
            fp[i] += h;
            let mut fm = f.clone();
            fm[i] -= h;
            let fd = (val_of(&fp) - val_of(&fm)) / (2.0 * h);
            assert!(
                (fd - grad[i]).abs() <= 1e-5 * (1.0 + fd.abs()),
                "coordinate {i}: fd={fd} grad={}",
                grad[i]
            );
        }
    }

    #[test]
    fn scalar_regularizer_gradients_match_finite_differences() {
        finite_diff_check(&SquaredL2::new(0.7).unwrap(), 11);
        finite_diff_check(&NegEntropy::new(0.7).unwrap(), 13);
        let prob = random_problem(5, 3, 2, 4);
        let gl = GroupLasso::new(&DualParams::new(0.6, 0.4), &prob.groups);
        // Group-lasso conjugate is C¹ too (away from the kink z = τ).
        finite_diff_check(&gl, 17);
    }

    #[test]
    fn negentropy_max_omega_is_softmax() {
        let reg = NegEntropy::new(0.5).unwrap();
        let f = [0.3, -0.2, 0.9, 0.1];
        let mass = 0.25;
        let mut t = [0.0; 4];
        let val = reg.max_omega(&f, mass, &mut t).unwrap();
        // Marginal holds exactly up to roundoff.
        let s: f64 = t.iter().sum();
        assert!((s - mass).abs() < 1e-12, "mass {s}");
        // Closed form: t_i ∝ exp(f_i/γ).
        let w: Vec<f64> = f.iter().map(|&v| (v / 0.5).exp()).collect();
        let ws: f64 = w.iter().sum();
        for (ti, wi) in t.iter().zip(&w) {
            assert!((ti - mass * wi / ws).abs() < 1e-12);
        }
        // Value matches ⟨f, t⟩ − γ Σ t ln t.
        let direct: f64 = f.iter().zip(&t).map(|(&fi, &ti)| fi * ti).sum::<f64>()
            - 0.5 * t.iter().map(|&ti| ti * ti.ln()).sum::<f64>();
        assert!((val - direct).abs() < 1e-12, "val={val} direct={direct}");
    }

    #[test]
    fn squared_l2_max_omega_delegates_to_waterfill() {
        let reg = SquaredL2::new(0.7).unwrap();
        let f = [1.0, -0.5, 0.3, 0.0];
        let mut t = [0.0; 4];
        let val = reg.max_omega(&f, 1.0, &mut t).unwrap();
        let (tw, vw) = waterfill(&f, 0.7, 1.0);
        assert_eq!(t.to_vec(), tw);
        assert_eq!(val, vw);
    }

    #[test]
    fn recover_plan_reg_columns_are_conjugate_gradients() {
        let prob = random_problem(3, 3, 2, 5);
        let reg = SquaredL2::new(0.4).unwrap();
        let mut rng = Pcg64::new(21);
        let x: Vec<f64> = (0..prob.dim()).map(|_| rng.uniform(-0.4, 0.5)).collect();
        let plan = recover_plan_reg(&prob, &reg, &x);
        let (alpha, beta) = x.split_at(prob.m());
        for j in 0..prob.n() {
            let c_j = prob.cost_t().row(j);
            for i in 0..prob.m() {
                let want = ((alpha[i] + beta[j] - c_j[i]).max(0.0)) / 0.4;
                assert!((plan[(i, j)] - want).abs() < 1e-15);
            }
        }
    }
}
