//! Solver integration: L-BFGS vs gradient descent on the actual OT dual,
//! stopping behaviour, and robustness across regularization regimes.

use grpot::data::synthetic;
use grpot::ot::dual::{DualOracle, DualParams, OtProblem};
use grpot::ot::fastot::{solve_fast_ot, FastOtConfig};
use grpot::ot::origin::OriginOracle;
use grpot::solvers::gd::{gradient_descent, GdOptions};
use grpot::solvers::lbfgs::{Lbfgs, LbfgsOptions};
use grpot::solvers::StopReason;
use grpot::testing::{check, Config};

#[test]
fn lbfgs_and_gd_reach_same_dual_value() {
    let pair = synthetic::controlled(3, 5, 0x501);
    let prob = OtProblem::from_dataset(&pair);
    let params = DualParams::new(0.5, 0.5);

    let mut o1 = OriginOracle::new(&prob, params);
    let mut lbfgs = Lbfgs::new(
        vec![0.0; prob.dim()],
        LbfgsOptions { max_iters: 2000, gtol: 1e-9, ftol: 1e-15, ..Default::default() },
        &mut o1,
    );
    lbfgs.run(&mut o1);
    let f_lbfgs = lbfgs.f();

    let mut o2 = OriginOracle::new(&prob, params);
    let (_, f_gd, _) = gradient_descent(
        &mut o2,
        vec![0.0; prob.dim()],
        &GdOptions { max_iters: 60_000, gtol: 1e-7, ..Default::default() },
    );
    assert!(
        (f_lbfgs - f_gd).abs() < 1e-4,
        "solvers disagree: lbfgs={f_lbfgs} gd={f_gd}"
    );
    // L-BFGS should be far more eval-efficient.
    let (e1, e2) = (o1.stats().evals, o2.stats().evals);
    assert!(e1 * 10 < e2, "{e1} vs {e2}");
}

#[test]
fn solver_stops_on_gradient_tolerance() {
    let pair = synthetic::controlled(3, 4, 0x502);
    let prob = OtProblem::from_dataset(&pair);
    let cfg = FastOtConfig {
        gamma: 0.5,
        rho: 0.5,
        lbfgs: LbfgsOptions { max_iters: 5000, gtol: 1e-7, ftol: 0.0, ..Default::default() },
        ..Default::default()
    };
    let res = solve_fast_ot(&prob, &cfg);
    assert!(
        matches!(res.stop, StopReason::GradTol | StopReason::LineSearchFailed),
        "{:?}",
        res.stop
    );
}

#[test]
fn solver_respects_iteration_cap() {
    let pair = synthetic::controlled(4, 6, 0x503);
    let prob = OtProblem::from_dataset(&pair);
    let cfg = FastOtConfig {
        gamma: 0.001,
        rho: 0.5,
        lbfgs: LbfgsOptions { max_iters: 7, gtol: 0.0, ftol: 0.0, ..Default::default() },
        ..Default::default()
    };
    let res = solve_fast_ot(&prob, &cfg);
    assert!(res.iterations <= 7);
    assert_eq!(res.stop, StopReason::MaxIters);
}

#[test]
fn dual_objective_nondecreasing_in_iterations_budget() {
    check("more iterations never hurt", &Config::cases(10), |rng| {
        let pair = synthetic::controlled(3, 4, rng.next_u64());
        let prob = OtProblem::from_dataset(&pair);
        let gamma = rng.uniform(0.05, 2.0);
        let rho = rng.uniform(0.1, 0.9);
        let run = |iters: usize| {
            let cfg = FastOtConfig {
                gamma,
                rho,
                lbfgs: LbfgsOptions {
                    max_iters: iters,
                    ftol: 0.0,
                    gtol: 1e-12,
                    ..Default::default()
                },
                ..Default::default()
            };
            solve_fast_ot(&prob, &cfg).dual_objective
        };
        let short = run(5);
        let long = run(50);
        if long < short - 1e-9 {
            return Err(format!("objective regressed: {short} -> {long}"));
        }
        Ok(())
    });
}

#[test]
fn extreme_hyperparameters_stay_finite() {
    let pair = synthetic::controlled(3, 4, 0x505);
    let prob = OtProblem::from_dataset(&pair);
    for gamma in [1e-4, 1e4] {
        for rho in [0.0, 0.99] {
            let cfg = FastOtConfig {
                gamma,
                rho,
                lbfgs: LbfgsOptions { max_iters: 200, ..Default::default() },
                ..Default::default()
            };
            let res = solve_fast_ot(&prob, &cfg);
            assert!(
                res.dual_objective.is_finite(),
                "non-finite dual at gamma={gamma} rho={rho}"
            );
            assert!(res.x.iter().all(|v| v.is_finite()));
        }
    }
}
