//! [`XlaDualOracle`] — the AOT JAX/Pallas dual oracle behind the same
//! [`DualOracle`] trait as the native Rust oracles, so the L-BFGS loop
//! is backend-agnostic.

use super::{Manifest, PjrtRuntime};
use crate::err;
use crate::error::{Context, Result};
use crate::ot::dual::{DualOracle, DualParams, OracleStats, OtProblem};

/// Dense dual oracle backed by the compiled `dual_obj_grad` artifact.
///
/// Static operands (a, b, cost, τ, λ_quad) are uploaded once; each
/// `eval` builds only the α/β literals and runs the executable.
pub struct XlaDualOracle {
    exe: xla::PjRtLoadedExecutable,
    m: usize,
    n: usize,
    num_groups: usize,
    a_lit: xla::Literal,
    b_lit: xla::Literal,
    cost_lit: xla::Literal,
    tau_lit: xla::Literal,
    lq_lit: xla::Literal,
    stats: OracleStats,
}

impl XlaDualOracle {
    /// Load the artifact matching `prob`'s shape from `artifact_dir`.
    ///
    /// Requires a uniform group structure (the AOT kernel's fast path);
    /// errors if no matching artifact exists — run `make artifacts`
    /// or regenerate with `python -m compile.aot --shapes L,g,n`.
    pub fn from_problem(
        runtime: &PjrtRuntime,
        prob: &OtProblem,
        params: &DualParams,
        artifact_dir: &std::path::Path,
    ) -> Result<Self> {
        params.validate();
        if !prob.groups.is_uniform() {
            return Err(err!(
                "XLA oracle requires uniform group sizes (got {:?}…)",
                &prob.groups.sizes[..prob.groups.sizes.len().min(4)]
            ));
        }
        let num_groups = prob.groups.num_groups();
        let group_size = prob.groups.sizes[0];
        let manifest = Manifest::load(artifact_dir)?;
        let entry = manifest
            .find_dual_oracle(num_groups, group_size, prob.n())
            .ok_or_else(|| {
                err!(
                    "no artifact for (L={num_groups}, g={group_size}, n={}); \
                     available: {:?}. Regenerate with `python -m compile.aot --shapes \
                     {num_groups},{group_size},{}`",
                    prob.n(),
                    manifest
                        .entries
                        .iter()
                        .map(|e| (e.num_groups, e.group_size, e.n))
                        .collect::<Vec<_>>(),
                    prob.n(),
                )
            })?;
        let exe = runtime.compile_hlo_text_file(&manifest.path_of(entry))?;

        let m = prob.m();
        let n = prob.n();
        // Cost in row-major (m × n), sorted-source order — prob stores
        // the transpose for the Rust hot loop.
        let cost = prob.cost();
        let cost_lit = xla::Literal::vec1(cost.as_slice())
            .reshape(&[m as i64, n as i64])
            .context("reshaping cost literal")?;
        Ok(XlaDualOracle {
            exe,
            m,
            n,
            num_groups,
            a_lit: xla::Literal::vec1(&prob.a),
            b_lit: xla::Literal::vec1(&prob.b),
            cost_lit,
            tau_lit: xla::Literal::scalar(params.tau()),
            lq_lit: xla::Literal::scalar(params.lambda_quad()),
            stats: OracleStats::default(),
        })
    }

    fn run(&self, x: &[f64]) -> Result<(f64, Vec<f64>, Vec<f64>)> {
        let alpha_lit = xla::Literal::vec1(&x[..self.m]);
        let beta_lit = xla::Literal::vec1(&x[self.m..]);
        let args = [
            &alpha_lit,
            &beta_lit,
            &self.a_lit,
            &self.b_lit,
            &self.cost_lit,
            &self.tau_lit,
            &self.lq_lit,
        ];
        let result = self.exe.execute(&args).context("executing dual oracle")?;
        let lit = result[0][0].to_literal_sync().context("fetching result")?;
        let (obj, ga, gb) = lit.to_tuple3().context("unpacking 3-tuple")?;
        let neg_obj = obj
            .get_first_element::<f64>()
            .context("reading objective scalar")?;
        Ok((
            neg_obj,
            ga.to_vec::<f64>().context("reading alpha gradient")?,
            gb.to_vec::<f64>().context("reading beta gradient")?,
        ))
    }
}

impl DualOracle for XlaDualOracle {
    fn shape(&self) -> (usize, usize) {
        (self.m, self.n)
    }

    fn eval(&mut self, x: &[f64], grad: &mut [f64]) -> f64 {
        assert_eq!(x.len(), self.m + self.n);
        assert_eq!(grad.len(), self.m + self.n);
        let (neg_obj, ga, gb) = self
            .run(x)
            .expect("XLA execution failed mid-solve (artifact/runtime mismatch)");
        grad[..self.m].copy_from_slice(&ga);
        grad[self.m..].copy_from_slice(&gb);
        // The XLA path is dense: every group gradient is computed.
        let dense_groups = (self.num_groups * self.n) as u64;
        self.stats.grads_computed += dense_groups;
        self.stats.record_eval(dense_groups);
        neg_obj
    }

    fn stats(&self) -> &OracleStats {
        &self.stats
    }
}
