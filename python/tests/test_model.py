"""L2 correctness: the dual oracle vs finite differences and vs the
conventions the Rust coordinator assumes, plus AOT artifact sanity."""

import json

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.model import dual_obj_grad, recover_plan
from compile.kernels import ref
from compile import aot


def random_instance(seed, L=3, g=4, n=6):
    rng = np.random.default_rng(seed)
    m = L * g
    return dict(
        alpha=jnp.asarray(rng.normal(scale=0.5, size=m)),
        beta=jnp.asarray(rng.normal(scale=0.5, size=n)),
        a=jnp.full(m, 1.0 / m),
        b=jnp.full(n, 1.0 / n),
        cost=jnp.asarray(rng.uniform(size=(m, n))),
        L=L, g=g, m=m, n=n,
    )


def test_pallas_and_ref_paths_agree():
    inst = random_instance(0)
    out_p = dual_obj_grad(
        inst["alpha"], inst["beta"], inst["a"], inst["b"], inst["cost"],
        0.3, 0.7, num_groups=inst["L"], group_size=inst["g"], use_pallas=True,
    )
    out_r = dual_obj_grad(
        inst["alpha"], inst["beta"], inst["a"], inst["b"], inst["cost"],
        0.3, 0.7, num_groups=inst["L"], group_size=inst["g"], use_pallas=False,
    )
    for p, r in zip(out_p, out_r):
        np.testing.assert_allclose(np.asarray(p), np.asarray(r), rtol=1e-12, atol=1e-12)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    tau=st.floats(min_value=0.01, max_value=1.0),
    lq=st.floats(min_value=0.1, max_value=3.0),
)
def test_gradient_matches_finite_differences(seed, tau, lq):
    inst = random_instance(seed)
    f0, ga, gb = dual_obj_grad(
        inst["alpha"], inst["beta"], inst["a"], inst["b"], inst["cost"],
        tau, lq, num_groups=inst["L"], group_size=inst["g"],
    )
    eps = 1e-6
    # Spot-check a few coordinates of each gradient block.
    for k in [0, inst["m"] // 2, inst["m"] - 1]:
        da = np.zeros(inst["m"]); da[k] = eps
        fp, _, _ = dual_obj_grad(
            inst["alpha"] + da, inst["beta"], inst["a"], inst["b"], inst["cost"],
            tau, lq, num_groups=inst["L"], group_size=inst["g"],
        )
        fm, _, _ = dual_obj_grad(
            inst["alpha"] - da, inst["beta"], inst["a"], inst["b"], inst["cost"],
            tau, lq, num_groups=inst["L"], group_size=inst["g"],
        )
        fd = (float(fp) - float(fm)) / (2 * eps)
        assert abs(fd - float(ga[k])) < 1e-5, (k, fd, float(ga[k]))
    for k in [0, inst["n"] - 1]:
        db = np.zeros(inst["n"]); db[k] = eps
        fp, _, _ = dual_obj_grad(
            inst["alpha"], inst["beta"] + db, inst["a"], inst["b"], inst["cost"],
            tau, lq, num_groups=inst["L"], group_size=inst["g"],
        )
        fm, _, _ = dual_obj_grad(
            inst["alpha"], inst["beta"] - db, inst["a"], inst["b"], inst["cost"],
            tau, lq, num_groups=inst["L"], group_size=inst["g"],
        )
        fd = (float(fp) - float(fm)) / (2 * eps)
        assert abs(fd - float(gb[k])) < 1e-5, (k, fd, float(gb[k]))


def test_neg_dual_at_zero_point():
    # alpha = beta = 0, c >= 0 → dual = 0, grads = (−a, −b).
    inst = random_instance(3)
    zero_a = jnp.zeros(inst["m"])
    zero_b = jnp.zeros(inst["n"])
    f, ga, gb = dual_obj_grad(
        zero_a, zero_b, inst["a"], inst["b"], inst["cost"],
        0.2, 0.8, num_groups=inst["L"], group_size=inst["g"],
    )
    assert float(f) == 0.0
    np.testing.assert_allclose(np.asarray(ga), -np.asarray(inst["a"]), rtol=1e-15)
    np.testing.assert_allclose(np.asarray(gb), -np.asarray(inst["b"]), rtol=1e-15)


def test_recover_plan_matches_kernel():
    inst = random_instance(7)
    t = recover_plan(
        inst["alpha"], inst["beta"], inst["cost"], 0.3, 0.7,
        num_groups=inst["L"], group_size=inst["g"],
    )
    t_ref, _ = ref.grad_psi_uniform(
        inst["alpha"], inst["beta"], inst["cost"], inst["L"], inst["g"], 0.3, 0.7
    )
    np.testing.assert_allclose(np.asarray(t), np.asarray(t_ref), rtol=1e-12)


def test_hlo_lowering_deterministic_and_parseable():
    text1 = aot.lower_shape(2, 3, 4)
    text2 = aot.lower_shape(2, 3, 4)
    assert text1 == text2, "AOT lowering must be deterministic"
    assert "HloModule" in text1
    # All seven parameters present.
    for i in range(7):
        assert f"parameter({i})" in text1, f"missing parameter({i})"


def test_build_writes_manifest(tmp_path):
    out = tmp_path / "arts"
    manifest = aot.build(str(out), [(2, 2, 4)])
    assert (out / "manifest.json").exists()
    entry = manifest["entries"][0]
    assert entry["m"] == 4 and entry["n"] == 4
    hlo_path = out / entry["file"]
    assert hlo_path.exists()
    data = json.loads((out / "manifest.json").read_text())
    assert data["entries"][0]["sha256"] == entry["sha256"]


def test_parse_shapes():
    assert aot.parse_shapes("1,2,3;4,5,6") == [(1, 2, 3), (4, 5, 6)]
    with pytest.raises(ValueError):
        aot.parse_shapes("1,2")
