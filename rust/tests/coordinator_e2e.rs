//! Coordinator integration: sweep scheduler end-to-end, TCP service
//! round-trips, config files, and failure handling.

use grpot::coordinator::config::{DatasetSpec, Method, SweepConfig};
use grpot::coordinator::metrics::Metrics;
use grpot::coordinator::service::{serve, Client};
use grpot::coordinator::sweep::run_sweep;
use grpot::jsonlite::Value;
use grpot::ot::solve::SolveOptions;

fn small_dataset() -> Value {
    Value::obj()
        .set("family", "synthetic")
        .set("param1", 4usize)
        .set("param2", 5usize)
        .set("seed", 11usize)
}

#[test]
fn service_ping_solve_metrics_shutdown() {
    let handle = serve("127.0.0.1:0", 2).expect("bind");
    let addr = handle.addr;
    let mut c = Client::connect(&addr).expect("connect");
    assert!(c.ping().expect("ping"));

    let resp = c
        .call(
            &Value::obj()
                .set("op", "solve")
                .set("id", 42usize)
                .set("dataset", small_dataset())
                .set("gamma", 0.5)
                .set("rho", 0.6)
                .set("method", "fast"),
        )
        .expect("solve");
    assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "{resp}");
    assert_eq!(resp.get("id").and_then(Value::as_usize), Some(42));
    assert!(resp.get("dual_objective").and_then(Value::as_f64).unwrap() > 0.0);
    assert!(resp.get("otda_accuracy").and_then(Value::as_f64).unwrap() > 0.0);

    // Identical request → cache hit.
    let _ = c
        .call(
            &Value::obj()
                .set("op", "solve")
                .set("dataset", small_dataset())
                .set("gamma", 0.5)
                .set("rho", 0.4)
                .set("method", "origin"),
        )
        .expect("second solve");
    let metrics = c.call(&Value::obj().set("op", "metrics")).expect("metrics");
    let hits = metrics
        .get_path(&["metrics", "counters", "service.cache_hits"])
        .and_then(Value::as_usize)
        .unwrap_or(0);
    assert!(hits >= 1, "expected a cache hit: {metrics}");

    handle.shutdown();
}

#[test]
fn service_rejects_malformed_requests() {
    let handle = serve("127.0.0.1:0", 1).expect("bind");
    let mut c = Client::connect(&handle.addr).expect("connect");
    for bad in [
        "not json at all",
        r#"{"no_op": 1}"#,
        r#"{"op": "solve"}"#,
        r#"{"op": "solve", "dataset": {"family": "nope"}, "gamma": 1, "rho": 0.5}"#,
        r#"{"op": "dance"}"#,
    ] {
        let resp = c.call(&grpot_raw(bad)).expect("call survives bad input");
        assert_eq!(
            resp.get("ok").and_then(Value::as_bool),
            Some(false),
            "input {bad:?} should fail: {resp}"
        );
        assert!(resp.get("error").is_some());
    }
    // Server must still be healthy afterwards.
    assert!(c.ping().expect("ping after errors"));
    handle.shutdown();
}

/// Send raw (possibly invalid) text as a request: wraps it so Client can
/// transmit it unchanged when it parses, otherwise transmits verbatim.
fn grpot_raw(raw: &str) -> Value {
    match grpot::jsonlite::parse(raw) {
        Ok(v) => v,
        // Invalid JSON: send as a bare string the server will fail to
        // parse as an object — mimics a garbage client line.
        Err(_) => Value::Str(raw.to_string()),
    }
}

#[test]
fn service_regularizer_wire_round_trip_and_rejection() {
    let handle = serve("127.0.0.1:0", 1).expect("bind");
    let mut c = Client::connect(&handle.addr).expect("connect");
    let solve_req = |reg: Option<&str>| {
        let mut v = Value::obj()
            .set("op", "solve")
            .set("dataset", small_dataset())
            .set("gamma", 0.5)
            .set("rho", 0.5)
            .set("method", "fast");
        if let Some(reg) = reg {
            v = v.set("regularizer", reg);
        }
        v
    };
    for reg in ["squared_l2", "negentropy"] {
        let resp = c.call(&solve_req(Some(reg))).expect("solve");
        assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "{resp}");
        assert_eq!(resp.get("regularizer").and_then(Value::as_str), Some(reg), "{resp}");
        let obj = resp.get("dual_objective").and_then(Value::as_f64).unwrap();
        assert!(obj.is_finite(), "{resp}");
    }
    // Omitted → the engine's default, echoed back so clients can see
    // what actually ran.
    let resp = c.call(&solve_req(None)).expect("solve");
    let default = grpot::ot::regularizer::RegKind::env_default().unwrap();
    assert_eq!(
        resp.get("regularizer").and_then(Value::as_str),
        Some(default.name()),
        "{resp}"
    );
    // Unknown value → structured rejection (error_kind + id echo), and
    // the connection survives.
    let resp = c
        .call(&solve_req(Some("lasso-soup")).set("id", 7usize))
        .expect("call survives bad regularizer");
    assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(false), "{resp}");
    assert_eq!(resp.get("error_kind").and_then(Value::as_str), Some("failed"), "{resp}");
    assert_eq!(resp.get("id").and_then(Value::as_usize), Some(7), "{resp}");
    assert!(
        resp.get("error")
            .and_then(Value::as_str)
            .unwrap()
            .contains("unknown regularizer"),
        "{resp}"
    );
    assert!(c.ping().expect("ping after rejection"));
    handle.shutdown();
}

#[test]
fn sweep_from_config_file() {
    let dir = std::env::temp_dir().join(format!("grpot-e2e-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("sweep.json");
    std::fs::write(
        &cfg_path,
        r#"{
            "dataset": {"family": "synthetic", "param1": 3, "param2": 4, "seed": 5},
            "gammas": [0.1, 1.0],
            "rhos": [0.5],
            "methods": ["fast", "origin"],
            "r": 5,
            "threads": 2,
            "max_iters": 60
        }"#,
    )
    .unwrap();
    let cfg = SweepConfig::from_file(&cfg_path).expect("parse config");
    assert_eq!(cfg.threads, 2);
    let metrics = Metrics::new();
    let report = run_sweep(&cfg, &metrics).expect("sweep");
    assert_eq!(report.records.len(), 4);
    for agg in &report.aggregates {
        assert!(agg.gain.is_some());
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sweep_includes_ablation_method() {
    let cfg = SweepConfig {
        dataset: DatasetSpec {
            family: "synthetic".into(),
            param1: 3,
            param2: 4,
            ..Default::default()
        },
        gammas: vec![0.5],
        rhos: vec![0.6],
        methods: vec![Method::Fast, Method::FastNoWs, Method::Origin],
        threads: 1,
        solve: SolveOptions::new().r(5).max_iters(80),
    };
    let report = run_sweep(&cfg, &Metrics::new()).expect("sweep");
    assert_eq!(report.records.len(), 3);
    let objs: Vec<f64> = report.records.iter().map(|r| r.dual_objective).collect();
    assert!(objs.windows(2).all(|w| w[0] == w[1]), "all methods agree: {objs:?}");
}

#[test]
fn concurrent_clients_share_problem_cache() {
    let handle = serve("127.0.0.1:0", 4).expect("bind");
    let addr = handle.addr;
    std::thread::scope(|s| {
        for k in 0..4 {
            s.spawn(move || {
                let mut c = Client::connect(&addr).expect("connect");
                let resp = c
                    .call(
                        &Value::obj()
                            .set("op", "solve")
                            .set("dataset", small_dataset())
                            .set("gamma", 0.2 + 0.1 * k as f64)
                            .set("rho", 0.5)
                            .set("method", "fast"),
                    )
                    .expect("solve");
                assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true));
            });
        }
    });
    let mut c = Client::connect(&addr).expect("connect");
    let metrics = c.call(&Value::obj().set("op", "metrics")).expect("metrics");
    let misses = metrics
        .get_path(&["metrics", "counters", "service.cache_misses"])
        .and_then(Value::as_usize)
        .unwrap();
    assert!(misses <= 4, "at most a few builders: {metrics}");
    handle.shutdown();
}
