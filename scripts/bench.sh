#!/usr/bin/env bash
# Perf trio + machine-readable summary.
#
# Runs the three performance benches in quick mode (or smoke mode when
# GRPOT_BENCH_SMOKE=1 is already set, as in the CI wiring):
#
#   * bench_parallel     — solve-level thread scaling + the fork-join vs
#                          persistent-pool dispatch comparison + the
#                          scalar-vs-SIMD dispatch rows
#   * bench_serve        — serving-engine closed-loop load harness
#   * hotpath_microbench — isolated oracle kernels (incl. the
#                          scalar-vs-SIMD kernel cases and their speedup
#                          ratios, and per-regularizer trait-oracle
#                          rows) + bare dispatch cost
#   * bench_scale        — dense vs factored cost-backend memory sweep;
#                          asserts the factored build solves under a
#                          budget the dense matrix exceeds
#   * bench_batch        — K-lane fused solve_batched vs K sequential
#                          solves; asserts byte-equality before timing
#
# plus fig2_synthetic_classes for the paper's gain-vs-classes table,
# whose rows now carry the skipped-group-fraction telemetry column,
#
# then collects every CSV the benches emitted into one machine-readable
# JSON file (default: BENCH_PR10.json at the repo root; override with
# GRPOT_BENCH_JSON). The JSON records the mode, so a smoke-mode CI run
# is never mistaken for a real measurement.
#
# Usage: bash scripts/bench.sh
#   GRPOT_BENCH_SMOKE=1 bash scripts/bench.sh   # CI smoke wiring
#   GRPOT_BENCH_JSON=out.json bash scripts/bench.sh

set -euo pipefail
ROOT="$(cd "$(dirname "$0")/.." && pwd)"
cd "$ROOT/rust"

OUT="${GRPOT_BENCH_JSON:-$ROOT/BENCH_PR10.json}"
REPORT_DIR="${GRPOT_REPORT_DIR:-$ROOT/rust/reports}"
export GRPOT_REPORT_DIR="$REPORT_DIR"

if [[ "${GRPOT_BENCH_SMOKE:-0}" != 0 ]]; then
    MODE=smoke
elif [[ "${GRPOT_BENCH_QUICK:-1}" != 0 ]]; then
    export GRPOT_BENCH_QUICK=1
    MODE=quick
else
    MODE=full
fi

BENCHES=(bench_parallel bench_serve hotpath_microbench bench_scale bench_batch fig2_synthetic_classes)
for b in "${BENCHES[@]}"; do
    echo
    echo "==> bench ($MODE mode): $b"
    cargo bench --bench "$b"
done

# Fold the emitted CSVs into one JSON document. Python is available on
# every image this repo targets; if it is ever missing, fall back to a
# stub JSON that still records mode + the CSV paths.
CSVS=(bench_parallel bench_parallel_dispatch bench_parallel_simd bench_serve
      hotpath_microbench hotpath_simd_speedup bench_scale bench_batch
      fig2_synthetic_classes)
if command -v python3 >/dev/null 2>&1; then
    MODE="$MODE" OUT="$OUT" REPORT_DIR="$REPORT_DIR" CSVS="${CSVS[*]}" python3 - <<'PY'
import csv, json, os

mode = os.environ["MODE"]
out = os.environ["OUT"]
report_dir = os.environ["REPORT_DIR"]
doc = {"mode": mode, "benches": {}}
for stem in os.environ["CSVS"].split():
    path = os.path.join(report_dir, stem + ".csv")
    if not os.path.exists(path):
        continue
    with open(path, newline="") as fh:
        rows = list(csv.reader(fh))
    if not rows:
        continue
    headers, data = rows[0], rows[1:]
    doc["benches"][stem] = [dict(zip(headers, row)) for row in data]
with open(out, "w") as fh:
    json.dump(doc, fh, indent=2, sort_keys=True)
    fh.write("\n")
print(f"bench.sh: wrote {out} ({mode} mode, {len(doc['benches'])} tables)")
PY
else
    {
        printf '{\n  "mode": "%s",\n  "note": "python3 unavailable; see CSVs",\n' "$MODE"
        printf '  "csv_dir": "%s"\n}\n' "$REPORT_DIR"
    } > "$OUT"
    echo "bench.sh: python3 missing — wrote stub $OUT"
fi
