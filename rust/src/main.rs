//! `grpot` — command-line entrypoint for the fast group-sparse OT
//! framework.
//!
//! Subcommands:
//! * `solve`   — one regularized OT solve on a named dataset.
//! * `sweep`   — the paper's (γ × ρ × method) grid with gain report.
//! * `serve`   — start the TCP OT service (serving-engine backed).
//! * `request` — send one solve request to a running service.
//! * `bench-serve` — closed-loop load test of the serving engine.
//! * `metrics` — fetch a running service's metrics (JSON or Prometheus).
//! * `validate-artifacts` — check AOT artifacts load & match Rust numerics.
//! * `info`    — build/runtime information.

use grpot::cli::{App, ArgSpec};
use grpot::coordinator::config::{DatasetSpec, Method, SweepConfig};
use grpot::coordinator::metrics::Metrics;
use grpot::coordinator::{registry, service, sweep};
use grpot::error::{Context, Result};
use grpot::jsonlite::Value;
use grpot::ot::cost::CostMode;
use grpot::ot::dual::{DualParams, OtProblem};
use grpot::ot::plan::recover_plan;
use grpot::ot::regularizer::{recover_plan_reg, AnyRegularizer, RegKind};
use grpot::ot::solve::SolveOptions;
use grpot::serve::loadgen::{run_load, LoadScenario};
use grpot::serve::ServeConfig;
use grpot::solvers::lbfgs::LbfgsOptions;

fn app() -> App {
    let dataset_args = |a: App| -> App {
        a.arg(ArgSpec::opt("dataset", "synthetic|digits|faces|objects").default("synthetic"))
            .arg(
                ArgSpec::opt("param1", "synthetic: #classes; digits/faces/objects: task index")
                    .default("10"),
            )
            .arg(
                ArgSpec::opt("param2", "synthetic: samples/class; digits: samples/domain")
                    .default("10"),
            )
            .arg(
                ArgSpec::opt("scale", "faces/objects: fraction of paper-size domains")
                    .default("0.1"),
            )
            .arg(ArgSpec::opt("seed", "dataset generation seed").default("55930"))
            .arg(ArgSpec::opt(
                "cost",
                "cost-matrix backend: dense|factored (default: $GRPOT_COST or dense)",
            ))
    };
    let engine_args = |a: App| -> App {
        a.arg(ArgSpec::opt("workers", "solver worker threads").default("4"))
            .arg(
                ArgSpec::opt("threads", "intra-solve oracle threads per worker (1 = serial)")
                    .default("1"),
            )
            .arg(
                ArgSpec::opt("core-budget", "cap on workers x threads (0 = autodetect cores)")
                    .default("0"),
            )
            .arg(ArgSpec::opt("queue-capacity", "admission queue bound").default("128"))
            .arg(ArgSpec::opt("max-batch", "max requests per micro-batch").default("16"))
            .arg(
                ArgSpec::opt("warm-cache-mb", "warm-start cache budget in MiB (0 disables)")
                    .default("64"),
            )
            .arg(
                ArgSpec::opt("deadline-ms", "default per-request deadline in ms (0 = none)")
                    .default("0"),
            )
            .arg(ArgSpec::switch("no-warm-start", "disable warm-start seeding"))
            .arg(
                ArgSpec::opt("warm-radius", "max (ln γ, ρ) distance for neighbor seeding")
                    .default("2.0"),
            )
            .arg(
                ArgSpec::opt("problem-cache-entries", "LRU cap on cached datasets")
                    .default("32"),
            )
            .arg(ArgSpec::opt("max-iters", "L-BFGS iteration cap per solve").default("1000"))
            .arg(ArgSpec::opt("r", "snapshot interval").default("10"))
            .arg(
                ArgSpec::opt(
                    "breaker-threshold",
                    "consecutive dataset failures before quarantine (0 disables)",
                )
                .default("3"),
            )
            .arg(
                ArgSpec::opt("breaker-cooldown-ms", "quarantine cooldown before a probe")
                    .default("5000"),
            )
            .arg(ArgSpec::switch(
                "no-shed",
                "disable load shedding of requests that cannot meet their deadline",
            ))
            .arg(ArgSpec::opt(
                "reg",
                "default regularizer for requests that don't name one: \
                 group_lasso|squared_l2|negentropy (default: $GRPOT_REG or group_lasso)",
            ))
            .arg(ArgSpec::opt(
                "batch-k",
                "coalesce up to K same-dataset group-lasso jobs into one fused \
                 multi-lane solve (default: $GRPOT_BATCH_K or 1 = off)",
            ))
            .arg(ArgSpec::opt(
                "tile-ring-kib",
                "factored-cost tile-ring budget per chunk in KiB \
                 (default: $GRPOT_TILE_RING_KIB or 1024)",
            ))
    };
    App::new(
        "grpot",
        "fast regularized discrete OT with group-sparse regularizers (AAAI'23 reproduction)",
    )
    .subcommand(dataset_args(
        App::new("solve", "run one regularized OT solve")
            .arg(ArgSpec::opt("gamma", "regularization strength γ").default("1.0"))
            .arg(ArgSpec::opt("rho", "group/quadratic balance ρ ∈ [0,1)").default("0.5"))
            .arg(ArgSpec::opt("method", "fast|fast-nows|origin|xla-origin").default("fast"))
            .arg(ArgSpec::opt("r", "snapshot interval").default("10"))
            .arg(
                ArgSpec::opt("threads", "intra-solve oracle threads (1 = paper-faithful)")
                    .default("1"),
            )
            .arg(ArgSpec::opt(
                "simd",
                "oracle kernel dispatch: auto|scalar|portable (default: $GRPOT_SIMD or auto)",
            ))
            .arg(ArgSpec::opt(
                "reg",
                "regularizer: group_lasso|squared_l2|negentropy (default: $GRPOT_REG or group_lasso)",
            ))
            .arg(ArgSpec::opt(
                "tile-ring-kib",
                "factored-cost tile-ring budget per chunk in KiB \
                 (default: $GRPOT_TILE_RING_KIB or 1024)",
            ))
            .arg(ArgSpec::switch(
                "plan-stats",
                "also recover the plan and print its statistics",
            )),
    ))
    .subcommand(dataset_args(
        App::new("sweep", "run the paper's hyperparameter grid")
            .arg(ArgSpec::opt("gammas", "γ grid").default("0.001,0.01,0.1,1,10,100,1000"))
            .arg(ArgSpec::opt("rhos", "ρ grid").default("0.2,0.4,0.6,0.8"))
            .arg(ArgSpec::opt("methods", "comma-separated methods").default("fast,origin"))
            .arg(ArgSpec::opt("threads", "parallel sweep workers").default("1"))
            .arg(
                ArgSpec::opt("solve-threads", "intra-solve oracle threads per job")
                    .default("1"),
            )
            .arg(ArgSpec::opt("max-iters", "L-BFGS iteration cap").default("1000"))
            .arg(ArgSpec::opt(
                "reg",
                "regularizer: group_lasso|squared_l2|negentropy (default: $GRPOT_REG or group_lasso)",
            ))
            .arg(ArgSpec::opt(
                "batch-k",
                "coalesce up to K consecutive same-method group-lasso grid jobs \
                 into one fused multi-lane solve (default: $GRPOT_BATCH_K or 1 = off)",
            ))
            .arg(ArgSpec::opt(
                "tile-ring-kib",
                "factored-cost tile-ring budget per chunk in KiB \
                 (default: $GRPOT_TILE_RING_KIB or 1024)",
            ))
            .arg(ArgSpec::opt("config", "JSON config file (overrides flags)"))
            .arg(ArgSpec::opt("out", "write the JSON report here")),
    ))
    .subcommand(engine_args(
        App::new("serve", "start the TCP OT service")
            .arg(ArgSpec::opt("bind", "listen address").default("127.0.0.1:7677"))
            .arg(ArgSpec::opt(
                "cost",
                "cost-matrix backend for cached problems: dense|factored \
                 (default: $GRPOT_COST or dense; requests may override per dataset)",
            ))
            .arg(ArgSpec::opt(
                "trace-out",
                "write Chrome trace-event JSON here on shutdown (needs GRPOT_TRACE)",
            )),
    ))
    .subcommand(
        App::new("request", "send one solve request to a running service")
            .arg(ArgSpec::opt("addr", "service address").default("127.0.0.1:7677"))
            .arg(ArgSpec::opt("json", "raw request JSON").required()),
    )
    .subcommand(
        App::new("metrics", "fetch a running service's metrics")
            .arg(ArgSpec::opt("addr", "service address").default("127.0.0.1:7677"))
            .arg(ArgSpec::opt("format", "json|prom").default("json")),
    )
    .subcommand(dataset_args(engine_args(
        App::new("bench-serve", "closed-loop load test of the serving engine")
            .arg(ArgSpec::opt("clients", "concurrent closed-loop clients").default("4"))
            .arg(ArgSpec::opt("cycles", "passes over the (γ×ρ) grid per client").default("3"))
            .arg(ArgSpec::opt("gammas", "γ grid").default("0.1,1"))
            .arg(ArgSpec::opt("rhos", "ρ grid").default("0.4,0.8"))
            .arg(ArgSpec::opt("method", "fast|fast-nows|origin|xla-origin").default("fast"))
            .arg(ArgSpec::opt(
                "chaos-seed",
                "seeded chaos mode: perturb every third request (deadlines, bad γ, poisoned dataset)",
            ))
            .arg(ArgSpec::opt("out", "write the JSON report here")),
    )))
    .subcommand(
        App::new("validate-artifacts", "compile AOT artifacts and cross-check numerics")
            .arg(ArgSpec::opt("dir", "artifact directory").default("artifacts")),
    )
    .subcommand(App::new("info", "print build and runtime information"))
}

fn cost_mode(m: &grpot::cli::Matches) -> Result<CostMode, grpot::cli::CliError> {
    match m.get("cost") {
        Some(s) => {
            CostMode::parse(s).map_err(|e| grpot::cli::CliError(format!("--cost: {e}")))
        }
        None => Ok(CostMode::Auto),
    }
}

fn dataset_spec(m: &grpot::cli::Matches) -> Result<DatasetSpec, grpot::cli::CliError> {
    Ok(DatasetSpec {
        family: m.get("dataset").unwrap_or("synthetic").to_string(),
        param1: m.get_usize("param1")?,
        param2: m.get_usize("param2")?,
        scale: m.get_f64("scale")?,
        seed: m.get_usize("seed")? as u64,
        cost: cost_mode(m)?,
    })
}

fn cmd_solve(m: &grpot::cli::Matches) -> Result<()> {
    let spec = dataset_spec(m)?;
    let gamma = m.get_f64("gamma")?;
    let rho = m.get_f64("rho")?;
    let r = m.get_usize("r")?;
    let threads = m.get_usize("threads")?;
    let method = Method::parse(m.get("method").unwrap_or("fast"))?;
    method.ensure_available()?;
    // An explicit --simd wins over GRPOT_SIMD (resolve gives forced
    // modes priority); absent flag, Auto defers to the env var.
    let simd = match m.get("simd") {
        Some(v) => grpot::simd::SimdMode::parse(v).context("--simd")?,
        None => grpot::simd::SimdMode::Auto,
    };
    let dispatch = grpot::simd::Dispatch::resolve(simd);
    // An explicit --reg wins over GRPOT_REG; absent flag, the unset
    // option defers to the env var (mirroring --simd / GRPOT_SIMD).
    let mut opts = SolveOptions::new()
        .gamma(gamma)
        .rho(rho)
        .r(r)
        .max_iters(1000)
        .threads(threads)
        .simd(simd);
    if let Some(s) = m.get("reg") {
        opts = opts.regularizer(RegKind::parse(s).context("--reg")?);
    }
    // An explicit --tile-ring-kib wins over GRPOT_TILE_RING_KIB (same
    // explicit-beats-env policy as --simd / --reg / --cost).
    if m.get("tile-ring-kib").is_some() {
        opts = opts.tile_ring_kib(m.get_usize("tile-ring-kib")?);
    }
    let kind = opts.resolve_regularizer()?;
    eprintln!("dataset: {}", registry::describe(&spec));
    let pair = registry::build_pair(&spec)?;
    // An explicit --cost wins over GRPOT_COST (the Auto default defers
    // to the env var); both backends solve byte-identically.
    let prob = OtProblem::try_from_dataset_mode(&pair, spec.cost)?;
    eprintln!(
        "problem: m={} n={} |L|={} threads={} simd={} reg={} cost={}",
        prob.m(),
        prob.n(),
        prob.groups.num_groups(),
        threads.max(1),
        dispatch.name(),
        kind.name(),
        prob.cost_mode_name()
    );
    let res = sweep::solve(&prob, method, &opts)?;
    let mut out = Value::obj()
        .set("method", method.name())
        .set("threads", threads.max(1))
        .set("simd", dispatch.name())
        .set("cost", prob.cost_mode_name())
        .set("regularizer", kind.name())
        .set("gamma", gamma)
        .set("rho", rho)
        .set("dual_objective", res.dual_objective)
        .set("iterations", res.iterations)
        .set("wall_time_s", res.wall_time_s)
        .set("grads_computed", res.stats.grads_computed)
        .set("grads_skipped", res.stats.grads_skipped);
    if m.get_flag("plan-stats") {
        let params = DualParams::new(gamma, rho);
        // The group-lasso plan uses the specialized recovery (and its
        // primal objective); other regularizers go through the generic
        // ∇Ω* recovery, whose primal is not the group-lasso objective.
        let plan = match kind {
            RegKind::GroupLasso => recover_plan(&prob, &params, &res.x),
            other => {
                let reg = AnyRegularizer::build(other, gamma, rho, &prob.groups)?;
                recover_plan_reg(&prob, &reg, &res.x)
            }
        };
        let (va, vb) = plan.marginal_violation(&prob);
        out = out
            .set("transport_cost", plan.transport_cost(&prob))
            .set("plan_density", plan.density(1e-12))
            .set("group_sparsity", plan.group_sparsity(&prob, 1e-12))
            .set("single_class_columns", plan.single_class_columns(&prob, 1e-12))
            .set("marginal_violation_a", va)
            .set("marginal_violation_b", vb)
            .set("otda_accuracy", grpot::eval::otda_accuracy(&pair, &prob, &plan));
        if kind == RegKind::GroupLasso {
            out = out.set("primal_objective", plan.primal_objective(&prob, &params));
        }
    }
    println!("{}", out.to_json());
    Ok(())
}

fn cmd_sweep(m: &grpot::cli::Matches) -> Result<()> {
    let cfg = if let Some(path) = m.get("config") {
        SweepConfig::from_file(std::path::Path::new(path))?
    } else {
        let methods = m
            .get("methods")
            .unwrap_or("fast,origin")
            .split(',')
            .map(|s| Method::parse(s.trim()))
            .collect::<Result<Vec<_>>>()?;
        let mut solve = SolveOptions::new()
            .threads(m.get_usize("solve-threads")?)
            .max_iters(m.get_usize("max-iters")?);
        if let Some(s) = m.get("reg") {
            solve = solve.regularizer(RegKind::parse(s).context("--reg")?);
        }
        // Explicit batching knobs win over their env defaults
        // (GRPOT_BATCH_K / GRPOT_TILE_RING_KIB).
        if m.get("batch-k").is_some() {
            solve = solve.batch_k(m.get_usize("batch-k")?);
        }
        if m.get("tile-ring-kib").is_some() {
            solve = solve.tile_ring_kib(m.get_usize("tile-ring-kib")?);
        }
        SweepConfig {
            dataset: dataset_spec(m)?,
            gammas: m.get_f64_list("gammas")?,
            rhos: m.get_f64_list("rhos")?,
            methods,
            threads: m.get_usize("threads")?,
            solve,
        }
    };
    eprintln!(
        "sweep: {} | {} γ × {} ρ × {} methods",
        registry::describe(&cfg.dataset),
        cfg.gammas.len(),
        cfg.rhos.len(),
        cfg.methods.len()
    );
    let metrics = Metrics::new();
    let report = sweep::run_sweep(&cfg, &metrics)?;
    println!("{:>10} {:>14} {:>14} {:>8}", "gamma", "t_origin[s]", "t_fast[s]", "gain");
    for a in &report.aggregates {
        let t = |mm: Method| {
            a.totals
                .iter()
                .find(|(x, _)| *x == mm)
                .map(|&(_, t)| t)
                .unwrap_or(f64::NAN)
        };
        println!(
            "{:>10.4} {:>14.4} {:>14.4} {:>8}",
            a.gamma,
            t(Method::Origin),
            t(Method::Fast),
            a.gain.map_or("-".to_string(), |g| format!("{g:.2}x"))
        );
    }
    if let Some(out) = m.get("out") {
        let body = Value::obj()
            .set("config", cfg.to_json())
            .set("report", report.to_json())
            .set("metrics", metrics.snapshot());
        std::fs::write(out, body.to_json())?;
        eprintln!("report written to {out}");
    }
    Ok(())
}

/// Build the engine configuration shared by `serve` and `bench-serve`.
fn engine_config(m: &grpot::cli::Matches) -> Result<ServeConfig, grpot::cli::CliError> {
    // Clamp to [0, 1 day] like the wire path: Duration::from_secs_f64
    // panics on non-finite/overflowing input.
    let deadline_ms = m.get_f64("deadline-ms")?;
    let deadline_ms = if deadline_ms.is_finite() && deadline_ms > 0.0 {
        deadline_ms.min(86_400_000.0)
    } else {
        0.0
    };
    let mut solve = SolveOptions::new()
        .threads(m.get_usize("threads")?)
        .r(m.get_usize("r")?)
        .lbfgs(LbfgsOptions { max_iters: m.get_usize("max-iters")?, ..Default::default() });
    if let Some(s) = m.get("reg") {
        let kind = RegKind::parse(s)
            .map_err(|e| grpot::cli::CliError(format!("--reg: {e}")))?;
        solve = solve.regularizer(kind);
    }
    // Explicit batching knobs win over their env defaults
    // (GRPOT_BATCH_K / GRPOT_TILE_RING_KIB).
    if m.get("batch-k").is_some() {
        solve = solve.batch_k(m.get_usize("batch-k")?);
    }
    if m.get("tile-ring-kib").is_some() {
        solve = solve.tile_ring_kib(m.get_usize("tile-ring-kib")?);
    }
    solve = solve.cost(cost_mode(m)?);
    Ok(ServeConfig {
        workers: m.get_usize("workers")?,
        core_budget: m.get_usize("core-budget")?,
        queue_capacity: m.get_usize("queue-capacity")?,
        max_batch: m.get_usize("max-batch")?,
        warm_cache_bytes: m.get_usize("warm-cache-mb")? << 20,
        warm_start: !m.get_flag("no-warm-start"),
        warm_radius: m.get_f64("warm-radius")?,
        problem_cache_entries: m.get_usize("problem-cache-entries")?,
        default_deadline: if deadline_ms > 0.0 {
            Some(std::time::Duration::from_secs_f64(deadline_ms / 1e3))
        } else {
            None
        },
        breaker_threshold: m.get_usize("breaker-threshold")?.min(u32::MAX as usize) as u32,
        breaker_cooldown: {
            // Same clamp policy as deadlines: from_secs_f64 panics on
            // non-finite or overflowing input.
            let ms = m.get_f64("breaker-cooldown-ms")?;
            let ms = if ms.is_finite() && ms > 0.0 { ms.min(86_400_000.0) } else { 0.0 };
            std::time::Duration::from_secs_f64(ms / 1e3)
        },
        shed: !m.get_flag("no-shed"),
        solve,
    })
}

fn cmd_serve(m: &grpot::cli::Matches) -> Result<()> {
    let bind = m.get("bind").unwrap_or("127.0.0.1:7677");
    let cfg = engine_config(m)?;
    if m.get("trace-out").is_some() && !grpot::obs::enabled() {
        eprintln!(
            "note: --trace-out set but GRPOT_TRACE is off; \
             the trace file will be empty (set GRPOT_TRACE=spans or full)"
        );
    }
    let batch_k = cfg.solve.resolve_batch_k().unwrap_or(1);
    let handle = service::serve_with(bind, cfg)?;
    eprintln!("grpot service listening on {}", handle.addr);
    if batch_k > 1 {
        eprintln!("batched solves: up to {batch_k} coalesced group-lasso jobs per fused pass");
    }
    eprintln!("send {{\"op\":\"shutdown\"}} to stop");
    let addr = handle.addr;
    // Stay resident until the service stops accepting pings (shutdown).
    loop {
        std::thread::sleep(std::time::Duration::from_secs(2));
        match service::Client::connect(&addr) {
            Ok(mut probe) => {
                if !probe.ping().unwrap_or(false) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    if let Some(out) = m.get("trace-out") {
        let trace = grpot::obs::span::drain_chrome_json();
        std::fs::write(out, trace.to_json())
            .with_context(|| format!("writing trace to {out}"))?;
        eprintln!("trace written to {out} (load in chrome://tracing or Perfetto)");
    }
    Ok(())
}

fn cmd_metrics(m: &grpot::cli::Matches) -> Result<()> {
    let addr: std::net::SocketAddr = m
        .get("addr")
        .unwrap_or("127.0.0.1:7677")
        .parse()
        .context("bad --addr")?;
    let format = m.get("format").unwrap_or("json");
    let mut client = service::Client::connect(&addr)?;
    match format {
        "json" => {
            let resp = client.call(&Value::obj().set("op", "metrics"))?;
            match resp.get("metrics") {
                Some(mm) => println!("{}", mm.to_json()),
                None => grpot::bail!("malformed metrics response: {}", resp.to_json()),
            }
        }
        "prom" => {
            let resp = client.call(&Value::obj().set("op", "metrics_prom"))?;
            match resp.get("prom").and_then(Value::as_str) {
                Some(text) => print!("{text}"),
                None => grpot::bail!("malformed metrics_prom response: {}", resp.to_json()),
            }
        }
        other => grpot::bail!("unknown --format '{other}' (expected json|prom)"),
    }
    Ok(())
}

fn cmd_request(m: &grpot::cli::Matches) -> Result<()> {
    let addr: std::net::SocketAddr = m
        .get("addr")
        .unwrap_or("127.0.0.1:7677")
        .parse()
        .context("bad --addr")?;
    let raw = m.get("json").expect("required");
    let req = grpot::jsonlite::parse(raw)?;
    let mut client = service::Client::connect(&addr)?;
    let resp = client.call(&req)?;
    println!("{}", resp.to_json());
    Ok(())
}

fn cmd_bench_serve(m: &grpot::cli::Matches) -> Result<()> {
    let cfg = engine_config(m)?;
    let method = Method::parse(m.get("method").unwrap_or("fast"))?;
    method.ensure_available()?;
    let scenario = LoadScenario {
        spec: dataset_spec(m)?,
        gammas: m.get_f64_list("gammas")?,
        rhos: m.get_f64_list("rhos")?,
        cycles: m.get_usize("cycles")?,
        clients: m.get_usize("clients")?,
        method,
        regularizer: cfg.solve.resolve_regularizer()?,
        deadline: None,
        chaos_seed: match m.get("chaos-seed") {
            Some(_) => Some(m.get_usize("chaos-seed")? as u64),
            None => None,
        },
    };
    eprintln!(
        "bench-serve: {} | {} clients × {} cycles × {} grid points | {} workers × {} threads | reg={} batch-k={}",
        registry::describe(&scenario.spec),
        scenario.clients,
        scenario.cycles,
        scenario.gammas.len() * scenario.rhos.len(),
        cfg.workers,
        cfg.solve.threads,
        scenario.regularizer.name(),
        cfg.solve.resolve_batch_k().unwrap_or(1)
    );
    let report = run_load(cfg, &scenario);
    report.print_summary();
    if let Some(out) = m.get("out") {
        std::fs::write(out, report.to_json().to_json())?;
        eprintln!("report written to {out}");
    }
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_validate_artifacts(m: &grpot::cli::Matches) -> Result<()> {
    use grpot::linalg::Mat;
    use grpot::rng::Pcg64;
    use grpot::runtime::{Manifest, PjrtRuntime, XlaDualOracle};
    let dir = std::path::PathBuf::from(m.get("dir").unwrap_or("artifacts"));
    let manifest = Manifest::load(&dir)?;
    let runtime = PjrtRuntime::cpu()?;
    println!("platform: {}", runtime.platform());
    for entry in &manifest.entries {
        let (l, g, n) = (entry.num_groups, entry.group_size, entry.n);
        let mut rng = Pcg64::new(0xA77E);
        let mmm = l * g;
        let cost = Mat::from_fn(mmm, n, |_, _| rng.uniform(0.0, 1.0));
        let labels: Vec<usize> = (0..mmm).map(|i| i / g).collect();
        let prob = OtProblem::from_parts(
            vec![1.0 / mmm as f64; mmm],
            vec![1.0 / n as f64; n],
            &cost,
            &labels,
        );
        let params = DualParams::new(0.8, 0.5);
        let mut oracle = XlaDualOracle::from_problem(&runtime, &prob, &params, &dir)?;
        let x: Vec<f64> = (0..prob.dim()).map(|_| rng.uniform(-0.3, 0.6)).collect();
        let mut g_xla = vec![0.0; prob.dim()];
        let f_xla = grpot::ot::dual::DualOracle::eval(&mut oracle, &x, &mut g_xla);
        let mut g_rust = vec![0.0; prob.dim()];
        let (f_rust, _) = grpot::ot::dual::eval_dense(&prob, &params, &x, &mut g_rust);
        let gerr = g_xla
            .iter()
            .zip(&g_rust)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        let ok = (f_xla - f_rust).abs() < 1e-9 && gerr < 1e-9;
        println!(
            "{} (L={l} g={g} n={n}): obj_err={:.2e} grad_err={gerr:.2e} {}",
            entry.name,
            (f_xla - f_rust).abs(),
            if ok { "OK" } else { "MISMATCH" },
        );
        if !ok {
            grpot::bail!("artifact {} numerics mismatch", entry.name);
        }
    }
    println!("all {} artifacts validated", manifest.entries.len());
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_validate_artifacts(_m: &grpot::cli::Matches) -> Result<()> {
    grpot::bail!(
        "this binary was built without the `xla` feature; \
         rebuild with `cargo build --features xla` to validate AOT artifacts"
    )
}

#[cfg(feature = "xla")]
fn print_runtime_info() {
    match grpot::runtime::PjrtRuntime::cpu() {
        Ok(rt) => println!("pjrt: {} available", rt.platform()),
        Err(e) => println!("pjrt: unavailable ({e})"),
    }
    match grpot::runtime::Manifest::load(&grpot::runtime::artifact_dir()) {
        Ok(man) => println!(
            "artifacts: {} entries in {}",
            man.entries.len(),
            man.dir.display()
        ),
        Err(_) => println!("artifacts: none (run `make artifacts`)"),
    }
}

#[cfg(not(feature = "xla"))]
fn print_runtime_info() {
    println!("pjrt: disabled (built without the `xla` cargo feature)");
}

fn cmd_info() -> Result<()> {
    println!("grpot {}", env!("CARGO_PKG_VERSION"));
    println!(
        "paper: Ida et al., \"Fast Regularized Discrete Optimal Transport \
         with Group-Sparse Regularizers\", AAAI 2023"
    );
    println!(
        "simd: {} (GRPOT_SIMD={})",
        grpot::simd::Dispatch::resolve(grpot::simd::SimdMode::Auto).name(),
        std::env::var("GRPOT_SIMD").unwrap_or_else(|_| "unset".into())
    );
    println!(
        "regularizers: group_lasso, squared_l2, negentropy (default: {}, GRPOT_REG={})",
        RegKind::env_default().map_or("invalid", |k| k.name()),
        std::env::var("GRPOT_REG").unwrap_or_else(|_| "unset".into())
    );
    println!(
        "cost backends: dense, factored (default: {}, GRPOT_COST={})",
        CostMode::env_default().map_or("invalid", |c| c.name()),
        std::env::var("GRPOT_COST").unwrap_or_else(|_| "unset".into())
    );
    println!(
        "batch: K={} (GRPOT_BATCH_K={})",
        SolveOptions::new()
            .resolve_batch_k()
            .map_or_else(|_| "invalid".to_string(), |k| k.to_string()),
        std::env::var("GRPOT_BATCH_K").unwrap_or_else(|_| "unset".into())
    );
    println!(
        "tile ring: {} KiB/chunk (GRPOT_TILE_RING_KIB={})",
        SolveOptions::new()
            .resolve_tile_ring_bytes()
            .map_or_else(|_| "invalid".to_string(), |b| (b >> 10).to_string()),
        std::env::var("GRPOT_TILE_RING_KIB").unwrap_or_else(|_| "unset".into())
    );
    println!(
        "trace: {} (GRPOT_TRACE={}, ring capacity {} spans/thread)",
        grpot::obs::trace_mode().name(),
        std::env::var("GRPOT_TRACE").unwrap_or_else(|_| "unset".into()),
        grpot::obs::ring::DEFAULT_RING_CAPACITY
    );
    println!(
        "faults: {} (GRPOT_FAULTS={})",
        grpot::fault::describe(),
        std::env::var("GRPOT_FAULTS").unwrap_or_else(|_| "unset".into())
    );
    print_runtime_info();
    Ok(())
}

fn main() {
    // Validate the SIMD knob once at launch: a malformed GRPOT_SIMD
    // must be one clear startup error, not a per-request panic inside a
    // serving-engine worker when the first oracle is constructed.
    if let Ok(v) = std::env::var("GRPOT_SIMD") {
        if let Err(e) = grpot::simd::SimdMode::parse(&v) {
            eprintln!("GRPOT_SIMD: {e}");
            std::process::exit(2);
        }
    }
    // Same policy for the regularizer knob: a malformed GRPOT_REG is
    // one clear startup error, not a late per-solve failure.
    if let Ok(v) = std::env::var("GRPOT_REG") {
        if let Err(e) = RegKind::parse(&v) {
            eprintln!("GRPOT_REG: {e}");
            std::process::exit(2);
        }
    }
    // And the cost backend: a malformed GRPOT_COST must fail at launch,
    // not when the first problem is built deep inside a worker.
    if let Ok(v) = std::env::var("GRPOT_COST") {
        if let Err(e) = CostMode::parse(&v) {
            eprintln!("GRPOT_COST: {e}");
            std::process::exit(2);
        }
    }
    // And the batching knobs: a malformed GRPOT_BATCH_K or
    // GRPOT_TILE_RING_KIB must fail at launch, not when the first
    // coalesced batch is assembled inside an engine worker. The
    // resolvers error only on bad env values (no flag set here).
    if let Err(e) = SolveOptions::new().resolve_batch_k() {
        eprintln!("{e}");
        std::process::exit(2);
    }
    if let Err(e) = SolveOptions::new().resolve_tile_ring_bytes() {
        eprintln!("{e}");
        std::process::exit(2);
    }
    // And GRPOT_TRACE: validate + latch the tracing mode once at launch
    // (the hot paths read a single atomic thereafter).
    if let Err(e) = grpot::obs::init_from_env() {
        eprintln!("{e}");
        std::process::exit(2);
    }
    // And GRPOT_FAULTS: a malformed failpoint spec is a launch error,
    // not a per-request surprise deep inside a worker.
    if let Err(e) = grpot::fault::init_from_env() {
        eprintln!("{e}");
        std::process::exit(2);
    }
    let parsed = match app().parse_env() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{}", e.0);
            std::process::exit(2);
        }
    };
    let result = match &parsed.subcommand {
        Some((name, m)) => match name.as_str() {
            "solve" => cmd_solve(m),
            "sweep" => cmd_sweep(m),
            "serve" => cmd_serve(m),
            "request" => cmd_request(m),
            "metrics" => cmd_metrics(m),
            "bench-serve" => cmd_bench_serve(m),
            "validate-artifacts" => cmd_validate_artifacts(m),
            "info" => cmd_info(),
            _ => unreachable!("cli rejects unknown subcommands"),
        },
        None => {
            eprintln!("{}", app().help());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
