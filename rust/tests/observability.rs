//! Observability integration: trace-ID propagation through the serving
//! engine under concurrency, span-ring overflow semantics, the
//! SolveReport ↔ OracleStats byte-match contract, a Prometheus
//! round-trip through an in-test parser, and the zero-perturbation
//! guarantee (tracing off or on, solver outputs are byte-identical).
//!
//! Trace mode and the span rings are process-global and `cargo test`
//! runs tests concurrently, so every assertion here tolerates *foreign*
//! spans (from sibling tests) and only ever asserts the **presence** of
//! its own trace IDs, never the absence of others.

use grpot::coordinator::config::{DatasetSpec, Method};
use grpot::coordinator::metrics::{exp_buckets, Metrics};
use grpot::coordinator::sweep;
use grpot::obs::ring::Ring;
use grpot::obs::{self, ObserverHook, TraceMode};
use grpot::ot::dual::OtProblem;
use grpot::ot::regularizer::RegKind;
use grpot::ot::solve::SolveOptions;
use grpot::serve::{Engine, ServeConfig, SolveRequest};
use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Trace mode is process-global and tests in this binary run on
/// concurrent threads: every test that *sets* the mode holds this lock
/// for its whole body so they serialize against each other. (Other
/// test binaries are separate processes and unaffected.)
static MODE_LOCK: Mutex<()> = Mutex::new(());

fn mode_guard() -> std::sync::MutexGuard<'static, ()> {
    MODE_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn tiny_spec(seed: u64) -> DatasetSpec {
    DatasetSpec {
        family: "synthetic".into(),
        param1: 4,
        param2: 5,
        seed,
        ..Default::default()
    }
}

fn request(seed: u64, gamma: f64, rho: f64) -> SolveRequest {
    SolveRequest {
        spec: tiny_spec(seed),
        gamma,
        rho,
        method: Method::Fast,
        regularizer: RegKind::GroupLasso,
        deadline: None,
        warm_start: true,
    }
}

fn tiny_problem(seed: u64) -> OtProblem {
    let pair = grpot::coordinator::registry::build_pair(&tiny_spec(seed)).expect("dataset");
    OtProblem::from_dataset(&pair)
}

/// Trace-ID propagation: every reply carries the unique nonzero ID
/// minted at admission, and with tracing on the queue-wait spans those
/// requests produced are drained with the same IDs stamped on them.
#[test]
fn trace_ids_propagate_through_engine_under_concurrency() {
    let _serial = mode_guard();
    obs::set_trace_mode(TraceMode::Full);
    let metrics = Arc::new(Metrics::new());
    let engine = Engine::start(
        ServeConfig { workers: 3, queue_capacity: 256, ..Default::default() },
        Arc::clone(&metrics),
    );
    let ids = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for c in 0..6usize {
            let engine = &engine;
            let ids = &ids;
            s.spawn(move || {
                let gammas = [0.2, 1.0, 5.0];
                for k in 0..4usize {
                    let reply = engine
                        .submit(request(3, gammas[(c + k) % gammas.len()], 0.5))
                        .expect("request served");
                    assert_ne!(reply.trace_id, 0, "reply must carry the admission trace ID");
                    ids.lock().unwrap().push(reply.trace_id);
                }
            });
        }
    });
    engine.shutdown();
    let ids = ids.into_inner().unwrap();
    let unique: HashSet<u64> = ids.iter().copied().collect();
    assert_eq!(unique.len(), ids.len(), "trace IDs must be unique per request");

    // Every request waited in the queue, so every trace ID must appear
    // on at least one drained queue-wait span. Foreign spans from
    // concurrently running tests are fine; missing *ours* is not.
    let spans = grpot::obs::ring::snapshot_all();
    let queue_ids: HashSet<u64> = spans
        .iter()
        .filter(|e| e.name_id == grpot::obs::names::QUEUE_WAIT)
        .map(|e| e.trace_id)
        .collect();
    for id in &unique {
        assert!(queue_ids.contains(id), "no queue.wait span drained for trace ID {id}");
    }
    // Solve + batch spans exist too (trace IDs of batch spans are 0;
    // just check the engine recorded some work under Full).
    assert!(
        spans.iter().any(|e| e.name_id == grpot::obs::names::ENGINE_SOLVE),
        "no engine.solve span drained"
    );
    obs::set_trace_mode(TraceMode::Off);
}

/// Ring overflow drops the oldest spans and never yields a torn event,
/// even with a concurrent reader hammering snapshots mid-write.
#[test]
fn ring_overflow_drops_oldest_without_tearing() {
    let ring = Arc::new(Ring::with_capacity(64));
    let writes: u64 = 20_000;
    let done = Arc::new(AtomicU64::new(0));
    let reader = {
        let ring = Arc::clone(&ring);
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            let mut drains = 0u64;
            while done.load(Ordering::Acquire) == 0 {
                for e in ring.snapshot() {
                    // Writer encodes trace_id == start_ns == i and
                    // dur_ns == i + 1; a torn read breaks the relation.
                    assert_eq!(e.trace_id, e.start_ns, "torn span: {e:?}");
                    assert_eq!(e.dur_ns, e.start_ns + 1, "torn span: {e:?}");
                }
                drains += 1;
            }
            drains
        })
    };
    for i in 0..writes {
        ring.record(0, 1, i, i, i + 1);
    }
    done.store(1, Ordering::Release);
    assert!(reader.join().unwrap() > 0);

    assert_eq!(ring.recorded(), writes);
    let survivors: Vec<u64> = ring.snapshot().iter().map(|e| e.trace_id).collect();
    assert_eq!(survivors.len(), 64);
    // Drop-oldest: everything still resident is from the newest window.
    for id in &survivors {
        assert!(*id >= writes - 64, "stale span {id} survived past capacity");
    }
}

/// The observer's SolveReport is built from the *same* OracleStats the
/// solver returns — counters byte-match, and the headline
/// skipped-group fraction is exactly skipped / (computed + skipped).
#[test]
fn solve_report_counters_match_oracle_stats() {
    let prob = tiny_problem(7);
    let (hook, cell) = ObserverHook::capture();
    let opts = SolveOptions::new()
        .gamma(1.0)
        .rho(0.5)
        .observer(hook)
        .trace_id(424242);
    let res = sweep::solve(&prob, Method::Fast, &opts).expect("solve");
    let report = cell.lock().unwrap().take().expect("observer must fire once");

    assert_eq!(report.trace_id, 424242);
    assert_eq!(report.method, "fast");
    assert_eq!(report.iterations, res.iterations);
    assert_eq!(report.outer_rounds, res.outer_rounds);
    assert_eq!(report.evals, res.stats.evals);
    assert_eq!(report.grads_computed, res.stats.grads_computed);
    assert_eq!(report.grads_skipped, res.stats.grads_skipped);
    assert_eq!(report.ub_checks, res.stats.ub_checks);
    assert_eq!(report.ws_hits, res.stats.ws_hits);
    let total = res.stats.grads_computed + res.stats.grads_skipped;
    assert!(total > 0);
    let expect = res.stats.grads_skipped as f64 / total as f64;
    assert_eq!(report.skipped_group_fraction.to_bits(), expect.to_bits());

    // Per-round telemetry sums back to the totals (the rounds partition
    // the counter deltas).
    let sum_computed: u64 = report.rounds.iter().map(|r| r.grads_computed).sum();
    let sum_skipped: u64 = report.rounds.iter().map(|r| r.grads_skipped).sum();
    assert_eq!(sum_computed, report.grads_computed);
    assert_eq!(sum_skipped, report.grads_skipped);
    assert!(report.wall_time_s >= 0.0);
    assert!(!report.simd_backend.is_empty());
}

/// Minimal Prometheus text-exposition parser: `name{labels} value`
/// lines plus `# TYPE` headers. Enough to round-trip our renderer.
fn parse_prom(text: &str) -> (Vec<(String, String)>, Vec<(String, f64)>) {
    let mut types = Vec::new();
    let mut samples = Vec::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().expect("type name").to_string();
            let kind = it.next().expect("type kind").to_string();
            assert!(it.next().is_none(), "trailing junk in TYPE line: {line}");
            types.push((name, kind));
        } else if line.starts_with('#') {
            continue; // HELP or comment
        } else {
            let (key, value) = line.rsplit_once(' ').expect("sample line: {line}");
            let v = if value == "+Inf" { f64::INFINITY } else { value.parse().unwrap() };
            samples.push((key.to_string(), v));
        }
    }
    (types, samples)
}

#[test]
fn prometheus_round_trip() {
    let m = Metrics::new();
    m.register_counters(&["serve.requests"]);
    m.incr("serve.requests", 7);
    m.set_gauge("queue.depth", 3.0);
    m.register_hist_buckets("lat", &exp_buckets(0.001, 10.0, 3)); // 1ms, 10ms, 100ms
    m.observe_hist("lat", 0.0005);
    m.observe_hist("lat", 0.05);
    m.observe_hist("lat", 2.0);
    let text = grpot::obs::prom::render(&m.snapshot());

    let (types, samples) = parse_prom(&text);
    let kind = |n: &str| types.iter().find(|(t, _)| t == n).map(|(_, k)| k.as_str());
    assert_eq!(kind("grpot_serve_requests"), Some("counter"));
    assert_eq!(kind("grpot_queue_depth"), Some("gauge"));
    assert_eq!(kind("grpot_lat"), Some("histogram"));

    let val = |k: &str| {
        samples
            .iter()
            .find(|(s, _)| s == k)
            .unwrap_or_else(|| panic!("missing sample {k} in:\n{text}"))
            .1
    };
    assert_eq!(val("grpot_serve_requests"), 7.0);
    assert_eq!(val("grpot_queue_depth"), 3.0);
    // Cumulative buckets: 0.0005 ≤ 0.001; 0.05 ≤ 0.1; 2.0 only in +Inf.
    assert_eq!(val("grpot_lat_bucket{le=\"0.001\"}"), 1.0);
    assert_eq!(val("grpot_lat_bucket{le=\"0.01\"}"), 1.0);
    assert_eq!(val("grpot_lat_bucket{le=\"0.1\"}"), 2.0);
    assert_eq!(val("grpot_lat_bucket{le=\"+Inf\"}"), 3.0);
    assert_eq!(val("grpot_lat_count"), 3.0);
    assert!((val("grpot_lat_sum") - 2.0505).abs() < 1e-12);
}

/// The zero-perturbation guarantee: the same solve with tracing Off and
/// Full produces byte-identical dual variables, objective and counters.
/// Tracing reads counters the solver already maintains; it must never
/// change what the solver computes.
#[test]
fn tracing_mode_never_perturbs_solver_results() {
    let _serial = mode_guard();
    let prob = tiny_problem(13);
    let opts = SolveOptions::new().gamma(0.8).rho(0.6).trace_id(7);
    let run = || sweep::solve(&prob, Method::Fast, &opts).expect("solve");

    obs::set_trace_mode(TraceMode::Off);
    let off = run();
    obs::set_trace_mode(TraceMode::Full);
    let full = run();
    obs::set_trace_mode(TraceMode::Off);
    let off2 = run();

    for (a, b) in [(&off, &full), (&off, &off2)] {
        assert_eq!(a.x.len(), b.x.len());
        for (xa, xb) in a.x.iter().zip(&b.x) {
            assert_eq!(xa.to_bits(), xb.to_bits(), "dual variables diverged");
        }
        assert_eq!(a.dual_objective.to_bits(), b.dual_objective.to_bits());
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.outer_rounds, b.outer_rounds);
        assert_eq!(a.stats, b.stats, "oracle counters diverged");
    }
}

/// An observer on SolveOptions also never perturbs the solve: with and
/// without the hook, outputs are byte-identical (the report is built
/// *from* the result, not folded into it).
#[test]
fn observer_hook_never_perturbs_solver_results() {
    let prob = tiny_problem(29);
    let base = SolveOptions::new().gamma(1.5).rho(0.4);
    let plain = sweep::solve(&prob, Method::Fast, &base).expect("solve");
    let (hook, cell) = ObserverHook::capture();
    let observed =
        sweep::solve(&prob, Method::Fast, &base.clone().observer(hook)).expect("solve");
    assert!(cell.lock().unwrap().is_some());
    for (xa, xb) in plain.x.iter().zip(&observed.x) {
        assert_eq!(xa.to_bits(), xb.to_bits());
    }
    assert_eq!(plain.dual_objective.to_bits(), observed.dual_objective.to_bits());
    assert_eq!(plain.stats, observed.stats);
}
