//! Warm-start dual cache: recent dual vectors keyed by (dataset, γ, ρ)
//! under an LRU byte budget.
//!
//! A serving workload revisits a small set of hyperparameter points on
//! a small set of datasets, so the dual optimum of a *nearby* (γ, ρ)
//! problem is an excellent L-BFGS seed — regularization-path solvers
//! exploit exactly this structure. Safety is free: the screening bounds
//! hold from any starting iterate (Theorem 2), so a warm start changes
//! the iteration count, never the answer.
//!
//! Nearness is measured in `(ln γ, ρ)` space — γ sweeps are logarithmic
//! (the paper's grid spans 1e-3…1e3) while ρ lives on [0, 1), so
//! `√((Δln γ)² + (Δρ)²)` weighs both axes comparably.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A cache hit: the seed vector plus how it matched.
#[derive(Clone)]
pub struct CacheSeed {
    pub dual: Arc<Vec<f64>>,
    /// Same (γ, ρ), not just nearby.
    pub exact: bool,
    /// Distance in `(ln γ, ρ)` space (0 for exact hits).
    pub distance: f64,
}

struct CacheEntry {
    dataset: String,
    gamma: f64,
    /// `ln γ`, hoisted at insert time: the nearest-neighbor scan is per
    /// lookup × per entry, so the logarithm is paid once per stored
    /// entry instead of once per comparison.
    ln_gamma: f64,
    rho: f64,
    dual: Arc<Vec<f64>>,
    bytes: usize,
    last_used: u64,
}

struct CacheState {
    entries: Vec<CacheEntry>,
    clock: u64,
    bytes: usize,
}

/// LRU-evicted store of dual vectors under a byte budget.
pub struct DualCache {
    state: Mutex<CacheState>,
    budget: usize,
    radius: f64,
    /// Entries evicted by the LRU budget loop since construction
    /// (telemetry; the engine publishes it as a gauge).
    evictions: AtomicU64,
}

/// Distance in `(ln γ, ρ)` space over *pre-computed* logs (see
/// [`CacheEntry::ln_gamma`]).
fn param_distance_ln(lg1: f64, r1: f64, lg2: f64, r2: f64) -> f64 {
    let dg = lg1 - lg2;
    let dr = r1 - r2;
    (dg * dg + dr * dr).sqrt()
}

fn entry_bytes(dual: &[f64]) -> usize {
    std::mem::size_of_val(dual)
}

impl DualCache {
    /// `budget` in bytes (0 disables the cache entirely); `radius` is
    /// the largest `(ln γ, ρ)` distance at which a neighbor still seeds.
    pub fn new(budget: usize, radius: f64) -> Self {
        DualCache {
            state: Mutex::new(CacheState { entries: Vec::new(), clock: 0, bytes: 0 }),
            budget,
            radius,
            evictions: AtomicU64::new(0),
        }
    }

    /// Entries evicted by the byte-budget LRU loop so far.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Current entry count.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current resident bytes.
    pub fn bytes(&self) -> usize {
        self.state.lock().unwrap().bytes
    }

    /// Store (or refresh) the dual for `(dataset, γ, ρ)`, evicting the
    /// least-recently-used entries until the budget holds. A vector
    /// larger than the whole budget is not cached.
    pub fn insert(&self, dataset: &str, gamma: f64, rho: f64, dual: Vec<f64>) {
        let bytes = entry_bytes(&dual);
        if bytes > self.budget {
            return;
        }
        let mut st = self.state.lock().unwrap();
        st.clock += 1;
        let clock = st.clock;
        if let Some(e) = st
            .entries
            .iter_mut()
            .find(|e| e.dataset == dataset && e.gamma == gamma && e.rho == rho)
        {
            // Replace in place: same key, fresher dual.
            let old = e.bytes;
            e.dual = Arc::new(dual);
            e.bytes = bytes;
            e.last_used = clock;
            st.bytes = st.bytes - old + bytes;
        } else {
            st.entries.push(CacheEntry {
                dataset: dataset.to_string(),
                gamma,
                ln_gamma: gamma.ln(),
                rho,
                dual: Arc::new(dual),
                bytes,
                last_used: clock,
            });
            st.bytes += bytes;
        }
        while st.bytes > self.budget {
            let lru = st
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(i, _)| i)
                .expect("bytes > 0 implies entries");
            let gone = st.entries.swap_remove(lru);
            st.bytes -= gone.bytes;
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Best seed for `(dataset, γ, ρ)`: the exact entry when present,
    /// otherwise the nearest same-dataset neighbor within the radius.
    pub fn lookup(&self, dataset: &str, gamma: f64, rho: f64) -> Option<CacheSeed> {
        let mut st = self.state.lock().unwrap();
        st.clock += 1;
        let clock = st.clock;
        // One `ln` per lookup; entries carry theirs from insert time.
        let ln_gamma = gamma.ln();
        let mut best: Option<(usize, f64)> = None;
        for (i, e) in st.entries.iter().enumerate() {
            if e.dataset != dataset {
                continue;
            }
            let d = if e.gamma == gamma && e.rho == rho {
                0.0
            } else {
                param_distance_ln(e.ln_gamma, e.rho, ln_gamma, rho)
            };
            let better = match best {
                None => true,
                Some((_, best_d)) => d < best_d,
            };
            if d <= self.radius && better {
                best = Some((i, d));
            }
        }
        best.map(|(i, d)| {
            st.entries[i].last_used = clock;
            CacheSeed { dual: Arc::clone(&st.entries[i].dual), exact: d == 0.0, distance: d }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dual(v: f64, len: usize) -> Vec<f64> {
        vec![v; len]
    }

    #[test]
    fn exact_hit_beats_neighbor() {
        let c = DualCache::new(1 << 20, 2.0);
        c.insert("ds", 1.0, 0.5, dual(1.0, 8));
        c.insert("ds", 1.1, 0.5, dual(2.0, 8));
        let hit = c.lookup("ds", 1.0, 0.5).expect("hit");
        assert!(hit.exact);
        assert_eq!(hit.distance, 0.0);
        assert_eq!(hit.dual[0], 1.0);
    }

    #[test]
    fn nearest_neighbor_within_radius() {
        let c = DualCache::new(1 << 20, 2.0);
        c.insert("ds", 1.0, 0.4, dual(1.0, 8));
        c.insert("ds", 10.0, 0.4, dual(2.0, 8));
        let hit = c.lookup("ds", 1.5, 0.4).expect("hit");
        assert!(!hit.exact);
        assert_eq!(hit.dual[0], 1.0); // ln 1.5 is closer to ln 1 than ln 10
        assert!(hit.distance > 0.0 && hit.distance < 1.0);
        // Far outside the radius: miss.
        assert!(c.lookup("ds", 1e6, 0.4).is_none());
        // Different dataset: miss.
        assert!(c.lookup("other", 1.0, 0.4).is_none());
    }

    #[test]
    fn lru_eviction_respects_budget() {
        let len = 16; // 128 bytes per entry
        let c = DualCache::new(3 * 128, 2.0);
        c.insert("ds", 1.0, 0.2, dual(1.0, len));
        c.insert("ds", 1.0, 0.4, dual(2.0, len));
        c.insert("ds", 1.0, 0.6, dual(3.0, len));
        assert_eq!(c.len(), 3);
        // Touch the oldest so it becomes most-recent.
        assert!(c.lookup("ds", 1.0, 0.2).unwrap().exact);
        // Inserting a fourth evicts the LRU — now (1.0, 0.4).
        c.insert("ds", 1.0, 0.8, dual(4.0, len));
        assert_eq!(c.len(), 3);
        assert_eq!(c.evictions(), 1);
        assert!(c.bytes() <= 3 * 128);
        assert!(c.lookup("ds", 1.0, 0.2).is_some_and(|s| s.exact));
        assert!(c.lookup("ds", 1.0, 0.8).is_some_and(|s| s.exact));
        assert!(!c.lookup("ds", 1.0, 0.4).is_some_and(|s| s.exact));
    }

    #[test]
    fn same_key_replaces_in_place() {
        let c = DualCache::new(1 << 20, 2.0);
        c.insert("ds", 1.0, 0.5, dual(1.0, 8));
        c.insert("ds", 1.0, 0.5, dual(9.0, 8));
        assert_eq!(c.len(), 1);
        assert_eq!(c.lookup("ds", 1.0, 0.5).unwrap().dual[0], 9.0);
    }

    #[test]
    fn oversized_and_zero_budget_entries_skipped() {
        let c = DualCache::new(64, 2.0);
        c.insert("ds", 1.0, 0.5, dual(1.0, 1000)); // 8000 bytes > 64
        assert!(c.is_empty());
        let off = DualCache::new(0, 2.0);
        off.insert("ds", 1.0, 0.5, dual(1.0, 2));
        assert!(off.lookup("ds", 1.0, 0.5).is_none());
    }
}
