//! Object-recognition substitute (Caltech-Office with DeCAF₆ features,
//! Fig. 5).
//!
//! The paper uses 4096-d DeCAF₆ activations (post-ReLU fc6 of an
//! ILSVRC-trained CNN) over 10 classes and four domains — Caltech-256
//! (1123), Amazon (958), Webcam (295), DSLR (157). Offline substitute:
//! sparse *nonnegative* feature vectors matching post-ReLU statistics
//! (most units silent, heavy-tailed active units); each class owns a
//! random subset of "selective units", each domain applies a gain
//! vector + unit dropout (camera/background statistics).

use super::{Dataset, DomainPair};
use crate::linalg::Mat;
use crate::rng::Pcg64;

const DIM: usize = 4096;
const NUM_CLASSES: usize = 10;
/// Selective units per class (≈2% of 4096, typical fc6 selectivity).
const UNITS_PER_CLASS: usize = 80;

/// The four Caltech-Office domains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OfficeDomain {
    Caltech,
    Amazon,
    Webcam,
    Dslr,
}

impl OfficeDomain {
    pub const ALL: [OfficeDomain; 4] = [
        OfficeDomain::Caltech,
        OfficeDomain::Amazon,
        OfficeDomain::Webcam,
        OfficeDomain::Dslr,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            OfficeDomain::Caltech => "caltech",
            OfficeDomain::Amazon => "amazon",
            OfficeDomain::Webcam => "webcam",
            OfficeDomain::Dslr => "dslr",
        }
    }

    /// Paper sample counts.
    pub fn full_size(&self) -> usize {
        match self {
            OfficeDomain::Caltech => 1123,
            OfficeDomain::Amazon => 958,
            OfficeDomain::Webcam => 295,
            OfficeDomain::Dslr => 157,
        }
    }

    fn index(&self) -> usize {
        match self {
            OfficeDomain::Caltech => 0,
            OfficeDomain::Amazon => 1,
            OfficeDomain::Webcam => 2,
            OfficeDomain::Dslr => 3,
        }
    }
}

/// Class-selective unit sets, shared across domains.
fn class_units(proto_seed: u64) -> Vec<Vec<usize>> {
    let mut rng = Pcg64::new(proto_seed);
    (0..NUM_CLASSES)
        .map(|_| rng.sample_indices(DIM, UNITS_PER_CLASS))
        .collect()
}

/// Generate one domain scaled to `scale ∈ (0, 1]` of the paper size.
pub fn generate(domain: OfficeDomain, scale: f64, proto_seed: u64, seed: u64) -> Dataset {
    assert!(scale > 0.0 && scale <= 1.0);
    let samples = ((domain.full_size() as f64 * scale).round() as usize).max(NUM_CLASSES);
    let units = class_units(proto_seed);
    // Domain-specific gain field + dropout rate.
    let mut drng = Pcg64::new(proto_seed ^ (0xDECAF + domain.index() as u64));
    let gains: Vec<f64> = (0..DIM).map(|_| drng.uniform(0.6, 1.4)).collect();
    let dropout = [0.1, 0.15, 0.3, 0.35][domain.index()];
    let background = [0.02, 0.03, 0.05, 0.04][domain.index()];

    let mut rng = Pcg64::new(seed);
    let mut x = Mat::zeros(samples, DIM);
    let mut labels = Vec::with_capacity(samples);
    for s in 0..samples {
        let class = s % NUM_CLASSES;
        labels.push(class);
        let row = x.row_mut(s);
        // Background firing: sparse small activations anywhere.
        let bg_count = (background * DIM as f64) as usize;
        for _ in 0..bg_count {
            let d = rng.below(DIM);
            row[d] += rng.exp1() * 0.2;
        }
        // Class-selective units: heavy-tailed (log-normal-ish) via exp
        // of a normal, with domain gain and dropout.
        for &d in &units[class] {
            if rng.f64() < dropout {
                continue;
            }
            let mag = (0.5 * rng.normal()).exp(); // lognormal, median 1
            row[d] += gains[d] * mag;
        }
    }
    Dataset { name: domain.name().to_string(), x, labels }
}

/// All 12 ordered Caltech-Office adaptation tasks at the given scale.
pub fn all_tasks(scale: f64, seed: u64) -> Vec<DomainPair> {
    let mut tasks = Vec::with_capacity(12);
    for (si, &s) in OfficeDomain::ALL.iter().enumerate() {
        for (ti, &t) in OfficeDomain::ALL.iter().enumerate() {
            if si == ti {
                continue;
            }
            tasks.push(DomainPair {
                source: generate(s, scale, 0xDECAF, seed + si as u64),
                target: generate(t, scale, 0xDECAF, seed + 100 + ti as u64),
            });
        }
    }
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_and_sparsity() {
        let d = generate(OfficeDomain::Dslr, 1.0, 1, 2);
        assert_eq!(d.len(), 157);
        assert_eq!(d.dim(), 4096);
        assert_eq!(d.num_classes(), 10);
        // Post-ReLU statistics: nonnegative and mostly zero.
        let nz = d.x.count_nonzero(0.0);
        let frac = nz as f64 / (d.len() * d.dim()) as f64;
        assert!(d.x.as_slice().iter().all(|&v| v >= 0.0));
        assert!(frac < 0.15, "too dense: {frac}");
        assert!(frac > 0.005, "too sparse: {frac}");
    }

    #[test]
    fn twelve_tasks_with_correct_sizes() {
        let tasks = all_tasks(0.2, 5);
        assert_eq!(tasks.len(), 12);
        let c_a = tasks
            .iter()
            .find(|t| t.task_name() == "caltech→amazon")
            .expect("task present");
        assert_eq!(c_a.source.len(), 225); // round(1123·0.2)
        assert_eq!(c_a.target.len(), 192); // round(958·0.2)
    }

    #[test]
    fn classes_cluster_across_domains() {
        let a = generate(OfficeDomain::Amazon, 0.3, 7, 1);
        let b = generate(OfficeDomain::Webcam, 0.6, 7, 9);
        let dist = |i: usize, j: usize| {
            crate::linalg::sub(a.x.row(i), b.x.row(j))
                .iter()
                .map(|v| v * v)
                .sum::<f64>()
        };
        let (mut same, mut diff) = ((0.0, 0usize), (0.0, 0usize));
        for i in 0..60.min(a.len()) {
            for j in 0..60.min(b.len()) {
                if a.labels[i] == b.labels[j] {
                    same = (same.0 + dist(i, j), same.1 + 1);
                } else {
                    diff = (diff.0 + dist(i, j), diff.1 + 1);
                }
            }
        }
        let sm = same.0 / same.1 as f64;
        let dm = diff.0 / diff.1 as f64;
        assert!(sm < 0.9 * dm, "same={sm} diff={dm}");
    }
}
