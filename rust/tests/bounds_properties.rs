//! Property-based tests of the screening bounds (Lemmas 1–6) and the
//! soft-threshold conjugate machinery, using the in-repo proptest-lite
//! harness on randomized problem instances, iterates and snapshots.

use grpot::linalg::Mat;
use grpot::ot::dual::{exact_z, DualOracle, DualParams, OtProblem};
use grpot::ot::screening::ScreeningOracle;
use grpot::rng::Pcg64;
use grpot::testing::{check, gen_group_sizes, offsets_from_sizes, Config};

/// Build a random ragged-group problem.
fn random_problem(rng: &mut Pcg64) -> OtProblem {
    let l = 1 + rng.below(5);
    let sizes = gen_group_sizes(rng, l, 6);
    let m: usize = sizes.iter().sum();
    let n = 1 + rng.below(8);
    let mut labels = Vec::with_capacity(m);
    for (g, &s) in sizes.iter().enumerate() {
        labels.resize(labels.len() + s, g);
    }
    let cost = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
    OtProblem::from_parts(vec![1.0 / m as f64; m], vec![1.0 / n as f64; n], &cost, &labels)
}

/// Manual recomputation of both bounds for one (l, j) pair.
struct ManualBounds {
    upper: f64,
    lower: f64,
    z: f64,
}

fn manual_bounds(
    prob: &OtProblem,
    snap_x: &[f64],
    x: &[f64],
    l: usize,
    j: usize,
) -> ManualBounds {
    let m = prob.m();
    let (alpha, beta) = x.split_at(m);
    let (s_alpha, s_beta) = snap_x.split_at(m);
    let c_j = prob.cost_t().row(j);
    let range = prob.groups.range(l);
    let sqrt_g = prob.groups.sqrt_sizes[l];

    // Snapshot quantities (Definitions 1–2).
    let mut z_tilde_sq = 0.0;
    let mut k_tilde_sq = 0.0;
    let mut o_tilde_sq = 0.0;
    for i in range.clone() {
        let f = s_alpha[i] + s_beta[j] - c_j[i];
        k_tilde_sq += f * f;
        if f > 0.0 {
            z_tilde_sq += f * f;
        } else {
            o_tilde_sq += f * f;
        }
    }
    // Deltas.
    let (mut dp_sq, mut dn_sq, mut dd_sq) = (0.0, 0.0, 0.0);
    for i in range.clone() {
        let d = alpha[i] - s_alpha[i];
        dd_sq += d * d;
        if d > 0.0 {
            dp_sq += d * d;
        } else {
            dn_sq += d * d;
        }
    }
    let db = beta[j] - s_beta[j];
    let upper = z_tilde_sq.sqrt() + dp_sq.sqrt() + sqrt_g * db.max(0.0);
    let lower = k_tilde_sq.sqrt()
        - dd_sq.sqrt()
        - sqrt_g * db.abs()
        - o_tilde_sq.sqrt()
        - dn_sq.sqrt()
        - sqrt_g * (-db).max(0.0);
    let z = exact_z(alpha, beta[j], c_j, range);
    ManualBounds { upper, lower, z }
}

#[test]
fn lemma1_upper_bound_dominates_z() {
    check("z̄ ≥ z (Lemma 1)", &Config::cases(100), |rng| {
        let prob = random_problem(rng);
        let dim = prob.dim();
        let snap_x: Vec<f64> = (0..dim).map(|_| rng.uniform(-0.6, 0.8)).collect();
        let x: Vec<f64> = snap_x.iter().map(|&v| v + rng.uniform(-0.3, 0.3)).collect();
        for l in 0..prob.groups.num_groups() {
            for j in 0..prob.n() {
                let b = manual_bounds(&prob, &snap_x, &x, l, j);
                if b.upper < b.z - 1e-12 {
                    return Err(format!("upper {} < z {} at (l={l}, j={j})", b.upper, b.z));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn lemma4_lower_bound_below_z() {
    check("z̲ ≤ z (Lemma 4)", &Config::cases(100), |rng| {
        let prob = random_problem(rng);
        let dim = prob.dim();
        let snap_x: Vec<f64> = (0..dim).map(|_| rng.uniform(-0.6, 0.8)).collect();
        let x: Vec<f64> = snap_x.iter().map(|&v| v + rng.uniform(-0.3, 0.3)).collect();
        for l in 0..prob.groups.num_groups() {
            for j in 0..prob.n() {
                let b = manual_bounds(&prob, &snap_x, &x, l, j);
                if b.lower > b.z + 1e-12 {
                    return Err(format!("lower {} > z {} at (l={l}, j={j})", b.lower, b.z));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn theorem3_upper_bound_exact_at_snapshot() {
    check("z̄ = z at Δ = 0 (Theorem 3)", &Config::cases(60), |rng| {
        let prob = random_problem(rng);
        let dim = prob.dim();
        let x: Vec<f64> = (0..dim).map(|_| rng.uniform(-0.6, 0.8)).collect();
        for l in 0..prob.groups.num_groups() {
            for j in 0..prob.n() {
                let b = manual_bounds(&prob, &x, &x, l, j);
                if (b.upper - b.z).abs() > 1e-12 {
                    return Err(format!("|z̄−z| = {} ≠ 0 at snapshot", (b.upper - b.z).abs()));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn corollary1_lower_bound_exact_for_signed_f() {
    // When f_[l] is all-positive or all-negative at the snapshot AND the
    // iterate hasn't moved, ε̲ = 0 (Corollary 1).
    check("ε̲ = 0 for one-signed f (Corollary 1)", &Config::cases(60), |rng| {
        let l = 1 + rng.below(3);
        let sizes = gen_group_sizes(rng, l, 5);
        let offsets = offsets_from_sizes(&sizes);
        let m = *offsets.last().unwrap();
        let n = 1 + rng.below(4);
        let mut labels = Vec::new();
        for (g, &s) in sizes.iter().enumerate() {
            labels.resize(labels.len() + s, g);
        }
        // Build a cost so f = α + β_j − c has one sign per group.
        let positive_group: Vec<bool> = (0..l).map(|_| rng.f64() < 0.5).collect();
        let mut group_of_row = Vec::new();
        for (g, &s) in sizes.iter().enumerate() {
            group_of_row.resize(group_of_row.len() + s, g);
        }
        let cost = Mat::from_fn(m, n, |i, _| {
            if positive_group[group_of_row[i]] {
                0.0 // f = α + β ≥ 0 (α, β chosen positive below)
            } else {
                10.0 // f strongly negative
            }
        });
        let prob = OtProblem::from_parts(
            vec![1.0 / m as f64; m],
            vec![1.0 / n as f64; n],
            &cost,
            &labels,
        );
        let x: Vec<f64> = (0..prob.dim()).map(|_| rng.uniform(0.1, 1.0)).collect();
        for l in 0..prob.groups.num_groups() {
            for j in 0..prob.n() {
                let b = manual_bounds(&prob, &x, &x, l, j);
                if (b.z - b.lower).abs() > 1e-12 {
                    return Err(format!(
                        "ε̲ = {} ≠ 0 for one-signed group (l={l}, j={j})",
                        (b.z - b.lower).abs()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn screened_oracle_never_diverges_from_dense_under_random_walks() {
    check("screened == dense along random walks", &Config::cases(40), |rng| {
        let prob = random_problem(rng);
        let params = DualParams::new(rng.uniform(0.05, 3.0), rng.uniform(0.0, 0.95));
        let mut oracle = ScreeningOracle::new(&prob, params, rng.f64() < 0.5);
        let mut x = vec![0.0; prob.dim()];
        for _ in 0..6 {
            for v in x.iter_mut() {
                *v += rng.uniform(-0.25, 0.3);
            }
            if rng.f64() < 0.3 {
                oracle.refresh(&x);
            }
            let mut g1 = vec![0.0; prob.dim()];
            let f1 = oracle.eval(&x, &mut g1);
            let mut g2 = vec![0.0; prob.dim()];
            let (f2, _) = grpot::ot::dual::eval_dense(&prob, &params, &x, &mut g2);
            if f1 != f2 {
                return Err(format!("objective: {f1} != {f2}"));
            }
            if g1 != g2 {
                return Err("gradient mismatch".into());
            }
        }
        Ok(())
    });
}

#[test]
fn skipped_groups_are_exactly_zero_in_dense_plan() {
    // Whatever the screening skips must be a zero group in the dense
    // plan — the safety property.
    check("skips are safe", &Config::cases(40), |rng| {
        let prob = random_problem(rng);
        let params = DualParams::new(rng.uniform(0.5, 5.0), rng.uniform(0.3, 0.9));
        let tau = params.tau();
        let snap_x: Vec<f64> = (0..prob.dim()).map(|_| rng.uniform(-0.4, 0.6)).collect();
        let x: Vec<f64> = snap_x.iter().map(|&v| v + rng.uniform(-0.2, 0.2)).collect();
        for l in 0..prob.groups.num_groups() {
            for j in 0..prob.n() {
                let b = manual_bounds(&prob, &snap_x, &x, l, j);
                if b.upper <= tau && b.z > tau {
                    return Err(format!(
                        "unsafe skip: upper {} ≤ τ {} but z {} > τ",
                        b.upper, tau, b.z
                    ));
                }
            }
        }
        Ok(())
    });
}
