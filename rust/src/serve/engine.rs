//! The serving engine: admission-controlled, micro-batched, warm-started
//! solve execution on a fixed worker pool.
//!
//! Lifecycle: [`Engine::start`] spawns `workers` threads; each loops on
//! [`super::batcher::next_batch`], so the number of concurrent solves is
//! exactly the worker count — the queue, not a thread explosion, absorbs
//! bursts. [`Engine::submit`] blocks the calling (connection) thread on a
//! [`super::queue::ResponseSlot`] until its ticket is answered, which is
//! guaranteed: every exit path — deadline expiry, rejected admission,
//! solver failure, engine shutdown — responds with a structured
//! [`RejectReason`] rather than dropping the ticket.
//!
//! Metrics published (all under the shared [`Metrics`] registry):
//!
//! | name | kind | meaning |
//! |------|------|---------|
//! | `serve.requests` | counter | submits accepted into the queue |
//! | `serve.rejected_queue_full` | counter | backpressure rejections |
//! | `serve.rejected_deadline` | counter | deadline expiries (pre-solve triage) |
//! | `serve.rejected_quarantined` | counter | circuit-breaker fast-fails |
//! | `serve.rejected_overloaded` | counter | load-shed rejections at admission |
//! | `serve.cancelled_midsolve` | counter | solves stopped by a cancellation checkpoint |
//! | `serve.breaker_trips` | counter | circuit-breaker open transitions |
//! | `serve.solves` | counter | solver runs (≤ requests: batching dedupes) |
//! | `serve.solve_panics` | counter | solves that panicked (answered as `failed`) |
//! | `serve.batches` | counter | micro-batches executed |
//! | `serve.warm_hits` / `serve.warm_misses` | counter | dual-cache outcome per solve |
//! | `serve.queue_depth` | gauge | queue depth after the last submit/batch |
//! | `serve.problem_cache_bytes` | gauge | resident cost-backend bytes across cached problems |
//! | `serve.warm_cache_bytes` | gauge | resident warm-cache bytes |
//! | `serve.warm_cache_evictions` | gauge | cumulative warm-cache LRU evictions |
//! | `serve.latency_seconds` | hist | end-to-end submit→response (+ fixed buckets) |
//! | `serve.solve_seconds` | hist | solver wall time per job (+ fixed buckets) |
//! | `serve.batch_size` | hist | tickets per batch |
//! | `service.cache_hits` / `service.cache_misses` | counter | problem-cache outcome |
//!
//! Observability: every ticket gets a trace ID at admission
//! ([`super::queue::Ticket::new`]), echoed in [`EngineReply::trace_id`].
//! With `GRPOT_TRACE` on, the engine records `queue.wait` (retroactive,
//! from the ticket's existing timestamps), `engine.batch`,
//! `engine.dataset_build` and `engine.solve` spans; each solve's
//! [`crate::obs::SolveReport`] is captured via the `SolveOptions`
//! observer hook and shared by every reply in the batch.
//!
//! Fault tolerance: every solve carries a [`crate::fault::CancelToken`]
//! derived from its targets' deadlines and parented on the engine's
//! shutdown token, so an expired deadline (or [`Engine::shutdown`])
//! stops the solver at its next iteration checkpoint — distinguishable
//! from pre-solve triage via `serve.cancelled_midsolve`. A per-dataset
//! circuit breaker quarantines keys whose builds/solves fail
//! `breaker_threshold` times in a row ([`RejectReason::Quarantined`],
//! half-open probe after the cooldown), and admission sheds load when
//! the estimated queue wait already exceeds a request's deadline
//! ([`RejectReason::Overloaded`]). `GRPOT_FAULTS` failpoints
//! (`queue.admit`, `engine.dataset_build`, `engine.solve`,
//! `cache.insert`) inject deterministic failures inside the same unwind
//! guards that protect real traffic.

use super::batcher::{next_batch, unique_jobs, Batch, JobKey};
use super::cache::DualCache;
use super::queue::{AdmissionQueue, EngineResult, Ticket};
use super::ServeConfig;
use crate::coordinator::config::{DatasetSpec, Method};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::registry::build_pair;
use crate::coordinator::sweep;
use crate::data::DomainPair;
use crate::err;
use crate::error::GrpotError;
use crate::fault::{self, sites, CancelToken};
use crate::ot::dual::OtProblem;
use crate::ot::fastot::FastOtResult;
use crate::ot::regularizer::RegKind;
use crate::pool::{BoundedQueue, ParallelCtx, PushError};
use crate::solvers::StopReason;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One solve request as the engine sees it.
#[derive(Clone, Debug)]
pub struct SolveRequest {
    pub spec: DatasetSpec,
    pub gamma: f64,
    pub rho: f64,
    pub method: Method,
    /// Which regularizer to solve with (the wire protocol's optional
    /// `regularizer` field; unknown values are rejected at parse time
    /// with a structured error, never a panic).
    pub regularizer: RegKind,
    /// Relative deadline; falls back to the engine default when `None`.
    pub deadline: Option<Duration>,
    /// Allow seeding from the warm-start cache (default true).
    pub warm_start: bool,
}

/// A dataset's generated pair and prepared OT problem, shared across
/// every request and batch that names the same spec.
pub struct CachedProblem {
    pub pair: DomainPair,
    pub prob: OtProblem,
}

/// Successful engine response.
#[derive(Clone)]
pub struct EngineReply {
    pub result: Arc<FastOtResult>,
    pub problem: Arc<CachedProblem>,
    /// Whether this solve was seeded from the warm-start cache.
    pub warm_started: bool,
    /// Tickets in the micro-batch this request rode in.
    pub batch_size: usize,
    /// Seconds between submit and solve start.
    pub queue_wait_s: f64,
    /// This request's trace ID (minted at admission, always nonzero).
    pub trace_id: u64,
    /// Telemetry for the solve that answered this request, shared by
    /// every ticket the batch deduplicated onto it. The report's
    /// `trace_id` is the first target's — other tickets keep their own
    /// in [`EngineReply::trace_id`].
    pub telemetry: Option<Arc<crate::obs::SolveReport>>,
}

/// Structured rejection — every way a request can fail without (or
/// instead of) a solver result.
#[derive(Clone, Debug)]
pub enum RejectReason {
    /// Admission queue at capacity (backpressure): retry later.
    QueueFull { capacity: usize },
    /// The deadline passed before the solve started.
    DeadlineExceeded { waited_s: f64 },
    /// The engine is shutting down.
    Shutdown,
    /// The dataset key is circuit-broken: recent builds/solves of it
    /// failed repeatedly, so requests fast-fail until the cooldown
    /// expires and a probe succeeds.
    Quarantined { retry_in_s: f64 },
    /// Load shed at admission: the estimated queue wait already exceeds
    /// the request's deadline, so queueing could only end in a
    /// `DeadlineExceeded` triage after burning queue capacity.
    Overloaded { estimated_wait_s: f64 },
    /// Request validation or solver-side failure.
    Failed(GrpotError),
}

impl RejectReason {
    /// Stable machine-readable kind (the wire protocol's `error_kind`).
    pub fn kind(&self) -> &'static str {
        match self {
            RejectReason::QueueFull { .. } => "queue_full",
            RejectReason::DeadlineExceeded { .. } => "deadline_exceeded",
            RejectReason::Shutdown => "shutdown",
            RejectReason::Quarantined { .. } => "quarantined",
            RejectReason::Overloaded { .. } => "overloaded",
            RejectReason::Failed(_) => "failed",
        }
    }
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::QueueFull { capacity } => {
                write!(f, "admission queue full ({capacity} requests queued); retry later")
            }
            RejectReason::DeadlineExceeded { waited_s } => {
                write!(f, "deadline exceeded after waiting {waited_s:.3}s")
            }
            RejectReason::Shutdown => write!(f, "engine is shutting down"),
            RejectReason::Quarantined { retry_in_s } => write!(
                f,
                "dataset quarantined after repeated failures; retry in {retry_in_s:.3}s"
            ),
            RejectReason::Overloaded { estimated_wait_s } => write!(
                f,
                "overloaded: estimated queue wait {estimated_wait_s:.3}s exceeds the deadline"
            ),
            RejectReason::Failed(e) => write!(f, "{e}"),
        }
    }
}

/// LRU-capped dataset → prepared-problem cache.
#[derive(Default)]
struct ProblemCache {
    entries: BTreeMap<String, (Arc<CachedProblem>, u64)>,
    clock: u64,
}

impl ProblemCache {
    /// Get and mark as recently used.
    fn touch(&mut self, key: &str) -> Option<Arc<CachedProblem>> {
        self.clock += 1;
        let clock = self.clock;
        self.entries.get_mut(key).map(|(p, used)| {
            *used = clock;
            Arc::clone(p)
        })
    }

    /// Insert, evicting the least-recently-used entries beyond `cap`.
    fn insert(&mut self, key: &str, problem: Arc<CachedProblem>, cap: usize) {
        self.clock += 1;
        let clock = self.clock;
        self.entries.insert(key.to_string(), (problem, clock));
        while self.entries.len() > cap.max(1) {
            let lru = self
                .entries
                .iter()
                .min_by_key(|(_, (_, used))| *used)
                .map(|(k, _)| k.clone())
                .expect("loop guard implies entries");
            self.entries.remove(&lru);
        }
    }

    /// Resident cost-backend bytes across all cached problems. Dense
    /// entries account the full m×n matrix (plus SIMD pack); factored
    /// entries only their coordinates + norms + tile-ring budget — the
    /// number an operator watches to see the factored backend's memory
    /// win.
    fn cost_bytes(&self) -> usize {
        self.entries.values().map(|(p, _)| p.prob.cost_bytes()).sum()
    }
}

/// Per-dataset circuit-breaker state. `Closed` admits everything;
/// `Open` fast-fails until its cooldown instant; `HalfOpen` admits
/// exactly one probe request and quarantines the rest until the probe's
/// outcome arrives (success closes, failure reopens).
#[derive(Clone, Copy, Debug, PartialEq)]
enum BState {
    Closed,
    Open { until: Instant },
    HalfOpen { probe_started: Instant },
}

/// Failure history for one dataset key. Only *infrastructure* failures
/// count — dataset-build errors/panics and solver panics — never solver
/// non-convergence or per-request validation, which say nothing about
/// the dataset being poisoned.
#[derive(Clone, Copy, Debug)]
struct Breaker {
    consecutive: u32,
    state: BState,
}

impl Breaker {
    fn new() -> Breaker {
        Breaker { consecutive: 0, state: BState::Closed }
    }

    /// Admission decision for this key. `Err(retry_in_s)` = quarantined.
    fn admit(&mut self, now: Instant, cooldown: Duration) -> Result<(), f64> {
        match self.state {
            BState::Closed => Ok(()),
            BState::Open { until } => {
                if now < until {
                    Err(until.saturating_duration_since(now).as_secs_f64())
                } else {
                    // Cooldown over: this request becomes the probe.
                    self.state = BState::HalfOpen { probe_started: now };
                    Ok(())
                }
            }
            BState::HalfOpen { probe_started } => {
                if now.saturating_duration_since(probe_started) > cooldown {
                    // The probe's outcome never came back (e.g. its
                    // submitter vanished mid-flight); let a fresh probe
                    // through rather than quarantining forever.
                    self.state = BState::HalfOpen { probe_started: now };
                    Ok(())
                } else {
                    Err(cooldown
                        .saturating_sub(now.saturating_duration_since(probe_started))
                        .as_secs_f64())
                }
            }
        }
    }

    /// Record an infrastructure failure; returns true when this failure
    /// trips the breaker open (new `Open` transition, for metrics).
    fn record_failure(&mut self, now: Instant, threshold: u32, cooldown: Duration) -> bool {
        self.consecutive = self.consecutive.saturating_add(1);
        let was_open = matches!(self.state, BState::Open { .. });
        // A failed half-open probe reopens immediately; otherwise trip
        // once the consecutive run reaches the threshold.
        if matches!(self.state, BState::HalfOpen { .. }) || self.consecutive >= threshold {
            self.state = BState::Open { until: now + cooldown };
            return !was_open;
        }
        false
    }

    fn record_success(&mut self) {
        self.consecutive = 0;
        self.state = BState::Closed;
    }
}

struct EngineState {
    cfg: ServeConfig,
    /// Effective intra-solve thread count after clamping
    /// `workers × solve.threads` to the core budget.
    threads_per_solve: usize,
    /// Solve-batching width: coalesce up to this many same-dataset
    /// group-lasso jobs into one K-lane batched solve
    /// ([`crate::ot::batch::solve_batched`]). 1 = sequential per-job
    /// solves (the default). Resolved once at startup from
    /// `ServeConfig::solve.batch_k` / `GRPOT_BATCH_K`.
    batch_k: usize,
    queue: AdmissionQueue,
    problems: Mutex<ProblemCache>,
    /// Per-key build locks: concurrent cold builds of *one* dataset are
    /// deduplicated without serializing builds of distinct datasets.
    problem_build: Mutex<BTreeMap<String, Arc<Mutex<()>>>>,
    duals: DualCache,
    /// Per-dataset-key circuit breakers. Entries exist only for keys
    /// with a live failure history (success removes them), so the map
    /// stays bounded by the set of currently-failing keys.
    breakers: Mutex<BTreeMap<String, Breaker>>,
    /// Root cancel token: [`Engine::shutdown`] cancels it, and every
    /// solve's per-job token is its child, so in-flight solves stop at
    /// their next iteration checkpoint instead of running to completion
    /// against a closed queue.
    shutdown: CancelToken,
    metrics: Arc<Metrics>,
}

/// Gauge series name for one dataset key's breaker state. The key is
/// escaped into a Prometheus label value; the renderer passes label
/// blocks through verbatim ([`crate::obs::prom`]).
fn breaker_gauge_name(key: &str) -> String {
    let escaped = key.replace('\\', "\\\\").replace('"', "\\\"");
    format!("serve.breaker_state{{dataset=\"{escaped}\"}}")
}

/// Numeric encoding of a breaker state for the per-key gauge:
/// closed = 0, open = 1, half-open = 2.
fn breaker_state_value(state: BState) -> f64 {
    match state {
        BState::Closed => 0.0,
        BState::Open { .. } => 1.0,
        BState::HalfOpen { .. } => 2.0,
    }
}

/// Circuit-breaker admission check for `key`; `None` = admitted.
fn breaker_check(state: &EngineState, key: &str) -> Option<RejectReason> {
    if state.cfg.breaker_threshold == 0 {
        return None;
    }
    let mut map = plock(&state.breakers);
    let b = map.get_mut(key)?; // no failure history → closed
    let verdict = b.admit(Instant::now(), state.cfg.breaker_cooldown);
    // Publish the (possibly just-transitioned, e.g. open → half-open)
    // state. The series set stays bounded: a gauge exists only while
    // the key has a live breaker entry, which success prunes.
    let gauge = breaker_state_value(b.state);
    drop(map);
    state.metrics.set_gauge(&breaker_gauge_name(key), gauge);
    match verdict {
        Ok(()) => None,
        Err(retry_in_s) => Some(RejectReason::Quarantined { retry_in_s }),
    }
}

/// Record a solve/build outcome for `key`'s breaker. Success clears the
/// key's history entirely (bounding the map) and drops its state gauge;
/// failure counts toward the threshold and may trip the breaker.
fn breaker_record(state: &EngineState, key: &str, ok: bool) {
    if state.cfg.breaker_threshold == 0 {
        return;
    }
    let mut map = plock(&state.breakers);
    if ok {
        let removed = map.remove(key).is_some();
        drop(map);
        if removed {
            state.metrics.remove_gauge(&breaker_gauge_name(key));
        }
        return;
    }
    let b = map.entry(key.to_string()).or_insert_with(Breaker::new);
    let tripped = b.record_failure(
        Instant::now(),
        state.cfg.breaker_threshold,
        state.cfg.breaker_cooldown,
    );
    let gauge = breaker_state_value(b.state);
    drop(map);
    state.metrics.set_gauge(&breaker_gauge_name(key), gauge);
    if tripped {
        state.metrics.incr("serve.breaker_trips", 1);
    }
}

/// Poison-tolerant lock: a panic caught elsewhere (dataset asserts,
/// solver bugs) must not turn every later request into a poison panic.
fn plock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Render a caught panic payload for a structured error message.
fn panic_message(panic: &(dyn std::any::Any + Send)) -> String {
    panic
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| panic.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

/// Handle to a running engine. Dropping it shuts the engine down,
/// draining queued tickets gracefully (each still gets a response).
pub struct Engine {
    state: Arc<EngineState>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Engine {
    /// Spawn the worker pool and return the handle.
    ///
    /// Intra-op threading composes with worker concurrency under a core
    /// budget: the effective per-solve thread count is clamped so
    /// `workers × solve.threads ≤ core_budget` (autodetected from
    /// `available_parallelism` when the config leaves it 0). Clamping
    /// changes wall time only — solves are deterministic in the thread
    /// count, so results are unaffected. Each engine worker owns one
    /// long-lived [`ParallelCtx`] whose oracle workers spawn lazily and
    /// park between solves, so the engine's steady-state thread
    /// population is `workers` plus at most
    /// `workers × (threads_per_solve − 1)` parked oracle workers.
    pub fn start(cfg: ServeConfig, metrics: Arc<Metrics>) -> Engine {
        // Once-only: embedders and test binaries get `GRPOT_TRACE` /
        // `GRPOT_FAULTS` honored without the CLI launch hook.
        crate::obs::latch_env_once();
        fault::latch_env_once();
        let workers = cfg.workers.max(1);
        let budget = if cfg.core_budget > 0 {
            cfg.core_budget
        } else {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        };
        let threads_per_solve = cfg.solve.threads.max(1).min((budget / workers).max(1));
        // Lenient resolution like `default_regularizer`: launch
        // validation already rejected a broken `GRPOT_BATCH_K` for the
        // CLI; embedders fall back to sequential solves.
        let batch_k = cfg.solve.resolve_batch_k().unwrap_or(1);
        let state = Arc::new(EngineState {
            threads_per_solve,
            batch_k,
            queue: BoundedQueue::new(cfg.queue_capacity.max(1)),
            problems: Mutex::new(ProblemCache::default()),
            problem_build: Mutex::new(BTreeMap::new()),
            duals: DualCache::new(cfg.warm_cache_bytes, cfg.warm_radius),
            breakers: Mutex::new(BTreeMap::new()),
            shutdown: CancelToken::new(),
            metrics,
            cfg,
        });
        // Pre-register the full metric surface so the service's
        // `metrics` op reports every serving counter from request one —
        // and so steady-state `incr` calls never take the counter map's
        // write lock.
        state.metrics.register_counters(&[
            "serve.requests",
            "serve.rejected_queue_full",
            "serve.rejected_deadline",
            "serve.rejected_quarantined",
            "serve.rejected_overloaded",
            "serve.cancelled_midsolve",
            "serve.breaker_trips",
            "serve.solves",
            "serve.solve_panics",
            "serve.batches",
            "serve.warm_hits",
            "serve.warm_misses",
            "service.cache_hits",
            "service.cache_misses",
        ]);
        state.metrics.set_gauge("serve.queue_depth", 0.0);
        state.metrics.set_gauge("serve.problem_cache_bytes", 0.0);
        state.metrics.set_gauge("serve.warm_cache_bytes", 0.0);
        state.metrics.set_gauge("serve.warm_cache_evictions", 0.0);
        // Fixed Prometheus-style buckets alongside the percentile
        // windows: ~100 µs … 3.3 s, doubling.
        let bounds = crate::coordinator::metrics::exp_buckets(1e-4, 2.0, 16);
        state.metrics.register_hist_buckets("serve.latency_seconds", &bounds);
        state.metrics.register_hist_buckets("serve.solve_seconds", &bounds);
        let workers = (0..workers)
            .map(|i| {
                let st = Arc::clone(&state);
                std::thread::Builder::new()
                    .name(format!("grpot-serve-{i}"))
                    .spawn(move || worker_loop(&st))
                    .expect("spawn serve worker")
            })
            .collect();
        Engine { state, workers: Mutex::new(workers) }
    }

    /// The shared metrics registry.
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.state.metrics
    }

    /// Current admission-queue depth.
    pub fn queue_depth(&self) -> usize {
        self.state.queue.len()
    }

    /// Effective intra-solve thread count after the core-budget clamp
    /// (`workers × threads_per_solve ≤ core_budget`).
    pub fn threads_per_solve(&self) -> usize {
        self.state.threads_per_solve
    }

    /// Effective solve-batching width: how many coalesced same-dataset
    /// group-lasso jobs one worker solves in a single K-lane batched
    /// pass. 1 = per-job sequential solves.
    pub fn batch_k(&self) -> usize {
        self.state.batch_k
    }

    /// The regularizer applied to requests that don't name one: the
    /// configured [`ServeConfig::solve`] default, resolved through
    /// `GRPOT_REG` / group-lasso when the config leaves it unset. A
    /// broken env var falls back to the explicit field rather than
    /// erroring (launch validation already rejected it for the CLI).
    pub fn default_regularizer(&self) -> RegKind {
        let solve = &self.state.cfg.solve;
        solve
            .resolve_regularizer()
            .unwrap_or_else(|_| solve.regularizer.unwrap_or_default())
    }

    /// Submit one request and block until its response. Admission
    /// failures return immediately; accepted requests always receive an
    /// answer (solve result, deadline expiry, or shutdown).
    pub fn submit(&self, request: SolveRequest) -> EngineResult {
        let m = &self.state.metrics;
        // Validate up front: these would panic inside the solver and a
        // panicking worker could never answer its tickets.
        if request.gamma.is_nan() || request.gamma <= 0.0 {
            return Err(RejectReason::Failed(err!(
                "gamma must be positive (got {})",
                request.gamma
            )));
        }
        if request.rho.is_nan() || !(0.0..1.0).contains(&request.rho) {
            return Err(RejectReason::Failed(err!(
                "rho must lie in [0, 1) (got {})",
                request.rho
            )));
        }
        if let Err(e) = request.method.ensure_available() {
            return Err(RejectReason::Failed(e));
        }
        // `queue.admit` failpoint: chaos tests inject admission-path
        // errors/panics here (a panic unwinds into the submitter —
        // exactly what a real admission bug would do).
        if let Err(e) = fault::check(sites::QUEUE_ADMIT) {
            return Err(RejectReason::Failed(e));
        }
        // Circuit breaker: fast-fail keys with a live quarantine instead
        // of burning queue capacity and a worker on a poisoned dataset.
        let dataset_key = request.spec.cache_key();
        if let Some(reason) = breaker_check(&self.state, &dataset_key) {
            m.incr("serve.rejected_quarantined", 1);
            return Err(reason);
        }
        // Load shedding: if history says this request cannot meet its
        // deadline even before queue wait is added, reject now. Needs an
        // observed mean solve time — a cold engine never sheds.
        if self.state.cfg.shed {
            if let Some(deadline) = request.deadline.or(self.state.cfg.default_deadline) {
                if let Some(mean_solve_s) = m.hist_mean("serve.solve_seconds") {
                    let est = shed_wait_estimate(
                        self.state.queue.len(),
                        self.state.cfg.workers,
                        mean_solve_s,
                    );
                    if est > deadline.as_secs_f64() {
                        m.incr("serve.rejected_overloaded", 1);
                        return Err(RejectReason::Overloaded { estimated_wait_s: est });
                    }
                }
            }
        }
        let started = Instant::now();
        let (ticket, slot) = Ticket::new(request, self.state.cfg.default_deadline);
        match self.state.queue.try_push(ticket) {
            Ok(depth) => {
                m.incr("serve.requests", 1);
                m.set_gauge("serve.queue_depth", depth as f64);
            }
            Err(PushError::Full(_)) => {
                m.incr("serve.rejected_queue_full", 1);
                return Err(RejectReason::QueueFull { capacity: self.state.queue.capacity() });
            }
            Err(PushError::Closed(_)) => return Err(RejectReason::Shutdown),
        }
        let out = slot.wait();
        m.observe_hist("serve.latency_seconds", started.elapsed().as_secs_f64());
        out
    }

    /// Stop accepting work, cancel in-flight solves at their next
    /// iteration checkpoint, answer still-queued tickets with
    /// [`RejectReason::Shutdown`], and join the workers. Idempotent;
    /// also invoked by `Drop`.
    pub fn shutdown(&self) {
        // Cancel before closing the queue so a worker mid-solve stops
        // cooperatively instead of finishing a result nobody waits for.
        self.state.shutdown.cancel();
        self.state.queue.close();
        let handles: Vec<_> = self.workers.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Expected queue wait for a newly admitted request: everything already
/// queued, spread across the workers, at the observed mean solve time.
fn shed_wait_estimate(queue_len: usize, workers: usize, mean_solve_s: f64) -> f64 {
    queue_len as f64 / workers.max(1) as f64 * mean_solve_s
}

fn worker_loop(state: &EngineState) {
    // One long-lived parallel context per engine worker: its oracle
    // workers spawn once (lazily, on the first threaded solve), park
    // between evals/solves, and are joined when the engine shuts down —
    // so across the engine at most `workers × (threads_per_solve − 1)`
    // parked threads exist, inside the core-budget clamp, and no solve
    // ever pays per-eval thread spawn cost.
    let ctx = ParallelCtx::new(state.threads_per_solve);
    loop {
        // Both the batcher pop (which hosts the `batcher.flush`
        // failpoint) and batch handling run under unwind guards: a
        // panicking worker would silently shrink the pool, and any
        // ticket the panic stranded is answered by its Drop backstop
        // when the batch goes out of scope.
        let popped = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            next_batch(&state.queue, state.cfg.max_batch)
        }));
        let batch = match popped {
            Ok(Some(batch)) => batch,
            Ok(None) => break,
            Err(_) => continue,
        };
        state
            .metrics
            .set_gauge("serve.queue_depth", state.queue.len() as f64);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            handle_batch(state, &batch, &ctx);
        }));
    }
}

/// Fetch or build the problem for a dataset key. Cold builds of the
/// same key are deduplicated by a per-key lock: whoever wins it
/// generates the problem once, everyone queued behind it re-checks and
/// hits; distinct keys build concurrently.
fn cached_problem(
    state: &EngineState,
    key: &str,
    spec: &DatasetSpec,
) -> crate::error::Result<Arc<CachedProblem>> {
    if let Some(hit) = plock(&state.problems).touch(key) {
        state.metrics.incr("service.cache_hits", 1);
        return Ok(hit);
    }
    let key_lock = Arc::clone(plock(&state.problem_build).entry(key.to_string()).or_default());
    let build_guard = plock(&key_lock);
    if let Some(hit) = plock(&state.problems).touch(key) {
        // Built by whoever held the lock while we waited.
        state.metrics.incr("service.cache_hits", 1);
        return Ok(hit);
    }
    state.metrics.incr("service.cache_misses", 1);
    let built = fault::check(sites::ENGINE_DATASET_BUILD)
        .and_then(|()| build_pair(spec))
        .and_then(|pair| {
            // Checked conversion: generated marginals/costs are audited
            // (finite costs, positive mass) instead of trusted, so a
            // buggy or adversarial generator yields a structured error
            // the breaker can count, never a poisoned cache entry. The
            // configured cost backend decides whether the cache holds a
            // resident m×n matrix or factored coordinates + norms.
            let prob =
                OtProblem::try_from_dataset_mode(&pair, spec.effective_cost(state.cfg.solve.cost))?;
            let cached = Arc::new(CachedProblem { pair, prob });
            let mut problems = plock(&state.problems);
            problems.insert(key, Arc::clone(&cached), state.cfg.problem_cache_entries);
            let bytes = problems.cost_bytes();
            drop(problems);
            state
                .metrics
                .set_gauge("serve.problem_cache_bytes", bytes as f64);
            Ok(cached)
        });
    drop(build_guard);
    plock(&state.problem_build).remove(key);
    built
}

fn handle_batch(state: &EngineState, batch: &Batch, ctx: &ParallelCtx) {
    let m = &state.metrics;
    // Shutdown fast-drain: once the engine is stopping, queued tickets
    // are answered immediately instead of solved — the submitter gets a
    // structured `Shutdown`, never a hang on a dying worker pool.
    if state.shutdown.is_cancelled() {
        for t in &batch.tickets {
            t.respond(Err(RejectReason::Shutdown));
        }
        return;
    }
    m.incr("serve.batches", 1);
    m.observe_hist("serve.batch_size", batch.len() as f64);
    let _batch_span = crate::obs::Span::start(crate::obs::names::ENGINE_BATCH, 0);

    // Deadline triage on dequeue: expired tickets never touch a solver.
    let now = Instant::now();
    let mut live: Vec<&Ticket> = Vec::with_capacity(batch.len());
    for t in &batch.tickets {
        // Queue wait is recorded retroactively from instants the ticket
        // already carries — the admission hot path reads no extra clock.
        crate::obs::record_span_at(crate::obs::names::QUEUE_WAIT, t.trace_id, t.submitted, now);
        if t.expired(now) {
            m.incr("serve.rejected_deadline", 1);
            t.respond(Err(RejectReason::DeadlineExceeded { waited_s: t.waited_s(now) }));
        } else {
            live.push(t);
        }
    }
    if live.is_empty() {
        return;
    }

    // Dataset work happens once for the whole batch. Dataset generators
    // assert on out-of-range specs (e.g. param1 = 0, bad scale) that the
    // wire protocol can't pre-validate per family, so the build is
    // unwind-guarded: a panicking build must answer its tickets instead
    // of killing the worker.
    let built = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let _build_span =
            crate::obs::Span::start(crate::obs::names::DATASET_BUILD, live[0].trace_id);
        cached_problem(state, &batch.dataset_key, &live[0].request.spec)
    }));
    let problem = match built {
        Ok(Ok(p)) => p,
        Ok(Err(e)) => {
            breaker_record(state, &batch.dataset_key, false);
            for t in &live {
                t.respond(Err(RejectReason::Failed(e.clone())));
            }
            return;
        }
        Err(panic) => {
            // The unwind skipped cached_problem's cleanup: drop the
            // per-key build-lock entry so repeated bad specs can't grow
            // the map without bound.
            plock(&state.problem_build).remove(&batch.dataset_key);
            breaker_record(state, &batch.dataset_key, false);
            let what = panic_message(panic.as_ref());
            for t in &live {
                t.respond(Err(RejectReason::Failed(err!(
                    "dataset build panicked: {what}"
                ))));
            }
            return;
        }
    };
    let batch_size = live.len();

    // Each distinct (γ, ρ, method, regularizer, warm) job solves once.
    // With batching enabled, group-lasso fast-family jobs coalesce into
    // K-lane batched solves (byte-identical per job — the batched
    // oracle's hard contract); everything else keeps the sequential
    // per-job path.
    let jobs = unique_jobs(&live);
    if state.batch_k > 1 {
        let (batchable, rest): (Vec<_>, Vec<_>) = jobs.into_iter().partition(|(job, _)| {
            job.regularizer == RegKind::GroupLasso
                && matches!(job.method, Method::Fast | Method::FastNoWs)
        });
        for group in batchable.chunks(state.batch_k) {
            solve_job_group(state, &batch.dataset_key, &problem, batch_size, &live, group, ctx);
        }
        for (job, idxs) in rest {
            solve_job(state, &batch.dataset_key, &problem, batch_size, &live, job, &idxs, ctx);
        }
    } else {
        for (job, idxs) in jobs {
            solve_job(state, &batch.dataset_key, &problem, batch_size, &live, job, &idxs, ctx);
        }
    }
}

/// One lane's pre-solve state inside a batched K-lane group — what
/// [`solve_job`] computes before its solver call, for one job.
struct LaneJob<'t> {
    job: JobKey,
    targets: Vec<&'t Ticket>,
    warm_key: String,
    warm_started: bool,
    report_cell: Arc<Mutex<Option<crate::obs::SolveReport>>>,
    /// Triage instant; replies report queue wait relative to it, like
    /// the sequential path.
    triage_now: Instant,
}

/// Solve up to `batch_k` coalesced jobs as one K-lane batched solve
/// ([`crate::ot::batch::solve_batched`]): the lanes share the batch's
/// dataset/problem and differ only in (γ, ρ, working-set, warm-start),
/// so one fused pass over the cost columns serves them all. Each job
/// keeps its own deadline triage, warm-start lookup, observer hook,
/// trace spans and cancel token, and its reply is byte-identical to the
/// sequential [`solve_job`] path.
fn solve_job_group(
    state: &EngineState,
    dataset_key: &str,
    problem: &Arc<CachedProblem>,
    batch_size: usize,
    live: &[&Ticket],
    group: &[(JobKey, Vec<usize>)],
    ctx: &ParallelCtx,
) {
    let m = &state.metrics;
    let mut lanes: Vec<LaneJob> = Vec::with_capacity(group.len());
    let mut opts_vec: Vec<crate::ot::solve::SolveOptions> = Vec::with_capacity(group.len());
    for (job, idxs) in group {
        let job = *job;
        // Second deadline triage, per job (same as the sequential path).
        let now = Instant::now();
        let mut targets: Vec<&Ticket> = Vec::with_capacity(idxs.len());
        for &i in idxs {
            let t = live[i];
            if t.expired(now) {
                m.incr("serve.rejected_deadline", 1);
                t.respond(Err(RejectReason::DeadlineExceeded { waited_s: t.waited_s(now) }));
            } else {
                targets.push(t);
            }
        }
        if targets.is_empty() {
            continue;
        }
        // Per-job `engine.solve` failpoint: an injected error fails
        // this job alone, leaving its batchmates to solve.
        if let Err(e) = fault::check(sites::ENGINE_SOLVE) {
            for t in targets {
                t.respond(Err(RejectReason::Failed(e.clone())));
            }
            continue;
        }
        // Only group-lasso jobs reach this path, so the warm key is the
        // bare dataset key (no regularizer suffix).
        let warm_key = dataset_key.to_string();
        let want_warm = job.warm_start && state.cfg.warm_start;
        let seed = if want_warm {
            state.duals.lookup(&warm_key, job.gamma, job.rho)
        } else {
            None
        };
        if want_warm {
            if seed.is_some() {
                m.incr("serve.warm_hits", 1);
            } else {
                m.incr("serve.warm_misses", 1);
            }
        }
        let warm_started = seed.is_some();
        let (hook, report_cell) = crate::obs::ObserverHook::capture();
        let solve_trace_id = targets[0].trace_id;
        let job_deadline = if targets.iter().all(|t| t.deadline.is_some()) {
            targets.iter().filter_map(|t| t.deadline).max()
        } else {
            None
        };
        let cancel = state.shutdown.child(job_deadline);
        let mut opts = state
            .cfg
            .solve
            .clone()
            .gamma(job.gamma)
            .rho(job.rho)
            .regularizer(RegKind::GroupLasso)
            .working_set(job.method != Method::FastNoWs)
            .ctx(ctx.clone())
            .observer(hook)
            .trace_id(solve_trace_id)
            .cancel(cancel);
        if let Some(s) = &seed {
            opts = opts.warm_start(s.dual.clone());
        }
        lanes.push(LaneJob { job, targets, warm_key, warm_started, report_cell, triage_now: now });
        opts_vec.push(opts);
    }
    if lanes.is_empty() {
        return;
    }

    // One unwind guard around the whole K-lane solve: a panic inside
    // the fused pass (injected `oracle.eval` fault or a real solver
    // bug) has no per-lane boundary, so it fails every lane in the
    // group — each job records its own breaker failure, exactly as K
    // sequential panics would.
    let solved = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let _solve_span =
            crate::obs::Span::start(crate::obs::names::ENGINE_SOLVE, lanes[0].targets[0].trace_id);
        crate::ot::batch::solve_batched(&problem.prob, &opts_vec)
    }));
    let results = match solved {
        Ok(Ok(r)) => r,
        Ok(Err(e)) => {
            // Option validation failure: structured per-job error, no
            // breaker event (it says nothing about the dataset).
            for lane in lanes {
                for t in lane.targets {
                    t.respond(Err(RejectReason::Failed(e.clone())));
                }
            }
            return;
        }
        Err(panic) => {
            let what = panic_message(panic.as_ref());
            for lane in lanes {
                m.incr("serve.solve_panics", 1);
                breaker_record(state, dataset_key, false);
                for t in lane.targets {
                    t.respond(Err(RejectReason::Failed(err!("solver panicked: {what}"))));
                }
            }
            return;
        }
    };
    for (lane, result) in lanes.into_iter().zip(results) {
        finish_job(state, dataset_key, problem, batch_size, lane, result);
    }
}

/// The sequential path's post-solve epilogue, per lane: cancellation
/// triage, metrics, breaker/cache bookkeeping and reply fan-out —
/// identical to the tail of [`solve_job`].
fn finish_job(
    state: &EngineState,
    dataset_key: &str,
    problem: &Arc<CachedProblem>,
    batch_size: usize,
    lane: LaneJob<'_>,
    result: FastOtResult,
) {
    let m = &state.metrics;
    let LaneJob { job, targets, warm_key, warm_started, report_cell, triage_now } = lane;
    // The fused pass has no per-lane wall clock here; the solver's own
    // per-lane wall time feeds the histogram load shedding reads.
    m.observe_hist("serve.solve_seconds", result.wall_time_s);
    if result.stop == StopReason::Cancelled {
        m.incr("serve.cancelled_midsolve", 1);
        let now = Instant::now();
        for t in targets {
            let reason = if state.shutdown.is_cancelled() {
                RejectReason::Shutdown
            } else {
                RejectReason::DeadlineExceeded { waited_s: t.waited_s(now) }
            };
            t.respond(Err(reason));
        }
        return;
    }
    m.incr("serve.solves", 1);
    breaker_record(state, dataset_key, true);
    if state.cfg.warm_start && result.stop.converged() {
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if fault::check(sites::CACHE_INSERT).is_ok() {
                state
                    .duals
                    .insert(&warm_key, job.gamma, job.rho, result.x.clone());
                m.set_gauge("serve.warm_cache_bytes", state.duals.bytes() as f64);
                m.set_gauge("serve.warm_cache_evictions", state.duals.evictions() as f64);
            }
        }));
    }
    let telemetry: Option<Arc<crate::obs::SolveReport>> = report_cell
        .lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
        .take()
        .map(Arc::new);
    let result = Arc::new(result);
    for t in targets {
        t.respond(Ok(EngineReply {
            result: Arc::clone(&result),
            problem: Arc::clone(problem),
            warm_started,
            batch_size,
            queue_wait_s: t.waited_s(triage_now),
            trace_id: t.trace_id,
            telemetry: telemetry.clone(),
        }));
    }
}

#[allow(clippy::too_many_arguments)]
fn solve_job(
    state: &EngineState,
    dataset_key: &str,
    problem: &Arc<CachedProblem>,
    batch_size: usize,
    live: &[&Ticket],
    job: JobKey,
    idxs: &[usize],
    ctx: &ParallelCtx,
) {
    let m = &state.metrics;
    // Second deadline triage: earlier jobs in this batch may have eaten
    // a ticket's remaining budget while it sat here.
    let now = Instant::now();
    let mut targets: Vec<&Ticket> = Vec::with_capacity(idxs.len());
    for &i in idxs {
        let t = live[i];
        if t.expired(now) {
            m.incr("serve.rejected_deadline", 1);
            t.respond(Err(RejectReason::DeadlineExceeded { waited_s: t.waited_s(now) }));
        } else {
            targets.push(t);
        }
    }
    if targets.is_empty() {
        return;
    }

    // Warm-start seed from the dual cache. Non-group-lasso duals live
    // under a regularizer-suffixed key: a warm start from any iterate is
    // sound (Theorem 2 holds from every starting point), but seeding
    // from a *different* regularizer's optimum would waste the hit.
    let warm_key = if job.regularizer == RegKind::GroupLasso {
        dataset_key.to_string()
    } else {
        format!("{dataset_key}|{}", job.regularizer.name())
    };
    let want_warm = job.warm_start && state.cfg.warm_start;
    let seed = if want_warm {
        state.duals.lookup(&warm_key, job.gamma, job.rho)
    } else {
        None
    };
    if want_warm {
        if seed.is_some() {
            m.incr("serve.warm_hits", 1);
        } else {
            m.incr("serve.warm_misses", 1);
        }
    }
    let x0 = seed.as_ref().map(|s| s.dual.as_slice());
    let warm_started = x0.is_some();

    // A panicking solve must never strand its tickets (a blocked
    // submitter waits forever) or kill the worker: catch the unwind and
    // answer with a structured failure instead. Reachable e.g. via
    // `xla-origin` in a `--features xla` build against the stub.
    // Telemetry: the solver fills one SolveReport per solve through the
    // observer hook; every ticket coalesced into this job shares it. The
    // first target's trace ID stamps the solve/outer-round spans.
    let (hook, report_cell) = crate::obs::ObserverHook::capture();
    let solve_trace_id = targets[0].trace_id;

    // Cooperative cancellation: the job's deadline is the latest of its
    // targets' deadlines (once it passes, *every* coalesced ticket has
    // expired; earlier-deadline targets are re-triaged below), disarmed
    // when any target may wait indefinitely. Parenting on the engine's
    // shutdown token lets `Engine::shutdown` stop the solve at its next
    // checkpoint. The token only ever *stops* iteration — an uncancelled
    // solve's arithmetic is untouched, so results stay byte-identical.
    let job_deadline = if targets.iter().all(|t| t.deadline.is_some()) {
        targets.iter().filter_map(|t| t.deadline).max()
    } else {
        None
    };
    let cancel = state.shutdown.child(job_deadline);

    let solved = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        // `engine.solve` failpoint: errors surface as solver failures,
        // panics exercise the unwind path below.
        fault::check(sites::ENGINE_SOLVE)?;
        m.time_hist("serve.solve_seconds", || {
            let _solve_span =
                crate::obs::Span::start(crate::obs::names::ENGINE_SOLVE, solve_trace_id);
            let mut opts = state
                .cfg
                .solve
                .clone()
                .gamma(job.gamma)
                .rho(job.rho)
                .regularizer(job.regularizer)
                .ctx(ctx.clone())
                .observer(hook.clone())
                .trace_id(solve_trace_id)
                .cancel(cancel.clone());
            if let Some(x0) = x0 {
                opts = opts.warm_start(x0.to_vec());
            }
            sweep::solve(&problem.prob, job.method, &opts)
        })
    }));
    let result = match solved {
        Ok(Ok(r)) => r,
        Ok(Err(e)) => {
            // Solver-side validation (e.g. a regularizer the method
            // can't run) answers every waiter with a structured error.
            // Not a breaker event: it says nothing about the dataset.
            for t in targets {
                t.respond(Err(RejectReason::Failed(e.clone())));
            }
            return;
        }
        Err(panic) => {
            let what = panic_message(panic.as_ref());
            m.incr("serve.solve_panics", 1);
            breaker_record(state, dataset_key, false);
            for t in targets {
                t.respond(Err(RejectReason::Failed(err!("solver panicked: {what}"))));
            }
            return;
        }
    };
    if result.stop == StopReason::Cancelled {
        // The solver stopped at a checkpoint: either the job deadline
        // passed mid-solve or the engine is shutting down. The iterate
        // is discarded — no cache write, no breaker event (cancellation
        // is the *caller's* doing, not the dataset's).
        m.incr("serve.cancelled_midsolve", 1);
        let now = Instant::now();
        for t in targets {
            let reason = if state.shutdown.is_cancelled() {
                RejectReason::Shutdown
            } else {
                RejectReason::DeadlineExceeded { waited_s: t.waited_s(now) }
            };
            t.respond(Err(reason));
        }
        return;
    }
    m.incr("serve.solves", 1);
    breaker_record(state, dataset_key, true);
    // Feed the cache only while warm starts are on (with them disabled
    // nothing ever reads the entries) and only from *converged* results:
    // a max-iters iterate can sit far from the optimum, and seeding
    // later solves from it would silently degrade warm-start quality.
    // The insert runs in its own unwind guard with the `cache.insert`
    // failpoint inside: cache trouble (injected or real) skips the
    // insert but must never fail a request that already has its result.
    if state.cfg.warm_start && result.stop.converged() {
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if fault::check(sites::CACHE_INSERT).is_ok() {
                state
                    .duals
                    .insert(&warm_key, job.gamma, job.rho, result.x.clone());
                m.set_gauge("serve.warm_cache_bytes", state.duals.bytes() as f64);
                m.set_gauge("serve.warm_cache_evictions", state.duals.evictions() as f64);
            }
        }));
    }

    let telemetry: Option<Arc<crate::obs::SolveReport>> =
        report_cell.lock().unwrap().take().map(Arc::new);
    let result = Arc::new(result);
    for t in targets {
        t.respond(Ok(EngineReply {
            result: Arc::clone(&result),
            problem: Arc::clone(problem),
            warm_started,
            batch_size,
            queue_wait_s: t.waited_s(now),
            trace_id: t.trace_id,
            telemetry: telemetry.clone(),
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::lbfgs::LbfgsOptions;

    /// Solver options tight enough that cold and warm-started solves of
    /// the same problem land within ~1e-12 of the same optimum, so the
    /// 1e-9 warm-vs-cold assertions have real margin.
    fn tight_lbfgs() -> LbfgsOptions {
        LbfgsOptions { max_iters: 4000, ftol: 1e-13, gtol: 1e-8, ..Default::default() }
    }

    fn tiny_spec(seed: u64) -> DatasetSpec {
        DatasetSpec {
            family: "synthetic".into(),
            param1: 3,
            param2: 4,
            seed,
            ..Default::default()
        }
    }

    fn request(seed: u64, gamma: f64, rho: f64) -> SolveRequest {
        SolveRequest {
            spec: tiny_spec(seed),
            gamma,
            rho,
            method: Method::Fast,
            regularizer: RegKind::GroupLasso,
            deadline: None,
            warm_start: true,
        }
    }

    fn tiny_engine(cfg: ServeConfig) -> Engine {
        Engine::start(cfg, Arc::new(Metrics::new()))
    }

    #[test]
    fn solve_roundtrip_and_warm_second_hit() {
        let engine = tiny_engine(ServeConfig {
            workers: 2,
            solve: crate::ot::solve::SolveOptions::new().lbfgs(tight_lbfgs()),
            ..Default::default()
        });
        let cold = engine.submit(request(5, 1.0, 0.5)).expect("cold solve");
        assert!(!cold.warm_started);
        assert!(cold.result.dual_objective > 0.0);
        let warm = engine.submit(request(5, 1.0, 0.5)).expect("warm solve");
        assert!(warm.warm_started);
        assert_eq!(engine.metrics().get("serve.warm_hits"), 1);
        assert_eq!(engine.metrics().get("serve.solves"), 2);
        // Warm result must match the cold objective (Theorem 2 survives
        // warm starts; cache seeds only change the iteration count).
        assert!(
            (warm.result.dual_objective - cold.result.dual_objective).abs() <= 1e-9,
            "cold={} warm={}",
            cold.result.dual_objective,
            warm.result.dual_objective
        );
        engine.shutdown();
    }

    #[test]
    fn invalid_params_rejected_before_admission() {
        let engine = tiny_engine(ServeConfig { workers: 1, ..Default::default() });
        let bad_gamma = engine.submit(SolveRequest { gamma: -1.0, ..request(1, 1.0, 0.5) });
        assert_eq!(bad_gamma.unwrap_err().kind(), "failed");
        let bad_rho = engine.submit(SolveRequest { rho: 1.5, ..request(1, 1.0, 0.5) });
        assert_eq!(bad_rho.unwrap_err().kind(), "failed");
        let nan = engine.submit(SolveRequest { gamma: f64::NAN, ..request(1, 1.0, 0.5) });
        assert_eq!(nan.unwrap_err().kind(), "failed");
        assert_eq!(engine.metrics().get("serve.requests"), 0);
    }

    #[test]
    fn unknown_dataset_family_fails_cleanly() {
        let engine = tiny_engine(ServeConfig { workers: 1, ..Default::default() });
        let mut req = request(1, 1.0, 0.5);
        req.spec.family = "nope".into();
        let err = engine.submit(req).unwrap_err();
        assert_eq!(err.kind(), "failed");
        // Engine still serves afterwards.
        assert!(engine.submit(request(1, 1.0, 0.5)).is_ok());
    }

    #[test]
    fn panicking_dataset_build_answers_and_survives() {
        let engine = tiny_engine(ServeConfig { workers: 1, ..Default::default() });
        let mut req = request(1, 1.0, 0.5);
        req.spec.param1 = 0; // the synthetic generator asserts on this
        let err = engine.submit(req).unwrap_err();
        assert_eq!(err.kind(), "failed");
        assert!(err.to_string().contains("panicked"), "{err}");
        // The worker survived the panic and still serves.
        assert!(engine.submit(request(1, 1.0, 0.5)).is_ok());
    }

    #[test]
    fn zero_deadline_expires_before_solve() {
        let engine = tiny_engine(ServeConfig { workers: 1, ..Default::default() });
        let mut req = request(1, 1.0, 0.5);
        req.deadline = Some(Duration::ZERO);
        let err = engine.submit(req).unwrap_err();
        assert_eq!(err.kind(), "deadline_exceeded");
        assert_eq!(engine.metrics().get("serve.rejected_deadline"), 1);
    }

    #[test]
    fn shutdown_answers_queued_work() {
        let engine = tiny_engine(ServeConfig { workers: 1, ..Default::default() });
        assert!(engine.submit(request(2, 0.5, 0.5)).is_ok());
        engine.shutdown();
        // Submits after shutdown are refused, not hung.
        let err = engine.submit(request(2, 0.5, 0.5)).unwrap_err();
        assert_eq!(err.kind(), "shutdown");
    }

    #[test]
    fn requests_pick_their_regularizer() {
        let engine = tiny_engine(ServeConfig { workers: 1, ..Default::default() });
        for kind in [RegKind::SquaredL2, RegKind::NegEntropy] {
            let mut req = request(3, 0.5, 0.5);
            req.regularizer = kind;
            let reply = engine.submit(req).expect("solve");
            assert!(
                reply.result.method.contains(kind.name()),
                "label '{}' should carry '{}'",
                reply.result.method,
                kind.name()
            );
            assert!(reply.result.dual_objective.is_finite());
        }
        assert_eq!(engine.metrics().get("serve.solves"), 2);
        engine.shutdown();
    }

    #[test]
    fn factored_cost_backend_serves_byte_identical_results() {
        use crate::ot::cost::CostMode;
        let solve = |mode: CostMode| {
            let engine = tiny_engine(ServeConfig {
                workers: 1,
                solve: crate::ot::solve::SolveOptions::new()
                    .lbfgs(tight_lbfgs())
                    .cost(mode),
                ..Default::default()
            });
            let reply = engine.submit(request(7, 0.8, 0.4)).expect("solve");
            let bytes = engine
                .metrics()
                .gauge("serve.problem_cache_bytes")
                .expect("gauge registered at start");
            let mode_name = reply.problem.prob.cost_mode_name();
            let out = (reply.result.dual_objective, reply.result.x.clone(), bytes, mode_name);
            engine.shutdown();
            out
        };
        let (obj_d, x_d, bytes_d, name_d) = solve(CostMode::Dense);
        let (obj_f, x_f, bytes_f, name_f) = solve(CostMode::Factored);
        assert_eq!(name_d, "dense");
        assert_eq!(name_f, "factored");
        assert!(bytes_d > 0.0 && bytes_f > 0.0, "dense={bytes_d} factored={bytes_f}");
        assert_eq!(obj_d.to_bits(), obj_f.to_bits());
        assert_eq!(x_d.len(), x_f.len());
        for (a, b) in x_d.iter().zip(&x_f) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn problem_cache_evicts_lru() {
        let mk = |seed| {
            let pair = build_pair(&tiny_spec(seed)).unwrap();
            let prob = OtProblem::from_dataset(&pair);
            Arc::new(CachedProblem { pair, prob })
        };
        let mut c = ProblemCache::default();
        c.insert("a", mk(1), 2);
        c.insert("b", mk(2), 2);
        assert!(c.touch("a").is_some()); // "a" becomes most-recent
        c.insert("c", mk(3), 2); // evicts "b", the LRU
        assert!(c.touch("b").is_none());
        assert!(c.touch("a").is_some());
        assert!(c.touch("c").is_some());
    }

    #[test]
    fn reject_reasons_render() {
        let reasons = [
            RejectReason::QueueFull { capacity: 4 },
            RejectReason::DeadlineExceeded { waited_s: 0.25 },
            RejectReason::Shutdown,
            RejectReason::Quarantined { retry_in_s: 1.5 },
            RejectReason::Overloaded { estimated_wait_s: 0.75 },
            RejectReason::Failed(err!("boom")),
        ];
        let kinds: Vec<&str> = reasons.iter().map(RejectReason::kind).collect();
        assert_eq!(
            kinds,
            vec![
                "queue_full",
                "deadline_exceeded",
                "shutdown",
                "quarantined",
                "overloaded",
                "failed"
            ]
        );
        for r in &reasons {
            assert!(!r.to_string().is_empty());
        }
    }

    #[test]
    fn breaker_state_machine_transitions() {
        let t0 = Instant::now();
        let cooldown = Duration::from_secs(5);
        let mut b = Breaker::new();
        // Closed admits; failures below the threshold stay closed.
        assert!(b.admit(t0, cooldown).is_ok());
        assert!(!b.record_failure(t0, 3, cooldown));
        assert!(!b.record_failure(t0, 3, cooldown));
        assert!(b.admit(t0, cooldown).is_ok());
        // Third consecutive failure trips it open (returns true once).
        assert!(b.record_failure(t0, 3, cooldown));
        let retry = b.admit(t0 + Duration::from_secs(1), cooldown).unwrap_err();
        assert!(retry > 0.0 && retry <= 5.0, "retry_in_s = {retry}");
        // Cooldown expiry: first admit becomes the half-open probe,
        // later arrivals stay quarantined while the probe is pending.
        assert!(b.admit(t0 + Duration::from_secs(6), cooldown).is_ok());
        assert!(matches!(b.state, BState::HalfOpen { .. }));
        assert!(b.admit(t0 + Duration::from_secs(7), cooldown).is_err());
        // A failed probe reopens immediately (another trip).
        assert!(b.record_failure(t0 + Duration::from_secs(7), 3, cooldown));
        assert!(b.admit(t0 + Duration::from_secs(8), cooldown).is_err());
        // A successful probe closes and clears the run.
        assert!(b.admit(t0 + Duration::from_secs(20), cooldown).is_ok());
        b.record_success();
        assert_eq!(b.state, BState::Closed);
        assert_eq!(b.consecutive, 0);
        assert!(b.admit(t0 + Duration::from_secs(21), cooldown).is_ok());
    }

    #[test]
    fn breaker_quarantines_failing_dataset_key() {
        let engine = tiny_engine(ServeConfig {
            workers: 1,
            breaker_threshold: 2,
            breaker_cooldown: Duration::from_secs(60),
            ..Default::default()
        });
        let mut req = request(9, 1.0, 0.5);
        req.spec.family = "nope".into();
        // Build failures up to the threshold surface as `failed`.
        for _ in 0..2 {
            assert_eq!(engine.submit(req.clone()).unwrap_err().kind(), "failed");
        }
        // The tripped breaker now fast-fails the key at admission.
        let err = engine.submit(req.clone()).unwrap_err();
        assert_eq!(err.kind(), "quarantined");
        assert!(err.to_string().contains("quarantined"), "{err}");
        assert_eq!(engine.metrics().get("serve.breaker_trips"), 1);
        assert_eq!(engine.metrics().get("serve.rejected_quarantined"), 1);
        // Other dataset keys are unaffected.
        assert!(engine.submit(request(1, 1.0, 0.5)).is_ok());
        engine.shutdown();
    }

    #[test]
    fn breaker_half_open_probe_after_cooldown() {
        let engine = tiny_engine(ServeConfig {
            workers: 1,
            breaker_threshold: 1,
            breaker_cooldown: Duration::from_millis(20),
            ..Default::default()
        });
        let mut req = request(11, 1.0, 0.5);
        req.spec.family = "nope".into();
        assert_eq!(engine.submit(req.clone()).unwrap_err().kind(), "failed"); // trips
        assert_eq!(engine.submit(req.clone()).unwrap_err().kind(), "quarantined");
        std::thread::sleep(Duration::from_millis(40));
        // Cooldown over: the next request is the half-open probe and
        // reaches the (still broken) build, which re-trips the breaker.
        assert_eq!(engine.submit(req.clone()).unwrap_err().kind(), "failed");
        assert_eq!(engine.submit(req.clone()).unwrap_err().kind(), "quarantined");
        assert_eq!(engine.metrics().get("serve.breaker_trips"), 2);
        engine.shutdown();
    }

    #[test]
    fn batched_engine_matches_sequential_engine() {
        let run = |k: usize| {
            let engine = tiny_engine(ServeConfig {
                workers: 1,
                solve: crate::ot::solve::SolveOptions::new().lbfgs(tight_lbfgs()).batch_k(k),
                ..Default::default()
            });
            assert_eq!(engine.batch_k(), k);
            let mut outs = Vec::new();
            for (g, r) in [(0.5, 0.2), (0.8, 0.4), (1.2, 0.6)] {
                let reply = engine.submit(request(13, g, r)).expect("solve");
                outs.push((
                    reply.result.x.clone(),
                    reply.result.dual_objective.to_bits(),
                    reply.result.iterations,
                ));
            }
            engine.shutdown();
            outs
        };
        // Replies (including warm-started later ones) must be
        // byte-identical whether the engine batches or not.
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn batched_group_answers_every_coalesced_job() {
        let engine = tiny_engine(ServeConfig {
            workers: 1,
            solve: crate::ot::solve::SolveOptions::new().lbfgs(tight_lbfgs()).batch_k(4),
            ..Default::default()
        });
        // Drive the K-lane group path directly with three coalesced
        // jobs — deterministic, no reliance on queue timing.
        let reqs = [(0.5, 0.2), (0.9, 0.4), (1.3, 0.6)];
        let mut tickets = Vec::new();
        let mut slots = Vec::new();
        for &(g, r) in &reqs {
            let mut req = request(17, g, r);
            req.warm_start = false; // cold lanes compare against cold sequential solves
            let (t, slot) = Ticket::new(req, None);
            tickets.push(t);
            slots.push(slot);
        }
        let live: Vec<&Ticket> = tickets.iter().collect();
        let key = tickets[0].dataset_key.clone();
        let problem = cached_problem(&engine.state, &key, &live[0].request.spec).unwrap();
        let jobs = unique_jobs(&live);
        assert_eq!(jobs.len(), reqs.len());
        let ctx = ParallelCtx::new(1);
        solve_job_group(&engine.state, &key, &problem, live.len(), &live, &jobs, &ctx);
        for (slot, &(g, r)) in slots.into_iter().zip(&reqs) {
            let reply = slot.wait().expect("every lane answered");
            assert_eq!(reply.batch_size, reqs.len());
            let seq = sweep::solve(
                &problem.prob,
                Method::Fast,
                &engine
                    .state
                    .cfg
                    .solve
                    .clone()
                    .gamma(g)
                    .rho(r)
                    .regularizer(RegKind::GroupLasso),
            )
            .unwrap();
            assert_eq!(reply.result.x, seq.x, "gamma={g} rho={r}");
            assert_eq!(reply.result.dual_objective.to_bits(), seq.dual_objective.to_bits());
            assert!(reply.telemetry.is_some(), "per-lane SolveReport captured");
        }
        engine.shutdown();
    }

    #[test]
    fn breaker_state_gauge_tracks_key_lifecycle() {
        let engine = tiny_engine(ServeConfig {
            workers: 1,
            breaker_threshold: 2,
            breaker_cooldown: Duration::from_secs(60),
            ..Default::default()
        });
        let mut req = request(19, 1.0, 0.5);
        req.spec.family = "nope".into();
        let gauge_name = breaker_gauge_name(&req.spec.cache_key());
        assert_eq!(engine.metrics().gauge(&gauge_name), None);
        // First failure: entry exists, breaker still closed.
        assert_eq!(engine.submit(req.clone()).unwrap_err().kind(), "failed");
        assert_eq!(engine.metrics().gauge(&gauge_name), Some(0.0));
        // Second failure trips it open.
        assert_eq!(engine.submit(req.clone()).unwrap_err().kind(), "failed");
        assert_eq!(engine.metrics().gauge(&gauge_name), Some(1.0));
        // A healthy key never publishes a series.
        let ok = request(19, 1.0, 0.5);
        let ok_gauge = breaker_gauge_name(&ok.spec.cache_key());
        assert!(engine.submit(ok).is_ok());
        assert_eq!(engine.metrics().gauge(&ok_gauge), None);
        engine.shutdown();
    }

    #[test]
    fn breaker_gauge_reports_half_open_probe_and_prunes_on_success() {
        let engine = tiny_engine(ServeConfig {
            workers: 1,
            breaker_threshold: 1,
            breaker_cooldown: Duration::from_millis(20),
            ..Default::default()
        });
        let mut req = request(23, 1.0, 0.5);
        req.spec.family = "nope".into();
        let key = req.spec.cache_key();
        let gauge_name = breaker_gauge_name(&key);
        assert_eq!(engine.submit(req).unwrap_err().kind(), "failed"); // trips
        assert_eq!(engine.metrics().gauge(&gauge_name), Some(1.0));
        std::thread::sleep(Duration::from_millis(40));
        // Cooldown over: the admission check converts the key to a
        // half-open probe and publishes state 2.
        assert!(breaker_check(&engine.state, &key).is_none());
        assert_eq!(engine.metrics().gauge(&gauge_name), Some(2.0));
        // The probe's success closes the breaker and prunes the series.
        breaker_record(&engine.state, &key, true);
        assert_eq!(engine.metrics().gauge(&gauge_name), None);
        engine.shutdown();
    }

    #[test]
    fn shed_estimate_scales_with_depth_and_workers() {
        assert_eq!(shed_wait_estimate(0, 4, 0.1), 0.0);
        assert_eq!(shed_wait_estimate(8, 4, 0.1), 0.2);
        assert_eq!(shed_wait_estimate(8, 0, 0.1), 0.8); // workers clamp to 1
        // An empty queue never sheds, whatever the deadline.
        assert!(shed_wait_estimate(0, 1, 100.0) <= 0.0);
    }
}
