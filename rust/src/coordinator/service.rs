//! TCP OT service: line-delimited JSON requests over a socket, executed
//! by the [`crate::serve`] engine (admission control, micro-batching,
//! warm-start cache).
//!
//! Requests (one JSON object per line):
//!
//! ```json
//! {"op": "ping"}
//! {"op": "metrics"}
//! {"op": "metrics_prom"}
//! {"op": "trace"}
//! {"op": "solve", "dataset": {"family": "synthetic", "param1": 10,
//!   "param2": 10, "seed": 1, "cost": {"mode": "factored"}},
//!   "gamma": 1.0, "rho": 0.5, "method": "fast",
//!   "regularizer": "group_lasso", "deadline_ms": 2000, "warm_start": true,
//!   "telemetry": true}
//! {"op": "shutdown"}
//! ```
//!
//! `regularizer` is optional (`group_lasso` | `squared_l2` |
//! `negentropy`); requests that omit it use the engine's configured
//! default. Unknown values get a structured rejection, never a panic.
//!
//! `dataset.cost` is optional — either a bare string or
//! `{"mode": "dense" | "factored"}` — and selects the cost-matrix
//! backend for that dataset's cached problem; omitted (or `"auto"`)
//! defers to the engine's configured default. Both backends return
//! byte-identical solver results; the factored backend holds
//! coordinates + norms instead of the m×n matrix.
//!
//! Responses: `{"ok": true, …}` or `{"ok": false, "error": "…"}`; engine
//! rejections additionally carry a machine-readable `"error_kind"`
//! (`queue_full` | `deadline_exceeded` | `shutdown` | `quarantined` |
//! `overloaded` | `failed`) so clients can distinguish backpressure,
//! circuit-broken datasets and overload from bad requests. Successful
//! solves report `warm_started`, `batch_size`, `queue_wait_s` and the
//! request's `trace_id` next to the solver fields, echo the
//! `regularizer` they solved with, and — when the request set
//! `"telemetry": true` — attach the solve's compact
//! [`crate::obs::SolveReport`] under `"telemetry"`.
//!
//! `metrics_prom` returns the same counters as `metrics` rendered in
//! Prometheus text exposition format (one string under `"prom"`);
//! `trace` drains the in-process span rings as Chrome trace-event JSON
//! under `"trace"` (empty unless the server runs with `GRPOT_TRACE`
//! set).

use super::config::{DatasetSpec, Method};
use super::metrics::Metrics;
use crate::err;
use crate::error::{Context, Result};
use crate::jsonlite::{self, Value};
use crate::ot::dual::DualParams;
use crate::ot::plan::recover_plan;
use crate::ot::regularizer::{recover_plan_reg, AnyRegularizer, RegKind};
use crate::serve::{Engine, ServeConfig, SolveRequest};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Shared server state.
struct ServerState {
    metrics: Arc<Metrics>,
    engine: Engine,
    stop: AtomicBool,
}

/// Handle to a running service.
pub struct ServiceHandle {
    pub addr: std::net::SocketAddr,
    join: Option<std::thread::JoinHandle<()>>,
    state: Arc<ServerState>,
}

impl ServiceHandle {
    /// Ask the server to stop and wait for it (the engine drains its
    /// queue before the workers exit).
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.state.stop.store(true, Ordering::SeqCst);
        // Unblock accept() with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        self.state.engine.shutdown();
    }
}

impl Drop for ServiceHandle {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Start the service on `bind` (use port 0 for an ephemeral port) with
/// `workers` solver threads and default engine settings.
pub fn serve(bind: &str, workers: usize) -> Result<ServiceHandle> {
    serve_with(bind, ServeConfig { workers: workers.max(1), ..Default::default() })
}

/// Start the service with a full engine configuration.
pub fn serve_with(bind: &str, cfg: ServeConfig) -> Result<ServiceHandle> {
    let listener = TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
    let addr = listener.local_addr()?;
    let metrics = Arc::new(Metrics::new());
    let state = Arc::new(ServerState {
        engine: Engine::start(cfg, Arc::clone(&metrics)),
        metrics,
        stop: AtomicBool::new(false),
    });
    let state2 = Arc::clone(&state);
    // One thread per connection (handlers block on the socket for the
    // connection's lifetime, so a fixed pool would be starved by idle
    // keep-alive clients). Solve concurrency is capped by the engine's
    // worker pool; overload beyond the admission queue is rejected with
    // a structured `queue_full` error instead of queuing unboundedly.
    let join = std::thread::Builder::new()
        .name("grpot-service".into())
        .spawn(move || {
            let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
            for conn in listener.incoming() {
                if state2.stop.load(Ordering::SeqCst) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        let st = Arc::clone(&state2);
                        handlers.push(std::thread::spawn(move || handle_conn(stream, &st)));
                    }
                    Err(_) => break,
                }
                handlers.retain(|h| !h.is_finished());
            }
            for h in handlers {
                let _ = h.join();
            }
        })?;
    Ok(ServiceHandle { addr, join: Some(join), state })
}

fn handle_conn(stream: TcpStream, state: &Arc<ServerState>) {
    let peer = stream.peer_addr().ok();
    // Periodically wake from blocking reads so idle keep-alive
    // connections observe the stop flag (otherwise shutdown would hang
    // on join until every client disconnects).
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(250)));
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => break, // connection closed
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if state.stop.load(Ordering::SeqCst) {
                    break;
                }
                // Keep any partial line already buffered; retry.
                continue;
            }
            Err(_) => break,
            Ok(_) => {}
        }
        if line.trim().is_empty() {
            line.clear();
            continue;
        }
        state.metrics.incr("service.requests", 1);
        let response = state
            .metrics
            .time("service.request_seconds", || handle_request(line.trim(), state));
        let response = match response {
            // Engine rejections arrive as objects that already carry
            // `ok: false` + `error_kind`; don't overwrite their verdict.
            Ok(v) if v.get("ok").is_some() => v,
            Ok(v) => v.set("ok", true),
            Err(e) => Value::obj().set("ok", false).set("error", format!("{e:#}")),
        };
        if writeln!(writer, "{}", response.to_json()).is_err() {
            break;
        }
        line.clear();
        if state.stop.load(Ordering::SeqCst) {
            break;
        }
    }
    let _ = peer;
}

/// Hard caps on client-controlled dataset sizes: a single line of JSON
/// must not be able to commission an `m × n` cost matrix that exhausts
/// memory. Generous for the in-repo generators, tiny next to what an
/// `O(mn)` build could otherwise be asked for.
const MAX_DATASET_SAMPLES: usize = 100_000;

fn parse_dataset(v: &Value) -> Result<DatasetSpec> {
    let d = v.get("dataset").ok_or_else(|| err!("missing 'dataset'"))?;
    let mut spec = DatasetSpec::default();
    if let Some(f) = d.get("family").and_then(Value::as_str) {
        spec.family = f.to_string();
    }
    if let Some(x) = d.get("param1").and_then(Value::as_usize) {
        spec.param1 = x;
    }
    if let Some(x) = d.get("param2").and_then(Value::as_usize) {
        spec.param2 = x;
    }
    if spec.param1 > MAX_DATASET_SAMPLES || spec.param2 > MAX_DATASET_SAMPLES {
        return Err(err!(
            "dataset params too large ({} × {}; cap {MAX_DATASET_SAMPLES} per side)",
            spec.param1,
            spec.param2
        ));
    }
    if let Some(x) = d.get("scale").and_then(Value::as_f64) {
        // Non-finite or non-positive scales would propagate NaN/degenerate
        // costs into the shared problem cache; reject at the wire.
        if !x.is_finite() || x <= 0.0 || x > 1e12 {
            return Err(err!("dataset scale must be finite, positive and ≤ 1e12 (got {x})"));
        }
        spec.scale = x;
    }
    if let Some(x) = d.get("seed").and_then(Value::as_f64) {
        if !x.is_finite() || x < 0.0 {
            return Err(err!("dataset seed must be a finite nonnegative number (got {x})"));
        }
        spec.seed = x as u64;
    }
    if let Some(c) = d.get("cost") {
        spec.cost = super::config::parse_cost_value(c)?;
    }
    Ok(spec)
}

fn handle_request(line: &str, state: &Arc<ServerState>) -> Result<Value> {
    let req = jsonlite::parse(line).context("parsing request json")?;
    let op = req
        .get("op")
        .and_then(Value::as_str)
        .ok_or_else(|| err!("missing 'op'"))?;
    match op {
        "ping" => Ok(Value::obj().set("pong", true)),
        "metrics" => Ok(Value::obj().set("metrics", state.metrics.snapshot())),
        "metrics_prom" => Ok(Value::obj()
            .set("prom", crate::obs::prom::render(&state.metrics.snapshot()))),
        "trace" => Ok(Value::obj().set("trace", crate::obs::span::drain_chrome_json())),
        "shutdown" => {
            state.stop.store(true, Ordering::SeqCst);
            Ok(Value::obj().set("stopping", true))
        }
        "solve" => {
            let spec = parse_dataset(&req)?;
            let gamma = req
                .get("gamma")
                .and_then(Value::as_f64)
                .ok_or_else(|| err!("missing 'gamma'"))?;
            let rho = req
                .get("rho")
                .and_then(Value::as_f64)
                .ok_or_else(|| err!("missing 'rho'"))?;
            let method = Method::parse(
                req.get("method").and_then(Value::as_str).unwrap_or("fast"),
            )?;
            method.ensure_available()?;
            let regularizer = match req.get("regularizer") {
                None => state.engine.default_regularizer(),
                Some(r) => {
                    let s = r
                        .as_str()
                        .ok_or_else(|| err!("'regularizer' must be a string"))?;
                    match RegKind::parse(s) {
                        Ok(k) => k,
                        Err(e) => {
                            // Same structured shape as engine rejections
                            // so clients branch on `error_kind`, and the
                            // bad value never reaches a solver.
                            let mut v = Value::obj()
                                .set("ok", false)
                                .set("error", e.to_string())
                                .set("error_kind", "failed");
                            if let Some(id) = req.get("id") {
                                v = v.set("id", id.clone());
                            }
                            return Ok(v);
                        }
                    }
                }
            };
            // Clamp to [0, 1 day]: Duration::from_secs_f64 panics on
            // non-finite/overflowing input, and a client-supplied value
            // must never be able to kill the connection handler.
            let deadline = req.get("deadline_ms").and_then(Value::as_f64).map(|ms| {
                let ms = if ms.is_finite() && ms > 0.0 { ms.min(86_400_000.0) } else { 0.0 };
                Duration::from_secs_f64(ms / 1e3)
            });
            let warm_start = req
                .get("warm_start")
                .and_then(Value::as_bool)
                .unwrap_or(true);
            let reply = match state.engine.submit(SolveRequest {
                spec,
                gamma,
                rho,
                method,
                regularizer,
                deadline,
                warm_start,
            }) {
                Ok(reply) => reply,
                Err(reject) => {
                    let mut v = Value::obj()
                        .set("ok", false)
                        .set("error", reject.to_string())
                        .set("error_kind", reject.kind());
                    if let Some(id) = req.get("id") {
                        v = v.set("id", id.clone());
                    }
                    return Ok(v);
                }
            };
            let res = &reply.result;
            let cached = &reply.problem;
            // Plan recovery must invert the same conjugate the solve
            // used: the specialized group-lasso path for group_lasso,
            // the generic ∇Ω* recovery otherwise.
            let plan = match regularizer {
                RegKind::GroupLasso => {
                    let params = DualParams::new(gamma, rho);
                    recover_plan(&cached.prob, &params, &res.x)
                }
                other => {
                    let reg = AnyRegularizer::build(other, gamma, rho, &cached.prob.groups)?;
                    recover_plan_reg(&cached.prob, &reg, &res.x)
                }
            };
            let acc = crate::eval::otda_accuracy(&cached.pair, &cached.prob, &plan);
            state.metrics.incr("service.solves", 1);
            let mut v = Value::obj()
                .set("method", method.name())
                .set("gamma", gamma)
                .set("rho", rho)
                .set("regularizer", regularizer.name())
                .set("dual_objective", res.dual_objective)
                .set("wall_time_s", res.wall_time_s)
                .set("iterations", res.iterations)
                .set("transport_cost", plan.transport_cost(&cached.prob))
                .set("group_sparsity", plan.group_sparsity(&cached.prob, 1e-12))
                .set("plan_density", plan.density(1e-12))
                .set("otda_accuracy", acc)
                .set("warm_started", reply.warm_started)
                .set("batch_size", reply.batch_size)
                .set("queue_wait_s", reply.queue_wait_s)
                .set("trace_id", reply.trace_id);
            if req.get("telemetry").and_then(Value::as_bool).unwrap_or(false) {
                if let Some(report) = &reply.telemetry {
                    v = v.set("telemetry", report.compact_json());
                }
            }
            if let Some(id) = req.get("id") {
                v = v.set("id", id.clone());
            }
            Ok(v)
        }
        other => Err(err!("unknown op '{other}'")),
    }
}

/// Minimal blocking client for the service.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting to service")?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { reader, writer: stream })
    }

    /// Send one request object; wait for and parse the response line.
    pub fn call(&mut self, req: &Value) -> Result<Value> {
        writeln!(self.writer, "{}", req.to_json())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        if line.is_empty() {
            return Err(err!("connection closed by server"));
        }
        Ok(jsonlite::parse(line.trim())?)
    }

    pub fn ping(&mut self) -> Result<bool> {
        let v = self.call(&Value::obj().set("op", "ping"))?;
        Ok(v.get("pong").and_then(Value::as_bool).unwrap_or(false))
    }
}
