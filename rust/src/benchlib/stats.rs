//! Robust summary statistics over timing samples.

/// Summary of a sample of measurements (seconds or any unit).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
}

impl Summary {
    /// Compute from raw samples. Panics on empty input.
    pub fn from_samples(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "no samples");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p10: percentile_sorted(&sorted, 10.0),
            p90: percentile_sorted(&sorted, 90.0),
        }
    }

    /// Relative standard deviation (coefficient of variation).
    pub fn rel_std(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std / self.mean
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}
