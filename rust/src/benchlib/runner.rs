//! Warmup + repeated-measurement runner.

use super::stats::Summary;
use std::time::Instant;

/// Options controlling one measurement.
#[derive(Clone, Debug)]
pub struct BenchOptions {
    /// Warmup executions whose timings are discarded.
    pub warmup: usize,
    /// Timed executions.
    pub iters: usize,
    /// Optional wall-clock budget in seconds: measurement stops early
    /// (after at least one timed iteration) once exceeded.
    pub max_seconds: f64,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions { warmup: 1, iters: 5, max_seconds: 120.0 }
    }
}

impl BenchOptions {
    /// Budget-friendly options for long end-to-end solves.
    pub fn slow() -> Self {
        BenchOptions { warmup: 0, iters: 3, max_seconds: 300.0 }
    }
}

/// Result of measuring one benchmark case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub summary: Summary,
    pub samples: Vec<f64>,
}

impl Measurement {
    pub fn seconds(&self) -> f64 {
        self.summary.median
    }
}

/// Measure `f` under `opts`; `f` performs one complete run per call.
/// Any setup needed per iteration belongs inside `f` before the returned
/// closure — `f` itself is fully timed.
///
/// In smoke mode ([`super::smoke_mode`]) the warmup is dropped and
/// exactly one timed iteration runs, whatever `opts` says — CI uses this
/// to exercise every bench binary without paying for real measurements.
pub fn bench_fn(name: &str, opts: &BenchOptions, mut f: impl FnMut()) -> Measurement {
    let (warmup, iters) = if super::smoke_mode() {
        (0, 1)
    } else {
        (opts.warmup, opts.iters.max(1))
    };
    for _ in 0..warmup {
        f();
    }
    let budget_start = Instant::now();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
        // At least one timed sample is always kept; stop once over budget.
        if budget_start.elapsed().as_secs_f64() > opts.max_seconds {
            break;
        }
    }
    Measurement {
        name: name.to_string(),
        summary: Summary::from_samples(&samples),
        samples,
    }
}
