//! Serving-engine load bench (ours, not in the paper): closed-loop
//! clients drive the micro-batching, warm-starting engine and we report
//! throughput, latency percentiles and the warm-start hit rate.
//!
//! The workload is the repeated-(γ, ρ) scenario a serving deployment
//! sees: cycle 1 is cold, later cycles re-request the same keys, so the
//! dual cache must show hits and tail latency must drop. Worker-count
//! rows expose the concurrency scaling of the engine itself.

mod common;

use common::{banner, size3};
use grpot::benchlib::{report_dir, Table};
use grpot::coordinator::config::{DatasetSpec, Method};
use grpot::ot::regularizer::RegKind;
use grpot::ot::solve::SolveOptions;
use grpot::serve::loadgen::{run_load, LoadScenario};
use grpot::serve::ServeConfig;
use grpot::solvers::lbfgs::LbfgsOptions;

fn main() {
    banner("bench_serve: serving engine under closed-loop load");
    let (clients, cycles) = size3((3, 2), (4, 3), (8, 5));
    let (param1, param2) = size3((3, 4), (10, 10), (10, 30));
    let gammas = size3(vec![0.5, 1.0], vec![0.1, 1.0], vec![0.1, 1.0, 10.0]);
    let rhos = size3(vec![0.5, 0.8], vec![0.4, 0.8], vec![0.2, 0.4, 0.6, 0.8]);
    let max_iters = size3(20, 200, 500);
    let worker_rows = size3(vec![1, 2], vec![1, 4], vec![1, 2, 4, 8]);

    let mut table = Table::new(
        "bench-serve — closed-loop serving load",
        &[
            "workers",
            "requests",
            "ok",
            "solves",
            "thru[req/s]",
            "p50[ms]",
            "p95[ms]",
            "p99[ms]",
            "warm-hit",
        ],
    );
    for workers in worker_rows {
        let scenario = LoadScenario {
            spec: DatasetSpec {
                family: "synthetic".into(),
                param1,
                param2,
                seed: 0xBE7C,
                ..Default::default()
            },
            gammas: gammas.clone(),
            rhos: rhos.clone(),
            cycles,
            clients,
            method: Method::Fast,
            regularizer: RegKind::GroupLasso,
            deadline: None,
        };
        let cfg = ServeConfig {
            workers,
            solve: SolveOptions::new().lbfgs(LbfgsOptions { max_iters, ..Default::default() }),
            ..Default::default()
        };
        println!("\n-- {workers} worker(s), {clients} clients, {cycles} cycles --");
        let report = run_load(cfg, &scenario);
        report.print_summary();
        // Hard invariants, asserted even in smoke mode: no lost
        // responses, and the repeated workload must warm-start.
        assert_eq!(
            report.ok + report.rejected_queue_full + report.rejected_deadline + report.failed,
            report.requests,
            "lost responses"
        );
        assert!(report.warm_hits > 0, "repeated workload must warm-start: {report:?}");
        assert!(report.solves <= report.requests as u64, "dedupe can only shrink work");
        table.row(vec![
            format!("{workers}"),
            format!("{}", report.requests),
            format!("{}", report.ok),
            format!("{}", report.solves),
            format!("{:.2}", report.throughput_rps),
            format!("{:.2}", report.p50_ms),
            format!("{:.2}", report.p95_ms),
            format!("{:.2}", report.p99_ms),
            format!("{:.1}%", 100.0 * report.warm_hit_rate),
        ]);
    }
    table.emit(&report_dir(), "bench_serve");
}
