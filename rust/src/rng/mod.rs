//! Deterministic pseudo-randomness substrate.
//!
//! PCG64 (PCG-XSL-RR 128/64) with Box–Muller normals. Every dataset
//! generator, test, and property-test in the repo derives its randomness
//! from an explicit seed through this module, so all experiments are
//! reproducible bit-for-bit.

mod pcg;

pub use pcg::Pcg64;

impl Pcg64 {
    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` via Lemire's method (unbiased).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller (one value per call; the spare is
    /// cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate 1.
    pub fn exp1(&mut self) -> f64 {
        -(1.0 - self.f64()).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k ≤ n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher–Yates: first k positions become the sample.
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Draw from a categorical distribution given (unnormalized,
    /// nonnegative) weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical needs positive total weight");
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Derive an independent child stream (for per-thread/per-job rngs).
    pub fn split(&mut self) -> Pcg64 {
        let seed = self.next_u64();
        let stream = self.next_u64() | 1;
        Pcg64::new_with_stream(seed, stream)
    }
}

#[cfg(test)]
mod tests;
