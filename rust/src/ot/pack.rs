//! Packed cost tiles for the lane-vectorized oracle kernels.
//!
//! The scalar kernels read column `j` of the cost matrix as row `j` of
//! the transposed `cost_t`, so a quad kernel over 4 columns would need
//! 4 strided row gathers per `i`. [`PackedCost`] re-lays `cost_t` into
//! per-(panel, group, quad) tiles interleaved `[i][lane]`, so the
//! vector kernels do one unit-stride load per `i` instead. The pack is
//! built lazily **once per problem instance**
//! (`OtProblem::packed_cost`) and `Arc`-shared by every vector-dispatch
//! oracle constructed on it afterwards — amortized over every L-BFGS
//! iteration, warm-started re-solve, sweep grid point and serving
//! request touching the same cached dataset; its memory cost is at
//! most one extra `m × n` `f64` copy.
//!
//! Layout. Columns follow the fixed chunk grid
//! ([`crate::pool::fixed_chunk_ranges`]) split into panels of
//! `PANEL_COLS` columns ([`panel_ranges`]); each panel contributes
//! `⌊panel_len / LANES⌋` full quads (leftover columns stay on the
//! scalar kernel and read `cost_t` directly). Within one panel the data
//! is ordered group-major:
//!
//! ```text
//! tile(panel, l, q)[k·LANES + t] = cost_t[(j₀(panel) + q·LANES + t, offsets[l] + k)]
//! ```
//!
//! i.e. groups ascending, quads ascending inside a group, then `i`
//! ascending with the quad's [`LANES`] columns interleaved — matching
//! the kernel walk (panel → group → quad) so tile reads are sequential.

use super::dual::{panel_ranges, OtProblem};
use crate::simd::LANES;
use std::ops::Range;

/// The packed, quad-interleaved copy of a problem's cost matrix over a
/// fixed chunk grid. Immutable after construction; shared by every
/// evaluation and snapshot refresh of the owning oracle.
pub struct PackedCost {
    data: Vec<f64>,
    /// Global panel index → offset of the panel's first tile in `data`.
    panel_base: Vec<usize>,
    /// Global panel index → number of full quads in the panel.
    panel_quads: Vec<usize>,
    /// Chunk index → global index of the chunk's first panel.
    chunk_panel_off: Vec<usize>,
    /// Group start offsets (`groups.offsets` prefix), cached so tile
    /// lookup needs no `&GroupStructure`.
    group_offsets: Vec<usize>,
}

impl PackedCost {
    /// Pack `prob.cost_t` over the chunk grid `ranges` (the same grid
    /// the owning oracle evaluates over — panel boundaries are a
    /// function of the grid alone, so the tiles line up with the walk
    /// at every thread count).
    pub fn pack(prob: &OtProblem, ranges: &[Range<usize>]) -> PackedCost {
        let m = prob.m();
        let groups = &prob.groups;
        let mut panel_base = Vec::new();
        let mut panel_quads = Vec::new();
        let mut chunk_panel_off = Vec::with_capacity(ranges.len());
        let mut total = 0usize;
        for range in ranges {
            chunk_panel_off.push(panel_base.len());
            for panel in panel_ranges(range.clone()) {
                let quads = panel.len() / LANES;
                panel_base.push(total);
                panel_quads.push(quads);
                total += quads * LANES * m;
            }
        }
        let mut data = Vec::with_capacity(total);
        for range in ranges {
            for panel in panel_ranges(range.clone()) {
                let quads = panel.len() / LANES;
                for l in 0..groups.num_groups() {
                    for q in 0..quads {
                        let j0 = panel.start + q * LANES;
                        for i in groups.range(l) {
                            for t in 0..LANES {
                                data.push(prob.cost_t()[(j0 + t, i)]);
                            }
                        }
                    }
                }
            }
        }
        debug_assert_eq!(data.len(), total);
        PackedCost {
            data,
            panel_base,
            panel_quads,
            chunk_panel_off,
            group_offsets: groups.offsets.clone(),
        }
    }

    /// Global index of chunk `c`'s first panel.
    #[inline]
    pub fn chunk_first_panel(&self, c: usize) -> usize {
        self.chunk_panel_off[c]
    }

    /// Full quads in global panel `gp` (leftover columns are scalar).
    #[inline]
    pub fn quads(&self, gp: usize) -> usize {
        self.panel_quads[gp]
    }

    /// The `[i][lane]`-interleaved tile of (global panel `gp`, group
    /// `l`, quad `q`): `LANES · g_l` values, unit stride.
    #[inline]
    pub fn tile(&self, gp: usize, l: usize, q: usize) -> &[f64] {
        let quads = self.panel_quads[gp];
        debug_assert!(q < quads);
        let g = self.group_offsets[l + 1] - self.group_offsets[l];
        let off =
            self.panel_base[gp] + LANES * (self.group_offsets[l] * quads + q * g);
        &self.data[off..off + LANES * g]
    }

    /// Bytes held by the packed copy (diagnostics; ≈ `8·m·n` when every
    /// panel is quad-aligned).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::pool::fixed_chunk_ranges;
    use crate::rng::Pcg64;

    fn random_problem(seed: u64, l: usize, g: usize, n: usize) -> OtProblem {
        let mut rng = Pcg64::new(seed);
        let m = l * g;
        let cost = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
        let labels: Vec<usize> = (0..m).map(|i| i / g).collect();
        OtProblem::from_parts(vec![1.0 / m as f64; m], vec![1.0 / n as f64; n], &cost, &labels)
    }

    /// Every tile entry must equal the corresponding `cost_t` entry —
    /// exhaustively, over ragged panels (n not a multiple of
    /// `PANEL_COLS`) and non-uniform groups.
    #[test]
    fn tiles_mirror_cost_t() {
        for (l, g, n) in [(3usize, 4usize, 19usize), (2, 3, 8), (5, 2, 37), (1, 6, 4)] {
            let prob = random_problem(0xAC4 + n as u64, l, g, n);
            let ranges = fixed_chunk_ranges(prob.n());
            let pack = PackedCost::pack(&prob, &ranges);
            for (c, range) in ranges.iter().enumerate() {
                for (p, panel) in panel_ranges(range.clone()).enumerate() {
                    let gp = pack.chunk_first_panel(c) + p;
                    assert_eq!(pack.quads(gp), panel.len() / LANES);
                    for li in 0..prob.groups.num_groups() {
                        let grange = prob.groups.range(li);
                        for q in 0..pack.quads(gp) {
                            let tile = pack.tile(gp, li, q);
                            assert_eq!(tile.len(), LANES * grange.len());
                            let j0 = panel.start + q * LANES;
                            for (k, i) in grange.clone().enumerate() {
                                for t in 0..LANES {
                                    assert_eq!(
                                        tile[k * LANES + t].to_bits(),
                                        prob.cost_t()[(j0 + t, i)].to_bits(),
                                        "tile ({gp},{li},{q}) k={k} t={t}"
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn memory_cost_is_at_most_one_cost_copy() {
        let prob = random_problem(9, 4, 5, 40);
        let pack = PackedCost::pack(&prob, &fixed_chunk_ranges(prob.n()));
        assert!(pack.bytes() <= prob.m() * prob.n() * std::mem::size_of::<f64>());
    }

    #[test]
    fn panels_shorter_than_a_quad_pack_nothing() {
        let prob = random_problem(11, 2, 2, 3); // n=3 < LANES
        let pack = PackedCost::pack(&prob, &fixed_chunk_ranges(prob.n()));
        assert_eq!(pack.quads(0), 0);
        assert_eq!(pack.bytes(), 0);
    }
}
