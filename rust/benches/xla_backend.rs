//! AOT backend bench (ours, not in the paper): the dense dual oracle in
//! native Rust vs the AOT JAX/Pallas artifact executed via PJRT, per
//! evaluation and end-to-end. Quantifies the FFI + dense-vectorized
//! trade-off and regression-tests the artifact path's performance.
//!
//! Requires the `xla` cargo feature; the default build prints a skip
//! notice so the smoke pass can still exercise the binary. Also skips
//! (with a notice) when artifacts are missing.

mod common;

#[cfg(feature = "xla")]
use common::*;
#[cfg(feature = "xla")]
use grpot::benchlib::{bench_fn, report_dir, BenchOptions, Table};
#[cfg(feature = "xla")]
use grpot::coordinator::config::Method;
#[cfg(feature = "xla")]
use grpot::coordinator::sweep::run_job;
#[cfg(feature = "xla")]
use grpot::ot::dual::{DualOracle, DualParams};
#[cfg(feature = "xla")]
use grpot::ot::origin::OriginOracle;
#[cfg(feature = "xla")]
use grpot::rng::Pcg64;
#[cfg(feature = "xla")]
use grpot::runtime::{artifact_dir, Manifest, PjrtRuntime, XlaDualOracle};

#[cfg(not(feature = "xla"))]
fn main() {
    common::banner("xla_backend: native vs AOT dense oracle");
    println!("SKIP: built without the `xla` cargo feature — rebuild with `--features xla`");
}

#[cfg(feature = "xla")]
fn main() {
    banner("xla_backend: native vs AOT dense oracle");
    let manifest = match Manifest::load(&artifact_dir()) {
        Ok(m) => m,
        Err(e) => {
            println!("SKIP: {e:#} — run `make artifacts`");
            return;
        }
    };
    let runtime = PjrtRuntime::cpu().expect("pjrt");
    let params = DualParams::new(0.5, 0.5);
    let opts = BenchOptions { warmup: 2, iters: 20, max_seconds: 60.0 };

    let mut table = Table::new(
        "AOT backend — per-eval latency and end-to-end solve",
        &["shape", "rust eval[ms]", "xla eval[ms]", "rust solve[s]", "xla solve[s]"],
    );
    for entry in &manifest.entries {
        let (l, g, n) = (entry.num_groups, entry.group_size, entry.n);
        let m = l * g;
        let mut rng = Pcg64::new(0xBE7C);
        let cost = grpot::linalg::Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
        let labels: Vec<usize> = (0..m).map(|i| i / g).collect();
        let prob = grpot::ot::dual::OtProblem::from_parts(
            vec![1.0 / m as f64; m],
            vec![1.0 / n as f64; n],
            &cost,
            &labels,
        );
        let x: Vec<f64> = (0..prob.dim()).map(|_| rng.uniform(-0.3, 0.5)).collect();
        let mut grad = vec![0.0; prob.dim()];

        let mut rust_oracle = OriginOracle::new(&prob, params);
        let rust_eval = bench_fn(&format!("rust-eval-{l}x{g}x{n}"), &opts, || {
            rust_oracle.eval(&x, &mut grad);
        });

        let mut xla_oracle =
            XlaDualOracle::from_problem(&runtime, &prob, &params, &artifact_dir())
                .expect("artifact load");
        let xla_eval = bench_fn(&format!("xla-eval-{l}x{g}x{n}"), &opts, || {
            xla_oracle.eval(&x, &mut grad);
        });

        let solve_opts = BenchOptions { warmup: 1, iters: 3, max_seconds: 120.0 };
        let rust_solve = bench_fn(&format!("rust-solve-{l}x{g}x{n}"), &solve_opts, || {
            run_job(&prob, Method::Origin, 0.5, 0.5, 10, 200);
        });
        let xla_solve = bench_fn(&format!("xla-solve-{l}x{g}x{n}"), &solve_opts, || {
            run_job(&prob, Method::XlaOrigin, 0.5, 0.5, 10, 200);
        });

        println!(
            "L={l} g={g} n={n}: eval rust {:.3}ms xla {:.3}ms | solve rust {:.3}s xla {:.3}s",
            rust_eval.seconds() * 1e3,
            xla_eval.seconds() * 1e3,
            rust_solve.seconds(),
            xla_solve.seconds()
        );
        table.row(vec![
            format!("L{l}g{g}n{n}"),
            format!("{:.3}", rust_eval.seconds() * 1e3),
            format!("{:.3}", xla_eval.seconds() * 1e3),
            format!("{:.3}", rust_solve.seconds()),
            format!("{:.3}", xla_solve.seconds()),
        ]);
    }
    table.emit(&report_dir(), "xla_backend");
}
