//! Determinism of the intra-solve parallel hot path: for a fixed
//! problem and config, `threads ∈ {1, 2, 4}` must return *byte-equal*
//! solutions, objectives, iteration counts and oracle counters for both
//! the screened and the dense method — the multicore analogue of the
//! paper's Theorem 2 (exactness under acceleration). The pool's ordered
//! chunk reduction is what makes this hold; these tests are the
//! executable statement of that guarantee.

use grpot::coordinator::config::Method;
use grpot::coordinator::sweep::solve_full_threads;
use grpot::linalg::Mat;
use grpot::ot::dual::{eval_dense, eval_dense_threads, DualParams, OracleStats, OtProblem};
use grpot::ot::fastot::{solve_fast_ot, FastOtConfig, FastOtResult};
use grpot::ot::origin::solve_origin;
use grpot::ot::semidual::solve_semidual_threads;
use grpot::rng::Pcg64;
use grpot::solvers::lbfgs::LbfgsOptions;

fn random_problem(seed: u64, l: usize, g: usize, n: usize) -> OtProblem {
    let mut rng = Pcg64::new(seed);
    let m = l * g;
    let cost = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
    let labels: Vec<usize> = (0..m).map(|i| i / g).collect();
    OtProblem::from_parts(vec![1.0 / m as f64; m], vec![1.0 / n as f64; n], &cost, &labels)
}

fn assert_stats_eq(a: &OracleStats, b: &OracleStats, what: &str) {
    assert_eq!(a.evals, b.evals, "{what}: evals");
    assert_eq!(a.grads_computed, b.grads_computed, "{what}: grads_computed");
    assert_eq!(a.grads_skipped, b.grads_skipped, "{what}: grads_skipped");
    assert_eq!(a.ub_checks, b.ub_checks, "{what}: ub_checks");
    assert_eq!(a.ws_hits, b.ws_hits, "{what}: ws_hits");
    assert_eq!(a.per_eval_grads, b.per_eval_grads, "{what}: per_eval_grads");
}

fn assert_results_identical(a: &FastOtResult, b: &FastOtResult, what: &str) {
    assert_eq!(a.x, b.x, "{what}: solution bytes");
    assert_eq!(a.dual_objective, b.dual_objective, "{what}: objective");
    assert_eq!(a.iterations, b.iterations, "{what}: iterations");
    assert_eq!(a.outer_rounds, b.outer_rounds, "{what}: outer rounds");
    assert_stats_eq(&a.stats, &b.stats, what);
}

/// The acceptance-criterion test: threads ∈ {1, 2, 4} are byte-equal
/// for `solve_fast_ot` and `solve_origin`, across hyperparameters that
/// hit both the skip-heavy and the dense regime.
#[test]
fn fast_and_origin_bit_identical_across_thread_counts() {
    // n = 37 spans multiple fixed chunks once MIN_FIXED_CHUNK_LEN = 16.
    let prob = random_problem(0xDE7, 5, 4, 37);
    for (gamma, rho) in [(0.1, 0.3), (1.0, 0.5), (8.0, 0.8)] {
        let cfg_with = |threads: usize| FastOtConfig {
            gamma,
            rho,
            threads,
            lbfgs: LbfgsOptions { max_iters: 120, ..Default::default() },
            ..Default::default()
        };
        let fast1 = solve_fast_ot(&prob, &cfg_with(1));
        let orig1 = solve_origin(&prob, &cfg_with(1));
        for threads in [2, 4] {
            let fast_t = solve_fast_ot(&prob, &cfg_with(threads));
            assert_results_identical(
                &fast1,
                &fast_t,
                &format!("fast γ={gamma} ρ={rho} threads={threads}"),
            );
            let orig_t = solve_origin(&prob, &cfg_with(threads));
            assert_results_identical(
                &orig1,
                &orig_t,
                &format!("origin γ={gamma} ρ={rho} threads={threads}"),
            );
        }
        // Theorem 2 must also hold *across* methods at any thread mix.
        assert_eq!(fast1.dual_objective, orig1.dual_objective);
        assert_eq!(fast1.x, orig1.x);
        assert_eq!(fast1.iterations, orig1.iterations);
    }
}

/// The threaded dense evaluation is byte-equal to the serial reference
/// `eval_dense` at arbitrary points (not just along solver iterates).
#[test]
fn eval_dense_threads_matches_serial_reference() {
    let prob = random_problem(0xE1A, 4, 5, 53);
    let params = DualParams::new(0.7, 0.4);
    let mut rng = Pcg64::new(31);
    let mut x = vec![0.0; prob.dim()];
    for _ in 0..8 {
        for v in x.iter_mut() {
            *v += rng.uniform(-0.3, 0.35);
        }
        let mut g1 = vec![0.0; prob.dim()];
        let (f1, n1) = eval_dense(&prob, &params, &x, &mut g1);
        for threads in [2, 3, 8] {
            let mut gt = vec![0.0; prob.dim()];
            let (ft, nt) = eval_dense_threads(&prob, &params, &x, &mut gt, threads);
            assert_eq!(f1, ft, "objective at threads={threads}");
            assert_eq!(g1, gt, "gradient at threads={threads}");
            assert_eq!(n1, nt);
        }
    }
}

/// Warm starts compose with threading: a threaded solve seeded at an
/// arbitrary iterate is byte-equal to the serial warm solve.
#[test]
fn warm_started_threaded_solve_matches_serial() {
    let prob = random_problem(0xAB5, 4, 3, 33);
    let mut rng = Pcg64::new(77);
    let x0: Vec<f64> = (0..prob.dim()).map(|_| rng.uniform(-0.2, 0.3)).collect();
    let cfg_with = |threads: usize| FastOtConfig {
        gamma: 0.6,
        rho: 0.55,
        threads,
        lbfgs: LbfgsOptions { max_iters: 90, ..Default::default() },
        ..Default::default()
    };
    let serial = grpot::ot::fastot::solve_fast_ot_from(&prob, &cfg_with(1), x0.clone());
    let threaded = grpot::ot::fastot::solve_fast_ot_from(&prob, &cfg_with(4), x0);
    assert_results_identical(&serial, &threaded, "warm-started fast");
}

/// The sweep-layer entry point plumbs the knob end to end.
#[test]
fn solve_full_threads_is_deterministic_per_method() {
    let prob = random_problem(0x5EE, 3, 4, 29);
    for method in [Method::Fast, Method::FastNoWs, Method::Origin] {
        let serial = solve_full_threads(&prob, method, 0.4, 0.6, 10, 80, 1);
        let threaded = solve_full_threads(&prob, method, 0.4, 0.6, 10, 80, 4);
        assert_results_identical(&serial, &threaded, method.name());
    }
}

/// The semi-dual oracle's column chunks reduce deterministically too.
#[test]
fn semidual_bit_identical_across_thread_counts() {
    let prob = random_problem(0x5D1, 3, 4, 41);
    let opts = LbfgsOptions { max_iters: 200, ..Default::default() };
    let serial = solve_semidual_threads(&prob, 0.2, &opts, 1);
    for threads in [2, 4] {
        let threaded = solve_semidual_threads(&prob, 0.2, &opts, threads);
        assert_eq!(serial.alpha, threaded.alpha, "threads={threads}: alpha bytes");
        assert_eq!(serial.objective, threaded.objective, "threads={threads}: objective");
        assert_eq!(serial.iterations, threaded.iterations, "threads={threads}: iterations");
        assert_eq!(serial.plan, threaded.plan, "threads={threads}: plan");
    }
}
