//! Datasets for the paper's four evaluation families.
//!
//! The image has no network access, so the real USPS/MNIST/PIE/
//! Caltech-Office archives are substituted by generators that match
//! each dataset's *geometry as seen by the solver* — class count,
//! feature dimension, per-domain sizes, class-clustered structure and a
//! controlled domain shift. The screening behaviour under study depends
//! only on that geometry (through the cost matrix), not on pixel-level
//! realism; see DESIGN.md §3 for the substitution table.

pub mod cost;
pub mod digits;
pub mod faces;
pub mod objects;
pub mod synthetic;

use crate::linalg::Mat;

/// A labeled point cloud on one domain.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Human-readable name ("usps-like", "pie05-like", …).
    pub name: String,
    /// Feature matrix, one sample per row.
    pub x: Mat,
    /// Class label per sample. For *target* domains these exist only
    /// for evaluation (the solver never sees them).
    pub labels: Vec<usize>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.x.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    pub fn num_classes(&self) -> usize {
        self.labels.iter().copied().max().map_or(0, |m| m + 1)
    }
}

/// A source (labeled) / target (unlabeled at solve time) pair.
#[derive(Clone, Debug)]
pub struct DomainPair {
    pub source: Dataset,
    pub target: Dataset,
}

impl DomainPair {
    /// Short "S→T" task label, e.g. `"usps→mnist"`.
    pub fn task_name(&self) -> String {
        format!("{}→{}", self.source.name, self.target.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_accessors() {
        let d = Dataset {
            name: "t".into(),
            x: Mat::zeros(3, 2),
            labels: vec![0, 2, 1],
        };
        assert_eq!(d.len(), 3);
        assert_eq!(d.dim(), 2);
        assert_eq!(d.num_classes(), 3);
        assert!(!d.is_empty());
    }

    #[test]
    fn pair_task_name() {
        let mk = |n: &str| Dataset { name: n.into(), x: Mat::zeros(1, 1), labels: vec![0] };
        let p = DomainPair { source: mk("u"), target: mk("m") };
        assert_eq!(p.task_name(), "u→m");
    }
}
