//! TCP OT service: line-delimited JSON requests over a socket.
//!
//! Requests (one JSON object per line):
//!
//! ```json
//! {"op": "ping"}
//! {"op": "metrics"}
//! {"op": "solve", "dataset": {"family": "synthetic", "param1": 10,
//!   "param2": 10, "seed": 1}, "gamma": 1.0, "rho": 0.5, "method": "fast"}
//! {"op": "shutdown"}
//! ```
//!
//! Responses: `{"ok": true, …}` or `{"ok": false, "error": "…"}`.
//! Problems (cost matrices) are cached per dataset spec, so repeated
//! requests against the same dataset pay generation cost once — the
//! serving-style hot path is solver-only, with Python nowhere in sight.

use super::config::{DatasetSpec, Method};
use super::metrics::Metrics;
use super::registry::build_pair;
use super::sweep::solve_full;
use crate::data::DomainPair;
use crate::err;
use crate::error::{Context, Result};
use crate::jsonlite::{self, Value};
use crate::ot::dual::{DualParams, OtProblem};
use crate::ot::plan::recover_plan;
use crate::pool::Semaphore;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

struct CachedProblem {
    pair: DomainPair,
    prob: OtProblem,
}

/// Shared server state.
struct ServerState {
    metrics: Metrics,
    cache: Mutex<BTreeMap<String, Arc<CachedProblem>>>,
    stop: AtomicBool,
    /// Caps concurrent solves (`workers` of [`serve`]).
    solve_gate: Semaphore,
}

/// Handle to a running service.
pub struct ServiceHandle {
    pub addr: std::net::SocketAddr,
    join: Option<std::thread::JoinHandle<()>>,
    state: Arc<ServerState>,
}

impl ServiceHandle {
    /// Ask the server to stop and wait for it.
    pub fn shutdown(mut self) {
        self.state.stop.store(true, Ordering::SeqCst);
        // Unblock accept() with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServiceHandle {
    fn drop(&mut self) {
        self.state.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Start the service on `bind` (use port 0 for an ephemeral port).
/// `workers` is the connection-handling pool size.
pub fn serve(bind: &str, workers: usize) -> Result<ServiceHandle> {
    let listener = TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
    let addr = listener.local_addr()?;
    let state = Arc::new(ServerState {
        metrics: Metrics::new(),
        cache: Mutex::new(BTreeMap::new()),
        stop: AtomicBool::new(false),
        solve_gate: Semaphore::new(workers.max(1)),
    });
    let state2 = Arc::clone(&state);
    // One thread per connection (handlers block on the socket for the
    // connection's lifetime, so a fixed pool would be starved by idle
    // keep-alive clients). The semaphore caps *concurrent solves* at
    // `workers` instead — that's the resource that matters.
    let join = std::thread::Builder::new()
        .name("grpot-service".into())
        .spawn(move || {
            let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
            for conn in listener.incoming() {
                if state2.stop.load(Ordering::SeqCst) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        let st = Arc::clone(&state2);
                        handlers.push(std::thread::spawn(move || handle_conn(stream, &st)));
                    }
                    Err(_) => break,
                }
                handlers.retain(|h| !h.is_finished());
            }
            for h in handlers {
                let _ = h.join();
            }
        })?;
    Ok(ServiceHandle { addr, join: Some(join), state })
}

fn handle_conn(stream: TcpStream, state: &Arc<ServerState>) {
    let peer = stream.peer_addr().ok();
    // Periodically wake from blocking reads so idle keep-alive
    // connections observe the stop flag (otherwise shutdown would hang
    // on join until every client disconnects).
    let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(250)));
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => break, // connection closed
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if state.stop.load(Ordering::SeqCst) {
                    break;
                }
                // Keep any partial line already buffered; retry.
                continue;
            }
            Err(_) => break,
            Ok(_) => {}
        }
        if line.trim().is_empty() {
            line.clear();
            continue;
        }
        state.metrics.incr("service.requests", 1);
        let response = state
            .metrics
            .time("service.request_seconds", || handle_request(line.trim(), state));
        let response = match response {
            Ok(v) => v.set("ok", true),
            Err(e) => Value::obj().set("ok", false).set("error", format!("{e:#}")),
        };
        if writeln!(writer, "{}", response.to_json()).is_err() {
            break;
        }
        line.clear();
        if state.stop.load(Ordering::SeqCst) {
            break;
        }
    }
    let _ = peer;
}

fn parse_dataset(v: &Value) -> Result<DatasetSpec> {
    let d = v.get("dataset").ok_or_else(|| err!("missing 'dataset'"))?;
    let mut spec = DatasetSpec::default();
    if let Some(f) = d.get("family").and_then(Value::as_str) {
        spec.family = f.to_string();
    }
    if let Some(x) = d.get("param1").and_then(Value::as_usize) {
        spec.param1 = x;
    }
    if let Some(x) = d.get("param2").and_then(Value::as_usize) {
        spec.param2 = x;
    }
    if let Some(x) = d.get("scale").and_then(Value::as_f64) {
        spec.scale = x;
    }
    if let Some(x) = d.get("seed").and_then(Value::as_f64) {
        spec.seed = x as u64;
    }
    Ok(spec)
}

fn cached_problem(state: &Arc<ServerState>, spec: &DatasetSpec) -> Result<Arc<CachedProblem>> {
    let key = format!(
        "{}:{}:{}:{}:{}",
        spec.family, spec.param1, spec.param2, spec.scale, spec.seed
    );
    if let Some(hit) = state.cache.lock().unwrap().get(&key) {
        state.metrics.incr("service.cache_hits", 1);
        return Ok(Arc::clone(hit));
    }
    state.metrics.incr("service.cache_misses", 1);
    let pair = build_pair(spec)?;
    let prob = OtProblem::from_dataset(&pair);
    let cached = Arc::new(CachedProblem { pair, prob });
    state
        .cache
        .lock()
        .unwrap()
        .insert(key, Arc::clone(&cached));
    Ok(cached)
}

fn handle_request(line: &str, state: &Arc<ServerState>) -> Result<Value> {
    let req = jsonlite::parse(line).context("parsing request json")?;
    let op = req
        .get("op")
        .and_then(Value::as_str)
        .ok_or_else(|| err!("missing 'op'"))?;
    match op {
        "ping" => Ok(Value::obj().set("pong", true)),
        "metrics" => Ok(Value::obj().set("metrics", state.metrics.snapshot())),
        "shutdown" => {
            state.stop.store(true, Ordering::SeqCst);
            Ok(Value::obj().set("stopping", true))
        }
        "solve" => {
            let spec = parse_dataset(&req)?;
            let gamma = req
                .get("gamma")
                .and_then(Value::as_f64)
                .ok_or_else(|| err!("missing 'gamma'"))?;
            let rho = req
                .get("rho")
                .and_then(Value::as_f64)
                .ok_or_else(|| err!("missing 'rho'"))?;
            let method = Method::parse(
                req.get("method").and_then(Value::as_str).unwrap_or("fast"),
            )?;
            method.ensure_available()?;
            let cached = cached_problem(state, &spec)?;
            let _permit = state.solve_gate.acquire();
            let res = solve_full(&cached.prob, method, gamma, rho, 10, 1000);
            let params = DualParams::new(gamma, rho);
            let plan = recover_plan(&cached.prob, &params, &res.x);
            let acc = crate::eval::otda_accuracy(&cached.pair, &cached.prob, &plan);
            state.metrics.incr("service.solves", 1);
            let mut v = Value::obj()
                .set("method", method.name())
                .set("gamma", gamma)
                .set("rho", rho)
                .set("dual_objective", res.dual_objective)
                .set("wall_time_s", res.wall_time_s)
                .set("iterations", res.iterations)
                .set("transport_cost", plan.transport_cost(&cached.prob))
                .set("group_sparsity", plan.group_sparsity(&cached.prob, 1e-12))
                .set("plan_density", plan.density(1e-12))
                .set("otda_accuracy", acc);
            if let Some(id) = req.get("id") {
                v = v.set("id", id.clone());
            }
            Ok(v)
        }
        other => Err(err!("unknown op '{other}'")),
    }
}

/// Minimal blocking client for the service.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting to service")?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { reader, writer: stream })
    }

    /// Send one request object; wait for and parse the response line.
    pub fn call(&mut self, req: &Value) -> Result<Value> {
        writeln!(self.writer, "{}", req.to_json())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        if line.is_empty() {
            return Err(err!("connection closed by server"));
        }
        Ok(jsonlite::parse(line.trim())?)
    }

    pub fn ping(&mut self) -> Result<bool> {
        let v = self.call(&Value::obj().set("op", "ping"))?;
        Ok(v.get("pong").and_then(Value::as_bool).unwrap_or(false))
    }
}
