//! Dense linear algebra substrate.
//!
//! The whole stack works on `f64` row-major matrices ([`Mat`]) and plain
//! `&[f64]` slices. This module provides exactly the operations the OT
//! core needs: BLAS-1 kernels, grouped partial norms, pairwise squared
//! Euclidean cost matrices, and a few reductions. No external crates.

mod mat;
mod ops;

pub use mat::Mat;
pub use ops::*;

#[cfg(test)]
mod tests;
