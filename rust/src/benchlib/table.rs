//! Paper-style result tables: aligned console/markdown output + CSV.

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-oriented results table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render as a github-markdown table with a title line.
    pub fn to_markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "### {}", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for i in 0..ncol {
                let _ = write!(line, " {:<w$} |", cells[i], w = widths[i]);
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for r in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(r, &widths));
        }
        out
    }

    /// Render as CSV (headers + rows; naive quoting, fine for our data).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Print to stdout and also persist markdown+csv under `dir`.
    pub fn emit(&self, dir: &Path, stem: &str) {
        println!("{}", self.to_markdown());
        let _ = std::fs::write(dir.join(format!("{stem}.md")), self.to_markdown());
        let _ = std::fs::write(dir.join(format!("{stem}.csv")), self.to_csv());
    }
}

/// Write raw CSV text to `dir/stem.csv`.
pub fn write_csv(dir: &Path, stem: &str, csv: &str) {
    let _ = std::fs::write(dir.join(format!("{stem}.csv")), csv);
}
