//! PJRT runtime: load the AOT JAX/Pallas artifacts and expose them as
//! [`DualOracle`]s on the Rust request path.
//!
//! Python runs only at build time (`make artifacts`); at runtime this
//! module parses `artifacts/manifest.json`, loads the HLO **text** of
//! the matching shape (text, not serialized proto — xla_extension 0.5.1
//! rejects jax≥0.5 64-bit-id protos), compiles it once on the PJRT CPU
//! client and executes it per L-BFGS evaluation.

mod manifest;
mod oracle;

pub use manifest::{ArtifactEntry, Manifest};
pub use oracle::XlaDualOracle;

use crate::error::{Context, Result};

/// Thin wrapper over the PJRT CPU client; compile once, execute many.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    /// Platform string, e.g. "cpu" (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn compile_hlo_text_file(
        &self,
        path: &std::path::Path,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(exe)
    }

    #[doc(hidden)]
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}

/// Default artifact directory (next to the binary's working directory,
/// overridable via `GRPOT_ARTIFACT_DIR`).
pub fn artifact_dir() -> std::path::PathBuf {
    std::env::var("GRPOT_ARTIFACT_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
