//! Figure B (appendix): magnitude of the upper/lower bound errors over
//! iterations on MNIST→USPS with γ = 0.1, ρ = 0.8.
//!
//! Paper shape: the upper-bound error |z̄ − z| decays towards zero as
//! optimization converges (Theorem 3); the lower-bound error levels off
//! at the Theorem-4 residual.

mod common;

use common::*;
use grpot::benchlib::{report_dir, Table};
use grpot::data::digits;
use grpot::ot::fastot::{solve_fast_ot_traced, FastOtConfig};
use grpot::solvers::lbfgs::LbfgsOptions;

fn main() {
    banner("figB: bound errors vs iteration");
    let samples = size3(60, 300, 800);
    let pair = digits::mnist_to_usps(samples, 0xF16B);
    let prob = problem_of(&pair);
    let cfg = FastOtConfig {
        gamma: 0.1,
        rho: 0.8,
        lbfgs: LbfgsOptions { max_iters: size3(20, 120, 120), ..Default::default() },
        ..Default::default()
    };
    let (res, traces) = solve_fast_ot_traced(&prob, &cfg);
    println!("converged in {} iterations (dual {:.6})", res.iterations, res.dual_objective);

    let mut table = Table::new(
        "Fig. B — bound errors over iterations (MNIST→USPS, γ=0.1, ρ=0.8)",
        &["iteration", "mean |ub - z|", "mean |z - lb|"],
    );
    for t in &traces {
        table.row(vec![
            format!("{}", t.iteration),
            format!("{:.6e}", t.mean_upper_err),
            format!("{:.6e}", t.mean_lower_err),
        ]);
    }
    table.emit(&report_dir(), "figb_error_bounds");

    // Shape: late upper-bound error ≪ early upper-bound error. Skipped
    // on the tiny smoke run (too few iterations for the averages).
    let early: f64 = traces.iter().take(5).map(|t| t.mean_upper_err).sum::<f64>() / 5.0;
    let late: f64 = traces.iter().rev().take(5).map(|t| t.mean_upper_err).sum::<f64>() / 5.0;
    println!("upper-bound error: early={early:.3e} late={late:.3e}");
    if !grpot::benchlib::smoke_mode() && traces.len() >= 10 {
        assert!(
            late <= early,
            "upper bound must tighten as optimization converges"
        );
    }
}
