//! Process-wide metrics: named counters, timers, gauges and windowed
//! histograms with JSON snapshots. Shared across the sweep scheduler,
//! the serving engine and the TCP service (all atomic / mutex-protected;
//! cheap enough for per-request use).

use crate::benchlib::percentile_sorted;
use crate::jsonlite::Value;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Sliding-window size per histogram: percentiles are computed over the
/// most recent samples only, so a long-lived service reports current
/// tail latency, not its all-time history.
const HIST_WINDOW: usize = 4096;

/// Ring buffer of recent samples plus an all-time count.
#[derive(Clone, Debug, Default)]
struct Window {
    samples: Vec<f64>,
    next: usize,
    total: u64,
}

impl Window {
    fn record(&mut self, v: f64) {
        if self.samples.len() < HIST_WINDOW {
            self.samples.push(v);
        } else {
            self.samples[self.next] = v;
            self.next = (self.next + 1) % HIST_WINDOW;
        }
        self.total += 1;
    }

    /// Ascending copy of the window (one sort serves many percentiles).
    fn sorted(&self) -> Option<Vec<f64>> {
        if self.samples.is_empty() {
            return None;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(sorted)
    }

    fn percentile(&self, p: f64) -> Option<f64> {
        self.sorted().map(|s| percentile_sorted(&s, p))
    }
}

/// A registry of counters, timers, gauges and histograms.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, AtomicU64>>,
    /// Sum of seconds and sample count per timer name.
    timers: Mutex<BTreeMap<String, (f64, u64)>>,
    /// Last-write-wins instantaneous values (queue depth, cache bytes).
    gauges: Mutex<BTreeMap<String, f64>>,
    /// Recent-window sample distributions (latency percentiles).
    hists: Mutex<BTreeMap<String, Window>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a named counter.
    pub fn incr(&self, name: &str, by: u64) {
        let mut map = self.counters.lock().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(by, Ordering::Relaxed);
    }

    /// Read a counter (0 when unset).
    pub fn get(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Record a duration sample.
    pub fn observe(&self, name: &str, seconds: f64) {
        let mut map = self.timers.lock().unwrap();
        let e = map.entry(name.to_string()).or_insert((0.0, 0));
        e.0 += seconds;
        e.1 += 1;
    }

    /// Time a closure and record it.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.observe(name, t.elapsed().as_secs_f64());
        out
    }

    /// Mean seconds of a timer (None when unset).
    pub fn mean_seconds(&self, name: &str) -> Option<f64> {
        let map = self.timers.lock().unwrap();
        map.get(name).map(|(s, c)| s / (*c).max(1) as f64)
    }

    /// Set an instantaneous gauge value (last write wins).
    pub fn set_gauge(&self, name: &str, value: f64) {
        self.gauges.lock().unwrap().insert(name.to_string(), value);
    }

    /// Read a gauge (None when never set).
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.lock().unwrap().get(name).copied()
    }

    /// Record a sample into a windowed histogram (for percentiles).
    pub fn observe_hist(&self, name: &str, value: f64) {
        let mut map = self.hists.lock().unwrap();
        map.entry(name.to_string()).or_default().record(value);
    }

    /// Time a closure and record the duration into a histogram.
    pub fn time_hist<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.observe_hist(name, t.elapsed().as_secs_f64());
        out
    }

    /// Percentile (0–100) over a histogram's recent window.
    pub fn hist_percentile(&self, name: &str, p: f64) -> Option<f64> {
        self.hists.lock().unwrap().get(name).and_then(|w| w.percentile(p))
    }

    /// All-time sample count of a histogram.
    pub fn hist_count(&self, name: &str) -> u64 {
        self.hists.lock().unwrap().get(name).map(|w| w.total).unwrap_or(0)
    }

    /// JSON snapshot of every counter, timer, gauge and histogram
    /// (histograms report p50/p95/p99 over their recent window).
    pub fn snapshot(&self) -> Value {
        let mut counters = Value::obj();
        for (k, v) in self.counters.lock().unwrap().iter() {
            counters = counters.set(k, v.load(Ordering::Relaxed));
        }
        let mut timers = Value::obj();
        for (k, (s, c)) in self.timers.lock().unwrap().iter() {
            timers = timers.set(
                k,
                Value::obj().set("total_s", *s).set("count", *c).set(
                    "mean_s",
                    if *c > 0 { *s / *c as f64 } else { 0.0 },
                ),
            );
        }
        let mut gauges = Value::obj();
        for (k, v) in self.gauges.lock().unwrap().iter() {
            gauges = gauges.set(k, *v);
        }
        let mut hists = Value::obj();
        for (k, w) in self.hists.lock().unwrap().iter() {
            let mut h = Value::obj().set("count", w.total);
            if let Some(sorted) = w.sorted() {
                for (label, p) in [("p50", 50.0), ("p95", 95.0), ("p99", 99.0)] {
                    h = h.set(label, percentile_sorted(&sorted, p));
                }
            }
            hists = hists.set(k, h);
        }
        Value::obj()
            .set("counters", counters)
            .set("timers", timers)
            .set("gauges", gauges)
            .set("hists", hists)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("jobs", 1);
        m.incr("jobs", 2);
        assert_eq!(m.get("jobs"), 3);
        assert_eq!(m.get("missing"), 0);
    }

    #[test]
    fn timers_record_and_average() {
        let m = Metrics::new();
        m.observe("solve", 1.0);
        m.observe("solve", 3.0);
        assert_eq!(m.mean_seconds("solve"), Some(2.0));
        let out = m.time("quick", || 42);
        assert_eq!(out, 42);
        assert!(m.mean_seconds("quick").unwrap() >= 0.0);
    }

    #[test]
    fn snapshot_is_json() {
        let m = Metrics::new();
        m.incr("a", 5);
        m.observe("t", 0.5);
        let v = m.snapshot();
        assert_eq!(v.get_path(&["counters", "a"]).unwrap().as_usize(), Some(5));
        assert!(v.get_path(&["timers", "t", "mean_s"]).is_some());
    }

    #[test]
    fn gauges_last_write_wins() {
        let m = Metrics::new();
        assert_eq!(m.gauge("depth"), None);
        m.set_gauge("depth", 3.0);
        m.set_gauge("depth", 7.0);
        assert_eq!(m.gauge("depth"), Some(7.0));
    }

    #[test]
    fn hist_percentiles_over_window() {
        let m = Metrics::new();
        assert_eq!(m.hist_percentile("lat", 50.0), None);
        for i in 1..=100 {
            m.observe_hist("lat", i as f64);
        }
        assert_eq!(m.hist_count("lat"), 100);
        let p50 = m.hist_percentile("lat", 50.0).unwrap();
        let p99 = m.hist_percentile("lat", 99.0).unwrap();
        assert!((p50 - 50.5).abs() < 1.0, "p50={p50}");
        assert!(p99 > 98.0 && p99 <= 100.0, "p99={p99}");
        let out = m.time_hist("timed", || 5);
        assert_eq!(out, 5);
        assert_eq!(m.hist_count("timed"), 1);
    }

    #[test]
    fn hist_window_slides() {
        let m = Metrics::new();
        // Overfill the window with low values, then high ones: the
        // window must reflect recent samples.
        for _ in 0..HIST_WINDOW {
            m.observe_hist("w", 1.0);
        }
        for _ in 0..HIST_WINDOW {
            m.observe_hist("w", 100.0);
        }
        assert_eq!(m.hist_count("w"), 2 * HIST_WINDOW as u64);
        assert_eq!(m.hist_percentile("w", 50.0), Some(100.0));
    }

    #[test]
    fn snapshot_includes_gauges_and_hists() {
        let m = Metrics::new();
        m.set_gauge("g", 2.5);
        for i in 0..10 {
            m.observe_hist("h", i as f64);
        }
        let v = m.snapshot();
        assert_eq!(v.get_path(&["gauges", "g"]).unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get_path(&["hists", "h", "count"]).unwrap().as_usize(), Some(10));
        assert!(v.get_path(&["hists", "h", "p95"]).unwrap().as_f64().unwrap() > 8.0);
    }

    #[test]
    fn concurrent_increments() {
        let m = std::sync::Arc::new(Metrics::new());
        let pool = crate::pool::ThreadPool::new(4);
        for _ in 0..100 {
            let m2 = std::sync::Arc::clone(&m);
            pool.execute(move || m2.incr("hits", 1));
        }
        pool.join();
        assert_eq!(m.get("hits"), 100);
    }
}
