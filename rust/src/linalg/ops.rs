//! BLAS-1 style kernels and the grouped partial norms used throughout
//! the screening bounds (Eqs. 6–7 of the paper).

use super::Mat;

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // Four-lane unrolled accumulation: deterministic and fast enough for
    // the solver's O(m+n) vectors; the O(mn) hot loops live in ot::.
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut tail = 0.0;
    for i in chunks * 4..a.len() {
        tail += a[i] * b[i];
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `x *= alpha`.
#[inline]
pub fn scal(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Euclidean norm.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Squared Euclidean norm.
#[inline]
pub fn nrm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// Infinity norm.
#[inline]
pub fn nrm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
}

/// `a - b` into a fresh vector.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x - y).collect()
}

/// Norm of the positive part: `‖[x]₊‖₂`.
#[inline]
pub fn nrm2_pos(x: &[f64]) -> f64 {
    let mut s = 0.0;
    for &v in x {
        if v > 0.0 {
            s += v * v;
        }
    }
    s.sqrt()
}

/// Norm of the negative part: `‖[x]₋‖₂` (reported as a nonnegative number).
#[inline]
pub fn nrm2_neg(x: &[f64]) -> f64 {
    let mut s = 0.0;
    for &v in x {
        if v < 0.0 {
            s += v * v;
        }
    }
    s.sqrt()
}

/// Per-group Euclidean norms of `x` partitioned by `offsets`
/// (`offsets[l]..offsets[l+1]` is group `l`).
pub fn grouped_nrm2(x: &[f64], offsets: &[usize]) -> Vec<f64> {
    grouped_reduce(x, offsets, nrm2)
}

/// Per-group `‖[·]₊‖₂`.
pub fn grouped_nrm2_pos(x: &[f64], offsets: &[usize]) -> Vec<f64> {
    grouped_reduce(x, offsets, nrm2_pos)
}

/// Per-group `‖[·]₋‖₂`.
pub fn grouped_nrm2_neg(x: &[f64], offsets: &[usize]) -> Vec<f64> {
    grouped_reduce(x, offsets, nrm2_neg)
}

fn grouped_reduce(x: &[f64], offsets: &[usize], f: impl Fn(&[f64]) -> f64) -> Vec<f64> {
    assert!(!offsets.is_empty());
    assert_eq!(*offsets.last().unwrap(), x.len(), "offsets must cover x");
    offsets
        .windows(2)
        .map(|w| f(&x[w[0]..w[1]]))
        .collect()
}

/// Pairwise squared Euclidean cost matrix `c_{ij} = ‖xs_i − xt_j‖₂²`
/// between the rows of `xs` (m×d) and `xt` (n×d).
///
/// Uses the expansion `‖u−v‖² = ‖u‖² + ‖v‖² − 2⟨u,v⟩` with a clamp at 0
/// to absorb rounding.
pub fn sq_euclidean_cost(xs: &Mat, xt: &Mat) -> Mat {
    assert_eq!(xs.cols(), xt.cols(), "feature dims differ");
    let m = xs.rows();
    let n = xt.rows();
    let xs_sq: Vec<f64> = (0..m).map(|i| nrm2_sq(xs.row(i))).collect();
    let xt_sq: Vec<f64> = (0..n).map(|j| nrm2_sq(xt.row(j))).collect();
    let mut out = Mat::zeros(m, n);
    for i in 0..m {
        let xi = xs.row(i);
        let orow = out.row_mut(i);
        for j in 0..n {
            let v = xs_sq[i] + xt_sq[j] - 2.0 * dot(xi, xt.row(j));
            orow[j] = v.max(0.0);
        }
    }
    out
}

/// Normalize a cost matrix by its max element (common practice in OT
/// implementations, incl. POT and Blondel et al.'s reference code) so
/// that γ has a dataset-independent scale.
pub fn normalize_by_max(c: &mut Mat) -> f64 {
    let m = c.max_abs();
    if m > 0.0 {
        scal(1.0 / m, c.as_mut_slice());
    }
    m
}

/// log-sum-exp of a slice (stable).
pub fn logsumexp(x: &[f64]) -> f64 {
    let m = x.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !m.is_finite() {
        return m;
    }
    let s: f64 = x.iter().map(|&v| (v - m).exp()).sum();
    m + s.ln()
}
