//! Dataset registry: resolve a [`DatasetSpec`] into a generated
//! [`DomainPair`] (see `data/` for the generator semantics).

use super::config::DatasetSpec;
use crate::data::{digits, faces, objects, synthetic, DomainPair};
use crate::err;
use crate::error::Result;

/// Instantiate the dataset a spec describes.
pub fn build_pair(spec: &DatasetSpec) -> Result<DomainPair> {
    match spec.family.as_str() {
        "synthetic" => Ok(synthetic::controlled(spec.param1, spec.param2, spec.seed)),
        "digits" => {
            // param1: 0 = usps→mnist, 1 = mnist→usps; param2 = samples.
            match spec.param1 {
                0 => Ok(digits::usps_to_mnist(spec.param2, spec.seed)),
                1 => Ok(digits::mnist_to_usps(spec.param2, spec.seed)),
                other => Err(err!("digits task must be 0 or 1, got {other}")),
            }
        }
        "faces" => {
            let tasks = faces::all_tasks(spec.scale, spec.seed);
            tasks
                .into_iter()
                .nth(spec.param1)
                .ok_or_else(|| err!("faces task index must be 0–11, got {}", spec.param1))
        }
        "objects" => {
            let tasks = objects::all_tasks(spec.scale, spec.seed);
            tasks
                .into_iter()
                .nth(spec.param1)
                .ok_or_else(|| err!("objects task index must be 0–11, got {}", spec.param1))
        }
        other => Err(err!(
            "unknown dataset family '{other}' (synthetic|digits|faces|objects)"
        )),
    }
}

/// Human-readable description of what a spec resolves to.
pub fn describe(spec: &DatasetSpec) -> String {
    match spec.family.as_str() {
        "synthetic" => format!(
            "synthetic |L|={} g={} (m=n={})",
            spec.param1,
            spec.param2,
            spec.param1 * spec.param2
        ),
        "digits" => format!(
            "digits task {} ({} samples/domain)",
            if spec.param1 == 0 { "U→M" } else { "M→U" },
            spec.param2
        ),
        "faces" => format!("faces task #{} (scale {})", spec.param1, spec.scale),
        "objects" => format!("objects task #{} (scale {})", spec.param1, spec.scale),
        other => format!("unknown family {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_resolution() {
        let spec = DatasetSpec {
            family: "synthetic".into(),
            param1: 3,
            param2: 4,
            ..Default::default()
        };
        let pair = build_pair(&spec).unwrap();
        assert_eq!(pair.source.len(), 12);
        assert!(describe(&spec).contains("|L|=3"));
    }

    #[test]
    fn digits_tasks() {
        let mut spec = DatasetSpec {
            family: "digits".into(),
            param1: 0,
            param2: 30,
            ..Default::default()
        };
        assert_eq!(build_pair(&spec).unwrap().task_name(), "usps→mnist");
        spec.param1 = 1;
        assert_eq!(build_pair(&spec).unwrap().task_name(), "mnist→usps");
        spec.param1 = 9;
        assert!(build_pair(&spec).is_err());
    }

    #[test]
    fn faces_and_objects_by_index() {
        let spec = DatasetSpec {
            family: "objects".into(),
            param1: 11,
            scale: 0.1,
            ..Default::default()
        };
        let pair = build_pair(&spec).unwrap();
        assert_eq!(pair.source.num_classes(), 10);
        let bad = DatasetSpec { param1: 12, ..spec };
        assert!(build_pair(&bad).is_err());
    }

    #[test]
    fn unknown_family_rejected() {
        let spec = DatasetSpec { family: "nope".into(), ..Default::default() };
        assert!(build_pair(&spec).is_err());
    }
}
