//! Minimal JSON substrate (parse + serialize).
//!
//! Used for the coordinator's config files, the sweep report format, the
//! artifact manifest written by `python/compile/aot.py`, and the TCP
//! service's line-delimited wire protocol. Supports the full JSON value
//! model; numbers are `f64` (integers round-trip exactly up to 2^53,
//! which is far beyond anything in this repo).

mod parse;
mod value;

pub use parse::{parse, ParseError};
pub use value::Value;

#[cfg(test)]
mod tests;
