//! Zero-dependency observability subsystem: the `GRPOT_TRACE` knob,
//! per-thread span rings with a Chrome-trace exporter, per-solve
//! telemetry reports, and a Prometheus text-exposition renderer.
//!
//! Three pillars:
//!
//! * **Tracing** ([`span`], [`ring`]) — per-request trace IDs are minted
//!   at admission ([`next_trace_id`]) and threaded queue → batcher →
//!   engine worker → solve. Hierarchical spans land in per-thread
//!   seqlock ring buffers (fixed capacity, drop-oldest, no locks on the
//!   record path) and are drained on demand into Chrome
//!   trace-event-format JSON ([`span::drain_chrome_json`]), which opens
//!   directly in `chrome://tracing` / Perfetto.
//! * **Solver telemetry** ([`report`]) — a [`SolveReport`] assembled per
//!   solve via the `SolveOptions` observer hook: per-outer-round
//!   screening skip counts, the skipped-group fraction (the paper's
//!   headline quantity, Lemmas 1–3), working-set density trajectory,
//!   SIMD backend, L-BFGS evaluation counts and pool utilization.
//! * **Exporters** ([`prom`]) — Prometheus text exposition rendered from
//!   a [`crate::coordinator::metrics::Metrics`] snapshot (counters,
//!   gauges, timers, windowed summaries and fixed-bucket histograms).
//!
//! The knob: `GRPOT_TRACE=off|spans|full` (default `off`). The disabled
//! path is compile-out-cheap — one relaxed atomic load, no allocation,
//! no `Instant::now` — so it cannot perturb the bit-exact solver math
//! or its wall-time within noise. `spans` records the request-level
//! span taxonomy (queue wait, batch, solve); `full` additionally
//! records solver-internal spans (per solve and per outer round).

pub mod prom;
pub mod report;
pub mod ring;
pub mod span;

pub use report::{ObserverHook, PoolUtilization, RoundTelemetry, SolveReport};
pub use span::{names, next_trace_id, record_span_at, Span};

use crate::err;
use crate::error::GrpotError;
use std::sync::atomic::{AtomicU8, Ordering};

/// Tracing level. Ordered: `Off < Spans < Full`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceMode {
    /// No spans recorded; the record path is a single relaxed load.
    #[default]
    Off = 0,
    /// Request-level spans (queue wait, batch, engine solve).
    Spans = 1,
    /// Request-level plus solver-internal spans (solve, outer rounds).
    Full = 2,
}

impl TraceMode {
    /// Parse the `GRPOT_TRACE` value. Unknown values are an error (the
    /// CLI validates at launch and exits 2, mirroring `GRPOT_SIMD`).
    pub fn parse(s: &str) -> Result<TraceMode, GrpotError> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "0" | "" => Ok(TraceMode::Off),
            "spans" => Ok(TraceMode::Spans),
            "full" => Ok(TraceMode::Full),
            other => Err(err!(
                "unknown trace mode '{other}' (expected off|spans|full)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TraceMode::Off => "off",
            TraceMode::Spans => "spans",
            TraceMode::Full => "full",
        }
    }
}

/// Process-wide trace mode. Relaxed everywhere: the knob is a coarse
/// on/off switch, not a synchronization point.
static TRACE_MODE: AtomicU8 = AtomicU8::new(0);

/// Set once the mode has been chosen explicitly (CLI launch or a test's
/// [`set_trace_mode`]); [`latch_env_once`] then leaves the mode alone.
static MODE_EXPLICIT: std::sync::atomic::AtomicBool =
    std::sync::atomic::AtomicBool::new(false);

/// Current mode (one relaxed load).
#[inline]
pub fn trace_mode() -> TraceMode {
    match TRACE_MODE.load(Ordering::Relaxed) {
        1 => TraceMode::Spans,
        2 => TraceMode::Full,
        _ => TraceMode::Off,
    }
}

/// Whether any span recording is on. THE hot-path gate: callers must
/// branch on this before touching `Instant::now` or the rings.
#[inline]
pub fn enabled() -> bool {
    TRACE_MODE.load(Ordering::Relaxed) != 0
}

/// Whether solver-internal (`full`) spans are on.
#[inline]
pub fn full_enabled() -> bool {
    TRACE_MODE.load(Ordering::Relaxed) >= 2
}

/// Set the process-wide trace mode (tests and the CLI launcher). An
/// explicit set always wins over the [`latch_env_once`] fallback.
pub fn set_trace_mode(mode: TraceMode) {
    MODE_EXPLICIT.store(true, Ordering::Relaxed);
    TRACE_MODE.store(mode as u8, Ordering::Relaxed);
}

/// Read `GRPOT_TRACE`, validate it, and install the mode. Returns the
/// installed mode; a malformed value is an error the caller turns into
/// a launch failure (never a late per-request surprise).
pub fn init_from_env() -> Result<TraceMode, GrpotError> {
    let mode = match std::env::var("GRPOT_TRACE") {
        Ok(v) => TraceMode::parse(&v).map_err(|e| err!("GRPOT_TRACE: {e}"))?,
        Err(_) => TraceMode::Off,
    };
    set_trace_mode(mode);
    Ok(mode)
}

/// Once-only best-effort env latch for processes without a launch hook
/// (test binaries, benches, embedders): the *first* call installs a
/// valid `GRPOT_TRACE` value; later calls — and any explicit
/// [`set_trace_mode`] before or after — win over the env. A malformed
/// value is silently ignored here (the CLI's [`init_from_env`] is the
/// strict validator). Called from solver/engine cold entry points, so
/// `GRPOT_TRACE=full cargo test` actually traces.
pub fn latch_env_once() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        if MODE_EXPLICIT.load(Ordering::Relaxed) {
            return; // an explicit set_trace_mode already happened
        }
        if let Ok(v) = std::env::var("GRPOT_TRACE") {
            if let Ok(mode) = TraceMode::parse(&v) {
                TRACE_MODE.store(mode as u8, Ordering::Relaxed);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_known_modes() {
        assert_eq!(TraceMode::parse("off").unwrap(), TraceMode::Off);
        assert_eq!(TraceMode::parse("SPANS").unwrap(), TraceMode::Spans);
        assert_eq!(TraceMode::parse(" full ").unwrap(), TraceMode::Full);
        assert!(TraceMode::parse("verbose").is_err());
    }

    #[test]
    fn mode_ordering_gates_full() {
        assert!(TraceMode::Off < TraceMode::Spans);
        assert!(TraceMode::Spans < TraceMode::Full);
        assert_eq!(TraceMode::Full.name(), "full");
    }
}
