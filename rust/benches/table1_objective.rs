//! Table 1: maximum objective values after convergence among all
//! hyperparameter combinations, origin vs ours, on the synthetic
//! dataset — they must be IDENTICAL (Theorem 2).

mod common;

use common::*;
use grpot::benchlib::{report_dir, Table};
use grpot::coordinator::config::Method;
use grpot::coordinator::sweep::run_job;
use grpot::data::synthetic;

fn main() {
    banner("table1: max objective origin vs ours");
    let class_counts: Vec<usize> = size3(vec![4], vec![10, 20, 40], vec![10, 20, 40, 80, 160]);
    let gammas = gamma_grid();
    let rhos = rho_grid();
    let mi = max_iters();

    let mut table = Table::new(
        "Table 1 — max objective over all hyperparameters (synthetic)",
        &["classes", "origin", "ours", "identical"],
    );
    let g = size3(3, 10, 10);
    for &l in &class_counts {
        let pair = synthetic::controlled_classes(l, g, 0x7AB1);
        let prob = problem_of(&pair);
        let mut best_o = f64::NEG_INFINITY;
        let mut best_f = f64::NEG_INFINITY;
        let mut all_equal = true;
        for &gamma in &gammas {
            for &rho in &rhos {
                let o = run_job(&prob, Method::Origin, gamma, rho, 10, mi);
                let f = run_job(&prob, Method::Fast, gamma, rho, 10, mi);
                all_equal &= o.dual_objective == f.dual_objective;
                best_o = best_o.max(o.dual_objective);
                best_f = best_f.max(f.dual_objective);
            }
        }
        println!("classes={l}: origin={best_o:.6e} ours={best_f:.6e} identical={all_equal}");
        table.row(vec![
            format!("{l}"),
            format!("{best_o:.6e}"),
            format!("{best_f:.6e}"),
            format!("{all_equal}"),
        ]);
        assert_eq!(best_o, best_f, "Table 1 requires identical maxima");
        assert!(all_equal, "every grid point must match (Theorem 2)");
    }
    table.emit(&report_dir(), "table1_objective");
}
