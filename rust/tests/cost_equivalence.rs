//! Dense/factored cost-backend byte-equality, end to end: for one point
//! cloud, `CostMode::Dense` (the resident n×m matrix) and
//! `CostMode::Factored` (coordinates + squared norms, tiles synthesized
//! on demand) must return *byte-equal* solutions, objectives, iteration
//! counts and `OracleStats` — for the screened, dense and semi-dual
//! methods, cold and warm-started, under scalar and vector dispatch, at
//! 1 and 4 oracle threads. The one deliberately excluded counter is
//! `tiles_built`: it is how much cost synthesis each backend paid
//! (always 0 for dense, dispatch-dependent for factored), a throughput
//! diagnostic rather than solver output.
//!
//! The `GRPOT_COST=factored` CI shard re-runs this suite (plus the
//! theorem2 and parallel-determinism suites) with the env default
//! flipped; both sides of every comparison here force an explicit mode,
//! so the assertions stay genuine dense-vs-factored crosses under any
//! env.

use grpot::linalg::Mat;
use grpot::ot::cost::CostMode;
use grpot::ot::dual::{OracleStats, OtProblem};
use grpot::ot::fastot::{solve_fast_ot, solve_fast_ot_from, FastOtConfig, FastOtResult};
use grpot::ot::origin::{solve_origin, solve_origin_from};
use grpot::ot::semidual::solve_semidual_simd;
use grpot::rng::Pcg64;
use grpot::simd::SimdMode;
use grpot::solvers::lbfgs::LbfgsOptions;

/// One point cloud, two problem builds: byte-equal inputs, different
/// cost representations. `l` groups of `g` source points each, `n`
/// targets, dimension `d`.
fn point_problems(seed: u64, l: usize, g: usize, n: usize, d: usize) -> (OtProblem, OtProblem) {
    let mut rng = Pcg64::new(seed);
    let m = l * g;
    let xs = Mat::from_fn(m, d, |_, _| rng.uniform(-1.0, 1.0));
    let xt = Mat::from_fn(n, d, |_, _| rng.uniform(-1.0, 1.0));
    let labels: Vec<usize> = (0..m).map(|i| i / g).collect();
    let dense = OtProblem::try_from_points(&xs, &labels, &xt, CostMode::Dense).expect("dense");
    let fact = OtProblem::try_from_points(&xs, &labels, &xt, CostMode::Factored).expect("factored");
    assert!(!dense.is_factored() && fact.is_factored());
    (dense, fact)
}

/// Field-wise equality *except* `tiles_built` — the only stat allowed
/// to differ across backends (see module doc).
fn assert_stats_eq(a: &OracleStats, b: &OracleStats, what: &str) {
    assert_eq!(a.evals, b.evals, "{what}: evals");
    assert_eq!(a.grads_computed, b.grads_computed, "{what}: grads_computed");
    assert_eq!(a.grads_skipped, b.grads_skipped, "{what}: grads_skipped");
    assert_eq!(a.ub_checks, b.ub_checks, "{what}: ub_checks");
    assert_eq!(a.ws_hits, b.ws_hits, "{what}: ws_hits");
    assert_eq!(a.per_eval_grads, b.per_eval_grads, "{what}: per_eval_grads");
}

/// `dense` vs `factored` result: solver output must be byte-equal, and
/// the synthesis counter must prove which backend did the synthesizing.
fn assert_backends_identical(dense: &FastOtResult, fact: &FastOtResult, what: &str) {
    assert_eq!(dense.x, fact.x, "{what}: solution bytes");
    assert_eq!(dense.dual_objective, fact.dual_objective, "{what}: objective");
    assert_eq!(dense.iterations, fact.iterations, "{what}: iterations");
    assert_eq!(dense.outer_rounds, fact.outer_rounds, "{what}: outer rounds");
    assert_stats_eq(&dense.stats, &fact.stats, what);
    assert_eq!(dense.stats.tiles_built, 0, "{what}: dense never synthesizes");
    assert!(fact.stats.tiles_built > 0, "{what}: factored must synthesize");
}

fn cfg(gamma: f64, rho: f64, threads: usize, simd: SimdMode) -> FastOtConfig {
    FastOtConfig {
        gamma,
        rho,
        threads,
        simd,
        lbfgs: LbfgsOptions { max_iters: 120, ..Default::default() },
        ..Default::default()
    }
}

/// The acceptance-criterion test: dense vs factored are byte-equal for
/// `solve_fast_ot` and `solve_origin` across hyperparameters hitting
/// both the skip-heavy and the dense regime, under scalar and
/// runtime-dispatched vector kernels, at 1 and 4 threads, cold start.
#[test]
fn fast_and_origin_bit_identical_across_backends() {
    // n = 37: multiple fixed chunks, ragged panels, a short final chunk
    // (leftover columns exercise the factored per-segment fallback).
    let (dense, fact) = point_problems(0xC057, 5, 4, 37, 3);
    for (gamma, rho) in [(0.1, 0.3), (1.0, 0.5), (8.0, 0.8)] {
        for threads in [1usize, 4] {
            for simd in [SimdMode::Scalar, SimdMode::Auto] {
                let what = format!("γ={gamma} ρ={rho} threads={threads} simd={simd:?}");
                let fast_d = solve_fast_ot(&dense, &cfg(gamma, rho, threads, simd));
                let fast_f = solve_fast_ot(&fact, &cfg(gamma, rho, threads, simd));
                assert_backends_identical(&fast_d, &fast_f, &format!("fast {what}"));
                let orig_d = solve_origin(&dense, &cfg(gamma, rho, threads, simd));
                let orig_f = solve_origin(&fact, &cfg(gamma, rho, threads, simd));
                assert_backends_identical(&orig_d, &orig_f, &format!("origin {what}"));
                // Theorem 2 must keep holding across methods under
                // either backend.
                assert_eq!(fast_f.dual_objective, orig_f.dual_objective);
                assert_eq!(fast_f.x, orig_f.x);
            }
        }
    }
}

/// Warm starts compose with the backend: dense and factored solves
/// seeded at the same arbitrary iterate stay byte-equal (snapshots
/// start at the warm point, so the screened walk immediately exercises
/// the mixed-activity tile-synthesis lanes).
#[test]
fn warm_started_solves_bit_identical_across_backends() {
    let (dense, fact) = point_problems(0xC058, 4, 3, 33, 2);
    let mut rng = Pcg64::new(99);
    let x0: Vec<f64> = (0..dense.dim()).map(|_| rng.uniform(-0.2, 0.3)).collect();
    for threads in [1usize, 4] {
        for simd in [SimdMode::Scalar, SimdMode::Auto] {
            let what = format!("warm threads={threads} simd={simd:?}");
            let c = cfg(0.6, 0.55, threads, simd);
            let fast_d = solve_fast_ot_from(&dense, &c, x0.clone());
            let fast_f = solve_fast_ot_from(&fact, &c, x0.clone());
            assert_backends_identical(&fast_d, &fast_f, &format!("fast {what}"));
            let orig_d = solve_origin_from(&dense, &c, x0.clone());
            let orig_f = solve_origin_from(&fact, &c, x0.clone());
            assert_backends_identical(&orig_d, &orig_f, &format!("origin {what}"));
        }
    }
}

/// Semi-dual: the column staging reads whole cost columns, which the
/// factored backend synthesizes per chunk — alpha, objective,
/// iterations and the recovered plan must be byte-equal end to end.
#[test]
fn semidual_bit_identical_across_backends() {
    let (dense, fact) = point_problems(0xC059, 3, 4, 41, 3);
    let opts = LbfgsOptions { max_iters: 200, ..Default::default() };
    for threads in [1usize, 4] {
        for simd in [SimdMode::Scalar, SimdMode::Auto] {
            let d = solve_semidual_simd(&dense, 0.2, &opts, threads, simd);
            let f = solve_semidual_simd(&fact, 0.2, &opts, threads, simd);
            let what = format!("threads={threads} simd={simd:?}");
            assert_eq!(d.alpha, f.alpha, "{what}: alpha bytes");
            assert_eq!(d.objective, f.objective, "{what}: objective");
            assert_eq!(d.iterations, f.iterations, "{what}: iterations");
            assert_eq!(d.plan, f.plan, "{what}: plan");
        }
    }
}

/// The second acceptance criterion: screened-out groups never pay cost
/// synthesis. Under scalar dispatch the factored backend synthesizes
/// exactly one segment per *computed* group gradient
/// (`tiles_built == grads_computed` by construction of `scalar_pair`),
/// so a skip-heavy screened solve proves the claim arithmetically:
/// the skipped (group, column) pairs — a strictly positive count —
/// contributed zero synthesis.
#[test]
fn screened_groups_never_synthesize_tiles() {
    let (dense, fact) = point_problems(0xC05A, 5, 4, 37, 3);
    // (0.1, 0.3) is the skip-heavy regime (same grid as above).
    let c = cfg(0.1, 0.3, 1, SimdMode::Scalar);
    let fast_f = solve_fast_ot(&fact, &c);
    assert!(fast_f.stats.grads_skipped > 0, "config must exercise screening");
    assert_eq!(
        fast_f.stats.tiles_built, fast_f.stats.grads_computed,
        "scalar factored synthesis is one segment per computed gradient"
    );
    // The unscreened baseline synthesizes for every pair it touches too
    // — and touches strictly more of them per eval.
    let orig_f = solve_origin(&fact, &c);
    assert_eq!(orig_f.stats.grads_skipped, 0);
    assert_eq!(orig_f.stats.tiles_built, orig_f.stats.grads_computed);
    // Dense never synthesizes, whatever the method.
    assert_eq!(solve_fast_ot(&dense, &c).stats.tiles_built, 0);
    assert_eq!(solve_origin(&dense, &c).stats.tiles_built, 0);
    // Vector dispatch amortizes synthesis across the tile ring: strictly
    // positive, never more than one build per computed gradient.
    let fast_v = solve_fast_ot(&fact, &cfg(0.1, 0.3, 1, SimdMode::Auto));
    assert!(fast_v.stats.tiles_built > 0);
    assert!(fast_v.stats.tiles_built <= fast_v.stats.grads_computed);
}

/// `try_from_points` rejects malformed inputs with structured errors
/// instead of panicking deep inside the solver.
#[test]
fn try_from_points_validates_inputs() {
    let xs = Mat::from_fn(4, 2, |i, c| (i * 2 + c) as f64);
    let xt = Mat::from_fn(3, 2, |i, c| (i + c) as f64);
    let labels = vec![0, 0, 1, 1];
    let fail = |xs: &Mat, lb: &[usize], xt: &Mat, frag: &str| {
        for mode in [CostMode::Dense, CostMode::Factored] {
            let err = match OtProblem::try_from_points(xs, lb, xt, mode) {
                Ok(_) => panic!("{frag}: must fail under {mode:?}"),
                Err(e) => e.to_string(),
            };
            assert!(err.contains(frag), "{mode:?}: {err:?} should mention {frag:?}");
        }
    };
    fail(&Mat::from_fn(0, 2, |_, _| 0.0), &[], &xt, "empty point set");
    fail(&xs, &labels, &Mat::from_fn(0, 2, |_, _| 0.0), "empty point set");
    fail(&Mat::from_fn(4, 0, |_, _| 0.0), &labels, &Mat::from_fn(3, 0, |_, _| 0.0), "dimension");
    fail(&xs, &labels, &Mat::from_fn(3, 5, |_, _| 0.0), "dimension mismatch");
    fail(&xs, &[0, 1], &xt, "labels");
    fail(&Mat::from_fn(4, 2, |_, _| f64::NAN), &labels, &xt, "non-finite");
    fail(&xs, &labels, &Mat::from_fn(3, 2, |_, _| f64::INFINITY), "non-finite");
    // And the happy path reports its backend + memory footprint: the
    // factored build must be resident-smaller than the dense matrix
    // even at toy sizes (4·3 entries vs (4+3)·(2+1) scalars).
    let d = OtProblem::try_from_points(&xs, &labels, &xt, CostMode::Dense).expect("dense");
    let f = OtProblem::try_from_points(&xs, &labels, &xt, CostMode::Factored).expect("factored");
    assert_eq!(d.cost_mode_name(), "dense");
    assert_eq!(f.cost_mode_name(), "factored");
    assert!(f.cost_bytes() < d.cost_bytes(), "{} !< {}", f.cost_bytes(), d.cost_bytes());
}
