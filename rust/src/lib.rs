//! # grpot — Fast Regularized Discrete Optimal Transport with Group-Sparse Regularizers
//!
//! A three-layer Rust + JAX + Pallas reproduction of
//! *Ida, Kanai, Adachi, Kumagai, Fujiwara — "Fast Regularized Discrete
//! Optimal Transport with Group-Sparse Regularizers", AAAI 2023*.
//!
//! The library solves the smooth relaxed dual of group-sparse regularized
//! discrete OT (Blondel, Seguy & Rolet 2018) with the paper's safe
//! screening accelerations:
//!
//! * **Upper bound screening** (Lemma 1–3): gradient groups whose
//!   soft-threshold norm is provably below the threshold are skipped.
//! * **Working set** (Lemma 4–6): groups provably *non*-zero bypass the
//!   upper-bound check entirely, removing its overhead.
//!
//! Both are exact (Theorem 2): the screened solver follows the same
//! optimization trajectory as the dense baseline.
//!
//! ## Layout
//!
//! * [`linalg`], [`rng`], [`jsonlite`], [`cli`], [`pool`], [`benchlib`],
//!   [`testing`], [`error`] — self-contained substrates (this image has
//!   no network access; the default build depends on no external crate).
//! * [`groups`], [`data`] — group structure and the four dataset
//!   families used in the paper's evaluation.
//! * [`ot`] — the OT core: dual oracle, dense baseline, screening, the
//!   Algorithm-1 driver, plan recovery, entropic/EMD baselines. The
//!   [`ot::regularizer`] module makes the conjugate pair Ω*/∇Ω* a
//!   pluggable trait (group lasso, squared ℓ2, negative entropy) and
//!   [`ot::solve::SolveOptions`] is the one builder every solver entry
//!   point consumes.
//! * [`simd`] — runtime-dispatched SIMD column-lane oracle kernels
//!   (AVX2 + portable mirror), bit-identical to the scalar kernels;
//!   `GRPOT_SIMD={auto,scalar,portable}` / `FastOtConfig.simd` select
//!   the path.
//! * [`solvers`] — L-BFGS (two-loop recursion + strong-Wolfe line
//!   search) and first-order solvers.
//! * `runtime` — PJRT loader for the AOT JAX/Pallas artifacts; gated
//!   behind the off-by-default `xla` cargo feature (the bindings crate
//!   cannot be fetched in this offline image).
//! * [`serve`] — the serving engine: admission-controlled request queue
//!   with deadlines and backpressure, micro-batching, a warm-start dual
//!   cache, and a closed-loop load generator.
//! * [`fault`] — fault tolerance: cooperative [`fault::CancelToken`]s
//!   polled by the solver drivers (deadlines abort mid-solve with a
//!   structured error) and a deterministic failpoint registry
//!   (`GRPOT_FAULTS=site:action:every-N`, off = one relaxed load).
//! * [`obs`] — observability: per-request trace IDs and span rings with
//!   a Chrome-trace exporter (`GRPOT_TRACE={off,spans,full}`), per-solve
//!   [`obs::SolveReport`] telemetry via the `SolveOptions` observer
//!   hook, and a Prometheus text-exposition renderer.
//! * [`coordinator`] — the L3 system: config, hyperparameter sweep
//!   scheduler, metrics, TCP service (wired on top of [`serve`]).
//! * [`eval`] — domain-adaptation evaluation (1-NN transfer accuracy).
//!
//! ## Quickstart
//!
//! ```
//! use grpot::prelude::*;
//!
//! // Two tiny class-clustered domains.
//! let ds = grpot::data::synthetic::controlled_classes(4, 5, 0xC0FFEE);
//! let prob = OtProblem::from_dataset(&ds);
//! let cfg = FastOtConfig { gamma: 1.0, rho: 0.5, ..Default::default() };
//! let fast = solve_fast_ot(&prob, &cfg);
//! let origin = solve_origin(&prob, &cfg);
//! assert!((fast.dual_objective - origin.dual_objective).abs() < 1e-9);
//! ```

// Numeric-kernel style: index loops mirror the paper's subscripts, and
// the inner oracle kernel needs every operand spelled out.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod benchlib;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod eval;
pub mod fault;
pub mod groups;
pub mod jsonlite;
pub mod linalg;
pub mod obs;
pub mod ot;
pub mod pool;
pub mod rng;
pub mod simd;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod serve;
pub mod solvers;
pub mod testing;

/// Convenience re-exports for downstream users and examples.
pub mod prelude {
    pub use crate::data::{cost::CostMatrix, Dataset, DomainPair};
    pub use crate::groups::GroupStructure;
    pub use crate::linalg::Mat;
    pub use crate::ot::dual::{DualOracle, DualParams, OtProblem};
    pub use crate::ot::fastot::{solve_fast_ot, FastOtConfig, FastOtResult};
    pub use crate::ot::origin::solve_origin;
    pub use crate::ot::plan::TransportPlan;
    pub use crate::ot::regularizer::{RegKind, Regularizer};
    pub use crate::ot::solve::SolveOptions;
    pub use crate::rng::Pcg64;
    pub use crate::solvers::lbfgs::{Lbfgs, LbfgsOptions};
}
