//! Cost-matrix helpers shared by examples, benches and the coordinator.

use super::DomainPair;
use crate::linalg::{self, Mat};

/// A cost matrix together with its normalization factor.
#[derive(Clone, Debug)]
pub struct CostMatrix {
    /// `m × n`, max-normalized to `[0, 1]`.
    pub c: Mat,
    /// The max value divided out (multiply back for raw costs).
    pub scale: f64,
}

impl CostMatrix {
    /// Squared-Euclidean cost between the two domains, max-normalized
    /// (the paper's setting: `c_ij = ‖x_S_i − x_T_j‖₂²`).
    pub fn squared_euclidean(pair: &DomainPair) -> CostMatrix {
        let mut c = linalg::sq_euclidean_cost(&pair.source.x, &pair.target.x);
        let scale = linalg::normalize_by_max(&mut c);
        CostMatrix { c, scale }
    }

    /// Raw (unnormalized) transport cost for a given plan value.
    pub fn denormalize(&self, normalized_cost: f64) -> f64 {
        normalized_cost * self.scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn normalized_to_unit_interval() {
        let pair = synthetic::controlled(3, 4, 5);
        let cm = CostMatrix::squared_euclidean(&pair);
        assert_eq!(cm.c.shape(), (12, 12));
        assert!(cm.scale > 0.0);
        assert!(cm.c.max_abs() <= 1.0 + 1e-12);
        assert!(cm.c.as_slice().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn denormalize_roundtrips() {
        let pair = synthetic::controlled(2, 3, 9);
        let cm = CostMatrix::squared_euclidean(&pair);
        let raw = linalg::sq_euclidean_cost(&pair.source.x, &pair.target.x);
        let got = cm.denormalize(cm.c[(0, 0)]);
        assert!((got - raw[(0, 0)]).abs() < 1e-9);
    }
}
