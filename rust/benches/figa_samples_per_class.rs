//! Figure A (appendix): gain vs samples-per-class g (|L| = 10 fixed,
//! n = m = 10·g). Paper shape: gain grows with g (up to 6.5×) because
//! the checking cost is O(|L|(n+g)) vs the baseline's O(|L|·n·g).

mod common;

use common::*;
use grpot::data::synthetic;

fn main() {
    banner("figA: gain vs samples/class");
    let gs: Vec<usize> = size3(vec![4], vec![10, 20, 40], vec![10, 20, 40, 80, 160]);
    let gammas = gamma_grid();
    let rhos = rho_grid();

    let mut blocks = Vec::new();
    for &g in &gs {
        let pair = synthetic::controlled_samples_per_class(g, 0xF16A);
        let prob = problem_of(&pair);
        println!("g={g} (m=n={}) …", prob.m());
        let rows = gain_sweep(&prob, &gammas, &rhos, 10);
        for r in &rows {
            println!("  gamma={:<8} gain={:.2}x", r.gamma, r.gain);
            assert!(r.objectives_match, "Theorem 2 violated at g={g}");
        }
        blocks.push((format!("g={g}"), rows));
    }
    emit_gain_table(
        "Fig. A — processing-time gain vs samples per class (synthetic, |L|=10)",
        "figa_samples_per_class",
        &blocks,
    );
}
