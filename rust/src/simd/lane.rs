//! The 4-lane `f64` vector abstraction behind the oracle kernels.
//!
//! Two implementations with **identical lane semantics**:
//!
//! * [`Portable4`] — plain `[f64; 4]` arithmetic, compiles everywhere;
//! * [`Avx2`] — `std::arch::x86_64` intrinsics (x86-64 only), reachable
//!   exclusively through `Dispatch::Avx2`, which is constructed only
//!   after `is_x86_feature_detected!("avx2")`.
//!
//! Per-lane `add`/`sub`/`mul` are IEEE-754 binary operations — the AVX2
//! `vaddpd`/`vsubpd`/`vmulpd` lanes round exactly like scalar `f64`
//! ops, so both backends are bit-identical to scalar arithmetic by the
//! IEEE standard, not by luck. `max`/`min` follow the x86
//! `MAXPD`/`MINPD` tie rules (ties and NaNs return the **second**
//! operand), and [`Portable4`] mirrors those rules exactly — with a
//! `+0.0` second operand this reproduces the scalar kernels'
//! `if f > 0.0 { f } else { 0.0 }` branch bit-for-bit, including the
//! `f == -0.0` case (both produce `+0.0`).
//!
//! All methods are `#[inline(always)]` so the generic kernels in
//! [`super::kernel`] collapse into the single `#[target_feature]` entry
//! function and are code-generated with AVX2 enabled there.

/// 4 × `f64` lane vector. See the module docs for the semantics
/// contract both implementations satisfy.
pub(crate) trait Lanes: Copy {
    fn splat(v: f64) -> Self;
    fn from_array(a: [f64; 4]) -> Self;
    /// Load the first 4 elements of `s` (unit stride, may be unaligned).
    fn load(s: &[f64]) -> Self;
    /// Store into the first 4 elements of `out`.
    fn store(self, out: &mut [f64]);
    fn to_array(self) -> [f64; 4];
    fn add(self, o: Self) -> Self;
    fn sub(self, o: Self) -> Self;
    fn mul(self, o: Self) -> Self;
    /// Lane-wise `MAXPD`: `if a > b { a } else { b }` (ties → `b`).
    fn max(self, o: Self) -> Self;
    /// Lane-wise `MINPD`: `if a < b { a } else { b }` (ties → `b`).
    fn min(self, o: Self) -> Self;
}

/// Portable scalar mirror: a `[f64; 4]` with x86 min/max tie semantics.
#[derive(Clone, Copy)]
pub(crate) struct Portable4([f64; 4]);

impl Lanes for Portable4 {
    #[inline(always)]
    fn splat(v: f64) -> Self {
        Portable4([v; 4])
    }

    #[inline(always)]
    fn from_array(a: [f64; 4]) -> Self {
        Portable4(a)
    }

    #[inline(always)]
    fn load(s: &[f64]) -> Self {
        Portable4([s[0], s[1], s[2], s[3]])
    }

    #[inline(always)]
    fn store(self, out: &mut [f64]) {
        out[..4].copy_from_slice(&self.0);
    }

    #[inline(always)]
    fn to_array(self) -> [f64; 4] {
        self.0
    }

    #[inline(always)]
    fn add(self, o: Self) -> Self {
        Portable4(std::array::from_fn(|t| self.0[t] + o.0[t]))
    }

    #[inline(always)]
    fn sub(self, o: Self) -> Self {
        Portable4(std::array::from_fn(|t| self.0[t] - o.0[t]))
    }

    #[inline(always)]
    fn mul(self, o: Self) -> Self {
        Portable4(std::array::from_fn(|t| self.0[t] * o.0[t]))
    }

    #[inline(always)]
    fn max(self, o: Self) -> Self {
        // MAXPD: DEST = SRC1 > SRC2 ? SRC1 : SRC2 (ties/NaN → SRC2).
        Portable4(std::array::from_fn(|t| if self.0[t] > o.0[t] { self.0[t] } else { o.0[t] }))
    }

    #[inline(always)]
    fn min(self, o: Self) -> Self {
        // MINPD: DEST = SRC1 < SRC2 ? SRC1 : SRC2 (ties/NaN → SRC2).
        Portable4(std::array::from_fn(|t| if self.0[t] < o.0[t] { self.0[t] } else { o.0[t] }))
    }
}

#[cfg(target_arch = "x86_64")]
pub(crate) use avx2::Avx2;

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::Lanes;
    use std::arch::x86_64::{
        __m256d, _mm256_add_pd, _mm256_loadu_pd, _mm256_max_pd, _mm256_min_pd, _mm256_mul_pd,
        _mm256_set1_pd, _mm256_storeu_pd, _mm256_sub_pd,
    };

    /// AVX2-backed lane vector.
    ///
    /// SAFETY contract for every method: a value of this type is only
    /// ever constructed inside the `#[target_feature(enable = "avx2")]`
    /// kernel entries of [`crate::simd::kernel`], which are themselves
    /// only called through `Dispatch::Avx2` — a variant produced
    /// exclusively after `is_x86_feature_detected!("avx2")` succeeded.
    /// The intrinsics below therefore never execute on a CPU that lacks
    /// the instructions. Loads/stores use the unaligned forms and the
    /// callers pass slices of at least 4 elements (debug-asserted), so
    /// no pointer arithmetic can leave the allocation.
    #[derive(Clone, Copy)]
    pub(crate) struct Avx2(__m256d);

    impl Lanes for Avx2 {
        #[inline(always)]
        fn splat(v: f64) -> Self {
            Avx2(unsafe { _mm256_set1_pd(v) })
        }

        #[inline(always)]
        fn from_array(a: [f64; 4]) -> Self {
            Avx2(unsafe { _mm256_loadu_pd(a.as_ptr()) })
        }

        #[inline(always)]
        fn load(s: &[f64]) -> Self {
            debug_assert!(s.len() >= 4);
            Avx2(unsafe { _mm256_loadu_pd(s.as_ptr()) })
        }

        #[inline(always)]
        fn store(self, out: &mut [f64]) {
            debug_assert!(out.len() >= 4);
            unsafe { _mm256_storeu_pd(out.as_mut_ptr(), self.0) }
        }

        #[inline(always)]
        fn to_array(self) -> [f64; 4] {
            let mut out = [0.0; 4];
            unsafe { _mm256_storeu_pd(out.as_mut_ptr(), self.0) }
            out
        }

        #[inline(always)]
        fn add(self, o: Self) -> Self {
            Avx2(unsafe { _mm256_add_pd(self.0, o.0) })
        }

        #[inline(always)]
        fn sub(self, o: Self) -> Self {
            Avx2(unsafe { _mm256_sub_pd(self.0, o.0) })
        }

        #[inline(always)]
        fn mul(self, o: Self) -> Self {
            Avx2(unsafe { _mm256_mul_pd(self.0, o.0) })
        }

        #[inline(always)]
        fn max(self, o: Self) -> Self {
            Avx2(unsafe { _mm256_max_pd(self.0, o.0) })
        }

        #[inline(always)]
        fn min(self, o: Self) -> Self {
            Avx2(unsafe { _mm256_min_pd(self.0, o.0) })
        }
    }
}
