//! Admission control: tickets, deadlines and the one-shot response
//! slot connecting a blocked submitter to the worker that eventually
//! answers it.
//!
//! The queue itself is [`crate::pool::BoundedQueue`]; this module adds
//! the serving semantics on top: a [`Ticket`] carries the request, its
//! submission time, an absolute deadline and the [`ResponseSlot`] the
//! submitter parks on. Backpressure is non-blocking by construction —
//! a full queue rejects at submit time rather than slowing intake.

use super::engine::{EngineReply, RejectReason, SolveRequest};
use crate::pool::BoundedQueue;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// What a submitter eventually receives.
pub type EngineResult = Result<EngineReply, RejectReason>;

/// The engine's admission queue.
pub type AdmissionQueue = BoundedQueue<Ticket>;

/// One-shot rendezvous: the submitter blocks in [`ResponseSlot::wait`]
/// until a worker calls [`ResponseSlot::put`].
pub struct ResponseSlot<T> {
    state: Mutex<Option<T>>,
    cvar: Condvar,
}

impl<T> Default for ResponseSlot<T> {
    fn default() -> Self {
        ResponseSlot { state: Mutex::new(None), cvar: Condvar::new() }
    }
}

impl<T> ResponseSlot<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Deliver the response (first write wins) and wake the waiter.
    pub fn put(&self, value: T) {
        let mut st = self.state.lock().unwrap();
        if st.is_none() {
            *st = Some(value);
        }
        drop(st);
        self.cvar.notify_all();
    }

    /// Block until a response arrives, then take it.
    pub fn wait(&self) -> T {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(v) = st.take() {
                return v;
            }
            st = self.cvar.wait(st).unwrap();
        }
    }

    /// Non-blocking take (tests / diagnostics).
    pub fn try_take(&self) -> Option<T> {
        self.state.lock().unwrap().take()
    }
}

/// A queued solve request: the work item flowing from `submit` through
/// the micro-batcher to a worker.
pub struct Ticket {
    pub request: SolveRequest,
    /// Precomputed [`crate::coordinator::config::DatasetSpec::cache_key`]
    /// — the batcher's coalescing key.
    pub dataset_key: String,
    /// Request trace ID, minted at admission and threaded through the
    /// batch → worker → solve chain into the reply (and, when tracing
    /// is on, stamped on every span this request produces).
    pub trace_id: u64,
    pub submitted: Instant,
    /// Absolute deadline (request-level, falling back to the engine
    /// default). `None` = may wait indefinitely.
    pub deadline: Option<Instant>,
    slot: Arc<ResponseSlot<EngineResult>>,
}

impl Ticket {
    /// Build a ticket and the slot handle its submitter parks on.
    pub fn new(
        request: SolveRequest,
        default_deadline: Option<Duration>,
    ) -> (Ticket, Arc<ResponseSlot<EngineResult>>) {
        let submitted = Instant::now();
        let slot = Arc::new(ResponseSlot::new());
        let deadline = request
            .deadline
            .or(default_deadline)
            .map(|d| submitted + d);
        let ticket = Ticket {
            dataset_key: request.spec.cache_key(),
            trace_id: crate::obs::next_trace_id(),
            request,
            submitted,
            deadline,
            slot: Arc::clone(&slot),
        };
        (ticket, slot)
    }

    /// Has the deadline passed as of `now`?
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }

    /// Seconds spent since submission.
    pub fn waited_s(&self, now: Instant) -> f64 {
        now.saturating_duration_since(self.submitted).as_secs_f64()
    }

    /// Answer the submitter.
    pub fn respond(&self, result: EngineResult) {
        self.slot.put(result);
    }
}

impl Drop for Ticket {
    /// Never-hang backstop: a ticket dropped without a response (a
    /// panicking code path between pop and respond) answers its
    /// submitter with a structured failure. First-write-wins on the
    /// slot makes this a no-op for every normally answered ticket.
    fn drop(&mut self) {
        self.slot.put(Err(RejectReason::Failed(crate::err!(
            "ticket dropped without a response (internal fault)"
        ))));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::{DatasetSpec, Method};

    fn request(deadline: Option<Duration>) -> SolveRequest {
        SolveRequest {
            spec: DatasetSpec::default(),
            gamma: 1.0,
            rho: 0.5,
            method: Method::Fast,
            regularizer: crate::ot::regularizer::RegKind::GroupLasso,
            deadline,
            warm_start: true,
        }
    }

    #[test]
    fn slot_roundtrip_across_threads() {
        let slot = Arc::new(ResponseSlot::<u32>::new());
        let s2 = Arc::clone(&slot);
        let h = std::thread::spawn(move || s2.wait());
        std::thread::sleep(Duration::from_millis(5));
        slot.put(99);
        assert_eq!(h.join().unwrap(), 99);
        // First write wins.
        slot.put(1);
        slot.put(2);
        assert_eq!(slot.try_take(), Some(1));
        assert_eq!(slot.try_take(), None);
    }

    #[test]
    fn ticket_deadline_resolution() {
        // Request deadline wins over the engine default.
        let (t, _slot) = Ticket::new(request(Some(Duration::ZERO)), Some(Duration::from_secs(60)));
        assert!(t.expired(Instant::now()));
        // Engine default applies when the request has none.
        let (t, _slot) = Ticket::new(request(None), Some(Duration::from_secs(60)));
        assert!(!t.expired(Instant::now()));
        // No deadline anywhere: never expires.
        let (t, _slot) = Ticket::new(request(None), None);
        assert!(!t.expired(Instant::now() + Duration::from_secs(3600)));
        assert!(t.waited_s(Instant::now()) >= 0.0);
    }

    #[test]
    fn ticket_precomputes_dataset_key() {
        let (t, _slot) = Ticket::new(request(None), None);
        assert_eq!(t.dataset_key, DatasetSpec::default().cache_key());
    }

    #[test]
    fn dropped_ticket_answers_its_submitter() {
        let (t, slot) = Ticket::new(request(None), None);
        drop(t);
        match slot.try_take() {
            Some(Err(RejectReason::Failed(e))) => {
                assert!(e.to_string().contains("dropped"), "{e}");
            }
            other => panic!("expected Failed backstop, got some={}", other.is_some()),
        }
        // An answered ticket's drop is a no-op (first write wins).
        let (t, slot) = Ticket::new(request(None), None);
        t.respond(Err(RejectReason::Shutdown));
        drop(t);
        assert!(matches!(slot.try_take(), Some(Err(RejectReason::Shutdown))));
    }

    #[test]
    fn tickets_get_unique_trace_ids() {
        let (a, _s1) = Ticket::new(request(None), None);
        let (b, _s2) = Ticket::new(request(None), None);
        assert_ne!(a.trace_id, 0);
        assert_ne!(a.trace_id, b.trace_id);
    }
}
