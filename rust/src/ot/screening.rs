//! Safe screening for the group-sparse OT dual — the paper's
//! contribution.
//!
//! Two devices accelerate the `O(|L|·n·g)` gradient evaluation:
//!
//! 1. **Upper bound** (Definition 1, Lemma 1–3). With snapshots
//!    `(α̃, β̃, Z̃)` taken every `r` solver iterations,
//!    `z̄_{l,j} = z̃_{l,j} + ‖[Δα_[l]]₊‖₂ + √g_l·[Δβ_j]₊ ≥ z_{l,j}`,
//!    so `z̄_{l,j} ≤ τ` proves `∇ψ(·)_[l] = 0` and the `O(g)` group
//!    computation is skipped — at `O(1)` marginal cost per pair once the
//!    `O(m+n)` per-eval Δ-norms are in place.
//! 2. **Lower bound / working set ℕ** (Definitions 2–3, Lemma 4–6).
//!    `z̲_{l,j} ≤ z_{l,j}`, so `z̲_{l,j} > τ` proves the group is
//!    *non*-zero; such pairs enter ℕ and bypass the upper-bound check,
//!    removing its overhead where it cannot help.
//!
//! Both devices are *safe*: every non-skipped pair is computed by the
//! exact same kernel as the dense baseline
//! ([`crate::ot::dual::group_grad_contrib`]), so the optimization
//! trajectory is identical (Theorem 2).
//!
//! The bound arithmetic itself lives in the
//! [`crate::ot::regularizer::ScreeningRule`] implementation
//! [`GroupLassoRule`] — the paper's Eq. 6/7 as one instance of the
//! generic screening interface. The rule is a statically dispatched
//! field, so the screened walk compiles to the same code as the
//! pre-trait inlined expressions and every decision stays byte-equal.

use super::cost::CostMatrix;
use super::dual::{
    exact_z, group_grad_contrib, panel_count, panel_ranges, quad_pair, reduce_chunks, scalar_pair,
    synth_quad_pair, ColChunkScratch, DualOracle, DualParams, KernelConsts, OracleStats, OtProblem,
    SimdEngine, PANEL_COLS,
};
use super::regularizer::{GroupLassoRule, ScreeningRule};
use super::solve::SolveOptions;
use crate::linalg;
use crate::pool::{fixed_chunk_ranges, ParallelCtx};
use crate::simd::{snapshot_quad, Dispatch, SimdMode, LANES};
use std::ops::Range;

/// Split a column-major buffer (`width` values per column) into one
/// mutable slice per column chunk — the disjoint views the parallel
/// snapshot/working-set passes write through.
fn split_cols<'s, T>(buf: &'s mut [T], ranges: &[Range<usize>], width: usize) -> Vec<&'s mut [T]> {
    split_lens(buf, ranges.iter().map(|r| r.len() * width))
}

/// Split a buffer into consecutive mutable slices of the given lengths
/// (the per-chunk panel-max views have chunk-dependent lengths).
fn split_lens<T>(buf: &mut [T], lens: impl IntoIterator<Item = usize>) -> Vec<&mut [T]> {
    let mut parts = Vec::new();
    let mut rest = buf;
    for len in lens {
        let (head, tail) = rest.split_at_mut(len);
        parts.push(head);
        rest = tail;
    }
    parts
}

/// One column's snapshot norms (z̃ and, with the working set, k̃/õ) —
/// the scalar reference loop of `recompute_snapshots`; the vector path
/// runs it on the columns left over after the full quads.
#[inline]
#[allow(clippy::too_many_arguments)]
fn snapshot_col_scalar(
    prob: &OtProblem,
    snap_alpha: &[f64],
    beta_j: f64,
    c_j: &[f64],
    use_ws: bool,
    base: usize,
    z: &mut [f64],
    k: &mut [f64],
    o: &mut [f64],
) {
    for l in 0..prob.groups.num_groups() {
        let mut zsq = 0.0;
        let mut ksq = 0.0;
        let mut osq = 0.0;
        for i in prob.groups.range(l) {
            let f = snap_alpha[i] + beta_j - c_j[i];
            ksq += f * f;
            if f > 0.0 {
                zsq += f * f;
            } else {
                osq += f * f;
            }
        }
        z[base + l] = zsq.sqrt();
        if use_ws {
            k[base + l] = ksq.sqrt();
            o[base + l] = osq.sqrt();
        }
    }
}

/// Screening-specific counters are kept in [`OracleStats`]; this struct
/// adds the Fig.-B diagnostic output.
#[derive(Clone, Debug, Default)]
pub struct BoundErrors {
    /// Mean `|z̄ − z|` over all (l, j).
    pub mean_upper: f64,
    /// Max `|z̄ − z|`.
    pub max_upper: f64,
    /// Mean `|z − z̲|` (working-set construction error).
    pub mean_lower: f64,
    /// Max `|z − z̲|`.
    pub max_lower: f64,
}

/// The screened negated-dual oracle (Algorithm 2).
pub struct ScreeningOracle<'a> {
    prob: &'a OtProblem,
    params: DualParams,
    /// Precomputed (γ, ρ)-derived kernel constants (τ, τ², 1/λ, …).
    consts: KernelConsts,
    /// The paper's Eq. 6/7 bounds as a [`ScreeningRule`] — the safe-skip
    /// arithmetic this oracle consults (statically dispatched, inlined:
    /// the expressions are byte-identical to the pre-trait inlined
    /// forms). The oracle itself remains group-lasso-specific (its
    /// snapshot norms are positive-part group norms); other
    /// regularizers run dense via
    /// [`crate::ot::regularizer::DenseRegOracle`].
    rule: GroupLassoRule,
    use_ws: bool,
    // Snapshot state (Definitions 1–2), refreshed by `refresh`.
    snap_alpha: Vec<f64>,
    snap_beta: Vec<f64>,
    /// `z̃_{l,j}` at index `j·|L| + l` (column-major in l for per-column walks).
    snap_z: Vec<f64>,
    /// Per-(panel, group) maxima of `snap_z`: index
    /// `(panel_off[chunk] + p)·|L| + l` for panel `p` of a chunk. Lets
    /// the eval declare a whole quiet panel skipped with **one** O(1)
    /// comparison per (panel, group) instead of `PANEL_COLS` bound
    /// checks. Rebuilt alongside `snap_z`.
    snap_z_pmax: Vec<f64>,
    /// Panel-index offset of each chunk into `snap_z_pmax` (in panels).
    panel_off: Vec<usize>,
    /// `k̃_{l,j} = ‖f̃_[l]‖₂` (only when the working set is enabled).
    snap_k: Vec<f64>,
    /// `õ_{l,j} = ‖[f̃_[l]]₋‖₂` (only when the working set is enabled).
    snap_o: Vec<f64>,
    /// Working set ℕ as a dense boolean mask, same indexing as `snap_z`.
    ws: Vec<bool>,
    /// `|ℕ|`, maintained by `rebuild_working_set` so density queries on
    /// metrics/trace paths are O(1) instead of an O(n·|L|) mask scan.
    ws_count: usize,
    // Per-eval scratch (allocated once).
    da_pos: Vec<f64>,
    // Intra-eval parallelism: a persistent parallel context (parked
    // workers, spawned once) + fixed column chunks + per-chunk scratch.
    ctx: ParallelCtx,
    ranges: Vec<Range<usize>>,
    slots: Vec<ColChunkScratch>,
    /// SIMD backend + packed cost tiles (built once at construction),
    /// shared by the eval walk and the snapshot refresh.
    engine: SimdEngine,
    stats: OracleStats,
    /// Cooperative cancellation, polled once per column chunk (one
    /// relaxed load). `None` skips the poll; an armed-but-uncancelled
    /// token is bitwise transparent.
    cancel: Option<crate::fault::CancelToken>,
}

impl<'a> ScreeningOracle<'a> {
    /// Create with snapshots initialized at `x = 0` and ℕ = ∅
    /// (Algorithm 1, line 1).
    pub fn new(prob: &'a OtProblem, params: DualParams, use_working_set: bool) -> Self {
        Self::with_threads(prob, params, use_working_set, 1)
    }

    /// [`ScreeningOracle::new`] with `threads` intra-evaluation workers
    /// on a fresh [`ParallelCtx`] owned by this oracle (its parked
    /// worker set spawns on the first parallel call and is joined when
    /// the oracle drops).
    pub fn with_threads(
        prob: &'a OtProblem,
        params: DualParams,
        use_working_set: bool,
        threads: usize,
    ) -> Self {
        Self::build(prob, params, use_working_set, ParallelCtx::new(threads), SimdMode::Auto)
    }

    /// Create from a [`SolveOptions`] — the builder-API constructor.
    /// `opts.regularizer` is not consulted: this oracle *is* the
    /// group-lasso screened oracle (γ = `opts.gamma`, ρ = `opts.rho`);
    /// the regularizer-dispatched entry is [`crate::ot::fastot::solve`].
    pub fn with_options(prob: &'a OtProblem, opts: &SolveOptions) -> Self {
        Self::build_with_ring(
            prob,
            DualParams::new(opts.gamma, opts.rho),
            opts.use_working_set,
            opts.make_ctx(),
            opts.simd,
            opts.resolve_tile_ring_bytes()
                .unwrap_or(super::cost::TILE_RING_BUDGET_BYTES),
        )
    }

    /// [`ScreeningOracle::new`] over a caller-provided parallel context.
    #[deprecated(note = "use `ScreeningOracle::with_options` with `SolveOptions::ctx`")]
    pub fn with_ctx(
        prob: &'a OtProblem,
        params: DualParams,
        use_working_set: bool,
        ctx: ParallelCtx,
    ) -> Self {
        Self::build(prob, params, use_working_set, ctx, SimdMode::Auto)
    }

    /// [`ScreeningOracle::new`] with a ctx and an explicit SIMD policy.
    #[deprecated(note = "use `ScreeningOracle::with_options` with `SolveOptions::ctx`/`simd`")]
    pub fn with_ctx_simd(
        prob: &'a OtProblem,
        params: DualParams,
        use_working_set: bool,
        ctx: ParallelCtx,
        simd: SimdMode,
    ) -> Self {
        Self::build(prob, params, use_working_set, ctx, simd)
    }

    /// The real constructor behind every entry point: snapshots at
    /// `x = 0`, ℕ = ∅, a fixed column-chunk grid over the caller's
    /// parallel context and a resolved SIMD engine. Evaluations,
    /// snapshot refreshes and working-set rebuilds shard over the fixed
    /// chunks with a deterministic ordered reduction, so every thread
    /// count (including 1) and every SIMD backend produces bit-identical
    /// gradients, objectives, screening decisions and counters.
    pub(crate) fn build(
        prob: &'a OtProblem,
        params: DualParams,
        use_working_set: bool,
        ctx: ParallelCtx,
        simd: SimdMode,
    ) -> Self {
        Self::build_with_ring(
            prob,
            params,
            use_working_set,
            ctx,
            simd,
            super::cost::TILE_RING_BUDGET_BYTES,
        )
    }

    /// [`ScreeningOracle::build`] with an explicit per-chunk tile-ring
    /// byte budget (the `--tile-ring-kib` knob). The budget moves only
    /// factored-tile retention (`tiles_built`), never solver output.
    pub(crate) fn build_with_ring(
        prob: &'a OtProblem,
        params: DualParams,
        use_working_set: bool,
        ctx: ParallelCtx,
        simd: SimdMode,
        ring_budget_bytes: usize,
    ) -> Self {
        params.validate();
        let m = prob.m();
        let n = prob.n();
        let num_groups = prob.groups.num_groups();
        let ranges = fixed_chunk_ranges(n);
        let slots = ColChunkScratch::slots_for_budget(prob, &ranges, ring_budget_bytes);
        let engine = SimdEngine::new(prob, simd);
        // Fixed panel layout: panel_off[c] is chunk c's first global
        // panel index; a function of the chunk grid (hence of n) alone.
        let mut panel_off = Vec::with_capacity(ranges.len());
        let mut total_panels = 0usize;
        for r in &ranges {
            panel_off.push(total_panels);
            total_panels += panel_count(r.len());
        }
        let consts = KernelConsts::new(&params);
        let mut o = ScreeningOracle {
            prob,
            rule: GroupLassoRule { tau: consts.tau },
            consts,
            params,
            use_ws: use_working_set,
            snap_alpha: vec![0.0; m],
            snap_beta: vec![0.0; n],
            snap_z: vec![0.0; n * num_groups],
            snap_z_pmax: vec![0.0; total_panels * num_groups],
            panel_off,
            snap_k: if use_working_set { vec![0.0; n * num_groups] } else { vec![] },
            snap_o: if use_working_set { vec![0.0; n * num_groups] } else { vec![] },
            ws: vec![false; n * num_groups],
            ws_count: 0,
            da_pos: vec![0.0; num_groups],
            ctx,
            ranges,
            slots,
            engine,
            stats: OracleStats::default(),
            cancel: None,
        };
        o.recompute_snapshots();
        o
    }

    /// Arm (or disarm) sub-eval cancellation: the token is polled once
    /// per column chunk at one relaxed load.
    pub(crate) fn set_cancel(&mut self, cancel: Option<crate::fault::CancelToken>) {
        self.cancel = cancel;
    }

    /// Convenience: fresh ctx + explicit SIMD policy.
    #[deprecated(note = "use `ScreeningOracle::with_options` with `SolveOptions::threads`/`simd`")]
    pub fn with_simd(
        prob: &'a OtProblem,
        params: DualParams,
        use_working_set: bool,
        threads: usize,
        simd: SimdMode,
    ) -> Self {
        Self::build(prob, params, use_working_set, ParallelCtx::new(threads), simd)
    }

    /// The safe-screening rule this oracle consults (the paper's Eq.
    /// 6/7 bounds).
    pub fn rule(&self) -> &dyn ScreeningRule {
        &self.rule
    }

    pub fn params(&self) -> &DualParams {
        &self.params
    }

    /// The SIMD backend this oracle's evaluations run.
    pub fn dispatch(&self) -> Dispatch {
        self.engine.dispatch
    }

    /// Fraction of (l, j) pairs currently in the working set. O(1):
    /// reads the counter maintained alongside the mask.
    pub fn working_set_density(&self) -> f64 {
        if self.ws.is_empty() {
            return 0.0;
        }
        self.ws_count as f64 / self.ws.len() as f64
    }

    /// Dense snapshot recomputation: one `O(mn)` pass filling z̃ (and
    /// k̃/õ when the working set is on) plus the per-(panel, group)
    /// maxima of z̃ at the *current snapshot point*. Column chunks run
    /// in parallel; every write is to a per-chunk disjoint slice, so
    /// the pass is trivially deterministic.
    fn recompute_snapshots(&mut self) {
        let num_groups = self.prob.groups.num_groups();
        let prob = self.prob;
        let snap_alpha = &self.snap_alpha;
        let snap_beta = &self.snap_beta;
        let use_ws = self.use_ws;
        let ranges = &self.ranges;

        struct SnapPart<'s> {
            z: &'s mut [f64],
            pmax: &'s mut [f64],
            k: &'s mut [f64],
            o: &'s mut [f64],
        }
        let z_parts = split_cols(&mut self.snap_z, ranges, num_groups);
        let pmax_parts = split_lens(
            &mut self.snap_z_pmax,
            ranges.iter().map(|r| panel_count(r.len()) * num_groups),
        );
        let (k_parts, o_parts) = if use_ws {
            (
                split_cols(&mut self.snap_k, ranges, num_groups),
                split_cols(&mut self.snap_o, ranges, num_groups),
            )
        } else {
            // Zero-length placeholder slices: never written below.
            let empties = |len: usize| (0..len).map(|_| Default::default()).collect::<Vec<_>>();
            (empties(ranges.len()), empties(ranges.len()))
        };
        let mut parts: Vec<SnapPart> = z_parts
            .into_iter()
            .zip(pmax_parts)
            .zip(k_parts)
            .zip(o_parts)
            .map(|(((z, pmax), k), o)| SnapPart { z, pmax, k, o })
            .collect();

        let engine = &self.engine;
        self.ctx.map_chunks(ranges, &mut parts, |c, range, part| {
            let start = range.start;
            // Cost-column staging for the factored backend (the dense
            // backend returns the resident row at zero cost). Refresh
            // runs once per r solver iterations, so the per-chunk
            // allocation is off the eval hot path.
            let mut colbuf = Vec::new();
            if let Some(pack) = &engine.pack {
                // Vector path: full quads via the packed tiles (per-lane
                // z̃/k̃/õ chains bit-identical to the scalar loop —
                // [`crate::simd::snapshot_quad`]), leftover columns
                // scalar. Every entry is an independent pure write, so
                // the walk order is free.
                for (p, panel) in panel_ranges(range.clone()).enumerate() {
                    let gp = pack.chunk_first_panel(c) + p;
                    let quads = pack.quads(gp);
                    for l in 0..num_groups {
                        let grange = prob.groups.range(l);
                        for q in 0..quads {
                            let j0 = panel.start + q * LANES;
                            let beta4 = [
                                snap_beta[j0],
                                snap_beta[j0 + 1],
                                snap_beta[j0 + 2],
                                snap_beta[j0 + 3],
                            ];
                            let (zsq4, ksq4, osq4) = snapshot_quad(
                                engine.dispatch,
                                snap_alpha,
                                &beta4,
                                pack.tile(gp, l, q),
                                grange.clone(),
                            );
                            for t in 0..LANES {
                                let base = (j0 + t - start) * num_groups;
                                part.z[base + l] = zsq4[t].sqrt();
                                if use_ws {
                                    part.k[base + l] = ksq4[t].sqrt();
                                    part.o[base + l] = osq4[t].sqrt();
                                }
                            }
                        }
                    }
                    for j in (panel.start + quads * LANES)..panel.end {
                        snapshot_col_scalar(
                            prob,
                            snap_alpha,
                            snap_beta[j],
                            prob.cost_col(j, &mut colbuf),
                            use_ws,
                            (j - start) * num_groups,
                            part.z,
                            part.k,
                            part.o,
                        );
                    }
                }
            } else {
                for (col, j) in range.clone().enumerate() {
                    snapshot_col_scalar(
                        prob,
                        snap_alpha,
                        snap_beta[j],
                        prob.cost_col(j, &mut colbuf),
                        use_ws,
                        col * num_groups,
                        part.z,
                        part.k,
                        part.o,
                    );
                }
            }
            // Per-(panel, group) maxima over the freshly written z̃ —
            // the O(1)-per-panel screen the eval loop reads.
            for (p, panel) in panel_ranges(range).enumerate() {
                let pbase = p * num_groups;
                for l in 0..num_groups {
                    let mut mx = 0.0f64;
                    for j in panel.clone() {
                        mx = mx.max(part.z[(j - start) * num_groups + l]);
                    }
                    part.pmax[pbase + l] = mx;
                }
            }
        });
    }

    /// Build ℕ from the *old* snapshots and the current iterate
    /// (Algorithm 1 lines 4–14), exactly in the paper's order — the set
    /// is constructed before the snapshots move. Column chunks run in
    /// parallel (disjoint mask slices + per-chunk membership counts).
    fn rebuild_working_set(&mut self, x: &[f64]) {
        let m = self.prob.m();
        let num_groups = self.prob.groups.num_groups();
        let (alpha, beta) = x.split_at(m);
        // Per-group ‖Δα_[l]‖₂ and ‖[Δα_[l]]₋‖₂ (O(m), stays serial).
        let mut da_nrm = vec![0.0; num_groups];
        let mut da_neg = vec![0.0; num_groups];
        for l in 0..num_groups {
            let mut s = 0.0;
            let mut sn = 0.0;
            for i in self.prob.groups.range(l) {
                let d = alpha[i] - self.snap_alpha[i];
                s += d * d;
                if d < 0.0 {
                    sn += d * d;
                }
            }
            da_nrm[l] = s.sqrt();
            da_neg[l] = sn.sqrt();
        }
        let sqrt_g = &self.prob.groups.sqrt_sizes;
        let snap_beta = &self.snap_beta;
        let snap_k = &self.snap_k;
        let snap_o = &self.snap_o;
        let (da_nrm, da_neg) = (&da_nrm, &da_neg);
        let rule = &self.rule;
        let tau = rule.threshold();
        let ranges = &self.ranges;

        struct WsPart<'s> {
            mask: &'s mut [bool],
            members: usize,
        }
        let mut parts: Vec<WsPart> = split_cols(&mut self.ws, ranges, num_groups)
            .into_iter()
            .map(|mask| WsPart { mask, members: 0 })
            .collect();
        self.ctx.map_chunks(ranges, &mut parts, |_, range, part| {
            part.members = 0;
            for (col, j) in range.enumerate() {
                let db = beta[j] - snap_beta[j];
                let db_abs = db.abs();
                let db_neg = (-db).max(0.0);
                let base = col * num_groups;
                let snap_base = j * num_groups;
                for l in 0..num_groups {
                    // Eq. 7 (the rule's lower bound).
                    let lower = rule.lower_bound(
                        snap_k[snap_base + l],
                        snap_o[snap_base + l],
                        da_nrm[l],
                        da_neg[l],
                        sqrt_g[l],
                        db_abs,
                        db_neg,
                    );
                    let member = lower > tau;
                    part.mask[base + l] = member;
                    part.members += usize::from(member);
                }
            }
        });
        self.ws_count = parts.iter().map(|p| p.members).sum();
    }

    /// Fig.-B diagnostic: exact `z`, upper bound `z̄` and lower bound
    /// `z̲` for every pair at `x`, against the *current* snapshots.
    pub fn bound_errors(&self, x: &[f64]) -> BoundErrors {
        let m = self.prob.m();
        let n = self.prob.n();
        let num_groups = self.prob.groups.num_groups();
        let (alpha, beta) = x.split_at(m);
        let mut da_pos = vec![0.0; num_groups];
        let mut da_nrm = vec![0.0; num_groups];
        let mut da_neg = vec![0.0; num_groups];
        for l in 0..num_groups {
            let (mut sp, mut s, mut sn) = (0.0, 0.0, 0.0);
            for i in self.prob.groups.range(l) {
                let d = alpha[i] - self.snap_alpha[i];
                s += d * d;
                if d > 0.0 {
                    sp += d * d;
                } else {
                    sn += d * d;
                }
            }
            da_pos[l] = sp.sqrt();
            da_nrm[l] = s.sqrt();
            da_neg[l] = sn.sqrt();
        }
        let sqrt_g = &self.prob.groups.sqrt_sizes;
        let mut out = BoundErrors::default();
        let mut count = 0.0;
        let mut colbuf = Vec::new();
        for j in 0..n {
            let c_j = self.prob.cost_col(j, &mut colbuf);
            let beta_j = beta[j];
            let db = beta_j - self.snap_beta[j];
            let db_pos = db.max(0.0);
            let db_abs = db.abs();
            let db_neg = (-db).max(0.0);
            let base = j * num_groups;
            for l in 0..num_groups {
                let z = exact_z(alpha, beta_j, c_j, self.prob.groups.range(l));
                let ub = self.rule.upper_bound(self.snap_z[base + l], da_pos[l], sqrt_g[l], db_pos);
                out.mean_upper += ub - z;
                out.max_upper = out.max_upper.max(ub - z);
                if self.use_ws {
                    let lb = self.rule.lower_bound(
                        self.snap_k[base + l],
                        self.snap_o[base + l],
                        da_nrm[l],
                        da_neg[l],
                        sqrt_g[l],
                        db_abs,
                        db_neg,
                    );
                    out.mean_lower += z - lb;
                    out.max_lower = out.max_lower.max(z - lb);
                }
                count += 1.0;
            }
        }
        out.mean_upper /= count;
        out.mean_lower /= count;
        out
    }
}

impl DualOracle for ScreeningOracle<'_> {
    fn shape(&self) -> (usize, usize) {
        (self.prob.m(), self.prob.n())
    }

    fn eval(&mut self, x: &[f64], grad: &mut [f64]) -> f64 {
        let m = self.prob.m();
        let n = self.prob.n();
        let num_groups = self.prob.groups.num_groups();
        debug_assert_eq!(x.len(), m + n);
        let (alpha, beta) = x.split_at(m);

        // Per-eval precomputation (Algorithm 2, line 5): ‖[Δα_[l]]₊‖₂.
        for l in 0..num_groups {
            let mut sp = 0.0;
            for i in self.prob.groups.range(l) {
                let d = alpha[i] - self.snap_alpha[i];
                if d > 0.0 {
                    sp += d * d;
                }
            }
            self.da_pos[l] = sp.sqrt();
        }

        for (gi, &ai) in grad[..m].iter_mut().zip(&self.prob.a) {
            *gi = -ai;
        }
        for (gj, &bj) in grad[m..].iter_mut().zip(&self.prob.b) {
            *gj = -bj;
        }
        let (grad_alpha, grad_beta) = grad.split_at_mut(m);

        let consts = &self.consts;
        let rule = &self.rule;
        let tau = rule.threshold();
        let prob = self.prob;
        let sqrt_g = &prob.groups.sqrt_sizes;
        let snap_z = &self.snap_z;
        let snap_z_pmax = &self.snap_z_pmax;
        let panel_off = &self.panel_off;
        let snap_beta = &self.snap_beta;
        let da_pos = &self.da_pos;
        let ws = &self.ws;
        let use_ws = self.use_ws;
        let ranges = &self.ranges;
        let engine = &self.engine;
        let cancel = self.cancel.as_ref();

        // Column chunks evaluate concurrently; per-chunk partials are
        // combined in chunk order below, so the screened gradient is
        // bit-identical for every thread count — and, because every
        // non-skipped pair runs the same kernel over the same chunking,
        // bit-identical to the dense baseline (Theorem 2).
        //
        // The walk is cache-blocked like the dense kernel: panels of
        // PANEL_COLS columns run group-by-group, so a group's snap_z
        // row segment, da_pos entry and grad_alpha slice stay hot
        // across the panel. Before touching a panel's pairs, one O(1)
        // comparison against the snapshotted per-(panel, group) max
        //   max_j z̃ + ‖[Δα]₊‖ + √g·max_j [Δβ_j]₊  ≤  τ
        // proves every pair's upper bound z̄ — and hence every z — is
        // at most τ, so the whole panel contributes nothing and is
        // skipped in bulk. Counters stay *exactly* per-pair identical:
        // a bulk-skipped pair would also have been ub-checked-and-
        // skipped individually (its ub is below the panel bound), and
        // no ℕ member can sit in a bulk-skipped panel — `refresh`
        // rebuilds ℕ at the same iterate the snapshots then move to,
        // and the membership test is a lower bound on z there (Lemma
        // 4–6), so every member has z̃ > τ and forces its panel max
        // above τ until the next rebuild replaces both together.
        self.ctx.map_chunks(ranges, &mut self.slots, |c, range, slot| {
            let cols0 = range.start;
            let cols = range.len();
            slot.reset(cols);
            // Sub-eval cancellation checkpoint: one relaxed load per
            // chunk; a cancelled chunk stays quiet and merges nothing.
            if cancel.is_some_and(|t| t.is_cancelled()) {
                return;
            }
            let mut db_pos = [0.0f64; PANEL_COLS];
            let mut mask = [false; PANEL_COLS];
            for (p, panel) in panel_ranges(range).enumerate() {
                let plen = panel.len();
                let mut db_max = 0.0f64;
                for (t, j) in panel.clone().enumerate() {
                    let v = (beta[j] - snap_beta[j]).max(0.0);
                    db_pos[t] = v;
                    db_max = db_max.max(v);
                }
                let pmax_base = (panel_off[c] + p) * num_groups;
                for l in 0..num_groups {
                    // O(1) quiet-panel screen (valid upper bound on
                    // every pair's z̄ in the panel — the rule applied
                    // to the panel-max snapshot norm).
                    if rule.upper_bound(snap_z_pmax[pmax_base + l], da_pos[l], sqrt_g[l], db_max)
                        <= tau
                    {
                        slot.ub_checks += plen as u64;
                        slot.skipped += plen as u64;
                        continue;
                    }
                    let group_range = prob.groups.range(l);
                    // Decision phase (Alg. 2): identical tests and
                    // counters on every backend — the skip logic never
                    // depends on the kernel that later runs.
                    for (t, j) in panel.clone().enumerate() {
                        let base = j * num_groups;
                        mask[t] = if use_ws && ws[base + l] {
                            // ℕ member: provably nonzero, no check
                            // (Alg. 2 lines 2–4).
                            slot.ws_hits += 1;
                            true
                        } else {
                            // Upper bound check (Alg. 2 lines 6–13).
                            slot.ub_checks += 1;
                            let ub =
                                rule.upper_bound(snap_z[base + l], da_pos[l], sqrt_g[l], db_pos[t]);
                            if ub <= tau {
                                slot.skipped += 1;
                                false
                            } else {
                                true
                            }
                        };
                    }
                    // Compute phase, ascending column order: a quad
                    // whose four columns all survived runs the vector
                    // kernel; a partially-skipped quad falls back to
                    // the scalar kernel per surviving lane — the
                    // per-element accumulation order is identical
                    // either way, so all backends stay byte-equal.
                    let mut from = 0usize;
                    if let Some(pack) = &engine.pack {
                        let gp = pack.chunk_first_panel(c) + p;
                        let quads = pack.quads(gp);
                        for q in 0..quads {
                            let t0 = q * LANES;
                            let j0 = panel.start + t0;
                            if mask[t0..t0 + LANES].iter().all(|&v| v) {
                                quad_pair(
                                    engine.dispatch,
                                    pack.tile(gp, l, q),
                                    alpha,
                                    beta,
                                    j0,
                                    cols0,
                                    group_range.clone(),
                                    consts,
                                    slot,
                                );
                            } else {
                                for t in t0..t0 + LANES {
                                    if mask[t] {
                                        scalar_pair(
                                            prob,
                                            consts,
                                            alpha,
                                            beta,
                                            panel.start + t,
                                            cols0,
                                            group_range.clone(),
                                            slot,
                                        );
                                    }
                                }
                            }
                        }
                        from = quads * LANES;
                    } else if engine.dispatch.is_vector() {
                        // Factored backend under a vector dispatch (no
                        // resident pack): full surviving quads run the
                        // quad kernel against ring-synthesized tiles —
                        // identical arithmetic and order to the packed
                        // path, so screened solves stay byte-equal
                        // across backends. A (panel, group) screened
                        // out everywhere never synthesizes its tile.
                        if let CostMatrix::Factored(fac) = prob.cost_backend() {
                            let quads = plen / LANES;
                            for q in 0..quads {
                                let t0 = q * LANES;
                                let j0 = panel.start + t0;
                                if mask[t0..t0 + LANES].iter().all(|&v| v) {
                                    synth_quad_pair(
                                        fac,
                                        engine.dispatch,
                                        alpha,
                                        beta,
                                        j0,
                                        cols0,
                                        panel.start,
                                        quads,
                                        l,
                                        group_range.clone(),
                                        consts,
                                        slot,
                                    );
                                } else {
                                    for t in t0..t0 + LANES {
                                        if mask[t] {
                                            scalar_pair(
                                                prob,
                                                consts,
                                                alpha,
                                                beta,
                                                panel.start + t,
                                                cols0,
                                                group_range.clone(),
                                                slot,
                                            );
                                        }
                                    }
                                }
                            }
                            from = quads * LANES;
                        }
                    }
                    for t in from..plen {
                        if mask[t] {
                            scalar_pair(
                                prob,
                                consts,
                                alpha,
                                beta,
                                panel.start + t,
                                cols0,
                                group_range.clone(),
                                slot,
                            );
                        }
                    }
                }
            }
            slot.fold_psi(cols);
        });
        let totals = reduce_chunks(&self.ranges, &self.slots, grad_alpha, grad_beta);

        self.stats.grads_computed += totals.grads;
        self.stats.grads_skipped += totals.skipped;
        self.stats.ub_checks += totals.ub_checks;
        self.stats.ws_hits += totals.ws_hits;
        self.stats.tiles_built += totals.tiles_built;
        self.stats.record_eval(totals.grads);

        let dual = linalg::dot(alpha, &self.prob.a) + linalg::dot(beta, &self.prob.b) - totals.psi;
        -dual
    }

    /// Algorithm 1, lines 4–15: rebuild ℕ from the old snapshots, then
    /// move the snapshots to the current iterate.
    fn refresh(&mut self, x: &[f64]) {
        let m = self.prob.m();
        if self.use_ws {
            self.rebuild_working_set(x);
        }
        self.snap_alpha.copy_from_slice(&x[..m]);
        self.snap_beta.copy_from_slice(&x[m..]);
        self.recompute_snapshots();
    }

    fn stats(&self) -> &OracleStats {
        &self.stats
    }

    fn simd_dispatch(&self) -> Option<Dispatch> {
        Some(self.engine.dispatch)
    }

    fn working_set_density(&self) -> Option<f64> {
        self.use_ws.then(|| ScreeningOracle::working_set_density(self))
    }

    fn parallel_ctx(&self) -> Option<&ParallelCtx> {
        Some(&self.ctx)
    }
}

/// Per-lane configuration of a [`BatchedOracle`] — one independent
/// (γ, ρ, working-set, cancel) problem sharing the batch's cost data.
pub(crate) struct BatchLaneSpec {
    pub(crate) params: DualParams,
    pub(crate) use_working_set: bool,
    pub(crate) simd: SimdMode,
    pub(crate) cancel: Option<crate::fault::CancelToken>,
    pub(crate) ring_budget_bytes: usize,
}

/// Per-chunk scratch shared across the batch's lanes: the staged cost
/// segment (read once per surviving (group, column) for *all* lanes —
/// the batching win) and the lane-interleaved gradient buffer the
/// multi-problem quad kernel writes through. Owned by the batch, not by
/// any lane's [`ColChunkScratch`], so the fused walk can borrow it
/// alongside every lane's scratch without aliasing.
struct BatchSharedScratch {
    /// Staged cost segment for one (group, column), `max_group` values.
    colbuf: Vec<f64>,
    /// [`crate::simd::batch_quad_contrib`] scratch, `LANES·max_group`.
    quad: Vec<f64>,
}

/// Shared per-lane view of one oracle's screening state — the read-only
/// half the fused walk consults, split from the mutable chunk scratch so
/// the closure can hold both.
struct LaneView<'v> {
    alpha: &'v [f64],
    beta: &'v [f64],
    consts: KernelConsts,
    tau: f64,
    rule: &'v GroupLassoRule,
    use_ws: bool,
    snap_beta: &'v [f64],
    snap_z: &'v [f64],
    snap_z_pmax: &'v [f64],
    ws: &'v [bool],
    da_pos: &'v [f64],
    cancel: Option<&'v crate::fault::CancelToken>,
}

/// One chunk's mutable state in the fused walk: every live lane's
/// [`ColChunkScratch`] plus the batch-owned shared scratch.
struct BatchChunk<'s> {
    per: Vec<&'s mut ColChunkScratch>,
    shared: &'s mut BatchSharedScratch,
}

/// K ≤ [`LANES`] independent screened oracles over **one**
/// [`OtProblem`], evaluated in a single fused pass over the cost
/// columns — the ISSUE-10 batched oracle. Each lane keeps its own
/// snapshots, working set, counters and chunk scratch (so its screening
/// decisions, gradient and objective are *byte-identical* to a
/// standalone [`ScreeningOracle`] at every iterate); what is shared is
/// the walk itself: each surviving (group, column) cost segment is
/// staged once and consumed by every lane that needs it, either through
/// the lane-remapped quad kernel
/// ([`crate::simd::batch_quad_contrib`], whose per-lane chains are
/// bitwise equal to the scalar kernel's) or the scalar kernel per lane.
/// The factored backend's `fill_seg` synthesis in particular runs once
/// per K-group instead of K times.
pub(crate) struct BatchedOracle<'a> {
    prob: &'a OtProblem,
    oracles: Vec<ScreeningOracle<'a>>,
    shared: Vec<BatchSharedScratch>,
    ranges: Vec<Range<usize>>,
    panel_off: Vec<usize>,
    ctx: ParallelCtx,
    /// Vector dispatch for the multi-problem quad kernel when any lane
    /// resolved one; `Scalar` otherwise. Per-lane results are bitwise
    /// dispatch-independent (the crate invariant), so one shared choice
    /// is safe.
    dispatch: Dispatch,
}

impl<'a> BatchedOracle<'a> {
    pub(crate) fn new(prob: &'a OtProblem, specs: &[BatchLaneSpec], ctx: ParallelCtx) -> Self {
        assert!(
            !specs.is_empty() && specs.len() <= LANES,
            "batch width must be 1..={LANES}, got {}",
            specs.len()
        );
        let oracles: Vec<ScreeningOracle<'a>> = specs
            .iter()
            .map(|s| {
                let mut o = ScreeningOracle::build_with_ring(
                    prob,
                    s.params,
                    s.use_working_set,
                    ctx.clone(),
                    s.simd,
                    s.ring_budget_bytes,
                );
                o.set_cancel(s.cancel.clone());
                o
            })
            .collect();
        // The chunk grid and panel layout are functions of n alone, so
        // every lane built the same ones; share lane 0's.
        let ranges = oracles[0].ranges.clone();
        let panel_off = oracles[0].panel_off.clone();
        let dispatch = oracles
            .iter()
            .map(|o| o.engine.dispatch)
            .find(|d| d.is_vector())
            .unwrap_or(Dispatch::Scalar);
        let max_group = prob.groups.max_size();
        let shared = (0..ranges.len())
            .map(|_| BatchSharedScratch {
                colbuf: vec![0.0; max_group],
                quad: vec![0.0; LANES * max_group],
            })
            .collect();
        BatchedOracle { prob, oracles, shared, ranges, panel_off, ctx, dispatch }
    }

    pub(crate) fn lanes(&self) -> usize {
        self.oracles.len()
    }

    pub(crate) fn lane(&self, p: usize) -> &ScreeningOracle<'a> {
        &self.oracles[p]
    }

    pub(crate) fn lane_mut(&mut self, p: usize) -> &mut ScreeningOracle<'a> {
        &mut self.oracles[p]
    }

    pub(crate) fn ctx(&self) -> &ParallelCtx {
        &self.ctx
    }

    /// One fused evaluation: for every lane `p` with `live[p]`, compute
    /// the negated dual objective and gradient of problem `p` at
    /// `xs[p]`, writing `fs[p]`/`grads[p]` and advancing that lane's
    /// [`OracleStats`] exactly as a standalone `eval` would. Lanes with
    /// `live[p] == false` are untouched (their `xs[p]` only needs the
    /// right length). All four slices must have `lanes()` entries.
    ///
    /// Byte-identity: each live lane walks the identical (panel, group,
    /// column) order as the sequential screened eval, makes the
    /// identical skip/ws decisions from its own state, and runs a
    /// kernel whose per-lane chains are bitwise equal to the scalar
    /// reference — so `fs`/`grads`/counters match the standalone oracle
    /// bit for bit at any K, thread count and dispatch (`tiles_built`
    /// excepted: staging is shared, so the factored backend charges one
    /// synthesis per K-group).
    pub(crate) fn eval_many(
        &mut self,
        xs: &[&[f64]],
        live: &[bool],
        fs: &mut [f64],
        grads: &mut [Vec<f64>],
    ) {
        let lanes = self.oracles.len();
        assert_eq!(xs.len(), lanes);
        assert_eq!(live.len(), lanes);
        assert_eq!(fs.len(), lanes);
        assert_eq!(grads.len(), lanes);
        let m = self.prob.m();
        let n = self.prob.n();
        let num_groups = self.prob.groups.num_groups();
        let prob = self.prob;
        let sqrt_g = &prob.groups.sqrt_sizes;

        // Per-lane prolog (Algorithm 2, line 5): ‖[Δα_[l]]₊‖₂ against
        // the lane's own snapshots, plus the −a/−b gradient init —
        // exactly the sequential eval's prolog, per live lane.
        for (p, o) in self.oracles.iter_mut().enumerate() {
            if !live[p] {
                continue;
            }
            debug_assert_eq!(xs[p].len(), m + n);
            let (alpha, _beta) = xs[p].split_at(m);
            for l in 0..num_groups {
                let mut sp = 0.0;
                for i in prob.groups.range(l) {
                    let d = alpha[i] - o.snap_alpha[i];
                    if d > 0.0 {
                        sp += d * d;
                    }
                }
                o.da_pos[l] = sp.sqrt();
            }
            let grad = &mut grads[p];
            for (gi, &ai) in grad[..m].iter_mut().zip(&prob.a) {
                *gi = -ai;
            }
            for (gj, &bj) in grad[m..].iter_mut().zip(&prob.b) {
                *gj = -bj;
            }
        }

        // Fused walk: shared-ref views of every lane's screening state
        // plus disjoint mutable chunk scratch, transposed chunk-major.
        {
            let mut views: Vec<LaneView<'_>> = Vec::with_capacity(lanes);
            let mut slot_iters = Vec::with_capacity(lanes);
            for (p, o) in self.oracles.iter_mut().enumerate() {
                let (alpha, beta) = xs[p].split_at(m);
                let ScreeningOracle {
                    consts,
                    rule,
                    use_ws,
                    snap_beta,
                    snap_z,
                    snap_z_pmax,
                    ws,
                    da_pos,
                    slots,
                    cancel,
                    ..
                } = o;
                views.push(LaneView {
                    alpha,
                    beta,
                    consts: *consts,
                    tau: rule.threshold(),
                    rule: &*rule,
                    use_ws: *use_ws,
                    snap_beta: snap_beta.as_slice(),
                    snap_z: snap_z.as_slice(),
                    snap_z_pmax: snap_z_pmax.as_slice(),
                    ws: ws.as_slice(),
                    da_pos: da_pos.as_slice(),
                    cancel: cancel.as_ref(),
                });
                slot_iters.push(slots.iter_mut());
            }
            let mut chunks: Vec<BatchChunk<'_>> = (0..self.ranges.len())
                .map(|_| {
                    slot_iters
                        .iter_mut()
                        .map(|it| it.next().expect("every lane has one slot per chunk"))
                        .collect::<Vec<_>>()
                })
                .zip(self.shared.iter_mut())
                .map(|(per, shared)| BatchChunk { per, shared })
                .collect();

            let views = &views;
            let panel_off = &self.panel_off;
            let dispatch = self.dispatch;
            self.ctx.map_chunks(&self.ranges, &mut chunks, |c, range, chunk| {
                let BatchChunk { per, shared } = chunk;
                let BatchSharedScratch { colbuf, quad } = &mut **shared;
                let cols0 = range.start;
                let cols = range.len();
                // Reset every live lane's scratch first (sequential
                // semantics: reset precedes the cancel poll), then poll
                // each lane's token once — a cancelled lane's chunk
                // stays quiet while the others proceed.
                let mut go = [false; LANES];
                for (p, v) in views.iter().enumerate() {
                    if !live[p] {
                        continue;
                    }
                    per[p].reset(cols);
                    go[p] = !v.cancel.is_some_and(|t| t.is_cancelled());
                }
                if !go[..views.len()].iter().any(|&b| b) {
                    return;
                }
                let mut db_pos = [[0.0f64; PANEL_COLS]; LANES];
                let mut db_max = [0.0f64; LANES];
                let mut mask = [[false; PANEL_COLS]; LANES];
                let mut lane_on = [false; LANES];
                let mut comp: Vec<usize> = Vec::with_capacity(LANES);
                for (pi, panel) in panel_ranges(range).enumerate() {
                    let plen = panel.len();
                    for (p, v) in views.iter().enumerate() {
                        if !go[p] {
                            continue;
                        }
                        db_max[p] = 0.0;
                        for (t, j) in panel.clone().enumerate() {
                            let w = (v.beta[j] - v.snap_beta[j]).max(0.0);
                            db_pos[p][t] = w;
                            db_max[p] = db_max[p].max(w);
                        }
                    }
                    let pmax_base = (panel_off[c] + pi) * num_groups;
                    for l in 0..num_groups {
                        let group_range = prob.groups.range(l);
                        let g = group_range.len();
                        let start = group_range.start;
                        // Decision phase per lane — identical tests and
                        // counters to the sequential screened eval,
                        // against each lane's own snapshots and ℕ.
                        for (p, v) in views.iter().enumerate() {
                            lane_on[p] = false;
                            if !go[p] {
                                continue;
                            }
                            let slot = &mut *per[p];
                            if v.rule.upper_bound(
                                v.snap_z_pmax[pmax_base + l],
                                v.da_pos[l],
                                sqrt_g[l],
                                db_max[p],
                            ) <= v.tau
                            {
                                slot.ub_checks += plen as u64;
                                slot.skipped += plen as u64;
                                continue;
                            }
                            let mut any = false;
                            for (t, j) in panel.clone().enumerate() {
                                let base = j * num_groups;
                                mask[p][t] = if v.use_ws && v.ws[base + l] {
                                    slot.ws_hits += 1;
                                    true
                                } else {
                                    slot.ub_checks += 1;
                                    let ub = v.rule.upper_bound(
                                        v.snap_z[base + l],
                                        v.da_pos[l],
                                        sqrt_g[l],
                                        db_pos[p][t],
                                    );
                                    if ub <= v.tau {
                                        slot.skipped += 1;
                                        false
                                    } else {
                                        true
                                    }
                                };
                                any |= mask[p][t];
                            }
                            lane_on[p] = any;
                        }
                        if !lane_on[..views.len()].iter().any(|&b| b) {
                            continue;
                        }
                        // Compute phase, ascending column order: stage
                        // this (group, column) cost segment once, then
                        // feed every surviving lane from it.
                        for (t, j) in panel.clone().enumerate() {
                            comp.clear();
                            for p in 0..views.len() {
                                if lane_on[p] && mask[p][t] {
                                    comp.push(p);
                                }
                            }
                            if comp.is_empty() {
                                continue;
                            }
                            let c_seg: &[f64] = match prob.cost_backend() {
                                CostMatrix::Dense(ct) => &ct.row(j)[group_range.clone()],
                                CostMatrix::Factored(fac) => {
                                    // Synthesized once for the whole
                                    // K-group — the batching win. One
                                    // build is charged (to the first
                                    // consumer); `tiles_built` is the
                                    // one batching-dependent counter.
                                    fac.fill_seg(j, group_range.clone(), &mut colbuf[..g]);
                                    per[comp[0]].tiles_built += 1;
                                    &colbuf[..g]
                                }
                            };
                            let col = j - cols0;
                            if dispatch.is_vector() && comp.len() > 1 {
                                // Lane-remapped quad kernel: unused SIMD
                                // lanes are padded with a duplicate of
                                // lane 0 and their results discarded.
                                let pad = comp[0];
                                let lane_of =
                                    |i: usize| *comp.get(i).unwrap_or(&pad);
                                let alphas: [&[f64]; LANES] =
                                    std::array::from_fn(|i| views[lane_of(i)].alpha);
                                let beta4: [f64; LANES] =
                                    std::array::from_fn(|i| views[lane_of(i)].beta[j]);
                                let consts4: [KernelConsts; LANES] =
                                    std::array::from_fn(|i| views[lane_of(i)].consts);
                                let (psi4, mass4, active) = crate::simd::batch_quad_contrib(
                                    dispatch,
                                    &alphas,
                                    &beta4,
                                    c_seg,
                                    group_range.clone(),
                                    &consts4,
                                    &mut quad[..LANES * g],
                                );
                                for (i, &p) in comp.iter().enumerate() {
                                    let slot = &mut *per[p];
                                    if active[i] {
                                        for k in 0..g {
                                            slot.grad_alpha[start + k] += quad[LANES * k + i];
                                        }
                                    }
                                    slot.psi_col[col] += psi4[i];
                                    slot.col_mass[col] += mass4[i];
                                    slot.grads += 1;
                                }
                            } else {
                                for &p in comp.iter() {
                                    let v = &views[p];
                                    let slot = &mut *per[p];
                                    let (psi, mass) = group_grad_contrib(
                                        v.alpha,
                                        v.beta[j],
                                        c_seg,
                                        group_range.clone(),
                                        &v.consts,
                                        &mut slot.grad_alpha,
                                        &mut slot.group,
                                    );
                                    slot.psi_col[col] += psi;
                                    slot.col_mass[col] += mass;
                                    slot.grads += 1;
                                }
                            }
                        }
                    }
                }
                for p in 0..views.len() {
                    if go[p] {
                        per[p].fold_psi(cols);
                    }
                }
            });
        }

        // Per-lane epilog: ordered chunk reduction into the lane's
        // gradient, stats fold and objective — the sequential eval's
        // tail, per live lane.
        for (p, o) in self.oracles.iter_mut().enumerate() {
            if !live[p] {
                continue;
            }
            let (alpha, beta) = xs[p].split_at(m);
            let (ga, gb) = grads[p].split_at_mut(m);
            let totals = reduce_chunks(&self.ranges, &o.slots, ga, gb);
            o.stats.grads_computed += totals.grads;
            o.stats.grads_skipped += totals.skipped;
            o.stats.ub_checks += totals.ub_checks;
            o.stats.ws_hits += totals.ws_hits;
            o.stats.tiles_built += totals.tiles_built;
            o.stats.record_eval(totals.grads);
            let dual =
                linalg::dot(alpha, &prob.a) + linalg::dot(beta, &prob.b) - totals.psi;
            fs[p] = -dual;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;

    fn random_problem(seed: u64, l: usize, g: usize, n: usize) -> OtProblem {
        let mut rng = Pcg64::new(seed);
        let m = l * g;
        let cost = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
        let labels: Vec<usize> = (0..m).map(|i| i / g).collect();
        OtProblem::from_parts(vec![1.0 / m as f64; m], vec![1.0 / n as f64; n], &cost, &labels)
    }

    /// Screened eval must equal dense eval exactly, at arbitrary points
    /// and snapshot states.
    #[test]
    fn screened_eval_equals_dense() {
        let prob = random_problem(3, 4, 3, 7);
        let params = DualParams::new(0.5, 0.6);
        for ws in [false, true] {
            let mut oracle = ScreeningOracle::new(&prob, params, ws);
            let mut rng = Pcg64::new(99);
            let mut x = vec![0.0; prob.dim()];
            for step in 0..12 {
                // Random walk; refresh snapshots at some steps.
                for v in x.iter_mut() {
                    *v += rng.uniform(-0.2, 0.25);
                }
                if step % 4 == 3 {
                    oracle.refresh(&x);
                }
                let mut g1 = vec![0.0; prob.dim()];
                let f1 = oracle.eval(&x, &mut g1);
                let mut g2 = vec![0.0; prob.dim()];
                let (f2, _) = super::super::dual::eval_dense(&prob, &params, &x, &mut g2);
                assert_eq!(f1, f2, "objective mismatch ws={ws} step={step}");
                assert_eq!(g1, g2, "gradient mismatch ws={ws} step={step}");
            }
        }
    }

    /// Oracle-level byte-equality across SIMD backends: eval, refresh
    /// and every counter must match the scalar reference exactly (the
    /// solver-level version lives in `tests/simd_equivalence.rs`).
    #[test]
    fn simd_backends_match_scalar_screened_oracle() {
        let prob = random_problem(3, 4, 3, 23);
        let params = DualParams::new(0.5, 0.6);
        for ws in [false, true] {
            let of = |threads: usize, simd| {
                ScreeningOracle::build(&prob, params, ws, ParallelCtx::new(threads), simd)
            };
            let mut scalar = of(1, SimdMode::Scalar);
            let mut auto = of(1, SimdMode::Auto);
            let mut portable = of(2, SimdMode::Portable);
            let mut rng = Pcg64::new(5);
            let mut x = vec![0.0; prob.dim()];
            for step in 0..10 {
                for v in x.iter_mut() {
                    *v += rng.uniform(-0.2, 0.25);
                }
                if step % 3 == 2 {
                    scalar.refresh(&x);
                    auto.refresh(&x);
                    portable.refresh(&x);
                }
                let mut g1 = vec![0.0; prob.dim()];
                let f1 = scalar.eval(&x, &mut g1);
                for oracle in [&mut auto, &mut portable] {
                    let mut g = vec![0.0; prob.dim()];
                    let f = oracle.eval(&x, &mut g);
                    assert_eq!(f1, f, "objective ws={ws} step={step}");
                    assert_eq!(g1, g, "gradient ws={ws} step={step}");
                }
            }
            assert_eq!(scalar.stats(), auto.stats(), "stats ws={ws}");
            assert_eq!(scalar.stats(), portable.stats(), "stats ws={ws}");
        }
    }

    #[test]
    fn skips_happen_for_strong_regularization() {
        let prob = random_problem(5, 6, 4, 10);
        // Large τ ⇒ lots of zero groups ⇒ skips after a refresh.
        let params = DualParams::new(5.0, 0.8);
        let mut oracle = ScreeningOracle::new(&prob, params, true);
        let x = vec![0.01; prob.dim()];
        oracle.refresh(&x);
        let mut g = vec![0.0; prob.dim()];
        oracle.eval(&x, &mut g);
        let s = oracle.stats();
        assert!(s.grads_skipped > 0, "expected skips, got {s:?}");
    }

    #[test]
    fn working_set_members_bypass_checks() {
        let prob = random_problem(7, 3, 5, 8);
        // Small τ ⇒ most groups active ⇒ ℕ should be non-empty after a
        // refresh near a well-separated point.
        let params = DualParams::new(0.05, 0.3);
        let mut oracle = ScreeningOracle::new(&prob, params, true);
        let mut x = vec![0.0; prob.dim()];
        // Push α, β up so f = α + β − c is clearly positive.
        for v in x.iter_mut() {
            *v = 1.0;
        }
        oracle.refresh(&x); // snapshots at x
        oracle.refresh(&x); // Δ=0 now; lower bound = k̃ − õ = z̃ exactly
        assert!(oracle.working_set_density() > 0.0);
        let before = oracle.stats().ws_hits;
        let mut g = vec![0.0; prob.dim()];
        oracle.eval(&x, &mut g);
        assert!(oracle.stats().ws_hits > before);
    }

    #[test]
    fn bounds_are_valid_at_random_points() {
        // z̲ ≤ z ≤ z̄ for random snapshots and iterates.
        let prob = random_problem(11, 4, 4, 6);
        let params = DualParams::new(1.0, 0.5);
        let mut oracle = ScreeningOracle::new(&prob, params, true);
        let mut rng = Pcg64::new(1234);
        let mut x = vec![0.0; prob.dim()];
        for _ in 0..8 {
            for v in x.iter_mut() {
                *v += rng.uniform(-0.3, 0.35);
            }
            let errs = oracle.bound_errors(&x);
            // mean_upper = mean(z̄ − z) ≥ 0 and mean_lower = mean(z − z̲) ≥ 0.
            assert!(errs.mean_upper >= -1e-12, "{errs:?}");
            assert!(errs.mean_lower >= -1e-12, "{errs:?}");
            if rng.f64() < 0.5 {
                oracle.refresh(&x);
            }
        }
    }

    #[test]
    fn bounds_tight_at_snapshot_point() {
        // Theorem 3: at Δ = 0 the upper bound is exact.
        let prob = random_problem(13, 3, 3, 5);
        let params = DualParams::new(0.8, 0.4);
        let mut oracle = ScreeningOracle::new(&prob, params, true);
        let mut x = vec![0.0; prob.dim()];
        let mut rng = Pcg64::new(5);
        for v in x.iter_mut() {
            *v = rng.uniform(-0.5, 0.7);
        }
        oracle.refresh(&x);
        let errs = oracle.bound_errors(&x);
        assert!(errs.max_upper.abs() < 1e-12, "{errs:?}");
    }

    /// Every per-lane counter except `tiles_built` (batching shares
    /// tile staging by design).
    fn assert_stats_eq_mod_tiles(a: &OracleStats, b: &OracleStats, what: &str) {
        assert_eq!(a.evals, b.evals, "evals {what}");
        assert_eq!(a.grads_computed, b.grads_computed, "grads_computed {what}");
        assert_eq!(a.grads_skipped, b.grads_skipped, "grads_skipped {what}");
        assert_eq!(a.ub_checks, b.ub_checks, "ub_checks {what}");
        assert_eq!(a.ws_hits, b.ws_hits, "ws_hits {what}");
        assert_eq!(a.per_eval_grads, b.per_eval_grads, "per_eval_grads {what}");
    }

    /// The tentpole contract at the oracle level: a K-lane fused
    /// evaluation must be byte-identical — objective, gradient and
    /// every counter except `tiles_built` — to K standalone oracles,
    /// for every K ∈ 1..=LANES, with heterogeneous (γ, ρ, working-set)
    /// lanes, distinct per-lane iterate trajectories, interleaved
    /// refreshes, and the batch running on a different thread count
    /// than the references.
    #[test]
    fn batched_eval_matches_sequential_lanes_bitwise() {
        let prob = random_problem(3, 4, 3, 23);
        let lane_cfgs =
            [(0.5, 0.6, true), (1.5, 0.3, false), (0.2, 0.8, true), (5.0, 0.7, true)];
        for take in 1..=lane_cfgs.len() {
            let cfgs = &lane_cfgs[..take];
            let mut seq: Vec<ScreeningOracle> = cfgs
                .iter()
                .map(|&(gamma, rho, ws)| {
                    ScreeningOracle::build(
                        &prob,
                        DualParams::new(gamma, rho),
                        ws,
                        ParallelCtx::new(1),
                        SimdMode::Auto,
                    )
                })
                .collect();
            let specs: Vec<BatchLaneSpec> = cfgs
                .iter()
                .map(|&(gamma, rho, ws)| BatchLaneSpec {
                    params: DualParams::new(gamma, rho),
                    use_working_set: ws,
                    simd: SimdMode::Auto,
                    cancel: None,
                    ring_budget_bytes: crate::ot::cost::TILE_RING_BUDGET_BYTES,
                })
                .collect();
            let mut batch = BatchedOracle::new(&prob, &specs, ParallelCtx::new(2));
            let mut rng = Pcg64::new(77);
            let mut xs: Vec<Vec<f64>> = (0..take).map(|_| vec![0.0; prob.dim()]).collect();
            let live = vec![true; take];
            let mut fs = vec![0.0; take];
            let mut grads: Vec<Vec<f64>> = (0..take).map(|_| vec![0.0; prob.dim()]).collect();
            for step in 0..8 {
                for x in xs.iter_mut() {
                    for v in x.iter_mut() {
                        *v += rng.uniform(-0.2, 0.25);
                    }
                }
                if step % 3 == 2 {
                    for (p, o) in seq.iter_mut().enumerate() {
                        o.refresh(&xs[p]);
                        batch.lane_mut(p).refresh(&xs[p]);
                    }
                }
                let views: Vec<&[f64]> = xs.iter().map(Vec::as_slice).collect();
                batch.eval_many(&views, &live, &mut fs, &mut grads);
                for (p, o) in seq.iter_mut().enumerate() {
                    let mut g = vec![0.0; prob.dim()];
                    let f = o.eval(&xs[p], &mut g);
                    assert_eq!(f, fs[p], "objective K={take} lane={p} step={step}");
                    assert_eq!(g, grads[p], "gradient K={take} lane={p} step={step}");
                }
            }
            for (p, o) in seq.iter().enumerate() {
                assert_stats_eq_mod_tiles(
                    o.stats(),
                    batch.lane(p).stats(),
                    &format!("K={take} lane={p}"),
                );
            }
        }
    }

    /// Retired (non-live) lanes are untouched by a fused eval: their
    /// outputs keep whatever the caller left there and their stats
    /// don't move.
    #[test]
    fn retired_lanes_stay_untouched() {
        let prob = random_problem(9, 3, 3, 11);
        let specs: Vec<BatchLaneSpec> = [(0.5, 0.5), (1.0, 0.4), (0.3, 0.7)]
            .iter()
            .map(|&(gamma, rho)| BatchLaneSpec {
                params: DualParams::new(gamma, rho),
                use_working_set: true,
                simd: SimdMode::Auto,
                cancel: None,
                ring_budget_bytes: crate::ot::cost::TILE_RING_BUDGET_BYTES,
            })
            .collect();
        let mut batch = BatchedOracle::new(&prob, &specs, ParallelCtx::new(1));
        let xs: Vec<Vec<f64>> = (0..3).map(|p| vec![0.1 * (p as f64 + 1.0); prob.dim()]).collect();
        let views: Vec<&[f64]> = xs.iter().map(Vec::as_slice).collect();
        let live = [true, false, true];
        let mut fs = [0.0, -7.5, 0.0];
        let mut grads: Vec<Vec<f64>> = (0..3).map(|_| vec![42.0; prob.dim()]).collect();
        let before = batch.lane(1).stats().clone();
        batch.eval_many(&views, &live, &mut fs, &mut grads);
        assert_eq!(fs[1], -7.5, "retired lane's objective overwritten");
        assert!(grads[1].iter().all(|&v| v == 42.0), "retired lane's gradient overwritten");
        assert_eq!(&before, batch.lane(1).stats(), "retired lane's stats moved");
        // Live lanes really did evaluate.
        assert_eq!(batch.lane(0).stats().evals, 1);
        assert_eq!(batch.lane(2).stats().evals, 1);
        assert!(grads[0].iter().any(|&v| v != 42.0));
    }
}
