//! Dataset-family integration tests: generator contracts the solver
//! relies on, across all four evaluation families.

use grpot::data::{digits, faces, objects, synthetic};
use grpot::eval;
use grpot::ot::dual::OtProblem;
use grpot::testing::{check, Config};

#[test]
fn synthetic_matches_paper_construction() {
    check("synthetic construction", &Config::cases(10), |rng| {
        let l = 1 + rng.below(8);
        let g = 1 + rng.below(12);
        let pair = synthetic::controlled(l, g, rng.next_u64());
        if pair.source.len() != l * g || pair.target.len() != l * g {
            return Err("n = m = |L|·g violated".into());
        }
        if pair.source.dim() != 2 {
            return Err("d must be 2".into());
        }
        // Every class is present with exactly g members on both domains.
        for ds in [&pair.source, &pair.target] {
            for class in 0..l {
                let count = ds.labels.iter().filter(|&&y| y == class).count();
                if count != g {
                    return Err(format!("class {class} has {count} != {g} members"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn problems_have_uniform_marginals_and_normalized_costs() {
    let pairs = vec![
        synthetic::controlled(4, 5, 1),
        digits::usps_to_mnist(50, 2),
        faces::all_tasks(0.03, 3).into_iter().next().unwrap(),
        objects::all_tasks(0.1, 4).into_iter().next().unwrap(),
    ];
    for pair in pairs {
        let prob = OtProblem::from_dataset(&pair);
        let sa: f64 = prob.a.iter().sum();
        let sb: f64 = prob.b.iter().sum();
        assert!((sa - 1.0).abs() < 1e-12, "{}: source marginal {sa}", pair.task_name());
        assert!((sb - 1.0).abs() < 1e-12, "{}: target marginal {sb}", pair.task_name());
        assert!(prob.cost_t().max_abs() <= 1.0 + 1e-12, "{}: cost not normalized", pair.task_name());
        assert!(prob.cost_t().as_slice().iter().all(|&c| c >= 0.0));
        // Group structure covers all source samples.
        assert_eq!(prob.groups.num_samples(), prob.m());
        assert_eq!(prob.groups.num_groups(), pair.source.num_classes());
    }
}

#[test]
fn faces_all_twelve_tasks_consistent_identities() {
    let tasks = faces::all_tasks(0.03, 0xDD);
    assert_eq!(tasks.len(), 12);
    for t in &tasks {
        assert_eq!(t.source.num_classes(), 68);
        assert_eq!(t.target.num_classes(), 68);
        assert_eq!(t.source.dim(), 1024);
    }
}

#[test]
fn objects_sizes_proportional_to_paper() {
    let tasks = objects::all_tasks(1.0, 0xEE);
    let sizes: std::collections::BTreeSet<usize> =
        tasks.iter().map(|t| t.source.len()).collect();
    assert_eq!(
        sizes,
        [1123usize, 958, 295, 157].into_iter().collect(),
        "paper's Caltech/Amazon/Webcam/DSLR sizes"
    );
}

#[test]
fn adaptation_is_learnable_on_every_family() {
    // OTDA must beat chance (1/#classes) clearly on each family — the
    // datasets must carry transferable class structure.
    let cases: Vec<(grpot::data::DomainPair, f64)> = vec![
        (synthetic::controlled(5, 10, 0xAB), 0.2),
        (digits::usps_to_mnist(150, 0xAC), 0.1),
        (faces::all_tasks(0.05, 0xAD).into_iter().next().unwrap(), 1.0 / 68.0),
        (objects::all_tasks(0.3, 0xAE).into_iter().next().unwrap(), 0.1),
    ];
    for (pair, chance) in cases {
        let prob = OtProblem::from_dataset(&pair);
        let cfg = grpot::ot::fastot::FastOtConfig {
            gamma: 0.05,
            rho: 0.5,
            ..Default::default()
        };
        let res = grpot::ot::fastot::solve_fast_ot(&prob, &cfg);
        let plan = grpot::ot::plan::recover_plan(&prob, &cfg.params(), &res.x);
        let acc = eval::otda_accuracy(&pair, &prob, &plan);
        assert!(
            acc > 2.5 * chance,
            "{}: OTDA accuracy {acc} too close to chance {chance}",
            pair.task_name()
        );
    }
}

#[test]
fn generators_deterministic_and_seed_sensitive() {
    let a = digits::usps_to_mnist(30, 7);
    let b = digits::usps_to_mnist(30, 7);
    let c = digits::usps_to_mnist(30, 8);
    assert_eq!(a.source.x.as_slice(), b.source.x.as_slice());
    assert_ne!(a.source.x.as_slice(), c.source.x.as_slice());

    let fa = faces::all_tasks(0.03, 9);
    let fb = faces::all_tasks(0.03, 9);
    assert_eq!(fa[0].source.x.as_slice(), fb[0].source.x.as_slice());
}
