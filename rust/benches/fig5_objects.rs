//! Figure 5: gain on the 12 Caltech-Office object-recognition tasks
//! (10 classes, DeCAF₆-like 4096-d features). Paper: up to 6.2×.
//! Domain sizes scaled (quick 0.15 / full 0.4 of 1123/958/295/157).

mod common;

use common::*;
use grpot::data::objects;

fn main() {
    banner("fig5: Caltech-Office object tasks");
    let scale = size3(0.05, 0.15, 0.4);
    let tasks = size3(2, 12, 12);
    let gammas = gamma_grid();
    let rhos = rho_grid();

    let mut blocks = Vec::new();
    for pair in objects::all_tasks(scale, 0xF165).into_iter().take(tasks) {
        let prob = problem_of(&pair);
        println!("task {} (m={}, n={}) …", pair.task_name(), prob.m(), prob.n());
        let rows = gain_sweep(&prob, &gammas, &rhos, 10);
        for r in &rows {
            println!("  gamma={:<8} gain={:.2}x", r.gamma, r.gain);
            assert!(r.objectives_match);
        }
        blocks.push((pair.task_name(), rows));
    }
    emit_gain_table(
        "Fig. 5 — processing-time gain on object recognition tasks (12 Caltech-Office pairs)",
        "fig5_objects",
        &blocks,
    );
}
