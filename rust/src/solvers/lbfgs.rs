//! Limited-memory BFGS with strong-Wolfe line search.
//!
//! Implemented as a *resumable* state machine: the Algorithm-1 driver
//! calls [`Lbfgs::step`] in blocks of `r` iterations and refreshes the
//! screening snapshots in between without losing curvature memory.
//!
//! The solver's own dot/axpy reductions are `O(m + n)` per iteration —
//! dwarfed by the oracle's `O(|L|·n·g)` evaluation — and stay serial on
//! purpose: intra-solve parallelism lives in the oracles (see
//! [`crate::pool::ParallelCtx`]), whose deterministic ordered reduction
//! keeps the whole trajectory bit-identical at any thread count. A
//! parallel dot here would buy nothing and break that invariant.
//!
//! Context lifetime: the oracle (not the solver) owns the
//! `ParallelCtx`, so its persistent parked workers survive across the
//! `r`-iteration step blocks, the `refresh` calls between them, and —
//! when the caller threads a long-lived ctx through `solve_*_ctx` —
//! across whole solves. `Lbfgs` itself never spawns or parks threads;
//! every `step`/`run` call drives the same worker set through the
//! oracle it is handed.

use super::linesearch::{strong_wolfe, WolfeOptions};
use super::{StepStatus, StopReason};
use crate::linalg;
use crate::ot::dual::DualOracle;
use std::collections::VecDeque;

/// L-BFGS options (defaults follow scipy's L-BFGS-B: m=10,
/// ftol≈2.2e-9, gtol=1e-5).
#[derive(Clone, Debug)]
pub struct LbfgsOptions {
    /// Number of stored (s, y) pairs.
    pub memory: usize,
    /// Hard iteration cap.
    pub max_iters: usize,
    /// Stop when `‖∇f‖∞ ≤ gtol`.
    pub gtol: f64,
    /// Stop when `(f_prev − f) ≤ ftol · max(|f|, |f_prev|, 1)`.
    pub ftol: f64,
    /// Line-search parameters.
    pub wolfe: WolfeOptions,
}

impl Default for LbfgsOptions {
    fn default() -> Self {
        LbfgsOptions {
            memory: 10,
            max_iters: 1000,
            gtol: 1e-5,
            ftol: 2.2e-9,
            wolfe: WolfeOptions::default(),
        }
    }
}

/// Resumable L-BFGS state.
pub struct Lbfgs {
    opts: LbfgsOptions,
    x: Vec<f64>,
    f: f64,
    g: Vec<f64>,
    s_mem: VecDeque<Vec<f64>>,
    y_mem: VecDeque<Vec<f64>>,
    rho_mem: VecDeque<f64>,
    iter: usize,
    stopped: Option<StopReason>,
}

impl Lbfgs {
    /// Initialize at `x0` (evaluates the oracle once).
    ///
    /// `x0` may be any finite iterate, not just the origin — the serving
    /// engine warm-starts from cached near-optimal duals this way. The
    /// solver makes no assumption about the starting point: curvature
    /// memory starts empty and the first step uses the 1/‖g‖ scaling
    /// heuristic, so a warm start close to the optimum converges in a
    /// handful of iterations.
    pub fn new(x0: Vec<f64>, opts: LbfgsOptions, oracle: &mut dyn DualOracle) -> Self {
        debug_assert!(x0.iter().all(|v| v.is_finite()), "non-finite warm-start iterate");
        let mut g = vec![0.0; x0.len()];
        let f = oracle.eval(&x0, &mut g);
        Lbfgs {
            opts,
            x: x0,
            f,
            g,
            s_mem: VecDeque::new(),
            y_mem: VecDeque::new(),
            rho_mem: VecDeque::new(),
            iter: 0,
            stopped: None,
        }
    }

    /// Current iterate.
    pub fn x(&self) -> &[f64] {
        &self.x
    }

    /// Current objective value.
    pub fn f(&self) -> f64 {
        self.f
    }

    /// Current gradient.
    pub fn grad(&self) -> &[f64] {
        &self.g
    }

    /// Completed iterations.
    pub fn iterations(&self) -> usize {
        self.iter
    }

    /// Why the solver stopped, if it has.
    pub fn stop_reason(&self) -> Option<StopReason> {
        self.stopped
    }

    /// Consume into `(x, f)`.
    pub fn into_solution(self) -> (Vec<f64>, f64) {
        (self.x, self.f)
    }

    /// Two-loop recursion: `dir = −H·g`.
    fn search_direction(&self) -> Vec<f64> {
        let k = self.s_mem.len();
        let mut q: Vec<f64> = self.g.clone();
        if k == 0 {
            for v in q.iter_mut() {
                *v = -*v;
            }
            return q;
        }
        let mut alphas = vec![0.0; k];
        for idx in (0..k).rev() {
            let a = self.rho_mem[idx] * linalg::dot(&self.s_mem[idx], &q);
            alphas[idx] = a;
            linalg::axpy(-a, &self.y_mem[idx], &mut q);
        }
        // Initial Hessian scaling γ = sᵀy / yᵀy (most recent pair).
        let last = k - 1;
        let sy = 1.0 / self.rho_mem[last];
        let yy = linalg::nrm2_sq(&self.y_mem[last]);
        let gamma = if yy > 0.0 { sy / yy } else { 1.0 };
        linalg::scal(gamma, &mut q);
        for idx in 0..k {
            let b = self.rho_mem[idx] * linalg::dot(&self.y_mem[idx], &q);
            linalg::axpy(alphas[idx] - b, &self.s_mem[idx], &mut q);
        }
        for v in q.iter_mut() {
            *v = -*v;
        }
        q
    }

    /// One L-BFGS iteration. Returns `Continue` or a terminal status.
    pub fn step(&mut self, oracle: &mut dyn DualOracle) -> StepStatus {
        if let Some(r) = self.stopped {
            return StepStatus::Stopped(r);
        }
        if linalg::nrm_inf(&self.g) <= self.opts.gtol {
            self.stopped = Some(StopReason::GradTol);
            return StepStatus::Stopped(StopReason::GradTol);
        }
        if self.iter >= self.opts.max_iters {
            self.stopped = Some(StopReason::MaxIters);
            return StepStatus::Stopped(StopReason::MaxIters);
        }

        let mut dir = self.search_direction();
        let mut dphi0 = linalg::dot(&self.g, &dir);
        if dphi0 >= 0.0 {
            // Memory produced a non-descent direction (can happen after
            // pathological curvature); restart from steepest descent.
            self.s_mem.clear();
            self.y_mem.clear();
            self.rho_mem.clear();
            dir = self.g.iter().map(|&v| -v).collect();
            dphi0 = linalg::dot(&self.g, &dir);
            if dphi0 >= 0.0 {
                self.stopped = Some(StopReason::GradTol);
                return StepStatus::Stopped(StopReason::GradTol);
            }
        }

        // First iteration: scale the step like 1/‖g‖ (standard heuristic).
        let init_step = if self.s_mem.is_empty() {
            (1.0 / linalg::nrm_inf(&self.g).max(1e-12)).min(1.0)
        } else {
            1.0
        };

        let ls = strong_wolfe(
            oracle,
            &self.x,
            self.f,
            &self.g,
            &dir,
            init_step,
            &self.opts.wolfe,
        );
        let ls = match ls {
            Some(r) => r,
            None => {
                self.stopped = Some(StopReason::LineSearchFailed);
                return StepStatus::Stopped(StopReason::LineSearchFailed);
            }
        };

        // Update memory with s = t·d, y = g_new − g_old.
        let mut s = dir;
        linalg::scal(ls.step, &mut s);
        let y = linalg::sub(&ls.grad, &self.g);
        let sy = linalg::dot(&s, &y);
        if sy > 1e-12 * linalg::nrm2(&s) * linalg::nrm2(&y) {
            if self.s_mem.len() == self.opts.memory {
                self.s_mem.pop_front();
                self.y_mem.pop_front();
                self.rho_mem.pop_front();
            }
            self.rho_mem.push_back(1.0 / sy);
            self.s_mem.push_back(s.clone());
            self.y_mem.push_back(y);
        }

        let f_prev = self.f;
        for (xi, &si) in self.x.iter_mut().zip(&s) {
            *xi += si;
        }
        self.f = ls.f;
        self.g = ls.grad;
        self.iter += 1;

        let fscale = self.f.abs().max(f_prev.abs()).max(1.0);
        if f_prev - self.f <= self.opts.ftol * fscale {
            self.stopped = Some(StopReason::FTol);
            return StepStatus::Stopped(StopReason::FTol);
        }
        StepStatus::Continue
    }

    /// Run until a stop condition fires; returns the reason.
    pub fn run(&mut self, oracle: &mut dyn DualOracle) -> StopReason {
        loop {
            if let StepStatus::Stopped(r) = self.step(oracle) {
                return r;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::dual::OracleStats;

    /// Adapter: plain smooth function as a DualOracle for solver tests.
    pub struct FnOracle<F: FnMut(&[f64], &mut [f64]) -> f64> {
        pub f: F,
        pub dim: usize,
        pub stats: OracleStats,
    }

    impl<F: FnMut(&[f64], &mut [f64]) -> f64> DualOracle for FnOracle<F> {
        fn shape(&self) -> (usize, usize) {
            (self.dim, 0)
        }
        fn eval(&mut self, x: &[f64], grad: &mut [f64]) -> f64 {
            self.stats.evals += 1;
            (self.f)(x, grad)
        }
        fn stats(&self) -> &OracleStats {
            &self.stats
        }
    }

    #[test]
    fn minimizes_quadratic_exactly() {
        // f(x) = ½Σ d_i (x_i − c_i)²
        let d = [1.0, 10.0, 100.0];
        let c = [1.0, -2.0, 3.0];
        let mut oracle = FnOracle {
            dim: 3,
            stats: OracleStats::default(),
            f: move |x: &[f64], g: &mut [f64]| {
                let mut f = 0.0;
                for i in 0..3 {
                    let e = x[i] - c[i];
                    g[i] = d[i] * e;
                    f += 0.5 * d[i] * e * e;
                }
                f
            },
        };
        let mut solver = Lbfgs::new(vec![0.0; 3], LbfgsOptions::default(), &mut oracle);
        let reason = solver.run(&mut oracle);
        assert!(matches!(reason, StopReason::GradTol | StopReason::FTol), "{reason:?}");
        for i in 0..3 {
            assert!((solver.x()[i] - c[i]).abs() < 1e-4, "x[{i}]={}", solver.x()[i]);
        }
    }

    #[test]
    fn minimizes_rosenbrock() {
        let mut oracle = FnOracle {
            dim: 2,
            stats: OracleStats::default(),
            f: |x: &[f64], g: &mut [f64]| {
                let (a, b) = (x[0], x[1]);
                g[0] = -2.0 * (1.0 - a) - 400.0 * a * (b - a * a);
                g[1] = 200.0 * (b - a * a);
                (1.0 - a).powi(2) + 100.0 * (b - a * a).powi(2)
            },
        };
        let opts = LbfgsOptions { max_iters: 500, ftol: 1e-14, ..Default::default() };
        let mut solver = Lbfgs::new(vec![-1.2, 1.0], opts, &mut oracle);
        solver.run(&mut oracle);
        assert!((solver.x()[0] - 1.0).abs() < 1e-3, "x={:?}", solver.x());
        assert!((solver.x()[1] - 1.0).abs() < 1e-3, "x={:?}", solver.x());
        assert!(solver.f() < 1e-6);
    }

    #[test]
    fn resumable_stepping_matches_run() {
        // Stepping one-by-one must reach the same solution as run().
        let mk = || FnOracle {
            dim: 2,
            stats: OracleStats::default(),
            f: |x: &[f64], g: &mut [f64]| {
                g[0] = 2.0 * x[0] + x[1];
                g[1] = x[0] + 4.0 * x[1] - 3.0;
                x[0] * x[0] + 0.5 * x[0] * x[1] + 2.0 * x[1] * x[1] - 3.0 * x[1]
            },
        };
        let mut o1 = mk();
        let mut s1 = Lbfgs::new(vec![5.0, -5.0], LbfgsOptions::default(), &mut o1);
        s1.run(&mut o1);

        let mut o2 = mk();
        let mut s2 = Lbfgs::new(vec![5.0, -5.0], LbfgsOptions::default(), &mut o2);
        while let StepStatus::Continue = s2.step(&mut o2) {}
        assert_eq!(s1.x(), s2.x());
        assert_eq!(s1.f(), s2.f());
    }

    #[test]
    fn warm_start_near_optimum_converges_fast() {
        // Seeding at (almost) the minimizer must terminate in far fewer
        // iterations than the cold solve and reach the same objective.
        let d = [2.0, 30.0, 7.0];
        let c = [0.5, -1.5, 2.0];
        let mk = || FnOracle {
            dim: 3,
            stats: OracleStats::default(),
            f: move |x: &[f64], g: &mut [f64]| {
                let mut f = 0.0;
                for i in 0..3 {
                    let e = x[i] - c[i];
                    g[i] = d[i] * e;
                    f += 0.5 * d[i] * e * e;
                }
                f
            },
        };
        let mut o_cold = mk();
        let mut cold = Lbfgs::new(vec![10.0; 3], LbfgsOptions::default(), &mut o_cold);
        cold.run(&mut o_cold);

        let mut o_warm = mk();
        let mut warm = Lbfgs::new(cold.x().to_vec(), LbfgsOptions::default(), &mut o_warm);
        warm.run(&mut o_warm);
        assert!(
            warm.iterations() <= 2,
            "warm start took {} iterations",
            warm.iterations()
        );
        assert!((warm.f() - cold.f()).abs() <= 1e-12, "{} vs {}", warm.f(), cold.f());
    }

    #[test]
    fn respects_max_iters() {
        let mut oracle = FnOracle {
            dim: 1,
            stats: OracleStats::default(),
            f: |x: &[f64], g: &mut [f64]| {
                g[0] = x[0].signum() * 1.0 + x[0] * 1e-3; // slow crawl
                x[0].abs() + 0.5e-3 * x[0] * x[0]
            },
        };
        let opts = LbfgsOptions { max_iters: 3, ftol: 0.0, gtol: 0.0, ..Default::default() };
        let mut solver = Lbfgs::new(vec![100.0], opts, &mut oracle);
        let reason = solver.run(&mut oracle);
        // Non-smooth kink: either hits the cap or stalls in line search.
        assert!(matches!(reason, StopReason::MaxIters | StopReason::LineSearchFailed));
        assert!(solver.iterations() <= 3);
    }
}
