//! Process-wide metrics: named counters and timers with JSON snapshots.
//! Shared across the sweep scheduler and the TCP service (all atomic /
//! mutex-protected; cheap enough for per-request use).

use crate::jsonlite::Value;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// A registry of counters and duration accumulators.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, AtomicU64>>,
    /// Sum of seconds and sample count per timer name.
    timers: Mutex<BTreeMap<String, (f64, u64)>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a named counter.
    pub fn incr(&self, name: &str, by: u64) {
        let mut map = self.counters.lock().unwrap();
        map.entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(by, Ordering::Relaxed);
    }

    /// Read a counter (0 when unset).
    pub fn get(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Record a duration sample.
    pub fn observe(&self, name: &str, seconds: f64) {
        let mut map = self.timers.lock().unwrap();
        let e = map.entry(name.to_string()).or_insert((0.0, 0));
        e.0 += seconds;
        e.1 += 1;
    }

    /// Time a closure and record it.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.observe(name, t.elapsed().as_secs_f64());
        out
    }

    /// Mean seconds of a timer (None when unset).
    pub fn mean_seconds(&self, name: &str) -> Option<f64> {
        let map = self.timers.lock().unwrap();
        map.get(name).map(|(s, c)| s / (*c).max(1) as f64)
    }

    /// JSON snapshot of every counter and timer.
    pub fn snapshot(&self) -> Value {
        let mut counters = Value::obj();
        for (k, v) in self.counters.lock().unwrap().iter() {
            counters = counters.set(k, v.load(Ordering::Relaxed));
        }
        let mut timers = Value::obj();
        for (k, (s, c)) in self.timers.lock().unwrap().iter() {
            timers = timers.set(
                k,
                Value::obj().set("total_s", *s).set("count", *c).set(
                    "mean_s",
                    if *c > 0 { *s / *c as f64 } else { 0.0 },
                ),
            );
        }
        Value::obj().set("counters", counters).set("timers", timers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("jobs", 1);
        m.incr("jobs", 2);
        assert_eq!(m.get("jobs"), 3);
        assert_eq!(m.get("missing"), 0);
    }

    #[test]
    fn timers_record_and_average() {
        let m = Metrics::new();
        m.observe("solve", 1.0);
        m.observe("solve", 3.0);
        assert_eq!(m.mean_seconds("solve"), Some(2.0));
        let out = m.time("quick", || 42);
        assert_eq!(out, 42);
        assert!(m.mean_seconds("quick").unwrap() >= 0.0);
    }

    #[test]
    fn snapshot_is_json() {
        let m = Metrics::new();
        m.incr("a", 5);
        m.observe("t", 0.5);
        let v = m.snapshot();
        assert_eq!(v.get_path(&["counters", "a"]).unwrap().as_usize(), Some(5));
        assert!(v.get_path(&["timers", "t", "mean_s"]).is_some());
    }

    #[test]
    fn concurrent_increments() {
        let m = std::sync::Arc::new(Metrics::new());
        let pool = crate::pool::ThreadPool::new(4);
        for _ in 0..100 {
            let m2 = std::sync::Arc::clone(&m);
            pool.execute(move || m2.incr("hits", 1));
        }
        pool.join();
        assert_eq!(m.get("hits"), 100);
    }
}
