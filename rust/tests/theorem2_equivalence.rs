//! Theorem 2 (exactness) integration tests: the screened solver must
//! reproduce the dense baseline's trajectory bit-for-bit across
//! datasets, hyperparameters, snapshot intervals and the working-set
//! ablation.
//!
//! Every solve honors `GRPOT_TEST_THREADS` (default 1): CI re-runs this
//! suite with 4 intra-solve oracle threads, and every bit-exact
//! assertion must hold unchanged — the parallel reduction is
//! deterministic by construction.

use grpot::coordinator::config::Method;
use grpot::coordinator::sweep::run_job_threads;
use grpot::data::{digits, faces, objects, synthetic};
use grpot::ot::dual::OtProblem;
use grpot::ot::fastot::{solve_fast_ot, FastOtConfig};
use grpot::ot::origin::solve_origin;
use grpot::ot::plan::recover_plan;
use grpot::solvers::lbfgs::LbfgsOptions;

fn check_pair(prob: &OtProblem, gamma: f64, rho: f64, r: usize) {
    let cfg = FastOtConfig {
        gamma,
        rho,
        r,
        threads: grpot::testing::env_threads(),
        lbfgs: LbfgsOptions { max_iters: 150, ..Default::default() },
        ..Default::default()
    };
    let fast = solve_fast_ot(prob, &cfg);
    let orig = solve_origin(prob, &cfg);
    assert_eq!(
        fast.dual_objective, orig.dual_objective,
        "objective differs (gamma={gamma}, rho={rho}, r={r})"
    );
    assert_eq!(fast.x, orig.x, "solution differs (gamma={gamma}, rho={rho}, r={r})");
    assert_eq!(fast.iterations, orig.iterations);
    // Recovered plans identical too.
    let params = cfg.params();
    let pf = recover_plan(prob, &params, &fast.x);
    let po = recover_plan(prob, &params, &orig.x);
    assert_eq!(pf.t, po.t);
}

#[test]
fn synthetic_grid() {
    let pair = synthetic::controlled(6, 5, 0x7E57);
    let prob = OtProblem::from_dataset(&pair);
    for gamma in [0.01, 0.5, 50.0] {
        for rho in [0.2, 0.8] {
            check_pair(&prob, gamma, rho, 10);
        }
    }
}

#[test]
fn digits_task() {
    let pair = digits::usps_to_mnist(80, 0x7E58);
    let prob = OtProblem::from_dataset(&pair);
    check_pair(&prob, 0.1, 0.6, 10);
    check_pair(&prob, 10.0, 0.4, 10);
}

#[test]
fn faces_task_ragged_groups() {
    // PIE domains have 68 classes with ragged group sizes after scaling.
    let pair = faces::all_tasks(0.03, 0x7E59).into_iter().next().unwrap();
    let prob = OtProblem::from_dataset(&pair);
    assert!(prob.groups.num_groups() > 1);
    check_pair(&prob, 0.5, 0.6, 10);
}

#[test]
fn objects_task_high_dim() {
    let pair = objects::all_tasks(0.08, 0x7E5A).into_iter().nth(5).unwrap();
    let prob = OtProblem::from_dataset(&pair);
    check_pair(&prob, 1.0, 0.8, 10);
}

#[test]
fn snapshot_interval_does_not_change_result() {
    // r only affects *when* bounds refresh, never what is computed.
    let threads = grpot::testing::env_threads();
    let pair = synthetic::controlled(5, 6, 0x7E5B);
    let prob = OtProblem::from_dataset(&pair);
    let base = {
        let cfg = FastOtConfig { gamma: 0.3, rho: 0.7, r: 1, threads, ..Default::default() };
        solve_fast_ot(&prob, &cfg)
    };
    for r in [2, 5, 10, 100] {
        let cfg = FastOtConfig { gamma: 0.3, rho: 0.7, r, threads, ..Default::default() };
        let res = solve_fast_ot(&prob, &cfg);
        assert_eq!(res.dual_objective, base.dual_objective, "r={r}");
        assert_eq!(res.x, base.x, "r={r}");
    }
}

#[test]
fn ablation_methods_agree() {
    let threads = grpot::testing::env_threads();
    let pair = synthetic::controlled(4, 8, 0x7E5C);
    let prob = OtProblem::from_dataset(&pair);
    let fast = run_job_threads(&prob, Method::Fast, 0.2, 0.6, 10, 150, threads);
    let nows = run_job_threads(&prob, Method::FastNoWs, 0.2, 0.6, 10, 150, threads);
    let orig = run_job_threads(&prob, Method::Origin, 0.2, 0.6, 10, 150, threads);
    assert_eq!(fast.dual_objective, orig.dual_objective);
    assert_eq!(nows.dual_objective, orig.dual_objective);
    assert_eq!(fast.iterations, orig.iterations);
}

#[test]
fn rho_zero_pure_quadratic_supported() {
    // ρ = 0 disables the group term (threshold 0 ⇒ nothing skippable);
    // the screened oracle must still agree with dense.
    let pair = synthetic::controlled(3, 5, 0x7E5D);
    let prob = OtProblem::from_dataset(&pair);
    check_pair(&prob, 0.5, 0.0, 10);
}
