use super::*;

#[test]
fn deterministic_given_seed() {
    let mut a = Pcg64::new(42);
    let mut b = Pcg64::new(42);
    for _ in 0..100 {
        assert_eq!(a.next_u64(), b.next_u64());
    }
    let mut c = Pcg64::new(43);
    assert_ne!(Pcg64::new(42).next_u64(), c.next_u64());
}

#[test]
fn f64_in_unit_interval() {
    let mut r = Pcg64::new(1);
    for _ in 0..10_000 {
        let v = r.f64();
        assert!((0.0..1.0).contains(&v));
    }
}

#[test]
fn uniform_mean_reasonable() {
    let mut r = Pcg64::new(7);
    let n = 50_000;
    let mean: f64 = (0..n).map(|_| r.uniform(2.0, 4.0)).sum::<f64>() / n as f64;
    assert!((mean - 3.0).abs() < 0.02, "mean={mean}");
}

#[test]
fn below_unbiased_rough() {
    let mut r = Pcg64::new(3);
    let mut counts = [0usize; 5];
    let n = 100_000;
    for _ in 0..n {
        counts[r.below(5)] += 1;
    }
    for &c in &counts {
        let p = c as f64 / n as f64;
        assert!((p - 0.2).abs() < 0.01, "p={p}");
    }
}

#[test]
fn normal_moments() {
    let mut r = Pcg64::new(11);
    let n = 200_000;
    let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    assert!(mean.abs() < 0.01, "mean={mean}");
    assert!((var - 1.0).abs() < 0.02, "var={var}");
}

#[test]
fn normal_ms_shifts() {
    let mut r = Pcg64::new(5);
    let n = 100_000;
    let mean: f64 = (0..n).map(|_| r.normal_ms(10.0, 0.5)).sum::<f64>() / n as f64;
    assert!((mean - 10.0).abs() < 0.02, "mean={mean}");
}

#[test]
fn shuffle_is_permutation() {
    let mut r = Pcg64::new(9);
    let mut v: Vec<usize> = (0..100).collect();
    r.shuffle(&mut v);
    let mut sorted = v.clone();
    sorted.sort_unstable();
    assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    assert_ne!(v, (0..100).collect::<Vec<_>>()); // overwhelmingly likely
}

#[test]
fn sample_indices_distinct() {
    let mut r = Pcg64::new(13);
    let s = r.sample_indices(50, 20);
    assert_eq!(s.len(), 20);
    let mut dedup = s.clone();
    dedup.sort_unstable();
    dedup.dedup();
    assert_eq!(dedup.len(), 20);
    assert!(dedup.iter().all(|&i| i < 50));
}

#[test]
fn categorical_respects_weights() {
    let mut r = Pcg64::new(17);
    let w = [1.0, 0.0, 3.0];
    let mut counts = [0usize; 3];
    let n = 40_000;
    for _ in 0..n {
        counts[r.categorical(&w)] += 1;
    }
    assert_eq!(counts[1], 0);
    let p2 = counts[2] as f64 / n as f64;
    assert!((p2 - 0.75).abs() < 0.01, "p2={p2}");
}

#[test]
fn split_streams_diverge() {
    let mut root = Pcg64::new(21);
    let mut a = root.split();
    let mut b = root.split();
    let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
    let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
    assert_ne!(va, vb);
}

#[test]
fn exp1_mean_one() {
    let mut r = Pcg64::new(23);
    let n = 100_000;
    let mean: f64 = (0..n).map(|_| r.exp1()).sum::<f64>() / n as f64;
    assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
}
