//! Exact discrete OT (Problem 1) via the transportation simplex —
//! the unregularized LP substrate used to validate the regularized
//! solvers (γ → 0 limit) and to report true Wasserstein costs.
//!
//! Standard MODI / u-v method with an explicit basis graph:
//! north-west-corner initial basis, potentials from a tree traversal,
//! block-search entering rule, cycle pivot. Marginals are perturbed by a
//! tiny per-row epsilon to break degeneracy (removed from the returned
//! plan by a final clean-up), which is the classic anti-cycling device
//! for the transportation problem.

use crate::linalg::Mat;

/// Result of an exact EMD solve.
#[derive(Clone, Debug)]
pub struct EmdResult {
    /// Optimal plan (m × n), dense.
    pub plan: Mat,
    /// `⟨T, C⟩` at the optimum.
    pub cost: f64,
    /// Dual potentials (u, v) — an optimality certificate:
    /// `u_i + v_j ≤ c_ij` everywhere with equality on support.
    pub u: Vec<f64>,
    pub v: Vec<f64>,
    /// Simplex pivots performed.
    pub pivots: usize,
}

/// Solve `min ⟨T, C⟩ s.t. T1 = a, Tᵀ1 = b, T ≥ 0` exactly.
///
/// `a` and `b` must have equal sums (up to rounding; they are
/// renormalized internally).
pub fn emd(a: &[f64], b: &[f64], cost: &Mat) -> EmdResult {
    let m = a.len();
    let n = b.len();
    assert_eq!(cost.shape(), (m, n));
    assert!(m > 0 && n > 0);
    let sa: f64 = a.iter().sum();
    let sb: f64 = b.iter().sum();
    assert!(sa > 0.0 && sb > 0.0);
    assert!(
        ((sa - sb) / sa).abs() < 1e-6,
        "marginals must balance: {sa} vs {sb}"
    );

    // Degeneracy-breaking perturbation.
    let eps = 1e-12 * sa.max(1.0);
    let supply: Vec<f64> = a.iter().map(|&x| x * (sb / sa) + eps).collect();
    let mut demand: Vec<f64> = b.to_vec();
    demand[n - 1] += eps * m as f64;

    // --- North-west corner initial basic feasible solution.
    // Basis arcs stored as (i, j, flow); adjacency for tree walks.
    let mut flow = std::collections::HashMap::<(usize, usize), f64>::new();
    let mut adj_s: Vec<Vec<usize>> = vec![Vec::new(); m]; // source -> basic targets
    let mut adj_t: Vec<Vec<usize>> = vec![Vec::new(); n]; // target -> basic sources
    {
        let mut i = 0;
        let mut j = 0;
        let mut s = supply.clone();
        let mut d = demand.clone();
        while i < m && j < n {
            let q = s[i].min(d[j]);
            flow.insert((i, j), q);
            adj_s[i].push(j);
            adj_t[j].push(i);
            s[i] -= q;
            d[j] -= q;
            if i == m - 1 && j == n - 1 {
                break;
            }
            if s[i] <= d[j] && i < m - 1 {
                i += 1;
            } else if j < n - 1 {
                j += 1;
            } else {
                i += 1;
            }
        }
    }

    let mut u = vec![0.0; m];
    let mut v = vec![0.0; n];
    let mut pivots = 0usize;
    let max_pivots = 50 * (m + n) * (m + n).max(16); // generous safety cap

    loop {
        // --- Potentials from the basis tree (BFS from source 0, u0 = 0).
        compute_potentials(&adj_s, &adj_t, cost, &mut u, &mut v);

        // --- Entering arc: most negative reduced cost (Dantzig rule).
        let mut best = (0usize, 0usize);
        let mut best_red = -1e-11;
        for i in 0..m {
            let crow = cost.row(i);
            let ui = u[i];
            for j in 0..n {
                let red = crow[j] - ui - v[j];
                if red < best_red {
                    best_red = red;
                    best = (i, j);
                }
            }
        }
        if best_red >= -1e-11 {
            break; // optimal
        }

        // --- Find the unique cycle: path from source best.0 to target
        // best.1 through basic arcs (alternating source/target nodes).
        let path = find_path(&adj_s, &adj_t, best.0, best.1, m, n)
            .expect("basis must connect all nodes");
        // Cycle: entering arc (s→t) + path t→…→s. Flow alternates signs;
        // arcs at odd positions along the cycle lose flow.
        // path is a list of (i, j) basic arcs from best.0 to best.1.
        let mut theta = f64::INFINITY;
        let mut leave = (usize::MAX, usize::MAX);
        for (k, &(i, j)) in path.iter().enumerate() {
            if k % 2 == 0 {
                // arcs traversed source→target direction lose flow
                let fl = flow[&(i, j)];
                if fl < theta {
                    theta = fl;
                    leave = (i, j);
                }
            }
        }
        debug_assert!(leave.0 != usize::MAX);

        // --- Pivot: adjust flows around the cycle.
        for (k, &(i, j)) in path.iter().enumerate() {
            let e = flow.get_mut(&(i, j)).unwrap();
            if k % 2 == 0 {
                *e -= theta;
            } else {
                *e += theta;
            }
        }
        flow.insert(best, theta);
        adj_s[best.0].push(best.1);
        adj_t[best.1].push(best.0);
        // Remove the leaving arc from the basis.
        flow.remove(&leave);
        adj_s[leave.0].retain(|&j| j != leave.1);
        adj_t[leave.1].retain(|&i| i != leave.0);

        pivots += 1;
        if pivots > max_pivots {
            panic!("network simplex exceeded pivot cap — degenerate cycling?");
        }
    }

    // --- Extract the plan (undo the perturbation by clipping).
    let mut plan = Mat::zeros(m, n);
    for (&(i, j), &f) in &flow {
        if f > 10.0 * eps * (m + n) as f64 {
            plan[(i, j)] = f;
        }
    }
    // Rescale rows exactly to `a` (perturbation removal).
    let rs = plan.row_sums();
    for i in 0..m {
        if rs[i] > 0.0 {
            let scale = a[i] / rs[i] * (sb / sa);
            for x in plan.row_mut(i) {
                *x *= scale;
            }
        }
    }
    let total_cost = plan.frobenius_dot(cost);
    EmdResult { plan, cost: total_cost, u, v, pivots }
}

/// Potentials from the basis tree: u_i + v_j = c_ij on basic arcs.
fn compute_potentials(
    adj_s: &[Vec<usize>],
    adj_t: &[Vec<usize>],
    cost: &Mat,
    u: &mut [f64],
    v: &mut [f64],
) {
    let m = adj_s.len();
    let n = adj_t.len();
    let mut seen_s = vec![false; m];
    let mut seen_t = vec![false; n];
    // The basis may momentarily be a forest when degenerate; root a BFS
    // at every unseen source.
    for root in 0..m {
        if seen_s[root] {
            continue;
        }
        u[root] = 0.0;
        seen_s[root] = true;
        let mut stack: Vec<(usize, bool)> = vec![(root, true)]; // (node, is_source)
        while let Some((node, is_source)) = stack.pop() {
            if is_source {
                for &j in &adj_s[node] {
                    if !seen_t[j] {
                        v[j] = cost[(node, j)] - u[node];
                        seen_t[j] = true;
                        stack.push((j, false));
                    }
                }
            } else {
                for &i in &adj_t[node] {
                    if !seen_s[i] {
                        u[i] = cost[(i, node)] - v[node];
                        seen_s[i] = true;
                        stack.push((i, true));
                    }
                }
            }
        }
    }
}

/// DFS path from source `si` to target `tj` through basic arcs.
/// Returns the arc list; arcs alternate target-bound / source-bound.
fn find_path(
    adj_s: &[Vec<usize>],
    adj_t: &[Vec<usize>],
    si: usize,
    tj: usize,
    m: usize,
    n: usize,
) -> Option<Vec<(usize, usize)>> {
    // Nodes: sources 0..m, targets m..m+n. Parent-arc tracking BFS.
    let total = m + n;
    let mut parent: Vec<Option<(usize, (usize, usize))>> = vec![None; total];
    let mut visited = vec![false; total];
    let mut queue = std::collections::VecDeque::new();
    visited[si] = true;
    queue.push_back(si);
    'bfs: while let Some(node) = queue.pop_front() {
        if node < m {
            let i = node;
            for &j in &adj_s[i] {
                let t_node = m + j;
                if !visited[t_node] {
                    visited[t_node] = true;
                    parent[t_node] = Some((node, (i, j)));
                    if j == tj {
                        break 'bfs;
                    }
                    queue.push_back(t_node);
                }
            }
        } else {
            let j = node - m;
            for &i in &adj_t[j] {
                if !visited[i] {
                    visited[i] = true;
                    parent[i] = Some((node, (i, j)));
                    queue.push_back(i);
                }
            }
        }
    }
    let t_node = m + tj;
    if !visited[t_node] {
        return None;
    }
    let mut path = Vec::new();
    let mut cur = t_node;
    while cur != si {
        let (prev, arc) = parent[cur]?;
        path.push(arc);
        cur = prev;
    }
    path.reverse();
    Some(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn identity_cost_gives_diagonal() {
        let a = vec![0.5, 0.5];
        let b = vec![0.5, 0.5];
        let c = Mat::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        let r = emd(&a, &b, &c);
        assert!((r.cost - 0.0).abs() < 1e-9, "cost={}", r.cost);
        assert!((r.plan[(0, 0)] - 0.5).abs() < 1e-9);
        assert!((r.plan[(1, 1)] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn forced_cross_transport() {
        // Source mass concentrated where it must move.
        let a = vec![1.0, 0.0];
        let b = vec![0.5, 0.5];
        let c = Mat::from_vec(2, 2, vec![0.0, 2.0, 3.0, 0.0]);
        let r = emd(&a, &b, &c);
        assert!((r.cost - 1.0).abs() < 1e-8, "cost={}", r.cost); // 0.5·0 + 0.5·2
        assert!((r.plan[(0, 1)] - 0.5).abs() < 1e-8);
    }

    #[test]
    fn rectangular_instances() {
        let a = vec![0.3, 0.7];
        let b = vec![0.2, 0.5, 0.3];
        let c = Mat::from_vec(2, 3, vec![1.0, 3.0, 5.0, 2.0, 1.0, 4.0]);
        let r = emd(&a, &b, &c);
        // Feasibility.
        let rs = r.plan.row_sums();
        let cs = r.plan.col_sums();
        for (got, want) in rs.iter().zip(&a) {
            assert!((got - want).abs() < 1e-8);
        }
        for (got, want) in cs.iter().zip(&b) {
            assert!((got - want).abs() < 1e-7);
        }
        // Optimality certificate: dual feasibility + complementary slackness.
        for i in 0..2 {
            for j in 0..3 {
                let red = c[(i, j)] - r.u[i] - r.v[j];
                assert!(red > -1e-8, "dual infeasible at ({i},{j}): {red}");
                if r.plan[(i, j)] > 1e-9 {
                    assert!(red.abs() < 1e-8, "slackness violated at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn beats_random_feasible_plans() {
        let mut rng = Pcg64::new(55);
        for trial in 0..10 {
            let m = 4;
            let n = 5;
            let a: Vec<f64> = {
                let mut v: Vec<f64> = (0..m).map(|_| rng.exp1() + 0.01).collect();
                let s: f64 = v.iter().sum();
                v.iter_mut().for_each(|x| *x /= s);
                v
            };
            let b: Vec<f64> = {
                let mut v: Vec<f64> = (0..n).map(|_| rng.exp1() + 0.01).collect();
                let s: f64 = v.iter().sum();
                v.iter_mut().for_each(|x| *x /= s);
                v
            };
            let c = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
            let r = emd(&a, &b, &c);
            // Compare against independent couplings a⊗b mixed with random
            // Sinkhorn-ish feasible plans.
            let indep = Mat::from_fn(m, n, |i, j| a[i] * b[j]);
            assert!(
                r.cost <= indep.frobenius_dot(&c) + 1e-9,
                "trial {trial}: emd {} > independent {}",
                r.cost,
                indep.frobenius_dot(&c)
            );
            // Certificate check.
            for i in 0..m {
                for j in 0..n {
                    assert!(c[(i, j)] - r.u[i] - r.v[j] > -1e-7);
                }
            }
            // Duality: Σ u_i a_i + Σ v_j b_j == cost.
            let dual: f64 = r.u.iter().zip(&a).map(|(&x, &y)| x * y).sum::<f64>()
                + r.v.iter().zip(&b).map(|(&x, &y)| x * y).sum::<f64>();
            assert!((dual - r.cost).abs() < 1e-6, "gap: {dual} vs {}", r.cost);
        }
    }

    #[test]
    fn single_row_and_column() {
        let r = emd(&[1.0], &[0.4, 0.6], &Mat::from_vec(1, 2, vec![2.0, 3.0]));
        assert!((r.cost - (0.4 * 2.0 + 0.6 * 3.0)).abs() < 1e-9);
        let r = emd(&[0.4, 0.6], &[1.0], &Mat::from_vec(2, 1, vec![2.0, 3.0]));
        assert!((r.cost - (0.4 * 2.0 + 0.6 * 3.0)).abs() < 1e-9);
    }
}
