//! Benchmark harness substrate (criterion is unavailable offline).
//!
//! Provides warmup + timed-iteration measurement with robust statistics,
//! paper-style gain tables, and markdown/CSV report emission. Every
//! `rust/benches/*.rs` target (one per paper figure/table) is a
//! `harness = false` binary built on this module.

mod runner;
mod stats;
mod table;

pub use runner::{bench_fn, BenchOptions, Measurement};
pub use stats::{percentile_sorted, Summary};
pub use table::{write_csv, Table};

use std::time::Instant;

/// Simple scope timer returning elapsed seconds.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

/// Standard output directory for bench reports (created on demand).
pub fn report_dir() -> std::path::PathBuf {
    let dir = std::env::var("GRPOT_REPORT_DIR").unwrap_or_else(|_| "reports".to_string());
    let p = std::path::PathBuf::from(dir);
    let _ = std::fs::create_dir_all(&p);
    p
}

/// `true` when `GRPOT_BENCH_QUICK` is set: benches shrink their grids so
/// the whole suite stays minutes, not hours. The full paper-scale grid
/// runs with the env var unset. Smoke mode implies quick mode.
pub fn quick_mode() -> bool {
    smoke_mode() || env_flag("GRPOT_BENCH_QUICK")
}

/// `true` when `GRPOT_BENCH_SMOKE` is set: every bench binary runs one
/// tiny iteration per case (problem sizes collapse, [`bench_fn`] takes a
/// single timed sample, statistical shape assertions are skipped) so CI
/// can exercise all bench binaries end-to-end in seconds.
pub fn smoke_mode() -> bool {
    env_flag("GRPOT_BENCH_SMOKE")
}

fn env_flag(name: &str) -> bool {
    std::env::var(name).map(|v| v != "0").unwrap_or(false)
}

#[cfg(test)]
mod tests;
