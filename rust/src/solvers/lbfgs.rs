//! Limited-memory BFGS with strong-Wolfe line search.
//!
//! Implemented as a *resumable* state machine: the Algorithm-1 driver
//! calls [`Lbfgs::step`] in blocks of `r` iterations and refreshes the
//! screening snapshots in between without losing curvature memory.
//!
//! The solver's own dot/axpy reductions are `O(m + n)` per iteration —
//! dwarfed by the oracle's `O(|L|·n·g)` evaluation — and stay serial on
//! purpose: intra-solve parallelism lives in the oracles (see
//! [`crate::pool::ParallelCtx`]), whose deterministic ordered reduction
//! keeps the whole trajectory bit-identical at any thread count. A
//! parallel dot here would buy nothing and break that invariant.
//!
//! Context lifetime: the oracle (not the solver) owns the
//! `ParallelCtx`, so its persistent parked workers survive across the
//! `r`-iteration step blocks, the `refresh` calls between them, and —
//! when the caller threads a long-lived ctx through `solve_*_ctx` —
//! across whole solves. `Lbfgs` itself never spawns or parks threads;
//! every `step`/`run` call drives the same worker set through the
//! oracle it is handed.

use super::linesearch::{WolfeMachine, WolfeOptions, WolfePoll};
use super::{StepStatus, StopReason};
use crate::linalg;
use crate::ot::dual::DualOracle;
use std::collections::VecDeque;

/// L-BFGS options (defaults follow scipy's L-BFGS-B: m=10,
/// ftol≈2.2e-9, gtol=1e-5).
#[derive(Clone, Debug)]
pub struct LbfgsOptions {
    /// Number of stored (s, y) pairs.
    pub memory: usize,
    /// Hard iteration cap.
    pub max_iters: usize,
    /// Stop when `‖∇f‖∞ ≤ gtol`.
    pub gtol: f64,
    /// Stop when `(f_prev − f) ≤ ftol · max(|f|, |f_prev|, 1)`.
    pub ftol: f64,
    /// Line-search parameters.
    pub wolfe: WolfeOptions,
}

impl Default for LbfgsOptions {
    fn default() -> Self {
        LbfgsOptions {
            memory: 10,
            max_iters: 1000,
            gtol: 1e-5,
            ftol: 2.2e-9,
            wolfe: WolfeOptions::default(),
        }
    }
}

/// What the caller must do next while driving an [`Lbfgs`] pump.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LbfgsStatus {
    /// Evaluate `f`/`∇f` at [`Lbfgs::pending`] and feed the pair back
    /// through [`Lbfgs::supply`].
    NeedEval,
    /// The initial iterate's value and gradient are now in place
    /// (returned by the first `supply` of a [`Lbfgs::deferred`] solver;
    /// no iteration has run yet).
    Seeded,
    /// One full L-BFGS iteration completed.
    Iterated,
    /// A stop condition fired; no further evaluations are needed.
    Stopped(StopReason),
}

/// Solver phase for the poll-driven evaluation pump.
enum Phase {
    /// Waiting for `f`/`∇f` at the initial iterate.
    Seed,
    /// Between iterations: the next `advance` runs the iteration head
    /// (stop checks, search direction) and starts a line search.
    Ready,
    /// Inside a line search along `dir`.
    Searching { dir: Vec<f64>, machine: WolfeMachine },
}

/// Resumable L-BFGS state.
///
/// Two driving modes share one implementation of the math:
/// [`Lbfgs::step`]/[`Lbfgs::run`] pull evaluations from an oracle the
/// caller hands in (the sequential path), while
/// [`Lbfgs::advance`]/[`Lbfgs::supply`] invert control so an external
/// driver can fuse the oracle evaluations of several independent
/// solvers into one pass (the batched path, [`crate::ot::batch`]).
/// `step` is itself a pump over `advance`/`supply`, so the two modes
/// perform bit-identical arithmetic by construction.
pub struct Lbfgs {
    opts: LbfgsOptions,
    x: Vec<f64>,
    f: f64,
    g: Vec<f64>,
    s_mem: VecDeque<Vec<f64>>,
    y_mem: VecDeque<Vec<f64>>,
    rho_mem: VecDeque<f64>,
    iter: usize,
    stopped: Option<StopReason>,
    phase: Phase,
    /// The point whose `f`/`∇f` the next `supply` call expects.
    x_trial: Vec<f64>,
}

impl Lbfgs {
    /// Initialize at `x0` (evaluates the oracle once).
    ///
    /// `x0` may be any finite iterate, not just the origin — the serving
    /// engine warm-starts from cached near-optimal duals this way. The
    /// solver makes no assumption about the starting point: curvature
    /// memory starts empty and the first step uses the 1/‖g‖ scaling
    /// heuristic, so a warm start close to the optimum converges in a
    /// handful of iterations.
    pub fn new(x0: Vec<f64>, opts: LbfgsOptions, oracle: &mut dyn DualOracle) -> Self {
        let mut solver = Lbfgs::deferred(x0, opts);
        let mut g = vec![0.0; solver.x.len()];
        let f = oracle.eval(&solver.x_trial, &mut g);
        solver.supply(f, &g);
        solver
    }

    /// Initialize at `x0` *without* evaluating: the solver starts in the
    /// seed phase and the first [`Self::supply`] must carry `f`/`∇f` at
    /// `x0` (evaluated at [`Self::pending`]). Used by the batched driver
    /// to fold the K initial evaluations into one fused pass.
    pub fn deferred(x0: Vec<f64>, opts: LbfgsOptions) -> Self {
        debug_assert!(x0.iter().all(|v| v.is_finite()), "non-finite warm-start iterate");
        let n = x0.len();
        Lbfgs {
            opts,
            x_trial: x0.clone(),
            x: x0,
            f: f64::NAN,
            g: vec![0.0; n],
            s_mem: VecDeque::new(),
            y_mem: VecDeque::new(),
            rho_mem: VecDeque::new(),
            iter: 0,
            stopped: None,
            phase: Phase::Seed,
        }
    }

    /// The iterate whose `f`/`∇f` the next [`Self::supply`] call expects
    /// (only meaningful after `advance` returned [`LbfgsStatus::NeedEval`]).
    pub fn pending(&self) -> &[f64] {
        &self.x_trial
    }

    /// Current iterate.
    pub fn x(&self) -> &[f64] {
        &self.x
    }

    /// Current objective value.
    pub fn f(&self) -> f64 {
        self.f
    }

    /// Current gradient.
    pub fn grad(&self) -> &[f64] {
        &self.g
    }

    /// Completed iterations.
    pub fn iterations(&self) -> usize {
        self.iter
    }

    /// Why the solver stopped, if it has.
    pub fn stop_reason(&self) -> Option<StopReason> {
        self.stopped
    }

    /// Consume into `(x, f)`.
    pub fn into_solution(self) -> (Vec<f64>, f64) {
        (self.x, self.f)
    }

    /// Two-loop recursion: `dir = −H·g`.
    fn search_direction(&self) -> Vec<f64> {
        let k = self.s_mem.len();
        let mut q: Vec<f64> = self.g.clone();
        if k == 0 {
            for v in q.iter_mut() {
                *v = -*v;
            }
            return q;
        }
        let mut alphas = vec![0.0; k];
        for idx in (0..k).rev() {
            let a = self.rho_mem[idx] * linalg::dot(&self.s_mem[idx], &q);
            alphas[idx] = a;
            linalg::axpy(-a, &self.y_mem[idx], &mut q);
        }
        // Initial Hessian scaling γ = sᵀy / yᵀy (most recent pair).
        let last = k - 1;
        let sy = 1.0 / self.rho_mem[last];
        let yy = linalg::nrm2_sq(&self.y_mem[last]);
        let gamma = if yy > 0.0 { sy / yy } else { 1.0 };
        linalg::scal(gamma, &mut q);
        for idx in 0..k {
            let b = self.rho_mem[idx] * linalg::dot(&self.y_mem[idx], &q);
            linalg::axpy(alphas[idx] - b, &self.s_mem[idx], &mut q);
        }
        for v in q.iter_mut() {
            *v = -*v;
        }
        q
    }

    /// Drive the pump forward without evaluating: returns `NeedEval`
    /// when an oracle evaluation at [`Self::pending`] is required, or a
    /// terminal `Stopped`. Running the iteration head (stop checks +
    /// search direction) happens here; finishing an iteration happens in
    /// [`Self::supply`].
    pub fn advance(&mut self) -> LbfgsStatus {
        if let Some(r) = self.stopped {
            return LbfgsStatus::Stopped(r);
        }
        match self.phase {
            Phase::Seed | Phase::Searching { .. } => return LbfgsStatus::NeedEval,
            Phase::Ready => {}
        }
        if linalg::nrm_inf(&self.g) <= self.opts.gtol {
            self.stopped = Some(StopReason::GradTol);
            return LbfgsStatus::Stopped(StopReason::GradTol);
        }
        if self.iter >= self.opts.max_iters {
            self.stopped = Some(StopReason::MaxIters);
            return LbfgsStatus::Stopped(StopReason::MaxIters);
        }

        let mut dir = self.search_direction();
        let mut dphi0 = linalg::dot(&self.g, &dir);
        if dphi0 >= 0.0 {
            // Memory produced a non-descent direction (can happen after
            // pathological curvature); restart from steepest descent.
            self.s_mem.clear();
            self.y_mem.clear();
            self.rho_mem.clear();
            dir = self.g.iter().map(|&v| -v).collect();
            dphi0 = linalg::dot(&self.g, &dir);
            if dphi0 >= 0.0 {
                self.stopped = Some(StopReason::GradTol);
                return LbfgsStatus::Stopped(StopReason::GradTol);
            }
        }

        // First iteration: scale the step like 1/‖g‖ (standard heuristic).
        let init_step = if self.s_mem.is_empty() {
            (1.0 / linalg::nrm_inf(&self.g).max(1e-12)).min(1.0)
        } else {
            1.0
        };

        let machine = match WolfeMachine::new(self.f, dphi0, init_step, &self.opts.wolfe) {
            Some(m) => m,
            None => {
                self.stopped = Some(StopReason::LineSearchFailed);
                return LbfgsStatus::Stopped(StopReason::LineSearchFailed);
            }
        };
        self.set_trial(machine.pending_step(), &dir);
        self.phase = Phase::Searching { dir, machine };
        LbfgsStatus::NeedEval
    }

    /// `x_trial = x + t·dir` (same update as the line search's `φ`).
    fn set_trial(&mut self, t: f64, dir: &[f64]) {
        for ((xi, &x0i), &di) in self.x_trial.iter_mut().zip(&self.x).zip(dir) {
            *xi = x0i + t * di;
        }
    }

    /// Feed the `f`/`∇f` pair evaluated at [`Self::pending`] into the
    /// pump. Returns `Seeded` after the initial evaluation, `NeedEval`
    /// when the line search wants another point, `Iterated` when one
    /// full iteration just completed, or a terminal `Stopped`.
    pub fn supply(&mut self, f: f64, grad: &[f64]) -> LbfgsStatus {
        debug_assert_eq!(grad.len(), self.x.len());
        match std::mem::replace(&mut self.phase, Phase::Ready) {
            Phase::Seed => {
                self.f = f;
                self.g.copy_from_slice(grad);
                LbfgsStatus::Seeded
            }
            Phase::Ready => panic!("Lbfgs::supply called without a pending evaluation"),
            Phase::Searching { dir, mut machine } => {
                let step = machine.pending_step();
                let dphit = linalg::dot(grad, &dir);
                match machine.advance(f, dphit) {
                    WolfePoll::Eval(t) => {
                        self.set_trial(t, &dir);
                        self.phase = Phase::Searching { dir, machine };
                        LbfgsStatus::NeedEval
                    }
                    WolfePoll::Accept { step: _, f: ft } => self.finish_iteration(dir, step, ft, grad),
                    WolfePoll::Fail => {
                        self.stopped = Some(StopReason::LineSearchFailed);
                        LbfgsStatus::Stopped(StopReason::LineSearchFailed)
                    }
                }
            }
        }
    }

    /// Accepted line-search point: update curvature memory, iterate, and
    /// run the ftol check. `grad` is `∇f` at the accepted point.
    fn finish_iteration(&mut self, dir: Vec<f64>, step: f64, ft: f64, grad: &[f64]) -> LbfgsStatus {
        // Update memory with s = t·d, y = g_new − g_old.
        let mut s = dir;
        linalg::scal(step, &mut s);
        let y: Vec<f64> = grad.iter().zip(&self.g).map(|(&a, &b)| a - b).collect();
        let sy = linalg::dot(&s, &y);
        if sy > 1e-12 * linalg::nrm2(&s) * linalg::nrm2(&y) {
            if self.s_mem.len() == self.opts.memory {
                self.s_mem.pop_front();
                self.y_mem.pop_front();
                self.rho_mem.pop_front();
            }
            self.rho_mem.push_back(1.0 / sy);
            self.s_mem.push_back(s.clone());
            self.y_mem.push_back(y);
        }

        let f_prev = self.f;
        for (xi, &si) in self.x.iter_mut().zip(&s) {
            *xi += si;
        }
        self.f = ft;
        self.g.copy_from_slice(grad);
        self.iter += 1;

        let fscale = self.f.abs().max(f_prev.abs()).max(1.0);
        if f_prev - self.f <= self.opts.ftol * fscale {
            self.stopped = Some(StopReason::FTol);
            return LbfgsStatus::Stopped(StopReason::FTol);
        }
        LbfgsStatus::Iterated
    }

    /// One L-BFGS iteration. Returns `Continue` or a terminal status.
    /// Pump loop over [`Self::advance`]/[`Self::supply`].
    pub fn step(&mut self, oracle: &mut dyn DualOracle) -> StepStatus {
        let mut gbuf = vec![0.0; self.x.len()];
        loop {
            match self.advance() {
                LbfgsStatus::NeedEval => {
                    let f = oracle.eval(&self.x_trial, &mut gbuf);
                    match self.supply(f, &gbuf) {
                        LbfgsStatus::Iterated => return StepStatus::Continue,
                        LbfgsStatus::Stopped(r) => return StepStatus::Stopped(r),
                        LbfgsStatus::Seeded | LbfgsStatus::NeedEval => {}
                    }
                }
                LbfgsStatus::Stopped(r) => return StepStatus::Stopped(r),
                LbfgsStatus::Seeded | LbfgsStatus::Iterated => {
                    unreachable!("advance never yields Seeded/Iterated")
                }
            }
        }
    }

    /// Run until a stop condition fires; returns the reason.
    pub fn run(&mut self, oracle: &mut dyn DualOracle) -> StopReason {
        loop {
            if let StepStatus::Stopped(r) = self.step(oracle) {
                return r;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::dual::OracleStats;

    /// Adapter: plain smooth function as a DualOracle for solver tests.
    pub struct FnOracle<F: FnMut(&[f64], &mut [f64]) -> f64> {
        pub f: F,
        pub dim: usize,
        pub stats: OracleStats,
    }

    impl<F: FnMut(&[f64], &mut [f64]) -> f64> DualOracle for FnOracle<F> {
        fn shape(&self) -> (usize, usize) {
            (self.dim, 0)
        }
        fn eval(&mut self, x: &[f64], grad: &mut [f64]) -> f64 {
            self.stats.evals += 1;
            (self.f)(x, grad)
        }
        fn stats(&self) -> &OracleStats {
            &self.stats
        }
    }

    #[test]
    fn minimizes_quadratic_exactly() {
        // f(x) = ½Σ d_i (x_i − c_i)²
        let d = [1.0, 10.0, 100.0];
        let c = [1.0, -2.0, 3.0];
        let mut oracle = FnOracle {
            dim: 3,
            stats: OracleStats::default(),
            f: move |x: &[f64], g: &mut [f64]| {
                let mut f = 0.0;
                for i in 0..3 {
                    let e = x[i] - c[i];
                    g[i] = d[i] * e;
                    f += 0.5 * d[i] * e * e;
                }
                f
            },
        };
        let mut solver = Lbfgs::new(vec![0.0; 3], LbfgsOptions::default(), &mut oracle);
        let reason = solver.run(&mut oracle);
        assert!(matches!(reason, StopReason::GradTol | StopReason::FTol), "{reason:?}");
        for i in 0..3 {
            assert!((solver.x()[i] - c[i]).abs() < 1e-4, "x[{i}]={}", solver.x()[i]);
        }
    }

    #[test]
    fn minimizes_rosenbrock() {
        let mut oracle = FnOracle {
            dim: 2,
            stats: OracleStats::default(),
            f: |x: &[f64], g: &mut [f64]| {
                let (a, b) = (x[0], x[1]);
                g[0] = -2.0 * (1.0 - a) - 400.0 * a * (b - a * a);
                g[1] = 200.0 * (b - a * a);
                (1.0 - a).powi(2) + 100.0 * (b - a * a).powi(2)
            },
        };
        let opts = LbfgsOptions { max_iters: 500, ftol: 1e-14, ..Default::default() };
        let mut solver = Lbfgs::new(vec![-1.2, 1.0], opts, &mut oracle);
        solver.run(&mut oracle);
        assert!((solver.x()[0] - 1.0).abs() < 1e-3, "x={:?}", solver.x());
        assert!((solver.x()[1] - 1.0).abs() < 1e-3, "x={:?}", solver.x());
        assert!(solver.f() < 1e-6);
    }

    #[test]
    fn resumable_stepping_matches_run() {
        // Stepping one-by-one must reach the same solution as run().
        let mk = || FnOracle {
            dim: 2,
            stats: OracleStats::default(),
            f: |x: &[f64], g: &mut [f64]| {
                g[0] = 2.0 * x[0] + x[1];
                g[1] = x[0] + 4.0 * x[1] - 3.0;
                x[0] * x[0] + 0.5 * x[0] * x[1] + 2.0 * x[1] * x[1] - 3.0 * x[1]
            },
        };
        let mut o1 = mk();
        let mut s1 = Lbfgs::new(vec![5.0, -5.0], LbfgsOptions::default(), &mut o1);
        s1.run(&mut o1);

        let mut o2 = mk();
        let mut s2 = Lbfgs::new(vec![5.0, -5.0], LbfgsOptions::default(), &mut o2);
        while let StepStatus::Continue = s2.step(&mut o2) {}
        assert_eq!(s1.x(), s2.x());
        assert_eq!(s1.f(), s2.f());
    }

    #[test]
    fn warm_start_near_optimum_converges_fast() {
        // Seeding at (almost) the minimizer must terminate in far fewer
        // iterations than the cold solve and reach the same objective.
        let d = [2.0, 30.0, 7.0];
        let c = [0.5, -1.5, 2.0];
        let mk = || FnOracle {
            dim: 3,
            stats: OracleStats::default(),
            f: move |x: &[f64], g: &mut [f64]| {
                let mut f = 0.0;
                for i in 0..3 {
                    let e = x[i] - c[i];
                    g[i] = d[i] * e;
                    f += 0.5 * d[i] * e * e;
                }
                f
            },
        };
        let mut o_cold = mk();
        let mut cold = Lbfgs::new(vec![10.0; 3], LbfgsOptions::default(), &mut o_cold);
        cold.run(&mut o_cold);

        let mut o_warm = mk();
        let mut warm = Lbfgs::new(cold.x().to_vec(), LbfgsOptions::default(), &mut o_warm);
        warm.run(&mut o_warm);
        assert!(
            warm.iterations() <= 2,
            "warm start took {} iterations",
            warm.iterations()
        );
        assert!((warm.f() - cold.f()).abs() <= 1e-12, "{} vs {}", warm.f(), cold.f());
    }

    #[test]
    fn respects_max_iters() {
        let mut oracle = FnOracle {
            dim: 1,
            stats: OracleStats::default(),
            f: |x: &[f64], g: &mut [f64]| {
                g[0] = x[0].signum() * 1.0 + x[0] * 1e-3; // slow crawl
                x[0].abs() + 0.5e-3 * x[0] * x[0]
            },
        };
        let opts = LbfgsOptions { max_iters: 3, ftol: 0.0, gtol: 0.0, ..Default::default() };
        let mut solver = Lbfgs::new(vec![100.0], opts, &mut oracle);
        let reason = solver.run(&mut oracle);
        // Non-smooth kink: either hits the cap or stalls in line search.
        assert!(matches!(reason, StopReason::MaxIters | StopReason::LineSearchFailed));
        assert!(solver.iterations() <= 3);
    }

    #[test]
    fn deferred_pump_matches_eager_run_bitwise() {
        // Driving the solver externally through advance/supply must
        // reproduce the oracle-pulling path bit-for-bit: same iterates,
        // same objective, same evaluation count.
        let mk = || FnOracle {
            dim: 2,
            stats: OracleStats::default(),
            f: |x: &[f64], g: &mut [f64]| {
                let (a, b) = (x[0], x[1]);
                g[0] = -2.0 * (1.0 - a) - 400.0 * a * (b - a * a);
                g[1] = 200.0 * (b - a * a);
                (1.0 - a).powi(2) + 100.0 * (b - a * a).powi(2)
            },
        };
        let opts = LbfgsOptions { max_iters: 200, ftol: 1e-14, ..Default::default() };

        let mut o1 = mk();
        let mut s1 = Lbfgs::new(vec![-1.2, 1.0], opts.clone(), &mut o1);
        let r1 = s1.run(&mut o1);

        let mut o2 = mk();
        let mut s2 = Lbfgs::deferred(vec![-1.2, 1.0], opts);
        let mut g = vec![0.0; 2];
        let r2 = loop {
            match s2.advance() {
                LbfgsStatus::NeedEval => {
                    let x = s2.pending().to_vec();
                    let f = o2.eval(&x, &mut g);
                    if let LbfgsStatus::Stopped(r) = s2.supply(f, &g) {
                        break r;
                    }
                }
                LbfgsStatus::Stopped(r) => break r,
                LbfgsStatus::Seeded | LbfgsStatus::Iterated => unreachable!(),
            }
        };
        assert_eq!(r1, r2);
        assert_eq!(s1.x(), s2.x());
        assert_eq!(s1.f().to_bits(), s2.f().to_bits());
        assert_eq!(s1.iterations(), s2.iterations());
        assert_eq!(o1.stats.evals, o2.stats.evals);
    }
}
