//! Per-solve telemetry: [`SolveReport`] and the observer hook that
//! delivers it.
//!
//! A report is assembled by the Algorithm-1 driver (and the semi-dual
//! solver) from counters the solve *already* maintains — `OracleStats`,
//! the working-set size, the pool's park/wake counters — so producing
//! it never touches the bit-exact kernel math. The headline field is
//! [`SolveReport::skipped_group_fraction`]: the fraction of group
//! gradients the paper's safe-screening bound (Lemmas 1–3) skipped,
//! computed from the same counters the solver result carries, so the
//! two agree byte-for-byte.

use crate::jsonlite::Value;
use std::fmt;
use std::sync::{Arc, Mutex};

/// Skipped-group fraction from raw counters: `skipped / (computed +
/// skipped)`, 0 when nothing was evaluated.
pub fn skipped_fraction(grads_computed: u64, grads_skipped: u64) -> f64 {
    let total = grads_computed + grads_skipped;
    if total == 0 {
        0.0
    } else {
        grads_skipped as f64 / total as f64
    }
}

/// Screening counters for one outer round (one `r`-iteration L-BFGS
/// block + working-set refresh): deltas of the oracle's cumulative
/// counters across the round.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RoundTelemetry {
    /// 1-based outer-round index.
    pub round: u32,
    pub grads_computed: u64,
    pub grads_skipped: u64,
    pub ub_checks: u64,
    pub ws_hits: u64,
    /// Working-set density |ℕ| / (L·n) *after* this round's refresh
    /// (None for oracles without a working set).
    pub ws_density: Option<f64>,
}

impl RoundTelemetry {
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj()
            .set("round", self.round as u64)
            .set("grads_computed", self.grads_computed)
            .set("grads_skipped", self.grads_skipped)
            .set("ub_checks", self.ub_checks)
            .set("ws_hits", self.ws_hits)
            .set(
                "skip_rate",
                skipped_fraction(self.grads_computed, self.grads_skipped),
            );
        if let Some(d) = self.ws_density {
            v = v.set("ws_density", d);
        }
        v
    }
}

/// Worker-pool utilization over one solve: busy vs parked nanoseconds
/// and park/wake transition counts, from the pool's always-on counters
/// (nanosecond timing only accumulates while tracing is enabled).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolUtilization {
    pub busy_ns: u64,
    pub parked_ns: u64,
    pub parks: u64,
    pub wakes: u64,
}

impl PoolUtilization {
    /// Counter delta `self − earlier` (saturating; pools are shared
    /// across solves, so per-solve numbers are start/end differences).
    pub fn since(&self, earlier: &PoolUtilization) -> PoolUtilization {
        PoolUtilization {
            busy_ns: self.busy_ns.saturating_sub(earlier.busy_ns),
            parked_ns: self.parked_ns.saturating_sub(earlier.parked_ns),
            parks: self.parks.saturating_sub(earlier.parks),
            wakes: self.wakes.saturating_sub(earlier.wakes),
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("busy_ns", self.busy_ns)
            .set("parked_ns", self.parked_ns)
            .set("parks", self.parks)
            .set("wakes", self.wakes)
    }
}

/// Everything one solve can tell an operator, assembled at the end of
/// the run and delivered through [`ObserverHook`].
#[derive(Clone, Debug, Default)]
pub struct SolveReport {
    /// Solver label (`fast`, `origin`, `semidual+negentropy`, …).
    pub method: String,
    /// Request trace ID (0 outside the serving path).
    pub trace_id: u64,
    /// Why the solver stopped ([`crate::solvers::StopReason::name`]:
    /// `grad_tol` | `ftol` | `max_iters` | `line_search_failed` |
    /// `cancelled`; empty when unset). Distinguishes a mid-solve
    /// cancellation from a converged result in telemetry.
    pub stop: &'static str,
    /// L-BFGS iterations taken.
    pub iterations: usize,
    /// Outer rounds completed (working-set refreshes).
    pub outer_rounds: usize,
    /// Oracle (value+gradient) evaluations.
    pub evals: u64,
    /// Evaluations beyond one per iteration — line-search backtracks.
    pub line_search_evals: u64,
    pub grads_computed: u64,
    pub grads_skipped: u64,
    pub ub_checks: u64,
    pub ws_hits: u64,
    /// Cost tiles synthesized on demand by the factored cost backend
    /// (0 under a dense resident matrix). Screened-out groups never
    /// synthesize a tile, so `tiles_built` under the fast solver drops
    /// with the skip rate. Dispatch-dependent (scalar evaluates per
    /// group segment, vector per tile-ring miss) — a throughput
    /// diagnostic, not part of the bit-exact solver output.
    pub tiles_built: u64,
    /// The paper's headline quantity: fraction of group gradients the
    /// screening bound skipped. Equals
    /// [`skipped_fraction`]`(grads_computed, grads_skipped)` over the
    /// same `OracleStats` the solver result carries.
    pub skipped_group_fraction: f64,
    /// Kernel backend the oracle dispatched to (`scalar`, `avx2`, …).
    pub simd_backend: &'static str,
    /// Per-outer-round counter deltas (the density trajectory).
    pub rounds: Vec<RoundTelemetry>,
    /// Worker-pool utilization delta across this solve.
    pub pool: PoolUtilization,
    pub wall_time_s: f64,
}

impl SolveReport {
    /// Full JSON (sweep reports, `--trace-out` sidecars).
    pub fn to_json(&self) -> Value {
        self.compact_json().set(
            "rounds",
            Value::Arr(self.rounds.iter().map(RoundTelemetry::to_json).collect()),
        )
    }

    /// Compact JSON for the serve response's `"telemetry"` echo: the
    /// scalars only, no per-round trajectory.
    pub fn compact_json(&self) -> Value {
        Value::obj()
            .set("method", self.method.as_str())
            .set("trace_id", self.trace_id)
            .set("stop", self.stop)
            .set("iterations", self.iterations)
            .set("outer_rounds", self.outer_rounds)
            .set("evals", self.evals)
            .set("line_search_evals", self.line_search_evals)
            .set("grads_computed", self.grads_computed)
            .set("grads_skipped", self.grads_skipped)
            .set("ub_checks", self.ub_checks)
            .set("ws_hits", self.ws_hits)
            .set("tiles_built", self.tiles_built)
            .set("skipped_group_fraction", self.skipped_group_fraction)
            .set("simd_backend", self.simd_backend)
            .set("pool", self.pool.to_json())
            .set("wall_time_s", self.wall_time_s)
    }
}

/// Shareable observer invoked with the finished [`SolveReport`]. Cloned
/// into solver configs; the wrapper keeps those configs `Debug` +
/// `Clone` without exposing the closure.
#[derive(Clone)]
pub struct ObserverHook(Arc<dyn Fn(&SolveReport) + Send + Sync>);

impl ObserverHook {
    pub fn new(f: impl Fn(&SolveReport) + Send + Sync + 'static) -> ObserverHook {
        ObserverHook(Arc::new(f))
    }

    /// Hook that stores the last report in a shared cell — the common
    /// "run one solve, read its report" pattern.
    pub fn capture() -> (ObserverHook, Arc<Mutex<Option<SolveReport>>>) {
        let cell: Arc<Mutex<Option<SolveReport>>> = Arc::new(Mutex::new(None));
        let sink = Arc::clone(&cell);
        let hook = ObserverHook::new(move |r| {
            *sink.lock().unwrap_or_else(std::sync::PoisonError::into_inner) =
                Some(r.clone());
        });
        (hook, cell)
    }

    pub fn emit(&self, report: &SolveReport) {
        (self.0)(report);
    }
}

impl fmt::Debug for ObserverHook {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("ObserverHook(..)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skipped_fraction_edges() {
        assert_eq!(skipped_fraction(0, 0), 0.0);
        assert_eq!(skipped_fraction(1, 3), 0.75);
        assert_eq!(skipped_fraction(5, 0), 0.0);
    }

    #[test]
    fn capture_hook_stores_last_report() {
        let (hook, cell) = ObserverHook::capture();
        assert!(cell.lock().unwrap().is_none());
        let mut report = SolveReport { trace_id: 9, ..Default::default() };
        report.skipped_group_fraction = 0.5;
        hook.emit(&report);
        let got = cell.lock().unwrap().clone().expect("captured");
        assert_eq!(got.trace_id, 9);
        assert_eq!(got.skipped_group_fraction, 0.5);
        assert_eq!(format!("{hook:?}"), "ObserverHook(..)");
    }

    #[test]
    fn pool_delta_saturates() {
        let a = PoolUtilization { busy_ns: 10, parked_ns: 5, parks: 2, wakes: 2 };
        let b = PoolUtilization { busy_ns: 25, parked_ns: 9, parks: 3, wakes: 4 };
        assert_eq!(
            b.since(&a),
            PoolUtilization { busy_ns: 15, parked_ns: 4, parks: 1, wakes: 2 }
        );
        assert_eq!(a.since(&b).busy_ns, 0);
    }

    #[test]
    fn report_json_roundtrips_headline_fields() {
        let report = SolveReport {
            method: "fast".into(),
            trace_id: 3,
            grads_computed: 10,
            grads_skipped: 30,
            skipped_group_fraction: 0.75,
            simd_backend: "scalar",
            rounds: vec![RoundTelemetry {
                round: 1,
                grads_computed: 10,
                grads_skipped: 30,
                ws_density: Some(0.25),
                ..Default::default()
            }],
            ..Default::default()
        };
        let v = report.to_json();
        assert_eq!(
            v.get("skipped_group_fraction").and_then(Value::as_f64),
            Some(0.75)
        );
        let rounds = v.get("rounds").and_then(Value::as_arr).unwrap();
        assert_eq!(rounds.len(), 1);
        assert_eq!(
            rounds[0].get("ws_density").and_then(Value::as_f64),
            Some(0.25)
        );
        assert_eq!(rounds[0].get("skip_rate").and_then(Value::as_f64), Some(0.75));
    }
}
