use super::*;

fn demo_app() -> App {
    App::new("grpot", "OT toolkit")
        .arg(ArgSpec::opt("gamma", "reg strength").default("1.0"))
        .arg(ArgSpec::opt("dataset", "dataset name").required())
        .arg(ArgSpec::switch("verbose", "chatty"))
        .subcommand(
            App::new("sweep", "run sweep")
                .arg(ArgSpec::opt("threads", "worker count").default("4"))
                .arg(ArgSpec::opt("gammas", "gamma grid").default("0.1,1,10")),
        )
}

#[test]
fn parses_values_and_defaults() {
    let m = demo_app().parse_from(&["--dataset", "digits"]).unwrap();
    assert_eq!(m.get("dataset"), Some("digits"));
    assert_eq!(m.get_f64("gamma").unwrap(), 1.0);
    assert!(!m.get_flag("verbose"));
}

#[test]
fn parses_equals_form_and_switch() {
    let m = demo_app()
        .parse_from(&["--dataset=faces", "--gamma=0.25", "--verbose"])
        .unwrap();
    assert_eq!(m.get("dataset"), Some("faces"));
    assert_eq!(m.get_f64("gamma").unwrap(), 0.25);
    assert!(m.get_flag("verbose"));
}

#[test]
fn missing_required_errors() {
    let e = demo_app().parse_from(&[]).unwrap_err();
    assert!(e.0.contains("dataset"), "{}", e.0);
}

#[test]
fn unknown_flag_errors() {
    let e = demo_app().parse_from(&["--dataset", "x", "--nope"]).unwrap_err();
    assert!(e.0.contains("unknown option"), "{}", e.0);
}

#[test]
fn subcommand_routing() {
    let m = demo_app().parse_from(&["sweep", "--threads", "8"]).unwrap();
    let (name, sub) = m.subcommand.unwrap();
    assert_eq!(name, "sweep");
    assert_eq!(sub.get_usize("threads").unwrap(), 8);
    assert_eq!(sub.get_f64_list("gammas").unwrap(), vec![0.1, 1.0, 10.0]);
}

#[test]
fn positional_args_collected() {
    let m = demo_app().parse_from(&["--dataset", "d", "file1", "file2"]).unwrap();
    assert_eq!(m.positional, vec!["file1", "file2"]);
}

#[test]
fn help_is_error_with_text() {
    let e = demo_app().parse_from(&["--help"]).unwrap_err();
    assert!(e.0.contains("USAGE"));
    assert!(e.0.contains("sweep"));
    assert!(e.0.contains("--gamma"));
}

#[test]
fn list_parsing_errors() {
    let m = demo_app()
        .parse_from(&["--dataset", "d", "sweep", "--gammas", "1,x"])
        .unwrap();
    let (_, sub) = m.subcommand.unwrap();
    let e = sub.get_f64_list("gammas").unwrap_err();
    assert!(e.0.contains("not a number"));
}

#[test]
fn switch_with_value_errors() {
    let e = demo_app().parse_from(&["--dataset", "d", "--verbose=yes"]).unwrap_err();
    assert!(e.0.contains("takes no value"));
}

#[test]
fn usize_list() {
    let app = App::new("t", "t").arg(ArgSpec::opt("ls", "labels").default("10, 20,40"));
    let m = app.parse_from(&[]).unwrap();
    assert_eq!(m.get_usize_list("ls").unwrap(), vec![10, 20, 40]);
}
