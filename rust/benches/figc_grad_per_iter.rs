//! Figure C (appendix): per-iteration gradient-computation counts
//! (first ten iterations, log scale in the paper) on MNIST→USPS with
//! γ = 0.1, ρ = 0.8 — ours vs the dense count |L|·n.
//!
//! Paper shape: ours skips more computations as iterations progress
//! (bounds tighten), down to 0.037% of dense.

mod common;

use common::*;
use grpot::benchlib::{report_dir, Table};
use grpot::data::digits;
use grpot::ot::fastot::{solve_fast_ot_traced, FastOtConfig};
use grpot::solvers::lbfgs::LbfgsOptions;

fn main() {
    banner("figC: per-iteration gradient counts");
    let samples = size3(60, 300, 800);
    let pair = digits::mnist_to_usps(samples, 0xF16C);
    let prob = problem_of(&pair);
    let cfg = FastOtConfig {
        gamma: 0.1,
        rho: 0.8,
        lbfgs: LbfgsOptions { max_iters: size3(15, 60, 60), ..Default::default() },
        ..Default::default()
    };
    let (_, traces) = solve_fast_ot_traced(&prob, &cfg);

    let mut table = Table::new(
        "Fig. C — per-iteration gradient computations (MNIST→USPS, γ=0.1, ρ=0.8)",
        &["iteration", "computed", "dense equivalent", "% of dense"],
    );
    for t in traces.iter().take(10) {
        // An iteration may contain several function evals (line search);
        // the dense-equivalent count is computed + skipped.
        let dense_eq = t.grads_this_iter + t.skipped_this_iter;
        let pct = 100.0 * t.grads_this_iter as f64 / dense_eq.max(1) as f64;
        table.row(vec![
            format!("{}", t.iteration),
            format!("{}", t.grads_this_iter),
            format!("{}", dense_eq),
            format!("{pct:.3}"),
        ]);
        println!(
            "iter {:>2}: computed {:>8} skipped {:>8}",
            t.iteration, t.grads_this_iter, t.skipped_this_iter
        );
    }
    table.emit(&report_dir(), "figc_grad_per_iter");

    // Shape: fraction computed decreases from iteration 1 to 10.
    let frac = |t: &grpot::ot::fastot::IterationTrace| {
        t.grads_this_iter as f64 / (t.grads_this_iter + t.skipped_this_iter).max(1) as f64
    };
    if !grpot::benchlib::smoke_mode() && traces.len() >= 10 {
        let early = frac(&traces[1]);
        let late = frac(&traces[9]);
        println!("computed fraction: iter1={early:.4} iter9={late:.4}");
        assert!(late <= early + 0.05, "skipping should improve over iterations");
    }
}
