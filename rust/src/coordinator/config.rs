//! Experiment configuration: JSON-file and flag-friendly structs.

use crate::err;
use crate::error::{Context, Result};
use crate::jsonlite::{self, Value};
use crate::ot::cost::CostMode;
use crate::ot::regularizer::RegKind;
use crate::ot::solve::SolveOptions;
use crate::simd::SimdMode;

/// Which solver backend a job uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// The paper's method: screening + working set.
    Fast,
    /// Screening only (Fig. D ablation).
    FastNoWs,
    /// Dense baseline (Blondel et al. 2018).
    Origin,
    /// Dense baseline through the AOT JAX/Pallas artifact via PJRT.
    XlaOrigin,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Fast => "fast",
            Method::FastNoWs => "fast-nows",
            Method::Origin => "origin",
            Method::XlaOrigin => "xla-origin",
        }
    }

    pub fn parse(s: &str) -> Result<Method> {
        match s {
            "fast" | "ours" => Ok(Method::Fast),
            "fast-nows" | "nows" => Ok(Method::FastNoWs),
            "origin" | "baseline" => Ok(Method::Origin),
            "xla-origin" | "xla" => Ok(Method::XlaOrigin),
            other => Err(err!(
                "unknown method '{other}' (expected fast|fast-nows|origin|xla-origin)"
            )),
        }
    }

    /// True when this method can run in the current build: `xla-origin`
    /// needs the `xla` cargo feature. Entry points (CLI, sweep, TCP
    /// service) check this so a disabled backend surfaces as a clean
    /// error instead of a panic.
    pub fn available(&self) -> bool {
        match self {
            Method::XlaOrigin => cfg!(feature = "xla"),
            _ => true,
        }
    }

    /// Error out unless [`Method::available`].
    pub fn ensure_available(&self) -> Result<()> {
        if self.available() {
            Ok(())
        } else {
            Err(err!(
                "method '{}' requires a build with the `xla` cargo feature \
                 (rebuild with `cargo build --features xla`)",
                self.name()
            ))
        }
    }
}

/// Dataset selector (see [`super::registry`]).
#[derive(Clone, Debug, PartialEq)]
pub struct DatasetSpec {
    /// "synthetic" | "digits" | "faces" | "objects".
    pub family: String,
    /// synthetic: number of classes; faces/objects: task index (0–11).
    pub param1: usize,
    /// synthetic: samples per class; digits: samples per domain.
    pub param2: usize,
    /// faces/objects: domain-size scale in (0, 1].
    pub scale: f64,
    pub seed: u64,
    /// Cost-matrix backend for the problem built from this spec.
    /// `Auto` (the default) defers to the serving/sweep config's
    /// solve-level selection; an explicit request-level mode wins.
    /// Both backends solve byte-identically — the choice only moves
    /// the memory/latency trade-off — but they cache differently, so
    /// the mode is part of [`DatasetSpec::cache_key`].
    pub cost: CostMode,
}

impl Default for DatasetSpec {
    fn default() -> Self {
        DatasetSpec {
            family: "synthetic".into(),
            param1: 10,
            param2: 10,
            scale: 0.1,
            seed: 0xDA7A,
            cost: CostMode::Auto,
        }
    }
}

impl DatasetSpec {
    /// Canonical cache key: two specs generate the same problem iff
    /// their keys match. Used by the serving engine's problem and
    /// warm-start caches and by the micro-batcher's coalescing rule.
    pub fn cache_key(&self) -> String {
        format!(
            "{}:{}:{}:{}:{}:{}",
            self.family,
            self.param1,
            self.param2,
            self.scale,
            self.seed,
            self.cost.name()
        )
    }

    /// The cost backend this spec's problem should be built with:
    /// request-level selection when explicit, else the engine/sweep
    /// `fallback` (typically `SolveOptions::cost`).
    pub fn effective_cost(&self, fallback: CostMode) -> CostMode {
        match self.cost {
            CostMode::Auto => fallback,
            explicit => explicit,
        }
    }
}

/// Full sweep configuration (the paper's experimental grid).
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub dataset: DatasetSpec,
    /// γ grid (paper: 1e-3 … 1e3).
    pub gammas: Vec<f64>,
    /// ρ grid (paper: 0.2, 0.4, 0.6, 0.8).
    pub rhos: Vec<f64>,
    pub methods: Vec<Method>,
    /// Worker threads for the job scheduler.
    pub threads: usize,
    /// Per-job solver options (snapshot interval `r`, intra-solve
    /// oracle workers — deterministic: records are bit-identical for
    /// every thread count — L-BFGS caps, SIMD policy, regularizer).
    /// γ/ρ are overridden by the grid per job.
    pub solve: SolveOptions,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            dataset: DatasetSpec::default(),
            gammas: vec![1e-3, 1e-2, 1e-1, 1.0, 1e1, 1e2, 1e3],
            rhos: vec![0.2, 0.4, 0.6, 0.8],
            methods: vec![Method::Fast, Method::Origin],
            threads: 1,
            solve: SolveOptions::new().max_iters(1000),
        }
    }
}

impl SweepConfig {
    /// Parse from a JSON document. Missing fields keep their defaults.
    pub fn from_json(v: &Value) -> Result<SweepConfig> {
        let mut cfg = SweepConfig::default();
        if let Some(ds) = v.get("dataset") {
            if let Some(f) = ds.get("family").and_then(Value::as_str) {
                cfg.dataset.family = f.to_string();
            }
            if let Some(x) = ds.get("param1").and_then(Value::as_usize) {
                cfg.dataset.param1 = x;
            }
            if let Some(x) = ds.get("param2").and_then(Value::as_usize) {
                cfg.dataset.param2 = x;
            }
            if let Some(x) = ds.get("scale").and_then(Value::as_f64) {
                cfg.dataset.scale = x;
            }
            if let Some(x) = ds.get("seed").and_then(Value::as_f64) {
                cfg.dataset.seed = x as u64;
            }
            if let Some(c) = ds.get("cost") {
                cfg.dataset.cost = parse_cost_value(c)?;
            }
        }
        if let Some(g) = v.get("gammas") {
            cfg.gammas = g.as_f64_vec().ok_or_else(|| err!("gammas must be numbers"))?;
        }
        if let Some(rh) = v.get("rhos") {
            cfg.rhos = rh.as_f64_vec().ok_or_else(|| err!("rhos must be numbers"))?;
        }
        if let Some(ms) = v.get("methods").and_then(Value::as_arr) {
            cfg.methods = ms
                .iter()
                .map(|m| {
                    Method::parse(m.as_str().ok_or_else(|| err!("method must be string"))?)
                })
                .collect::<Result<_>>()?;
        }
        if let Some(x) = v.get("r").and_then(Value::as_usize) {
            cfg.solve.r = x;
        }
        if let Some(x) = v.get("threads").and_then(Value::as_usize) {
            cfg.threads = x;
        }
        if let Some(x) = v.get("solve_threads").and_then(Value::as_usize) {
            cfg.solve.threads = x;
        }
        if let Some(x) = v.get("max_iters").and_then(Value::as_usize) {
            cfg.solve.lbfgs.max_iters = x;
        }
        if let Some(s) = v.get("regularizer") {
            let s = s.as_str().ok_or_else(|| err!("regularizer must be a string"))?;
            cfg.solve.regularizer = Some(RegKind::parse(s)?);
        }
        if let Some(s) = v.get("simd") {
            let s = s.as_str().ok_or_else(|| err!("simd must be a string"))?;
            cfg.solve.simd = SimdMode::parse(s).map_err(|e| err!("simd: {e}"))?;
        }
        if let Some(c) = v.get("cost") {
            cfg.solve.cost = parse_cost_value(c)?;
        }
        Ok(cfg)
    }

    /// Load from a JSON file.
    pub fn from_file(path: &std::path::Path) -> Result<SweepConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = jsonlite::parse(&text).context("parsing sweep config")?;
        Self::from_json(&v)
    }

    /// Serialize (for reports / reproducibility records).
    pub fn to_json(&self) -> Value {
        Value::obj()
            .set(
                "dataset",
                Value::obj()
                    .set("family", self.dataset.family.as_str())
                    .set("param1", self.dataset.param1)
                    .set("param2", self.dataset.param2)
                    .set("scale", self.dataset.scale)
                    .set("seed", self.dataset.seed)
                    .set("cost", self.dataset.cost.name()),
            )
            .set("gammas", self.gammas.as_slice())
            .set("rhos", self.rhos.as_slice())
            .set(
                "methods",
                Value::Arr(self.methods.iter().map(|m| Value::from(m.name())).collect()),
            )
            .set("r", self.solve.r)
            .set("threads", self.threads)
            .set("solve_threads", self.solve.threads)
            .set("max_iters", self.solve.lbfgs.max_iters)
            .set(
                "regularizer",
                // Resolved (explicit, else GRPOT_REG/group-lasso) so the
                // record reproduces the run even if the env changes; a
                // broken env var falls back to the explicit field.
                self.solve
                    .resolve_regularizer()
                    .unwrap_or_else(|_| self.solve.regularizer.unwrap_or_default())
                    .name(),
            )
            .set("simd", self.solve.simd.name())
            .set("cost", self.solve.cost.name())
    }
}

/// Parse a cost-mode JSON value: either a bare string (`"factored"`) or
/// the wire protocol's object form (`{"mode": "factored"}`).
pub(crate) fn parse_cost_value(v: &Value) -> Result<CostMode> {
    let s = match v.as_str() {
        Some(s) => s,
        None => v
            .get("mode")
            .and_then(Value::as_str)
            .ok_or_else(|| err!("cost must be a string or {{\"mode\": ...}} object"))?,
    };
    CostMode::parse(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in [Method::Fast, Method::FastNoWs, Method::Origin, Method::XlaOrigin] {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("bogus").is_err());
    }

    #[test]
    fn config_json_roundtrip() {
        let cfg = SweepConfig {
            gammas: vec![0.1, 1.0],
            rhos: vec![0.5],
            methods: vec![Method::Fast, Method::XlaOrigin],
            threads: 3,
            solve: SolveOptions::new()
                .r(5)
                .threads(2)
                .max_iters(50)
                .simd(SimdMode::Scalar)
                .regularizer(RegKind::SquaredL2),
            dataset: DatasetSpec {
                family: "digits".into(),
                param1: 0,
                param2: 300,
                scale: 1.0,
                seed: 7,
                cost: CostMode::Factored,
            },
        };
        let json = cfg.to_json().to_json();
        let back = SweepConfig::from_json(&crate::jsonlite::parse(&json).unwrap()).unwrap();
        assert_eq!(back.gammas, cfg.gammas);
        assert_eq!(back.rhos, cfg.rhos);
        assert_eq!(back.methods, cfg.methods);
        assert_eq!(back.solve.r, 5);
        assert_eq!(back.threads, 3);
        assert_eq!(back.solve.threads, 2);
        assert_eq!(back.solve.lbfgs.max_iters, 50);
        assert_eq!(back.solve.simd, SimdMode::Scalar);
        assert_eq!(back.solve.regularizer, Some(RegKind::SquaredL2));
        assert_eq!(back.dataset, cfg.dataset);
    }

    #[test]
    fn config_json_rejects_unknown_regularizer() {
        let v = crate::jsonlite::parse(r#"{"regularizer": "lasso-soup"}"#).unwrap();
        let e = SweepConfig::from_json(&v).unwrap_err();
        assert!(e.0.contains("unknown regularizer"), "{e}");
    }

    #[test]
    fn xla_availability_tracks_feature() {
        assert!(Method::Fast.available());
        assert!(Method::Origin.ensure_available().is_ok());
        assert_eq!(Method::XlaOrigin.available(), cfg!(feature = "xla"));
        if !cfg!(feature = "xla") {
            let e = Method::XlaOrigin.ensure_available().unwrap_err();
            assert!(e.0.contains("xla"), "{e}");
        }
    }

    #[test]
    fn cache_key_distinguishes_specs() {
        let a = DatasetSpec::default();
        let mut b = a.clone();
        assert_eq!(a.cache_key(), b.cache_key());
        b.seed += 1;
        assert_ne!(a.cache_key(), b.cache_key());
        let mut c = a.clone();
        c.cost = CostMode::Factored;
        assert_ne!(a.cache_key(), c.cache_key());
    }

    #[test]
    fn cost_value_parses_string_and_wire_object() {
        let s = crate::jsonlite::parse(r#""factored""#).unwrap();
        assert_eq!(parse_cost_value(&s).unwrap(), CostMode::Factored);
        let o = crate::jsonlite::parse(r#"{"mode": "dense"}"#).unwrap();
        assert_eq!(parse_cost_value(&o).unwrap(), CostMode::Dense);
        let bad = crate::jsonlite::parse(r#"{"mode": "ram-doubler"}"#).unwrap();
        assert!(parse_cost_value(&bad).is_err());
        assert!(parse_cost_value(&crate::jsonlite::parse("3").unwrap()).is_err());
    }

    #[test]
    fn effective_cost_prefers_explicit_spec() {
        let mut spec = DatasetSpec::default();
        assert_eq!(spec.effective_cost(CostMode::Factored), CostMode::Factored);
        spec.cost = CostMode::Dense;
        assert_eq!(spec.effective_cost(CostMode::Factored), CostMode::Dense);
    }

    #[test]
    fn defaults_match_paper_grid() {
        let cfg = SweepConfig::default();
        assert_eq!(cfg.gammas.len(), 7);
        assert_eq!(cfg.rhos, vec![0.2, 0.4, 0.6, 0.8]);
        assert_eq!(cfg.solve.r, 10);
        assert_eq!(cfg.solve.lbfgs.max_iters, 1000);
        assert_eq!(cfg.solve.regularizer, None);
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let v = crate::jsonlite::parse(r#"{"rhos": [0.9]}"#).unwrap();
        let cfg = SweepConfig::from_json(&v).unwrap();
        assert_eq!(cfg.rhos, vec![0.9]);
        assert_eq!(cfg.gammas.len(), 7); // default retained
    }
}
