//! Smooth relaxed dual of group-sparse regularized discrete OT.
//!
//! Primal (Problem 2, with the experimental-section parametrization):
//!
//! ```text
//! min_{T ∈ U(a,b)} ⟨T, C⟩ + Σ_j Ψ(t_j),
//! Ψ(t) = γ ( ½(1−ρ)‖t‖₂² + ρ Σ_l ‖t_[l]‖₂ )
//!      = ½ λ_quad ‖t‖₂² + τ Σ_l ‖t_[l]‖₂,   λ_quad = γ(1−ρ), τ = γρ.
//! ```
//!
//! Dual (Problem 4): `max_{α,β} αᵀa + βᵀb − Σ_j ψ(α + β_j 1_m − c_j)`
//! with the conjugate in closed form. Writing `f = α + β_j 1 − c_j` and
//! `z_{l,j} = ‖[f_[l]]₊‖₂` (Definition 1):
//!
//! ```text
//! ψ(f)      = Σ_l [z_{l,j} − τ]₊² / (2 λ_quad)
//! ∇ψ(f)_[l] = [1 − τ/z_{l,j}]₊ [f_[l]]₊ / λ_quad        (Eq. 5)
//! ```
//!
//! so a group contributes to neither value nor gradient when
//! `z_{l,j} ≤ τ` — the fact both the dense baseline and the screening
//! method exploit. Solvers *minimize* the negated dual.

use super::cost::{CostMatrix, CostMode, FactoredCost, TileRing};
use super::pack::PackedCost;
use crate::data::DomainPair;
use crate::fault::CancelToken;
use crate::groups::GroupStructure;
use crate::linalg::{self, Mat};
use crate::pool::{fixed_chunk_ranges, ParallelCtx};
use crate::simd::{Dispatch, SimdMode, LANES};
use std::ops::Range;
use std::sync::{Arc, OnceLock};

/// Regularization hyperparameters (experimental-section form).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DualParams {
    /// Overall regularization strength γ > 0.
    pub gamma: f64,
    /// Balance ρ ∈ (0, 1): ρ→0 pure quadratic, ρ→1 pure group-lasso.
    pub rho: f64,
}

impl DualParams {
    pub fn new(gamma: f64, rho: f64) -> Self {
        let p = DualParams { gamma, rho };
        p.validate();
        p
    }

    pub fn validate(&self) {
        assert!(self.gamma > 0.0, "gamma must be positive");
        assert!(
            self.rho >= 0.0 && self.rho < 1.0,
            "rho must lie in [0, 1); rho=1 makes the conjugate degenerate"
        );
    }

    /// Quadratic coefficient `λ_quad = γ(1−ρ)`.
    #[inline]
    pub fn lambda_quad(&self) -> f64 {
        self.gamma * (1.0 - self.rho)
    }

    /// Group-lasso coefficient and skip threshold `τ = γρ` (the paper's `μγ`).
    #[inline]
    pub fn tau(&self) -> f64 {
        self.gamma * self.rho
    }

    /// The paper's `μ` (Eq. 3) for this (γ, ρ).
    pub fn mu(&self) -> f64 {
        self.rho / (1.0 - self.rho)
    }
}

impl Default for DualParams {
    fn default() -> Self {
        DualParams { gamma: 1.0, rho: 0.5 }
    }
}

/// Loop-invariant (γ, ρ)-derived constants, computed once per problem
/// instead of per (group, column) pair: the inner kernel otherwise pays
/// a `sqrt` per *zero* group and two divisions per active group, which
/// in the screened sparse regime is a measurable share of the per-eval
/// floor. Every oracle evaluates through the same table, so the (fixed)
/// arithmetic stays identical across methods and thread counts.
#[derive(Clone, Copy, Debug)]
pub struct KernelConsts {
    /// Group-lasso threshold `τ = γρ`.
    pub tau: f64,
    /// `τ²` — lets the skip test run on `z²` so zero groups never pay
    /// the `sqrt`.
    pub tau_sq: f64,
    /// `1/λ_quad` — turns the per-active-group division into a multiply.
    pub inv_lq: f64,
    /// `1/(2 λ_quad)` — same, for the ψ value term.
    pub half_inv_lq: f64,
}

impl KernelConsts {
    pub fn new(params: &DualParams) -> Self {
        let tau = params.tau();
        let lq = params.lambda_quad();
        KernelConsts { tau, tau_sq: tau * tau, inv_lq: 1.0 / lq, half_inv_lq: 0.5 / lq }
    }
}

/// Columns per cache panel in the blocked oracle walks: panels of
/// `PANEL_COLS` columns are processed group-by-group so one group's
/// slice of `alpha`/`grad_alpha` (and, in the screened oracle, its
/// `snap_z` row segment and `da_pos` entry) stays in L1 across the
/// whole panel instead of being re-streamed once per column.
pub(crate) const PANEL_COLS: usize = 8;

/// The fixed panels of one column chunk: sub-ranges of at most
/// [`PANEL_COLS`] columns, aligned to the chunk start. A function of the
/// chunk boundaries alone (which are themselves a function of `n`
/// alone), so panel-level decisions are thread-count-invariant.
pub(crate) fn panel_ranges(range: Range<usize>) -> impl Iterator<Item = Range<usize>> {
    let (start, end) = (range.start, range.end);
    (0..range.len().div_ceil(PANEL_COLS)).map(move |p| {
        let lo = start + p * PANEL_COLS;
        lo..(lo + PANEL_COLS).min(end)
    })
}

/// Number of panels a chunk of `len` columns splits into.
pub(crate) fn panel_count(len: usize) -> usize {
    len.div_ceil(PANEL_COLS)
}

/// A regularized-OT instance: marginals, cost and group structure.
///
/// The cost lives behind a [`CostMatrix`] backend. The dense backend
/// stores it **transposed** (`n×m`): the dual oracles walk column `j`
/// of `C` in the inner loop, so row `j` of the stored matrix keeps that
/// access contiguous. The factored backend stores only coordinates +
/// squared norms (O((m+n)·d)) and synthesizes bitwise-identical values
/// on demand. Source samples are in *sorted (grouped)* order;
/// `groups.perm` maps back to the caller's order.
pub struct OtProblem {
    /// Source marginal `a` (length m, sums to 1).
    pub a: Vec<f64>,
    /// Target marginal `b` (length n, sums to 1).
    pub b: Vec<f64>,
    /// The cost backend, sorted source order
    /// (`c(x_S_i, x_T_j)` at logical position `(i, j)`). Private so
    /// every mutation goes through [`OtProblem::cost_t_mut`], which
    /// invalidates the packed-tile cache below — a stale pack would
    /// silently break the byte-equal-across-backends invariant.
    cost: CostMatrix,
    /// Group partition of the (sorted) source samples.
    pub groups: GroupStructure,
    /// Lazily packed cost tiles over the canonical chunk grid
    /// ([`fixed_chunk_ranges`]`(n)`) — a pure function of the cost data,
    /// built on the first vector-dispatch oracle construction and then
    /// shared by every later oracle on this problem instance, so the
    /// serving engine's per-dataset cached problem packs once across
    /// all requests and a sweep packs once across its whole grid.
    tiles: OnceLock<Arc<PackedCost>>,
}

impl Clone for OtProblem {
    fn clone(&self) -> Self {
        // An already-built tile cache is carried over by `Arc` (tiles
        // are a pure function of the cost data, which is cloned
        // bit-identically, and `cost_t` is private — any later
        // mutation goes through `cost_t_mut`, which drops the clone's
        // own cache), so cloning never forces a repack.
        let tiles = OnceLock::new();
        if let Some(t) = self.tiles.get() {
            let _ = tiles.set(Arc::clone(t));
        }
        OtProblem {
            a: self.a.clone(),
            b: self.b.clone(),
            cost: self.cost.clone(),
            groups: self.groups.clone(),
            tiles,
        }
    }
}

impl std::fmt::Debug for OtProblem {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OtProblem")
            .field("a", &self.a)
            .field("b", &self.b)
            .field("cost", &self.cost)
            .field("groups", &self.groups)
            .field("tiles_packed", &self.tiles.get().is_some())
            .finish()
    }
}

impl OtProblem {
    /// Build from a labeled source / unlabeled target pair with squared
    /// Euclidean costs normalized by the max entry (standard practice;
    /// gives γ a dataset-independent scale). Cost backend follows
    /// [`CostMode::Auto`] (`GRPOT_COST`, dense by default; a malformed
    /// variable falls back to dense here — the CLI validates it at
    /// launch, and the checked entries surface it as an error).
    pub fn from_dataset(pair: &DomainPair) -> OtProblem {
        Self::from_dataset_mode(pair, CostMode::Auto)
    }

    /// [`OtProblem::from_dataset`] with an explicit cost backend. Both
    /// backends run the same arithmetic — [`linalg::sq_euclidean_cost`]
    /// materialized vs. the factored form synthesized on demand — and
    /// are bitwise interchangeable everywhere downstream.
    pub fn from_dataset_mode(pair: &DomainPair, mode: CostMode) -> OtProblem {
        let mode = mode.resolve().unwrap_or(CostMode::Dense);
        let groups = GroupStructure::from_labels(&pair.source.labels);
        // Permute source rows into grouped order.
        let d = pair.source.x.cols();
        let xs = Mat::from_fn(groups.num_samples(), d, |k, c| {
            pair.source.x[(groups.perm[k], c)]
        });
        let m = xs.rows();
        let n = pair.target.x.rows();
        let cost = match mode {
            CostMode::Factored => {
                CostMatrix::Factored(FactoredCost::build(xs, pair.target.x.clone()))
            }
            _ => {
                let mut cost = linalg::sq_euclidean_cost(&xs, &pair.target.x);
                linalg::normalize_by_max(&mut cost);
                CostMatrix::Dense(cost.transpose())
            }
        };
        OtProblem {
            a: vec![1.0 / m as f64; m],
            b: vec![1.0 / n as f64; n],
            cost,
            groups,
            tiles: OnceLock::new(),
        }
    }

    /// Build from explicit parts. `cost` is `m×n` in the *original*
    /// source order; rows are permuted into grouped order internally.
    pub fn from_parts(a: Vec<f64>, b: Vec<f64>, cost: &Mat, labels: &[usize]) -> OtProblem {
        let m = cost.rows();
        let n = cost.cols();
        assert_eq!(a.len(), m);
        assert_eq!(b.len(), n);
        assert_eq!(labels.len(), m);
        let groups = GroupStructure::from_labels(labels);
        let mut cost_t = Mat::zeros(n, m);
        for j in 0..n {
            let row = cost_t.row_mut(j);
            for (k, &orig) in groups.perm.iter().enumerate() {
                row[k] = cost[(orig, j)];
            }
        }
        let a_perm = groups.permute(&a);
        OtProblem {
            a: a_perm,
            b,
            cost: CostMatrix::Dense(cost_t),
            groups,
            tiles: OnceLock::new(),
        }
    }

    /// Checked [`OtProblem::from_dataset`]: audits the generated pair
    /// (non-empty domains, matching label count, finite coordinates —
    /// a degenerate all-equal source would otherwise normalize the cost
    /// to NaN) and returns a structured error instead of panicking or
    /// poisoning downstream solves. The serving engine builds every
    /// cached problem through this entry so an untrusted dataset spec
    /// can never install non-finite costs.
    pub fn try_from_dataset(pair: &DomainPair) -> crate::error::Result<OtProblem> {
        Self::try_from_dataset_mode(pair, CostMode::Auto)
    }

    /// Checked [`OtProblem::from_dataset_mode`]. Unlike the infallible
    /// entry, a malformed `GRPOT_COST` surfaces here as a structured
    /// error (the serving engine routes every wire request through
    /// this, so a bad environment fails loudly instead of silently
    /// solving dense).
    pub fn try_from_dataset_mode(
        pair: &DomainPair,
        mode: CostMode,
    ) -> crate::error::Result<OtProblem> {
        let mode = mode.resolve()?;
        let m = pair.source.x.rows();
        let n = pair.target.x.rows();
        if m == 0 || n == 0 {
            return Err(crate::err!("dataset has empty domain (source {m} × target {n})"));
        }
        if pair.source.labels.len() != m {
            return Err(crate::err!(
                "dataset has {} labels for {m} source samples",
                pair.source.labels.len()
            ));
        }
        if !pair.source.x.as_slice().iter().all(|v| v.is_finite())
            || !pair.target.x.as_slice().iter().all(|v| v.is_finite())
        {
            return Err(crate::err!("dataset contains non-finite coordinates"));
        }
        let prob = OtProblem::from_dataset_mode(pair, mode);
        if !prob.cost_finite() {
            return Err(crate::err!(
                "dataset produced a non-finite normalized cost (degenerate coordinates?)"
            ));
        }
        Ok(prob)
    }

    /// Build directly from point coordinates: `source_x` is `m×d` with
    /// one group label per row, `target_x` is `n×d`; marginals are
    /// uniform and the cost is max-normalized squared ℓ2, exactly as in
    /// [`OtProblem::from_dataset_mode`]. This is the natural entry for
    /// the factored backend (which *is* the coordinates), but accepts
    /// any resolved mode. All validation returns structured errors.
    pub fn try_from_points(
        source_x: &Mat,
        labels: &[usize],
        target_x: &Mat,
        mode: CostMode,
    ) -> crate::error::Result<OtProblem> {
        let mode = mode.resolve()?;
        let m = source_x.rows();
        let n = target_x.rows();
        if m == 0 || n == 0 {
            return Err(crate::err!("empty point set (source {m} × target {n})"));
        }
        let d = source_x.cols();
        if d == 0 {
            return Err(crate::err!("points have zero feature dimension"));
        }
        if target_x.cols() != d {
            return Err(crate::err!(
                "feature dimension mismatch: source d={d}, target d={}",
                target_x.cols()
            ));
        }
        if labels.len() != m {
            return Err(crate::err!("{} labels for {m} source samples", labels.len()));
        }
        if !source_x.as_slice().iter().all(|v| v.is_finite())
            || !target_x.as_slice().iter().all(|v| v.is_finite())
        {
            return Err(crate::err!("points contain non-finite coordinates"));
        }
        let pair = DomainPair {
            source: crate::data::Dataset {
                name: "points".into(),
                x: source_x.clone(),
                labels: labels.to_vec(),
            },
            target: crate::data::Dataset {
                name: "points".into(),
                x: target_x.clone(),
                labels: Vec::new(),
            },
        };
        let prob = OtProblem::from_dataset_mode(&pair, mode);
        if !prob.cost_finite() {
            return Err(crate::err!(
                "points produced a non-finite normalized cost (degenerate coordinates?)"
            ));
        }
        Ok(prob)
    }

    /// Whether every (stored or synthesizable) cost entry is finite —
    /// the post-construction audit the checked constructors share. For
    /// the factored backend finite inputs make every entry finite iff
    /// the norms and normalization constant are (each entry is a fixed
    /// combination of them), so the check stays O(m+n).
    fn cost_finite(&self) -> bool {
        match &self.cost {
            CostMatrix::Dense(ct) => ct.as_slice().iter().all(|v| v.is_finite()),
            CostMatrix::Factored(f) => f.is_finite(),
        }
    }

    /// Checked [`OtProblem::from_parts`]: dimension mismatches and
    /// non-finite / non-probability inputs come back as structured
    /// errors instead of the unchecked constructor's panics.
    pub fn try_from_parts(
        a: Vec<f64>,
        b: Vec<f64>,
        cost: &Mat,
        labels: &[usize],
    ) -> crate::error::Result<OtProblem> {
        let (m, n) = cost.shape();
        if m == 0 || n == 0 {
            return Err(crate::err!("cost matrix has empty dimension ({m} × {n})"));
        }
        if a.len() != m || b.len() != n || labels.len() != m {
            return Err(crate::err!(
                "shape mismatch: cost {m}×{n}, |a|={}, |b|={}, |labels|={}",
                a.len(),
                b.len(),
                labels.len()
            ));
        }
        if !cost.as_slice().iter().all(|v| v.is_finite()) {
            return Err(crate::err!("cost matrix contains non-finite entries"));
        }
        for (name, marg) in [("a", &a), ("b", &b)] {
            if !marg.iter().all(|v| v.is_finite() && *v >= 0.0) {
                return Err(crate::err!(
                    "marginal {name} must be finite and nonnegative"
                ));
            }
            if marg.iter().sum::<f64>() <= 0.0 {
                return Err(crate::err!("marginal {name} has zero total mass"));
            }
        }
        Ok(OtProblem::from_parts(a, b, cost, labels))
    }

    #[inline]
    pub fn m(&self) -> usize {
        self.a.len()
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.b.len()
    }

    /// Dual variable dimension `m + n`.
    #[inline]
    pub fn dim(&self) -> usize {
        self.m() + self.n()
    }

    /// Dense `m×n` cost in sorted-source order (copies; tests/baselines).
    /// Works on either backend (the factored path synthesizes — only
    /// call on sizes where materializing is acceptable).
    pub fn cost(&self) -> Mat {
        match &self.cost {
            CostMatrix::Dense(ct) => ct.transpose(),
            CostMatrix::Factored(f) => Mat::from_fn(f.m(), f.n(), |i, j| f.entry(i, j)),
        }
    }

    /// The transposed (`n×m`) cost matrix — row `j` is column `j` of
    /// the cost, the slice the dense oracle inner loops walk.
    ///
    /// # Panics
    /// On the factored backend, which deliberately never materializes
    /// this matrix; factored-aware paths go through
    /// [`OtProblem::cost_col`] or the tile synthesis in the chunk walks.
    #[inline]
    pub fn cost_t(&self) -> &Mat {
        match &self.cost {
            CostMatrix::Dense(ct) => ct,
            CostMatrix::Factored(_) => {
                panic!("cost_t() called on a factored cost backend (never materialized)")
            }
        }
    }

    /// Mutable access to the transposed cost (dense backend only; the
    /// factored backend has no stored matrix to edit). Drops the
    /// packed-tile cache, so the next vector-dispatch oracle repacks
    /// from the edited costs instead of reading stale tiles.
    pub fn cost_t_mut(&mut self) -> &mut Mat {
        self.tiles.take();
        match &mut self.cost {
            CostMatrix::Dense(ct) => ct,
            CostMatrix::Factored(_) => {
                panic!("cost_t_mut() called on a factored cost backend (never materialized)")
            }
        }
    }

    /// Cost column `j` as a slice: zero-copy on the dense backend,
    /// synthesized into `buf` on the factored one. The shared entry for
    /// every full-column consumer (semi-dual staging, plan recovery,
    /// screening error bounds).
    #[inline]
    pub fn cost_col<'a>(&'a self, j: usize, buf: &'a mut Vec<f64>) -> &'a [f64] {
        self.cost.col(j, buf)
    }

    /// The cost backend (chunk walks dispatch on it directly).
    #[inline]
    pub(crate) fn cost_backend(&self) -> &CostMatrix {
        &self.cost
    }

    /// Whether the factored (synthesize-on-demand) backend is active.
    #[inline]
    pub fn is_factored(&self) -> bool {
        self.cost.is_factored()
    }

    /// Cost backend name for telemetry / `grpot info`.
    pub fn cost_mode_name(&self) -> &'static str {
        self.cost.mode_name()
    }

    /// Resident bytes of the cost representation — what a dataset cache
    /// should account. Dense: the n×m matrix (the packed-tile copy is
    /// charged separately on first vector use); factored: coordinates +
    /// norms only.
    pub fn cost_bytes(&self) -> usize {
        self.cost.bytes()
    }

    /// The packed cost tiles over the canonical chunk grid, built on
    /// first use and shared (O(1) `Arc` clone) by every vector-dispatch
    /// oracle constructed on this problem instance afterwards.
    pub(crate) fn packed_cost(&self) -> Arc<PackedCost> {
        Arc::clone(
            self.tiles
                .get_or_init(|| Arc::new(PackedCost::pack(self, &fixed_chunk_ranges(self.n())))),
        )
    }
}

/// The resolved SIMD backend plus the packed cost tiles the vector
/// kernels read — built once per oracle (next to its solve-lifetime
/// [`ParallelCtx`]) and shared by every evaluation and snapshot refresh.
/// Scalar dispatch packs nothing: the original kernels keep reading
/// `cost_t` rows, and the memory cost of the tiles (≈ one extra m×n
/// `f64` copy) is only paid when a vector backend will actually use
/// them.
pub(crate) struct SimdEngine {
    pub(crate) dispatch: Dispatch,
    /// `Some` iff `dispatch.is_vector()` — a shared handle on the
    /// problem's lazily-packed tile cache ([`OtProblem::packed_cost`]),
    /// so repeated oracle constructions on one problem never repack.
    pub(crate) pack: Option<Arc<PackedCost>>,
}

impl SimdEngine {
    /// The tiles are laid out over the canonical grid
    /// ([`fixed_chunk_ranges`]`(n)`) — the exact grid every oracle
    /// evaluates over (there is deliberately no way to hand this
    /// engine a different grid, which would silently misalign tiles).
    pub(crate) fn new(prob: &OtProblem, mode: SimdMode) -> SimdEngine {
        let dispatch = Dispatch::resolve(mode);
        // The factored backend never materializes the matrix a pack
        // would read from; its vector path synthesizes tiles into the
        // per-chunk ring instead.
        let pack =
            (dispatch.is_vector() && !prob.is_factored()).then(|| prob.packed_cost());
        SimdEngine { dispatch, pack }
    }
}

/// Counters shared by all oracles. A "group gradient computation" is one
/// evaluation of `∇ψ(·)_[l]` for a single `(l, j)` — the unit the paper
/// counts in Figures 6 and C.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct OracleStats {
    /// Number of `eval` calls (function+gradient evaluations).
    pub evals: u64,
    /// Exact group gradients computed.
    pub grads_computed: u64,
    /// Group gradients skipped via the upper bound.
    pub grads_skipped: u64,
    /// Upper bounds evaluated (the overhead the working set removes).
    pub ub_checks: u64,
    /// Group gradients routed through the working set ℕ.
    pub ws_hits: u64,
    /// Cost tiles/segments synthesized by the factored backend during
    /// evaluation (0 on dense). Counts *synthesis work*: on the scalar
    /// path one per (group, column) segment filled, on the vector path
    /// one per tile-ring miss — so screened-out groups provably never
    /// pay cost synthesis (their count never moves), but the value is
    /// dispatch-dependent: equality checks across backends/dispatches
    /// must compare the other fields individually.
    pub tiles_built: u64,
    /// Per-eval history of `grads_computed` deltas (Fig. C).
    pub per_eval_grads: Vec<u64>,
}

impl OracleStats {
    pub fn record_eval(&mut self, grads_this_eval: u64) {
        self.evals += 1;
        self.per_eval_grads.push(grads_this_eval);
    }
}

/// A (value, gradient) oracle for the negated dual, `x = [α; β]`.
///
/// Implementations: [`crate::ot::origin::OriginOracle`] (dense),
/// [`crate::ot::screening::ScreeningOracle`] (the paper's method) and,
/// behind the `xla` feature, `crate::runtime::XlaDualOracle` (AOT
/// JAX/Pallas via PJRT).
pub trait DualOracle {
    /// Problem dimensions `(m, n)`.
    fn shape(&self) -> (usize, usize);

    /// Evaluate the negated dual at `x = [α; β]`, writing its gradient
    /// into `grad` (same length). Returns the objective value.
    fn eval(&mut self, x: &[f64], grad: &mut [f64]) -> f64;

    /// Called by the Algorithm-1 driver after each `r`-iteration block
    /// with the current iterate (snapshot + working-set refresh point).
    /// Dense oracles may ignore it.
    fn refresh(&mut self, _x: &[f64]) {}

    /// Counter access.
    fn stats(&self) -> &OracleStats;

    /// SIMD dispatch this oracle's kernels actually use, when known
    /// (telemetry only; never consulted by the math).
    fn simd_dispatch(&self) -> Option<crate::simd::Dispatch> {
        None
    }

    /// Working-set density |ℕ| / (L·n), when the oracle maintains a
    /// working set (telemetry only).
    fn working_set_density(&self) -> Option<f64> {
        None
    }

    /// The parallel context driving this oracle's chunked evaluation,
    /// when it owns one (telemetry only; used to read pool counters).
    fn parallel_ctx(&self) -> Option<&crate::pool::ParallelCtx> {
        None
    }
}

/// Compute `ψ` and `∇ψ` contributions of one `(group, column)` pair and
/// accumulate into the gradient. Returns the pair's ψ value.
///
/// This is THE inner kernel: both the dense baseline and the screening
/// method call this exact function for every non-skipped pair, which is
/// what makes Theorem 2 (identical trajectories) hold bit-for-bit.
///
/// `grad_alpha` is the α-part of the negated-dual gradient; the returned
/// `col_mass` (Σ_i t_ij over this group) must be added to `∂/∂β_j`.
///
/// The skip test compares `z²` against the precomputed `τ²`
/// ([`KernelConsts`]), so groups below the threshold — the common case
/// in the screened sparse regime — never pay the `sqrt`; active groups
/// multiply by the precomputed `1/λ_quad` instead of dividing.
///
/// `c_seg` is the cost *segment* for this group — `c_seg[k]` is the
/// cost at row `range.start + k` — so the kernel reads the same slice
/// whether it came from a resident matrix row (dense: the caller
/// passes `&row[range]`) or was just synthesized by the factored
/// backend. Same values, same order: the indexing change is invisible
/// to the arithmetic.
#[inline]
pub fn group_grad_contrib(
    alpha: &[f64],
    beta_j: f64,
    c_seg: &[f64],
    range: std::ops::Range<usize>,
    consts: &KernelConsts,
    grad_alpha: &mut [f64],
    scratch: &mut [f64],
) -> (f64, f64) {
    // Pass 1: materialize [f]₊ into scratch and accumulate z².
    let start = range.start;
    let g = range.len();
    debug_assert!(scratch.len() >= g);
    debug_assert_eq!(c_seg.len(), g);
    let mut zsq = 0.0;
    for (k, i) in range.clone().enumerate() {
        let f = alpha[i] + beta_j - c_seg[k];
        let fp = if f > 0.0 { f } else { 0.0 };
        // Branchless store keeps the loop tight; zsq only sums positives.
        scratch[k] = fp;
        zsq += fp * fp;
    }
    if zsq <= consts.tau_sq {
        return (0.0, 0.0);
    }
    let z = zsq.sqrt();
    // Pass 2: t = scale · [f]₊ from scratch (no recomputation of f).
    let slack = z - consts.tau;
    let scale = slack * consts.inv_lq / z;
    let mut col_mass = 0.0;
    for k in 0..g {
        let t = scale * scratch[k];
        grad_alpha[start + k] += t;
        col_mass += t;
    }
    (slack * slack * consts.half_inv_lq, col_mass)
}

/// `z_{l,j} = ‖[ (α + β_j 1 − c_j)_[l] ]₊‖₂` for one pair (used by
/// diagnostics and tests; the hot path inlines it).
pub fn exact_z(
    alpha: &[f64],
    beta_j: f64,
    c_j: &[f64],
    range: std::ops::Range<usize>,
) -> f64 {
    let mut zsq = 0.0;
    for i in range {
        let f = alpha[i] + beta_j - c_j[i];
        if f > 0.0 {
            zsq += f * f;
        }
    }
    zsq.sqrt()
}

/// Per-chunk scratch for the column-parallel oracle evaluations: a
/// partial α-gradient, per-column transported masses, the group kernel
/// buffer and partial counters. The oracles keep one of these per fixed
/// column chunk, reused across evaluations, so the steady state stays
/// allocation-free at any thread count.
pub struct ColChunkScratch {
    /// This chunk's α-gradient contribution (length m, zeroed per eval).
    pub(crate) grad_alpha: Vec<f64>,
    /// Per-column `Σ_i t_ij` for the chunk's columns (→ `∂/∂β_j`).
    pub(crate) col_mass: Vec<f64>,
    /// Per-column `Σ_l ψ` staging: the panel walk visits a column once
    /// per group, so ψ is staged per column and folded into `psi` in
    /// ascending column order — the deterministic association.
    pub(crate) psi_col: Vec<f64>,
    /// [`group_grad_contrib`] scratch (max group size).
    pub(crate) group: Vec<f64>,
    /// Quad-kernel scratch: `[i][lane]`-interleaved `[f]₊` staging for
    /// [`crate::simd::group_quad_contrib`] (`LANES ×` max group size).
    pub(crate) quad: Vec<f64>,
    /// Factored-backend staging for one synthesized (group, column)
    /// cost segment (max group size; scalar path).
    pub(crate) cost_seg: Vec<f64>,
    /// Factored-backend tile cache for the vector path (`Some` iff the
    /// problem is factored; allocation is lazy inside the ring, so
    /// scalar-dispatch factored solves never pay for it). Tiles are a
    /// pure function of the immutable cost, so the ring persists across
    /// evaluations — the steady state replays instead of resynthesizing.
    pub(crate) ring: Option<TileRing>,
    /// Partial `Σ ψ` over this chunk's (l, j) pairs.
    pub(crate) psi: f64,
    pub(crate) grads: u64,
    pub(crate) skipped: u64,
    pub(crate) ub_checks: u64,
    pub(crate) ws_hits: u64,
    /// Cost segments/tiles synthesized this eval ([`OracleStats::tiles_built`]).
    pub(crate) tiles_built: u64,
}

impl ColChunkScratch {
    pub(crate) fn new(m: usize, max_cols: usize, max_group: usize) -> Self {
        ColChunkScratch {
            grad_alpha: vec![0.0; m],
            col_mass: vec![0.0; max_cols],
            psi_col: vec![0.0; max_cols],
            group: vec![0.0; max_group],
            quad: vec![0.0; LANES * max_group],
            cost_seg: vec![0.0; max_group],
            ring: None,
            psi: 0.0,
            grads: 0,
            skipped: 0,
            ub_checks: 0,
            ws_hits: 0,
            tiles_built: 0,
        }
    }

    /// One scratch slot per chunk of `ranges`, sized for `prob`. On the
    /// factored backend each slot carries its own [`TileRing`] (slots
    /// map 1:1 to fixed chunks, so rings are unshared and lock-free;
    /// the per-slot byte cap × [`crate::pool::MAX_FIXED_CHUNKS`] bounds
    /// total ring memory at a constant).
    pub(crate) fn slots_for(prob: &OtProblem, ranges: &[Range<usize>]) -> Vec<ColChunkScratch> {
        Self::slots_for_budget(prob, ranges, super::cost::TILE_RING_BUDGET_BYTES)
    }

    /// [`ColChunkScratch::slots_for`] with an explicit per-slot tile-ring
    /// byte budget (the `--tile-ring-kib` knob). The budget only changes
    /// how many synthesized tiles stay resident between visits, never
    /// their values, so every budget is byte-equal on the solve outputs.
    pub(crate) fn slots_for_budget(
        prob: &OtProblem,
        ranges: &[Range<usize>],
        ring_budget_bytes: usize,
    ) -> Vec<ColChunkScratch> {
        let max_cols = ranges.iter().map(|r| r.len()).max().unwrap_or(0);
        let max_group = prob.groups.max_size();
        (0..ranges.len())
            .map(|_| {
                let mut slot = ColChunkScratch::new(prob.m(), max_cols, max_group);
                if prob.is_factored() {
                    slot.ring =
                        Some(TileRing::with_budget(PANEL_COLS * max_group, ring_budget_bytes));
                }
                slot
            })
            .collect()
    }

    /// Zero the accumulators. `grad_alpha`, `col_mass` and `psi_col` are
    /// only dirtied when a gradient was actually computed, so a chunk
    /// whose previous eval computed nothing skips the O(m + cols)
    /// re-zero — the screened sparse regime keeps its cheap per-eval
    /// floor. The per-column buffers are re-zeroed only over the active
    /// prefix `cols` (this chunk's column count): a slot only ever
    /// serves its one fixed chunk, so entries past its `cols` — present
    /// because slots are sized for the longest chunk — can never have
    /// been dirtied.
    pub(crate) fn reset(&mut self, cols: usize) {
        debug_assert!(cols <= self.col_mass.len());
        if self.grads > 0 {
            for v in self.grad_alpha.iter_mut() {
                *v = 0.0;
            }
            for v in self.col_mass[..cols].iter_mut() {
                *v = 0.0;
            }
            for v in self.psi_col[..cols].iter_mut() {
                *v = 0.0;
            }
        }
        self.psi = 0.0;
        self.grads = 0;
        self.skipped = 0;
        self.ub_checks = 0;
        self.ws_hits = 0;
        self.tiles_built = 0;
    }

    /// Fold the per-column ψ staging into `psi` in ascending column
    /// order — called once per chunk after the panel walk. A quiet chunk
    /// (no gradients) holds exact zeros and skips the fold.
    pub(crate) fn fold_psi(&mut self, cols: usize) {
        self.psi = 0.0;
        if self.grads > 0 {
            for &v in &self.psi_col[..cols] {
                self.psi += v;
            }
        }
    }
}

/// Dense kernel over one fixed column chunk, accumulating into the
/// chunk's scratch. The reference [`eval_dense`] and the threaded
/// [`crate::ot::origin::OriginOracle`] both run this exact function
/// over the exact same chunk boundaries, so serial and threaded
/// evaluations agree bit-for-bit.
///
/// The walk is **cache-blocked**: panels of [`PANEL_COLS`] columns are
/// processed group-by-group (`l` outer, `j` inner), so one group's
/// slices of `alpha` and `grad_alpha` stay resident across the panel.
/// Per-element accumulation order is unchanged (for a fixed α entry,
/// contributions still arrive in ascending column order; for a fixed
/// column, in ascending group order), and ψ is staged per column, so
/// the reduction stays deterministic.
/// When a packed-tile engine is active the same walk runs the quad
/// kernel over each panel's full quads (lanes = columns, bit-identical
/// per-lane chains, lane fold in ascending column order — see
/// [`crate::simd`]) and the scalar kernel over the leftover columns, so
/// the scalar and vector paths produce byte-equal results. On the
/// factored backend the vector walk is fed from the slot's
/// [`TileRing`] (synthesized tiles in the identical packed layout)
/// instead of a resident pack — same kernels, same order, byte-equal.
///
/// `cancel` is polled once per chunk (one relaxed load, never inside
/// the lane reduction): a cancelled chunk stays quiet (grads = 0, exact
/// zeros), so the ordered reduction merges nothing from it and
/// uncancelled evaluations are bitwise unaffected by the check.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dense_chunk(
    prob: &OtProblem,
    consts: &KernelConsts,
    alpha: &[f64],
    beta: &[f64],
    c: usize,
    range: Range<usize>,
    slot: &mut ColChunkScratch,
    engine: &SimdEngine,
    cancel: Option<&CancelToken>,
) {
    let cols = range.len();
    slot.reset(cols);
    if cancel.is_some_and(|t| t.is_cancelled()) {
        return;
    }
    match (&engine.pack, prob.cost_backend()) {
        (Some(pack), _) => {
            dense_chunk_vector(prob, consts, alpha, beta, c, range, slot, engine.dispatch, pack)
        }
        (None, CostMatrix::Factored(fac)) if engine.dispatch.is_vector() => {
            dense_chunk_synth(prob, fac, consts, alpha, beta, range, slot, engine.dispatch)
        }
        _ => dense_chunk_scalar(prob, consts, alpha, beta, range, slot),
    }
    slot.fold_psi(cols);
}

/// One scalar (group, column) pair: run [`group_grad_contrib`] and
/// stage its ψ / column mass / counter into the chunk scratch. The unit
/// both walks (dense and screened, scalar and vector-with-fallback)
/// compose from, so the kernel call is written exactly once.
#[inline]
pub(crate) fn scalar_pair(
    prob: &OtProblem,
    consts: &KernelConsts,
    alpha: &[f64],
    beta: &[f64],
    j: usize,
    cols0: usize,
    group_range: Range<usize>,
    slot: &mut ColChunkScratch,
) {
    let g = group_range.len();
    let (psi, mass) = match prob.cost_backend() {
        CostMatrix::Dense(ct) => group_grad_contrib(
            alpha,
            beta[j],
            &ct.row(j)[group_range.clone()],
            group_range,
            consts,
            &mut slot.grad_alpha,
            &mut slot.group,
        ),
        CostMatrix::Factored(fac) => {
            // Synthesize exactly this (group, column) segment — never a
            // full column — so screened callers only pay for what they
            // actually evaluate.
            fac.fill_seg(j, group_range.clone(), &mut slot.cost_seg[..g]);
            slot.tiles_built += 1;
            group_grad_contrib(
                alpha,
                beta[j],
                &slot.cost_seg[..g],
                group_range,
                consts,
                &mut slot.grad_alpha,
                &mut slot.group,
            )
        }
    };
    let col = j - cols0;
    slot.psi_col[col] += psi;
    slot.col_mass[col] += mass;
    slot.grads += 1;
}

/// One vector (group, quad) unit: [`crate::simd::group_quad_contrib`]
/// over columns `j0..j0+LANES` against a packed tile, staged like four
/// [`scalar_pair`] calls in ascending column order.
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn quad_pair(
    dispatch: Dispatch,
    tile: &[f64],
    alpha: &[f64],
    beta: &[f64],
    j0: usize,
    cols0: usize,
    group_range: Range<usize>,
    consts: &KernelConsts,
    slot: &mut ColChunkScratch,
) {
    let beta4 = [beta[j0], beta[j0 + 1], beta[j0 + 2], beta[j0 + 3]];
    let (psi4, mass4) = crate::simd::group_quad_contrib(
        dispatch,
        alpha,
        &beta4,
        tile,
        group_range,
        consts,
        &mut slot.grad_alpha,
        &mut slot.quad,
    );
    let col0 = j0 - cols0;
    for t in 0..LANES {
        slot.psi_col[col0 + t] += psi4[t];
        slot.col_mass[col0 + t] += mass4[t];
    }
    slot.grads += LANES as u64;
}

/// One vector (group, quad) unit on the **factored** backend: like
/// [`quad_pair`], but the tile is synthesized into (or replayed from)
/// the slot's [`TileRing`] — the screened walk's ring-fed quad unit.
/// The ring entry covers *all* `quads` of the (panel, group), so a
/// group that survives screening anywhere in a panel synthesizes its
/// tile once and replays it for every surviving quad; a group screened
/// out across the whole panel never reaches this function and never
/// synthesizes anything.
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn synth_quad_pair(
    fac: &FactoredCost,
    dispatch: Dispatch,
    alpha: &[f64],
    beta: &[f64],
    j0: usize,
    cols0: usize,
    panel_start: usize,
    quads: usize,
    group_l: usize,
    group_range: Range<usize>,
    consts: &KernelConsts,
    slot: &mut ColChunkScratch,
) {
    let g = group_range.len();
    // Disjoint field borrows: the ring's tile slice must coexist with
    // the mutable gradient/staging buffers.
    let ColChunkScratch { grad_alpha, col_mass, psi_col, quad, ring, grads, tiles_built, .. } =
        slot;
    let ring = ring.as_mut().expect("factored slots carry a tile ring");
    let (tile_all, built) = ring.entry((panel_start, group_l), quads * LANES * g, |buf| {
        fac.fill_panel_group(panel_start, quads, group_range.clone(), buf)
    });
    if built {
        *tiles_built += 1;
    }
    let q = (j0 - panel_start) / LANES;
    let tile = &tile_all[q * LANES * g..(q + 1) * LANES * g];
    let beta4 = [beta[j0], beta[j0 + 1], beta[j0 + 2], beta[j0 + 3]];
    let (psi4, mass4) = crate::simd::group_quad_contrib(
        dispatch,
        alpha,
        &beta4,
        tile,
        group_range,
        consts,
        grad_alpha,
        quad,
    );
    let col0 = j0 - cols0;
    for t in 0..LANES {
        psi_col[col0 + t] += psi4[t];
        col_mass[col0 + t] += mass4[t];
    }
    *grads += LANES as u64;
}

/// The scalar panel walk — the reference arithmetic every other path
/// reproduces bitwise.
fn dense_chunk_scalar(
    prob: &OtProblem,
    consts: &KernelConsts,
    alpha: &[f64],
    beta: &[f64],
    range: Range<usize>,
    slot: &mut ColChunkScratch,
) {
    let num_groups = prob.groups.num_groups();
    let cols0 = range.start;
    for panel in panel_ranges(range) {
        for l in 0..num_groups {
            let group_range = prob.groups.range(l);
            for j in panel.clone() {
                scalar_pair(prob, consts, alpha, beta, j, cols0, group_range.clone(), slot);
            }
        }
    }
}

/// The lane-vectorized panel walk: full quads through the packed tiles,
/// leftover columns through the scalar kernel, in the same
/// (panel, group, ascending column) order as the scalar walk.
#[allow(clippy::too_many_arguments)]
fn dense_chunk_vector(
    prob: &OtProblem,
    consts: &KernelConsts,
    alpha: &[f64],
    beta: &[f64],
    c: usize,
    range: Range<usize>,
    slot: &mut ColChunkScratch,
    dispatch: Dispatch,
    pack: &PackedCost,
) {
    let num_groups = prob.groups.num_groups();
    let cols0 = range.start;
    let first_panel = pack.chunk_first_panel(c);
    for (p, panel) in panel_ranges(range).enumerate() {
        let gp = first_panel + p;
        let quads = pack.quads(gp);
        for l in 0..num_groups {
            let group_range = prob.groups.range(l);
            for q in 0..quads {
                let j0 = panel.start + q * LANES;
                quad_pair(
                    dispatch,
                    pack.tile(gp, l, q),
                    alpha,
                    beta,
                    j0,
                    cols0,
                    group_range.clone(),
                    consts,
                    slot,
                );
            }
            for j in (panel.start + quads * LANES)..panel.end {
                scalar_pair(prob, consts, alpha, beta, j, cols0, group_range.clone(), slot);
            }
        }
    }
}

/// The factored vector walk: identical (panel, group, ascending column)
/// order to [`dense_chunk_vector`], but the quad kernel reads tiles
/// synthesized into the slot's [`TileRing`] instead of a resident pack
/// — [`FactoredCost::fill_panel_group`] produces the exact packed
/// `[i][lane]` layout with bitwise-identical values, so this path is
/// byte-equal to the dense vector path (and hence to the scalar
/// reference). Leftover columns synthesize per-group segments like the
/// factored scalar path. Ring hits replay cached tiles at zero
/// synthesis cost; only misses bump `tiles_built`.
#[allow(clippy::too_many_arguments)]
fn dense_chunk_synth(
    prob: &OtProblem,
    fac: &FactoredCost,
    consts: &KernelConsts,
    alpha: &[f64],
    beta: &[f64],
    range: Range<usize>,
    slot: &mut ColChunkScratch,
    dispatch: Dispatch,
) {
    let num_groups = prob.groups.num_groups();
    let cols0 = range.start;
    // Disjoint field borrows: the ring's returned tile slice must
    // coexist with the mutable gradient/staging buffers.
    let ColChunkScratch {
        grad_alpha,
        col_mass,
        psi_col,
        group,
        quad,
        cost_seg,
        ring,
        grads,
        tiles_built,
        ..
    } = slot;
    let ring = ring.as_mut().expect("factored slots carry a tile ring");
    for panel in panel_ranges(range) {
        let quads = panel.len() / LANES;
        for l in 0..num_groups {
            let group_range = prob.groups.range(l);
            let g = group_range.len();
            if quads > 0 {
                let (tile_all, built) =
                    ring.entry((panel.start, l), quads * LANES * g, |buf| {
                        fac.fill_panel_group(panel.start, quads, group_range.clone(), buf)
                    });
                if built {
                    *tiles_built += 1;
                }
                for q in 0..quads {
                    let j0 = panel.start + q * LANES;
                    let tile = &tile_all[q * LANES * g..(q + 1) * LANES * g];
                    let beta4 = [beta[j0], beta[j0 + 1], beta[j0 + 2], beta[j0 + 3]];
                    let (psi4, mass4) = crate::simd::group_quad_contrib(
                        dispatch,
                        alpha,
                        &beta4,
                        tile,
                        group_range.clone(),
                        consts,
                        grad_alpha,
                        quad,
                    );
                    let col0 = j0 - cols0;
                    for t in 0..LANES {
                        psi_col[col0 + t] += psi4[t];
                        col_mass[col0 + t] += mass4[t];
                    }
                    *grads += LANES as u64;
                }
            }
            for j in (panel.start + quads * LANES)..panel.end {
                fac.fill_seg(j, group_range.clone(), &mut cost_seg[..g]);
                *tiles_built += 1;
                let (psi, mass) = group_grad_contrib(
                    alpha,
                    beta[j],
                    &cost_seg[..g],
                    group_range.clone(),
                    consts,
                    grad_alpha,
                    group,
                );
                let col = j - cols0;
                psi_col[col] += psi;
                col_mass[col] += mass;
                *grads += 1;
            }
        }
    }
}

/// Per-eval counter totals folded out of the chunk slots by
/// [`reduce_chunks`], mirroring the [`OracleStats`] counters.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct ChunkTotals {
    pub(crate) psi: f64,
    pub(crate) grads: u64,
    pub(crate) skipped: u64,
    pub(crate) ub_checks: u64,
    pub(crate) ws_hits: u64,
    pub(crate) tiles_built: u64,
}

/// Combine per-chunk partials into the shared gradient **in ascending
/// chunk order** — the deterministic reduction: the association of every
/// floating-point sum is fixed by the chunk boundaries (a function of n
/// alone), never by which thread produced a partial.
pub(crate) fn reduce_chunks(
    ranges: &[Range<usize>],
    slots: &[ColChunkScratch],
    grad_alpha: &mut [f64],
    grad_beta: &mut [f64],
) -> ChunkTotals {
    let mut t = ChunkTotals::default();
    for (range, slot) in ranges.iter().zip(slots) {
        // A chunk that computed nothing holds exact zeros everywhere:
        // merging it would only add +0.0 terms (values unchanged under
        // `==`; the decision itself is thread-count-independent), so the
        // screened sparse regime skips the O(m) merge per quiet chunk.
        if slot.grads > 0 {
            t.psi += slot.psi;
            for (gi, &pi) in grad_alpha.iter_mut().zip(&slot.grad_alpha) {
                *gi += pi;
            }
            for (k, j) in range.clone().enumerate() {
                grad_beta[j] += slot.col_mass[k];
            }
        }
        t.grads += slot.grads;
        t.skipped += slot.skipped;
        t.ub_checks += slot.ub_checks;
        t.ws_hits += slot.ws_hits;
        t.tiles_built += slot.tiles_built;
    }
    t
}

/// Shared dense evaluation over caller-provided chunking/scratch — the
/// zero-alloc entry used by [`crate::ot::origin::OriginOracle`].
/// `cancel` is polled once per chunk; a mid-eval cancellation leaves
/// the remaining chunks quiet (the result is then only used to carry
/// `StopReason::Cancelled` out of the solver, never as a converged
/// iterate).
#[allow(clippy::too_many_arguments)]
pub(crate) fn eval_dense_with(
    prob: &OtProblem,
    consts: &KernelConsts,
    x: &[f64],
    grad: &mut [f64],
    ctx: &ParallelCtx,
    ranges: &[Range<usize>],
    slots: &mut [ColChunkScratch],
    engine: &SimdEngine,
    cancel: Option<&CancelToken>,
) -> (f64, ChunkTotals) {
    let (alpha, beta) = dense_prolog(prob, x, grad);
    let (grad_alpha, grad_beta) = grad.split_at_mut(prob.m());
    ctx.map_chunks(ranges, slots, |c, range, slot| {
        dense_chunk(prob, consts, alpha, beta, c, range, slot, engine, cancel);
    });
    dense_epilog(prob, alpha, beta, ranges, slots, grad_alpha, grad_beta)
}

/// Shape checks + gradient initialization shared by the dense entries:
/// ∇(−D) starts at (−a, −b); transport mass is added on top.
fn dense_prolog<'x>(prob: &OtProblem, x: &'x [f64], grad: &mut [f64]) -> (&'x [f64], &'x [f64]) {
    let m = prob.m();
    let n = prob.n();
    assert_eq!(x.len(), m + n);
    assert_eq!(grad.len(), m + n);
    for (gi, &ai) in grad[..m].iter_mut().zip(&prob.a) {
        *gi = -ai;
    }
    for (gj, &bj) in grad[m..].iter_mut().zip(&prob.b) {
        *gj = -bj;
    }
    x.split_at(m)
}

/// Ordered chunk reduction + dual assembly shared by the dense entries.
fn dense_epilog(
    prob: &OtProblem,
    alpha: &[f64],
    beta: &[f64],
    ranges: &[Range<usize>],
    slots: &[ColChunkScratch],
    grad_alpha: &mut [f64],
    grad_beta: &mut [f64],
) -> (f64, ChunkTotals) {
    let totals = reduce_chunks(ranges, slots, grad_alpha, grad_beta);
    let dual = linalg::dot(alpha, &prob.a) + linalg::dot(beta, &prob.b) - totals.psi;
    (-dual, totals)
}

/// Fully dense negated-dual evaluation — the reference implementation
/// every oracle must agree with. O(mn) per call.
///
/// The accumulation is *chunk-ordered*: columns are processed in the
/// fixed chunks of [`fixed_chunk_ranges`] and per-chunk partial sums are
/// combined in chunk order. This is the canonical arithmetic for the
/// whole crate — the screened oracle and the threaded dense oracle
/// reproduce it bit-for-bit at every thread count.
pub fn eval_dense(
    prob: &OtProblem,
    params: &DualParams,
    x: &[f64],
    grad: &mut [f64],
) -> (f64, u64) {
    eval_dense_threads(prob, params, x, grad, 1)
}

/// [`eval_dense`] with `threads` oracle workers — bit-identical to the
/// serial call for every thread count (deterministic ordered reduction).
/// Creates a context (and, for `threads > 1`, its parked worker set)
/// per call: repeated evaluations should hold a [`DenseEvalScratch`] +
/// [`ParallelCtx`] and use [`eval_dense_reusing`], or an
/// [`crate::ot::origin::OriginOracle`].
pub fn eval_dense_threads(
    prob: &OtProblem,
    params: &DualParams,
    x: &[f64],
    grad: &mut [f64],
    threads: usize,
) -> (f64, u64) {
    let mut scratch = DenseEvalScratch::new(prob);
    eval_dense_reusing(prob, params, x, grad, &ParallelCtx::new(threads), &mut scratch)
}

/// Reusable chunk grid + per-chunk scratch for the standalone dense
/// entries ([`eval_dense_reusing`] / [`eval_dense_forkjoin`]); the
/// oracles embed the same state internally.
pub struct DenseEvalScratch {
    ranges: Vec<Range<usize>>,
    slots: Vec<ColChunkScratch>,
    engine: SimdEngine,
}

impl DenseEvalScratch {
    /// Auto SIMD policy (runtime-dispatched; `GRPOT_SIMD` overrides).
    pub fn new(prob: &OtProblem) -> Self {
        Self::with_simd(prob, SimdMode::Auto)
    }

    /// Explicit SIMD policy — `SimdMode::Scalar` forces the reference
    /// scalar kernels (and skips packing the cost tiles).
    pub fn with_simd(prob: &OtProblem, simd: SimdMode) -> Self {
        let ranges = fixed_chunk_ranges(prob.n());
        let slots = ColChunkScratch::slots_for(prob, &ranges);
        let engine = SimdEngine::new(prob, simd);
        DenseEvalScratch { ranges, slots, engine }
    }

    /// The backend this scratch's evaluations run.
    pub fn dispatch(&self) -> Dispatch {
        self.engine.dispatch
    }
}

/// [`eval_dense`] over a caller-held context and scratch — the
/// persistent-dispatch half of the `bench_parallel` comparison (and a
/// zero-alloc repeated-eval entry in its own right).
pub fn eval_dense_reusing(
    prob: &OtProblem,
    params: &DualParams,
    x: &[f64],
    grad: &mut [f64],
    ctx: &ParallelCtx,
    scratch: &mut DenseEvalScratch,
) -> (f64, u64) {
    let consts = KernelConsts::new(params);
    let (f, totals) = eval_dense_with(
        prob,
        &consts,
        x,
        grad,
        ctx,
        &scratch.ranges,
        &mut scratch.slots,
        &scratch.engine,
        None,
    );
    (f, totals.grads)
}

/// [`eval_dense_reusing`] dispatched through the one-shot scoped
/// fork-join ([`crate::pool::forkjoin_map_chunks`]) instead of the
/// persistent parked pool — the PR-3 dispatch, kept ONLY for the
/// `bench_parallel` / `hotpath_microbench` comparison; nothing on the
/// solver hot path calls this. Byte-equal results to every other dense
/// entry (same chunks, same kernel, same ordered reduction).
pub fn eval_dense_forkjoin(
    prob: &OtProblem,
    params: &DualParams,
    x: &[f64],
    grad: &mut [f64],
    threads: usize,
    scratch: &mut DenseEvalScratch,
) -> (f64, u64) {
    let consts = KernelConsts::new(params);
    let (alpha, beta) = dense_prolog(prob, x, grad);
    let (grad_alpha, grad_beta) = grad.split_at_mut(prob.m());
    let engine = &scratch.engine;
    crate::pool::forkjoin_map_chunks(
        threads,
        &scratch.ranges,
        &mut scratch.slots,
        |c, range, slot| {
            dense_chunk(prob, &consts, alpha, beta, c, range, slot, engine, None);
        },
    );
    let (f, totals) =
        dense_epilog(prob, alpha, beta, &scratch.ranges, &scratch.slots, grad_alpha, grad_beta);
    (f, totals.grads)
}

/// The (positive) dual objective at `x` (no gradient).
pub fn dual_objective(prob: &OtProblem, params: &DualParams, x: &[f64]) -> f64 {
    let mut grad = vec![0.0; x.len()];
    -eval_dense(prob, params, x, &mut grad).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pool::fixed_chunk_len;
    use crate::rng::Pcg64;

    fn toy_problem() -> OtProblem {
        // 4 source samples in 2 groups, 3 targets.
        let cost = Mat::from_vec(
            4,
            3,
            vec![
                0.1, 0.9, 0.5, //
                0.2, 0.8, 0.4, //
                0.9, 0.1, 0.5, //
                0.8, 0.2, 0.6,
            ],
        );
        OtProblem::from_parts(
            vec![0.25; 4],
            vec![1.0 / 3.0; 3],
            &cost,
            &[0, 0, 1, 1],
        )
    }

    #[test]
    fn params_mapping() {
        let p = DualParams::new(2.0, 0.25);
        assert!((p.lambda_quad() - 1.5).abs() < 1e-15);
        assert!((p.tau() - 0.5).abs() < 1e-15);
        assert!((p.mu() - (1.0 / 3.0)).abs() < 1e-15);
    }

    #[test]
    #[should_panic]
    fn rho_one_rejected() {
        DualParams::new(1.0, 1.0);
    }

    #[test]
    fn problem_shapes() {
        let p = toy_problem();
        assert_eq!(p.m(), 4);
        assert_eq!(p.n(), 3);
        assert_eq!(p.dim(), 7);
        assert_eq!(p.cost_t().shape(), (3, 4));
        assert_eq!(p.cost().shape(), (4, 3));
        assert_eq!(p.groups.num_groups(), 2);
    }

    #[test]
    fn eval_zero_point() {
        // At α=β=0 and c ≥ 0: every f = −c ≤ 0, so ψ = 0 and T = 0.
        let p = toy_problem();
        let params = DualParams::new(1.0, 0.5);
        let x = vec![0.0; p.dim()];
        let mut g = vec![0.0; p.dim()];
        let (negd, _) = eval_dense(&p, &params, &x, &mut g);
        assert!((negd - 0.0).abs() < 1e-15);
        // Gradient is (−a, −b).
        for i in 0..p.m() {
            assert!((g[i] + p.a[i]).abs() < 1e-15);
        }
        for j in 0..p.n() {
            assert!((g[p.m() + j] + p.b[j]).abs() < 1e-15);
        }
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let p = toy_problem();
        let params = DualParams::new(0.7, 0.3);
        let mut rng = Pcg64::new(42);
        let x: Vec<f64> = (0..p.dim()).map(|_| rng.uniform(-0.5, 0.8)).collect();
        let mut g = vec![0.0; p.dim()];
        let (f0, _) = eval_dense(&p, &params, &x, &mut g);
        let eps = 1e-6;
        for k in 0..p.dim() {
            let mut xp = x.clone();
            xp[k] += eps;
            let mut xm = x.clone();
            xm[k] -= eps;
            let mut scratch = vec![0.0; p.dim()];
            let (fp, _) = eval_dense(&p, &params, &xp, &mut scratch);
            let (fm, _) = eval_dense(&p, &params, &xm, &mut scratch);
            let fd = (fp - fm) / (2.0 * eps);
            assert!(
                (fd - g[k]).abs() < 1e-5,
                "component {k}: fd={fd} analytic={} f0={f0}",
                g[k]
            );
        }
    }

    #[test]
    fn psi_closed_form_matches_conjugate_definition() {
        // ψ(f) must equal sup_{g≥0} fᵀg − Ψ(g); verify against a fine
        // numeric maximization over the soft-threshold parametric form.
        let params = DualParams::new(1.3, 0.4);
        let tau = params.tau();
        let lq = params.lambda_quad();
        let f = [0.8, -0.2, 0.5, 0.1];
        // Closed form for a single group:
        let z: f64 = f.iter().filter(|&&v| v > 0.0).map(|v| v * v).sum::<f64>().sqrt();
        let closed = if z > tau { (z - tau) * (z - tau) / (2.0 * lq) } else { 0.0 };
        // Numeric: maximize over g = s·[f]₊ direction (optimal direction)
        // plus random perturbations must not beat it.
        let fplus: Vec<f64> = f.iter().map(|&v| v.max(0.0)).collect();
        let obj = |g: &[f64]| -> f64 {
            let dot: f64 = f.iter().zip(g).map(|(a, b)| a * b).sum();
            let nrm2: f64 = g.iter().map(|v| v * v).sum();
            let nrm: f64 = nrm2.sqrt();
            dot - lq / 2.0 * nrm2 - tau * nrm
        };
        let mut best = 0.0f64;
        for step in 0..2000 {
            let s = step as f64 * 1e-3;
            let g: Vec<f64> = fplus.iter().map(|&v| s * v).collect();
            best = best.max(obj(&g));
        }
        assert!((best - closed).abs() < 1e-4, "numeric={best} closed={closed}");
        // Random nonnegative candidates never exceed the closed form.
        let mut rng = Pcg64::new(7);
        for _ in 0..500 {
            let g: Vec<f64> = (0..4).map(|_| rng.uniform(0.0, 1.5)).collect();
            assert!(obj(&g) <= closed + 1e-9);
        }
    }

    #[test]
    fn group_grad_zero_below_threshold() {
        let alpha = [0.1, 0.1];
        let c = [0.0, 0.0];
        let mut ga = [0.0, 0.0];
        let mut scratch = [0.0, 0.0];
        // z = sqrt(2)*0.1 ≈ 0.141 < tau=0.5 ⇒ zero contribution.
        // (τ = γρ = 0.5, λ_quad = γ(1−ρ) = 0.5 at these params.)
        let consts = KernelConsts::new(&DualParams::new(1.0, 0.5));
        let (psi, mass) =
            group_grad_contrib(&alpha, 0.0, &c, 0..2, &consts, &mut ga, &mut scratch);
        assert_eq!(psi, 0.0);
        assert_eq!(mass, 0.0);
        assert_eq!(ga, [0.0, 0.0]);
    }

    #[test]
    fn panel_ranges_cover_every_chunk_exactly() {
        for (lo, hi) in [(0usize, 0usize), (3, 19), (16, 48), (5, 6), (0, 8), (7, 40)] {
            let panels: Vec<_> = panel_ranges(lo..hi).collect();
            let mut expect = lo;
            for p in &panels {
                assert_eq!(p.start, expect, "contiguous panels in {lo}..{hi}");
                assert!(!p.is_empty() && p.len() <= PANEL_COLS);
                expect = p.end;
            }
            assert_eq!(expect, hi.max(lo), "panels cover {lo}..{hi}");
            assert_eq!(panels.len(), panel_count(hi - lo));
        }
    }

    #[test]
    fn kernel_consts_match_params() {
        let p = DualParams::new(2.0, 0.25);
        let c = KernelConsts::new(&p);
        assert_eq!(c.tau, p.tau());
        assert_eq!(c.tau_sq, p.tau() * p.tau());
        assert_eq!(c.inv_lq, 1.0 / p.lambda_quad());
        assert_eq!(c.half_inv_lq, 0.5 / p.lambda_quad());
    }

    #[test]
    fn reusing_and_forkjoin_entries_match_reference() {
        let p = toy_problem();
        let params = DualParams::new(0.7, 0.3);
        let mut rng = Pcg64::new(8);
        let x: Vec<f64> = (0..p.dim()).map(|_| rng.uniform(-0.5, 0.8)).collect();
        let mut g_ref = vec![0.0; p.dim()];
        let (f_ref, n_ref) = eval_dense(&p, &params, &x, &mut g_ref);
        let ctx = ParallelCtx::new(2);
        let mut scratch = DenseEvalScratch::new(&p);
        for _ in 0..3 {
            let mut g = vec![0.0; p.dim()];
            let (f, n) = eval_dense_reusing(&p, &params, &x, &mut g, &ctx, &mut scratch);
            assert_eq!(f, f_ref);
            assert_eq!(g, g_ref);
            assert_eq!(n, n_ref);
            let mut g = vec![0.0; p.dim()];
            let (f, n) = eval_dense_forkjoin(&p, &params, &x, &mut g, 2, &mut scratch);
            assert_eq!(f, f_ref);
            assert_eq!(g, g_ref);
            assert_eq!(n, n_ref);
        }
    }

    #[test]
    fn packed_cost_is_cached_per_problem_instance() {
        let p = toy_problem();
        let first = p.packed_cost();
        let again = p.packed_cost();
        assert!(Arc::ptr_eq(&first, &again), "second access must reuse the cached pack");
        // A clone shares the already-built pack (identical cost data).
        let cloned = p.clone();
        let theirs = cloned.packed_cost();
        assert!(Arc::ptr_eq(&first, &theirs), "clone must not repack");
        // A clone taken before the first pack builds its own lazily.
        let fresh_clone = toy_problem().clone();
        let built = fresh_clone.packed_cost();
        assert!(!Arc::ptr_eq(&first, &built));
    }

    #[test]
    fn cost_mutation_invalidates_tile_cache() {
        let mut p = toy_problem();
        let before = p.packed_cost();
        // Mutating a clone's costs drops only the clone's cache.
        let mut cloned = p.clone();
        cloned.cost_t_mut()[(0, 0)] += 0.5;
        assert!(!Arc::ptr_eq(&before, &cloned.packed_cost()), "clone must repack");
        assert!(Arc::ptr_eq(&before, &p.packed_cost()), "original keeps its pack");
        p.cost_t_mut()[(0, 0)] += 0.5;
        let after = p.packed_cost();
        assert!(!Arc::ptr_eq(&before, &after), "mutation must force a repack");
    }

    #[test]
    fn reset_clamps_to_active_prefix() {
        // A slot sized for the longest chunk but serving a short final
        // chunk must only re-zero the active prefix; entries past it
        // are never dirtied by the walk, so the clamp loses nothing.
        let mut s = ColChunkScratch::new(4, 8, 3);
        s.grads = 1;
        s.col_mass[2] = 1.0;
        s.psi_col[3] = 2.0;
        // Simulate the untouched (never-dirtied) tail staying as-is.
        s.col_mass[6] = 0.0;
        s.reset(4);
        assert!(s.col_mass[..4].iter().all(|&v| v == 0.0));
        assert!(s.psi_col[..4].iter().all(|&v| v == 0.0));
        assert_eq!(s.grads, 0);
        assert_eq!(s.psi, 0.0);
    }

    /// Short-final-chunk regression for the clamped reset: a problem
    /// whose fixed grid ends in a chunk shorter than the slot's
    /// capacity must stay byte-stable across repeated evaluations on
    /// reused scratch.
    #[test]
    fn short_final_chunk_reuse_is_byte_stable() {
        let mut rng = Pcg64::new(0x19);
        // n = 19 ⇒ chunks [0, 16) and [16, 19): the final chunk uses 3
        // of its 16 slot columns.
        let m = 6;
        let n = 19;
        let cost = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
        let p = OtProblem::from_parts(
            vec![1.0 / m as f64; m],
            vec![1.0 / n as f64; n],
            &cost,
            &[0, 0, 1, 1, 2, 2],
        );
        assert!(fixed_chunk_ranges(p.n()).last().unwrap().len() < fixed_chunk_len(p.n()));
        let params = DualParams::new(0.6, 0.4);
        let ctx = ParallelCtx::new(1);
        let mut scratch = DenseEvalScratch::new(&p);
        let xs: Vec<Vec<f64>> = (0..3)
            .map(|_| (0..p.dim()).map(|_| rng.uniform(-0.4, 0.6)).collect())
            .collect();
        for x in &xs {
            let mut g_fresh = vec![0.0; p.dim()];
            let (f_fresh, _) = eval_dense(&p, &params, x, &mut g_fresh);
            let mut g = vec![0.0; p.dim()];
            let (f, _) = eval_dense_reusing(&p, &params, x, &mut g, &ctx, &mut scratch);
            assert_eq!(f.to_bits(), f_fresh.to_bits());
            assert_eq!(g, g_fresh);
        }
    }

    /// The packed-tile vector walk must reproduce the scalar walk
    /// byte-for-byte — over ragged panels, partial quads and mixed
    /// group activity, at 1 and 2 threads.
    #[test]
    fn simd_dense_eval_matches_scalar_bitwise() {
        let mut rng = Pcg64::new(0x51D2);
        let m = 10; // groups of 3, 3, 4
        let n = 19; // ragged panels + short final chunk
        let cost = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
        let p = OtProblem::from_parts(
            vec![1.0 / m as f64; m],
            vec![1.0 / n as f64; n],
            &cost,
            &[0, 0, 0, 1, 1, 1, 2, 2, 2, 2],
        );
        for params in [DualParams::new(0.6, 0.4), DualParams::new(5.0, 0.8)] {
            for _ in 0..4 {
                let x: Vec<f64> = (0..p.dim()).map(|_| rng.uniform(-0.4, 0.6)).collect();
                let mut g_ref = vec![0.0; p.dim()];
                let mut scalar = DenseEvalScratch::with_simd(&p, SimdMode::Scalar);
                let ctx1 = ParallelCtx::new(1);
                let (f_ref, n_ref) =
                    eval_dense_reusing(&p, &params, &x, &mut g_ref, &ctx1, &mut scalar);
                for mode in [SimdMode::Auto, SimdMode::Portable] {
                    for threads in [1usize, 2] {
                        let ctx = ParallelCtx::new(threads);
                        let mut scratch = DenseEvalScratch::with_simd(&p, mode);
                        let mut g = vec![0.0; p.dim()];
                        let (f, ng) =
                            eval_dense_reusing(&p, &params, &x, &mut g, &ctx, &mut scratch);
                        assert_eq!(
                            f.to_bits(),
                            f_ref.to_bits(),
                            "objective {mode:?} threads={threads}"
                        );
                        assert_eq!(g, g_ref, "gradient {mode:?} threads={threads}");
                        assert_eq!(ng, n_ref, "grad count {mode:?} threads={threads}");
                    }
                }
            }
        }
    }

    #[test]
    fn try_from_parts_validates_inputs() {
        let cost = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        // Well-formed inputs succeed and match the unchecked path.
        let ok = OtProblem::try_from_parts(vec![0.6, 0.4], vec![0.5, 0.5], &cost, &[1, 0])
            .expect("valid parts");
        assert_eq!(ok.a, vec![0.4, 0.6]);
        // Shape mismatch.
        let e = OtProblem::try_from_parts(vec![0.5; 3], vec![0.5, 0.5], &cost, &[1, 0])
            .unwrap_err();
        assert!(e.to_string().contains("shape mismatch"), "{e}");
        // Non-finite cost.
        let bad = Mat::from_vec(2, 2, vec![1.0, f64::NAN, 3.0, 4.0]);
        let e = OtProblem::try_from_parts(vec![0.5, 0.5], vec![0.5, 0.5], &bad, &[0, 1])
            .unwrap_err();
        assert!(e.to_string().contains("non-finite"), "{e}");
        // Negative / zero-mass marginals.
        let e = OtProblem::try_from_parts(vec![-0.1, 1.1], vec![0.5, 0.5], &cost, &[0, 1])
            .unwrap_err();
        assert!(e.to_string().contains("nonnegative"), "{e}");
        let e = OtProblem::try_from_parts(vec![0.5, 0.5], vec![0.0, 0.0], &cost, &[0, 1])
            .unwrap_err();
        assert!(e.to_string().contains("zero total mass"), "{e}");
        // Empty dimension.
        let empty = Mat::zeros(0, 2);
        let e = OtProblem::try_from_parts(vec![], vec![0.5, 0.5], &empty, &[]).unwrap_err();
        assert!(e.to_string().contains("empty"), "{e}");
    }

    #[test]
    fn try_from_dataset_accepts_generated_pairs_and_rejects_poison() {
        let spec = crate::coordinator::config::DatasetSpec {
            family: "synthetic".into(),
            param1: 3,
            param2: 4,
            seed: 1,
            ..Default::default()
        };
        let pair = crate::coordinator::registry::build_pair(&spec).unwrap();
        let checked = OtProblem::try_from_dataset(&pair).expect("generated pair is valid");
        let unchecked = OtProblem::from_dataset(&pair);
        assert_eq!(checked.a, unchecked.a);
        assert_eq!(checked.b, unchecked.b);
        // Poison a coordinate: the checked path reports, never panics.
        let mut bad = crate::coordinator::registry::build_pair(&spec).unwrap();
        bad.source.x[(0, 0)] = f64::INFINITY;
        let e = OtProblem::try_from_dataset(&bad).unwrap_err();
        assert!(e.to_string().contains("non-finite"), "{e}");
    }

    #[test]
    fn from_parts_permutes_cost_rows() {
        // Labels out of order: sample 0 has label 1, sample 1 label 0.
        let cost = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let p = OtProblem::from_parts(vec![0.6, 0.4], vec![0.5, 0.5], &cost, &[1, 0]);
        // Sorted order: sample1 (label0) first.
        assert_eq!(p.a, vec![0.4, 0.6]);
        assert_eq!(p.cost_t()[(0, 0)], 3.0); // c(sample1, target0)
        assert_eq!(p.cost_t()[(0, 1)], 1.0);
        assert_eq!(p.cost_t()[(1, 0)], 4.0);
        assert_eq!(p.cost_t()[(1, 1)], 2.0);
    }

    fn points_pair(seed: u64, m: usize, n: usize, d: usize) -> (Mat, Vec<usize>, Mat) {
        let mut rng = Pcg64::new(seed);
        let xs = Mat::from_fn(m, d, |_, _| rng.uniform(-1.0, 1.0));
        let xt = Mat::from_fn(n, d, |_, _| rng.uniform(-1.0, 1.0));
        let labels: Vec<usize> = (0..m).map(|i| i / 3).collect();
        (xs, labels, xt)
    }

    /// The factored backend must expose bitwise-identical cost values
    /// to the dense build of the same points, and factored evaluation
    /// (scalar and vector, 1 and 2 threads) must be byte-equal to the
    /// dense reference.
    #[test]
    fn factored_backend_matches_dense_bitwise() {
        let (xs, labels, xt) = points_pair(0xFAC7, 9, 19, 3);
        let dense =
            OtProblem::try_from_points(&xs, &labels, &xt, CostMode::Dense).unwrap();
        let fact =
            OtProblem::try_from_points(&xs, &labels, &xt, CostMode::Factored).unwrap();
        assert!(!dense.is_factored());
        assert!(fact.is_factored());
        assert_eq!(fact.cost_mode_name(), "factored");
        // Cost values agree entry-for-entry…
        let (cd, cf) = (dense.cost(), fact.cost());
        assert_eq!(cd.shape(), cf.shape());
        for (a, b) in cd.as_slice().iter().zip(cf.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // …and columns through the shared accessor.
        let mut buf = Vec::new();
        let col = fact.cost_col(5, &mut buf);
        for (i, &v) in col.iter().enumerate() {
            assert_eq!(v.to_bits(), cd[(i, 5)].to_bits());
        }
        // …and the factored footprint is the small one.
        assert!(fact.cost_bytes() < dense.cost_bytes());
        // Full evaluation: every dispatch × thread count byte-equal.
        let params = DualParams::new(0.7, 0.3);
        let mut rng = Pcg64::new(0xEE);
        let x: Vec<f64> = (0..dense.dim()).map(|_| rng.uniform(-0.4, 0.6)).collect();
        let mut g_ref = vec![0.0; dense.dim()];
        let (f_ref, n_ref) = eval_dense(&dense, &params, &x, &mut g_ref);
        for mode in [SimdMode::Scalar, SimdMode::Auto] {
            for threads in [1usize, 2] {
                let ctx = ParallelCtx::new(threads);
                let mut scratch = DenseEvalScratch::with_simd(&fact, mode);
                let mut g = vec![0.0; fact.dim()];
                let (f, ng) = eval_dense_reusing(&fact, &params, &x, &mut g, &ctx, &mut scratch);
                assert_eq!(f.to_bits(), f_ref.to_bits(), "{mode:?} threads={threads}");
                assert_eq!(g, g_ref, "{mode:?} threads={threads}");
                assert_eq!(ng, n_ref, "{mode:?} threads={threads}");
                // Repeat on the warm ring: hits must replay identically.
                let mut g2 = vec![0.0; fact.dim()];
                let (f2, _) = eval_dense_reusing(&fact, &params, &x, &mut g2, &ctx, &mut scratch);
                assert_eq!(f2.to_bits(), f_ref.to_bits());
                assert_eq!(g2, g_ref);
            }
        }
    }

    #[test]
    #[should_panic(expected = "factored cost backend")]
    fn cost_t_panics_on_factored() {
        let (xs, labels, xt) = points_pair(0xD00D, 6, 5, 2);
        let fact = OtProblem::try_from_points(&xs, &labels, &xt, CostMode::Factored).unwrap();
        let _ = fact.cost_t();
    }

    /// A cancelled token quiets every chunk: the eval returns the
    /// no-transport objective (only the −a/−b prolog survives) instead
    /// of running the walk. Uncancelled armed tokens change nothing.
    #[test]
    fn cancelled_eval_stays_quiet_and_armed_token_is_transparent() {
        let p = toy_problem();
        let params = DualParams::new(0.7, 0.3);
        let consts = KernelConsts::new(&params);
        let mut rng = Pcg64::new(3);
        let x: Vec<f64> = (0..p.dim()).map(|_| rng.uniform(0.3, 1.0)).collect();
        let ctx = ParallelCtx::new(1);
        let ranges = fixed_chunk_ranges(p.n());
        let mut slots = ColChunkScratch::slots_for(&p, &ranges);
        let engine = SimdEngine::new(&p, SimdMode::Scalar);
        let mut g_ref = vec![0.0; p.dim()];
        let (f_ref, totals_ref) =
            eval_dense_with(&p, &consts, &x, &mut g_ref, &ctx, &ranges, &mut slots, &engine, None);
        assert!(totals_ref.grads > 0, "x chosen to transport mass");
        // Armed but uncancelled: byte-identical.
        let armed = CancelToken::with_deadline(
            std::time::Instant::now() + std::time::Duration::from_secs(3600),
        );
        let mut g = vec![0.0; p.dim()];
        let (f, totals) = eval_dense_with(
            &p, &consts, &x, &mut g, &ctx, &ranges, &mut slots, &engine, Some(&armed),
        );
        assert_eq!(f.to_bits(), f_ref.to_bits());
        assert_eq!(g, g_ref);
        assert_eq!(totals.grads, totals_ref.grads);
        // Cancelled: every chunk quiet, zero gradients computed.
        let dead = CancelToken::new();
        dead.cancel();
        let mut g = vec![0.0; p.dim()];
        let (_, totals) = eval_dense_with(
            &p, &consts, &x, &mut g, &ctx, &ranges, &mut slots, &engine, Some(&dead),
        );
        assert_eq!(totals.grads, 0);
        for i in 0..p.m() {
            assert_eq!(g[i], -p.a[i]);
        }
    }
}
