//! Hot-path microbenchmarks for the performance pass (§Perf in
//! EXPERIMENTS.md): isolates the dense oracle evaluation, the screened
//! evaluation (high/low sparsity), snapshot refresh and working-set
//! construction so individual optimizations can be measured.

mod common;

use common::*;
use grpot::benchlib::{bench_fn, report_dir, BenchOptions, Table};
use grpot::data::synthetic;
use grpot::ot::dual::{DualOracle, DualParams};
use grpot::ot::origin::OriginOracle;
use grpot::ot::regularizer::{AnyRegularizer, DenseRegOracle, RegKind};
use grpot::ot::screening::ScreeningOracle;
use grpot::ot::solve::SolveOptions;
use grpot::pool::{chunk_ranges, forkjoin_map_chunks, ParallelCtx};
use grpot::rng::Pcg64;
use grpot::simd::{Dispatch, SimdMode};

fn main() {
    banner("hotpath microbench");
    let l = size3(8, 40, 160);
    let pair = synthetic::controlled_classes(l, 10, 0x407B);
    let prob = problem_of(&pair);
    println!("problem: m=n={} |L|={}", prob.m(), l);

    let mut rng = Pcg64::new(3);
    // A dual point with mixed activity (some groups on, some off).
    let x: Vec<f64> = (0..prob.dim()).map(|_| rng.uniform(-0.1, 0.15)).collect();
    let mut grad = vec![0.0; prob.dim()];
    let opts = BenchOptions { warmup: 2, iters: 15, max_seconds: 120.0 };

    let mut table = Table::new("hot-path microbenchmarks", &["case", "ms/op"]);
    let mut record = |name: &str, ms: f64| {
        println!("{name:<34} {ms:>9.3} ms");
        table.row(vec![name.into(), format!("{ms:.3}")]);
    };

    // Dense eval, serial and with 4 intra-eval oracle threads (results
    // are bit-identical; only the wall clock moves).
    let sparse_params = DualParams::new(5.0, 0.8); // strong reg ⇒ sparse
    let dense_params = DualParams::new(0.01, 0.2); // weak reg ⇒ dense
    for (tag, params) in [("sparse", sparse_params), ("dense", dense_params)] {
        for threads in [1usize, 4] {
            let mut origin = OriginOracle::with_threads(&prob, params, threads);
            let t = bench_fn("origin", &opts, || {
                origin.eval(&x, &mut grad);
            });
            record(&format!("origin eval ({tag}, {threads}t)"), t.seconds() * 1e3);

            let mut screen = ScreeningOracle::with_threads(&prob, params, true, threads);
            screen.refresh(&x);
            let t = bench_fn("screen", &opts, || {
                screen.eval(&x, &mut grad);
            });
            record(&format!("screened eval ({tag}, {threads}t)"), t.seconds() * 1e3);
        }
    }

    // Snapshot refresh (the O(mn) periodic cost), serial vs threaded.
    for threads in [1usize, 4] {
        let mut screen = ScreeningOracle::with_threads(&prob, sparse_params, true, threads);
        let t = bench_fn("refresh", &opts, || {
            screen.refresh(&x);
        });
        record(&format!("snapshot + ws refresh ({threads}t)"), t.seconds() * 1e3);
    }

    // Per-regularizer dense eval through the pluggable trait oracle:
    // group lasso here measures the trait-dispatch path against the
    // specialized kernels above; squared ℓ2 / negentropy are the new
    // conjugates (Blondel et al. 2018) with no SIMD specialization yet.
    for kind in [RegKind::GroupLasso, RegKind::SquaredL2, RegKind::NegEntropy] {
        for threads in [1usize, 4] {
            let reg = AnyRegularizer::build(kind, 1.0, 0.5, &prob.groups).expect("build reg");
            let mut oracle = DenseRegOracle::new(&prob, reg, ParallelCtx::new(threads));
            let t = bench_fn("reg-dense", &opts, || {
                oracle.eval(&x, &mut grad);
            });
            record(&format!("trait dense eval ({}, {threads}t)", kind.name()), t.seconds() * 1e3);
        }
    }

    // SIMD kernel comparison: the scalar reference kernels vs the
    // runtime-dispatched vector kernels on the same evaluations —
    // full-panel dense (all quads fully active), a masked screened
    // panel (mixed activity ⇒ vector quads + per-lane scalar fallback)
    // and the skip-heavy screened regime (bulk panel skips dominate).
    // Byte-equality is asserted before timing; the speedup rows land in
    // the bench JSON through the emitted CSV.
    let simd_name = Dispatch::resolve(SimdMode::Auto).name();
    println!("\nsimd kernels: auto dispatch resolves to '{simd_name}'");
    // Ratios live in their own table so the bench JSON never mixes a
    // unitless speedup into the ms/op column.
    let mut ratio_table =
        Table::new("simd kernel speedup (scalar ms / auto ms)", &["case", "speedup"]);
    let medium_params = DualParams::new(1.0, 0.5);
    let mut g_s = vec![0.0; prob.dim()];
    let mut g_a = vec![0.0; prob.dim()];
    let cases: [(&str, DualParams, bool); 3] = [
        ("dense full panel", dense_params, false),
        ("screened masked panel", medium_params, true),
        ("screened skip-heavy", sparse_params, true),
    ];
    let simd_opts = |params: DualParams, simd: SimdMode| {
        SolveOptions::new().gamma(params.gamma).rho(params.rho).simd(simd)
    };
    for (tag, params, screened) in cases {
        let (scalar_ms, auto_ms) = if screened {
            let mut s = ScreeningOracle::with_options(&prob, &simd_opts(params, SimdMode::Scalar));
            let mut a = ScreeningOracle::with_options(&prob, &simd_opts(params, SimdMode::Auto));
            s.refresh(&x);
            a.refresh(&x);
            let fs = s.eval(&x, &mut g_s);
            let fa = a.eval(&x, &mut g_a);
            assert_eq!(fs.to_bits(), fa.to_bits(), "{tag}: objective dispatch mismatch");
            assert_eq!(g_s, g_a, "{tag}: gradient dispatch mismatch");
            let ts = bench_fn("simd-scalar", &opts, || {
                s.eval(&x, &mut g_s);
            });
            let ta = bench_fn("simd-auto", &opts, || {
                a.eval(&x, &mut g_a);
            });
            (ts.seconds() * 1e3, ta.seconds() * 1e3)
        } else {
            let mut s = OriginOracle::with_options(&prob, &simd_opts(params, SimdMode::Scalar));
            let mut a = OriginOracle::with_options(&prob, &simd_opts(params, SimdMode::Auto));
            let fs = s.eval(&x, &mut g_s);
            let fa = a.eval(&x, &mut g_a);
            assert_eq!(fs.to_bits(), fa.to_bits(), "{tag}: objective dispatch mismatch");
            assert_eq!(g_s, g_a, "{tag}: gradient dispatch mismatch");
            let ts = bench_fn("simd-scalar", &opts, || {
                s.eval(&x, &mut g_s);
            });
            let ta = bench_fn("simd-auto", &opts, || {
                a.eval(&x, &mut g_a);
            });
            (ts.seconds() * 1e3, ta.seconds() * 1e3)
        };
        record(&format!("{tag} (simd scalar)"), scalar_ms);
        record(&format!("{tag} (simd {simd_name})"), auto_ms);
        let speedup = scalar_ms / auto_ms.max(1e-9);
        println!("{:<34} {speedup:>8.2}x", format!("{tag} (speedup)"));
        ratio_table.row(vec![tag.into(), format!("{speedup:.2}")]);
    }
    ratio_table.emit(&report_dir(), "hotpath_simd_speedup");

    // Tracing overhead on the full fast solve: GRPOT_TRACE=off (one
    // relaxed atomic load per gate) vs full (solve + outer-round spans
    // into the per-thread rings). Byte-equality of the solver outputs
    // across modes is asserted before timing — the observability layer
    // must never perturb the math it watches.
    {
        use grpot::coordinator::sweep;
        use grpot::obs::{self, TraceMode};
        let trace_opts = SolveOptions::new().gamma(1.0).rho(0.5).max_iters(common::max_iters());
        obs::set_trace_mode(TraceMode::Off);
        let off_res = sweep::solve(&prob, grpot::coordinator::config::Method::Fast, &trace_opts)
            .expect("solve");
        obs::set_trace_mode(TraceMode::Full);
        let full_res = sweep::solve(&prob, grpot::coordinator::config::Method::Fast, &trace_opts)
            .expect("solve");
        assert_eq!(
            off_res.dual_objective.to_bits(),
            full_res.dual_objective.to_bits(),
            "tracing perturbed the objective"
        );
        for (a, b) in off_res.x.iter().zip(&full_res.x) {
            assert_eq!(a.to_bits(), b.to_bits(), "tracing perturbed the dual variables");
        }
        obs::set_trace_mode(TraceMode::Off);
        let t = bench_fn("solve-trace-off", &opts, || {
            let _ = sweep::solve(&prob, grpot::coordinator::config::Method::Fast, &trace_opts);
        });
        record("fast solve (GRPOT_TRACE=off)", t.seconds() * 1e3);
        obs::set_trace_mode(TraceMode::Full);
        let t = bench_fn("solve-trace-full", &opts, || {
            let _ = sweep::solve(&prob, grpot::coordinator::config::Method::Fast, &trace_opts);
        });
        record("fast solve (GRPOT_TRACE=full)", t.seconds() * 1e3);
        obs::set_trace_mode(TraceMode::Off);
    }

    // Cancellation-checkpoint overhead on the full fast solve: no token
    // (a plain Option test per iteration) vs an armed far-future
    // deadline token (one relaxed atomic load + a clock read per
    // iteration). Uncancelled tokens must never perturb the math —
    // byte-equality is asserted before timing.
    {
        use grpot::coordinator::sweep;
        use grpot::fault::CancelToken;
        let far = std::time::Instant::now() + std::time::Duration::from_secs(3600);
        let base_opts = SolveOptions::new().gamma(1.0).rho(0.5).max_iters(common::max_iters());
        let armed_opts = base_opts.clone().cancel(CancelToken::with_deadline(far));
        let plain_res = sweep::solve(&prob, grpot::coordinator::config::Method::Fast, &base_opts)
            .expect("solve");
        let armed_res = sweep::solve(&prob, grpot::coordinator::config::Method::Fast, &armed_opts)
            .expect("solve");
        assert_eq!(
            plain_res.dual_objective.to_bits(),
            armed_res.dual_objective.to_bits(),
            "an uncancelled token perturbed the objective"
        );
        for (a, b) in plain_res.x.iter().zip(&armed_res.x) {
            assert_eq!(a.to_bits(), b.to_bits(), "an uncancelled token perturbed the duals");
        }
        assert_eq!(plain_res.iterations, armed_res.iterations);
        let t = bench_fn("solve-no-token", &opts, || {
            let _ = sweep::solve(&prob, grpot::coordinator::config::Method::Fast, &base_opts);
        });
        record("fast solve (no cancel token)", t.seconds() * 1e3);
        let t = bench_fn("solve-armed-token", &opts, || {
            let _ = sweep::solve(&prob, grpot::coordinator::config::Method::Fast, &armed_opts);
        });
        record("fast solve (armed deadline token)", t.seconds() * 1e3);
    }

    // Bare dispatch latency on a near-empty job — the per-eval floor the
    // screened sparse regime pays: persistent parked handoff vs the
    // PR-3 scoped fork-join over the same 32-chunk grid.
    let ranges = chunk_ranges(32 * 16, 16);
    let mut slots = vec![0u64; ranges.len()];
    let touch = |c: usize, _range: std::ops::Range<usize>, slot: &mut u64| {
        *slot = c as u64;
    };
    let ctx = ParallelCtx::new(4);
    ctx.map_chunks(&ranges, &mut slots, touch); // spawn outside timing
    let t = bench_fn("dispatch-persistent", &opts, || {
        ctx.map_chunks(&ranges, &mut slots, touch);
    });
    record("dispatch persistent (4t, empty)", t.seconds() * 1e3);
    let t = bench_fn("dispatch-forkjoin", &opts, || {
        forkjoin_map_chunks(4, &ranges, &mut slots, touch);
    });
    record("dispatch fork-join (4t, empty)", t.seconds() * 1e3);

    table.emit(&report_dir(), "hotpath_microbench");
}
