//! Group structure induced by source-domain class labels.
//!
//! The group-sparse regularizer treats all source samples sharing a
//! class label as one group (Eq. 3 of the paper). For cache-friendly
//! per-group access the source samples are re-ordered so each group is a
//! contiguous index range; [`GroupStructure`] records the partition and
//! the permutation back to the original sample order.

/// Contiguous group partition of `m` source samples into `|L|` groups.
#[derive(Clone, Debug, PartialEq)]
pub struct GroupStructure {
    /// `offsets[l]..offsets[l+1]` are the (sorted-order) indices of group `l`.
    pub offsets: Vec<usize>,
    /// Group sizes `g_l` (`offsets` deltas, cached).
    pub sizes: Vec<usize>,
    /// `sqrt(g_l)` — appears in both screening bounds (Eqs. 6–7).
    pub sqrt_sizes: Vec<f64>,
    /// `perm[k]` = original index of the sample now at sorted position `k`.
    pub perm: Vec<usize>,
    /// Class label of each group (ascending).
    pub labels: Vec<usize>,
}

impl GroupStructure {
    /// Build from per-sample class labels (arbitrary usize labels).
    ///
    /// Samples are stably sorted by label; gaps in label ids are fine
    /// (no empty groups are created).
    pub fn from_labels(labels: &[usize]) -> GroupStructure {
        let m = labels.len();
        assert!(m > 0, "no samples");
        let mut perm: Vec<usize> = (0..m).collect();
        perm.sort_by_key(|&i| (labels[i], i)); // stable by construction
        let mut offsets = vec![0usize];
        let mut group_labels = Vec::new();
        let mut cur = labels[perm[0]];
        group_labels.push(cur);
        for (k, &i) in perm.iter().enumerate() {
            if labels[i] != cur {
                offsets.push(k);
                cur = labels[i];
                group_labels.push(cur);
            }
        }
        offsets.push(m);
        let sizes: Vec<usize> = offsets.windows(2).map(|w| w[1] - w[0]).collect();
        let sqrt_sizes = sizes.iter().map(|&s| (s as f64).sqrt()).collect();
        GroupStructure { offsets, sizes, sqrt_sizes, perm, labels: group_labels }
    }

    /// Build a uniform partition: `l` groups of exactly `g` elements.
    pub fn uniform(l: usize, g: usize) -> GroupStructure {
        assert!(l > 0 && g > 0);
        let offsets: Vec<usize> = (0..=l).map(|k| k * g).collect();
        GroupStructure {
            offsets,
            sizes: vec![g; l],
            sqrt_sizes: vec![(g as f64).sqrt(); l],
            perm: (0..l * g).collect(),
            labels: (0..l).collect(),
        }
    }

    /// Number of groups `|L|`.
    #[inline]
    pub fn num_groups(&self) -> usize {
        self.sizes.len()
    }

    /// Total number of samples `m`.
    #[inline]
    pub fn num_samples(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    /// Index range of group `l` in sorted order.
    #[inline]
    pub fn range(&self, l: usize) -> std::ops::Range<usize> {
        self.offsets[l]..self.offsets[l + 1]
    }

    /// Largest group size.
    pub fn max_size(&self) -> usize {
        self.sizes.iter().copied().max().unwrap()
    }

    /// True when all groups have the same size (the AOT kernel's fast
    /// path requires this).
    pub fn is_uniform(&self) -> bool {
        self.sizes.windows(2).all(|w| w[0] == w[1])
    }

    /// Group id of sorted position `k` (binary search; off the hot path).
    pub fn group_of(&self, k: usize) -> usize {
        debug_assert!(k < self.num_samples());
        match self.offsets.binary_search(&k) {
            Ok(l) if l < self.num_groups() => l,
            Ok(l) => l - 1,
            Err(ins) => ins - 1,
        }
    }

    /// Apply the sorting permutation to a per-sample slice.
    pub fn permute<T: Copy>(&self, xs: &[T]) -> Vec<T> {
        assert_eq!(xs.len(), self.perm.len());
        self.perm.iter().map(|&i| xs[i]).collect()
    }

    /// Invert the sorting permutation on a per-sample slice (sorted →
    /// original order).
    pub fn unpermute<T: Copy + Default>(&self, xs: &[T]) -> Vec<T> {
        assert_eq!(xs.len(), self.perm.len());
        let mut out = vec![T::default(); xs.len()];
        for (k, &i) in self.perm.iter().enumerate() {
            out[i] = xs[k];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_labels_sorts_and_partitions() {
        let labels = vec![2, 0, 1, 0, 2, 2];
        let gs = GroupStructure::from_labels(&labels);
        assert_eq!(gs.num_groups(), 3);
        assert_eq!(gs.num_samples(), 6);
        assert_eq!(gs.sizes, vec![2, 1, 3]);
        assert_eq!(gs.offsets, vec![0, 2, 3, 6]);
        assert_eq!(gs.labels, vec![0, 1, 2]);
        for l in 0..gs.num_groups() {
            for k in gs.range(l) {
                assert_eq!(labels[gs.perm[k]], gs.labels[l]);
            }
        }
    }

    #[test]
    fn from_labels_is_stable() {
        let labels = vec![1, 1, 0, 1];
        let gs = GroupStructure::from_labels(&labels);
        assert_eq!(gs.perm, vec![2, 0, 1, 3]);
    }

    #[test]
    fn uniform_structure() {
        let gs = GroupStructure::uniform(3, 4);
        assert_eq!(gs.num_groups(), 3);
        assert_eq!(gs.num_samples(), 12);
        assert!(gs.is_uniform());
        assert_eq!(gs.range(1), 4..8);
        assert_eq!(gs.max_size(), 4);
        assert!((gs.sqrt_sizes[0] - 2.0).abs() < 1e-15);
    }

    #[test]
    fn group_of_matches_ranges() {
        let gs = GroupStructure::from_labels(&[0, 0, 1, 2, 2, 2]);
        for l in 0..gs.num_groups() {
            for k in gs.range(l) {
                assert_eq!(gs.group_of(k), l);
            }
        }
    }

    #[test]
    fn permute_roundtrip() {
        let labels = vec![3, 1, 2, 1, 3];
        let gs = GroupStructure::from_labels(&labels);
        let xs = vec![10.0, 11.0, 12.0, 13.0, 14.0];
        let p = gs.permute(&xs);
        let back = gs.unpermute(&p);
        assert_eq!(back, xs);
        let pl = gs.permute(&labels);
        let mut sorted = pl.clone();
        sorted.sort_unstable();
        assert_eq!(pl, sorted);
    }

    #[test]
    fn non_uniform_detected() {
        let gs = GroupStructure::from_labels(&[0, 0, 1]);
        assert!(!gs.is_uniform());
    }
}
