//! Declarative command-line parsing substrate (clap is unavailable in
//! this offline image).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean
//! switches, defaults, required flags, typed accessors and an
//! auto-generated `--help`.
//!
//! ```
//! use grpot::cli::{App, ArgSpec};
//! let app = App::new("demo", "demo tool")
//!     .arg(ArgSpec::opt("gamma", "regularization strength").default("1.0"))
//!     .arg(ArgSpec::switch("verbose", "chatty output"));
//! let m = app.parse_from(&["--gamma", "0.5", "--verbose"]).unwrap();
//! assert_eq!(m.get_f64("gamma").unwrap(), 0.5);
//! assert!(m.get_flag("verbose"));
//! ```

use std::collections::BTreeMap;

/// Declaration of one `--name` argument.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub help: String,
    pub takes_value: bool,
    pub required: bool,
    pub default: Option<String>,
}

impl ArgSpec {
    /// Value-taking option (`--name v` or `--name=v`).
    pub fn opt(name: &str, help: &str) -> Self {
        ArgSpec {
            name: name.into(),
            help: help.into(),
            takes_value: true,
            required: false,
            default: None,
        }
    }

    /// Boolean switch (`--name`).
    pub fn switch(name: &str, help: &str) -> Self {
        ArgSpec {
            name: name.into(),
            help: help.into(),
            takes_value: false,
            required: false,
            default: None,
        }
    }

    pub fn required(mut self) -> Self {
        self.required = true;
        self
    }

    pub fn default(mut self, v: &str) -> Self {
        self.default = Some(v.into());
        self
    }
}

/// An application or subcommand definition.
#[derive(Clone, Debug)]
pub struct App {
    pub name: String,
    pub about: String,
    pub args: Vec<ArgSpec>,
    pub subcommands: Vec<App>,
}

/// Parse result.
#[derive(Debug, Default)]
pub struct Matches {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    /// Positional arguments (anything not starting with `--`).
    pub positional: Vec<String>,
    /// `(name, matches)` of the chosen subcommand, if any.
    pub subcommand: Option<(String, Box<Matches>)>,
}

/// Error with a message suitable for printing to stderr.
#[derive(Debug, PartialEq)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}
impl std::error::Error for CliError {}

impl App {
    pub fn new(name: &str, about: &str) -> Self {
        App { name: name.into(), about: about.into(), args: vec![], subcommands: vec![] }
    }

    pub fn arg(mut self, a: ArgSpec) -> Self {
        self.args.push(a);
        self
    }

    pub fn subcommand(mut self, s: App) -> Self {
        self.subcommands.push(s);
        self
    }

    /// Render the help text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        if !self.subcommands.is_empty() {
            s.push_str(" <SUBCOMMAND>");
        }
        if !self.args.is_empty() {
            s.push_str(" [OPTIONS]");
        }
        s.push('\n');
        if !self.args.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for a in &self.args {
                let head = if a.takes_value {
                    format!("--{} <v>", a.name)
                } else {
                    format!("--{}", a.name)
                };
                let extra = match (&a.default, a.required) {
                    (Some(d), _) => format!(" [default: {d}]"),
                    (None, true) => " [required]".to_string(),
                    _ => String::new(),
                };
                s.push_str(&format!("  {head:<24} {}{extra}\n", a.help));
            }
        }
        if !self.subcommands.is_empty() {
            s.push_str("\nSUBCOMMANDS:\n");
            for sc in &self.subcommands {
                s.push_str(&format!("  {:<18} {}\n", sc.name, sc.about));
            }
        }
        s
    }

    /// Parse from explicit tokens (for tests) — no program name expected.
    pub fn parse_from(&self, tokens: &[&str]) -> Result<Matches, CliError> {
        let owned: Vec<String> = tokens.iter().map(|s| s.to_string()).collect();
        self.parse_tokens(&owned)
    }

    /// Parse `std::env::args()` (skipping the program name).
    pub fn parse_env(&self) -> Result<Matches, CliError> {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        self.parse_tokens(&tokens)
    }

    fn parse_tokens(&self, tokens: &[String]) -> Result<Matches, CliError> {
        let mut m = Matches::default();
        let mut i = 0;
        while i < tokens.len() {
            let tok = &tokens[i];
            if tok == "--help" || tok == "-h" {
                return Err(CliError(self.help()));
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self.args.iter().find(|a| a.name == name).ok_or_else(|| {
                    CliError(format!("unknown option --{name}\n\n{}", self.help()))
                })?;
                if spec.takes_value {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            tokens
                                .get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{name} needs a value")))?
                        }
                    };
                    m.values.insert(name, v);
                } else {
                    if inline_val.is_some() {
                        return Err(CliError(format!("--{name} takes no value")));
                    }
                    m.flags.insert(name, true);
                }
            } else if let Some(sub) = self.subcommands.iter().find(|s| &s.name == tok) {
                let rest = &tokens[i + 1..];
                let sub_m = sub.parse_tokens(rest)?;
                m.subcommand = Some((sub.name.clone(), Box::new(sub_m)));
                // Parent-level required flags are not enforced when a
                // subcommand is chosen (the subcommand owns the action).
                return self.finish_with(m, false);
            } else {
                m.positional.push(tok.clone());
            }
            i += 1;
        }
        self.finish(m)
    }

    fn finish(&self, m: Matches) -> Result<Matches, CliError> {
        self.finish_with(m, true)
    }

    fn finish_with(&self, mut m: Matches, enforce_required: bool) -> Result<Matches, CliError> {
        for a in &self.args {
            if a.takes_value && !m.values.contains_key(&a.name) {
                if let Some(d) = &a.default {
                    m.values.insert(a.name.clone(), d.clone());
                } else if a.required && enforce_required {
                    return Err(CliError(format!("missing required option --{}", a.name)));
                }
            }
        }
        Ok(m)
    }
}

impl Matches {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        let raw = self
            .get(name)
            .ok_or_else(|| CliError(format!("--{name} not provided")))?;
        raw.parse()
            .map_err(|_| CliError(format!("--{name}: '{raw}' is not a number")))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        let raw = self
            .get(name)
            .ok_or_else(|| CliError(format!("--{name} not provided")))?;
        raw.parse()
            .map_err(|_| CliError(format!("--{name}: '{raw}' is not an integer")))
    }

    /// Comma-separated list of floats, e.g. `--gammas 0.1,1,10`.
    pub fn get_f64_list(&self, name: &str) -> Result<Vec<f64>, CliError> {
        let raw = self
            .get(name)
            .ok_or_else(|| CliError(format!("--{name} not provided")))?;
        raw.split(',')
            .map(|t| {
                t.trim()
                    .parse()
                    .map_err(|_| CliError(format!("--{name}: '{t}' is not a number")))
            })
            .collect()
    }

    /// Comma-separated list of integers.
    pub fn get_usize_list(&self, name: &str) -> Result<Vec<usize>, CliError> {
        let raw = self
            .get(name)
            .ok_or_else(|| CliError(format!("--{name} not provided")))?;
        raw.split(',')
            .map(|t| {
                t.trim()
                    .parse()
                    .map_err(|_| CliError(format!("--{name}: '{t}' is not an integer")))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests;
