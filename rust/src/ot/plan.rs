//! Transport-plan recovery and plan-quality metrics.
//!
//! From the dual solution `(α*, β*)` the optimal plan of Problem 2 is
//! `t*_j = ∇ψ(α* + β*_j 1 − c_j)` (Eq. 5), recovered column by column.

use super::dual::{DualParams, OtProblem};
use crate::linalg::Mat;

/// A recovered transport plan.
///
/// Rows are source samples in **sorted (grouped)** order; use
/// [`TransportPlan::to_original_order`] for the caller's ordering.
#[derive(Clone, Debug)]
pub struct TransportPlan {
    /// Dense plan, `m × n`.
    pub t: Mat,
}

/// Recover the plan from dual variables `x = [α; β]`.
pub fn recover_plan(prob: &OtProblem, params: &DualParams, x: &[f64]) -> TransportPlan {
    let m = prob.m();
    let n = prob.n();
    let (alpha, beta) = x.split_at(m);
    let tau = params.tau();
    let lq = params.lambda_quad();
    let num_groups = prob.groups.num_groups();
    let mut t = Mat::zeros(m, n);
    let mut colbuf = Vec::new();
    for j in 0..n {
        let c_j = prob.cost_col(j, &mut colbuf);
        let beta_j = beta[j];
        for l in 0..num_groups {
            let range = prob.groups.range(l);
            let mut zsq = 0.0;
            for i in range.clone() {
                let f = alpha[i] + beta_j - c_j[i];
                if f > 0.0 {
                    zsq += f * f;
                }
            }
            let z = zsq.sqrt();
            if z > tau {
                let scale = (z - tau) / (lq * z);
                for i in range {
                    let f = alpha[i] + beta_j - c_j[i];
                    if f > 0.0 {
                        t[(i, j)] = scale * f;
                    }
                }
            }
        }
    }
    TransportPlan { t }
}

impl TransportPlan {
    /// `⟨T, C⟩` — the transport cost part of the primal objective
    /// (the "OT distance" reported by applications).
    pub fn transport_cost(&self, prob: &OtProblem) -> f64 {
        let mut s = 0.0;
        let mut colbuf = Vec::new();
        for j in 0..prob.n() {
            let c_j = prob.cost_col(j, &mut colbuf);
            for i in 0..prob.m() {
                s += self.t[(i, j)] * c_j[i];
            }
        }
        s
    }

    /// Full primal objective `⟨T, C⟩ + Σ_j Ψ(t_j)`.
    pub fn primal_objective(&self, prob: &OtProblem, params: &DualParams) -> f64 {
        let lq = params.lambda_quad();
        let tau = params.tau();
        let num_groups = prob.groups.num_groups();
        let mut reg = 0.0;
        for j in 0..prob.n() {
            let mut sq = 0.0;
            for i in 0..prob.m() {
                let v = self.t[(i, j)];
                sq += v * v;
            }
            reg += 0.5 * lq * sq;
            for l in 0..num_groups {
                let mut gsq = 0.0;
                for i in prob.groups.range(l) {
                    let v = self.t[(i, j)];
                    gsq += v * v;
                }
                reg += tau * gsq.sqrt();
            }
        }
        self.transport_cost(prob) + reg
    }

    /// `(‖T·1 − a‖₁, ‖Tᵀ·1 − b‖₁)` — marginal constraint violations.
    /// The relaxed dual only enforces the marginals asymptotically in
    /// γ → 0; applications report/monitor these.
    pub fn marginal_violation(&self, prob: &OtProblem) -> (f64, f64) {
        let rs = self.t.row_sums();
        let cs = self.t.col_sums();
        let va: f64 = rs.iter().zip(&prob.a).map(|(&r, &a)| (r - a).abs()).sum();
        let vb: f64 = cs.iter().zip(&prob.b).map(|(&c, &b)| (c - b).abs()).sum();
        (va, vb)
    }

    /// Fraction of entries with `|t_ij| > tol`.
    pub fn density(&self, tol: f64) -> f64 {
        self.t.count_nonzero(tol) as f64 / (self.t.rows() * self.t.cols()) as f64
    }

    /// Fraction of (group, column) blocks that are entirely zero — the
    /// group sparsity the regularizer induces (Fig. 1 of the paper).
    pub fn group_sparsity(&self, prob: &OtProblem, tol: f64) -> f64 {
        let num_groups = prob.groups.num_groups();
        let mut zero_blocks = 0usize;
        for j in 0..prob.n() {
            for l in 0..num_groups {
                let any = prob.groups.range(l).any(|i| self.t[(i, j)].abs() > tol);
                if !any {
                    zero_blocks += 1;
                }
            }
        }
        zero_blocks as f64 / (num_groups * prob.n()) as f64
    }

    /// For each target column, is all its incoming mass from a single
    /// class? Returns the fraction of columns with single-class mass —
    /// the qualitative property illustrated by the paper's Figure 1.
    pub fn single_class_columns(&self, prob: &OtProblem, tol: f64) -> f64 {
        let num_groups = prob.groups.num_groups();
        let mut pure = 0usize;
        let mut nonempty = 0usize;
        for j in 0..prob.n() {
            let mut active = 0;
            for l in 0..num_groups {
                if prob.groups.range(l).any(|i| self.t[(i, j)].abs() > tol) {
                    active += 1;
                }
            }
            if active > 0 {
                nonempty += 1;
                if active == 1 {
                    pure += 1;
                }
            }
        }
        if nonempty == 0 {
            0.0
        } else {
            pure as f64 / nonempty as f64
        }
    }

    /// Barycentric mapping of source points into the target domain:
    /// `x̂_i = (Σ_j T_ij x_T_j) / (Σ_j T_ij)` (rows with no mass map to 0).
    pub fn barycentric_map(&self, xt: &Mat) -> Mat {
        assert_eq!(xt.rows(), self.t.cols());
        let mut out = self.t.matmul(xt);
        let row_mass = self.t.row_sums();
        for i in 0..out.rows() {
            let w = row_mass[i];
            if w > 1e-300 {
                for v in out.row_mut(i) {
                    *v /= w;
                }
            }
        }
        out
    }

    /// Re-order rows back to the caller's original source order.
    pub fn to_original_order(&self, prob: &OtProblem) -> Mat {
        let m = self.t.rows();
        let n = self.t.cols();
        let mut out = Mat::zeros(m, n);
        for (k, &orig) in prob.groups.perm.iter().enumerate() {
            out.row_mut(orig).copy_from_slice(self.t.row(k));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::fastot::{solve_fast_ot, FastOtConfig};
    use crate::rng::Pcg64;

    fn problem(seed: u64) -> OtProblem {
        let mut rng = Pcg64::new(seed);
        let (l, g, n) = (3, 4, 10);
        let m = l * g;
        let cost = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
        let labels: Vec<usize> = (0..m).map(|i| i / g).collect();
        OtProblem::from_parts(vec![1.0 / m as f64; m], vec![1.0 / n as f64; n], &cost, &labels)
    }

    #[test]
    fn plan_is_nonnegative_and_bounded() {
        let prob = problem(42);
        let cfg = FastOtConfig { gamma: 0.1, rho: 0.5, ..Default::default() };
        let res = solve_fast_ot(&prob, &cfg);
        let plan = recover_plan(&prob, &cfg.params(), &res.x);
        for v in plan.t.as_slice() {
            assert!(*v >= 0.0);
            assert!(*v <= 1.0 + 1e-9);
        }
    }

    #[test]
    fn marginals_near_feasible_at_convergence() {
        // The relaxed dual has no hard constraints, but at a converged
        // dual optimum the plan's marginals track (a, b) closely (the
        // dual gradient IS the marginal residual). Note the violation is
        // NOT monotone in γ: the dual variables rescale with γ.
        let prob = problem(7);
        for gamma in [10.0, 1.0, 0.1] {
            let cfg = FastOtConfig { gamma, rho: 0.5, ..Default::default() };
            let res = solve_fast_ot(&prob, &cfg);
            let (va, vb) =
                recover_plan(&prob, &cfg.params(), &res.x).marginal_violation(&prob);
            assert!(va < 0.01, "gamma={gamma}: row-marginal violation {va}");
            assert!(vb < 0.01, "gamma={gamma}: col-marginal violation {vb}");
        }
    }

    #[test]
    fn stronger_group_term_gives_more_group_sparsity() {
        let prob = problem(13);
        let sparsity = |rho: f64| {
            let cfg = FastOtConfig { gamma: 1.0, rho, ..Default::default() };
            let res = solve_fast_ot(&prob, &cfg);
            recover_plan(&prob, &cfg.params(), &res.x).group_sparsity(&prob, 1e-12)
        };
        let low = sparsity(0.1);
        let high = sparsity(0.9);
        assert!(high >= low, "group sparsity should grow with rho: {low} vs {high}");
        assert!(high > 0.0);
    }

    #[test]
    fn duality_gap_vanishes() {
        // Primal(T*) − Dual(α*, β*) → 0 at the optimum (strong duality
        // of the smoothed problem).
        let prob = problem(99);
        let cfg = FastOtConfig {
            gamma: 0.5,
            rho: 0.4,
            lbfgs: crate::solvers::lbfgs::LbfgsOptions {
                max_iters: 2000,
                gtol: 1e-9,
                ftol: 1e-15,
                ..Default::default()
            },
            ..Default::default()
        };
        let res = solve_fast_ot(&prob, &cfg);
        let plan = recover_plan(&prob, &cfg.params(), &res.x);
        // The smooth-relaxed dual drops the marginal constraints, so the
        // "gap" here is primal-with-penalty vs dual: at optimum,
        // primal(T*) + penalty-terms == dual via Fenchel. We verify the
        // Fenchel identity: dual = αᵀa + βᵀb − Σ ψ and
        // primal = ⟨T,C⟩ + Ψ(T); equality holds at optimum with
        // ⟨T, α⊕β − C⟩ = Ψ(T) + Σψ.
        let (alpha, beta) = res.alpha_beta(&prob);
        let mut lhs = 0.0; // ⟨T, α⊕β − C⟩
        for j in 0..prob.n() {
            let c_j = prob.cost_t().row(j);
            for i in 0..prob.m() {
                lhs += plan.t[(i, j)] * (alpha[i] + beta[j] - c_j[i]);
            }
        }
        let psi_sum = crate::linalg::dot(alpha, &prob.a) + crate::linalg::dot(beta, &prob.b)
            - res.dual_objective;
        let reg = plan.primal_objective(&prob, &cfg.params()) - plan.transport_cost(&prob);
        assert!(
            (lhs - (psi_sum + reg)).abs() < 1e-6,
            "Fenchel identity violated: {lhs} vs {}",
            psi_sum + reg
        );
    }

    #[test]
    fn original_order_roundtrip() {
        let cost = Mat::from_vec(3, 2, vec![0.0, 1.0, 1.0, 0.0, 0.5, 0.5]);
        // Labels force permutation: [1, 0, 1] → order [1, 0, 2].
        let prob = OtProblem::from_parts(
            vec![1.0 / 3.0; 3],
            vec![0.5, 0.5],
            &cost,
            &[1, 0, 1],
        );
        let cfg = FastOtConfig { gamma: 0.1, rho: 0.3, ..Default::default() };
        let res = solve_fast_ot(&prob, &cfg);
        let plan = recover_plan(&prob, &cfg.params(), &res.x);
        let orig = plan.to_original_order(&prob);
        // Row 1 (label 0) in original order == row 0 in sorted order.
        assert_eq!(orig.row(1), plan.t.row(0));
        assert_eq!(orig.row(0), plan.t.row(1));
        assert_eq!(orig.row(2), plan.t.row(2));
    }

    #[test]
    fn barycentric_map_shapes_and_weights() {
        let t = Mat::from_vec(2, 2, vec![0.5, 0.0, 0.25, 0.25]);
        let plan = TransportPlan { t };
        let xt = Mat::from_vec(2, 3, vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0]);
        let mapped = plan.barycentric_map(&xt);
        assert_eq!(mapped.shape(), (2, 3));
        // Row 0: all mass on target 0 → maps exactly to x_T0.
        assert_eq!(mapped.row(0), &[1.0, 0.0, 0.0]);
        // Row 1: equal mass → midpoint.
        assert_eq!(mapped.row(1), &[0.5, 0.5, 0.0]);
    }
}
