//! End-to-end driver: exercises the full three-layer system on a real
//! small workload and reports the paper's headline metric (processing-
//! time gain without accuracy loss). Results are recorded in
//! EXPERIMENTS.md.
//!
//! Pipeline covered:
//!   1. AOT artifacts (JAX/Pallas → HLO text) loaded via PJRT and
//!      cross-validated against the native oracle (L1/L2 ⇄ L3 seam);
//!   2. the paper's sweep protocol (γ × ρ grid, fast vs origin) on the
//!      synthetic workload — gains + Theorem-2 objective equality;
//!   3. a real downstream task: digits domain adaptation, accuracy vs
//!      the label-blind entropic baseline;
//!   4. the TCP service handling batched requests.
//!
//! Run: `cargo run --release --example end_to_end`

use grpot::benchlib::Table;
use grpot::coordinator::config::{DatasetSpec, Method, SweepConfig};
use grpot::coordinator::metrics::Metrics;
use grpot::coordinator::{service, sweep};
use grpot::error::Result;
use grpot::eval;
use grpot::jsonlite::Value;
use grpot::ot::plan::recover_plan;
use grpot::prelude::*;

/// AOT seam: artifacts → PJRT → numerics check vs native oracle.
#[cfg(feature = "xla")]
fn aot_seam_check() -> Result<()> {
    match grpot::runtime::Manifest::load(&grpot::runtime::artifact_dir()) {
        Ok(manifest) => {
            let runtime = grpot::runtime::PjrtRuntime::cpu()?;
            let entry = manifest.entries.iter().min_by_key(|e| e.m * e.n).unwrap();
            let (l, g, n) = (entry.num_groups, entry.group_size, entry.n);
            let mut rng = Pcg64::new(1);
            let m = l * g;
            let cost = grpot::linalg::Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
            let labels: Vec<usize> = (0..m).map(|i| i / g).collect();
            let prob = OtProblem::from_parts(
                vec![1.0 / m as f64; m],
                vec![1.0 / n as f64; n],
                &cost,
                &labels,
            );
            let params = DualParams::new(0.5, 0.5);
            let mut oracle = grpot::runtime::XlaDualOracle::from_problem(
                &runtime,
                &prob,
                &params,
                &grpot::runtime::artifact_dir(),
            )?;
            let x: Vec<f64> = (0..prob.dim()).map(|_| rng.uniform(-0.3, 0.5)).collect();
            let mut gx = vec![0.0; prob.dim()];
            let fx = oracle.eval(&x, &mut gx);
            let mut gr = vec![0.0; prob.dim()];
            let (fr, _) = grpot::ot::dual::eval_dense(&prob, &params, &x, &mut gr);
            println!(
                "  artifact {} vs native: obj err {:.2e} (platform {})",
                entry.name,
                (fx - fr).abs(),
                runtime.platform()
            );
            assert!((fx - fr).abs() < 1e-9, "AOT numerics mismatch");
        }
        Err(_) => println!("  (artifacts not built — run `make artifacts`; skipping seam check)"),
    }
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn aot_seam_check() -> Result<()> {
    println!("  (built without the `xla` feature; skipping seam check)");
    Ok(())
}

fn main() -> Result<()> {
    println!("=== grpot end-to-end driver ===\n");

    println!("[1/4] AOT artifact validation");
    aot_seam_check()?;

    // ---------------------------------------------------------------
    // 2. Paper sweep: gains on the synthetic workload.
    // ---------------------------------------------------------------
    println!("\n[2/4] paper sweep (synthetic |L|=40, g=10 → m=n=400)");
    let cfg = SweepConfig {
        dataset: DatasetSpec {
            family: "synthetic".into(),
            param1: 40,
            param2: 10,
            ..Default::default()
        },
        gammas: vec![0.01, 0.1, 1.0, 10.0],
        rhos: vec![0.2, 0.4, 0.6, 0.8],
        methods: vec![Method::Fast, Method::Origin],
        threads: 1,
        solve: SolveOptions::new().max_iters(400),
    };
    let metrics = Metrics::new();
    let report = sweep::run_sweep(&cfg, &metrics)?;
    let mut table = Table::new(
        "end-to-end sweep: per-γ totals over ρ ∈ {0.2,0.4,0.6,0.8}",
        &["gamma", "t_origin[s]", "t_fast[s]", "gain"],
    );
    for a in &report.aggregates {
        let t = |m: Method| {
            a.totals.iter().find(|(x, _)| *x == m).map(|&(_, t)| t).unwrap_or(f64::NAN)
        };
        table.row(vec![
            format!("{}", a.gamma),
            format!("{:.3}", t(Method::Origin)),
            format!("{:.3}", t(Method::Fast)),
            a.gain.map_or("-".into(), |g| format!("{g:.2}x")),
        ]);
    }
    table.emit(&grpot::benchlib::report_dir(), "end_to_end_sweep");
    // Theorem 2 on the whole grid.
    for gamma in &cfg.gammas {
        for rho in &cfg.rhos {
            let get = |m: Method| {
                report
                    .records
                    .iter()
                    .find(|r| r.method == m && r.gamma == *gamma && r.rho == *rho)
                    .unwrap()
                    .dual_objective
            };
            assert!(
                get(Method::Fast) == get(Method::Origin),
                "objective mismatch at gamma={gamma} rho={rho}"
            );
        }
    }
    println!("  Theorem 2 verified on all {} grid points", cfg.gammas.len() * cfg.rhos.len());

    // ---------------------------------------------------------------
    // 3. Downstream accuracy: digits adaptation.
    // ---------------------------------------------------------------
    println!("\n[3/4] digits adaptation (U→M, 300 samples/domain)");
    let pair = grpot::data::digits::usps_to_mnist(300, 0xE2E);
    let prob = OtProblem::from_dataset(&pair);
    let base = eval::no_adaptation_accuracy(&pair);
    let sol_cfg = FastOtConfig { gamma: 0.01, rho: 0.6, ..Default::default() };
    let res = solve_fast_ot(&prob, &sol_cfg);
    let plan = recover_plan(&prob, &sol_cfg.params(), &res.x);
    let acc = eval::otda_accuracy(&pair, &prob, &plan);
    println!("  no adaptation  : {base:.3}");
    println!("  group-sparse OT: {acc:.3} (solve {:.2}s, {:.1}% grads skipped)",
        res.wall_time_s,
        100.0 * res.stats.grads_skipped as f64
            / (res.stats.grads_computed + res.stats.grads_skipped).max(1) as f64);

    // ---------------------------------------------------------------
    // 4. Service: batched requests.
    // ---------------------------------------------------------------
    println!("\n[4/4] TCP service smoke");
    let handle = service::serve("127.0.0.1:0", 2)?;
    let mut client = service::Client::connect(&handle.addr)?;
    let resp = client.call(
        &Value::obj()
            .set("op", "solve")
            .set(
                "dataset",
                Value::obj()
                    .set("family", "synthetic")
                    .set("param1", 10usize)
                    .set("param2", 10usize)
                    .set("seed", 3usize),
            )
            .set("gamma", 0.1)
            .set("rho", 0.6)
            .set("method", "fast"),
    )?;
    assert!(resp.get("ok").and_then(Value::as_bool) == Some(true), "{resp}");
    println!(
        "  service solve: dual={:.6} wall={:.3}s",
        resp.get("dual_objective").and_then(Value::as_f64).unwrap(),
        resp.get("wall_time_s").and_then(Value::as_f64).unwrap()
    );
    handle.shutdown();

    println!("\nend_to_end OK — see reports/end_to_end_sweep.md");
    Ok(())
}
