//! Fault-tolerance substrate: cooperative cancellation and deterministic
//! fault injection.
//!
//! Two pieces, both zero-dependency and compile-out-cheap:
//!
//! * **[`CancelToken`]** — a shared `AtomicU64` carrying an absolute
//!   deadline plus a manual-cancel bit. Solvers poll it between L-BFGS
//!   iterations / outer rounds (one relaxed load per check when armed,
//!   a plain `Option` test when not), so an expired deadline terminates
//!   a solve at the next checkpoint with a structured error instead of
//!   burning a worker to completion. Cancellation never changes the
//!   math: an uncancelled solve is byte-identical to one run without a
//!   token (Theorem 2 guarantees correctness from any iterate, so
//!   stopping early is always *safe*, merely unconverged).
//! * **The failpoint registry** — named injection sites
//!   ([`sites`]) armed via `GRPOT_FAULTS="site:action:every-N"` with
//!   actions `panic` | `delay(ms)` | `err`. When no faults are
//!   installed, [`check`] is a single relaxed load ([`obs::trace_mode`]
//!   discipline — the registry cannot perturb bit-exactness or
//!   wall-time within noise). Deterministic by construction: the N-th
//!   hit of a site fires, independent of timing.
//!
//! The knob mirrors `GRPOT_TRACE`: the CLI validates `GRPOT_FAULTS` at
//! launch and exits 2 on a malformed value ([`init_from_env`]); test
//! binaries and benches latch the env once, best-effort
//! ([`latch_env_once`]); tests install programmatically
//! ([`set_faults`] / [`clear`]).

use crate::err;
use crate::error::GrpotError;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Cancellation tokens
// ---------------------------------------------------------------------------

/// Process-wide epoch for deadline encoding. `Instant` has no absolute
/// representation, so deadlines are stored as nanoseconds since the
/// first token ever created — monotone, cheap to compare, and immune to
/// wall-clock adjustments.
static EPOCH: OnceLock<Instant> = OnceLock::new();

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds from the epoch to `t`, saturating at zero for instants
/// before the epoch (an already-past deadline must read as expired, not
/// unarmed).
fn nanos_since_epoch(t: Instant) -> u64 {
    t.checked_duration_since(epoch())
        .map(|d| d.as_nanos().min((u64::MAX >> 1) as u128) as u64)
        .unwrap_or(0)
}

/// Shared token state. Bit layout of `bits`:
/// * bit 0 — manual-cancel flag (set by [`CancelToken::cancel`]);
/// * bits 1..=63 — absolute deadline in nanoseconds since [`EPOCH`],
///   clamped to ≥ 1 so a pre-epoch deadline still arms; 0 = no deadline.
struct TokenState {
    bits: AtomicU64,
}

impl TokenState {
    fn new(deadline: Option<Instant>) -> TokenState {
        let bits = match deadline {
            Some(t) => nanos_since_epoch(t).max(1) << 1,
            None => 0,
        };
        TokenState { bits: AtomicU64::new(bits) }
    }
}

/// Cooperative cancellation handle: an absolute deadline plus a
/// manual-cancel bit behind one shared `AtomicU64`.
///
/// Clones share state — cancelling any clone cancels them all. A
/// [`child`](CancelToken::child) token additionally observes its
/// parent, so the serve engine can cancel every in-flight solve at
/// shutdown through one parent token while each job keeps its own
/// deadline.
///
/// The uncancelled fast path is one relaxed load per clause (own bits,
/// then parent bits); `Instant::now()` is only consulted when a
/// deadline is actually armed.
#[derive(Clone)]
pub struct CancelToken {
    inner: Arc<TokenState>,
    parent: Option<Arc<TokenState>>,
}

impl CancelToken {
    /// A token with no deadline; fires only on explicit [`cancel`](Self::cancel).
    pub fn new() -> CancelToken {
        CancelToken { inner: Arc::new(TokenState::new(None)), parent: None }
    }

    /// A token that reads cancelled once `deadline` passes.
    pub fn with_deadline(deadline: Instant) -> CancelToken {
        CancelToken { inner: Arc::new(TokenState::new(Some(deadline))), parent: None }
    }

    /// A child token: cancelled when *either* its own deadline passes /
    /// [`cancel`](Self::cancel) is called on it, or `self` (the parent)
    /// is cancelled. The child does not propagate back to the parent.
    pub fn child(&self, deadline: Option<Instant>) -> CancelToken {
        CancelToken {
            inner: Arc::new(TokenState::new(deadline)),
            parent: Some(Arc::clone(&self.inner)),
        }
    }

    /// Flip the manual-cancel bit; every clone and child observes it.
    pub fn cancel(&self) {
        self.inner.bits.fetch_or(1, Ordering::Relaxed);
    }

    /// Whether the token reads cancelled: manual bit set (own or
    /// parent), or an armed deadline has passed.
    #[inline]
    pub fn is_cancelled(&self) -> bool {
        let own = self.inner.bits.load(Ordering::Relaxed);
        let par = match &self.parent {
            Some(p) => p.bits.load(Ordering::Relaxed),
            None => 0,
        };
        if (own | par) & 1 != 0 {
            return true;
        }
        let own_dl = own >> 1;
        let par_dl = par >> 1;
        if own_dl == 0 && par_dl == 0 {
            return false;
        }
        let now = nanos_since_epoch(Instant::now());
        (own_dl != 0 && now >= own_dl) || (par_dl != 0 && now >= par_dl)
    }
}

impl Default for CancelToken {
    fn default() -> Self {
        CancelToken::new()
    }
}

impl std::fmt::Debug for CancelToken {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let bits = self.inner.bits.load(Ordering::Relaxed);
        f.debug_struct("CancelToken")
            .field("cancelled", &self.is_cancelled())
            .field("deadline_armed", &(bits >> 1 != 0))
            .field("has_parent", &self.parent.is_some())
            .finish()
    }
}

// ---------------------------------------------------------------------------
// Failpoint registry
// ---------------------------------------------------------------------------

/// The registered injection sites. `check` at an unknown site is legal
/// (it simply never fires), but specs referencing a site outside this
/// list are rejected at parse time — a typo'd `GRPOT_FAULTS` must fail
/// loudly, not silently never fire.
pub mod sites {
    /// `Engine::submit`, before admission control.
    pub const QUEUE_ADMIT: &str = "queue.admit";
    /// `batcher::next_batch`, after a batch is formed.
    pub const BATCHER_FLUSH: &str = "batcher.flush";
    /// Engine dataset build, inside the per-batch unwind guard.
    pub const ENGINE_DATASET_BUILD: &str = "engine.dataset_build";
    /// Engine solve, inside the per-job unwind guard.
    pub const ENGINE_SOLVE: &str = "engine.solve";
    /// Warm-start dual-cache insert (faults skip the insert, never the
    /// request).
    pub const CACHE_INSERT: &str = "cache.insert";
    /// Per-iteration oracle evaluation in the solver drivers.
    pub const ORACLE_EVAL: &str = "oracle.eval";
    /// Sweep-coordinator per-job execution (`sweep::run_job_opts`).
    pub const SWEEP_JOB: &str = "sweep.job";

    /// Every registered site (docs, CLI `info`, chaos sweeps).
    pub const ALL: [&str; 7] = [
        QUEUE_ADMIT,
        BATCHER_FLUSH,
        ENGINE_DATASET_BUILD,
        ENGINE_SOLVE,
        CACHE_INSERT,
        ORACLE_EVAL,
        SWEEP_JOB,
    ];
}

/// What an armed failpoint does when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// `panic!` at the site (exercises unwind guards).
    Panic,
    /// Sleep for the given milliseconds, then continue normally
    /// (exercises deadline/cancellation paths).
    Delay(u64),
    /// Return a structured `GrpotError` from the site (exercises error
    /// plumbing; sites without an error channel escalate to a panic and
    /// document it).
    Err,
}

/// One armed failpoint: fire `action` on every `every`-th hit of `site`.
struct FaultSpec {
    site: String,
    action: Action,
    every: u64,
    hits: AtomicU64,
}

/// Fast-path gate: true iff at least one spec is installed. [`check`]
/// reads only this when the registry is empty.
static ARMED: AtomicBool = AtomicBool::new(false);

/// Set once faults were chosen explicitly (CLI launch or a test's
/// [`set_faults`]/[`clear`]); [`latch_env_once`] then leaves them alone.
static EXPLICIT: AtomicBool = AtomicBool::new(false);

/// Total faults fired since process start (all sites, all actions).
static INJECTED: AtomicU64 = AtomicU64::new(0);

static REGISTRY: Mutex<Vec<FaultSpec>> = Mutex::new(Vec::new());

fn registry() -> std::sync::MutexGuard<'static, Vec<FaultSpec>> {
    // A panic *at a failpoint* happens while the lock is not held (the
    // guard drops before the action runs), but stay poison-tolerant
    // anyway: the registry is plain data.
    REGISTRY.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Parse a `GRPOT_FAULTS` value: comma-separated `site:action:every-N`
/// entries, e.g. `engine.solve:panic:every-3,oracle.eval:delay(5):every-1`.
/// `off`, `0` and the empty string mean no faults. Unknown sites,
/// actions, or a malformed cadence are errors.
pub fn parse(s: &str) -> Result<Vec<(String, Action, u64)>, GrpotError> {
    let s = s.trim();
    if s.is_empty() || s.eq_ignore_ascii_case("off") || s == "0" {
        return Ok(Vec::new());
    }
    let mut specs = Vec::new();
    for entry in s.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let parts: Vec<&str> = entry.split(':').collect();
        if parts.len() != 3 {
            return Err(err!(
                "malformed fault spec '{entry}' (expected site:action:every-N)"
            ));
        }
        let site = parts[0].trim();
        if !sites::ALL.contains(&site) {
            return Err(err!(
                "unknown fault site '{site}' (expected one of {})",
                sites::ALL.join("|")
            ));
        }
        let action = parse_action(parts[1].trim())
            .ok_or_else(|| err!("unknown fault action '{}' (expected panic|delay(ms)|err)", parts[1].trim()))?;
        let every = parts[2]
            .trim()
            .strip_prefix("every-")
            .and_then(|n| n.parse::<u64>().ok())
            .filter(|&n| n >= 1)
            .ok_or_else(|| err!("malformed fault cadence '{}' (expected every-N, N ≥ 1)", parts[2].trim()))?;
        specs.push((site.to_string(), action, every));
    }
    Ok(specs)
}

fn parse_action(s: &str) -> Option<Action> {
    match s.to_ascii_lowercase().as_str() {
        "panic" => Some(Action::Panic),
        "err" => Some(Action::Err),
        other => other
            .strip_prefix("delay(")
            .and_then(|rest| rest.strip_suffix(')'))
            .and_then(|ms| ms.trim().parse::<u64>().ok())
            .map(Action::Delay),
    }
}

/// Install a fault set programmatically (tests, the CLI launcher). An
/// explicit install always wins over the [`latch_env_once`] fallback.
/// Hit counters start at zero.
pub fn set_faults(specs: &[(String, Action, u64)]) {
    EXPLICIT.store(true, Ordering::Relaxed);
    let mut reg = registry();
    reg.clear();
    for (site, action, every) in specs {
        reg.push(FaultSpec {
            site: site.clone(),
            action: *action,
            every: *every,
            hits: AtomicU64::new(0),
        });
    }
    ARMED.store(!reg.is_empty(), Ordering::Relaxed);
}

/// Remove every installed fault; [`check`] returns to the single-load
/// fast path.
pub fn clear() {
    set_faults(&[]);
}

/// Total faults fired since process start.
pub fn injected() -> u64 {
    INJECTED.load(Ordering::Relaxed)
}

/// Human-readable summary of the installed fault set (CLI `info`).
pub fn describe() -> String {
    let reg = registry();
    if reg.is_empty() {
        return "off".to_string();
    }
    reg.iter()
        .map(|f| {
            let action = match f.action {
                Action::Panic => "panic".to_string(),
                Action::Delay(ms) => format!("delay({ms})"),
                Action::Err => "err".to_string(),
            };
            format!("{}:{}:every-{}", f.site, action, f.every)
        })
        .collect::<Vec<_>>()
        .join(",")
}

/// Read `GRPOT_FAULTS`, validate it, and install the fault set. A
/// malformed value is an error the caller turns into a launch failure
/// (never a late per-request surprise) — mirrors `GRPOT_TRACE`.
pub fn init_from_env() -> Result<usize, GrpotError> {
    let specs = match std::env::var("GRPOT_FAULTS") {
        Ok(v) => parse(&v).map_err(|e| err!("GRPOT_FAULTS: {e}"))?,
        Err(_) => Vec::new(),
    };
    set_faults(&specs);
    Ok(specs.len())
}

/// Once-only best-effort env latch for processes without a launch hook
/// (test binaries, benches, embedders): the *first* call installs a
/// valid `GRPOT_FAULTS` value; later calls — and any explicit
/// [`set_faults`] before or after — win over the env. A malformed value
/// is silently ignored here (the CLI's [`init_from_env`] is the strict
/// validator). Called from `Engine::start`, so
/// `GRPOT_FAULTS=… cargo test` actually injects.
pub fn latch_env_once() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        if EXPLICIT.load(Ordering::Relaxed) {
            return; // an explicit set_faults already happened
        }
        if let Ok(v) = std::env::var("GRPOT_FAULTS") {
            if let Ok(specs) = parse(&v) {
                let mut reg = registry();
                reg.clear();
                for (site, action, every) in specs {
                    reg.push(FaultSpec { site, action, every, hits: AtomicU64::new(0) });
                }
                ARMED.store(!reg.is_empty(), Ordering::Relaxed);
            }
        }
    });
}

/// THE injection point. With no faults installed this is a single
/// relaxed load; with faults installed, the `every`-th hit of `site`
/// fires its action: `panic` unwinds, `delay` sleeps then returns
/// `Ok`, `err` returns a structured error. Call sites without an error
/// channel escalate `Err` to a panic (their unwind guards keep the
/// never-hang guarantee).
#[inline]
pub fn check(site: &str) -> crate::error::Result<()> {
    if !ARMED.load(Ordering::Relaxed) {
        return Ok(());
    }
    check_slow(site)
}

#[cold]
fn check_slow(site: &str) -> crate::error::Result<()> {
    // Decide under the lock, act outside it: a panic action must not
    // poison the registry, and a delay must not block other sites.
    let fire = {
        let reg = registry();
        reg.iter().find(|f| f.site == site).and_then(|f| {
            let n = f.hits.fetch_add(1, Ordering::Relaxed) + 1;
            if n % f.every == 0 { Some((f.action, n)) } else { None }
        })
    };
    let Some((action, n)) = fire else {
        return Ok(());
    };
    INJECTED.fetch_add(1, Ordering::Relaxed);
    match action {
        Action::Panic => panic!("failpoint {site}: injected panic (hit {n})"),
        Action::Delay(ms) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            Ok(())
        }
        Action::Err => Err(err!("failpoint {site}: injected error (hit {n})")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    /// Fault-installing tests share the process-global registry, so
    /// they serialize on this lock (same pattern as the trace-mode
    /// tests in `tests/observability.rs`).
    static FAULT_LOCK: Mutex<()> = Mutex::new(());

    fn guard() -> std::sync::MutexGuard<'static, ()> {
        FAULT_LOCK.lock().unwrap_or_else(PoisonError::into_inner)
    }

    #[test]
    fn fresh_token_is_not_cancelled() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        assert!(format!("{t:?}").contains("cancelled: false"));
    }

    #[test]
    fn manual_cancel_is_shared_across_clones() {
        let t = CancelToken::new();
        let c = t.clone();
        t.cancel();
        assert!(t.is_cancelled());
        assert!(c.is_cancelled());
    }

    #[test]
    fn past_deadline_reads_cancelled_future_does_not() {
        let past = CancelToken::with_deadline(Instant::now() - Duration::from_secs(1));
        assert!(past.is_cancelled());
        let future = CancelToken::with_deadline(Instant::now() + Duration::from_secs(3600));
        assert!(!future.is_cancelled());
        // The deadline arms even when it predates the process epoch
        // (encoding clamps to ≥ 1 instead of collapsing to "no deadline").
        assert!(format!("{past:?}").contains("deadline_armed: true"));
    }

    #[test]
    fn deadline_expiry_flips_the_token() {
        let t = CancelToken::with_deadline(Instant::now() + Duration::from_millis(20));
        assert!(!t.is_cancelled());
        std::thread::sleep(Duration::from_millis(40));
        assert!(t.is_cancelled());
    }

    #[test]
    fn child_observes_parent_cancel_but_not_vice_versa() {
        let parent = CancelToken::new();
        let child = parent.child(None);
        assert!(!child.is_cancelled());
        parent.cancel();
        assert!(child.is_cancelled());

        let parent2 = CancelToken::new();
        let child2 = parent2.child(None);
        child2.cancel();
        assert!(child2.is_cancelled());
        assert!(!parent2.is_cancelled());
    }

    #[test]
    fn child_keeps_its_own_deadline() {
        let parent = CancelToken::new();
        let child = parent.child(Some(Instant::now() - Duration::from_secs(1)));
        assert!(child.is_cancelled());
        assert!(!parent.is_cancelled());
    }

    #[test]
    fn parse_accepts_the_grammar() {
        assert!(parse("").unwrap().is_empty());
        assert!(parse("off").unwrap().is_empty());
        assert!(parse("0").unwrap().is_empty());
        let specs = parse("engine.solve:panic:every-3, oracle.eval:delay(5):every-1").unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0], ("engine.solve".to_string(), Action::Panic, 3));
        assert_eq!(specs[1], ("oracle.eval".to_string(), Action::Delay(5), 1));
        assert_eq!(parse("cache.insert:err:every-2").unwrap()[0].1, Action::Err);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(parse("bogus.site:panic:every-1").is_err());
        assert!(parse("engine.solve:explode:every-1").is_err());
        assert!(parse("engine.solve:panic:every-0").is_err());
        assert!(parse("engine.solve:panic:always").is_err());
        assert!(parse("engine.solve:panic").is_err());
        assert!(parse("engine.solve:delay(ms):every-1").is_err());
    }

    #[test]
    fn empty_registry_is_inert_and_cheap() {
        let _g = guard();
        clear();
        for site in sites::ALL {
            assert!(check(site).is_ok());
        }
    }

    // Firing tests install *test-only* site names via `set_faults`
    // ([`check`] matches any string; only `parse` restricts names):
    // these unit tests share a process with every other lib test, and
    // arming a production site — even briefly — could fire into a
    // concurrently running engine/solver test.

    #[test]
    fn err_fires_on_cadence() {
        let _g = guard();
        set_faults(&[("test.cadence".to_string(), Action::Err, 3)]);
        assert!(check("test.cadence").is_ok()); // hit 1
        assert!(check("test.cadence").is_ok()); // hit 2
        let e = check("test.cadence").unwrap_err(); // hit 3 fires
        assert!(e.to_string().contains("failpoint test.cadence"));
        assert!(check("test.cadence").is_ok()); // hit 4
        // Other sites are untouched.
        assert!(check(sites::ENGINE_SOLVE).is_ok());
        clear();
    }

    #[test]
    fn panic_action_panics_with_site_name() {
        let _g = guard();
        set_faults(&[("test.panic".to_string(), Action::Panic, 1)]);
        let res = std::panic::catch_unwind(|| check("test.panic"));
        clear();
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("failpoint test.panic"), "{msg}");
    }

    #[test]
    fn delay_action_sleeps_then_continues() {
        let _g = guard();
        set_faults(&[("test.delay".to_string(), Action::Delay(10), 1)]);
        let before = injected();
        let start = Instant::now();
        assert!(check("test.delay").is_ok());
        assert!(start.elapsed() >= Duration::from_millis(10));
        assert!(injected() > before);
        clear();
    }

    #[test]
    fn describe_round_trips_the_grammar() {
        let _g = guard();
        // Real site names (parse insists), but cadences far beyond what
        // any concurrent test could hit during the install window.
        let specs =
            parse("engine.solve:panic:every-999983,oracle.eval:delay(5):every-999979").unwrap();
        set_faults(&specs);
        let shown = describe();
        clear();
        assert_eq!(parse(&shown).unwrap(), specs);
        assert_eq!(describe(), "off");
    }
}
