//! Strong-Wolfe line search (Nocedal & Wright, Algorithms 3.5/3.6).

use crate::ot::dual::DualOracle;

/// Line-search parameters.
#[derive(Clone, Copy, Debug)]
pub struct WolfeOptions {
    /// Sufficient-decrease constant (Armijo), typically 1e-4.
    pub c1: f64,
    /// Curvature constant, 0.9 for quasi-Newton directions.
    pub c2: f64,
    /// Maximum bracketing + zoom evaluations.
    pub max_evals: usize,
    /// Upper bound on the step length.
    pub step_max: f64,
}

impl Default for WolfeOptions {
    fn default() -> Self {
        WolfeOptions { c1: 1e-4, c2: 0.9, max_evals: 30, step_max: 1e6 }
    }
}

/// Result of a successful search.
pub struct LineSearchResult {
    pub step: f64,
    pub f: f64,
    /// Gradient at the accepted point (full-dimension).
    pub grad: Vec<f64>,
    pub evals: usize,
}

struct Phi<'a, 'b> {
    oracle: &'a mut dyn DualOracle,
    x0: &'b [f64],
    dir: &'b [f64],
    xt: Vec<f64>,
    gt: Vec<f64>,
    evals: usize,
}

impl Phi<'_, '_> {
    /// Evaluate φ(t) = f(x0 + t·d) and φ'(t) = ∇f(x0+t·d)ᵀd.
    fn eval(&mut self, t: f64) -> (f64, f64) {
        for ((xi, &x0i), &di) in self.xt.iter_mut().zip(self.x0).zip(self.dir) {
            *xi = x0i + t * di;
        }
        let f = self.oracle.eval(&self.xt, &mut self.gt);
        self.evals += 1;
        let dphi = crate::linalg::dot(&self.gt, self.dir);
        (f, dphi)
    }
}

/// What the caller must do next while driving a [`WolfeMachine`].
#[derive(Clone, Copy, Debug)]
pub enum WolfePoll {
    /// Evaluate `φ(t)`/`φ'(t)` at this trial step and feed the pair back
    /// through [`WolfeMachine::advance`].
    Eval(f64),
    /// The point just evaluated satisfies the strong Wolfe conditions;
    /// the caller's last gradient buffer holds `∇f` at the accepted
    /// iterate.
    Accept { step: f64, f: f64 },
    /// No acceptable step within the evaluation budget.
    Fail,
}

#[derive(Clone, Copy)]
enum WState {
    /// Bracketing phase (Algorithm 3.5): expanding trial steps until a
    /// bracket is found or a step is accepted.
    Bracket { iter: usize, t_prev: f64, f_prev: f64, dphi_prev: f64 },
    /// Zoom phase (Algorithm 3.6): shrinking `[lo, hi]`.
    Zoom { remaining: usize, t_lo: f64, f_lo: f64, dphi_lo: f64, t_hi: f64, f_hi: f64 },
    Done,
}

/// Poll-driven strong-Wolfe search: the *caller* owns `φ` evaluation, so
/// several searches over independent problems can share one fused oracle
/// pass (the batched multi-problem driver in [`crate::ot::batch`]). The
/// transition logic is the single implementation of the Wolfe conditions
/// in this crate — [`strong_wolfe`] is a synchronous pump over it, so the
/// sequential and batched paths cannot drift apart.
pub struct WolfeMachine {
    opts: WolfeOptions,
    f0: f64,
    dphi0: f64,
    pending: f64,
    state: WState,
}

impl WolfeMachine {
    /// Start a search from `φ(0) = f0`, `φ'(0) = dphi0`. Returns `None`
    /// when `dphi0` is not a descent slope (or the budget is zero) —
    /// exactly the cases where [`strong_wolfe`] returns `None` without
    /// evaluating the oracle.
    pub fn new(f0: f64, dphi0: f64, init_step: f64, opts: &WolfeOptions) -> Option<Self> {
        if dphi0 >= 0.0 || opts.max_evals == 0 {
            return None;
        }
        Some(WolfeMachine {
            opts: *opts,
            f0,
            dphi0,
            pending: init_step.min(opts.step_max),
            state: WState::Bracket { iter: 0, t_prev: 0.0, f_prev: f0, dphi_prev: dphi0 },
        })
    }

    /// The trial step whose `φ`/`φ'` values the next [`Self::advance`]
    /// call expects.
    pub fn pending_step(&self) -> f64 {
        self.pending
    }

    /// Consume the evaluation at [`Self::pending_step`] and return the
    /// next action.
    pub fn advance(&mut self, ft: f64, dphit: f64) -> WolfePoll {
        let t = self.pending;
        match self.state {
            WState::Bracket { iter, t_prev, f_prev, dphi_prev } => {
                let armijo_ok = ft <= self.f0 + self.opts.c1 * t * self.dphi0;
                if !armijo_ok || (iter > 0 && ft >= f_prev) {
                    self.state = WState::Zoom {
                        remaining: self.opts.max_evals,
                        t_lo: t_prev,
                        f_lo: f_prev,
                        dphi_lo: dphi_prev,
                        t_hi: t,
                        f_hi: ft,
                    };
                    return self.zoom_trial();
                }
                if dphit.abs() <= -self.opts.c2 * self.dphi0 {
                    self.state = WState::Done;
                    return WolfePoll::Accept { step: t, f: ft };
                }
                if dphit >= 0.0 {
                    self.state = WState::Zoom {
                        remaining: self.opts.max_evals,
                        t_lo: t,
                        f_lo: ft,
                        dphi_lo: dphit,
                        t_hi: t_prev,
                        f_hi: f_prev,
                    };
                    return self.zoom_trial();
                }
                let t_next = (2.0 * t).min(self.opts.step_max);
                if (t_next >= self.opts.step_max && iter > 3) || iter + 1 >= self.opts.max_evals {
                    self.state = WState::Done;
                    return WolfePoll::Fail;
                }
                self.state =
                    WState::Bracket { iter: iter + 1, t_prev: t, f_prev: ft, dphi_prev: dphit };
                self.pending = t_next;
                WolfePoll::Eval(t_next)
            }
            WState::Zoom { remaining, t_lo, f_lo, dphi_lo, t_hi, f_hi } => {
                if ft > self.f0 + self.opts.c1 * t * self.dphi0 || ft >= f_lo {
                    self.state = WState::Zoom { remaining, t_lo, f_lo, dphi_lo, t_hi: t, f_hi: ft };
                } else {
                    if dphit.abs() <= -self.opts.c2 * self.dphi0 {
                        self.state = WState::Done;
                        return WolfePoll::Accept { step: t, f: ft };
                    }
                    let (nt_hi, nf_hi) = if dphit * (t_hi - t_lo) >= 0.0 {
                        (t_lo, f_lo)
                    } else {
                        (t_hi, f_hi)
                    };
                    self.state = WState::Zoom {
                        remaining,
                        t_lo: t,
                        f_lo: ft,
                        dphi_lo: dphit,
                        t_hi: nt_hi,
                        f_hi: nf_hi,
                    };
                }
                self.zoom_trial()
            }
            WState::Done => WolfePoll::Fail,
        }
    }

    /// Pick the next zoom trial point from the current bracket:
    /// quadratic interpolation of `(f_lo, dphi_lo, f_hi)` safeguarded
    /// into the middle 80% of the bracket, falling back to bisection.
    fn zoom_trial(&mut self) -> WolfePoll {
        let WState::Zoom { remaining, t_lo, f_lo, dphi_lo, t_hi, f_hi } = self.state else {
            return WolfePoll::Fail;
        };
        if remaining == 0 || (t_hi - t_lo).abs() < 1e-16 * t_lo.abs().max(1.0) {
            self.state = WState::Done;
            return WolfePoll::Fail;
        }
        let mut t = quadratic_min(t_lo, f_lo, dphi_lo, t_hi, f_hi);
        let lo = t_lo.min(t_hi);
        let hi = t_lo.max(t_hi);
        let margin = 0.1 * (hi - lo);
        if !t.is_finite() || t < lo + margin || t > hi - margin {
            t = 0.5 * (lo + hi);
        }
        self.state = WState::Zoom { remaining: remaining - 1, t_lo, f_lo, dphi_lo, t_hi, f_hi };
        self.pending = t;
        WolfePoll::Eval(t)
    }
}

/// Find a step satisfying the strong Wolfe conditions along `dir` from
/// `x0`. `f0`/`dphi0` are the value and directional derivative at 0
/// (`dphi0` must be negative). Returns `None` when no acceptable step is
/// found within the evaluation budget. Synchronous pump over
/// [`WolfeMachine`].
pub fn strong_wolfe(
    oracle: &mut dyn DualOracle,
    x0: &[f64],
    f0: f64,
    grad0: &[f64],
    dir: &[f64],
    init_step: f64,
    opts: &WolfeOptions,
) -> Option<LineSearchResult> {
    let dphi0 = crate::linalg::dot(grad0, dir);
    let mut machine = WolfeMachine::new(f0, dphi0, init_step, opts)?;
    let n = x0.len();
    let mut phi = Phi {
        oracle,
        x0,
        dir,
        xt: vec![0.0; n],
        gt: vec![0.0; n],
        evals: 0,
    };
    let mut t = machine.pending_step();
    loop {
        let (ft, dphit) = phi.eval(t);
        match machine.advance(ft, dphit) {
            WolfePoll::Eval(next) => t = next,
            WolfePoll::Accept { step, f } => {
                let evals = phi.evals;
                return Some(LineSearchResult { step, f, grad: phi.gt, evals });
            }
            WolfePoll::Fail => return None,
        }
    }
}

/// Minimizer of the quadratic through `(a, fa)` with slope `dfa` and `(b, fb)`.
fn quadratic_min(a: f64, fa: f64, dfa: f64, b: f64, fb: f64) -> f64 {
    let db = b - a;
    let denom = 2.0 * (fb - fa - dfa * db);
    if denom.abs() < 1e-300 {
        return f64::NAN;
    }
    a - dfa * db * db / denom
}
