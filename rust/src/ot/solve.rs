//! The unified solve-options builder — one configuration surface for
//! every solver family.
//!
//! PRs 3–5 grew a constructor ladder per knob (`with_threads`,
//! `with_ctx`, `with_simd`, `with_ctx_simd`, `solve_*_ctx`,
//! `solve_full_warm_ctx_simd`, …). [`SolveOptions`] collapses that into
//! one builder consumed by one `solve(problem, &opts)` entry per
//! family:
//!
//! * [`crate::ot::fastot::solve`] — the paper's screened full dual,
//! * [`crate::ot::origin::solve`] — the dense full-dual baseline,
//! * [`crate::ot::semidual::solve`] — the semi-dual (exact column
//!   marginals),
//! * [`crate::coordinator::sweep::solve`] — method-dispatched (the
//!   sweep/serve/CLI entry).
//!
//! ```no_run
//! use grpot::ot::solve::SolveOptions;
//! # let prob: grpot::ot::dual::OtProblem = unimplemented!();
//! let opts = SolveOptions::new().gamma(0.5).rho(0.6).threads(4);
//! let res = grpot::ot::fastot::solve(&prob, &opts).unwrap();
//! ```
//!
//! The legacy entry points remain as thin `#[deprecated]` shims that
//! pin the group-lasso regularizer (so `GRPOT_REG` can never re-route
//! them) and forward here.

use super::cost::CostMode;
use super::regularizer::RegKind;
use crate::pool::ParallelCtx;
use crate::simd::SimdMode;
use crate::solvers::lbfgs::LbfgsOptions;

/// Options shared by every solver family. Construct with
/// [`SolveOptions::new`] (or `Default`) and chain the builder setters;
/// unknown-to-a-family knobs are ignored by that family (e.g. the
/// semi-dual has no working set).
#[derive(Clone)]
pub struct SolveOptions {
    /// Overall regularization strength γ (> 0).
    pub gamma: f64,
    /// Group/quadratic balance ρ ∈ [0, 1) — group-lasso only; scalar
    /// regularizers ignore it.
    pub rho: f64,
    /// Snapshot interval `r` in solver iterations (paper: 10).
    pub r: usize,
    /// Enable the lower-bound working set ℕ (screened method only).
    pub use_working_set: bool,
    /// Inner L-BFGS options (iteration cap, tolerances, memory).
    pub lbfgs: LbfgsOptions,
    /// Intra-solve oracle workers. Deterministic: results are
    /// bit-identical for every value. Ignored when `ctx` is set.
    pub threads: usize,
    /// SIMD policy for the specialized group-lasso kernels (`GRPOT_SIMD`
    /// replaces the `Auto` default; explicit modes win). The generic
    /// regularizer path is scalar and ignores this.
    pub simd: SimdMode,
    /// Which regularizer to solve with. `None` defers to
    /// [`RegKind::env_default`] (`GRPOT_REG`, else group lasso); the
    /// legacy shims pin `Some(GroupLasso)`.
    pub regularizer: Option<RegKind>,
    /// Warm-start iterate: `[α; β]` for the full dual, `α` for the
    /// semi-dual. `None` starts at the origin.
    pub warm_start: Option<Vec<f64>>,
    /// Long-lived parallel context; clones share its parked worker set,
    /// so repeated solves (the serving engine, the sweep loop) never
    /// respawn threads. When set, `threads` is ignored in favor of
    /// `ctx.threads()`.
    pub ctx: Option<ParallelCtx>,
    /// Telemetry observer invoked once with the finished
    /// [`crate::obs::SolveReport`]. Reports are assembled from counters
    /// the solve maintains anyway, so setting this never changes solver
    /// output.
    pub observer: Option<crate::obs::ObserverHook>,
    /// Request trace ID stamped on spans and the report (0 = not part
    /// of a traced request).
    pub trace_id: u64,
    /// Cooperative cancellation token polled between solver iterations
    /// and once per column chunk inside oracle evaluations. `None` (the
    /// default) removes the checks entirely; an uncancelled token costs
    /// one relaxed load per checkpoint and never changes solver output.
    pub cancel: Option<crate::fault::CancelToken>,
    /// Cost-matrix backend for problems *built from* this options
    /// struct (the serving engine's dataset path, `try_from_points`).
    /// `Auto` (the default) defers to `GRPOT_COST`, else dense;
    /// `Factored` stores coordinates + norms (O((m+n)·d)) and
    /// synthesizes cost tiles on demand — byte-identical solves at a
    /// fraction of the memory for squared-ℓ2 costs. Solves over an
    /// already-built [`super::dual::OtProblem`] ignore it (the problem
    /// carries its own backend).
    pub cost: CostMode,
    /// Batched-solve width K for the consumers that coalesce several
    /// (γ, ρ) problems over one dataset into a fused
    /// [`crate::ot::batch::solve_batched`] pass (the serving engine's
    /// `--batch-k`, the sweep grid). `None` defers to `GRPOT_BATCH_K`
    /// (else 1, batching off); an explicit value wins. Batching changes
    /// data movement only — every problem's result stays byte-identical
    /// to its sequential solve at any K.
    pub batch_k: Option<usize>,
    /// Per-chunk [`crate::ot::cost::TileRing`] budget in KiB for the
    /// factored cost backend (`--tile-ring-kib`). `None` defers to
    /// `GRPOT_TILE_RING_KIB`, else the fixed ~1 MiB default
    /// ([`crate::ot::cost::TILE_RING_BUDGET_BYTES`]). The budget moves
    /// only tile *retention* (and hence `tiles_built`), never solve
    /// outputs.
    pub tile_ring_kib: Option<usize>,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            gamma: 1.0,
            rho: 0.5,
            r: 10,
            use_working_set: true,
            lbfgs: LbfgsOptions::default(),
            threads: 1,
            simd: SimdMode::Auto,
            regularizer: None,
            warm_start: None,
            ctx: None,
            observer: None,
            trace_id: 0,
            cancel: None,
            cost: CostMode::Auto,
            batch_k: None,
            tile_ring_kib: None,
        }
    }
}

impl std::fmt::Debug for SolveOptions {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SolveOptions")
            .field("gamma", &self.gamma)
            .field("rho", &self.rho)
            .field("r", &self.r)
            .field("use_working_set", &self.use_working_set)
            .field("lbfgs", &self.lbfgs)
            .field("threads", &self.threads)
            .field("simd", &self.simd)
            .field("regularizer", &self.regularizer)
            .field("warm_start", &self.warm_start.as_ref().map(Vec::len))
            .field("ctx_threads", &self.ctx.as_ref().map(ParallelCtx::threads))
            .field("observer", &self.observer.is_some())
            .field("trace_id", &self.trace_id)
            .field("cancel", &self.cancel.is_some())
            .field("cost", &self.cost)
            .field("batch_k", &self.batch_k)
            .field("tile_ring_kib", &self.tile_ring_kib)
            .finish()
    }
}

impl SolveOptions {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn gamma(mut self, gamma: f64) -> Self {
        self.gamma = gamma;
        self
    }

    pub fn rho(mut self, rho: f64) -> Self {
        self.rho = rho;
        self
    }

    /// Snapshot interval `r`.
    pub fn r(mut self, r: usize) -> Self {
        self.r = r;
        self
    }

    /// Shorthand for capping `lbfgs.max_iters`.
    pub fn max_iters(mut self, max_iters: usize) -> Self {
        self.lbfgs.max_iters = max_iters;
        self
    }

    pub fn lbfgs(mut self, lbfgs: LbfgsOptions) -> Self {
        self.lbfgs = lbfgs;
        self
    }

    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn simd(mut self, simd: SimdMode) -> Self {
        self.simd = simd;
        self
    }

    pub fn regularizer(mut self, kind: RegKind) -> Self {
        self.regularizer = Some(kind);
        self
    }

    pub fn warm_start(mut self, x0: Vec<f64>) -> Self {
        self.warm_start = Some(x0);
        self
    }

    pub fn ctx(mut self, ctx: ParallelCtx) -> Self {
        self.ctx = Some(ctx);
        self
    }

    pub fn working_set(mut self, use_working_set: bool) -> Self {
        self.use_working_set = use_working_set;
        self
    }

    /// Install a telemetry observer (see
    /// [`crate::obs::ObserverHook::capture`] for the common pattern).
    pub fn observer(mut self, hook: crate::obs::ObserverHook) -> Self {
        self.observer = Some(hook);
        self
    }

    /// Stamp this solve's spans and report with a request trace ID.
    pub fn trace_id(mut self, trace_id: u64) -> Self {
        self.trace_id = trace_id;
        self
    }

    /// Attach a cooperative cancellation token (deadline and/or manual
    /// cancel); the solve stops at the next checkpoint once it fires.
    pub fn cancel(mut self, token: crate::fault::CancelToken) -> Self {
        self.cancel = Some(token);
        self
    }

    /// Select the cost-matrix backend for problems built from these
    /// options (dense resident matrix vs factored coordinates + norms).
    pub fn cost(mut self, mode: CostMode) -> Self {
        self.cost = mode;
        self
    }

    /// Set the batched-solve width K for coalescing consumers (serving
    /// engine, sweep grid). `1` disables batching.
    pub fn batch_k(mut self, k: usize) -> Self {
        self.batch_k = Some(k);
        self
    }

    /// Set the per-chunk factored-cost tile-ring budget in KiB.
    pub fn tile_ring_kib(mut self, kib: usize) -> Self {
        self.tile_ring_kib = Some(kib);
        self
    }

    /// The effective batch width: the explicit value (clamped to ≥ 1),
    /// else `GRPOT_BATCH_K`, else 1 (batching off). A malformed or zero
    /// env value is an error.
    pub fn resolve_batch_k(&self) -> crate::error::Result<usize> {
        if let Some(k) = self.batch_k {
            return Ok(k.max(1));
        }
        match std::env::var("GRPOT_BATCH_K") {
            Ok(s) => match s.trim().parse::<usize>() {
                Ok(k) if k >= 1 => Ok(k),
                _ => Err(crate::err!(
                    "GRPOT_BATCH_K must be a positive integer, got '{s}'"
                )),
            },
            Err(_) => Ok(1),
        }
    }

    /// The effective tile-ring budget in bytes: the explicit KiB value,
    /// else `GRPOT_TILE_RING_KIB`, else the fixed default. A malformed
    /// or zero env value is an error.
    pub fn resolve_tile_ring_bytes(&self) -> crate::error::Result<usize> {
        super::cost::resolve_tile_ring_bytes(self.tile_ring_kib)
    }

    /// The effective regularizer kind: the explicit selection, else the
    /// `GRPOT_REG`/group-lasso default (a bad env value is an error).
    pub fn resolve_regularizer(&self) -> crate::error::Result<RegKind> {
        match self.regularizer {
            Some(kind) => Ok(kind),
            None => RegKind::env_default(),
        }
    }

    /// The parallel context this solve runs on: the configured one
    /// (shared parked workers), else a fresh solve-lifetime context.
    pub fn make_ctx(&self) -> ParallelCtx {
        match &self.ctx {
            Some(ctx) => ctx.clone(),
            None => ParallelCtx::new(self.threads),
        }
    }

    /// View as the legacy per-solve config (the Algorithm-1 driver's
    /// parameter block).
    pub fn fastot_config(&self) -> super::fastot::FastOtConfig {
        super::fastot::FastOtConfig {
            gamma: self.gamma,
            rho: self.rho,
            r: self.r,
            use_working_set: self.use_working_set,
            threads: self.threads,
            simd: self.simd,
            lbfgs: self.lbfgs.clone(),
            observer: self.observer.clone(),
            trace_id: self.trace_id,
            cancel: self.cancel.clone(),
            cost: self.cost,
            tile_ring_kib: self.tile_ring_kib,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_sets_every_knob() {
        let opts = SolveOptions::new()
            .gamma(0.3)
            .rho(0.7)
            .r(5)
            .max_iters(42)
            .threads(3)
            .simd(SimdMode::Scalar)
            .regularizer(RegKind::SquaredL2)
            .warm_start(vec![0.0; 4])
            .working_set(false)
            .cancel(crate::fault::CancelToken::new())
            .cost(CostMode::Factored)
            .batch_k(3)
            .tile_ring_kib(256);
        assert_eq!(opts.gamma, 0.3);
        assert_eq!(opts.rho, 0.7);
        assert_eq!(opts.r, 5);
        assert_eq!(opts.lbfgs.max_iters, 42);
        assert_eq!(opts.threads, 3);
        assert_eq!(opts.simd, SimdMode::Scalar);
        assert_eq!(opts.regularizer, Some(RegKind::SquaredL2));
        assert_eq!(opts.warm_start.as_ref().map(Vec::len), Some(4));
        assert!(!opts.use_working_set);
        assert!(opts.cancel.is_some());
        assert_eq!(opts.cost, CostMode::Factored);
        assert_eq!(opts.batch_k, Some(3));
        assert_eq!(opts.tile_ring_kib, Some(256));
        assert_eq!(opts.resolve_batch_k().unwrap(), 3);
        assert_eq!(opts.resolve_tile_ring_bytes().unwrap(), 256 * 1024);
        let cfg = opts.fastot_config();
        assert_eq!(cfg.gamma, 0.3);
        assert_eq!(cfg.lbfgs.max_iters, 42);
        assert!(!cfg.use_working_set);
        assert!(cfg.cancel.is_some());
        assert_eq!(cfg.cost, CostMode::Factored);
        assert_eq!(cfg.tile_ring_kib, Some(256));
    }

    #[test]
    fn batch_k_explicit_wins_and_defaults_to_one() {
        assert_eq!(SolveOptions::new().batch_k(4).resolve_batch_k().unwrap(), 4);
        // Explicit zero is clamped rather than erroring (builder misuse,
        // not env misconfiguration).
        assert_eq!(SolveOptions::new().batch_k(0).resolve_batch_k().unwrap(), 1);
        if std::env::var("GRPOT_BATCH_K").is_err() {
            assert_eq!(SolveOptions::new().resolve_batch_k().unwrap(), 1);
        }
    }

    #[test]
    fn explicit_regularizer_wins_over_env_default() {
        let opts = SolveOptions::new().regularizer(RegKind::NegEntropy);
        assert_eq!(opts.resolve_regularizer().unwrap(), RegKind::NegEntropy);
        // Unset: defers to the env/group-lasso default. We don't set
        // the env var here (process-global); the GRPOT_REG CI shard
        // covers the env side end to end.
        if std::env::var("GRPOT_REG").is_err() {
            let opts = SolveOptions::new();
            assert_eq!(opts.resolve_regularizer().unwrap(), RegKind::GroupLasso);
        }
    }

    #[test]
    fn ctx_threads_take_precedence() {
        let opts = SolveOptions::new().threads(1).ctx(crate::pool::ParallelCtx::new(3));
        assert_eq!(opts.make_ctx().threads(), 3);
        let opts = SolveOptions::new().threads(2);
        assert_eq!(opts.make_ctx().threads(), 2);
    }
}
