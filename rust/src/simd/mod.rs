//! SIMD column-lane oracle kernels — bit-exact, runtime-dispatched.
//!
//! The per-iteration gradient pass runs [`crate::ot::dual::group_grad_contrib`]
//! over every surviving (group, column) pair; this module makes that
//! kernel process [`LANES`] **columns** of a cache panel at once. The
//! key design constraint is that vectorization happens *across column
//! lanes, never across the `i` reduction*:
//!
//! * each lane carries one column's independent `zsq` / `t` / `col_mass`
//!   chain, accumulated over ascending `i` exactly like the scalar
//!   kernel — per-lane `add`/`mul`/`max`/`sqrt` are IEEE-754 operations
//!   identical to their scalar `f64` counterparts, so every lane's
//!   arithmetic is bit-for-bit the scalar kernel's arithmetic;
//! * the only cross-lane operation — folding the per-lane `t_{ij}` into
//!   `grad_alpha[i]` — sums lanes in **ascending column order**, which
//!   is exactly the association the scalar panel walk produces (column
//!   `j` is finished before column `j+1` touches the same `grad_alpha`
//!   entries);
//! * no FMA contraction anywhere: both paths use plain mul-then-add
//!   (rustc never contracts `a * b + c`, and the vector backends only
//!   use `vmulpd`/`vaddpd`, never `vfmadd`).
//!
//! Scalar and SIMD paths are therefore byte-equal *by construction*,
//! and `tests/simd_equivalence.rs` + the `GRPOT_SIMD=scalar` CI shard
//! assert it end to end (solutions, objectives, iteration counts and
//! `OracleStats` all compared bitwise).
//!
//! ## Backends and dispatch
//!
//! [`Dispatch::resolve`] picks the backend once per oracle:
//!
//! * `avx2` — `std::arch::x86_64` intrinsics, selected only when
//!   `is_x86_feature_detected!("avx2")` confirms the CPU supports them
//!   at runtime (never by compile-time target flags alone);
//! * `portable` — a `[f64; 4]` mirror with the same lane semantics
//!   (including the x86 `MAXPD`/`MINPD` tie rules), used on every other
//!   target — the vector kernels build and run correctly everywhere;
//! * `scalar` — the original scalar kernels, selected by
//!   `GRPOT_SIMD=scalar` or `FastOtConfig.simd`; the reference the
//!   other two must match bitwise.
//!
//! The environment variable `GRPOT_SIMD` (`auto` | `scalar` |
//! `portable`) replaces the default `Auto` policy when set — that is
//! how the CI shard forces the scalar reference path through every
//! solver entry point without touching call sites. A config that
//! explicitly forces `Scalar` or `Portable` wins over the env var, so
//! forced bench baselines stay what their labels claim.
//!
//! All `unsafe` in the crate's SIMD support lives in this module
//! ([`lane`] holds the intrinsic calls, [`kernel`] the
//! `#[target_feature]` entry wrappers); every intrinsic call site is
//! reachable only through a [`Dispatch::Avx2`] value, which can only be
//! constructed after runtime feature detection.

mod kernel;
mod lane;

pub use kernel::{batch_quad_contrib, group_quad_contrib, snapshot_quad, sub_into};

/// Columns processed per vector kernel call (one lane per column).
pub const LANES: usize = 4;

/// User-facing SIMD policy knob (`FastOtConfig.simd`, `GRPOT_SIMD`,
/// `solve --simd`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SimdMode {
    /// Pick the fastest backend the CPU supports at runtime.
    #[default]
    Auto,
    /// Force the original scalar kernels (the bitwise reference).
    Scalar,
    /// Force the portable `[f64; 4]` mirror even when AVX2 is available
    /// (exercises the fallback on AVX2 hardware; testing/bench knob).
    Portable,
}

impl SimdMode {
    /// Parse a knob value. Accepts `auto`, `scalar`, `portable`.
    pub fn parse(s: &str) -> Result<SimdMode, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(SimdMode::Auto),
            "scalar" => Ok(SimdMode::Scalar),
            "portable" => Ok(SimdMode::Portable),
            other => Err(format!("unknown SIMD mode '{other}' (expected auto|scalar|portable)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SimdMode::Auto => "auto",
            SimdMode::Scalar => "scalar",
            SimdMode::Portable => "portable",
        }
    }
}

/// The backend a solve actually runs, resolved once at oracle
/// construction and fixed for the oracle's lifetime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dispatch {
    /// Original scalar kernels; no packed tiles are built.
    Scalar,
    /// Vector kernels on the portable `[f64; 4]` mirror.
    Portable,
    /// Vector kernels on AVX2 intrinsics (runtime-detected x86-64 only).
    #[cfg(target_arch = "x86_64")]
    Avx2,
}

impl Dispatch {
    /// Resolve `mode` to a backend. `GRPOT_SIMD`, when set, replaces
    /// the **default `Auto` policy only** — the CI scalar shard and the
    /// CLI knob ride on this (configs default to `Auto` everywhere),
    /// while an explicitly forced `Scalar`/`Portable` always wins, so a
    /// stray env var can never silently relabel a forced-scalar
    /// baseline (benches assert real scalar-vs-vector comparisons).
    /// `Auto` selects AVX2 only after `is_x86_feature_detected!`
    /// confirms it; everywhere else it selects the portable mirror.
    pub fn resolve(mode: SimdMode) -> Dispatch {
        let mode = match mode {
            SimdMode::Auto => match std::env::var("GRPOT_SIMD") {
                Ok(v) => SimdMode::parse(&v).unwrap_or_else(|e| panic!("GRPOT_SIMD: {e}")),
                Err(_) => SimdMode::Auto,
            },
            explicit => explicit,
        };
        match mode {
            SimdMode::Scalar => Dispatch::Scalar,
            SimdMode::Portable => Dispatch::Portable,
            SimdMode::Auto => Dispatch::fastest(),
        }
    }

    #[cfg(target_arch = "x86_64")]
    fn fastest() -> Dispatch {
        if std::arch::is_x86_feature_detected!("avx2") {
            Dispatch::Avx2
        } else {
            Dispatch::Portable
        }
    }

    #[cfg(not(target_arch = "x86_64"))]
    fn fastest() -> Dispatch {
        Dispatch::Portable
    }

    /// True for the lane-vectorized backends (they need packed tiles).
    pub fn is_vector(&self) -> bool {
        !matches!(self, Dispatch::Scalar)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dispatch::Scalar => "scalar",
            Dispatch::Portable => "portable",
            #[cfg(target_arch = "x86_64")]
            Dispatch::Avx2 => "avx2",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::dual::{group_grad_contrib, DualParams, KernelConsts};
    use crate::rng::Pcg64;

    #[test]
    fn mode_parsing() {
        assert_eq!(SimdMode::parse("auto"), Ok(SimdMode::Auto));
        assert_eq!(SimdMode::parse(" Scalar "), Ok(SimdMode::Scalar));
        assert_eq!(SimdMode::parse("portable"), Ok(SimdMode::Portable));
        assert!(SimdMode::parse("avx512").is_err());
        assert_eq!(SimdMode::default(), SimdMode::Auto);
    }

    #[test]
    fn explicit_modes_win_over_env() {
        // Forced modes resolve unconditionally — GRPOT_SIMD may only
        // replace the Auto default, never an explicit baseline.
        assert_eq!(Dispatch::resolve(SimdMode::Scalar), Dispatch::Scalar);
        assert_eq!(Dispatch::resolve(SimdMode::Portable), Dispatch::Portable);
        if std::env::var("GRPOT_SIMD").is_err() {
            assert!(Dispatch::resolve(SimdMode::Auto).is_vector());
        }
    }

    /// Every vector backend must reproduce the scalar kernel bitwise on
    /// one quad: same ψ, same column masses, same gradient bytes — for
    /// fully active, fully inactive and mixed-activity lane patterns.
    #[test]
    fn quad_kernel_matches_scalar_bitwise() {
        let consts = KernelConsts::new(&DualParams::new(1.0, 0.5));
        let mut rng = Pcg64::new(0x51D);
        let g = 7usize;
        let start = 3usize;
        let m = start + g + 2;
        let backends: Vec<Dispatch> = {
            let mut b = vec![Dispatch::Portable];
            #[cfg(target_arch = "x86_64")]
            if std::arch::is_x86_feature_detected!("avx2") {
                b.push(Dispatch::Avx2);
            }
            b
        };
        for case in 0..64 {
            let alpha: Vec<f64> = (0..m).map(|_| rng.uniform(-0.4, 0.6)).collect();
            // Bias β per case so some quads are all-active, some
            // all-inactive and some mixed.
            let bias = [-1.5, 0.0, 1.0, rng.uniform(-1.0, 1.0)][case % 4];
            let beta4: [f64; 4] = std::array::from_fn(|_| bias + rng.uniform(-0.6, 0.8));
            let cols: Vec<Vec<f64>> =
                (0..LANES).map(|_| (0..m).map(|_| rng.uniform(0.0, 1.0)).collect()).collect();
            // Interleaved [i][lane] tile over the group range.
            let mut tile = Vec::with_capacity(LANES * g);
            for k in 0..g {
                for c in &cols {
                    tile.push(c[start + k]);
                }
            }
            // Scalar reference: the panel walk's column-ascending order.
            let mut ga_ref = vec![0.0; m];
            let mut scratch = vec![0.0; g];
            let mut psi_ref = [0.0; LANES];
            let mut mass_ref = [0.0; LANES];
            for t in 0..LANES {
                let (psi, mass) = group_grad_contrib(
                    &alpha,
                    beta4[t],
                    &cols[t][start..start + g],
                    start..start + g,
                    &consts,
                    &mut ga_ref,
                    &mut scratch,
                );
                psi_ref[t] = psi;
                mass_ref[t] = mass;
            }
            for &dispatch in &backends {
                let mut ga = vec![0.0; m];
                let mut quad = vec![0.0; LANES * g];
                let (psi, mass) = group_quad_contrib(
                    dispatch,
                    &alpha,
                    &beta4,
                    &tile,
                    start..start + g,
                    &consts,
                    &mut ga,
                    &mut quad,
                );
                for t in 0..LANES {
                    assert_eq!(
                        psi[t].to_bits(),
                        psi_ref[t].to_bits(),
                        "psi lane {t} case {case} ({})",
                        dispatch.name()
                    );
                    assert_eq!(
                        mass[t].to_bits(),
                        mass_ref[t].to_bits(),
                        "mass lane {t} case {case} ({})",
                        dispatch.name()
                    );
                }
                for i in 0..m {
                    assert_eq!(
                        ga[i].to_bits(),
                        ga_ref[i].to_bits(),
                        "grad_alpha[{i}] case {case} ({})",
                        dispatch.name()
                    );
                }
            }
        }
    }

    /// The batched-problem kernel (lanes = problems, one shared column)
    /// must reproduce per-problem scalar calls bitwise: same ψ, same
    /// column masses, same gradient contributions — for fully active,
    /// fully inactive and mixed-activity lane patterns under *different*
    /// per-lane (γ, ρ) constants.
    #[test]
    fn batch_kernel_matches_scalar_bitwise() {
        let params =
            [(1.0, 0.5), (0.3, 0.2), (2.5, 0.9), (1.0, 0.05)].map(|(g, r)| DualParams::new(g, r));
        let consts4: [KernelConsts; LANES] = std::array::from_fn(|t| KernelConsts::new(&params[t]));
        let mut rng = Pcg64::new(0xBA7C);
        let g = 6usize;
        let start = 2usize;
        let m = start + g + 3;
        let backends: Vec<Dispatch> = {
            let mut b = vec![Dispatch::Portable];
            #[cfg(target_arch = "x86_64")]
            if std::arch::is_x86_feature_detected!("avx2") {
                b.push(Dispatch::Avx2);
            }
            b
        };
        for case in 0..64 {
            // Four independent dual iterates over ONE shared column.
            let alphas: Vec<Vec<f64>> = (0..LANES)
                .map(|_| (0..m).map(|_| rng.uniform(-0.4, 0.6)).collect())
                .collect();
            let bias = [-1.5, 0.0, 1.0, rng.uniform(-1.0, 1.0)][case % 4];
            let beta4: [f64; 4] = std::array::from_fn(|_| bias + rng.uniform(-0.6, 0.8));
            let col: Vec<f64> = (0..m).map(|_| rng.uniform(0.0, 1.0)).collect();
            let c_seg = &col[start..start + g];
            // Scalar reference: one group_grad_contrib per problem.
            let mut ga_ref: Vec<Vec<f64>> = vec![vec![0.0; m]; LANES];
            let mut scratch = vec![0.0; g];
            let mut psi_ref = [0.0; LANES];
            let mut mass_ref = [0.0; LANES];
            for t in 0..LANES {
                let (psi, mass) = group_grad_contrib(
                    &alphas[t],
                    beta4[t],
                    c_seg,
                    start..start + g,
                    &consts4[t],
                    &mut ga_ref[t],
                    &mut scratch,
                );
                psi_ref[t] = psi;
                mass_ref[t] = mass;
            }
            for &dispatch in &backends {
                let mut quad = vec![0.0; LANES * g];
                let alpha_refs: [&[f64]; LANES] = std::array::from_fn(|t| alphas[t].as_slice());
                let (psi, mass, active) = batch_quad_contrib(
                    dispatch,
                    &alpha_refs,
                    &beta4,
                    c_seg,
                    start..start + g,
                    &consts4,
                    &mut quad,
                );
                // Apply the caller-side gradient adds.
                let mut ga: Vec<Vec<f64>> = vec![vec![0.0; m]; LANES];
                for t in 0..LANES {
                    if !active[t] {
                        continue;
                    }
                    for k in 0..g {
                        ga[t][start + k] += quad[LANES * k + t];
                    }
                }
                for t in 0..LANES {
                    assert_eq!(
                        psi[t].to_bits(),
                        psi_ref[t].to_bits(),
                        "psi lane {t} case {case} ({})",
                        dispatch.name()
                    );
                    assert_eq!(
                        mass[t].to_bits(),
                        mass_ref[t].to_bits(),
                        "mass lane {t} case {case} ({})",
                        dispatch.name()
                    );
                    assert_eq!(active[t], psi_ref[t] != 0.0 || mass_ref[t] != 0.0 || {
                        // A lane is active iff the scalar kernel passed the
                        // zero-group gate; reconstruct it from the inputs.
                        let mut zsq = 0.0;
                        for k in 0..g {
                            let f = alphas[t][start + k] + beta4[t] - c_seg[k];
                            let fp = if f > 0.0 { f } else { 0.0 };
                            zsq += fp * fp;
                        }
                        zsq > consts4[t].tau_sq
                    });
                    for i in 0..m {
                        assert_eq!(
                            ga[t][i].to_bits(),
                            ga_ref[t][i].to_bits(),
                            "grad_alpha[{i}] lane {t} case {case} ({})",
                            dispatch.name()
                        );
                    }
                }
            }
        }
    }

    /// The snapshot quad must reproduce the scalar z̃/k̃/õ chains bitwise.
    #[test]
    fn snapshot_quad_matches_scalar_bitwise() {
        let mut rng = Pcg64::new(0x5A9);
        let g = 5usize;
        let start = 2usize;
        let m = start + g + 1;
        let backends: Vec<Dispatch> = {
            let mut b = vec![Dispatch::Portable];
            #[cfg(target_arch = "x86_64")]
            if std::arch::is_x86_feature_detected!("avx2") {
                b.push(Dispatch::Avx2);
            }
            b
        };
        for case in 0..32 {
            let alpha: Vec<f64> = (0..m).map(|_| rng.uniform(-0.5, 0.7)).collect();
            let beta4: [f64; 4] = std::array::from_fn(|_| rng.uniform(-0.8, 0.9));
            let cols: Vec<Vec<f64>> =
                (0..LANES).map(|_| (0..m).map(|_| rng.uniform(0.0, 1.0)).collect()).collect();
            let mut tile = Vec::with_capacity(LANES * g);
            for k in 0..g {
                for c in &cols {
                    tile.push(c[start + k]);
                }
            }
            // Scalar reference: the recompute_snapshots inner loop.
            let mut zsq_ref = [0.0; LANES];
            let mut ksq_ref = [0.0; LANES];
            let mut osq_ref = [0.0; LANES];
            for t in 0..LANES {
                for i in start..start + g {
                    let f = alpha[i] + beta4[t] - cols[t][i];
                    ksq_ref[t] += f * f;
                    if f > 0.0 {
                        zsq_ref[t] += f * f;
                    } else {
                        osq_ref[t] += f * f;
                    }
                }
            }
            for &dispatch in &backends {
                let (zsq, ksq, osq) =
                    snapshot_quad(dispatch, &alpha, &beta4, &tile, start..start + g);
                for t in 0..LANES {
                    assert_eq!(zsq[t].to_bits(), zsq_ref[t].to_bits(), "zsq lane {t} case {case}");
                    assert_eq!(ksq[t].to_bits(), ksq_ref[t].to_bits(), "ksq lane {t} case {case}");
                    assert_eq!(osq[t].to_bits(), osq_ref[t].to_bits(), "osq lane {t} case {case}");
                }
            }
        }
    }

    #[test]
    fn sub_into_matches_scalar_on_every_backend() {
        let mut rng = Pcg64::new(77);
        let a: Vec<f64> = (0..23).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let b: Vec<f64> = (0..23).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let reference: Vec<f64> = a.iter().zip(&b).map(|(&x, &y)| x - y).collect();
        let backends: Vec<Dispatch> = {
            let mut v = vec![Dispatch::Scalar, Dispatch::Portable];
            #[cfg(target_arch = "x86_64")]
            if std::arch::is_x86_feature_detected!("avx2") {
                v.push(Dispatch::Avx2);
            }
            v
        };
        for dispatch in backends {
            let mut out = vec![0.0; a.len()];
            sub_into(dispatch, &mut out, &a, &b);
            for (got, want) in out.iter().zip(&reference) {
                assert_eq!(got.to_bits(), want.to_bits(), "{}", dispatch.name());
            }
        }
    }
}
