use super::*;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

#[test]
fn pool_runs_all_jobs() {
    let pool = ThreadPool::new(4);
    let counter = Arc::new(AtomicUsize::new(0));
    for _ in 0..100 {
        let c = Arc::clone(&counter);
        pool.execute(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
    }
    pool.join();
    assert_eq!(counter.load(Ordering::SeqCst), 100);
}

#[test]
fn pool_join_then_more_jobs() {
    let pool = ThreadPool::new(2);
    let counter = Arc::new(AtomicUsize::new(0));
    for round in 0..3 {
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), (round + 1) * 10);
    }
}

#[test]
fn pool_drop_joins() {
    let counter = Arc::new(AtomicUsize::new(0));
    {
        let pool = ThreadPool::new(3);
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_micros(100));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
    }
    assert_eq!(counter.load(Ordering::SeqCst), 50);
}

#[test]
fn parallel_chunks_cover_range_disjointly() {
    let n = 1003;
    let data: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
    parallel_for_chunks(n, 7, |lo, hi| {
        for i in lo..hi {
            data[i].fetch_add(1, Ordering::Relaxed);
        }
    });
    assert!(data.iter().all(|c| c.load(Ordering::Relaxed) == 1));
}

#[test]
fn parallel_chunks_single_thread_and_empty() {
    let hit = AtomicUsize::new(0);
    parallel_for_chunks(10, 1, |lo, hi| {
        hit.fetch_add(hi - lo, Ordering::Relaxed);
    });
    assert_eq!(hit.load(Ordering::Relaxed), 10);
    parallel_for_chunks(0, 4, |_, _| {});
}

#[test]
fn parallel_dynamic_covers_all() {
    let n = 517;
    let data: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
    parallel_for_dynamic(n, 5, 8, |i| {
        data[i].fetch_add(1, Ordering::Relaxed);
    });
    assert!(data.iter().all(|c| c.load(Ordering::Relaxed) == 1));
}

#[test]
fn pool_size() {
    assert_eq!(ThreadPool::new(3).size(), 3);
}

#[test]
fn fixed_chunks_cover_range_and_ignore_thread_count() {
    for n in [0usize, 1, 15, 16, 17, 100, 1003, 10_000] {
        let ranges = fixed_chunk_ranges(n);
        assert!(ranges.len() <= MAX_FIXED_CHUNKS, "n={n}: {} chunks", ranges.len());
        let mut expect = 0;
        for r in &ranges {
            assert_eq!(r.start, expect, "contiguous at n={n}");
            assert!(!r.is_empty());
            expect = r.end;
        }
        assert_eq!(expect, n, "chunks must cover 0..{n}");
        if n == 0 {
            assert!(ranges.is_empty());
        }
        // Boundaries are a function of n alone — recomputing yields the
        // exact same partition (no hidden thread-count dependence).
        assert_eq!(ranges, fixed_chunk_ranges(n));
    }
}

#[test]
fn map_reduce_is_bit_identical_across_thread_counts() {
    // A sum whose value depends on fp association: if any thread count
    // changed the reduction order, the totals would differ in the last
    // bits. All counts must agree with the serial chunked fold exactly.
    let n = 4097;
    let vals: Vec<f64> = (0..n).map(|i| 1.0 / (i as f64 + 0.37) * (-1.0f64).powi(i as i32)).collect();
    let run = |threads: usize| {
        parallel_map_reduce(
            threads,
            n as usize,
            97,
            0.0f64,
            |_, range| {
                let mut s = 0.0;
                for i in range {
                    s += vals[i];
                }
                s
            },
            |acc, part| acc + part,
        )
    };
    let serial = run(1);
    for threads in [2, 3, 4, 8] {
        let t = run(threads);
        assert_eq!(serial.to_bits(), t.to_bits(), "threads={threads}");
    }
}

#[test]
fn map_reduce_empty_range_returns_init_without_mapping() {
    let mapped = AtomicUsize::new(0);
    let out = parallel_map_reduce(
        4,
        0,
        8,
        41usize,
        |_, _| {
            mapped.fetch_add(1, Ordering::SeqCst);
            1usize
        },
        |acc, v| acc + v,
    );
    assert_eq!(out, 41);
    assert_eq!(mapped.load(Ordering::SeqCst), 0);
}

#[test]
fn map_reduce_chunk_larger_than_len_is_one_chunk() {
    let chunks = parallel_map_reduce(
        4,
        5,
        1000,
        Vec::new(),
        |c, range| (c, range.start, range.end),
        |mut acc: Vec<(usize, usize, usize)>, v| {
            acc.push(v);
            acc
        },
    );
    assert_eq!(chunks, vec![(0, 0, 5)]);
}

#[test]
fn map_reduce_propagates_worker_panics() {
    for threads in [1, 4] {
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            parallel_map_reduce(
                threads,
                100,
                8,
                0u64,
                |c, range| {
                    if c == 3 {
                        panic!("worker exploded");
                    }
                    range.len() as u64
                },
                |acc, v| acc + v,
            )
        }));
        assert!(res.is_err(), "panic must propagate at threads={threads}");
    }
}

#[test]
fn map_chunks_gives_each_chunk_its_slot() {
    let ranges = chunk_ranges(103, 10);
    let mut slots = vec![0usize; ranges.len()];
    ParallelCtx::new(4).map_chunks(&ranges, &mut slots, |c, range, slot| {
        *slot = c * 1000 + range.len();
    });
    for (c, (slot, range)) in slots.iter().zip(&ranges).enumerate() {
        assert_eq!(*slot, c * 1000 + range.len());
    }
}

#[test]
fn parallel_ctx_clamps_to_one() {
    assert_eq!(ParallelCtx::new(0).threads(), 1);
    assert!(!ParallelCtx::serial().is_parallel());
    assert!(ParallelCtx::new(2).is_parallel());
    assert_eq!(ParallelCtx::default(), ParallelCtx::serial());
}

#[test]
fn parallel_ctx_spawns_lazily_and_only_once() {
    let ctx = ParallelCtx::new(3);
    assert_eq!(ctx.live_workers(), 0, "no threads before the first parallel call");
    let ranges = chunk_ranges(64, 4);
    let mut slots = vec![0usize; ranges.len()];
    for round in 0..5 {
        ctx.map_chunks(&ranges, &mut slots, |c, range, slot| {
            *slot = round * 10_000 + c * 100 + range.len();
        });
        for (c, (slot, range)) in slots.iter().zip(&ranges).enumerate() {
            assert_eq!(*slot, round * 10_000 + c * 100 + range.len());
        }
        assert_eq!(ctx.live_workers(), 2, "threads−1 parked workers, spawned once");
    }
}

#[test]
fn parallel_ctx_serial_never_spawns() {
    let ctx = ParallelCtx::serial();
    let ranges = chunk_ranges(50, 5);
    let mut slots = vec![0usize; ranges.len()];
    ctx.map_chunks(&ranges, &mut slots, |c, _, slot| *slot = c + 1);
    assert_eq!(ctx.live_workers(), 0);
    assert!(slots.iter().enumerate().all(|(c, &s)| s == c + 1));
}

#[test]
fn persistent_and_forkjoin_dispatch_agree() {
    // Same chunk grid, same map, both dispatchers: identical slots.
    let ranges = chunk_ranges(997, 13);
    let fill = |c: usize, range: Range<usize>, slot: &mut f64| {
        let mut s = 0.0;
        for i in range {
            s += 1.0 / (i as f64 + 0.25) * if c % 2 == 0 { 1.0 } else { -1.0 };
        }
        *slot = s;
    };
    let ctx = ParallelCtx::new(4);
    let mut persistent = vec![0.0f64; ranges.len()];
    ctx.map_chunks(&ranges, &mut persistent, fill);
    let mut forkjoin = vec![0.0f64; ranges.len()];
    forkjoin_map_chunks(4, &ranges, &mut forkjoin, fill);
    for (p, f) in persistent.iter().zip(&forkjoin) {
        assert_eq!(p.to_bits(), f.to_bits());
    }
}

#[test]
fn parallel_ctx_worker_panic_propagates_and_pool_survives() {
    let ctx = ParallelCtx::new(4);
    let ranges = chunk_ranges(64, 4); // 16 chunks, 4 per block
    let mut slots = vec![0usize; ranges.len()];
    // Chunk 7 lives in a parked worker's block (block 1 at per=4).
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        ctx.map_chunks(&ranges, &mut slots, |c, _, slot| {
            if c == 7 {
                panic!("worker chunk exploded");
            }
            *slot = c;
        });
    }));
    assert!(r.is_err(), "worker panic must reach the caller");
    // Chunk 0 runs on the calling thread; its panic must propagate too.
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        ctx.map_chunks(&ranges, &mut slots, |c, _, slot| {
            if c == 0 {
                panic!("caller chunk exploded");
            }
            *slot = c;
        });
    }));
    assert!(r.is_err(), "caller-block panic must propagate");
    // The pool is still usable after both unwinds.
    ctx.map_chunks(&ranges, &mut slots, |c, range, slot| *slot = c * 100 + range.len());
    for (c, (slot, range)) in slots.iter().zip(&ranges).enumerate() {
        assert_eq!(*slot, c * 100 + range.len());
    }
    assert_eq!(ctx.live_workers(), 3);
}

#[test]
fn parallel_ctx_drop_joins_every_worker() {
    let ctx = ParallelCtx::new(4);
    let counter = ctx.live_worker_counter();
    let ranges = chunk_ranges(32, 2);
    let mut slots = vec![0usize; ranges.len()];
    ctx.map_chunks(&ranges, &mut slots, |c, _, slot| *slot = c);
    assert_eq!(counter.load(Ordering::SeqCst), 3);
    let clone = ctx.clone();
    drop(ctx);
    assert_eq!(counter.load(Ordering::SeqCst), 3, "clone keeps the pool alive");
    drop(clone);
    assert_eq!(counter.load(Ordering::SeqCst), 0, "last drop joins all workers");
}

#[test]
fn bounded_queue_fifo_and_backpressure() {
    let q = BoundedQueue::new(3);
    assert_eq!(q.capacity(), 3);
    assert_eq!(q.try_push(1).unwrap(), 1);
    assert_eq!(q.try_push(2).unwrap(), 2);
    assert_eq!(q.try_push(3).unwrap(), 3);
    match q.try_push(4) {
        Err(PushError::Full(item)) => assert_eq!(item, 4),
        other => panic!("expected Full, got {other:?}"),
    }
    assert_eq!(q.pop(), Some(1));
    assert_eq!(q.try_pop(), Some(2));
    assert_eq!(q.len(), 1);
    assert_eq!(q.try_push(5).unwrap(), 2);
}

#[test]
fn bounded_queue_close_drains_then_ends() {
    let q = BoundedQueue::new(4);
    q.try_push("a").unwrap();
    q.try_push("b").unwrap();
    q.close();
    match q.try_push("c") {
        Err(PushError::Closed(item)) => assert_eq!(item, "c"),
        other => panic!("expected Closed, got {other:?}"),
    }
    // Graceful drain: queued items stay poppable, then None.
    assert_eq!(q.pop(), Some("a"));
    assert_eq!(q.pop(), Some("b"));
    assert_eq!(q.pop(), None);
    assert!(q.is_closed());
}

#[test]
fn bounded_queue_close_wakes_blocked_consumers() {
    let q = Arc::new(BoundedQueue::<u32>::new(2));
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                s.spawn(move || q.pop())
            })
            .collect();
        std::thread::sleep(std::time::Duration::from_millis(10));
        q.try_push(7).unwrap();
        q.close();
        let got: Vec<Option<u32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(got.iter().filter(|v| v.is_some()).count(), 1);
        assert_eq!(got.iter().filter(|v| v.is_none()).count(), 2);
    });
}

#[test]
fn bounded_queue_drain_matching_preserves_order() {
    let q = BoundedQueue::new(8);
    for v in [1, 2, 3, 4, 5, 6] {
        q.try_push(v).unwrap();
    }
    let even = q.drain_matching(2, |v| v % 2 == 0);
    assert_eq!(even, vec![2, 4]); // capped at 2, FIFO among matches
    assert_eq!(q.len(), 4);
    assert_eq!(q.pop(), Some(1));
    assert_eq!(q.pop(), Some(3));
    assert_eq!(q.pop(), Some(5));
    assert_eq!(q.pop(), Some(6));
}

#[test]
fn bounded_queue_drain_matching_empty_queue_returns_nothing() {
    let q: BoundedQueue<u32> = BoundedQueue::new(4);
    let mut calls = 0;
    let out = q.drain_matching(8, |_| {
        calls += 1;
        true
    });
    assert!(out.is_empty());
    assert_eq!(calls, 0, "predicate never runs on an empty queue");
    assert_eq!(q.len(), 0);
}

#[test]
fn bounded_queue_drain_matching_no_match_leaves_queue_untouched() {
    let q = BoundedQueue::new(8);
    for v in [1, 3, 5, 7] {
        q.try_push(v).unwrap();
    }
    let mut calls = 0;
    let out = q.drain_matching(4, |v| {
        calls += 1;
        v % 2 == 0
    });
    assert!(out.is_empty());
    assert_eq!(calls, 4, "predicate runs once per item on a miss");
    // FIFO order preserved exactly.
    assert_eq!(q.pop(), Some(1));
    assert_eq!(q.pop(), Some(3));
    assert_eq!(q.pop(), Some(5));
    assert_eq!(q.pop(), Some(7));
}

#[test]
fn bounded_queue_drain_matching_zero_max_is_a_noop() {
    let q = BoundedQueue::new(4);
    q.try_push(2).unwrap();
    let out = q.drain_matching(0, |_| true);
    assert!(out.is_empty());
    assert_eq!(q.len(), 1);
}

#[test]
fn bounded_queue_drain_matching_calls_pred_once_per_item() {
    // The first-match probe must not re-invoke the predicate on items
    // it already inspected.
    let q = BoundedQueue::new(8);
    for v in [1, 2, 3, 4] {
        q.try_push(v).unwrap();
    }
    let mut seen = Vec::new();
    let taken = q.drain_matching(8, |&v| {
        seen.push(v);
        v % 2 == 0
    });
    assert_eq!(taken, vec![2, 4]);
    assert_eq!(seen, vec![1, 2, 3, 4], "each item inspected exactly once");
    assert_eq!(q.pop(), Some(1));
    assert_eq!(q.pop(), Some(3));
}

#[test]
fn bounded_queue_mpmc_under_contention() {
    let q = Arc::new(BoundedQueue::new(16));
    let produced = 4 * 50;
    let consumed = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|s| {
        for _ in 0..3 {
            let (q, consumed) = (Arc::clone(&q), Arc::clone(&consumed));
            s.spawn(move || {
                while q.pop().is_some() {
                    consumed.fetch_add(1, Ordering::SeqCst);
                }
            });
        }
        for t in 0..4 {
            let q = Arc::clone(&q);
            s.spawn(move || {
                for i in 0..50 {
                    // Spin on backpressure: producers outrun consumers.
                    let mut v = t * 1000 + i;
                    loop {
                        match q.try_push(v) {
                            Ok(_) => break,
                            Err(PushError::Full(back)) => {
                                v = back;
                                std::thread::yield_now();
                            }
                            Err(PushError::Closed(_)) => panic!("closed early"),
                        }
                    }
                }
            });
        }
        // Producers finish, then close so consumers exit.
        while consumed.load(Ordering::SeqCst) + q.len() < produced {
            std::thread::yield_now();
        }
        q.close();
    });
    assert_eq!(consumed.load(Ordering::SeqCst), produced);
}

#[test]
fn semaphore_caps_concurrency() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    let sem = Arc::new(Semaphore::new(2));
    let active = Arc::new(AtomicUsize::new(0));
    let peak = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|s| {
        for _ in 0..8 {
            let (sem, active, peak) =
                (Arc::clone(&sem), Arc::clone(&active), Arc::clone(&peak));
            s.spawn(move || {
                let _p = sem.acquire();
                let now = active.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_millis(5));
                active.fetch_sub(1, Ordering::SeqCst);
            });
        }
    });
    assert!(peak.load(Ordering::SeqCst) <= 2);
    assert_eq!(sem.available(), 2);
}
