//! Face-recognition substitute (Multi-PIE, Fig. 4).
//!
//! The PIE benchmark has 68 identities photographed from four poses
//! (P05, P07, P09, P29) at 32×32 (d = 1024). Offline substitute: each
//! identity gets a fixed latent prototype; each pose applies a fixed
//! *linear* transformation (pose = viewpoint change ≈ linear in pixel
//! space for small rotations) plus illumination gain and noise. Class
//! count, dimensionality, per-domain sizes (3332/1629/1632/1632) and the
//! 12-task grid all match the paper.

use super::{Dataset, DomainPair};
use crate::linalg::Mat;
use crate::rng::Pcg64;

const DIM: usize = 1024;
const NUM_IDENTITIES: usize = 68;
const LATENT: usize = 32;

/// The four PIE domains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PieDomain {
    P05,
    P07,
    P09,
    P29,
}

impl PieDomain {
    pub const ALL: [PieDomain; 4] =
        [PieDomain::P05, PieDomain::P07, PieDomain::P09, PieDomain::P29];

    pub fn name(&self) -> &'static str {
        match self {
            PieDomain::P05 => "pie05",
            PieDomain::P07 => "pie07",
            PieDomain::P09 => "pie09",
            PieDomain::P29 => "pie29",
        }
    }

    /// Paper sample counts.
    pub fn full_size(&self) -> usize {
        match self {
            PieDomain::P05 => 3332,
            PieDomain::P07 => 1629,
            PieDomain::P09 => 1632,
            PieDomain::P29 => 1632,
        }
    }

    fn index(&self) -> usize {
        match self {
            PieDomain::P05 => 0,
            PieDomain::P07 => 1,
            PieDomain::P09 => 2,
            PieDomain::P29 => 3,
        }
    }
}

/// Shared identity prototypes in a latent space (seeded independently of
/// domain so identities correspond across poses).
fn identity_latents(proto_seed: u64) -> Vec<[f64; LATENT]> {
    let mut rng = Pcg64::new(proto_seed);
    (0..NUM_IDENTITIES)
        .map(|_| {
            let mut z = [0.0f64; LATENT];
            for v in z.iter_mut() {
                *v = rng.normal();
            }
            z
        })
        .collect()
}

/// Per-pose projection latent → pixels: a fixed random linear map with a
/// pose-specific rotation mixed in, plus illumination gain.
struct PoseRender {
    proj: Vec<f64>, // DIM × LATENT row-major
    gain: f64,
    noise: f64,
}

fn pose_render(domain: PieDomain, proto_seed: u64) -> PoseRender {
    // Shared base projection + pose-specific perturbation: poses are
    // *related* linear views of the same latent identity.
    let mut base_rng = Pcg64::new(proto_seed ^ 0xFACE);
    let mut base = vec![0.0f64; DIM * LATENT];
    for v in base.iter_mut() {
        *v = base_rng.normal() / (LATENT as f64).sqrt();
    }
    let mut pose_rng = Pcg64::new(proto_seed ^ (0xBEEF + domain.index() as u64));
    let mut proj = base;
    // Pose deviation: 35% of the energy is pose-specific.
    for v in proj.iter_mut() {
        *v = 0.81f64.sqrt() * *v + 0.19f64.sqrt() * pose_rng.normal() / (LATENT as f64).sqrt();
    }
    let gain = [1.0, 0.85, 1.1, 0.75][domain.index()];
    let noise = [0.08, 0.12, 0.1, 0.15][domain.index()];
    PoseRender { proj, gain, noise }
}

/// Generate one PIE-like domain scaled to `scale ∈ (0, 1]` of the paper
/// size (e.g. 0.1 → P05 has 333 samples).
pub fn generate(domain: PieDomain, scale: f64, proto_seed: u64, seed: u64) -> Dataset {
    assert!(scale > 0.0 && scale <= 1.0);
    let samples = ((domain.full_size() as f64 * scale).round() as usize).max(NUM_IDENTITIES);
    let latents = identity_latents(proto_seed);
    let render = pose_render(domain, proto_seed);
    let mut rng = Pcg64::new(seed);
    let mut x = Mat::zeros(samples, DIM);
    let mut labels = Vec::with_capacity(samples);
    for s in 0..samples {
        let id = s % NUM_IDENTITIES;
        labels.push(id);
        // Per-shot latent jitter (expression/illumination conditions).
        let mut z = latents[id];
        for v in z.iter_mut() {
            *v += 0.35 * rng.normal();
        }
        let row = x.row_mut(s);
        for (d, out) in row.iter_mut().enumerate() {
            let mut acc = 0.0;
            let prow = &render.proj[d * LATENT..(d + 1) * LATENT];
            for (p, zv) in prow.iter().zip(&z) {
                acc += p * zv;
            }
            *out = render.gain * acc + render.noise * rng.normal();
        }
    }
    Dataset { name: domain.name().to_string(), x, labels }
}

/// All 12 ordered PIE adaptation tasks at the given scale.
pub fn all_tasks(scale: f64, seed: u64) -> Vec<DomainPair> {
    let mut tasks = Vec::with_capacity(12);
    for (si, &s) in PieDomain::ALL.iter().enumerate() {
        for (ti, &t) in PieDomain::ALL.iter().enumerate() {
            if si == ti {
                continue;
            }
            tasks.push(DomainPair {
                source: generate(s, scale, 0x91E, seed + si as u64),
                target: generate(t, scale, 0x91E, seed + 100 + ti as u64),
            });
        }
    }
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_scale_and_dims() {
        let d = generate(PieDomain::P05, 0.1, 1, 2);
        assert_eq!(d.len(), 333);
        assert_eq!(d.dim(), 1024);
        assert_eq!(d.num_classes(), 68);
        let d7 = generate(PieDomain::P07, 1.0, 1, 2);
        assert_eq!(d7.len(), 1629);
    }

    #[test]
    fn twelve_tasks() {
        let tasks = all_tasks(0.05, 3);
        assert_eq!(tasks.len(), 12);
        // All ordered pairs distinct.
        let names: std::collections::BTreeSet<String> =
            tasks.iter().map(|t| t.task_name()).collect();
        assert_eq!(names.len(), 12);
    }

    #[test]
    fn identities_cluster_across_poses() {
        let a = generate(PieDomain::P05, 0.08, 7, 1);
        let b = generate(PieDomain::P09, 0.16, 7, 9);
        let dist = |i: usize, j: usize| {
            crate::linalg::sub(a.x.row(i), b.x.row(j))
                .iter()
                .map(|v| v * v)
                .sum::<f64>()
        };
        let mut same = (0.0, 0);
        let mut diff = (0.0, 0);
        for i in 0..80.min(a.len()) {
            for j in 0..80.min(b.len()) {
                if a.labels[i] == b.labels[j] {
                    same = (same.0 + dist(i, j), same.1 + 1);
                } else {
                    diff = (diff.0 + dist(i, j), diff.1 + 1);
                }
            }
        }
        assert!(same.1 > 0 && diff.1 > 0);
        let same_mean = same.0 / same.1 as f64;
        let diff_mean = diff.0 / diff.1 as f64;
        assert!(
            same_mean < 0.9 * diff_mean,
            "cross-pose identity structure lost: same={same_mean} diff={diff_mean}"
        );
    }
}
