//! PCG-XSL-RR 128/64 core generator (O'Neill 2014).

/// 128-bit-state PCG generator producing 64-bit outputs.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    pub(super) spare: Option<f64>,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Seed with a 64-bit value (default stream).
    pub fn new(seed: u64) -> Self {
        Self::new_with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Seed with explicit stream selector (must effectively be odd; the
    /// constructor forces the low bit).
    pub fn new_with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc, spare: None };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }
}
