//! Recursive-descent JSON parser.

use super::Value;
use std::collections::BTreeMap;

/// Parse failure with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            map.insert(key, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        s.push(ch);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}
