//! Serving-engine integration: a multi-client concurrency hammer, the
//! warm-vs-cold Theorem-2 invariant, backpressure and deadline
//! semantics, and the TCP wire protocol's new serving fields.

use grpot::coordinator::config::{DatasetSpec, Method};
use grpot::coordinator::metrics::Metrics;
use grpot::coordinator::service::{serve_with, Client};
use grpot::jsonlite::Value;
use grpot::ot::regularizer::RegKind;
use grpot::ot::solve::SolveOptions;
use grpot::serve::{Engine, RejectReason, ServeConfig, SolveRequest};
use grpot::solvers::lbfgs::LbfgsOptions;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};

fn tiny_spec(seed: u64) -> DatasetSpec {
    DatasetSpec {
        family: "synthetic".into(),
        param1: 4,
        param2: 5,
        seed,
        ..Default::default()
    }
}

fn request(seed: u64, gamma: f64, rho: f64) -> SolveRequest {
    SolveRequest {
        spec: tiny_spec(seed),
        gamma,
        rho,
        method: Method::Fast,
        regularizer: RegKind::GroupLasso,
        deadline: None,
        warm_start: true,
    }
}

/// Solver options tight enough that independent solves of the same
/// problem agree to well below the 1e-9 assertion threshold.
fn tight_lbfgs() -> LbfgsOptions {
    LbfgsOptions { max_iters: 4000, ftol: 1e-13, gtol: 1e-8, ..Default::default() }
}

#[test]
fn hammer_no_deadlocks_no_lost_responses() {
    let metrics = Arc::new(Metrics::new());
    let engine = Engine::start(
        ServeConfig { workers: 3, queue_capacity: 256, ..Default::default() },
        Arc::clone(&metrics),
    );
    let clients = 8;
    let per_client = 6;
    let ok = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for c in 0..clients {
            let engine = &engine;
            let ok = &ok;
            s.spawn(move || {
                // Overlapping (γ, ρ) walks: plenty of identical
                // concurrent requests for the batcher to dedupe.
                let gammas = [0.2, 1.0, 5.0];
                let rhos = [0.4, 0.7];
                for k in 0..per_client {
                    let gamma = gammas[(c + k) % gammas.len()];
                    let rho = rhos[k % rhos.len()];
                    let reply = engine
                        .submit(request(3, gamma, rho))
                        .expect("every request must be answered");
                    assert!(reply.result.dual_objective > 0.0);
                    assert!(reply.batch_size >= 1);
                    ok.fetch_add(1, Ordering::SeqCst);
                }
            });
        }
    });
    let total = (clients * per_client) as u64;
    assert_eq!(ok.load(Ordering::SeqCst) as u64, total);
    assert_eq!(metrics.get("serve.requests"), total);
    assert_eq!(metrics.hist_count("serve.latency_seconds"), total);
    // Identical concurrent requests dedupe; repeats warm-start.
    assert!(metrics.get("serve.solves") <= total);
    assert!(metrics.get("serve.warm_hits") > 0, "repeated keys must hit the dual cache");
    assert_eq!(engine.queue_depth(), 0);
    engine.shutdown();
}

#[test]
fn warm_started_solve_matches_cold_dual_objective() {
    let metrics = Arc::new(Metrics::new());
    let engine = Engine::start(
        ServeConfig {
            workers: 2,
            solve: SolveOptions::new().lbfgs(tight_lbfgs()),
            ..Default::default()
        },
        Arc::clone(&metrics),
    );
    // Cold reference: warm starts disabled for this request.
    let mut cold_req = request(11, 0.8, 0.6);
    cold_req.warm_start = false;
    let cold = engine.submit(cold_req).expect("cold solve");
    assert!(!cold.warm_started);

    // Populate the cache, then solve the identical problem warm.
    engine.submit(request(11, 0.8, 0.6)).expect("cache-filling solve");
    let warm = engine.submit(request(11, 0.8, 0.6)).expect("warm solve");
    assert!(warm.warm_started, "second identical solve must warm-start");
    assert!(metrics.get("serve.warm_hits") >= 1);

    // The Theorem-2 invariant survives warm starts: same problem, same
    // dual objective to 1e-9, regardless of the starting iterate.
    let diff = (warm.result.dual_objective - cold.result.dual_objective).abs();
    assert!(
        diff <= 1e-9,
        "warm={} cold={} diff={diff:e}",
        warm.result.dual_objective,
        cold.result.dual_objective
    );
    // Warm starts seed close to the optimum, so they converge in fewer
    // iterations than the cold solve.
    assert!(
        warm.result.iterations <= cold.result.iterations,
        "warm {} vs cold {} iterations",
        warm.result.iterations,
        cold.result.iterations
    );
    engine.shutdown();
}

#[test]
fn engine_clamps_intra_solve_threads_to_core_budget() {
    // workers × threads_per_solve must never exceed the configured core
    // budget: 2 workers under a 4-core budget cap an 8-thread request
    // at 2 threads per solve.
    let capped = Engine::start(
        ServeConfig {
            workers: 2,
            solve: SolveOptions::new().threads(8),
            core_budget: 4,
            ..Default::default()
        },
        Arc::new(Metrics::new()),
    );
    assert_eq!(capped.threads_per_solve(), 2);
    capped.shutdown();

    // A budget already consumed by the workers floors at 1 thread per
    // solve (worker concurrency wins; intra-op parallelism yields).
    let floored = Engine::start(
        ServeConfig {
            workers: 4,
            solve: SolveOptions::new().threads(8),
            core_budget: 2,
            ..Default::default()
        },
        Arc::new(Metrics::new()),
    );
    assert_eq!(floored.threads_per_solve(), 1);
    floored.shutdown();

    // Requests under the budget pass through unclamped.
    let roomy = Engine::start(
        ServeConfig {
            workers: 2,
            solve: SolveOptions::new().threads(3),
            core_budget: 64,
            ..Default::default()
        },
        Arc::new(Metrics::new()),
    );
    assert_eq!(roomy.threads_per_solve(), 3);
    roomy.shutdown();
}

#[test]
fn multithreaded_warm_solves_match_cold_serial() {
    // Reference: cold solve on a serial single-worker engine.
    let serial = Engine::start(
        ServeConfig {
            workers: 1,
            solve: SolveOptions::new().lbfgs(tight_lbfgs()),
            ..Default::default()
        },
        Arc::new(Metrics::new()),
    );
    let mut cold_req = request(77, 0.9, 0.5);
    cold_req.warm_start = false;
    let cold = serial.submit(cold_req.clone()).expect("serial cold solve");
    serial.shutdown();

    // Same request on a multithreaded engine (explicit budget so the
    // clamp can't silently serialize it on small CI machines).
    let threaded = Engine::start(
        ServeConfig {
            workers: 2,
            solve: SolveOptions::new().threads(4).lbfgs(tight_lbfgs()),
            core_budget: 64,
            ..Default::default()
        },
        Arc::new(Metrics::new()),
    );
    assert_eq!(threaded.threads_per_solve(), 4);
    // Cold × threaded is bit-identical to cold × serial: the ordered
    // chunk reduction is deterministic in the thread count.
    let tcold = threaded.submit(cold_req).expect("threaded cold solve");
    assert_eq!(tcold.result.dual_objective, cold.result.dual_objective);
    assert_eq!(tcold.result.x, cold.result.x);
    assert_eq!(tcold.result.iterations, cold.result.iterations);

    // Warm × threaded still lands on the same optimum to 1e-9 (warm
    // starts change the trajectory, never the fixed point — Theorem 2).
    threaded.submit(request(77, 0.9, 0.5)).expect("cache-filling solve");
    let warm = threaded.submit(request(77, 0.9, 0.5)).expect("warm solve");
    assert!(warm.warm_started, "second identical solve must warm-start");
    let diff = (warm.result.dual_objective - cold.result.dual_objective).abs();
    assert!(
        diff <= 1e-9,
        "warm threaded={} cold serial={} diff={diff:e}",
        warm.result.dual_objective,
        cold.result.dual_objective
    );
    threaded.shutdown();
}

#[test]
fn backpressure_rejects_with_structured_error() {
    let engine = Engine::start(
        ServeConfig {
            workers: 1,
            queue_capacity: 1,
            max_batch: 1,
            ..Default::default()
        },
        Arc::new(Metrics::new()),
    );
    let burst = 6;
    let barrier = Barrier::new(burst);
    let ok = AtomicUsize::new(0);
    let full = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..burst {
            let engine = &engine;
            let barrier = &barrier;
            let (ok, full) = (&ok, &full);
            s.spawn(move || {
                barrier.wait();
                match engine.submit(request(21, 1.0, 0.5)) {
                    Ok(_) => {
                        ok.fetch_add(1, Ordering::SeqCst);
                    }
                    Err(RejectReason::QueueFull { capacity }) => {
                        assert_eq!(capacity, 1);
                        full.fetch_add(1, Ordering::SeqCst);
                    }
                    Err(other) => panic!("unexpected rejection: {other}"),
                }
            });
        }
    });
    assert_eq!(ok.load(Ordering::SeqCst) + full.load(Ordering::SeqCst), burst);
    // A simultaneous burst against a single slow worker with a 1-deep
    // queue must shed load…
    assert!(full.load(Ordering::SeqCst) >= 1, "no backpressure seen");
    // …but never drop everyone.
    assert!(ok.load(Ordering::SeqCst) >= 1, "no request served");
    assert_eq!(
        engine.metrics().get("serve.rejected_queue_full"),
        full.load(Ordering::SeqCst) as u64
    );
    engine.shutdown();
}

#[test]
fn service_exposes_serving_protocol_and_metrics() {
    let handle = serve_with(
        "127.0.0.1:0",
        ServeConfig { workers: 2, ..Default::default() },
    )
    .expect("bind");
    let mut c = Client::connect(&handle.addr).expect("connect");

    let solve_req = |warm: bool| {
        Value::obj()
            .set("op", "solve")
            .set(
                "dataset",
                Value::obj()
                    .set("family", "synthetic")
                    .set("param1", 4usize)
                    .set("param2", 5usize)
                    .set("seed", 31usize),
            )
            .set("gamma", 0.5)
            .set("rho", 0.6)
            .set("method", "fast")
            .set("warm_start", warm)
    };

    // First solve: cold, carries the serving fields.
    let first = c.call(&solve_req(true)).expect("solve");
    assert_eq!(first.get("ok").and_then(Value::as_bool), Some(true), "{first}");
    assert_eq!(first.get("warm_started").and_then(Value::as_bool), Some(false));
    assert!(first.get("batch_size").and_then(Value::as_usize).unwrap() >= 1);
    assert!(first.get("queue_wait_s").and_then(Value::as_f64).unwrap() >= 0.0);

    // Second identical solve: warm.
    let second = c.call(&solve_req(true)).expect("solve");
    assert_eq!(second.get("warm_started").and_then(Value::as_bool), Some(true), "{second}");
    let d1 = first.get("dual_objective").and_then(Value::as_f64).unwrap();
    let d2 = second.get("dual_objective").and_then(Value::as_f64).unwrap();
    assert!((d1 - d2).abs() <= 1e-9, "warm TCP solve drifted: {d1} vs {d2}");

    // Expired deadline: structured rejection, not a generic error.
    let expired = c
        .call(&solve_req(true).set("deadline_ms", 0.0))
        .expect("call");
    assert_eq!(expired.get("ok").and_then(Value::as_bool), Some(false), "{expired}");
    assert_eq!(
        expired.get("error_kind").and_then(Value::as_str),
        Some("deadline_exceeded"),
        "{expired}"
    );

    // An absurd deadline is clamped, never a connection-killing panic.
    let huge = c
        .call(&solve_req(true).set("deadline_ms", 1e300))
        .expect("call survives huge deadline");
    assert_eq!(huge.get("ok").and_then(Value::as_bool), Some(true), "{huge}");

    // Metrics op: full serving surface (percentiles, queue depth,
    // rejections, warm hits/misses).
    let m = c.call(&Value::obj().set("op", "metrics")).expect("metrics");
    let counters = [
        "serve.requests",
        "serve.rejected_deadline",
        "serve.warm_hits",
        "serve.warm_misses",
        "serve.solves",
    ];
    for name in counters {
        assert!(
            m.get_path(&["metrics", "counters", name]).is_some(),
            "missing counter {name}: {m}"
        );
    }
    assert!(
        m.get_path(&["metrics", "hists", "serve.latency_seconds", "p50"]).is_some(),
        "missing latency p50: {m}"
    );
    assert!(
        m.get_path(&["metrics", "hists", "serve.latency_seconds", "p99"]).is_some(),
        "missing latency p99: {m}"
    );
    assert!(
        m.get_path(&["metrics", "gauges", "serve.queue_depth"]).is_some(),
        "missing queue depth gauge: {m}"
    );
    assert!(
        m.get_path(&["metrics", "counters", "serve.rejected_deadline"])
            .and_then(Value::as_usize)
            .unwrap()
            >= 1
    );
    handle.shutdown();
}

#[test]
fn batching_dedupes_identical_queued_requests() {
    // One worker + a barrier burst of identical requests: whatever the
    // interleaving, responses must be complete and solves must not
    // exceed the number of distinct arrival waves (requests ≥ solves).
    let metrics = Arc::new(Metrics::new());
    let engine = Engine::start(
        ServeConfig { workers: 1, queue_capacity: 64, ..Default::default() },
        Arc::clone(&metrics),
    );
    let burst = 6;
    let barrier = Barrier::new(burst);
    std::thread::scope(|s| {
        for _ in 0..burst {
            let engine = &engine;
            let barrier = &barrier;
            s.spawn(move || {
                barrier.wait();
                let reply = engine.submit(request(41, 2.0, 0.5)).expect("answered");
                assert!(reply.result.dual_objective > 0.0);
            });
        }
    });
    let solves = metrics.get("serve.solves");
    assert!(solves >= 1 && solves <= burst as u64, "solves={solves}");
    // All six were identical; at least the ones queued behind the first
    // batch share a solve whenever any batching happened at all.
    assert_eq!(metrics.get("serve.requests"), burst as u64);
    engine.shutdown();
}
