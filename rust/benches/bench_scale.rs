//! Cost-backend memory scaling: the factored backend's reason to exist.
//!
//! Sweeps problem sizes and reports, per size, the resident bytes of
//! the dense cost representation (the n×m matrix — doubled again by the
//! SIMD tile pack on the vector path) against the factored
//! representation (coordinates + squared norms, O((m+n)·d)), then runs
//! the headline experiment: the largest size is solved **factored only**
//! under a memory budget the dense backend provably cannot satisfy. In
//! full mode the headline problem has n·m ≥ 10⁸ cost entries (m = n =
//! 10⁴: a 1.6 GB dense footprint with the pack, ~0.5 MB factored)
//! against a 256 MiB budget; quick and smoke modes scale the sizes and
//! the budget down but keep every relational assertion.
//!
//! At the smallest size of each sweep, both backends are built and
//! solved and the results asserted byte-equal — the integration-level
//! mirror of `tests/cost_equivalence.rs`, so the speed/memory rows and
//! the equivalence guarantee come from the same binary.

mod common;

use common::*;
use grpot::benchlib::{report_dir, Table, Timer};
use grpot::data::synthetic;
use grpot::ot::cost::CostMode;
use grpot::ot::dual::OtProblem;
use grpot::ot::fastot::{solve_fast_ot, FastOtConfig, FastOtResult};
use grpot::simd::SimdMode;
use grpot::solvers::lbfgs::LbfgsOptions;

fn solve(prob: &OtProblem) -> FastOtResult {
    let cfg = FastOtConfig {
        gamma: 0.5,
        rho: 0.6,
        threads: size3(2, 4, 4),
        simd: SimdMode::Auto,
        lbfgs: LbfgsOptions { max_iters: size3(5, 10, 15), ..Default::default() },
        ..Default::default()
    };
    solve_fast_ot(prob, &cfg)
}

/// Resident bytes of the dense backend at a given shape: the n×m
/// matrix, plus the packed-tile copy the vector dispatch builds on
/// first use. Computed analytically so the sweep can report sizes this
/// machine could never materialize; validated against a real build at
/// the smallest size.
fn dense_resident_bytes(m: usize, n: usize) -> u128 {
    2 * 8 * m as u128 * n as u128
}

fn human(bytes: u128) -> String {
    if bytes >= 1 << 30 {
        format!("{:.2} GiB", bytes as f64 / (1u64 << 30) as f64)
    } else if bytes >= 1 << 20 {
        format!("{:.2} MiB", bytes as f64 / (1u64 << 20) as f64)
    } else {
        format!("{:.1} KiB", bytes as f64 / 1024.0)
    }
}

fn main() {
    banner("cost-backend memory scaling");
    // (|L|, g) per sweep point; m = n = |L|·g, d = 2. The last entry is
    // the headline size: full mode m = n = 10⁴ ⇒ n·m = 10⁸.
    let sizes: Vec<(usize, usize)> = size3(
        vec![(4, 10), (8, 15), (24, 10)],
        vec![(10, 10), (25, 20), (50, 40)],
        vec![(25, 40), (50, 100), (100, 100)],
    );
    // The budget the headline solve must fit under — and the dense
    // backend must not.
    let budget: u128 = size3(256 << 10, 16 << 20, 256 << 20);

    let mut table = Table::new(
        "cost-backend memory scaling",
        &["m", "n", "entries", "dense_bytes", "factored_bytes", "ratio", "t_factored[s]", "tiles_built"],
    );
    let mut headline: Option<(OtProblem, u128)> = None;
    for (idx, &(l, g)) in sizes.iter().enumerate() {
        let pair = synthetic::controlled(l, g, 0x5CA1E + idx as u64);
        let timer = Timer::start();
        let fact = OtProblem::try_from_dataset_mode(&pair, CostMode::Factored)
            .expect("factored build");
        let build_s = timer.elapsed_s();
        let (m, n) = (fact.m(), fact.n());
        let dense_bytes = dense_resident_bytes(m, n);
        let fact_bytes = fact.cost_bytes() as u128;
        assert!(
            fact_bytes < dense_bytes,
            "factored must be resident-smaller at every size"
        );

        if idx == 0 {
            // Ground the analytic dense figure and the equivalence claim
            // on a real dense build at the one size where that is cheap.
            let dense = OtProblem::try_from_dataset_mode(&pair, CostMode::Dense)
                .expect("dense build");
            assert_eq!(dense.cost_bytes() as u128 * 2, dense_bytes, "analytic model drifted");
            let rd = solve(&dense);
            let rf = solve(&fact);
            assert_eq!(rd.x, rf.x, "backends diverged on the smallest sweep size");
            assert_eq!(rd.dual_objective, rf.dual_objective);
            println!("equivalence check at m={m} n={n}: ok");
        }

        let timer = Timer::start();
        let res = solve(&fact);
        let solve_s = timer.elapsed_s();
        assert!(res.dual_objective.is_finite());
        println!(
            "m={m:>6} n={n:>6} dense={:>10} factored={:>9} ratio={:>8.0}x build={build_s:.3}s \
             solve={solve_s:.3}s tiles_built={}",
            human(dense_bytes),
            human(fact_bytes),
            dense_bytes as f64 / fact_bytes as f64,
            res.stats.tiles_built,
        );
        table.row(vec![
            format!("{m}"),
            format!("{n}"),
            format!("{}", m as u128 * n as u128),
            format!("{dense_bytes}"),
            format!("{fact_bytes}"),
            format!("{:.0}", dense_bytes as f64 / fact_bytes as f64),
            format!("{solve_s:.4}"),
            format!("{}", res.stats.tiles_built),
        ]);
        if idx == sizes.len() - 1 {
            headline = Some((fact, dense_bytes));
        }
    }

    // The headline claim: at the largest size the dense representation
    // busts the budget while the factored problem — already built and
    // solved above — fits with room to spare.
    let (fact, dense_bytes) = headline.expect("non-empty sweep");
    let entries = fact.m() as u128 * fact.n() as u128;
    assert!(
        dense_bytes > budget,
        "dense {} must exceed the {} budget",
        human(dense_bytes),
        human(budget)
    );
    assert!(
        (fact.cost_bytes() as u128) < budget,
        "factored {} must fit the {} budget",
        human(fact.cost_bytes() as u128),
        human(budget)
    );
    if !grpot::benchlib::smoke_mode() && !grpot::benchlib::quick_mode() {
        assert!(entries >= 100_000_000, "full-mode headline must reach n·m ≥ 10⁸");
    }
    println!(
        "headline: n·m = {entries} cost entries solved factored under a {} budget \
         (dense would need {})",
        human(budget),
        human(dense_bytes),
    );
    table.emit(&report_dir(), "bench_scale");
}
