//! Thread-pool substrate (tokio/rayon are unavailable offline).
//!
//! Two facilities:
//!
//! * [`ThreadPool`] — a fixed pool of workers consuming boxed jobs from a
//!   shared channel; used by the coordinator's sweep scheduler and the
//!   TCP service.
//! * [`parallel_for_chunks`] — fork-join data parallelism over an index
//!   range using `std::thread::scope`; used off the solver's hot path
//!   (dataset generation, evaluation) so single-solver benchmarks remain
//!   one-core, matching the paper's single-CPU-core setup.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool. Jobs are executed FIFO; `join` blocks until
/// every submitted job has finished. Dropping the pool joins workers.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl ThreadPool {
    /// Create a pool with `n` workers (n ≥ 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "pool needs at least one worker");
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                thread::Builder::new()
                    .name(format!("grpot-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => {
                                job();
                                let (lock, cvar) = &*pending;
                                let mut p = lock.lock().unwrap();
                                *p -= 1;
                                if *p == 0 {
                                    cvar.notify_all();
                                }
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, pending }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(job))
            .expect("worker channel closed");
    }

    /// Block until all submitted jobs have completed.
    pub fn join(&self) {
        let (lock, cvar) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cvar.wait(p).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit their loop
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Counting semaphore (std has none on stable): used by the TCP service
/// to cap concurrent solves while connections run thread-per-socket.
pub struct Semaphore {
    state: Mutex<usize>,
    cvar: std::sync::Condvar,
}

/// RAII permit; releases on drop.
pub struct SemaphorePermit<'a> {
    sem: &'a Semaphore,
}

impl Semaphore {
    pub fn new(permits: usize) -> Self {
        assert!(permits > 0);
        Semaphore { state: Mutex::new(permits), cvar: std::sync::Condvar::new() }
    }

    /// Block until a permit is available.
    pub fn acquire(&self) -> SemaphorePermit<'_> {
        let mut avail = self.state.lock().unwrap();
        while *avail == 0 {
            avail = self.cvar.wait(avail).unwrap();
        }
        *avail -= 1;
        SemaphorePermit { sem: self }
    }

    /// Current free permits (diagnostics).
    pub fn available(&self) -> usize {
        *self.state.lock().unwrap()
    }
}

impl Drop for SemaphorePermit<'_> {
    fn drop(&mut self) {
        let mut avail = self.sem.state.lock().unwrap();
        *avail += 1;
        self.sem.cvar.notify_one();
    }
}

/// Run `body(chunk_start, chunk_end)` over `0..n` split into contiguous
/// chunks across `threads` scoped threads. `body` must be `Sync`-safe via
/// captured shared state; results are typically written to disjoint
/// slices by the caller.
pub fn parallel_for_chunks<F>(n: usize, threads: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        body(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let body = &body;
            s.spawn(move || body(lo, hi));
        }
    });
}

/// Dynamic work-stealing-ish variant: threads atomically grab blocks of
/// `block` indices until the range is exhausted. Better for ragged work
/// (e.g. sweep jobs with very different solve times).
pub fn parallel_for_dynamic<F>(n: usize, threads: usize, block: usize, body: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 || n <= block {
        for i in 0..n {
            body(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..threads {
            let next = &next;
            let body = &body;
            s.spawn(move || loop {
                let start = next.fetch_add(block, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for i in start..(start + block).min(n) {
                    body(i);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests;
