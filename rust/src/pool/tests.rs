use super::*;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

#[test]
fn pool_runs_all_jobs() {
    let pool = ThreadPool::new(4);
    let counter = Arc::new(AtomicUsize::new(0));
    for _ in 0..100 {
        let c = Arc::clone(&counter);
        pool.execute(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
    }
    pool.join();
    assert_eq!(counter.load(Ordering::SeqCst), 100);
}

#[test]
fn pool_join_then_more_jobs() {
    let pool = ThreadPool::new(2);
    let counter = Arc::new(AtomicUsize::new(0));
    for round in 0..3 {
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), (round + 1) * 10);
    }
}

#[test]
fn pool_drop_joins() {
    let counter = Arc::new(AtomicUsize::new(0));
    {
        let pool = ThreadPool::new(3);
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_micros(100));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
    }
    assert_eq!(counter.load(Ordering::SeqCst), 50);
}

#[test]
fn parallel_chunks_cover_range_disjointly() {
    let n = 1003;
    let data: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
    parallel_for_chunks(n, 7, |lo, hi| {
        for i in lo..hi {
            data[i].fetch_add(1, Ordering::Relaxed);
        }
    });
    assert!(data.iter().all(|c| c.load(Ordering::Relaxed) == 1));
}

#[test]
fn parallel_chunks_single_thread_and_empty() {
    let hit = AtomicUsize::new(0);
    parallel_for_chunks(10, 1, |lo, hi| {
        hit.fetch_add(hi - lo, Ordering::Relaxed);
    });
    assert_eq!(hit.load(Ordering::Relaxed), 10);
    parallel_for_chunks(0, 4, |_, _| {});
}

#[test]
fn parallel_dynamic_covers_all() {
    let n = 517;
    let data: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
    parallel_for_dynamic(n, 5, 8, |i| {
        data[i].fetch_add(1, Ordering::Relaxed);
    });
    assert!(data.iter().all(|c| c.load(Ordering::Relaxed) == 1));
}

#[test]
fn pool_size() {
    assert_eq!(ThreadPool::new(3).size(), 3);
}

#[test]
fn semaphore_caps_concurrency() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    let sem = Arc::new(Semaphore::new(2));
    let active = Arc::new(AtomicUsize::new(0));
    let peak = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|s| {
        for _ in 0..8 {
            let (sem, active, peak) =
                (Arc::clone(&sem), Arc::clone(&active), Arc::clone(&peak));
            s.spawn(move || {
                let _p = sem.acquire();
                let now = active.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_millis(5));
                active.fetch_sub(1, Ordering::SeqCst);
            });
        }
    });
    assert!(peak.load(Ordering::SeqCst) <= 2);
    assert_eq!(sem.available(), 2);
}
