//! Offline stub of the `xla` bindings crate (the xla-rs API subset the
//! `grpot` runtime uses).
//!
//! Purpose: `cargo build --features xla` must *compile* in a
//! network-less image that cannot fetch the real bindings crate or the
//! `xla_extension` shared library. Every runtime entry point returns a
//! [`Error`] explaining how to swap in the real thing: repoint the
//! `xla` path dependency in `rust/Cargo.toml` at an xla-rs checkout and
//! rebuild.
//!
//! The types mirror xla-rs names and signatures exactly where `grpot`
//! touches them ([`PjRtClient`], [`PjRtLoadedExecutable`],
//! [`PjRtBuffer`], [`Literal`], [`HloModuleProto`], [`XlaComputation`]);
//! nothing else is provided. Because [`PjRtClient::cpu`] already fails,
//! no stubbed execution path is reachable in practice.

use std::borrow::Borrow;
use std::fmt;

/// Stub error carrying the "this is not the real runtime" message.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias.
pub type Result<T> = std::result::Result<T, Error>;

fn stub_err<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla stub: {what} is unavailable — this build links the in-tree stub of the `xla` \
         bindings (rust/xla-stub). Point the `xla` path dependency in rust/Cargo.toml at a \
         real xla-rs checkout (with libxla_extension) and rebuild with `--features xla` to \
         enable the PJRT runtime"
    )))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        stub_err("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err("PjRtClient::compile")
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        stub_err("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Compiled executable (stub: never obtainable, execution always fails).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err("PjRtBuffer::to_literal_sync")
    }
}

/// Host literal. Constructors succeed (they are called before any
/// fallible PJRT interaction); accessors fail.
#[derive(Clone)]
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f64]) -> Literal {
        Literal(())
    }

    pub fn scalar(_value: f64) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal(()))
    }

    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        stub_err("Literal::to_tuple3")
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        stub_err("Literal::get_first_element")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        stub_err("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_runtime_entry_errors_with_pointer() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(e.to_string().contains("xla stub"), "{e}");
        assert!(e.to_string().contains("rust/xla-stub"), "{e}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_ok());
        assert!(lit.clone().to_tuple3().is_err());
        assert!(lit.get_first_element::<f64>().is_err());
        assert!(lit.to_vec::<f64>().is_err());
        let _ = Literal::scalar(0.5);
    }
}
