use super::*;

#[test]
fn parse_scalars() {
    assert_eq!(parse("null").unwrap(), Value::Null);
    assert_eq!(parse("true").unwrap(), Value::Bool(true));
    assert_eq!(parse("false").unwrap(), Value::Bool(false));
    assert_eq!(parse("42").unwrap(), Value::Num(42.0));
    assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
    assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
}

#[test]
fn parse_nested() {
    let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
    assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    let arr = v.get("a").unwrap().as_arr().unwrap();
    assert_eq!(arr[0].as_f64(), Some(1.0));
    assert_eq!(arr[2].get("b"), Some(&Value::Null));
    assert_eq!(v.get_path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
}

#[test]
fn parse_escapes_and_unicode() {
    let v = parse(r#""a\nb\t\"c\" é 😀""#).unwrap();
    assert_eq!(v.as_str(), Some("a\nb\t\"c\" é 😀"));
    // Raw multibyte passthrough
    let v = parse("\"héllo\"").unwrap();
    assert_eq!(v.as_str(), Some("héllo"));
}

#[test]
fn roundtrip() {
    let orig = Value::obj()
        .set("name", "fig2")
        .set("gain", 6.8)
        .set("classes", vec![10usize, 20, 40])
        .set("ok", true)
        .set("nested", Value::obj().set("x", Value::Null));
    let text = orig.to_json();
    let back = parse(&text).unwrap();
    assert_eq!(back, orig);
}

#[test]
fn roundtrip_numbers_precisely() {
    for x in [0.0, 1.0, -1.5, 1e-9, 123456789.0, 0.1, 2.0_f64.powi(52)] {
        let t = Value::Num(x).to_json();
        assert_eq!(parse(&t).unwrap().as_f64(), Some(x), "text={t}");
    }
}

#[test]
fn errors_carry_position() {
    let e = parse("{\"a\": }").unwrap_err();
    assert!(e.pos > 0);
    assert!(parse("[1, 2").is_err());
    assert!(parse("").is_err());
    assert!(parse("[1] extra").is_err());
    assert!(parse("{'single': 1}").is_err());
}

#[test]
fn accessors() {
    let v = parse(r#"{"n": 3, "xs": [1.5, 2.5], "flag": false}"#).unwrap();
    assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
    assert_eq!(v.get("xs").unwrap().as_f64_vec(), Some(vec![1.5, 2.5]));
    assert_eq!(v.get("flag").unwrap().as_bool(), Some(false));
    assert_eq!(v.get("missing"), None);
    assert_eq!(v.get("n").unwrap().as_str(), None);
    assert_eq!(Value::Num(1.5).as_usize(), None);
    assert_eq!(Value::Num(-2.0).as_usize(), None);
}

#[test]
fn nan_serializes_as_null() {
    assert_eq!(Value::Num(f64::NAN).to_json(), "null");
}

#[test]
fn deterministic_key_order() {
    let v = Value::obj().set("z", 1usize).set("a", 2usize);
    assert_eq!(v.to_json(), r#"{"a":2,"z":1}"#);
}
