//! Figure 3: gain on the two digit-recognition adaptation tasks
//! (USPS→MNIST and MNIST→USPS). Paper: up to 8.6× with 5000 samples per
//! domain; we default to 600 (quick) / 1500 (full) samples — the gain
//! *shape over γ* is the reproduction target, not the absolute factor.

mod common;

use common::*;
use grpot::data::digits;

fn main() {
    banner("fig3: digit adaptation tasks");
    let samples = size3(40, 600, 1500);
    let gammas = gamma_grid();
    let rhos = rho_grid();

    let mut blocks = Vec::new();
    for pair in digits::all_tasks(samples, 0xF163) {
        let prob = problem_of(&pair);
        println!("task {} (m=n={}) …", pair.task_name(), prob.m());
        let rows = gain_sweep(&prob, &gammas, &rhos, 10);
        for r in &rows {
            println!("  gamma={:<8} gain={:.2}x", r.gamma, r.gain);
            assert!(r.objectives_match);
        }
        blocks.push((pair.task_name(), rows));
    }
    emit_gain_table(
        "Fig. 3 — processing-time gain on digit recognition tasks",
        "fig3_digits",
        &blocks,
    );
}
