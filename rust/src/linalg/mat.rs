//! Row-major dense matrix.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major `f64` matrix.
///
/// Rows are contiguous: element `(i, j)` lives at `data[i * cols + j]`.
/// The OT hot path iterates over *columns* of the cost matrix, so cost
/// matrices are stored transposed (`n x m`) by the callers that need
/// column access; see [`crate::ot::dual::OtProblem`].
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with `v`.
    pub fn full(rows: usize, cols: usize, v: f64) -> Self {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    /// Build from an existing row-major buffer.
    ///
    /// # Panics
    /// If `data.len() != rows * cols`.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Mat { rows, cols, data }
    }

    /// Build row by row from a function of `(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Identity-like square matrix of size `n`.
    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Contiguous row slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable contiguous row slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column copied into a fresh vector (columns are strided).
    pub fn col_to_vec(&self, j: usize) -> Vec<f64> {
        assert!(j < self.cols);
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Raw row-major buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw row-major buffer.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the row-major buffer.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Tile side of the blocked [`Mat::transpose`]: 32×32 `f64` tiles
    /// are 8 KiB read + 8 KiB written — both sides stay L1-resident, so
    /// the strided writes hit cache lines that were just loaded instead
    /// of streaming the full destination once per source row.
    const TRANSPOSE_TILE: usize = 32;

    /// Out-of-place transpose, blocked into 32×32 tiles.
    /// Element-for-element identical to the naive double loop (it is a
    /// pure permutation); only the traversal order — and therefore the
    /// cache behaviour on the large `cost_t` builds — changes.
    pub fn transpose(&self) -> Mat {
        const B: usize = Mat::TRANSPOSE_TILE;
        let mut out = Mat::zeros(self.cols, self.rows);
        for ib in (0..self.rows).step_by(B) {
            let imax = (ib + B).min(self.rows);
            for jb in (0..self.cols).step_by(B) {
                let jmax = (jb + B).min(self.cols);
                for i in ib..imax {
                    let r = self.row(i);
                    for j in jb..jmax {
                        out.data[j * self.rows + i] = r[j];
                    }
                }
            }
        }
        out
    }

    /// The unblocked reference transpose (tests cross-check the tiled
    /// path against it; not used on any hot path).
    #[doc(hidden)]
    pub fn transpose_naive(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            let r = self.row(i);
            for (j, &v) in r.iter().enumerate() {
                out.data[j * self.rows + i] = v;
            }
        }
        out
    }

    /// Matrix-vector product `self * x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows).map(|i| super::dot(self.row(i), x)).collect()
    }

    /// Transposed matrix-vector product `selfᵀ * x`.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for (o, &v) in out.iter_mut().zip(self.row(i)) {
                *o += xi * v;
            }
        }
        out
    }

    /// Dense matrix product `self * rhs` (used only off the hot path:
    /// barycentric mapping, tests).
    pub fn matmul(&self, rhs: &Mat) -> Mat {
        assert_eq!(self.cols, rhs.rows, "inner dimension mismatch");
        let mut out = Mat::zeros(self.rows, rhs.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let rrow = rhs.row(k);
                let orow = out.row_mut(i);
                for (o, &b) in orow.iter_mut().zip(rrow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Sum over every element.
    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    /// Row sums (length `rows`).
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.rows).map(|i| self.row(i).iter().sum()).collect()
    }

    /// Column sums (length `cols`).
    pub fn col_sums(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            for (o, &v) in out.iter_mut().zip(self.row(i)) {
                *o += v;
            }
        }
        out
    }

    /// Max absolute element (0 for empty).
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
    }

    /// Count of elements with `|v| > tol`.
    pub fn count_nonzero(&self, tol: f64) -> usize {
        self.data.iter().filter(|v| v.abs() > tol).count()
    }

    /// Element-wise map into a new matrix.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }

    /// Frobenius inner product `⟨self, rhs⟩`.
    pub fn frobenius_dot(&self, rhs: &Mat) -> f64 {
        assert_eq!(self.shape(), rhs.shape());
        super::dot(&self.data, &rhs.data)
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show_rows = self.rows.min(8);
        for i in 0..show_rows {
            let r = self.row(i);
            let shown: Vec<String> =
                r.iter().take(8).map(|v| format!("{v:.4}")).collect();
            let ell = if self.cols > 8 { ", …" } else { "" };
            writeln!(f, "  [{}{}]", shown.join(", "), ell)?;
        }
        if self.rows > show_rows {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}
