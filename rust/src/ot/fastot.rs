//! Algorithm 1 — the outer driver: blocks of `r` L-BFGS iterations
//! interleaved with working-set construction and snapshot refreshes.
//!
//! The same driver runs the dense baseline (whose `refresh` is a no-op),
//! so "ours" and "origin" execute an identical L-BFGS call sequence and
//! Theorem 2 (identical trajectory, objective and solution) is directly
//! observable in tests and benchmarks.

use super::cost::CostMode;
use super::dual::{DualOracle, DualParams, OracleStats, OtProblem};
use super::regularizer::{AnyRegularizer, DenseRegOracle, Regularizer};
use super::screening::ScreeningOracle;
use super::solve::SolveOptions;
use crate::err;
use crate::error::Result;
use crate::obs::report::skipped_fraction;
use crate::obs::{names, ObserverHook, RoundTelemetry, Span};
use crate::pool::ParallelCtx;
use crate::simd::SimdMode;
use crate::solvers::lbfgs::{Lbfgs, LbfgsOptions};
use crate::solvers::{StepStatus, StopReason};
use std::time::Instant;

/// Configuration for the fast OT solve (and for the baseline driven
/// through the same loop).
#[derive(Clone, Debug)]
pub struct FastOtConfig {
    /// Overall regularization strength γ.
    pub gamma: f64,
    /// Quadratic/group balance ρ ∈ [0, 1).
    pub rho: f64,
    /// Snapshot interval `r` in solver iterations (paper: 10).
    pub r: usize,
    /// Enable the lower-bound working set ℕ (the paper's second idea).
    pub use_working_set: bool,
    /// Intra-solve oracle workers for the column-parallel hot loops
    /// (eval, snapshot refresh, working-set rebuild). Deterministic:
    /// results are bit-identical for every value, including the
    /// paper-faithful single-core default of 1. Workers are spawned
    /// once per solve (persistent parked set inside the oracle's
    /// [`crate::pool::ParallelCtx`]); callers that solve repeatedly
    /// should pass a long-lived ctx via
    /// [`crate::ot::solve::SolveOptions::ctx`] instead, which this
    /// field then defers to.
    pub threads: usize,
    /// SIMD policy for the oracle kernels: `Auto` (default) runtime-
    /// dispatches to AVX2 when the CPU supports it (portable lane
    /// mirror otherwise); `Scalar` forces the reference scalar kernels.
    /// Results are byte-equal either way (`tests/simd_equivalence.rs`);
    /// only the wall clock moves. The `GRPOT_SIMD` environment
    /// variable, when set, replaces the `Auto` default; an explicit
    /// `Scalar`/`Portable` here wins over the env var.
    pub simd: SimdMode,
    /// Inner solver options.
    pub lbfgs: LbfgsOptions,
    /// Telemetry observer: invoked once with the finished
    /// [`crate::obs::SolveReport`]. Telemetry is assembled from counters
    /// the solve already maintains, so `None` (the default) and `Some`
    /// produce byte-identical solver results.
    pub observer: Option<ObserverHook>,
    /// Request trace ID stamped on this solve's spans and report (0
    /// outside the serving path).
    pub trace_id: u64,
    /// Cooperative cancellation token polled once per L-BFGS iteration
    /// **and** once per column chunk inside every oracle evaluation (one
    /// relaxed load each — sub-eval granularity, so a cancelled huge
    /// solve stops within one chunk, not one full O(mn) eval). `None`
    /// (the default) skips the checks entirely; an armed but uncancelled
    /// token is byte-identical to a token-free run. On cancellation the
    /// driver stops at the next iteration boundary with
    /// [`StopReason::Cancelled`] — a cancelled result is never treated
    /// as converged (a mid-eval cancellation leaves the final partial
    /// L-BFGS step meaningless, which is why `Cancelled` results are
    /// never cached or warm-start seeds).
    pub cancel: Option<crate::fault::CancelToken>,
    /// Cost-matrix backend selection. Consumed at *problem construction*
    /// ([`OtProblem::from_dataset_mode`] /
    /// [`OtProblem::try_from_points`]), not by the solve itself — the
    /// problem already carries its backend by the time an oracle sees
    /// it. Carried here so [`SolveOptions::fastot_config`] preserves the
    /// full option surface for serving/sweep consumers that build
    /// problems from one config struct.
    pub cost: CostMode,
    /// Per-chunk factored-cost tile-ring budget in KiB (`None` defers
    /// to `GRPOT_TILE_RING_KIB`, else the fixed ~1 MiB default). Moves
    /// only tile retention/`tiles_built`, never solver output.
    pub tile_ring_kib: Option<usize>,
}

impl Default for FastOtConfig {
    fn default() -> Self {
        FastOtConfig {
            gamma: 1.0,
            rho: 0.5,
            r: 10,
            use_working_set: true,
            threads: 1,
            simd: SimdMode::Auto,
            lbfgs: LbfgsOptions::default(),
            observer: None,
            trace_id: 0,
            cancel: None,
            cost: CostMode::Auto,
            tile_ring_kib: None,
        }
    }
}

impl FastOtConfig {
    pub fn params(&self) -> DualParams {
        DualParams::new(self.gamma, self.rho)
    }
}

/// Outcome of a dual solve.
#[derive(Clone, Debug)]
pub struct FastOtResult {
    /// Dual variables `[α; β]` (source part in sorted/grouped order).
    pub x: Vec<f64>,
    /// The (positive) dual objective of Problem 4 at `x`.
    pub dual_objective: f64,
    /// L-BFGS iterations performed.
    pub iterations: usize,
    /// Outer (snapshot) rounds — the paper's `s_r`.
    pub outer_rounds: usize,
    /// Why the solver stopped.
    pub stop: StopReason,
    /// Oracle counters (gradient computations, skips, …).
    pub stats: OracleStats,
    /// Wall-clock seconds of the whole solve.
    pub wall_time_s: f64,
    /// Method label ("fast", "fast-nows", "origin", "xla-origin").
    pub method: String,
}

impl FastOtResult {
    /// Split the solution into (α, β) given the problem.
    pub fn alpha_beta(&self, prob: &OtProblem) -> (&[f64], &[f64]) {
        self.x.split_at(prob.m())
    }
}

/// Drive any oracle through the Algorithm-1 loop from `x = 0`.
pub fn drive(
    prob: &OtProblem,
    cfg: &FastOtConfig,
    oracle: &mut dyn DualOracle,
    method: &str,
) -> FastOtResult {
    drive_from(prob, cfg, oracle, method, vec![0.0; prob.dim()])
}

/// Drive any oracle through the Algorithm-1 loop from an arbitrary
/// starting iterate `x0` — the serving engine's warm-start entry point.
///
/// The screening bounds are *safe from any point* (Theorem 2 makes no
/// assumption about the starting iterate), so warm-started screened and
/// dense solves still follow bit-identical trajectories. For a nonzero
/// `x0` the oracle snapshots are refreshed at `x0` first so the bounds
/// start tight there instead of at the `x = 0` construction point; with
/// `x0 = 0` the call sequence is byte-identical to [`drive`].
pub fn drive_from(
    prob: &OtProblem,
    cfg: &FastOtConfig,
    oracle: &mut dyn DualOracle,
    method: &str,
    x0: Vec<f64>,
) -> FastOtResult {
    assert!(cfg.r >= 1, "snapshot interval must be >= 1");
    assert_eq!(x0.len(), prob.dim(), "warm-start iterate has wrong dimension");
    let start = Instant::now();
    // Telemetry reads counters the solve maintains anyway; with no
    // observer nothing below allocates or branches per iteration.
    let observing = cfg.observer.is_some();
    let pool_at_start =
        if observing { oracle.parallel_ctx().map(|c| c.pool_stats()) } else { None };
    let counters = |s: &OracleStats| (s.grads_computed, s.grads_skipped, s.ub_checks, s.ws_hits);
    let mut prev = counters(oracle.stats());
    let mut rounds: Vec<RoundTelemetry> = Vec::new();
    let round_delta = |oracle: &dyn DualOracle,
                       prev: &mut (u64, u64, u64, u64),
                       rounds: &mut Vec<RoundTelemetry>| {
        let cur = counters(oracle.stats());
        rounds.push(RoundTelemetry {
            round: rounds.len() as u32 + 1,
            grads_computed: cur.0 - prev.0,
            grads_skipped: cur.1 - prev.1,
            ub_checks: cur.2 - prev.2,
            ws_hits: cur.3 - prev.3,
            ws_density: oracle.working_set_density(),
        });
        *prev = cur;
    };
    let _solve_span = Span::start_full(names::SOLVE, cfg.trace_id);
    if x0.iter().any(|&v| v != 0.0) {
        oracle.refresh(&x0);
    }
    let mut solver = Lbfgs::new(x0, cfg.lbfgs.clone(), oracle);
    let mut outer_rounds = 0usize;
    let stop = 'outer: loop {
        let _round_span = Span::start_full(names::OUTER_ROUND, cfg.trace_id);
        for _ in 0..cfg.r {
            // Cancellation checkpoint: a plain Option test when no
            // token is attached, one relaxed load when one is. Checked
            // before the step so an expired deadline never pays for
            // another oracle evaluation.
            if cfg.cancel.as_ref().is_some_and(|t| t.is_cancelled()) {
                break 'outer StopReason::Cancelled;
            }
            // The driver has no error channel; an `err` failpoint here
            // escalates to a panic that the serving engine's unwind
            // guard turns into a structured failure.
            if let Err(e) = crate::fault::check(crate::fault::sites::ORACLE_EVAL) {
                panic!("{e}");
            }
            match solver.step(oracle) {
                StepStatus::Continue => {}
                StepStatus::Stopped(reason) => break 'outer reason,
            }
        }
        // Algorithm 1, lines 4–15.
        oracle.refresh(solver.x());
        outer_rounds += 1;
        if observing {
            round_delta(&*oracle, &mut prev, &mut rounds);
        }
    };
    let iterations = solver.iterations();
    let (x, f) = solver.into_solution();
    let stats = oracle.stats().clone();
    let wall_time_s = start.elapsed().as_secs_f64();
    if let Some(hook) = &cfg.observer {
        // The terminal (partial) round, if any counters moved since the
        // last refresh.
        if counters(&stats) != prev {
            round_delta(&*oracle, &mut prev, &mut rounds);
        }
        let report = crate::obs::SolveReport {
            method: method.to_string(),
            trace_id: cfg.trace_id,
            stop: stop.name(),
            iterations,
            outer_rounds,
            evals: stats.evals,
            // One eval seeds L-BFGS and each iteration needs one; the
            // rest are line-search backtracks.
            line_search_evals: stats.evals.saturating_sub(iterations as u64 + 1),
            grads_computed: stats.grads_computed,
            grads_skipped: stats.grads_skipped,
            ub_checks: stats.ub_checks,
            ws_hits: stats.ws_hits,
            tiles_built: stats.tiles_built,
            // Same counters FastOtResult.stats carries — the report and
            // the result agree byte-for-byte by construction.
            skipped_group_fraction: skipped_fraction(stats.grads_computed, stats.grads_skipped),
            simd_backend: oracle.simd_dispatch().map(|d| d.name()).unwrap_or("scalar"),
            rounds,
            pool: match (oracle.parallel_ctx(), pool_at_start) {
                (Some(ctx), Some(at_start)) => ctx.pool_stats().since(&at_start),
                _ => crate::obs::PoolUtilization::default(),
            },
            wall_time_s,
        };
        hook.emit(&report);
    }
    FastOtResult {
        x,
        dual_objective: -f,
        iterations,
        outer_rounds,
        stop,
        stats,
        wall_time_s,
        method: method.to_string(),
    }
}

/// The screened solve every entry point funnels into: group-lasso
/// oracle on the caller's ctx (`cfg.threads` is ignored in favor of
/// `ctx.threads()`). The oracle's column-parallel hot loops run on the
/// ctx's persistent parked workers, so a serving worker's consecutive
/// solves — warm restarts included — never respawn threads. Determinism
/// is untouched (same fixed chunk grid, same ordered reduction).
fn solve_fast_ot_inner(
    prob: &OtProblem,
    cfg: &FastOtConfig,
    x0: Vec<f64>,
    ctx: &ParallelCtx,
) -> FastOtResult {
    // Infallible legacy entry points resolve the ring budget leniently
    // (a bad env value falls back to the default); the fallible
    // `fastot::solve` path has already validated it by this point.
    let ring = super::cost::resolve_tile_ring_bytes(cfg.tile_ring_kib)
        .unwrap_or(super::cost::TILE_RING_BUDGET_BYTES);
    let mut oracle = ScreeningOracle::build_with_ring(
        prob,
        cfg.params(),
        cfg.use_working_set,
        ctx.clone(),
        cfg.simd,
        ring,
    );
    oracle.set_cancel(cfg.cancel.clone());
    let label = if cfg.use_working_set { "fast" } else { "fast-nows" };
    drive_from(prob, cfg, &mut oracle, label, x0)
}

/// Resolve a warm-start iterate for the full dual (dimension-checked).
pub(crate) fn full_dual_x0(prob: &OtProblem, opts: &SolveOptions) -> Result<Vec<f64>> {
    match &opts.warm_start {
        Some(x0) if x0.len() != prob.dim() => Err(err!(
            "warm-start iterate has length {}, the full dual needs m + n = {}",
            x0.len(),
            prob.dim()
        )),
        Some(x0) => Ok(x0.clone()),
        None => Ok(vec![0.0; prob.dim()]),
    }
}

/// The unified fast-method entry: solve the (screened, where the
/// regularizer admits screening) full dual under `opts`.
///
/// * Group lasso (the default): the paper's Algorithm 1/2 path,
///   bit-identical to [`solve_fast_ot`] — SIMD kernels, safe skipping,
///   working set.
/// * Squared ℓ2 / negative entropy: no screening rule exists, so the
///   solve runs the generic dense oracle
///   ([`crate::ot::regularizer::DenseRegOracle`]) through the same
///   Algorithm-1 driver; the result's method label is
///   `"fast+<regularizer>"`.
pub fn solve(prob: &OtProblem, opts: &SolveOptions) -> Result<FastOtResult> {
    let kind = opts.resolve_regularizer()?;
    let reg = AnyRegularizer::build(kind, opts.gamma, opts.rho, &prob.groups)?;
    let x0 = full_dual_x0(prob, opts)?;
    // Validate the tile-ring knob strictly on this fallible entry (the
    // inner driver falls back leniently for the infallible legacy
    // paths).
    opts.resolve_tile_ring_bytes()?;
    let cfg = opts.fastot_config();
    let ctx = opts.make_ctx();
    match reg {
        AnyRegularizer::GroupLasso(_) => Ok(solve_fast_ot_inner(prob, &cfg, x0, &ctx)),
        other => {
            let label =
                format!("{}+{}", if cfg.use_working_set { "fast" } else { "fast-nows" }, other.name());
            let mut oracle = DenseRegOracle::new(prob, other, ctx);
            oracle.set_cancel(cfg.cancel.clone());
            Ok(drive_from(prob, &cfg, &mut oracle, &label, x0))
        }
    }
}

/// Solve with the paper's method (both ideas enabled by default).
pub fn solve_fast_ot(prob: &OtProblem, cfg: &FastOtConfig) -> FastOtResult {
    solve_fast_ot_from(prob, cfg, vec![0.0; prob.dim()])
}

/// Solve with the paper's method from a warm-start iterate `x0`.
pub fn solve_fast_ot_from(prob: &OtProblem, cfg: &FastOtConfig, x0: Vec<f64>) -> FastOtResult {
    solve_fast_ot_inner(prob, cfg, x0, &ParallelCtx::new(cfg.threads))
}

/// [`solve_fast_ot_from`] over a caller-provided parallel context.
#[deprecated(note = "use `fastot::solve` with `SolveOptions::ctx`/`warm_start`")]
pub fn solve_fast_ot_ctx(
    prob: &OtProblem,
    cfg: &FastOtConfig,
    x0: Vec<f64>,
    ctx: &ParallelCtx,
) -> FastOtResult {
    solve_fast_ot_inner(prob, cfg, x0, ctx)
}

/// Per-iteration diagnostics used by the Fig. B/C benchmarks: runs the
/// fast method while recording bound errors and per-eval gradient
/// counts at every solver iteration.
pub struct IterationTrace {
    pub iteration: usize,
    pub dual_objective: f64,
    pub mean_upper_err: f64,
    pub mean_lower_err: f64,
    pub grads_this_iter: u64,
    pub skipped_this_iter: u64,
}

/// Solve while tracing per-iteration screening behaviour (O(mn) extra
/// work per iteration — diagnostics only).
pub fn solve_fast_ot_traced(
    prob: &OtProblem,
    cfg: &FastOtConfig,
) -> (FastOtResult, Vec<IterationTrace>) {
    let start = Instant::now();
    let mut oracle = ScreeningOracle::build(
        prob,
        cfg.params(),
        cfg.use_working_set,
        ParallelCtx::new(cfg.threads),
        cfg.simd,
    );
    let x0 = vec![0.0; prob.dim()];
    let mut solver = Lbfgs::new(x0, cfg.lbfgs.clone(), &mut oracle);
    let mut traces = Vec::new();
    let mut outer_rounds = 0usize;
    let mut prev_grads = oracle.stats().grads_computed;
    let mut prev_skipped = oracle.stats().grads_skipped;
    let stop = 'outer: loop {
        for _ in 0..cfg.r {
            let status = solver.step(&mut oracle);
            let errs = oracle.bound_errors(solver.x());
            let s = oracle.stats();
            traces.push(IterationTrace {
                iteration: solver.iterations(),
                dual_objective: -solver.f(),
                mean_upper_err: errs.mean_upper,
                mean_lower_err: errs.mean_lower,
                grads_this_iter: s.grads_computed - prev_grads,
                skipped_this_iter: s.grads_skipped - prev_skipped,
            });
            prev_grads = s.grads_computed;
            prev_skipped = s.grads_skipped;
            if let StepStatus::Stopped(reason) = status {
                break 'outer reason;
            }
        }
        oracle.refresh(solver.x());
        outer_rounds += 1;
    };
    let iterations = solver.iterations();
    let (x, f) = solver.into_solution();
    let res = FastOtResult {
        x,
        dual_objective: -f,
        iterations,
        outer_rounds,
        stop,
        stats: oracle.stats().clone(),
        wall_time_s: start.elapsed().as_secs_f64(),
        method: "fast-traced".to_string(),
    };
    (res, traces)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::ot::origin::solve_origin;
    use crate::rng::Pcg64;

    fn random_problem(seed: u64, l: usize, g: usize, n: usize) -> OtProblem {
        let mut rng = Pcg64::new(seed);
        let m = l * g;
        let cost = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
        let labels: Vec<usize> = (0..m).map(|i| i / g).collect();
        OtProblem::from_parts(vec![1.0 / m as f64; m], vec![1.0 / n as f64; n], &cost, &labels)
    }

    #[test]
    fn fast_matches_origin_trajectory() {
        // Theorem 2: identical objective AND identical solution.
        let prob = random_problem(21, 4, 3, 9);
        for rho in [0.2, 0.5, 0.8] {
            for gamma in [0.1, 1.0, 10.0] {
                let cfg = FastOtConfig {
                    gamma,
                    rho,
                    lbfgs: LbfgsOptions { max_iters: 120, ..Default::default() },
                    ..Default::default()
                };
                let fast = solve_fast_ot(&prob, &cfg);
                let orig = solve_origin(&prob, &cfg);
                assert_eq!(
                    fast.dual_objective, orig.dual_objective,
                    "objective differs at gamma={gamma} rho={rho}"
                );
                assert_eq!(fast.x, orig.x, "solution differs at gamma={gamma} rho={rho}");
                assert_eq!(fast.iterations, orig.iterations);
            }
        }
    }

    #[test]
    fn warm_start_preserves_theorem2_trajectory() {
        // Theorem 2 holds from any starting iterate: screened and dense
        // solves warm-started at the same x0 must stay bit-identical.
        let prob = random_problem(17, 4, 3, 9);
        let cfg = FastOtConfig {
            gamma: 0.7,
            rho: 0.5,
            lbfgs: LbfgsOptions { max_iters: 80, ..Default::default() },
            ..Default::default()
        };
        let mut rng = crate::rng::Pcg64::new(404);
        let x0: Vec<f64> = (0..prob.dim()).map(|_| rng.uniform(-0.3, 0.4)).collect();
        let fast = solve_fast_ot_from(&prob, &cfg, x0.clone());
        let orig = crate::ot::origin::solve_origin_from(&prob, &cfg, x0.clone());
        assert_eq!(fast.dual_objective, orig.dual_objective);
        assert_eq!(fast.x, orig.x);
        assert_eq!(fast.iterations, orig.iterations);
        // And warm-starting from a *converged* cold solution barely
        // moves: the dual objective must agree with it to 1e-9. (Tight
        // tolerances so the cold solve actually converges rather than
        // stopping at the iteration cap.)
        let cfg = FastOtConfig {
            lbfgs: LbfgsOptions { max_iters: 4000, ftol: 1e-13, gtol: 1e-8, ..Default::default() },
            ..cfg
        };
        let cold = solve_fast_ot(&prob, &cfg);
        let rewarmed = solve_fast_ot_from(&prob, &cfg, cold.x.clone());
        assert!(
            (rewarmed.dual_objective - cold.dual_objective).abs() <= 1e-9,
            "cold={} rewarmed={}",
            cold.dual_objective,
            rewarmed.dual_objective
        );
    }

    #[test]
    fn working_set_does_not_change_result() {
        let prob = random_problem(33, 5, 4, 8);
        let base = FastOtConfig { gamma: 0.5, rho: 0.6, ..Default::default() };
        let with_ws = solve_fast_ot(&prob, &base);
        let without = solve_fast_ot(
            &prob,
            &FastOtConfig { use_working_set: false, ..base.clone() },
        );
        assert_eq!(with_ws.dual_objective, without.dual_objective);
        assert_eq!(with_ws.x, without.x);
    }

    #[test]
    fn fast_skips_more_than_it_computes_when_sparse() {
        let prob = random_problem(7, 8, 5, 20);
        let cfg = FastOtConfig { gamma: 10.0, rho: 0.8, ..Default::default() };
        let fast = solve_fast_ot(&prob, &cfg);
        let s = &fast.stats;
        let total = s.grads_computed + s.grads_skipped;
        assert!(total > 0);
        assert!(
            s.grads_skipped as f64 > 0.3 * total as f64,
            "skip rate too low: {s:?}"
        );
    }

    #[test]
    fn traced_solve_matches_plain() {
        let prob = random_problem(9, 3, 3, 6);
        let cfg = FastOtConfig { gamma: 1.0, rho: 0.5, ..Default::default() };
        let plain = solve_fast_ot(&prob, &cfg);
        let (traced, traces) = solve_fast_ot_traced(&prob, &cfg);
        assert_eq!(plain.dual_objective, traced.dual_objective);
        assert_eq!(plain.iterations, traced.iterations);
        // One trace per step() call: the terminal call may or may not
        // have performed an iteration.
        assert!(
            traces.len() == traced.iterations || traces.len() == traced.iterations + 1,
            "traces={} iters={}",
            traces.len(),
            traced.iterations
        );
        // Bound errors must be nonnegative everywhere.
        for t in &traces {
            assert!(t.mean_upper_err >= -1e-12);
            assert!(t.mean_lower_err >= -1e-12);
        }
    }

    #[test]
    fn cancelled_token_stops_at_first_checkpoint() {
        let prob = random_problem(5, 3, 3, 6);
        let token = crate::fault::CancelToken::new();
        token.cancel();
        let cfg = FastOtConfig { cancel: Some(token), ..Default::default() };
        let res = solve_fast_ot(&prob, &cfg);
        assert_eq!(res.stop, StopReason::Cancelled);
        assert_eq!(res.iterations, 0);
        assert!(!res.stop.converged());
    }

    #[test]
    fn armed_uncancelled_token_is_byte_identical() {
        let prob = random_problem(21, 4, 3, 9);
        let base = FastOtConfig { gamma: 0.7, rho: 0.5, ..Default::default() };
        let plain = solve_fast_ot(&prob, &base);
        let token = crate::fault::CancelToken::with_deadline(
            Instant::now() + std::time::Duration::from_secs(3600),
        );
        let armed = solve_fast_ot(&prob, &FastOtConfig { cancel: Some(token), ..base });
        assert_eq!(plain.x, armed.x);
        assert_eq!(plain.dual_objective, armed.dual_objective);
        assert_eq!(plain.iterations, armed.iterations);
        assert_eq!(plain.stop, armed.stop);
    }

    #[test]
    fn dual_objective_increases_with_iterations() {
        let prob = random_problem(15, 4, 4, 10);
        let cfg = FastOtConfig {
            gamma: 0.2,
            rho: 0.4,
            lbfgs: LbfgsOptions { max_iters: 60, ..Default::default() },
            ..Default::default()
        };
        let res = solve_fast_ot(&prob, &cfg);
        assert!(res.dual_objective > 0.0);
        assert!(res.iterations > 0);
    }
}
