"""L2 JAX model: the negated-dual oracle of Problem 4.

Assembles the full (value, gradient) computation the Rust coordinator
needs per L-BFGS evaluation, calling the L1 Pallas kernel for the
O(m·n) soft-threshold work and plain jnp for the O(m + n) reductions.
``aot.py`` lowers :func:`dual_obj_grad` once per problem shape to HLO
text; Python never runs at request time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels.group_softthresh import grad_psi_pallas
from .kernels import ref


@functools.partial(
    jax.jit, static_argnames=("num_groups", "group_size", "use_pallas")
)
def dual_obj_grad(
    alpha,
    beta,
    a,
    b,
    cost,
    tau,
    lambda_quad,
    *,
    num_groups: int,
    group_size: int,
    use_pallas: bool = True,
):
    """Negated dual objective and gradient at ``(alpha, beta)``.

    Returns ``(neg_obj, grad_alpha, grad_beta)`` — identical convention
    to the Rust ``eval_dense``/``OriginOracle``.
    """
    if use_pallas:
        t, z = grad_psi_pallas(
            alpha, beta, cost, tau, lambda_quad,
            num_groups=num_groups, group_size=group_size,
        )
    else:
        t, z = ref.grad_psi_uniform(
            alpha, beta, cost, num_groups, group_size, tau, lambda_quad
        )
    psi = ref.psi_from_z(z, tau, lambda_quad)
    dual = jnp.dot(alpha, a) + jnp.dot(beta, b) - psi
    grad_alpha = jnp.sum(t, axis=1) - a
    grad_beta = jnp.sum(t, axis=0) - b
    return -dual, grad_alpha, grad_beta


@functools.partial(
    jax.jit, static_argnames=("num_groups", "group_size", "use_pallas")
)
def recover_plan(
    alpha,
    beta,
    cost,
    tau,
    lambda_quad,
    *,
    num_groups: int,
    group_size: int,
    use_pallas: bool = True,
):
    """Transport plan T* from converged duals (Eq. 5)."""
    if use_pallas:
        t, _ = grad_psi_pallas(
            alpha, beta, cost, tau, lambda_quad,
            num_groups=num_groups, group_size=group_size,
        )
    else:
        t, _ = ref.grad_psi_uniform(
            alpha, beta, cost, num_groups, group_size, tau, lambda_quad
        )
    return t
