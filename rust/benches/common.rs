//! Shared helpers for the per-figure bench binaries.
//!
//! Every bench prints the paper-style rows to stdout and persists
//! markdown + CSV under `reports/`. Set `GRPOT_BENCH_QUICK=1` to shrink
//! the grids (CI-sized); set `GRPOT_BENCH_SMOKE=1` to collapse every
//! bench to one tiny iteration (the `scripts/ci.sh` smoke pass); unset
//! both for the full paper-scale run.

// Each bench binary links this module and uses its own subset.
#![allow(dead_code)]

use grpot::benchlib::{quick_mode, report_dir, smoke_mode, Table};
use grpot::coordinator::config::Method;
use grpot::coordinator::sweep::run_job;
use grpot::data::DomainPair;
use grpot::ot::dual::OtProblem;

/// Pick a size/grid by mode: smoke ≪ quick < full.
pub fn size3<T>(smoke: T, quick: T, full: T) -> T {
    if smoke_mode() {
        smoke
    } else if quick_mode() {
        quick
    } else {
        full
    }
}

/// The paper's γ grid (full), a 4-point quick version, or one point in
/// smoke mode.
pub fn gamma_grid() -> Vec<f64> {
    size3(
        vec![0.1],
        vec![0.01, 0.1, 1.0, 10.0],
        vec![1e-3, 1e-2, 1e-1, 1.0, 1e1, 1e2, 1e3],
    )
}

/// The paper's ρ grid (full), a 2-point quick version, or one point in
/// smoke mode.
pub fn rho_grid() -> Vec<f64> {
    size3(vec![0.6], vec![0.4, 0.8], vec![0.2, 0.4, 0.6, 0.8])
}

/// Solver iteration cap per job (keeps full sweeps tractable while past
/// the convergence point for most (γ, ρ)).
pub fn max_iters() -> usize {
    size3(20, 300, 1000)
}

/// Measurement of one method on one problem at one γ (summed over the
/// ρ grid, exactly the paper's aggregation).
pub struct GainRow {
    pub gamma: f64,
    pub t_origin: f64,
    pub t_fast: f64,
    pub gain: f64,
    /// Fraction of group gradients the fast method skipped over the ρ
    /// grid — the paper's headline telemetry, aggregated like the times.
    pub skip_rate: f64,
    /// Same dual objectives across methods on the whole ρ grid?
    pub objectives_match: bool,
}

/// Run the paper's protocol on one problem: per γ, total time over the
/// ρ grid for `origin` and `fast`; verify Theorem 2 along the way.
pub fn gain_sweep(prob: &OtProblem, gammas: &[f64], rhos: &[f64], r: usize) -> Vec<GainRow> {
    let mi = max_iters();
    gammas
        .iter()
        .map(|&gamma| {
            let mut t_fast = 0.0;
            let mut t_origin = 0.0;
            let mut objectives_match = true;
            let (mut computed, mut skipped) = (0u64, 0u64);
            for &rho in rhos {
                let f = run_job(prob, Method::Fast, gamma, rho, r, mi);
                let o = run_job(prob, Method::Origin, gamma, rho, r, mi);
                t_fast += f.wall_time_s;
                t_origin += o.wall_time_s;
                computed += f.grads_computed;
                skipped += f.grads_skipped;
                objectives_match &= f.dual_objective == o.dual_objective;
            }
            GainRow {
                gamma,
                t_origin,
                t_fast,
                gain: t_origin / t_fast.max(1e-12),
                skip_rate: grpot::obs::report::skipped_fraction(computed, skipped),
                objectives_match,
            }
        })
        .collect()
}

/// Emit a gain table for a family of labeled problems (one block per
/// label), paper-figure style.
pub fn emit_gain_table(
    title: &str,
    stem: &str,
    blocks: &[(String, Vec<GainRow>)],
) {
    let mut table = Table::new(
        title,
        &["case", "gamma", "t_origin[s]", "t_fast[s]", "gain", "skip_rate", "thm2"],
    );
    for (label, rows) in blocks {
        for row in rows {
            table.row(vec![
                label.clone(),
                format!("{}", row.gamma),
                format!("{:.4}", row.t_origin),
                format!("{:.4}", row.t_fast),
                format!("{:.2}x", row.gain),
                format!("{:.3}", row.skip_rate),
                if row.objectives_match { "ok".into() } else { "MISMATCH".into() },
            ]);
        }
    }
    table.emit(&report_dir(), stem);
}

/// Build a problem from a generated pair (includes the cost matrix).
pub fn problem_of(pair: &DomainPair) -> OtProblem {
    OtProblem::from_dataset(pair)
}

/// Standard bench banner.
pub fn banner(name: &str) {
    println!("== {name} ({} mode) ==", size3("smoke", "quick", "full"));
}
