//! The paper's controlled synthetic dataset (Fig. 2 / Fig. A / Table 1).
//!
//! Exactly the construction of the Experiment section: `|L|` classes,
//! `g` samples per class, d = 2; class `l` of the source is
//! `N((5l, −5), I)` and of the target `N((5l, +5), I)`; `n = m = |L|·g`.
//! Target labels are produced for evaluation only.

use super::{Dataset, DomainPair};
use crate::linalg::Mat;
use crate::rng::Pcg64;

/// Paper construction with `num_classes` classes and `g` samples per
/// class on both domains.
pub fn controlled(num_classes: usize, g: usize, seed: u64) -> DomainPair {
    assert!(num_classes > 0 && g > 0);
    let mut rng = Pcg64::new(seed);
    let make = |rng: &mut Pcg64, y_mean: f64, name: &str| {
        let m = num_classes * g;
        let mut x = Mat::zeros(m, 2);
        let mut labels = Vec::with_capacity(m);
        for l in 0..num_classes {
            for k in 0..g {
                let row = l * g + k;
                x[(row, 0)] = rng.normal_ms(l as f64 * 5.0, 1.0);
                x[(row, 1)] = rng.normal_ms(y_mean, 1.0);
                labels.push(l);
            }
        }
        Dataset { name: name.to_string(), x, labels }
    };
    let source = make(&mut rng, -5.0, &format!("synth-src-L{num_classes}-g{g}"));
    let target = make(&mut rng, 5.0, &format!("synth-tgt-L{num_classes}-g{g}"));
    DomainPair { source, target }
}

/// Fig.-2 family: fixed g = 10, growing class count.
pub fn controlled_classes(num_classes: usize, g: usize, seed: u64) -> DomainPair {
    controlled(num_classes, g, seed)
}

/// Fig.-A family: fixed |L| = 10, growing samples-per-class.
pub fn controlled_samples_per_class(g: usize, seed: u64) -> DomainPair {
    controlled(10, g, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_paper() {
        let p = controlled(40, 10, 1);
        assert_eq!(p.source.len(), 400);
        assert_eq!(p.target.len(), 400);
        assert_eq!(p.source.dim(), 2);
        assert_eq!(p.source.num_classes(), 40);
        assert_eq!(p.target.num_classes(), 40);
    }

    #[test]
    fn class_means_separate() {
        let p = controlled(4, 200, 7);
        // Class 3 mean-x ≈ 15, class 0 mean-x ≈ 0.
        let mean_x = |ds: &Dataset, class: usize| {
            let idx: Vec<usize> =
                (0..ds.len()).filter(|&i| ds.labels[i] == class).collect();
            idx.iter().map(|&i| ds.x[(i, 0)]).sum::<f64>() / idx.len() as f64
        };
        assert!((mean_x(&p.source, 0) - 0.0).abs() < 0.3);
        assert!((mean_x(&p.source, 3) - 15.0).abs() < 0.3);
        // Domains split on the y axis.
        let mean_y = |ds: &Dataset| {
            (0..ds.len()).map(|i| ds.x[(i, 1)]).sum::<f64>() / ds.len() as f64
        };
        assert!(mean_y(&p.source) < -4.5);
        assert!(mean_y(&p.target) > 4.5);
    }

    #[test]
    fn deterministic_in_seed() {
        let a = controlled(3, 5, 42);
        let b = controlled(3, 5, 42);
        assert_eq!(a.source.x.as_slice(), b.source.x.as_slice());
        let c = controlled(3, 5, 43);
        assert_ne!(a.source.x.as_slice(), c.source.x.as_slice());
    }
}
