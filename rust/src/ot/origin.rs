//! Dense baseline oracle — the "origin" method of Blondel, Seguy &
//! Rolet (2018): every group's gradient is computed at every evaluation,
//! `O(|L|·n·g)` per call.

use super::dual::{
    eval_dense_with, ColChunkScratch, DualOracle, DualParams, KernelConsts, OracleStats,
    OtProblem, SimdEngine,
};
use super::fastot::{drive_from, full_dual_x0, FastOtConfig, FastOtResult};
use super::regularizer::{AnyRegularizer, DenseRegOracle, Regularizer};
use super::solve::SolveOptions;
use crate::error::Result;
use crate::pool::{fixed_chunk_ranges, ParallelCtx};
use crate::simd::{Dispatch, SimdMode};
use crate::solvers::lbfgs::{Lbfgs, LbfgsOptions};
use std::ops::Range;

/// Dense (non-screened) negated-dual oracle. Column chunks evaluate in
/// parallel on the context's persistent parked workers with a
/// deterministic ordered reduction, so results are bit-identical for
/// every thread count (see [`crate::pool::ParallelCtx`]); scratch is
/// per-chunk and persistent, keeping the steady state allocation-free.
pub struct OriginOracle<'a> {
    prob: &'a OtProblem,
    params: DualParams,
    consts: KernelConsts,
    stats: OracleStats,
    ctx: ParallelCtx,
    ranges: Vec<Range<usize>>,
    slots: Vec<ColChunkScratch>,
    /// SIMD backend + packed cost tiles, resolved/packed once at
    /// construction and reused by every evaluation.
    engine: SimdEngine,
    /// Cooperative cancellation, polled once per column chunk inside
    /// [`eval_dense_with`] — sub-eval granularity on top of the
    /// driver's per-iteration checkpoint. `None` (the default) skips
    /// the poll entirely; an armed-but-uncancelled token is bitwise
    /// transparent.
    cancel: Option<crate::fault::CancelToken>,
}

impl<'a> OriginOracle<'a> {
    pub fn new(prob: &'a OtProblem, params: DualParams) -> Self {
        Self::with_threads(prob, params, 1)
    }

    /// Create with `threads` intra-evaluation workers (1 = serial) on a
    /// fresh [`ParallelCtx`] owned by this oracle.
    pub fn with_threads(prob: &'a OtProblem, params: DualParams, threads: usize) -> Self {
        Self::build(prob, params, ParallelCtx::new(threads), SimdMode::Auto)
    }

    /// Create from the unified options surface: γ/ρ, ctx/threads and
    /// SIMD policy come from `opts` (`opts.regularizer` is not
    /// consulted — this oracle *is* the dense group-lasso baseline; the
    /// generic path is [`super::regularizer::DenseRegOracle`]).
    pub fn with_options(prob: &'a OtProblem, opts: &SolveOptions) -> Self {
        Self::build(prob, DualParams::new(opts.gamma, opts.rho), opts.make_ctx(), opts.simd)
    }

    /// Create over a caller-provided parallel context (the serving
    /// engine's per-worker long-lived ctx; clones share its parked
    /// worker set). SIMD policy is `Auto` (runtime-dispatched;
    /// `GRPOT_SIMD` overrides).
    #[deprecated(note = "use `OriginOracle::with_options` with `SolveOptions::ctx`")]
    pub fn with_ctx(prob: &'a OtProblem, params: DualParams, ctx: ParallelCtx) -> Self {
        Self::build(prob, params, ctx, SimdMode::Auto)
    }

    /// Caller-provided context with an explicit SIMD policy —
    /// `SimdMode::Scalar` forces the reference scalar kernels. Scalar
    /// and vector backends return byte-equal results either way.
    #[deprecated(note = "use `OriginOracle::with_options` with `SolveOptions::ctx`/`simd`")]
    pub fn with_ctx_simd(
        prob: &'a OtProblem,
        params: DualParams,
        ctx: ParallelCtx,
        simd: SimdMode,
    ) -> Self {
        Self::build(prob, params, ctx, simd)
    }

    /// Convenience: fresh ctx + explicit SIMD policy (benches/tests).
    #[deprecated(note = "use `OriginOracle::with_options` with `SolveOptions::threads`/`simd`")]
    pub fn with_simd(
        prob: &'a OtProblem,
        params: DualParams,
        threads: usize,
        simd: SimdMode,
    ) -> Self {
        Self::build(prob, params, ParallelCtx::new(threads), simd)
    }

    /// The one real constructor every public entry funnels into.
    pub(crate) fn build(
        prob: &'a OtProblem,
        params: DualParams,
        ctx: ParallelCtx,
        simd: SimdMode,
    ) -> Self {
        params.validate();
        let ranges = fixed_chunk_ranges(prob.n());
        let slots = ColChunkScratch::slots_for(prob, &ranges);
        let engine = SimdEngine::new(prob, simd);
        OriginOracle {
            prob,
            consts: KernelConsts::new(&params),
            params,
            stats: OracleStats::default(),
            ctx,
            ranges,
            slots,
            engine,
            cancel: None,
        }
    }

    /// Arm (or disarm) sub-eval cancellation: the token is polled once
    /// per column chunk at one relaxed load.
    pub(crate) fn set_cancel(&mut self, cancel: Option<crate::fault::CancelToken>) {
        self.cancel = cancel;
    }

    pub fn params(&self) -> &DualParams {
        &self.params
    }

    /// The SIMD backend this oracle's evaluations run.
    pub fn dispatch(&self) -> Dispatch {
        self.engine.dispatch
    }
}

impl DualOracle for OriginOracle<'_> {
    fn shape(&self) -> (usize, usize) {
        (self.prob.m(), self.prob.n())
    }

    fn eval(&mut self, x: &[f64], grad: &mut [f64]) -> f64 {
        let (f, totals) = eval_dense_with(
            self.prob,
            &self.consts,
            x,
            grad,
            &self.ctx,
            &self.ranges,
            &mut self.slots,
            &self.engine,
            self.cancel.as_ref(),
        );
        self.stats.grads_computed += totals.grads;
        self.stats.tiles_built += totals.tiles_built;
        self.stats.record_eval(totals.grads);
        f
    }

    fn stats(&self) -> &OracleStats {
        &self.stats
    }

    fn simd_dispatch(&self) -> Option<Dispatch> {
        Some(self.engine.dispatch)
    }

    fn parallel_ctx(&self) -> Option<&ParallelCtx> {
        Some(&self.ctx)
    }
}

/// The dense-baseline solve every entry point funnels into
/// (`cfg.threads` is ignored in favor of `ctx.threads()`).
fn solve_origin_inner(
    prob: &OtProblem,
    cfg: &FastOtConfig,
    x0: Vec<f64>,
    ctx: &ParallelCtx,
) -> FastOtResult {
    let params = DualParams::new(cfg.gamma, cfg.rho);
    let mut oracle = OriginOracle::build(prob, params, ctx.clone(), cfg.simd);
    oracle.set_cancel(cfg.cancel.clone());
    drive_from(prob, cfg, &mut oracle, "origin", x0)
}

/// The unified dense-baseline entry: solve the full dual under `opts`
/// with no screening, whatever the regularizer.
///
/// * Group lasso (the default): the SIMD-kerneled [`OriginOracle`],
///   bit-identical to [`solve_origin`].
/// * Squared ℓ2 / negative entropy: the generic scalar
///   [`super::regularizer::DenseRegOracle`]; the result's method label
///   is `"origin+<regularizer>"`.
pub fn solve(prob: &OtProblem, opts: &SolveOptions) -> Result<FastOtResult> {
    let kind = opts.resolve_regularizer()?;
    let reg = AnyRegularizer::build(kind, opts.gamma, opts.rho, &prob.groups)?;
    let x0 = full_dual_x0(prob, opts)?;
    let cfg = opts.fastot_config();
    let ctx = opts.make_ctx();
    match reg {
        AnyRegularizer::GroupLasso(_) => Ok(solve_origin_inner(prob, &cfg, x0, &ctx)),
        other => {
            let label = format!("origin+{}", other.name());
            let mut oracle = DenseRegOracle::new(prob, other, ctx);
            oracle.set_cancel(cfg.cancel.clone());
            Ok(drive_from(prob, &cfg, &mut oracle, &label, x0))
        }
    }
}

/// Solve the dual with the dense baseline. Drives L-BFGS in the same
/// r-iteration blocks as [`crate::ot::fastot::solve_fast_ot`] so the two
/// trajectories are directly comparable (Theorem 2).
pub fn solve_origin(prob: &OtProblem, cfg: &FastOtConfig) -> FastOtResult {
    solve_origin_from(prob, cfg, vec![0.0; prob.dim()])
}

/// Dense-baseline solve from a warm-start iterate `x0`.
pub fn solve_origin_from(prob: &OtProblem, cfg: &FastOtConfig, x0: Vec<f64>) -> FastOtResult {
    solve_origin_inner(prob, cfg, x0, &ParallelCtx::new(cfg.threads))
}

/// [`solve_origin_from`] over a caller-provided parallel context.
#[deprecated(note = "use `origin::solve` with `SolveOptions::ctx`/`warm_start`")]
pub fn solve_origin_ctx(
    prob: &OtProblem,
    cfg: &FastOtConfig,
    x0: Vec<f64>,
    ctx: &ParallelCtx,
) -> FastOtResult {
    solve_origin_inner(prob, cfg, x0, ctx)
}

/// Convenience: solve with explicit L-BFGS options (tests).
pub fn solve_origin_lbfgs(
    prob: &OtProblem,
    params: DualParams,
    opts: &LbfgsOptions,
) -> (Vec<f64>, f64, u64) {
    let mut oracle = OriginOracle::new(prob, params);
    let x0 = vec![0.0; prob.dim()];
    let mut solver = Lbfgs::new(x0, opts.clone(), &mut oracle);
    solver.run(&mut oracle);
    let evals = oracle.stats().evals;
    let (x, f) = solver.into_solution();
    (x, -f, evals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn tiny() -> OtProblem {
        let cost = Mat::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        OtProblem::from_parts(vec![0.5, 0.5], vec![0.5, 0.5], &cost, &[0, 1])
    }

    #[test]
    fn origin_counts_all_groups() {
        let p = tiny();
        let mut o = OriginOracle::new(&p, DualParams::new(1.0, 0.5));
        let mut g = vec![0.0; p.dim()];
        let x0 = vec![0.0; p.dim()];
        let x1 = vec![0.1; p.dim()];
        o.eval(&x0, &mut g);
        o.eval(&x1, &mut g);
        assert_eq!(o.stats().evals, 2);
        // 2 groups × 2 columns per eval.
        assert_eq!(o.stats().grads_computed, 8);
        assert_eq!(o.stats().per_eval_grads, vec![4, 4]);
    }

    #[test]
    fn solve_origin_increases_dual() {
        let p = tiny();
        let params = DualParams::new(0.5, 0.5);
        let (x, dual, _) = solve_origin_lbfgs(&p, params, &LbfgsOptions::default());
        // Dual at the solution must beat the zero point (which gives 0).
        assert!(dual > 0.0, "dual={dual}");
        assert_eq!(x.len(), 4);
    }
}
