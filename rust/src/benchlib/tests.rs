use super::*;

#[test]
fn summary_basic_stats() {
    let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
    assert_eq!(s.n, 5);
    assert!((s.mean - 3.0).abs() < 1e-12);
    assert!((s.median - 3.0).abs() < 1e-12);
    assert_eq!(s.min, 1.0);
    assert_eq!(s.max, 5.0);
    assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
}

#[test]
fn summary_single_sample() {
    let s = Summary::from_samples(&[7.0]);
    assert_eq!(s.median, 7.0);
    assert_eq!(s.std, 0.0);
    assert_eq!(s.p10, 7.0);
    assert_eq!(s.rel_std(), 0.0);
}

#[test]
fn percentiles_interpolate() {
    let sorted = [0.0, 10.0];
    assert!((stats_percentile(&sorted, 50.0) - 5.0).abs() < 1e-12);
    assert!((stats_percentile(&sorted, 90.0) - 9.0).abs() < 1e-12);
}

fn stats_percentile(sorted: &[f64], p: f64) -> f64 {
    super::stats::percentile_sorted(sorted, p)
}

#[test]
fn bench_fn_counts_iterations() {
    let mut count = 0;
    let opts = BenchOptions { warmup: 2, iters: 5, max_seconds: 60.0 };
    let m = bench_fn("t", &opts, || {
        count += 1;
    });
    assert_eq!(count, 7); // 2 warmup + 5 timed
    assert_eq!(m.samples.len(), 5);
    assert!(m.seconds() >= 0.0);
}

#[test]
fn bench_fn_budget_stops_early() {
    let opts = BenchOptions { warmup: 0, iters: 1000, max_seconds: 0.05 };
    let m = bench_fn("slow", &opts, || {
        std::thread::sleep(std::time::Duration::from_millis(20));
    });
    assert!(m.samples.len() < 1000);
    assert!(!m.samples.is_empty());
}

#[test]
fn table_markdown_and_csv() {
    let mut t = Table::new("Fig X", &["classes", "gain"]);
    t.row(vec!["10".into(), "2.0".into()]);
    t.row(vec!["20".into(), "3.5".into()]);
    let md = t.to_markdown();
    assert!(md.contains("### Fig X"));
    assert!(md.contains("| classes | gain |"));
    assert!(md.contains("| 20"));
    let csv = t.to_csv();
    assert!(csv.starts_with("classes,gain\n"));
    assert!(csv.contains("20,3.5"));
}

#[test]
fn csv_quoting() {
    let mut t = Table::new("q", &["a"]);
    t.row(vec!["x,y".into()]);
    assert!(t.to_csv().contains("\"x,y\""));
}

#[test]
#[should_panic]
fn table_row_width_mismatch_panics() {
    let mut t = Table::new("t", &["a", "b"]);
    t.row(vec!["1".into()]);
}
