//! Hyperparameter sweep scheduler — the paper's measurement protocol.
//!
//! The paper evaluates every (γ, ρ) combination and reports, per γ, the
//! **total** processing time across ρ ∈ {0.2, 0.4, 0.6, 0.8} for each
//! method; the headline metric is the per-γ *gain* = t_origin / t_ours.
//! This module runs that grid (optionally across worker threads for
//! multi-task figures — individual solves stay single-threaded like the
//! paper's one-CPU-core setup), collects per-job records and aggregates
//! gains.

use super::config::{Method, SweepConfig};
use super::metrics::Metrics;
use super::registry::build_pair;
use crate::err;
use crate::error::Result;
use crate::jsonlite::Value;
use crate::ot::dual::OtProblem;
use crate::ot::fastot::FastOtResult;
use crate::ot::regularizer::RegKind;
use crate::ot::solve::SolveOptions;
use crate::pool::{ParallelCtx, ThreadPool};
use crate::simd::SimdMode;
use crate::solvers::lbfgs::LbfgsOptions;
use std::sync::{Arc, Mutex};

/// One completed sweep job.
#[derive(Clone, Debug)]
pub struct SweepRecord {
    pub method: Method,
    pub gamma: f64,
    pub rho: f64,
    pub wall_time_s: f64,
    pub dual_objective: f64,
    pub iterations: usize,
    pub grads_computed: u64,
    pub grads_skipped: u64,
}

impl SweepRecord {
    /// The paper's headline quantity for this job: fraction of group
    /// gradients safe screening skipped.
    pub fn skipped_group_fraction(&self) -> f64 {
        crate::obs::report::skipped_fraction(self.grads_computed, self.grads_skipped)
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("method", self.method.name())
            .set("gamma", self.gamma)
            .set("rho", self.rho)
            .set("wall_time_s", self.wall_time_s)
            .set("dual_objective", self.dual_objective)
            .set("iterations", self.iterations)
            .set("grads_computed", self.grads_computed)
            .set("grads_skipped", self.grads_skipped)
            .set("skipped_group_fraction", self.skipped_group_fraction())
    }
}

/// Per-γ aggregate: total seconds per method and the paper's gain.
#[derive(Clone, Debug)]
pub struct GammaAggregate {
    pub gamma: f64,
    /// `(method, total seconds over the ρ grid)`.
    pub totals: Vec<(Method, f64)>,
    /// `t_origin / t_fast` when both present.
    pub gain: Option<f64>,
}

/// Complete sweep output.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub records: Vec<SweepRecord>,
    pub aggregates: Vec<GammaAggregate>,
    /// Max objective over all hyperparameters per method (Table 1).
    pub max_objective: Vec<(Method, f64)>,
}

/// The unified method-dispatched entry — sweep, serve and CLI all land
/// here. `opts.use_working_set` is overridden by the method (it *is*
/// the fast/fast-nows distinction).
///
/// Regularizer support by method: `fast`/`fast-nows`/`origin` accept
/// every [`RegKind`] (non-group-lasso kinds run the generic dense
/// oracle — no screening rule exists for them); `xla-origin` is
/// group-lasso only (the compiled artifact bakes in the group-lasso
/// kernel).
pub fn solve(prob: &OtProblem, method: Method, opts: &SolveOptions) -> Result<FastOtResult> {
    // Once-only: lets `GRPOT_TRACE=full cargo test/bench` trace without
    // the CLI launch hook; free after the first call.
    crate::obs::latch_env_once();
    match method {
        Method::Fast | Method::FastNoWs => {
            let opts = opts.clone().working_set(method != Method::FastNoWs);
            crate::ot::fastot::solve(prob, &opts)
        }
        Method::Origin => crate::ot::origin::solve(prob, opts),
        Method::XlaOrigin => solve_xla(prob, opts),
    }
}

#[cfg(feature = "xla")]
fn solve_xla(prob: &OtProblem, opts: &SolveOptions) -> Result<FastOtResult> {
    let kind = opts.resolve_regularizer()?;
    if kind != RegKind::GroupLasso {
        return Err(err!(
            "method 'xla-origin' supports only the group-lasso regularizer (got '{}')",
            kind.name()
        ));
    }
    let cfg = opts.fastot_config();
    let x0 = crate::ot::fastot::full_dual_x0(prob, opts)?;
    let runtime = crate::runtime::PjrtRuntime::cpu().expect("pjrt client");
    let params = cfg.params();
    let mut oracle = crate::runtime::XlaDualOracle::from_problem(
        &runtime,
        prob,
        &params,
        &crate::runtime::artifact_dir(),
    )
    .expect("artifact for problem shape (run `make artifacts`)");
    Ok(crate::ot::fastot::drive_from(prob, &cfg, &mut oracle, "xla-origin", x0))
}

// Backstop for direct programmatic calls; every user-facing entry
// point rejects the method earlier via `Method::ensure_available`, so
// this is unreachable from the CLI, sweep and TCP-service paths.
#[cfg(not(feature = "xla"))]
fn solve_xla(_prob: &OtProblem, _opts: &SolveOptions) -> Result<FastOtResult> {
    Err(err!(
        "method 'xla-origin' needs the PJRT runtime; rebuild with `cargo build --features xla`"
    ))
}

/// Legacy-shaped core: positional knobs → [`SolveOptions`] with the
/// group-lasso regularizer pinned (so `GRPOT_REG` can never re-route a
/// pre-trait call site). Panics where the old terminal panicked
/// (unavailable method, invalid hyperparameters).
#[allow(clippy::too_many_arguments)]
fn solve_full_inner(
    prob: &OtProblem,
    method: Method,
    gamma: f64,
    rho: f64,
    r: usize,
    lbfgs: LbfgsOptions,
    x0: Option<&[f64]>,
    ctx: &ParallelCtx,
    simd: SimdMode,
) -> FastOtResult {
    let mut opts = SolveOptions::new()
        .gamma(gamma)
        .rho(rho)
        .r(r)
        .lbfgs(lbfgs)
        .threads(ctx.threads())
        .simd(simd)
        .regularizer(RegKind::GroupLasso)
        .ctx(ctx.clone());
    if let Some(x0) = x0 {
        opts = opts.warm_start(x0.to_vec());
    }
    solve(prob, method, &opts).unwrap_or_else(|e| panic!("{e}"))
}

/// Solve one (method, γ, ρ) job, returning the full solver result.
pub fn solve_full(
    prob: &OtProblem,
    method: Method,
    gamma: f64,
    rho: f64,
    r: usize,
    max_iters: usize,
) -> FastOtResult {
    solve_full_inner(
        prob,
        method,
        gamma,
        rho,
        r,
        LbfgsOptions { max_iters, ..Default::default() },
        None,
        &ParallelCtx::new(1),
        SimdMode::Auto,
    )
}

/// [`solve_full_threads`] with an explicit SIMD policy.
#[allow(clippy::too_many_arguments)]
#[deprecated(note = "use `sweep::solve` with `SolveOptions::threads`/`simd`")]
pub fn solve_full_simd(
    prob: &OtProblem,
    method: Method,
    gamma: f64,
    rho: f64,
    r: usize,
    max_iters: usize,
    threads: usize,
    simd: SimdMode,
) -> FastOtResult {
    solve_full_inner(
        prob,
        method,
        gamma,
        rho,
        r,
        LbfgsOptions { max_iters, ..Default::default() },
        None,
        &ParallelCtx::new(threads),
        simd,
    )
}

/// [`solve_full`] with `threads` intra-solve oracle workers. The solve
/// is deterministic: any thread count returns the bit-identical result.
#[deprecated(note = "use `sweep::solve` with `SolveOptions::threads`")]
pub fn solve_full_threads(
    prob: &OtProblem,
    method: Method,
    gamma: f64,
    rho: f64,
    r: usize,
    max_iters: usize,
    threads: usize,
) -> FastOtResult {
    solve_full_inner(
        prob,
        method,
        gamma,
        rho,
        r,
        LbfgsOptions { max_iters, ..Default::default() },
        None,
        &ParallelCtx::new(threads),
        SimdMode::Auto,
    )
}

/// Solve one (method, γ, ρ) job with explicit L-BFGS options, an
/// optional warm-start iterate and an intra-solve thread count.
#[allow(clippy::too_many_arguments)]
#[deprecated(note = "use `sweep::solve` with `SolveOptions::lbfgs`/`warm_start`")]
pub fn solve_full_warm(
    prob: &OtProblem,
    method: Method,
    gamma: f64,
    rho: f64,
    r: usize,
    lbfgs: LbfgsOptions,
    x0: Option<&[f64]>,
    threads: usize,
) -> FastOtResult {
    solve_full_inner(
        prob,
        method,
        gamma,
        rho,
        r,
        lbfgs,
        x0,
        &ParallelCtx::new(threads),
        SimdMode::Auto,
    )
}

/// [`solve_full_warm`] over a caller-provided long-lived parallel
/// context.
#[allow(clippy::too_many_arguments)]
#[deprecated(note = "use `sweep::solve` with `SolveOptions::ctx`")]
pub fn solve_full_warm_ctx(
    prob: &OtProblem,
    method: Method,
    gamma: f64,
    rho: f64,
    r: usize,
    lbfgs: LbfgsOptions,
    x0: Option<&[f64]>,
    ctx: &ParallelCtx,
) -> FastOtResult {
    solve_full_inner(prob, method, gamma, rho, r, lbfgs, x0, ctx, SimdMode::Auto)
}

/// [`solve_full_warm_ctx`] with an explicit SIMD policy.
#[allow(clippy::too_many_arguments)]
#[deprecated(note = "use `sweep::solve` with `SolveOptions::ctx`/`simd`")]
pub fn solve_full_warm_ctx_simd(
    prob: &OtProblem,
    method: Method,
    gamma: f64,
    rho: f64,
    r: usize,
    lbfgs: LbfgsOptions,
    x0: Option<&[f64]>,
    ctx: &ParallelCtx,
    simd: SimdMode,
) -> FastOtResult {
    solve_full_inner(prob, method, gamma, rho, r, lbfgs, x0, ctx, simd)
}

/// Solve one (method, γ, ρ) job under `opts` and fold the result into a
/// [`SweepRecord`] — the sweep loop's per-job entry.
pub fn run_job_opts(prob: &OtProblem, method: Method, opts: &SolveOptions) -> Result<SweepRecord> {
    // `sweep.job` failpoint: chaos tests inject per-job failures here so
    // the coordinator's surfacing path (structured error out of the
    // grid, never a dead worker) stays covered. A panic action unwinds
    // through the job pool exactly like a real solver bug would.
    crate::fault::check(crate::fault::sites::SWEEP_JOB)?;
    let res = solve(prob, method, opts)?;
    Ok(SweepRecord {
        method,
        gamma: opts.gamma,
        rho: opts.rho,
        wall_time_s: res.wall_time_s,
        dual_objective: res.dual_objective,
        iterations: res.iterations,
        grads_computed: res.stats.grads_computed,
        grads_skipped: res.stats.grads_skipped,
    })
}

/// Solve one (method, γ, ρ) job on a prepared problem.
pub fn run_job(
    prob: &OtProblem,
    method: Method,
    gamma: f64,
    rho: f64,
    r: usize,
    max_iters: usize,
) -> SweepRecord {
    run_job_inner(prob, method, gamma, rho, r, max_iters, &ParallelCtx::new(1))
}

/// [`run_job`] with `threads` intra-solve oracle workers per job.
#[allow(clippy::too_many_arguments)]
#[deprecated(note = "use `sweep::run_job_opts` with `SolveOptions::threads`")]
pub fn run_job_threads(
    prob: &OtProblem,
    method: Method,
    gamma: f64,
    rho: f64,
    r: usize,
    max_iters: usize,
    threads: usize,
) -> SweepRecord {
    run_job_inner(prob, method, gamma, rho, r, max_iters, &ParallelCtx::new(threads))
}

/// [`run_job`] over a caller-provided long-lived parallel context.
#[allow(clippy::too_many_arguments)]
#[deprecated(note = "use `sweep::run_job_opts` with `SolveOptions::ctx`")]
pub fn run_job_ctx(
    prob: &OtProblem,
    method: Method,
    gamma: f64,
    rho: f64,
    r: usize,
    max_iters: usize,
    ctx: &ParallelCtx,
) -> SweepRecord {
    run_job_inner(prob, method, gamma, rho, r, max_iters, ctx)
}

/// Legacy-shaped job core (group lasso pinned, panics on error — the
/// pre-trait contract).
fn run_job_inner(
    prob: &OtProblem,
    method: Method,
    gamma: f64,
    rho: f64,
    r: usize,
    max_iters: usize,
    ctx: &ParallelCtx,
) -> SweepRecord {
    let res = solve_full_inner(
        prob,
        method,
        gamma,
        rho,
        r,
        LbfgsOptions { max_iters, ..Default::default() },
        None,
        ctx,
        SimdMode::Auto,
    );
    SweepRecord {
        method,
        gamma,
        rho,
        wall_time_s: res.wall_time_s,
        dual_objective: res.dual_objective,
        iterations: res.iterations,
        grads_computed: res.stats.grads_computed,
        grads_skipped: res.stats.grads_skipped,
    }
}

/// Run the full grid described by `cfg`. When `cfg.threads > 1`, jobs
/// run concurrently; each job additionally uses `cfg.solve.threads`
/// intra-solve oracle workers (deterministic — wall times change, the
/// records never do). The caller owns the `threads × solve.threads`
/// core budget; the serving engine clamps it, the sweep trusts the
/// config. Every job solves with `cfg.solve.regularizer` (γ/ρ come
/// from the grid).
pub fn run_sweep(cfg: &SweepConfig, metrics: &Metrics) -> Result<SweepReport> {
    for m in &cfg.methods {
        m.ensure_available()?;
    }
    let pair = build_pair(&cfg.dataset)?;
    // The dataset-level cost selection wins over the solve-level one
    // (same precedence as the serving engine); both backends produce
    // byte-identical records, so this only moves the memory footprint.
    let prob = Arc::new(OtProblem::try_from_dataset_mode(
        &pair,
        cfg.dataset.effective_cost(cfg.solve.cost),
    )?);
    let jobs: Vec<(Method, f64, f64)> = cfg
        .methods
        .iter()
        .flat_map(|&m| {
            cfg.gammas
                .iter()
                .flat_map(move |&g| cfg.rhos.iter().map(move |&r| (m, g, r)))
        })
        .collect();
    metrics.incr("sweep.jobs_total", jobs.len() as u64);

    let solve_threads = cfg.solve.threads.max(1);
    let records: Vec<SweepRecord> = if cfg.threads <= 1 {
        // One long-lived ctx (one parked worker set) reused across the
        // whole grid: the per-solve spawn cost disappears entirely.
        let ctx = ParallelCtx::new(solve_threads);
        // With `--batch-k`/`GRPOT_BATCH_K` above 1, consecutive
        // same-method group-lasso jobs — the (γ, ρ) grid's natural
        // shape — coalesce into K-lane batched solves
        // ([`crate::ot::batch::solve_batched`]). Records stay
        // byte-identical to sequential ones (the batched oracle's hard
        // contract); only the wall clock moves.
        let batch_k = cfg.solve.resolve_batch_k()?;
        let batchable = batch_k > 1 && cfg.solve.resolve_regularizer()? == RegKind::GroupLasso;
        let mut recs = Vec::with_capacity(jobs.len());
        let mut i = 0;
        while i < jobs.len() {
            let (m, g, r) = jobs[i];
            let mut run = 1;
            if batchable && matches!(m, Method::Fast | Method::FastNoWs) {
                while run < batch_k && i + run < jobs.len() && jobs[i + run].0 == m {
                    run += 1;
                }
            }
            if run > 1 {
                // Per-job failpoint parity with `run_job_opts`.
                for _ in 0..run {
                    crate::fault::check(crate::fault::sites::SWEEP_JOB)?;
                }
                let group: Vec<SolveOptions> = jobs[i..i + run]
                    .iter()
                    .map(|&(_, g, r)| {
                        cfg.solve
                            .clone()
                            .gamma(g)
                            .rho(r)
                            .ctx(ctx.clone())
                            .working_set(m != Method::FastNoWs)
                    })
                    .collect();
                let results = crate::ot::batch::solve_batched(&prob, &group)?;
                for (&(_, g, r), res) in jobs[i..i + run].iter().zip(results) {
                    let rec = SweepRecord {
                        method: m,
                        gamma: g,
                        rho: r,
                        wall_time_s: res.wall_time_s,
                        dual_objective: res.dual_objective,
                        iterations: res.iterations,
                        grads_computed: res.stats.grads_computed,
                        grads_skipped: res.stats.grads_skipped,
                    };
                    metrics.incr("sweep.jobs_done", 1);
                    metrics.observe("sweep.job_seconds", rec.wall_time_s);
                    recs.push(rec);
                }
            } else {
                let opts = cfg.solve.clone().gamma(g).rho(r).ctx(ctx.clone());
                let rec = run_job_opts(&prob, m, &opts)?;
                metrics.incr("sweep.jobs_done", 1);
                metrics.observe("sweep.job_seconds", rec.wall_time_s);
                recs.push(rec);
            }
            i += run;
        }
        recs
    } else {
        let results = Arc::new(Mutex::new(Vec::with_capacity(jobs.len())));
        let pool = ThreadPool::new(cfg.threads);
        for &(m, g, r) in &jobs {
            let prob = Arc::clone(&prob);
            let results = Arc::clone(&results);
            // Concurrent jobs must not share one ctx (its dispatch
            // serializes), so each job owns a solve-lifetime ctx;
            // the parked set still amortizes over every eval of
            // that solve.
            let mut opts = cfg.solve.clone().gamma(g).rho(r).threads(solve_threads);
            opts.ctx = None;
            pool.execute(move || {
                let rec = run_job_opts(&prob, m, &opts);
                results.lock().unwrap().push(rec);
            });
        }
        pool.join();
        let recs = Arc::try_unwrap(results).unwrap().into_inner().unwrap();
        let mut recs = recs.into_iter().collect::<Result<Vec<SweepRecord>>>()?;
        // Deterministic order for reports.
        recs.sort_by(|a, b| {
            (a.method.name(), a.gamma, a.rho)
                .partial_cmp(&(b.method.name(), b.gamma, b.rho))
                .unwrap()
        });
        metrics.incr("sweep.jobs_done", recs.len() as u64);
        recs
    };

    Ok(aggregate(cfg, records))
}

/// Aggregate records into per-γ totals, gains, and max objectives.
pub fn aggregate(cfg: &SweepConfig, records: Vec<SweepRecord>) -> SweepReport {
    let mut aggregates = Vec::new();
    for &gamma in &cfg.gammas {
        let mut totals = Vec::new();
        for &m in &cfg.methods {
            let total: f64 = records
                .iter()
                .filter(|r| r.method == m && r.gamma == gamma)
                .map(|r| r.wall_time_s)
                .sum();
            totals.push((m, total));
        }
        let t_fast = totals
            .iter()
            .find(|(m, _)| *m == Method::Fast)
            .map(|&(_, t)| t);
        let t_origin = totals
            .iter()
            .find(|(m, _)| *m == Method::Origin)
            .map(|&(_, t)| t);
        let gain = match (t_fast, t_origin) {
            (Some(f), Some(o)) if f > 0.0 => Some(o / f),
            _ => None,
        };
        aggregates.push(GammaAggregate { gamma, totals, gain });
    }
    let max_objective = cfg
        .methods
        .iter()
        .map(|&m| {
            let best = records
                .iter()
                .filter(|r| r.method == m)
                .map(|r| r.dual_objective)
                .fold(f64::NEG_INFINITY, f64::max);
            (m, best)
        })
        .collect();
    SweepReport { records, aggregates, max_objective }
}

impl SweepReport {
    /// Full JSON report (records + aggregates).
    pub fn to_json(&self) -> Value {
        let recs: Vec<Value> = self.records.iter().map(|r| r.to_json()).collect();
        let aggs: Vec<Value> = self
            .aggregates
            .iter()
            .map(|a| {
                let mut v = Value::obj().set("gamma", a.gamma);
                for (m, t) in &a.totals {
                    v = v.set(&format!("total_s_{}", m.name()), *t);
                }
                if let Some(g) = a.gain {
                    v = v.set("gain", g);
                }
                v
            })
            .collect();
        Value::obj()
            .set("records", Value::Arr(recs))
            .set("aggregates", Value::Arr(aggs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::DatasetSpec;

    fn tiny_cfg(threads: usize) -> SweepConfig {
        SweepConfig {
            dataset: DatasetSpec {
                family: "synthetic".into(),
                param1: 3,
                param2: 4,
                ..Default::default()
            },
            gammas: vec![0.1, 1.0],
            rhos: vec![0.4, 0.8],
            methods: vec![Method::Fast, Method::Origin],
            threads,
            // Pin the regularizer so a `GRPOT_REG` override in the
            // environment cannot re-route this determinism check.
            solve: SolveOptions::new().r(5).max_iters(60).regularizer(RegKind::GroupLasso),
        }
    }

    #[test]
    fn sweep_covers_grid_and_matches_theorem2() {
        let metrics = Metrics::new();
        let report = run_sweep(&tiny_cfg(1), &metrics).unwrap();
        assert_eq!(report.records.len(), 2 * 2 * 2);
        assert_eq!(metrics.get("sweep.jobs_done"), 8);
        // Theorem 2 on every grid point: identical objectives.
        for &gamma in &[0.1, 1.0] {
            for &rho in &[0.4, 0.8] {
                let find = |m: Method| {
                    report
                        .records
                        .iter()
                        .find(|r| r.method == m && r.gamma == gamma && r.rho == rho)
                        .unwrap()
                };
                let f = find(Method::Fast);
                let o = find(Method::Origin);
                assert_eq!(f.dual_objective, o.dual_objective);
                assert_eq!(f.iterations, o.iterations);
            }
        }
        // Aggregates carry gains.
        for a in &report.aggregates {
            assert!(a.gain.is_some());
        }
        // Table-1 check: same max objective for both methods.
        let fast_max = report.max_objective.iter().find(|(m, _)| *m == Method::Fast).unwrap().1;
        let orig_max = report.max_objective.iter().find(|(m, _)| *m == Method::Origin).unwrap().1;
        assert_eq!(fast_max, orig_max);
    }

    #[test]
    fn batched_serial_sweep_matches_sequential_records() {
        let metrics = Metrics::new();
        let base = run_sweep(&tiny_cfg(1), &metrics).unwrap();
        let mut cfg = tiny_cfg(1);
        cfg.solve = cfg.solve.batch_k(4);
        let batched = run_sweep(&cfg, &metrics).unwrap();
        // The fast method's 4 grid jobs ride one 4-lane batched solve;
        // origin stays sequential. Every record field except wall time
        // must be byte-identical.
        assert_eq!(base.records.len(), batched.records.len());
        for (s, b) in base.records.iter().zip(&batched.records) {
            assert_eq!(s.method, b.method);
            assert_eq!(s.gamma, b.gamma);
            assert_eq!(s.rho, b.rho);
            assert_eq!(s.dual_objective.to_bits(), b.dual_objective.to_bits());
            assert_eq!(s.iterations, b.iterations);
            assert_eq!(s.grads_computed, b.grads_computed);
            assert_eq!(s.grads_skipped, b.grads_skipped);
        }
    }

    #[test]
    fn threaded_sweep_matches_serial_objectives() {
        let metrics = Metrics::new();
        let serial = run_sweep(&tiny_cfg(1), &metrics).unwrap();
        let threaded = run_sweep(&tiny_cfg(4), &metrics).unwrap();
        assert_eq!(serial.records.len(), threaded.records.len());
        // Wall times differ; objectives must not.
        let key = |r: &SweepRecord| (r.method.name(), r.gamma.to_bits(), r.rho.to_bits());
        let mut s: Vec<_> = serial.records.iter().map(|r| (key(r), r.dual_objective)).collect();
        let mut t: Vec<_> = threaded.records.iter().map(|r| (key(r), r.dual_objective)).collect();
        s.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        t.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        assert_eq!(s, t);
    }

    #[test]
    fn intra_solve_threads_do_not_change_records() {
        // solve_threads only adds oracle workers inside each job; the
        // deterministic ordered reduction keeps every record bit-equal.
        let metrics = Metrics::new();
        let serial = run_sweep(&tiny_cfg(1), &metrics).unwrap();
        let mut cfg = tiny_cfg(1);
        cfg.solve.threads = 4;
        let threaded = run_sweep(&cfg, &metrics).unwrap();
        for (s, t) in serial.records.iter().zip(&threaded.records) {
            assert_eq!(s.method, t.method);
            assert_eq!(s.dual_objective, t.dual_objective);
            assert_eq!(s.iterations, t.iterations);
            assert_eq!(s.grads_computed, t.grads_computed);
            assert_eq!(s.grads_skipped, t.grads_skipped);
        }
    }

    #[test]
    fn factored_cost_sweep_matches_dense_records() {
        let metrics = Metrics::new();
        let dense = run_sweep(&tiny_cfg(1), &metrics).unwrap();
        let mut cfg = tiny_cfg(1);
        cfg.dataset.cost = crate::ot::cost::CostMode::Factored;
        let factored = run_sweep(&cfg, &metrics).unwrap();
        assert_eq!(dense.records.len(), factored.records.len());
        for (d, f) in dense.records.iter().zip(&factored.records) {
            assert_eq!(d.method, f.method);
            assert_eq!(d.dual_objective.to_bits(), f.dual_objective.to_bits());
            assert_eq!(d.iterations, f.iterations);
            assert_eq!(d.grads_computed, f.grads_computed);
            assert_eq!(d.grads_skipped, f.grads_skipped);
        }
    }

    #[test]
    fn report_json_shape() {
        let metrics = Metrics::new();
        let mut cfg = tiny_cfg(1);
        cfg.gammas = vec![1.0];
        cfg.rhos = vec![0.5];
        let report = run_sweep(&cfg, &metrics).unwrap();
        let v = report.to_json();
        assert_eq!(v.get("records").unwrap().as_arr().unwrap().len(), 2);
        let agg = &v.get("aggregates").unwrap().as_arr().unwrap()[0];
        assert!(agg.get("gain").unwrap().as_f64().unwrap() > 0.0);
    }
}
