//! Optimal-transport core.
//!
//! * [`dual`] — the smooth relaxed dual of group-sparse regularized OT
//!   (Problem 4 of the paper) and the [`dual::DualOracle`] abstraction.
//! * [`origin`] — the dense baseline oracle (Blondel, Seguy & Rolet 2018).
//! * [`screening`] — the paper's contribution: upper-bound skipping
//!   (Lemmas 1–3) and the lower-bound working set (Lemmas 4–6).
//! * [`fastot`] — Algorithm 1: the outer driver interleaving r solver
//!   iterations with snapshot/working-set refreshes.
//! * [`plan`] — transport-plan recovery and sparsity/marginal metrics.
//! * [`sinkhorn`] — entropic OT baselines (Cuturi 2013; Courty et al.
//!   2017 ℓ1ℓ2 group regularization via generalized conditional
//!   gradient).
//! * [`emd`] — exact LP optimal transport via network simplex.
//! * [`semidual`] — the semi-dual formulation (extension).
//! * [`pack`] — packed cost tiles for the SIMD column-lane kernels
//!   ([`crate::simd`]).
//! * [`cost`] — cost-matrix backends: the resident dense matrix and the
//!   factored squared-ℓ2 form (coordinates + norms, O((m+n)·d) memory)
//!   that synthesizes tiles on demand through a per-chunk
//!   [`cost::TileRing`].
//! * [`regularizer`] — the pluggable [`regularizer::Regularizer`] /
//!   [`regularizer::ScreeningRule`] traits: group lasso (the paper's,
//!   byte-identical behind the trait), squared ℓ2 and negative entropy.
//! * [`solve`] — the unified [`solve::SolveOptions`] builder consumed
//!   by one `solve(problem, &opts)` entry per solver family.
//! * [`batch`] — solve-many-at-once: K independent (γ, ρ, warm-start)
//!   problems over one [`dual::OtProblem`] evaluated in lockstep
//!   through a fused oracle pass ([`screening::BatchedOracle`]), each
//!   lane byte-identical to its sequential solve.

pub mod batch;
pub mod cost;
pub mod dual;
pub mod emd;
pub mod fastot;
pub mod origin;
pub mod pack;
pub mod plan;
pub mod regularizer;
pub mod screening;
pub mod semidual;
pub mod sinkhorn;
pub mod solve;
