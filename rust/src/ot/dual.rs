//! Smooth relaxed dual of group-sparse regularized discrete OT.
//!
//! Primal (Problem 2, with the experimental-section parametrization):
//!
//! ```text
//! min_{T ∈ U(a,b)} ⟨T, C⟩ + Σ_j Ψ(t_j),
//! Ψ(t) = γ ( ½(1−ρ)‖t‖₂² + ρ Σ_l ‖t_[l]‖₂ )
//!      = ½ λ_quad ‖t‖₂² + τ Σ_l ‖t_[l]‖₂,   λ_quad = γ(1−ρ), τ = γρ.
//! ```
//!
//! Dual (Problem 4): `max_{α,β} αᵀa + βᵀb − Σ_j ψ(α + β_j 1_m − c_j)`
//! with the conjugate in closed form. Writing `f = α + β_j 1 − c_j` and
//! `z_{l,j} = ‖[f_[l]]₊‖₂` (Definition 1):
//!
//! ```text
//! ψ(f)      = Σ_l [z_{l,j} − τ]₊² / (2 λ_quad)
//! ∇ψ(f)_[l] = [1 − τ/z_{l,j}]₊ [f_[l]]₊ / λ_quad        (Eq. 5)
//! ```
//!
//! so a group contributes to neither value nor gradient when
//! `z_{l,j} ≤ τ` — the fact both the dense baseline and the screening
//! method exploit. Solvers *minimize* the negated dual.

use crate::data::DomainPair;
use crate::groups::GroupStructure;
use crate::linalg::{self, Mat};
use crate::pool::{fixed_chunk_ranges, ParallelCtx};
use std::ops::Range;

/// Regularization hyperparameters (experimental-section form).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DualParams {
    /// Overall regularization strength γ > 0.
    pub gamma: f64,
    /// Balance ρ ∈ (0, 1): ρ→0 pure quadratic, ρ→1 pure group-lasso.
    pub rho: f64,
}

impl DualParams {
    pub fn new(gamma: f64, rho: f64) -> Self {
        let p = DualParams { gamma, rho };
        p.validate();
        p
    }

    pub fn validate(&self) {
        assert!(self.gamma > 0.0, "gamma must be positive");
        assert!(
            self.rho >= 0.0 && self.rho < 1.0,
            "rho must lie in [0, 1); rho=1 makes the conjugate degenerate"
        );
    }

    /// Quadratic coefficient `λ_quad = γ(1−ρ)`.
    #[inline]
    pub fn lambda_quad(&self) -> f64 {
        self.gamma * (1.0 - self.rho)
    }

    /// Group-lasso coefficient and skip threshold `τ = γρ` (the paper's `μγ`).
    #[inline]
    pub fn tau(&self) -> f64 {
        self.gamma * self.rho
    }

    /// The paper's `μ` (Eq. 3) for this (γ, ρ).
    pub fn mu(&self) -> f64 {
        self.rho / (1.0 - self.rho)
    }
}

impl Default for DualParams {
    fn default() -> Self {
        DualParams { gamma: 1.0, rho: 0.5 }
    }
}

/// A regularized-OT instance: marginals, cost and group structure.
///
/// The cost matrix is stored **transposed** (`n×m`): the dual oracles
/// walk column `j` of `C` in the inner loop, so row `j` of `cost_t`
/// keeps that access contiguous. Source samples are in *sorted
/// (grouped)* order; `groups.perm` maps back to the caller's order.
#[derive(Clone, Debug)]
pub struct OtProblem {
    /// Source marginal `a` (length m, sums to 1).
    pub a: Vec<f64>,
    /// Target marginal `b` (length n, sums to 1).
    pub b: Vec<f64>,
    /// Transposed cost: `cost_t[(j, i)] = c(x_S_i, x_T_j)`, sorted order.
    pub cost_t: Mat,
    /// Group partition of the (sorted) source samples.
    pub groups: GroupStructure,
}

impl OtProblem {
    /// Build from a labeled source / unlabeled target pair with squared
    /// Euclidean costs normalized by the max entry (standard practice;
    /// gives γ a dataset-independent scale).
    pub fn from_dataset(pair: &DomainPair) -> OtProblem {
        let groups = GroupStructure::from_labels(&pair.source.labels);
        // Permute source rows into grouped order.
        let d = pair.source.x.cols();
        let xs = Mat::from_fn(groups.num_samples(), d, |k, c| {
            pair.source.x[(groups.perm[k], c)]
        });
        let mut cost = linalg::sq_euclidean_cost(&xs, &pair.target.x);
        linalg::normalize_by_max(&mut cost);
        let m = xs.rows();
        let n = pair.target.x.rows();
        OtProblem {
            a: vec![1.0 / m as f64; m],
            b: vec![1.0 / n as f64; n],
            cost_t: cost.transpose(),
            groups,
        }
    }

    /// Build from explicit parts. `cost` is `m×n` in the *original*
    /// source order; rows are permuted into grouped order internally.
    pub fn from_parts(a: Vec<f64>, b: Vec<f64>, cost: &Mat, labels: &[usize]) -> OtProblem {
        let m = cost.rows();
        let n = cost.cols();
        assert_eq!(a.len(), m);
        assert_eq!(b.len(), n);
        assert_eq!(labels.len(), m);
        let groups = GroupStructure::from_labels(labels);
        let mut cost_t = Mat::zeros(n, m);
        for j in 0..n {
            let row = cost_t.row_mut(j);
            for (k, &orig) in groups.perm.iter().enumerate() {
                row[k] = cost[(orig, j)];
            }
        }
        let a_perm = groups.permute(&a);
        OtProblem { a: a_perm, b, cost_t, groups }
    }

    #[inline]
    pub fn m(&self) -> usize {
        self.a.len()
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.b.len()
    }

    /// Dual variable dimension `m + n`.
    #[inline]
    pub fn dim(&self) -> usize {
        self.m() + self.n()
    }

    /// Dense `m×n` cost in sorted-source order (copies; tests/baselines).
    pub fn cost(&self) -> Mat {
        self.cost_t.transpose()
    }
}

/// Counters shared by all oracles. A "group gradient computation" is one
/// evaluation of `∇ψ(·)_[l]` for a single `(l, j)` — the unit the paper
/// counts in Figures 6 and C.
#[derive(Clone, Debug, Default)]
pub struct OracleStats {
    /// Number of `eval` calls (function+gradient evaluations).
    pub evals: u64,
    /// Exact group gradients computed.
    pub grads_computed: u64,
    /// Group gradients skipped via the upper bound.
    pub grads_skipped: u64,
    /// Upper bounds evaluated (the overhead the working set removes).
    pub ub_checks: u64,
    /// Group gradients routed through the working set ℕ.
    pub ws_hits: u64,
    /// Per-eval history of `grads_computed` deltas (Fig. C).
    pub per_eval_grads: Vec<u64>,
}

impl OracleStats {
    pub fn record_eval(&mut self, grads_this_eval: u64) {
        self.evals += 1;
        self.per_eval_grads.push(grads_this_eval);
    }
}

/// A (value, gradient) oracle for the negated dual, `x = [α; β]`.
///
/// Implementations: [`crate::ot::origin::OriginOracle`] (dense),
/// [`crate::ot::screening::ScreeningOracle`] (the paper's method) and,
/// behind the `xla` feature, `crate::runtime::XlaDualOracle` (AOT
/// JAX/Pallas via PJRT).
pub trait DualOracle {
    /// Problem dimensions `(m, n)`.
    fn shape(&self) -> (usize, usize);

    /// Evaluate the negated dual at `x = [α; β]`, writing its gradient
    /// into `grad` (same length). Returns the objective value.
    fn eval(&mut self, x: &[f64], grad: &mut [f64]) -> f64;

    /// Called by the Algorithm-1 driver after each `r`-iteration block
    /// with the current iterate (snapshot + working-set refresh point).
    /// Dense oracles may ignore it.
    fn refresh(&mut self, _x: &[f64]) {}

    /// Counter access.
    fn stats(&self) -> &OracleStats;
}

/// Compute `ψ` and `∇ψ` contributions of one `(group, column)` pair and
/// accumulate into the gradient. Returns the pair's ψ value.
///
/// This is THE inner kernel: both the dense baseline and the screening
/// method call this exact function for every non-skipped pair, which is
/// what makes Theorem 2 (identical trajectories) hold bit-for-bit.
///
/// `grad_alpha` is the α-part of the negated-dual gradient; the returned
/// `col_mass` (Σ_i t_ij over this group) must be added to `∂/∂β_j`.
#[inline]
pub fn group_grad_contrib(
    alpha: &[f64],
    beta_j: f64,
    c_j: &[f64],
    range: std::ops::Range<usize>,
    tau: f64,
    lambda_quad: f64,
    grad_alpha: &mut [f64],
    scratch: &mut [f64],
) -> (f64, f64) {
    // Pass 1: materialize [f]₊ into scratch and accumulate z².
    let start = range.start;
    let g = range.len();
    debug_assert!(scratch.len() >= g);
    let mut zsq = 0.0;
    for (k, i) in range.clone().enumerate() {
        let f = alpha[i] + beta_j - c_j[i];
        let fp = if f > 0.0 { f } else { 0.0 };
        // Branchless store keeps the loop tight; zsq only sums positives.
        scratch[k] = fp;
        zsq += fp * fp;
    }
    let z = zsq.sqrt();
    if z <= tau {
        return (0.0, 0.0);
    }
    // Pass 2: t = scale · [f]₊ from scratch (no recomputation of f).
    let scale = (z - tau) / (lambda_quad * z);
    let mut col_mass = 0.0;
    for k in 0..g {
        let t = scale * scratch[k];
        grad_alpha[start + k] += t;
        col_mass += t;
    }
    let slack = z - tau;
    (slack * slack / (2.0 * lambda_quad), col_mass)
}

/// `z_{l,j} = ‖[ (α + β_j 1 − c_j)_[l] ]₊‖₂` for one pair (used by
/// diagnostics and tests; the hot path inlines it).
pub fn exact_z(
    alpha: &[f64],
    beta_j: f64,
    c_j: &[f64],
    range: std::ops::Range<usize>,
) -> f64 {
    let mut zsq = 0.0;
    for i in range {
        let f = alpha[i] + beta_j - c_j[i];
        if f > 0.0 {
            zsq += f * f;
        }
    }
    zsq.sqrt()
}

/// Per-chunk scratch for the column-parallel oracle evaluations: a
/// partial α-gradient, per-column transported masses, the group kernel
/// buffer and partial counters. The oracles keep one of these per fixed
/// column chunk, reused across evaluations, so the steady state stays
/// allocation-free at any thread count.
pub struct ColChunkScratch {
    /// This chunk's α-gradient contribution (length m, zeroed per eval).
    pub(crate) grad_alpha: Vec<f64>,
    /// Per-column `Σ_i t_ij` for the chunk's columns (→ `∂/∂β_j`).
    pub(crate) col_mass: Vec<f64>,
    /// [`group_grad_contrib`] scratch (max group size).
    pub(crate) group: Vec<f64>,
    /// Partial `Σ ψ` over this chunk's (l, j) pairs.
    pub(crate) psi: f64,
    pub(crate) grads: u64,
    pub(crate) skipped: u64,
    pub(crate) ub_checks: u64,
    pub(crate) ws_hits: u64,
}

impl ColChunkScratch {
    pub(crate) fn new(m: usize, max_cols: usize, max_group: usize) -> Self {
        ColChunkScratch {
            grad_alpha: vec![0.0; m],
            col_mass: vec![0.0; max_cols],
            group: vec![0.0; max_group],
            psi: 0.0,
            grads: 0,
            skipped: 0,
            ub_checks: 0,
            ws_hits: 0,
        }
    }

    /// One scratch slot per chunk of `ranges`, sized for `prob`.
    pub(crate) fn slots_for(prob: &OtProblem, ranges: &[Range<usize>]) -> Vec<ColChunkScratch> {
        let max_cols = ranges.iter().map(|r| r.len()).max().unwrap_or(0);
        (0..ranges.len())
            .map(|_| ColChunkScratch::new(prob.m(), max_cols, prob.groups.max_size()))
            .collect()
    }

    /// Zero the accumulators (col_mass is fully overwritten per eval).
    /// `grad_alpha` is only dirtied by [`group_grad_contrib`], which
    /// writes iff it counts a gradient, so a chunk whose previous eval
    /// computed nothing skips the O(m) re-zero — the screened sparse
    /// regime keeps its cheap per-eval floor.
    pub(crate) fn reset(&mut self) {
        if self.grads > 0 {
            for v in self.grad_alpha.iter_mut() {
                *v = 0.0;
            }
        }
        self.psi = 0.0;
        self.grads = 0;
        self.skipped = 0;
        self.ub_checks = 0;
        self.ws_hits = 0;
    }
}

/// Dense per-column kernel over one fixed column chunk, accumulating
/// into the chunk's scratch. The reference [`eval_dense`] and the
/// threaded [`crate::ot::origin::OriginOracle`] both run this exact
/// function over the exact same chunk boundaries, so serial and
/// threaded evaluations agree bit-for-bit.
pub(crate) fn dense_chunk(
    prob: &OtProblem,
    tau: f64,
    lq: f64,
    alpha: &[f64],
    beta: &[f64],
    range: Range<usize>,
    slot: &mut ColChunkScratch,
) {
    slot.reset();
    let num_groups = prob.groups.num_groups();
    for (k, j) in range.enumerate() {
        let c_j = prob.cost_t.row(j);
        let beta_j = beta[j];
        let mut col_mass = 0.0;
        for l in 0..num_groups {
            let (psi, mass) = group_grad_contrib(
                alpha,
                beta_j,
                c_j,
                prob.groups.range(l),
                tau,
                lq,
                &mut slot.grad_alpha,
                &mut slot.group,
            );
            slot.psi += psi;
            col_mass += mass;
            slot.grads += 1;
        }
        slot.col_mass[k] = col_mass;
    }
}

/// Combine per-chunk partials into the shared gradient **in ascending
/// chunk order** — the deterministic reduction: the association of every
/// floating-point sum is fixed by the chunk boundaries (a function of n
/// alone), never by which thread produced a partial. Returns
/// `(psi_total, grads, skipped, ub_checks, ws_hits)`.
pub(crate) fn reduce_chunks(
    ranges: &[Range<usize>],
    slots: &[ColChunkScratch],
    grad_alpha: &mut [f64],
    grad_beta: &mut [f64],
) -> (f64, u64, u64, u64, u64) {
    let mut psi_total = 0.0;
    let (mut grads, mut skipped, mut ub_checks, mut ws_hits) = (0u64, 0u64, 0u64, 0u64);
    for (range, slot) in ranges.iter().zip(slots) {
        // A chunk that computed nothing holds exact zeros everywhere:
        // merging it would only add +0.0 terms (values unchanged under
        // `==`; the decision itself is thread-count-independent), so the
        // screened sparse regime skips the O(m) merge per quiet chunk.
        if slot.grads > 0 {
            psi_total += slot.psi;
            for (gi, &pi) in grad_alpha.iter_mut().zip(&slot.grad_alpha) {
                *gi += pi;
            }
            for (k, j) in range.clone().enumerate() {
                grad_beta[j] += slot.col_mass[k];
            }
        }
        grads += slot.grads;
        skipped += slot.skipped;
        ub_checks += slot.ub_checks;
        ws_hits += slot.ws_hits;
    }
    (psi_total, grads, skipped, ub_checks, ws_hits)
}

/// Shared dense evaluation over caller-provided chunking/scratch — the
/// zero-alloc entry used by [`crate::ot::origin::OriginOracle`].
pub(crate) fn eval_dense_with(
    prob: &OtProblem,
    params: &DualParams,
    x: &[f64],
    grad: &mut [f64],
    ctx: ParallelCtx,
    ranges: &[Range<usize>],
    slots: &mut [ColChunkScratch],
) -> (f64, u64) {
    let m = prob.m();
    let n = prob.n();
    assert_eq!(x.len(), m + n);
    assert_eq!(grad.len(), m + n);
    let (alpha, beta) = x.split_at(m);
    let tau = params.tau();
    let lq = params.lambda_quad();

    // ∇(−D) starts at (−a, −b); transport mass is added on top.
    for (gi, &ai) in grad[..m].iter_mut().zip(&prob.a) {
        *gi = -ai;
    }
    for (gj, &bj) in grad[m..].iter_mut().zip(&prob.b) {
        *gj = -bj;
    }
    let (grad_alpha, grad_beta) = grad.split_at_mut(m);

    ctx.map_chunks(ranges, slots, |_, range, slot| {
        dense_chunk(prob, tau, lq, alpha, beta, range, slot);
    });
    let (psi_total, grads, ..) = reduce_chunks(ranges, slots, grad_alpha, grad_beta);

    let dual = linalg::dot(alpha, &prob.a) + linalg::dot(beta, &prob.b) - psi_total;
    (-dual, grads)
}

/// Fully dense negated-dual evaluation — the reference implementation
/// every oracle must agree with. O(mn) per call.
///
/// The accumulation is *chunk-ordered*: columns are processed in the
/// fixed chunks of [`fixed_chunk_ranges`] and per-chunk partial sums are
/// combined in chunk order. This is the canonical arithmetic for the
/// whole crate — the screened oracle and the threaded dense oracle
/// reproduce it bit-for-bit at every thread count.
pub fn eval_dense(
    prob: &OtProblem,
    params: &DualParams,
    x: &[f64],
    grad: &mut [f64],
) -> (f64, u64) {
    eval_dense_threads(prob, params, x, grad, 1)
}

/// [`eval_dense`] with `threads` oracle workers — bit-identical to the
/// serial call for every thread count (deterministic ordered reduction).
pub fn eval_dense_threads(
    prob: &OtProblem,
    params: &DualParams,
    x: &[f64],
    grad: &mut [f64],
    threads: usize,
) -> (f64, u64) {
    let ranges = fixed_chunk_ranges(prob.n());
    let mut slots = ColChunkScratch::slots_for(prob, &ranges);
    eval_dense_with(prob, params, x, grad, ParallelCtx::new(threads), &ranges, &mut slots)
}

/// The (positive) dual objective at `x` (no gradient).
pub fn dual_objective(prob: &OtProblem, params: &DualParams, x: &[f64]) -> f64 {
    let mut grad = vec![0.0; x.len()];
    -eval_dense(prob, params, x, &mut grad).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn toy_problem() -> OtProblem {
        // 4 source samples in 2 groups, 3 targets.
        let cost = Mat::from_vec(
            4,
            3,
            vec![
                0.1, 0.9, 0.5, //
                0.2, 0.8, 0.4, //
                0.9, 0.1, 0.5, //
                0.8, 0.2, 0.6,
            ],
        );
        OtProblem::from_parts(
            vec![0.25; 4],
            vec![1.0 / 3.0; 3],
            &cost,
            &[0, 0, 1, 1],
        )
    }

    #[test]
    fn params_mapping() {
        let p = DualParams::new(2.0, 0.25);
        assert!((p.lambda_quad() - 1.5).abs() < 1e-15);
        assert!((p.tau() - 0.5).abs() < 1e-15);
        assert!((p.mu() - (1.0 / 3.0)).abs() < 1e-15);
    }

    #[test]
    #[should_panic]
    fn rho_one_rejected() {
        DualParams::new(1.0, 1.0);
    }

    #[test]
    fn problem_shapes() {
        let p = toy_problem();
        assert_eq!(p.m(), 4);
        assert_eq!(p.n(), 3);
        assert_eq!(p.dim(), 7);
        assert_eq!(p.cost_t.shape(), (3, 4));
        assert_eq!(p.cost().shape(), (4, 3));
        assert_eq!(p.groups.num_groups(), 2);
    }

    #[test]
    fn eval_zero_point() {
        // At α=β=0 and c ≥ 0: every f = −c ≤ 0, so ψ = 0 and T = 0.
        let p = toy_problem();
        let params = DualParams::new(1.0, 0.5);
        let x = vec![0.0; p.dim()];
        let mut g = vec![0.0; p.dim()];
        let (negd, _) = eval_dense(&p, &params, &x, &mut g);
        assert!((negd - 0.0).abs() < 1e-15);
        // Gradient is (−a, −b).
        for i in 0..p.m() {
            assert!((g[i] + p.a[i]).abs() < 1e-15);
        }
        for j in 0..p.n() {
            assert!((g[p.m() + j] + p.b[j]).abs() < 1e-15);
        }
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let p = toy_problem();
        let params = DualParams::new(0.7, 0.3);
        let mut rng = Pcg64::new(42);
        let x: Vec<f64> = (0..p.dim()).map(|_| rng.uniform(-0.5, 0.8)).collect();
        let mut g = vec![0.0; p.dim()];
        let (f0, _) = eval_dense(&p, &params, &x, &mut g);
        let eps = 1e-6;
        for k in 0..p.dim() {
            let mut xp = x.clone();
            xp[k] += eps;
            let mut xm = x.clone();
            xm[k] -= eps;
            let mut scratch = vec![0.0; p.dim()];
            let (fp, _) = eval_dense(&p, &params, &xp, &mut scratch);
            let (fm, _) = eval_dense(&p, &params, &xm, &mut scratch);
            let fd = (fp - fm) / (2.0 * eps);
            assert!(
                (fd - g[k]).abs() < 1e-5,
                "component {k}: fd={fd} analytic={} f0={f0}",
                g[k]
            );
        }
    }

    #[test]
    fn psi_closed_form_matches_conjugate_definition() {
        // ψ(f) must equal sup_{g≥0} fᵀg − Ψ(g); verify against a fine
        // numeric maximization over the soft-threshold parametric form.
        let params = DualParams::new(1.3, 0.4);
        let tau = params.tau();
        let lq = params.lambda_quad();
        let f = [0.8, -0.2, 0.5, 0.1];
        // Closed form for a single group:
        let z: f64 = f.iter().filter(|&&v| v > 0.0).map(|v| v * v).sum::<f64>().sqrt();
        let closed = if z > tau { (z - tau) * (z - tau) / (2.0 * lq) } else { 0.0 };
        // Numeric: maximize over g = s·[f]₊ direction (optimal direction)
        // plus random perturbations must not beat it.
        let fplus: Vec<f64> = f.iter().map(|&v| v.max(0.0)).collect();
        let obj = |g: &[f64]| -> f64 {
            let dot: f64 = f.iter().zip(g).map(|(a, b)| a * b).sum();
            let nrm2: f64 = g.iter().map(|v| v * v).sum();
            let nrm: f64 = nrm2.sqrt();
            dot - lq / 2.0 * nrm2 - tau * nrm
        };
        let mut best = 0.0f64;
        for step in 0..2000 {
            let s = step as f64 * 1e-3;
            let g: Vec<f64> = fplus.iter().map(|&v| s * v).collect();
            best = best.max(obj(&g));
        }
        assert!((best - closed).abs() < 1e-4, "numeric={best} closed={closed}");
        // Random nonnegative candidates never exceed the closed form.
        let mut rng = Pcg64::new(7);
        for _ in 0..500 {
            let g: Vec<f64> = (0..4).map(|_| rng.uniform(0.0, 1.5)).collect();
            assert!(obj(&g) <= closed + 1e-9);
        }
    }

    #[test]
    fn group_grad_zero_below_threshold() {
        let alpha = [0.1, 0.1];
        let c = [0.0, 0.0];
        let mut ga = [0.0, 0.0];
        let mut scratch = [0.0, 0.0];
        // z = sqrt(2)*0.1 ≈ 0.141 < tau=0.5 ⇒ zero contribution.
        let (psi, mass) =
            group_grad_contrib(&alpha, 0.0, &c, 0..2, 0.5, 1.0, &mut ga, &mut scratch);
        assert_eq!(psi, 0.0);
        assert_eq!(mass, 0.0);
        assert_eq!(ga, [0.0, 0.0]);
    }

    #[test]
    fn from_parts_permutes_cost_rows() {
        // Labels out of order: sample 0 has label 1, sample 1 label 0.
        let cost = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let p = OtProblem::from_parts(vec![0.6, 0.4], vec![0.5, 0.5], &cost, &[1, 0]);
        // Sorted order: sample1 (label0) first.
        assert_eq!(p.a, vec![0.4, 0.6]);
        assert_eq!(p.cost_t[(0, 0)], 3.0); // c(sample1, target0)
        assert_eq!(p.cost_t[(0, 1)], 1.0);
        assert_eq!(p.cost_t[(1, 0)], 4.0);
        assert_eq!(p.cost_t[(1, 1)], 2.0);
    }
}
