//! Micro-batching: coalesce concurrent requests that share a dataset
//! spec, and deduplicate identical (γ, ρ, method) jobs within a batch.
//!
//! A batch pays the dataset cost (cost matrix, group structure, problem
//! cache round-trip) once; an identical-job group pays its *solve* once
//! and fans the result out to every waiter. Both effects compound under
//! load: the hotter the key, the bigger the batches, the cheaper each
//! request — the classic serving-engine shape.

use super::queue::{AdmissionQueue, Ticket};
use crate::coordinator::config::Method;
use crate::ot::regularizer::RegKind;
use std::borrow::Borrow;
use std::collections::BTreeMap;

/// A group of tickets sharing one dataset spec.
pub struct Batch {
    pub dataset_key: String,
    pub tickets: Vec<Ticket>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.tickets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tickets.is_empty()
    }
}

/// Block for the next ticket, then opportunistically drain up to
/// `max_batch − 1` already-queued tickets with the same dataset key.
/// Returns `None` once the queue is closed and drained (worker exit).
pub fn next_batch(queue: &AdmissionQueue, max_batch: usize) -> Option<Batch> {
    let first = queue.pop()?;
    let dataset_key = first.dataset_key.clone();
    let mut tickets = vec![first];
    if max_batch > 1 {
        tickets.extend(queue.drain_matching(max_batch - 1, |t| t.dataset_key == dataset_key));
    }
    // `batcher.flush` failpoint. This path has no error channel, so an
    // injected `err` escalates to a panic: the worker loop's unwind
    // guard catches it and every popped ticket answers its submitter
    // through the Ticket `Drop` backstop instead of hanging.
    if let Err(e) = crate::fault::check(crate::fault::sites::BATCHER_FLUSH) {
        panic!("{e}");
    }
    Some(Batch { dataset_key, tickets })
}

/// One distinct solve within a batch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JobKey {
    pub gamma: f64,
    pub rho: f64,
    pub method: Method,
    pub regularizer: RegKind,
    pub warm_start: bool,
}

/// Group ticket indices by identical (γ, ρ, method, regularizer, warm)
/// so each distinct job is solved exactly once. Deterministic order
/// (sorted by the key's bits), each group's indices in arrival order.
/// Accepts owned or borrowed tickets (the engine batches over
/// `&Ticket`s).
pub fn unique_jobs<T: Borrow<Ticket>>(tickets: &[T]) -> Vec<(JobKey, Vec<usize>)> {
    let mut groups: BTreeMap<(u64, u64, &'static str, &'static str, bool), Vec<usize>> =
        BTreeMap::new();
    for (i, t) in tickets.iter().enumerate() {
        let r = &t.borrow().request;
        groups
            .entry((
                r.gamma.to_bits(),
                r.rho.to_bits(),
                r.method.name(),
                r.regularizer.name(),
                r.warm_start,
            ))
            .or_default()
            .push(i);
    }
    groups
        .into_iter()
        .map(|((gamma_bits, rho_bits, method, regularizer, warm_start), idxs)| {
            (
                JobKey {
                    gamma: f64::from_bits(gamma_bits),
                    rho: f64::from_bits(rho_bits),
                    method: Method::parse(method).expect("name round-trips"),
                    regularizer: RegKind::parse(regularizer).expect("name round-trips"),
                    warm_start,
                },
                idxs,
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::DatasetSpec;
    use crate::pool::BoundedQueue;
    use crate::serve::engine::SolveRequest;

    fn ticket(seed: u64, gamma: f64, rho: f64) -> Ticket {
        let spec = DatasetSpec { seed, ..Default::default() };
        let (t, _slot) = Ticket::new(
            SolveRequest {
                spec,
                gamma,
                rho,
                method: Method::Fast,
                regularizer: RegKind::GroupLasso,
                deadline: None,
                warm_start: true,
            },
            None,
        );
        t
    }

    #[test]
    fn batches_coalesce_same_dataset_only() {
        let q: AdmissionQueue = BoundedQueue::new(16);
        for t in [
            ticket(1, 0.1, 0.5),
            ticket(2, 0.1, 0.5),
            ticket(1, 0.2, 0.5),
            ticket(1, 0.3, 0.5),
        ] {
            assert!(q.try_push(t).is_ok());
        }
        let b = next_batch(&q, 8).expect("batch");
        assert_eq!(b.len(), 3); // seeds 1, skipping the seed-2 ticket
        assert!(!b.is_empty());
        assert!(b.tickets.iter().all(|t| t.dataset_key == b.dataset_key));
        let b2 = next_batch(&q, 8).expect("batch");
        assert_eq!(b2.len(), 1);
        assert_ne!(b2.dataset_key, b.dataset_key);
    }

    #[test]
    fn batch_size_is_capped() {
        let q: AdmissionQueue = BoundedQueue::new(16);
        for _ in 0..6 {
            assert!(q.try_push(ticket(7, 1.0, 0.5)).is_ok());
        }
        let b = next_batch(&q, 4).expect("batch");
        assert_eq!(b.len(), 4);
        assert_eq!(q.len(), 2);
        // max_batch = 1 degenerates to one-at-a-time.
        let b = next_batch(&q, 1).expect("batch");
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn identical_jobs_deduplicate() {
        let tickets = vec![
            ticket(1, 0.1, 0.5),
            ticket(1, 0.2, 0.5),
            ticket(1, 0.1, 0.5),
            ticket(1, 0.1, 0.5),
        ];
        let jobs = unique_jobs(&tickets);
        assert_eq!(jobs.len(), 2);
        let total: usize = jobs.iter().map(|(_, idxs)| idxs.len()).sum();
        assert_eq!(total, 4);
        let (key, idxs) = jobs
            .iter()
            .find(|(k, _)| k.gamma == 0.1)
            .expect("0.1 group");
        assert_eq!(idxs.as_slice(), &[0, 2, 3]);
        assert_eq!(key.method, Method::Fast);
        assert!(key.warm_start);
    }

    #[test]
    fn closed_queue_ends_batching() {
        let q: AdmissionQueue = BoundedQueue::new(4);
        assert!(q.try_push(ticket(1, 1.0, 0.5)).is_ok());
        q.close();
        assert!(next_batch(&q, 4).is_some()); // graceful drain
        assert!(next_batch(&q, 4).is_none());
    }
}
