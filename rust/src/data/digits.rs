//! Digit-recognition substitute (USPS ↔ MNIST, Fig. 3 / Fig. 6 / B / C).
//!
//! Real datasets are unavailable offline; we generate 16×16 (d = 256)
//! "digit" images per the substitution rule: each of the 10 classes has
//! a shared smooth prototype stroke pattern, and each *domain* renders
//! it with its own thickness, contrast and background-noise statistics
//! (USPS scans vs MNIST pen strokes differ exactly in those). What the
//! OT solver sees — 10 class-clusters per domain, matched across
//! domains, with a consistent inter-domain shift — is preserved.

use super::{Dataset, DomainPair};
use crate::linalg::Mat;
use crate::rng::Pcg64;

const SIDE: usize = 16;
const DIM: usize = SIDE * SIDE;
const NUM_CLASSES: usize = 10;

/// Per-domain rendering style.
#[derive(Clone, Copy, Debug)]
pub struct DomainStyle {
    /// Stroke thickness (Gaussian blur radius in pixels).
    pub blur: f64,
    /// Foreground intensity scale.
    pub contrast: f64,
    /// Additive background noise std.
    pub noise: f64,
    /// Global intensity offset.
    pub offset: f64,
    /// Seed of the domain's fixed per-pixel gain/offset field (sensor
    /// response): this is what makes *straight* 1-NN across domains
    /// degrade while the within-domain class geometry stays intact —
    /// the regime where OT adaptation pays off.
    pub field_seed: u64,
    /// Strength of the per-pixel field distortion in [0, 1).
    pub field_strength: f64,
}

/// USPS-like: thin strokes, lower contrast, scanner noise.
pub const USPS_STYLE: DomainStyle = DomainStyle {
    blur: 0.8,
    contrast: 0.85,
    noise: 0.12,
    offset: 0.05,
    field_seed: 0x0505,
    field_strength: 2.0,
};

/// MNIST-like: thicker strokes, high contrast, clean background.
pub const MNIST_STYLE: DomainStyle = DomainStyle {
    blur: 1.4,
    contrast: 1.0,
    noise: 0.05,
    offset: 0.0,
    field_seed: 0x1417,
    field_strength: 2.0,
};

/// Shared class prototypes: a fixed random walk of "pen strokes" on the
/// 16×16 grid per class, derived from `proto_seed` only (so both
/// domains agree on what a "3" is).
fn class_prototypes(proto_seed: u64) -> Vec<[f64; DIM]> {
    let mut rng = Pcg64::new(proto_seed);
    (0..NUM_CLASSES)
        .map(|_| {
            let mut img = [0.0f64; DIM];
            // 3 strokes of a random walk each ~20 steps.
            for _ in 0..3 {
                let mut x = 3.0 + rng.f64() * 10.0;
                let mut y = 3.0 + rng.f64() * 10.0;
                let mut dx = rng.uniform(-1.0, 1.0);
                let mut dy = rng.uniform(-1.0, 1.0);
                for _ in 0..20 {
                    let xi = x.round().clamp(0.0, (SIDE - 1) as f64) as usize;
                    let yi = y.round().clamp(0.0, (SIDE - 1) as f64) as usize;
                    img[yi * SIDE + xi] = 1.0;
                    dx += rng.uniform(-0.4, 0.4);
                    dy += rng.uniform(-0.4, 0.4);
                    let norm = (dx * dx + dy * dy).sqrt().max(0.3);
                    x = (x + dx / norm).clamp(0.0, (SIDE - 1) as f64);
                    y = (y + dy / norm).clamp(0.0, (SIDE - 1) as f64);
                }
            }
            img
        })
        .collect()
}

/// Separable Gaussian blur with radius `sigma` on a 16×16 image.
fn blur(img: &[f64; DIM], sigma: f64) -> [f64; DIM] {
    let radius = (3.0 * sigma).ceil() as i64;
    let mut kernel = Vec::with_capacity((2 * radius + 1) as usize);
    for t in -radius..=radius {
        kernel.push((-(t * t) as f64 / (2.0 * sigma * sigma)).exp());
    }
    let ksum: f64 = kernel.iter().sum();
    let mut tmp = [0.0f64; DIM];
    // Horizontal pass.
    for y in 0..SIDE {
        for x in 0..SIDE {
            let mut acc = 0.0;
            for (ki, t) in (-radius..=radius).enumerate() {
                let xx = (x as i64 + t).clamp(0, SIDE as i64 - 1) as usize;
                acc += kernel[ki] * img[y * SIDE + xx];
            }
            tmp[y * SIDE + x] = acc / ksum;
        }
    }
    // Vertical pass.
    let mut out = [0.0f64; DIM];
    for y in 0..SIDE {
        for x in 0..SIDE {
            let mut acc = 0.0;
            for (ki, t) in (-radius..=radius).enumerate() {
                let yy = (y as i64 + t).clamp(0, SIDE as i64 - 1) as usize;
                acc += kernel[ki] * tmp[yy * SIDE + x];
            }
            out[y * SIDE + x] = acc / ksum;
        }
    }
    out
}

/// Render `samples` images of the given style. Classes are balanced
/// (sequential round-robin like the paper's random subsample in
/// expectation).
pub fn render_domain(
    name: &str,
    style: DomainStyle,
    samples: usize,
    proto_seed: u64,
    seed: u64,
) -> Dataset {
    let protos = class_prototypes(proto_seed);
    let blurred: Vec<[f64; DIM]> = protos.iter().map(|p| blur(p, style.blur)).collect();
    // Fixed per-pixel sensor response of this domain.
    let mut frng = Pcg64::new(style.field_seed);
    let gains: Vec<f64> = (0..DIM)
        .map(|_| 1.0 + style.field_strength * frng.uniform(-1.0, 1.0))
        .collect();
    let offsets: Vec<f64> = (0..DIM)
        .map(|_| 0.25 * style.field_strength * frng.f64())
        .collect();
    let mut rng = Pcg64::new(seed);
    let mut x = Mat::zeros(samples, DIM);
    let mut labels = Vec::with_capacity(samples);
    for s in 0..samples {
        let class = s % NUM_CLASSES;
        labels.push(class);
        let base = &blurred[class];
        let jx = rng.uniform(0.9, 1.1); // per-sample stroke-intensity jitter
        let row = x.row_mut(s);
        for (d, v) in row.iter_mut().enumerate() {
            let raw = style.offset + style.contrast * jx * base[d]
                + rng.normal() * style.noise;
            let val = gains[d] * raw + offsets[d];
            *v = val.clamp(0.0, 1.0);
        }
    }
    Dataset { name: name.to_string(), x, labels }
}

/// The USPS→MNIST adaptation task with `samples` per domain
/// (paper: 5000).
pub fn usps_to_mnist(samples: usize, seed: u64) -> DomainPair {
    DomainPair {
        source: render_domain("usps", USPS_STYLE, samples, 0xD161, seed),
        target: render_domain("mnist", MNIST_STYLE, samples, 0xD161, seed ^ 0xFFFF),
    }
}

/// The MNIST→USPS adaptation task.
pub fn mnist_to_usps(samples: usize, seed: u64) -> DomainPair {
    DomainPair {
        source: render_domain("mnist", MNIST_STYLE, samples, 0xD161, seed),
        target: render_domain("usps", USPS_STYLE, samples, 0xD161, seed ^ 0xFFFF),
    }
}

/// Both digit tasks (Fig. 3).
pub fn all_tasks(samples: usize, seed: u64) -> Vec<DomainPair> {
    vec![usps_to_mnist(samples, seed), mnist_to_usps(samples, seed + 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let p = usps_to_mnist(60, 3);
        assert_eq!(p.source.len(), 60);
        assert_eq!(p.source.dim(), 256);
        assert_eq!(p.source.num_classes(), 10);
        for &v in p.source.x.as_slice() {
            assert!((0.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn classes_are_clustered_within_domain() {
        // Same-class pairs must be closer than cross-class pairs on average.
        let d = render_domain("t", MNIST_STYLE, 100, 0xD161, 5);
        let dist = |i: usize, j: usize| {
            crate::linalg::sub(d.x.row(i), d.x.row(j))
                .iter()
                .map(|v| v * v)
                .sum::<f64>()
        };
        let mut same = (0.0, 0);
        let mut diff = (0.0, 0);
        for i in 0..40 {
            for j in (i + 1)..40 {
                if d.labels[i] == d.labels[j] {
                    same = (same.0 + dist(i, j), same.1 + 1);
                } else {
                    diff = (diff.0 + dist(i, j), diff.1 + 1);
                }
            }
        }
        let same_mean = same.0 / same.1 as f64;
        let diff_mean = diff.0 / diff.1 as f64;
        assert!(
            same_mean < 0.6 * diff_mean,
            "class clusters too weak: same={same_mean} diff={diff_mean}"
        );
    }

    #[test]
    fn domains_share_class_geometry() {
        // Cross-domain same-class distance < cross-domain cross-class
        // distance (otherwise adaptation is impossible).
        let p = usps_to_mnist(100, 11);
        let dist = |i: usize, j: usize| {
            crate::linalg::sub(p.source.x.row(i), p.target.x.row(j))
                .iter()
                .map(|v| v * v)
                .sum::<f64>()
        };
        let mut same = (0.0, 0);
        let mut diff = (0.0, 0);
        for i in 0..50 {
            for j in 0..50 {
                if p.source.labels[i] == p.target.labels[j] {
                    same = (same.0 + dist(i, j), same.1 + 1);
                } else {
                    diff = (diff.0 + dist(i, j), diff.1 + 1);
                }
            }
        }
        // With the strong sensor-field distortion the margin is small
        // (that's the point: straight 1-NN degrades) but same-class
        // cross-domain distances must still be lower on average.
        assert!(same.0 / (same.1 as f64) < 0.98 * diff.0 / diff.1 as f64);
    }

    #[test]
    fn two_tasks() {
        let ts = all_tasks(20, 1);
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].task_name(), "usps→mnist");
        assert_eq!(ts[1].task_name(), "mnist→usps");
    }
}
