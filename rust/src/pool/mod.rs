//! Thread-pool substrate (tokio/rayon are unavailable offline).
//!
//! Facilities:
//!
//! * [`ThreadPool`] — a fixed pool of workers consuming boxed jobs from a
//!   shared channel; used by the coordinator's sweep scheduler and the
//!   TCP service.
//! * [`BoundedQueue`] — a capacity-bounded MPMC FIFO whose `try_push`
//!   never blocks (the serving engine's admission-control substrate:
//!   overload surfaces as an immediate rejection, not unbounded memory).
//! * [`Semaphore`] — a counting semaphore (std has none on stable).
//! * [`parallel_for_chunks`] — fork-join data parallelism over an index
//!   range using `std::thread::scope`; used off the solver's hot path
//!   (dataset generation, evaluation) where thread-count-dependent
//!   chunking is acceptable.
//! * [`ParallelCtx`] / [`parallel_map_reduce`] — the solver hot path's
//!   *deterministic* data-parallel facility: work is sharded over
//!   **fixed** chunks whose boundaries depend only on the problem size
//!   (never on the worker count), each chunk writes into its own slot,
//!   and partial results are combined in ascending chunk order on the
//!   calling thread — no atomics, no reduction races — so floating-point
//!   outputs are bit-identical for every thread count, including 1.
//!
//!   Since PR 4 a `ParallelCtx` owns a **persistent parked worker set**:
//!   `threads − 1` workers are spawned once (lazily, on the first
//!   parallel call), park on a condvar between calls, and are woken with
//!   a (generation, job) handoff — the per-evaluation `thread::scope`
//!   fork-join (tens of µs per oracle eval, thousands of evals per
//!   solve) is gone from the hot path. The chunk→slot assignment is the
//!   same block math as the fork-join version, and *which* thread runs a
//!   chunk can never influence the result, so bit-exactness across
//!   thread counts is untouched. [`forkjoin_map_chunks`] keeps the
//!   one-shot scoped dispatch for off-hot-path use and as the baseline
//!   of the `bench_parallel` dispatch comparison.

use std::collections::VecDeque;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;
use std::time::Instant;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool. Jobs are executed FIFO; `join` blocks until
/// every submitted job has finished. Dropping the pool joins workers.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl ThreadPool {
    /// Create a pool with `n` workers (n ≥ 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "pool needs at least one worker");
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                thread::Builder::new()
                    .name(format!("grpot-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => {
                                job();
                                let (lock, cvar) = &*pending;
                                let mut p = lock.lock().unwrap();
                                *p -= 1;
                                if *p == 0 {
                                    cvar.notify_all();
                                }
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, pending }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(job))
            .expect("worker channel closed");
    }

    /// Block until all submitted jobs have completed.
    pub fn join(&self) {
        let (lock, cvar) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cvar.wait(p).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit their loop
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Counting semaphore (std has none on stable): used by the TCP service
/// to cap concurrent solves while connections run thread-per-socket.
pub struct Semaphore {
    state: Mutex<usize>,
    cvar: std::sync::Condvar,
}

/// RAII permit; releases on drop.
pub struct SemaphorePermit<'a> {
    sem: &'a Semaphore,
}

impl Semaphore {
    pub fn new(permits: usize) -> Self {
        assert!(permits > 0);
        Semaphore { state: Mutex::new(permits), cvar: std::sync::Condvar::new() }
    }

    /// Block until a permit is available.
    pub fn acquire(&self) -> SemaphorePermit<'_> {
        let mut avail = self.state.lock().unwrap();
        while *avail == 0 {
            avail = self.cvar.wait(avail).unwrap();
        }
        *avail -= 1;
        SemaphorePermit { sem: self }
    }

    /// Current free permits (diagnostics).
    pub fn available(&self) -> usize {
        *self.state.lock().unwrap()
    }
}

impl Drop for SemaphorePermit<'_> {
    fn drop(&mut self) {
        let mut avail = self.sem.state.lock().unwrap();
        *avail += 1;
        self.sem.cvar.notify_one();
    }
}

/// Why `try_push` failed; the rejected item is handed back so callers
/// can report on it (e.g. answer the request with a structured error).
#[derive(Debug)]
pub enum PushError<T> {
    /// The queue held `capacity` items already.
    Full(T),
    /// [`BoundedQueue::close`] was called; no further items are accepted.
    Closed(T),
}

struct BoundedState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Capacity-bounded MPMC FIFO. Producers never block: `try_push` fails
/// immediately when the queue is full (backpressure) or closed.
/// Consumers block in `pop` until an item arrives; after `close`, `pop`
/// drains the remaining items and then returns `None`.
pub struct BoundedQueue<T> {
    state: Mutex<BoundedState<T>>,
    cvar: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    /// Create with a hard capacity (≥ 1).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "queue needs capacity >= 1");
        BoundedQueue {
            state: Mutex::new(BoundedState { items: VecDeque::new(), closed: false }),
            cvar: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Enqueue without blocking. Returns the queue depth after the push,
    /// or the item wrapped in the reason it was refused.
    pub fn try_push(&self, item: T) -> Result<usize, PushError<T>> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(PushError::Closed(item));
        }
        if st.items.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        st.items.push_back(item);
        let depth = st.items.len();
        drop(st);
        self.cvar.notify_one();
        Ok(depth)
    }

    /// Dequeue, blocking until an item is available. Returns `None` only
    /// once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.cvar.wait(st).unwrap();
        }
    }

    /// Non-blocking dequeue.
    pub fn try_pop(&self) -> Option<T> {
        self.state.lock().unwrap().items.pop_front()
    }

    /// Remove up to `max` items satisfying `pred`, preserving FIFO order
    /// among both the taken and the remaining items. Non-blocking; used
    /// by the micro-batcher to coalesce same-dataset requests.
    ///
    /// The common polling cases — empty queue, no matching item — return
    /// early without allocating or rebuilding the queue; `pred` is still
    /// called at most once per item.
    pub fn drain_matching(&self, max: usize, mut pred: impl FnMut(&T) -> bool) -> Vec<T> {
        let mut st = self.state.lock().unwrap();
        if max == 0 || st.items.is_empty() {
            return Vec::new();
        }
        // Probe for the first match before touching the queue: a miss
        // costs one scan and zero allocations.
        let Some(first) = st.items.iter().position(&mut pred) else {
            return Vec::new();
        };
        let items = std::mem::take(&mut st.items);
        let mut taken = Vec::new();
        let mut rest = VecDeque::with_capacity(items.len());
        for (idx, item) in items.into_iter().enumerate() {
            if idx < first {
                rest.push_back(item);
            } else if idx == first || (taken.len() < max && pred(&item)) {
                // `first` already matched during the probe; don't call
                // `pred` on it a second time.
                taken.push(item);
            } else {
                rest.push_back(item);
            }
        }
        st.items = rest;
        taken
    }

    /// Current depth.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Refuse new items and wake every blocked consumer. Items already
    /// queued remain poppable (graceful drain).
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cvar.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }
}

/// Run `body(chunk_start, chunk_end)` over `0..n` split into contiguous
/// chunks across `threads` scoped threads. `body` must be `Sync`-safe via
/// captured shared state; results are typically written to disjoint
/// slices by the caller.
pub fn parallel_for_chunks<F>(n: usize, threads: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        body(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let body = &body;
            s.spawn(move || body(lo, hi));
        }
    });
}

/// Upper bound on the number of fixed chunks produced by
/// [`fixed_chunk_ranges`]. Bounds both the per-chunk scratch memory the
/// oracles keep resident and the ordered-reduction cost.
pub const MAX_FIXED_CHUNKS: usize = 32;

/// Lower bound on indices per fixed chunk: tiny problems collapse to a
/// single chunk instead of paying fork-join overhead per column.
pub const MIN_FIXED_CHUNK_LEN: usize = 16;

/// Chunk length used by [`fixed_chunk_ranges`] for a range of `n`
/// indices. A function of `n` **only** — never of the worker count —
/// which is what makes chunked reductions thread-count-invariant.
pub fn fixed_chunk_len(n: usize) -> usize {
    n.div_ceil(MAX_FIXED_CHUNKS).max(MIN_FIXED_CHUNK_LEN)
}

/// Split `0..n` into contiguous ranges of `chunk` indices (last may be
/// short). `n = 0` yields no ranges.
pub fn chunk_ranges(n: usize, chunk: usize) -> Vec<Range<usize>> {
    assert!(chunk >= 1, "chunk length must be >= 1");
    let mut out = Vec::with_capacity(n.div_ceil(chunk));
    let mut lo = 0;
    while lo < n {
        let hi = (lo + chunk).min(n);
        out.push(lo..hi);
        lo = hi;
    }
    out
}

/// The fixed, thread-count-independent chunking of `0..n` used by the
/// column-parallel oracles: at most [`MAX_FIXED_CHUNKS`] chunks of at
/// least [`MIN_FIXED_CHUNK_LEN`] indices each.
pub fn fixed_chunk_ranges(n: usize) -> Vec<Range<usize>> {
    chunk_ranges(n, fixed_chunk_len(n))
}

/// A type-erased block job handed from the dispatching thread to the
/// parked workers. `run(env, b)` executes block `b` of the current
/// call's chunk grid against the caller's stack-held environment; the
/// raw pointer stays valid because the dispatcher never returns (or
/// unwinds) before every participating worker has reported done.
#[derive(Clone, Copy)]
struct JobMsg {
    run: unsafe fn(*const (), usize),
    env: *const (),
    /// Parked workers with a block this generation (caller runs block 0,
    /// parked worker `w` runs block `w + 1` for `w < participants`).
    participants: usize,
}

// SAFETY: `env` points at a `BlockJob` whose slot pointer and map
// closure are constrained to `S: Send` / `F: Sync` by `map_chunks`; the
// dispatcher keeps the pointee alive until all participants finish.
unsafe impl Send for JobMsg {}

struct PoolState {
    /// Bumped once per dispatched job; workers compare against the last
    /// generation they served so stale wakeups fall back to sleep.
    generation: u64,
    job: Option<JobMsg>,
    /// Participants that have finished the current generation.
    finished: usize,
    /// First panic payload caught in a worker this generation.
    panic: Option<Box<dyn std::any::Any + Send + 'static>>,
    shutdown: bool,
}

/// Pool utilization counters, read by [`ParallelCtx::pool_stats`] for
/// solver telemetry. Park/wake transition counts are always on (one
/// relaxed add per worker per job — off the per-chunk path entirely);
/// the nanosecond busy/parked clocks only accumulate while tracing is
/// enabled ([`crate::obs::enabled`]), so `GRPOT_TRACE=off` adds no
/// `Instant::now` calls to the handoff.
#[derive(Default)]
struct PoolCounters {
    parks: AtomicU64,
    wakes: AtomicU64,
    busy_ns: AtomicU64,
    parked_ns: AtomicU64,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here between jobs.
    work: Condvar,
    /// The dispatcher parks here until `finished == participants`.
    done: Condvar,
    stats: PoolCounters,
}

/// The spawned half of a [`ParallelCtx`]: `threads − 1` parked worker
/// threads plus the shared handoff state. Dropping it wakes every
/// worker with the shutdown flag and joins them all.
struct WorkerSet {
    shared: Arc<PoolShared>,
    handles: Vec<thread::JoinHandle<()>>,
    /// Serializes dispatches from clones of the same ctx used on
    /// different threads (the engine gives each worker its own ctx, so
    /// this lock is uncontended on the hot path).
    dispatch: Mutex<()>,
    live: Arc<AtomicUsize>,
}

impl WorkerSet {
    fn spawn(workers: usize, live: Arc<AtomicUsize>) -> WorkerSet {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                generation: 0,
                job: None,
                finished: 0,
                panic: None,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            stats: PoolCounters::default(),
        });
        let handles = (0..workers)
            .map(|w| {
                let shared = Arc::clone(&shared);
                live.fetch_add(1, Ordering::SeqCst);
                thread::Builder::new()
                    .name(format!("grpot-oracle-{w}"))
                    .spawn(move || worker_loop(&shared, w))
                    .expect("spawn parked oracle worker")
            })
            .collect();
        WorkerSet { shared, handles, dispatch: Mutex::new(()), live }
    }

    /// Hand `blocks` blocks of the erased job to the pool: the caller
    /// runs block 0 inline, parked workers run blocks `1..blocks`, and
    /// this call returns only after every block has finished. Worker
    /// panics (and the caller's own) propagate after the join point, so
    /// `env` never dangles and the pool stays reusable afterwards.
    fn dispatch(&self, blocks: usize, run: unsafe fn(*const (), usize), env: *const ()) {
        // Poison-tolerant: a previous dispatch that propagated a panic
        // must not turn every later dispatch into a PoisonError panic —
        // the reusable-after-panic guarantee depends on it.
        let serialize = self
            .dispatch
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let participants = blocks - 1;
        debug_assert!(participants <= self.handles.len(), "more blocks than workers");
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert!(st.job.is_none(), "dispatch while a job is in flight");
            st.generation += 1;
            st.finished = 0;
            st.job = Some(JobMsg { run, env, participants });
            self.shared.work.notify_all();
        }
        // The caller is worker 0: it contributes a block instead of
        // sleeping through the job.
        let own = catch_unwind(AssertUnwindSafe(|| unsafe { (run)(env, 0) }));
        let worker_panic = {
            let mut st = self.shared.state.lock().unwrap();
            while st.finished < participants {
                st = self.shared.done.wait(st).unwrap();
            }
            st.job = None;
            st.panic.take()
        };
        // Release the dispatch lock *before* re-raising so the unwind
        // cannot poison it (belt to the braces above).
        drop(serialize);
        if let Err(p) = own {
            resume_unwind(p);
        }
        if let Some(p) = worker_panic {
            resume_unwind(p);
        }
    }
}

impl Drop for WorkerSet {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
            self.live.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

fn worker_loop(shared: &PoolShared, w: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            // `parked_at` is set on the first actual condvar wait of this
            // park episode; spurious wakeups that fall back to sleep keep
            // the original timestamp so the episode is counted once.
            let mut parked_at: Option<Instant> = None;
            let job = loop {
                if st.shutdown {
                    break None;
                }
                if st.generation > seen {
                    if let Some(job) = st.job {
                        seen = st.generation;
                        break Some(job);
                    }
                }
                if parked_at.is_none() {
                    shared.stats.parks.fetch_add(1, Ordering::Relaxed);
                    if crate::obs::enabled() {
                        parked_at = Some(Instant::now());
                    }
                }
                st = shared.work.wait(st).unwrap();
            };
            drop(st);
            if let Some(t) = parked_at {
                shared
                    .stats
                    .parked_ns
                    .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
            match job {
                Some(job) => {
                    shared.stats.wakes.fetch_add(1, Ordering::Relaxed);
                    job
                }
                None => return,
            }
        };
        if w >= job.participants {
            // No block for this worker this generation; back to sleep.
            continue;
        }
        let busy_at = if crate::obs::enabled() { Some(Instant::now()) } else { None };
        let out = catch_unwind(AssertUnwindSafe(|| unsafe { (job.run)(job.env, w + 1) }));
        if let Some(t) = busy_at {
            shared
                .stats
                .busy_ns
                .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        let mut st = shared.state.lock().unwrap();
        if let Err(p) = out {
            // Keep the first payload; the job still counts as finished
            // so the dispatcher's join point is reached either way.
            if st.panic.is_none() {
                st.panic = Some(p);
            }
        }
        st.finished += 1;
        if st.finished == job.participants {
            shared.done.notify_one();
        }
    }
}

/// Lazily-spawned pool backing a [`ParallelCtx`]: nothing is spawned
/// until the first genuinely parallel `map_chunks` call, so serial
/// contexts (the default everywhere) never cost a thread.
struct LazyPool {
    /// Parked workers to spawn (`threads − 1`).
    workers: usize,
    set: OnceLock<WorkerSet>,
    /// Live parked-worker count for this pool: incremented per spawn,
    /// decremented after each join in `WorkerSet::drop`. Shared out via
    /// [`ParallelCtx::live_worker_counter`] so tests can assert the
    /// drop-joins-everything invariant without a global registry.
    live: Arc<AtomicUsize>,
}

/// The environment of one `map_chunks` call, shared by address with the
/// parked workers for the duration of the dispatch.
struct BlockJob<'a, S, F> {
    ranges: &'a [Range<usize>],
    slots: *mut S,
    k: usize,
    per: usize,
    map: &'a F,
}

/// Run block `b`: chunks `[b·per, (b+1)·per) ∩ [0, k)`, each against its
/// own slot. SAFETY: blocks are disjoint, so every `slots.add(c)` is an
/// exclusive reference for the duration of the call; `env` outlives the
/// dispatch by construction.
unsafe fn run_block<S, F>(env: *const (), b: usize)
where
    F: Fn(usize, Range<usize>, &mut S) + Sync,
{
    let job = &*(env as *const BlockJob<'_, S, F>);
    let lo = b * job.per;
    let hi = ((b + 1) * job.per).min(job.k);
    for c in lo..hi {
        let slot = &mut *job.slots.add(c);
        (job.map)(c, job.ranges[c].clone(), slot);
    }
}

/// Intra-solve parallelism context: how many worker threads a solver's
/// oracle may use per evaluation. `threads = 1` (the default
/// everywhere) runs the identical chunked code path serially, so the
/// paper-faithful single-core configuration and the multicore one
/// produce byte-equal iterates.
///
/// A ctx owns a persistent parked worker set (spawned lazily on the
/// first parallel call, parked on a condvar between calls, joined when
/// the last clone drops), so per-evaluation dispatch is a mutex +
/// condvar handoff instead of `threads` OS thread spawns. Clones share
/// the pool — the serving engine keeps one long-lived ctx per engine
/// worker and threads it through every solve.
#[derive(Clone)]
pub struct ParallelCtx {
    threads: usize,
    pool: Arc<LazyPool>,
}

impl Default for ParallelCtx {
    fn default() -> Self {
        ParallelCtx::serial()
    }
}

impl std::fmt::Debug for ParallelCtx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ParallelCtx")
            .field("threads", &self.threads)
            .field("spawned", &self.pool.set.get().is_some())
            .finish()
    }
}

/// Equality is on the *configuration* (thread count) only: two contexts
/// with the same thread count are interchangeable even when they own
/// distinct worker sets.
impl PartialEq for ParallelCtx {
    fn eq(&self, other: &Self) -> bool {
        self.threads == other.threads
    }
}

impl Eq for ParallelCtx {}

impl ParallelCtx {
    /// Create with `threads` workers (0 is treated as 1). No threads are
    /// spawned until the first parallel `map_chunks` call.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        ParallelCtx {
            threads,
            pool: Arc::new(LazyPool {
                workers: threads - 1,
                set: OnceLock::new(),
                live: Arc::new(AtomicUsize::new(0)),
            }),
        }
    }

    /// The single-threaded context (still runs the chunked code path).
    pub fn serial() -> Self {
        ParallelCtx::new(1)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn is_parallel(&self) -> bool {
        self.threads > 1
    }

    /// Parked worker threads currently alive in this ctx's pool: 0
    /// before the lazy spawn, `threads − 1` after, 0 again once the
    /// last clone has dropped (which joins them).
    pub fn live_workers(&self) -> usize {
        self.pool.live.load(Ordering::SeqCst)
    }

    /// A handle on the live-worker counter that outlives the ctx — the
    /// pool-lifecycle tests assert it returns to 0 after `Drop`.
    pub fn live_worker_counter(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.pool.live)
    }

    /// Cumulative utilization counters of this ctx's parked worker set
    /// (all zeros before the lazy spawn and for serial contexts). The
    /// counters are pool-lifetime totals; per-solve numbers are deltas
    /// via [`crate::obs::PoolUtilization::since`]. Park/wake counts are
    /// always on; the nanosecond clocks accumulate only while tracing
    /// is enabled.
    pub fn pool_stats(&self) -> crate::obs::PoolUtilization {
        match self.pool.set.get() {
            Some(set) => {
                let s = &set.shared.stats;
                crate::obs::PoolUtilization {
                    busy_ns: s.busy_ns.load(Ordering::Relaxed),
                    parked_ns: s.parked_ns.load(Ordering::Relaxed),
                    parks: s.parks.load(Ordering::Relaxed),
                    wakes: s.wakes.load(Ordering::Relaxed),
                }
            }
            None => crate::obs::PoolUtilization::default(),
        }
    }

    /// Map over pre-chunked work: `map(chunk_idx, range, slot)` runs
    /// once per chunk with exclusive access to that chunk's slot.
    /// Chunk→slot assignment is by index and chunk boundaries come from
    /// the caller, so *which thread* ran a chunk can never influence the
    /// result; callers then combine slots in chunk order for a
    /// deterministic reduction. A panic in any worker (or in the
    /// caller's own block) propagates after the internal join point and
    /// leaves the pool reusable.
    ///
    /// Parallel calls are served by the persistent parked workers —
    /// woken with a generation-stamped job, parked again once their
    /// block is done — with the same static block assignment as the
    /// fork-join dispatch (worker `b` owns chunks `[b·per, (b+1)·per)`;
    /// column costs are near-uniform, so static splitting balances fine
    /// without work stealing).
    pub fn map_chunks<S, F>(&self, ranges: &[Range<usize>], slots: &mut [S], map: F)
    where
        S: Send,
        F: Fn(usize, Range<usize>, &mut S) + Sync,
    {
        assert_eq!(ranges.len(), slots.len(), "one slot per chunk");
        let k = ranges.len();
        if k == 0 {
            return;
        }
        let workers = self.threads.min(k);
        if workers <= 1 {
            for (c, slot) in slots.iter_mut().enumerate() {
                map(c, ranges[c].clone(), slot);
            }
            return;
        }
        let per = k.div_ceil(workers);
        let blocks = k.div_ceil(per);
        let set = self
            .pool
            .set
            .get_or_init(|| WorkerSet::spawn(self.pool.workers, Arc::clone(&self.pool.live)));
        let job = BlockJob { ranges, slots: slots.as_mut_ptr(), k, per, map: &map };
        set.dispatch(blocks, run_block::<S, F>, &job as *const BlockJob<'_, S, F> as *const ());
    }
}

/// One-shot scoped fork-join over pre-chunked work — the pre-PR-4
/// dispatch, kept **off the hot path** for single-use helpers and as
/// the baseline of the `bench_parallel` / `hotpath_microbench` dispatch
/// comparison. Identical chunk→slot/block assignment to
/// [`ParallelCtx::map_chunks`], so both dispatchers produce byte-equal
/// results; only the per-call spawn/join overhead differs.
pub fn forkjoin_map_chunks<S, F>(threads: usize, ranges: &[Range<usize>], slots: &mut [S], map: F)
where
    S: Send,
    F: Fn(usize, Range<usize>, &mut S) + Sync,
{
    assert_eq!(ranges.len(), slots.len(), "one slot per chunk");
    let k = ranges.len();
    if k == 0 {
        return;
    }
    let workers = threads.max(1).min(k);
    if workers <= 1 {
        for (c, slot) in slots.iter_mut().enumerate() {
            map(c, ranges[c].clone(), slot);
        }
        return;
    }
    let per = k.div_ceil(workers);
    thread::scope(|s| {
        for (b, block) in slots.chunks_mut(per).enumerate() {
            let map = &map;
            s.spawn(move || {
                for (off, slot) in block.iter_mut().enumerate() {
                    let c = b * per + off;
                    map(c, ranges[c].clone(), slot);
                }
            });
        }
    });
}

/// Deterministic sharded map-reduce over `0..n` in fixed chunks of
/// `chunk` indices: `map(chunk_idx, range)` runs fork-join style on up
/// to `threads` workers, and `reduce(acc, value)` folds the chunk
/// values **in ascending chunk order** on the calling thread — per-chunk
/// partials, never atomics — so the result is bit-identical for every
/// `threads`, including 1. `n = 0` returns `init` without calling `map`;
/// `chunk > n` degenerates to one chunk. Panics in `map` propagate.
///
/// This is the *one-shot* entry (scoped fork-join, no persistent pool):
/// per-eval hot loops hold a [`ParallelCtx`] instead.
pub fn parallel_map_reduce<T, A, M, R>(
    threads: usize,
    n: usize,
    chunk: usize,
    init: A,
    map: M,
    mut reduce: R,
) -> A
where
    T: Send,
    M: Fn(usize, Range<usize>) -> T + Sync,
    R: FnMut(A, T) -> A,
{
    let ranges = chunk_ranges(n, chunk.max(1));
    let mut slots: Vec<Option<T>> = Vec::new();
    slots.resize_with(ranges.len(), || None);
    forkjoin_map_chunks(threads, &ranges, &mut slots, |c, range, slot| {
        *slot = Some(map(c, range));
    });
    let mut acc = init;
    for slot in slots {
        acc = reduce(acc, slot.expect("every chunk was mapped"));
    }
    acc
}

/// Dynamic work-stealing-ish variant: threads atomically grab blocks of
/// `block` indices until the range is exhausted. Better for ragged work
/// (e.g. sweep jobs with very different solve times).
pub fn parallel_for_dynamic<F>(n: usize, threads: usize, block: usize, body: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 || n <= block {
        for i in 0..n {
            body(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..threads {
            let next = &next;
            let body = &body;
            s.spawn(move || loop {
                let start = next.fetch_add(block, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for i in start..(start + block).min(n) {
                    body(i);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests;
