#!/usr/bin/env bash
# Local CI gate — mirrors .github/workflows/ci.yml exactly:
#
#   1. cargo fmt --check
#   2. cargo clippy --all-targets -- -D warnings -A deprecated
#      (the deprecated constructor shims kept for the SolveOptions
#      migration are exercised on purpose by the compat tests)
#   3. cargo build --release            (tier-1, part 1)
#   4. cargo test -q                    (tier-1, part 2)
#   5. GRPOT_TEST_THREADS=4 shard: the theorem2_equivalence suite
#      re-runs with 4 intra-solve oracle threads, plus a re-run of
#      parallel_determinism and the pool_lifecycle suite, so
#      thread-count bit-exactness and the persistent-pool lifecycle
#      (reuse / panic recovery / drop-joins) are gated on every push
#   6. GRPOT_SIMD=scalar shard: the theorem2_equivalence suite re-runs
#      with the scalar reference kernels forced through every solver
#      entry point, plus simd_equivalence and parallel_determinism, so
#      both dispatch paths (scalar and runtime-selected SIMD) are gated
#      on every push — the default runs above exercise auto dispatch
#   6b. GRPOT_COST=factored shard: cost_equivalence, theorem2_equivalence
#      and parallel_determinism re-run with the factored cost backend as
#      the env default, so every Auto-mode problem build streams
#      synthesized tiles instead of the resident matrix — the dense
#      default is exercised by every other run
#   6c. GRPOT_BATCH_K=4 shard: the batch_equivalence matrix and the
#      serving engine suite re-run with env-defaulted batching on, so
#      the fused multi-lane solve path (and its byte-identity contract
#      against sequential solves) is gated on every push; malformed
#      GRPOT_BATCH_K / GRPOT_TILE_RING_KIB values must fail `grpot
#      info` at launch (exit 2)
#   7. GRPOT_REG={squared_l2,negentropy} shards: the regularizer env
#      default is pushed through the trait-dispatched solver path while
#      theorem2_equivalence re-runs alongside to prove the pinned
#      group-lasso entry points never re-route under the env var
#   8. GRPOT_TRACE=full shard: the bit-exactness suites plus the
#      observability suite re-run with tracing fully on, gating the
#      zero-perturbation contract (spans and telemetry never change
#      solver output) under the most intrusive trace mode
#   9. chaos shard: the chaos suite (fault injection at every failpoint
#      site, mid-solve cancellation, circuit breaking, load shedding,
#      hostile wire input), the bit-exactness suites re-run with the
#      fault registry explicitly empty (GRPOT_FAULTS=off — the disarmed
#      fast path must never perturb solver output), and a grammar gate:
#      a malformed GRPOT_FAULTS must fail `grpot info` at launch
#  10. cargo build --release --features xla   (in-tree stub must keep compiling)
#  11. bench smoke pass: every bench binary once, GRPOT_BENCH_SMOKE=1
#      (includes bench_parallel, which asserts thread-count determinism,
#      the fork-join-vs-persistent dispatch equivalence and the
#      scalar-vs-SIMD kernel equivalence; hotpath_microbench, which
#      reports per-regularizer trait-oracle rows and the
#      cancellation-token overhead pair; and bench_scale, which asserts
#      the factored cost backend fits a memory budget the dense
#      representation exceeds — scaled down in smoke mode)
#  12. GRPOT_BENCH_SMOKE=1 bash scripts/bench.sh — the perf benches again
#      through the bench.sh wrapper, checking the machine-readable
#      bench JSON emission end to end (written to a temp file so a
#      smoke run never clobbers real recorded numbers)
#
# Everything except step 7 runs with default features only (zero
# external crate dependencies — this image has no network). Step 7
# compiles the PJRT runtime against the in-tree `rust/xla-stub` crate,
# which errors at runtime but keeps the feature buildable offline; the
# gated bench/test surface prints a skip notice in the smoke pass.
#
# Usage: bash scripts/ci.sh [--no-lint]

set -euo pipefail
cd "$(dirname "$0")/../rust"

NO_LINT=0
if [[ "${1:-}" == "--no-lint" ]]; then
    NO_LINT=1
fi

step() { echo; echo "==> $*"; }

if [[ "$NO_LINT" == 0 ]]; then
    step "cargo fmt --check"
    cargo fmt --check

    step "cargo clippy --all-targets -- -D warnings -A deprecated"
    cargo clippy --all-targets -- -D warnings -A deprecated
fi

step "cargo build --release"
cargo build --release

step "cargo test -q"
cargo test -q

step "cargo test -q (GRPOT_TEST_THREADS=4 parallel shard)"
GRPOT_TEST_THREADS=4 cargo test -q \
    --test theorem2_equivalence \
    --test parallel_determinism \
    --test pool_lifecycle

step "cargo test -q (GRPOT_SIMD=scalar dispatch shard)"
GRPOT_SIMD=scalar cargo test -q \
    --test theorem2_equivalence \
    --test simd_equivalence \
    --test parallel_determinism

step "cargo test -q (GRPOT_COST=factored cost-backend shard)"
GRPOT_COST=factored cargo test -q \
    --test cost_equivalence \
    --test theorem2_equivalence \
    --test parallel_determinism

for reg in squared_l2 negentropy; do
    step "cargo test -q (GRPOT_REG=$reg regularizer shard)"
    GRPOT_REG="$reg" cargo test -q \
        --test regularizer_equivalence \
        --test theorem2_equivalence
done

step "cargo test -q (GRPOT_TRACE=full observability shard)"
GRPOT_TRACE=full cargo test -q \
    --test theorem2_equivalence \
    --test parallel_determinism \
    --test simd_equivalence \
    --test observability

step "cargo test -q (GRPOT_BATCH_K=4 batched-solve shard)"
# The batched-equivalence matrix plus the serving engine re-run with
# env-defaulted batching on: every coalescible engine job goes through
# the fused multi-lane path, and each result must stay byte-identical
# to its sequential solve. A malformed GRPOT_BATCH_K is a launch error.
GRPOT_BATCH_K=4 cargo test -q \
    --test batch_equivalence \
    --test serve_engine
if GRPOT_BATCH_K="zero-ish" ./target/release/grpot info >/dev/null 2>&1; then
    echo "GRPOT_BATCH_K grammar gate failed: malformed value was accepted"
    exit 1
fi
if GRPOT_TILE_RING_KIB="0" ./target/release/grpot info >/dev/null 2>&1; then
    echo "GRPOT_TILE_RING_KIB grammar gate failed: zero budget was accepted"
    exit 1
fi

step "cargo test -q (chaos shard: fault injection + cancellation + breaker)"
cargo test -q --test chaos
# Bit-exactness with the fault registry explicitly disarmed: the
# single-load fast path in fault::check must never perturb the math.
GRPOT_FAULTS=off cargo test -q \
    --test theorem2_equivalence \
    --test simd_equivalence
# A malformed GRPOT_FAULTS is a launch error (exit 2), never a late
# per-request surprise inside a worker.
if GRPOT_FAULTS="bogus.site:panic:every-1" ./target/release/grpot info >/dev/null 2>&1; then
    echo "GRPOT_FAULTS grammar gate failed: malformed spec was accepted"
    exit 1
fi

step "cargo build --release --features xla (offline stub)"
cargo build --release --features xla

step "bench smoke pass (GRPOT_BENCH_SMOKE=1, one tiny iteration each)"
BENCHES=(
    fig2_synthetic_classes
    fig3_digits
    fig4_faces
    fig5_objects
    fig6_grad_counts
    figa_samples_per_class
    figb_error_bounds
    figc_grad_per_iter
    figd_lower_bound_ablation
    table1_objective
    hotpath_microbench
    bench_parallel
    bench_scale
    bench_batch
    xla_backend
    bench_serve
)
for b in "${BENCHES[@]}"; do
    step "bench smoke: $b"
    GRPOT_BENCH_SMOKE=1 cargo bench --bench "$b"
done

step "bench.sh smoke (machine-readable bench JSON emission)"
BENCH_JSON_TMP="$(mktemp -t grpot-bench-smoke-XXXXXX.json)"
GRPOT_BENCH_SMOKE=1 GRPOT_BENCH_JSON="$BENCH_JSON_TMP" bash ../scripts/bench.sh
test -s "$BENCH_JSON_TMP" || { echo "bench.sh produced no JSON"; exit 1; }
rm -f "$BENCH_JSON_TMP"

echo
echo "ci.sh: all gates green"
