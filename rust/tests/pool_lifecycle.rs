//! Lifecycle guarantees of the persistent parked worker pool behind
//! `pool::ParallelCtx` — the PR-4 replacement for the per-eval
//! `thread::scope` fork-join:
//!
//! * worker **reuse**: a ctx held across ≥ 1000 consecutive oracle
//!   evaluations returns byte-equal results to a fresh ctx per eval;
//! * **panic safety**: a panic inside a worker (or in the caller's own
//!   block) propagates to the caller and leaves the pool reusable;
//! * **shutdown**: dropping the last ctx clone joins every worker — no
//!   leaked threads, asserted via the pool's live-worker counter;
//! * **determinism across solves**: one ctx shared by a solve and its
//!   warm-started re-solve produces the bit-identical trajectory the
//!   fresh-ctx (and serial) solves produce.

use grpot::linalg::Mat;
use grpot::ot::dual::{DualOracle, DualParams, OtProblem};
use grpot::ot::fastot::{solve_fast_ot, solve_fast_ot_ctx, solve_fast_ot_from, FastOtConfig};
use grpot::ot::origin::OriginOracle;
use grpot::pool::{chunk_ranges, ParallelCtx};
use grpot::rng::Pcg64;
use grpot::solvers::lbfgs::LbfgsOptions;
use std::sync::atomic::Ordering;

fn random_problem(seed: u64, l: usize, g: usize, n: usize) -> OtProblem {
    let mut rng = Pcg64::new(seed);
    let m = l * g;
    let cost = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
    let labels: Vec<usize> = (0..m).map(|i| i / g).collect();
    OtProblem::from_parts(vec![1.0 / m as f64; m], vec![1.0 / n as f64; n], &cost, &labels)
}

/// Worker reuse: 1000 consecutive evals through one parked worker set
/// are byte-equal to evals through a fresh ctx (fresh spawn) each time.
#[test]
fn reused_ctx_matches_fresh_ctx_across_1000_evals() {
    let prob = random_problem(0x9001, 4, 4, 37);
    let params = DualParams::new(0.6, 0.5);
    let ctx = ParallelCtx::new(4);
    let mut reused = OriginOracle::with_ctx(&prob, params, ctx.clone());
    let mut x = vec![0.0; prob.dim()];
    let mut g_reused = vec![0.0; prob.dim()];
    let mut g_fresh = vec![0.0; prob.dim()];
    let mut rng = Pcg64::new(7);
    for step in 0..1000 {
        // Deterministic drifting iterate; cheap per-step perturbation.
        let k = step % prob.dim();
        x[k] += rng.uniform(-0.05, 0.06);
        let f_reused = reused.eval(&x, &mut g_reused);
        let mut fresh = OriginOracle::with_threads(&prob, params, 4);
        let f_fresh = fresh.eval(&x, &mut g_fresh);
        assert_eq!(f_reused.to_bits(), f_fresh.to_bits(), "objective at step {step}");
        assert_eq!(g_reused, g_fresh, "gradient at step {step}");
    }
    assert_eq!(ctx.live_workers(), 3, "one parked set served all 1000 evals");
}

/// Panics propagate from worker blocks and from the caller's own block,
/// and the pool keeps serving afterwards.
#[test]
fn panic_in_worker_propagates_and_pool_stays_usable() {
    let ctx = ParallelCtx::new(4);
    let ranges = chunk_ranges(48, 3); // 16 chunks → blocks of 4
    let mut slots = vec![0usize; ranges.len()];
    for poison in [9usize, 0] {
        // 9 runs on a parked worker (block 2), 0 on the calling thread.
        let got = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            ctx.map_chunks(&ranges, &mut slots, |c, _, slot| {
                if c == poison {
                    panic!("chunk {c} poisoned");
                }
                *slot = c + 1;
            });
        }));
        assert!(got.is_err(), "panic on chunk {poison} must reach the caller");
    }
    // Pool reusable: a clean pass over the same grid still works.
    ctx.map_chunks(&ranges, &mut slots, |c, range, slot| *slot = c * 1000 + range.len());
    for (c, (slot, range)) in slots.iter().zip(&ranges).enumerate() {
        assert_eq!(*slot, c * 1000 + range.len());
    }
    // And a full solve through the same ctx still succeeds.
    let prob = random_problem(0x9002, 3, 4, 33);
    let cfg = FastOtConfig {
        gamma: 0.8,
        rho: 0.5,
        lbfgs: LbfgsOptions { max_iters: 40, ..Default::default() },
        ..Default::default()
    };
    let res = solve_fast_ot_ctx(&prob, &cfg, vec![0.0; prob.dim()], &ctx);
    assert!(res.dual_objective > 0.0);
}

/// Dropping the last clone joins every worker: the pool's live-worker
/// counter returns to zero (leak check without a global registry).
#[test]
fn drop_joins_all_workers_no_leaks() {
    let ctx = ParallelCtx::new(4);
    let counter = ctx.live_worker_counter();
    assert_eq!(counter.load(Ordering::SeqCst), 0, "lazy: nothing spawned yet");
    let ranges = chunk_ranges(64, 4);
    let mut slots = vec![0u64; ranges.len()];
    ctx.map_chunks(&ranges, &mut slots, |c, _, slot| *slot = c as u64);
    assert_eq!(counter.load(Ordering::SeqCst), 3, "threads − 1 parked workers");
    let clone = ctx.clone();
    drop(ctx);
    assert_eq!(
        counter.load(Ordering::SeqCst),
        3,
        "a live clone keeps the worker set parked"
    );
    drop(clone);
    assert_eq!(counter.load(Ordering::SeqCst), 0, "last drop joined every worker");
}

/// A solve and its warm-started re-solve sharing one ctx stay
/// bit-identical to fresh-ctx and serial runs — pool state carried
/// across solves can never leak into results.
#[test]
fn shared_ctx_across_solve_and_warm_resolve_is_deterministic() {
    let prob = random_problem(0x9003, 4, 3, 41);
    let cfg = |threads: usize| FastOtConfig {
        gamma: 0.5,
        rho: 0.6,
        threads,
        lbfgs: LbfgsOptions { max_iters: 80, ..Default::default() },
        ..Default::default()
    };
    let ctx = ParallelCtx::new(4);
    let cold_shared = solve_fast_ot_ctx(&prob, &cfg(4), vec![0.0; prob.dim()], &ctx);
    let warm_shared = solve_fast_ot_ctx(&prob, &cfg(4), cold_shared.x.clone(), &ctx);

    // Fresh-ctx references (serial and threaded).
    let cold_serial = solve_fast_ot(&prob, &cfg(1));
    assert_eq!(cold_shared.x, cold_serial.x, "cold solve bytes");
    assert_eq!(cold_shared.dual_objective, cold_serial.dual_objective);
    assert_eq!(cold_shared.iterations, cold_serial.iterations);

    let warm_serial = solve_fast_ot_from(&prob, &cfg(1), cold_serial.x.clone());
    assert_eq!(warm_shared.x, warm_serial.x, "warm re-solve bytes");
    assert_eq!(warm_shared.dual_objective, warm_serial.dual_objective);
    assert_eq!(warm_shared.iterations, warm_serial.iterations);
    assert_eq!(ctx.live_workers(), 3, "both solves rode the same parked set");
}
