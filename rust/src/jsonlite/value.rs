//! JSON value model and serializer.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so serialization is
/// deterministic (sorted keys) — handy for golden tests and diffs.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Empty object.
    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    /// Builder-style insert; panics if `self` is not an object.
    pub fn set(mut self, key: &str, v: impl Into<Value>) -> Value {
        match &mut self {
            Value::Obj(map) => {
                map.insert(key.to_string(), v.into());
            }
            _ => panic!("Value::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(map) => map.get(key),
            _ => None,
        }
    }

    /// Path lookup: `get_path(&["a", "b"])` is `self["a"]["b"]`.
    pub fn get_path(&self, path: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Array of numbers convenience accessor.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Value::as_f64).collect()
    }

    /// Serialize compactly (no whitespace).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(x) => write_num(*x, out),
            Value::Str(s) => write_str(s, out),
            Value::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Value::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_num(x: f64, out: &mut String) {
    if x.is_nan() || x.is_infinite() {
        // JSON has no NaN/Inf; encode as null like most tolerant emitters.
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        // Shortest roundtrip formatting from the std formatter.
        out.push_str(&format!("{x}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_json())
    }
}

impl From<f64> for Value {
    fn from(x: f64) -> Value {
        Value::Num(x)
    }
}
impl From<usize> for Value {
    fn from(x: usize) -> Value {
        Value::Num(x as f64)
    }
}
impl From<u64> for Value {
    fn from(x: u64) -> Value {
        Value::Num(x as f64)
    }
}
impl From<i64> for Value {
    fn from(x: i64) -> Value {
        Value::Num(x as f64)
    }
}
impl From<bool> for Value {
    fn from(x: bool) -> Value {
        Value::Bool(x)
    }
}
impl From<&str> for Value {
    fn from(x: &str) -> Value {
        Value::Str(x.to_string())
    }
}
impl From<String> for Value {
    fn from(x: String) -> Value {
        Value::Str(x)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(xs: Vec<T>) -> Value {
        Value::Arr(xs.into_iter().map(Into::into).collect())
    }
}
impl From<&[f64]> for Value {
    fn from(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }
}
