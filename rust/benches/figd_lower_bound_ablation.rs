//! Figure D (appendix): the working-set (lower-bound) ablation at
//! |L| = 10 — "ours" vs "ours w/o lower bounds" vs origin, per γ.
//!
//! Paper shape: without the second idea the method can dip *below* 1×
//! at small |L| (checking overhead dominates); with it, ≈2×.

mod common;

use common::*;
use grpot::benchlib::{report_dir, Table};
use grpot::coordinator::config::Method;
use grpot::coordinator::sweep::run_job;
use grpot::data::synthetic;

fn main() {
    banner("figD: lower-bound (working set) ablation");
    let pair = synthetic::controlled_classes(10, size3(3, 10, 10), 0xF16D);
    let prob = problem_of(&pair);
    let rhos = rho_grid();
    let mi = max_iters();

    let mut table = Table::new(
        "Fig. D — gain with and without the lower-bound working set (|L|=10)",
        &["gamma", "gain with LB", "gain w/o LB"],
    );
    for &gamma in &gamma_grid() {
        let mut t_fast = 0.0;
        let mut t_nows = 0.0;
        let mut t_origin = 0.0;
        for &rho in &rhos {
            let f = run_job(&prob, Method::Fast, gamma, rho, 10, mi);
            let nw = run_job(&prob, Method::FastNoWs, gamma, rho, 10, mi);
            let o = run_job(&prob, Method::Origin, gamma, rho, 10, mi);
            assert_eq!(f.dual_objective, o.dual_objective);
            assert_eq!(nw.dual_objective, o.dual_objective);
            t_fast += f.wall_time_s;
            t_nows += nw.wall_time_s;
            t_origin += o.wall_time_s;
        }
        let with_lb = t_origin / t_fast.max(1e-12);
        let without = t_origin / t_nows.max(1e-12);
        println!("gamma={gamma:<8} with-LB={with_lb:.2}x  without={without:.2}x");
        table.row(vec![
            format!("{gamma}"),
            format!("{with_lb:.2}"),
            format!("{without:.2}"),
        ]);
    }
    table.emit(&report_dir(), "figd_lower_bound_ablation");
}
