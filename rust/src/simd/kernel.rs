//! Lane-vectorized oracle kernels: one generic body per kernel,
//! instantiated once for the portable mirror and once inside an AVX2
//! `#[target_feature]` entry, so both backends run the *same* code with
//! the same per-lane arithmetic.
//!
//! Bit-exactness argument (see also the module docs of [`crate::simd`]):
//! a lane is one column, its `zsq`/`col_mass` accumulators advance over
//! ascending `i` exactly like the scalar kernel's, and the single
//! cross-lane fold adds lanes into `grad_alpha[i]` in ascending column
//! order — the association the scalar panel walk already produces. The
//! two places the vector path performs an operation the scalar path
//! *skips* are additions of `+0.0` to accumulators that are provably
//! never `-0.0` (they start at `+0.0` and only ever gain non-negative
//! terms), which is a bitwise identity under IEEE-754; everything else
//! is operation-for-operation identical.
//!
//! Scope: the identity holds for all **finite** inputs (every input
//! the solver can produce — costs are finite by construction and a
//! non-finite iterate already poisons the objective before any kernel
//! comparison matters). Under `f = NaN`/`±inf` the snapshot `õ` chain
//! ([`snapshot_quad`]'s `min`-based `[f]₋`) is the one place scalar
//! and vector arithmetic can differ, because no single branchless
//! formulation reproduces the scalar `if f > 0.0` routing for both
//! `NaN` and `+inf` at once.

use super::lane::{Lanes, Portable4};
use super::{Dispatch, LANES};
use crate::ot::dual::KernelConsts;
use std::ops::Range;

/// ψ and ∇ψ of one group over a quad of [`LANES`] columns — the vector
/// form of [`crate::ot::dual::group_grad_contrib`].
///
/// `tile` is the packed `[i][lane]` cost slice for this (group, quad)
/// (`4·g` values, unit stride — see [`crate::ot::pack::PackedCost`]);
/// `beta4` holds the quad's β values in ascending column order; `quad`
/// is caller scratch of at least `4·g` values. Returns the per-lane
/// `(ψ, col_mass)` pairs; lane `t`'s values are bit-identical to a
/// scalar `group_grad_contrib` call for column `j₀ + t`, and
/// `grad_alpha` receives exactly the bytes the four scalar calls (in
/// ascending column order) would have produced.
///
/// Must not be called with `Dispatch::Scalar` — the scalar path keeps
/// running the original kernel and never packs tiles.
pub fn group_quad_contrib(
    dispatch: Dispatch,
    alpha: &[f64],
    beta4: &[f64; LANES],
    tile: &[f64],
    range: Range<usize>,
    consts: &KernelConsts,
    grad_alpha: &mut [f64],
    quad: &mut [f64],
) -> ([f64; LANES], [f64; LANES]) {
    match dispatch {
        Dispatch::Scalar => unreachable!("scalar dispatch never reaches the quad kernel"),
        Dispatch::Portable => {
            group_quad_generic::<Portable4>(alpha, beta4, tile, range, consts, grad_alpha, quad)
        }
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Dispatch::Avx2` is only constructed after
        // `is_x86_feature_detected!("avx2")` succeeded (see
        // `Dispatch::resolve`), so the target-feature entry is valid on
        // this CPU.
        Dispatch::Avx2 => unsafe {
            group_quad_avx2(alpha, beta4, tile, range, consts, grad_alpha, quad)
        },
    }
}

/// ψ and ∇ψ of one group of **one shared column** under [`LANES`]
/// independent problems — the lane-remapped form of
/// [`crate::ot::dual::group_grad_contrib`] used by the batched
/// multi-problem oracle ([`crate::ot::batch`]): instead of four columns
/// of one problem, the lanes carry the *same* column `j` under four
/// (γ, ρ, dual-iterate) triples, so the cost segment `c_seg` is read
/// once for all four.
///
/// `alphas[t]`/`beta4[t]`/`consts4[t]` are problem `t`'s dual iterate
/// and kernel constants; `c_seg` is the shared unit-stride cost segment
/// for this (column, group) (`g` values). `quad` is caller scratch of
/// at least `4·g` values; on return, for every lane `t` with
/// `active[t]`, `quad[4·k + t]` holds the gradient contribution
/// `t_{ij}` for row `range.start + k` of problem `t` — the caller
/// applies `grad_alpha_t[range.start + k] += quad[4·k + t]` itself
/// (each element receives exactly one add, the same single add the
/// scalar kernel performs), because the four problems' gradients live
/// in four different vectors. Inactive lanes (zero groups) get no
/// defined `quad` contents and must receive no gradient adds, exactly
/// like the scalar kernel's early return.
///
/// Returns per-lane `(ψ, col_mass, active)`; lane `t`'s values are
/// bit-identical to a scalar `group_grad_contrib` call for problem `t`
/// on column `j` — each lane's `zsq`/`t`/`col_mass` chains advance over
/// ascending `i` exactly like the scalar kernel's, and there is no
/// cross-lane fold at all (the lanes belong to different problems).
///
/// Must not be called with `Dispatch::Scalar`.
pub fn batch_quad_contrib(
    dispatch: Dispatch,
    alphas: &[&[f64]; LANES],
    beta4: &[f64; LANES],
    c_seg: &[f64],
    range: Range<usize>,
    consts4: &[KernelConsts; LANES],
    quad: &mut [f64],
) -> ([f64; LANES], [f64; LANES], [bool; LANES]) {
    match dispatch {
        Dispatch::Scalar => unreachable!("scalar dispatch never reaches the quad kernel"),
        Dispatch::Portable => {
            batch_quad_generic::<Portable4>(alphas, beta4, c_seg, range, consts4, quad)
        }
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as in `group_quad_contrib`.
        Dispatch::Avx2 => unsafe { batch_quad_avx2(alphas, beta4, c_seg, range, consts4, quad) },
    }
}

/// Snapshot norms of one group over a quad of [`LANES`] columns — the
/// vector form of the `recompute_snapshots` inner loop: per-lane
/// `(Σ[f]₊², Σf², Σ[f]₋²)` chains over ascending `i`, bit-identical to
/// the scalar chains (the scalar loop's skipped `+0.0` additions are
/// bitwise no-ops on these non-negative accumulators).
pub fn snapshot_quad(
    dispatch: Dispatch,
    alpha: &[f64],
    beta4: &[f64; LANES],
    tile: &[f64],
    range: Range<usize>,
) -> ([f64; LANES], [f64; LANES], [f64; LANES]) {
    match dispatch {
        Dispatch::Scalar => unreachable!("scalar dispatch never reaches the quad kernel"),
        Dispatch::Portable => snapshot_quad_generic::<Portable4>(alpha, beta4, tile, range),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as in `group_quad_contrib`.
        Dispatch::Avx2 => unsafe { snapshot_quad_avx2(alpha, beta4, tile, range) },
    }
}

/// Element-wise `out[i] = a[i] - b[i]` (the semi-dual oracle's column
/// staging). Bit-identical on every backend — subtraction is a single
/// IEEE operation per element — so this entry accepts
/// `Dispatch::Scalar` too.
pub fn sub_into(dispatch: Dispatch, out: &mut [f64], a: &[f64], b: &[f64]) {
    assert_eq!(out.len(), a.len());
    assert_eq!(out.len(), b.len());
    match dispatch {
        Dispatch::Scalar => {
            for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
                *o = x - y;
            }
        }
        Dispatch::Portable => sub_generic::<Portable4>(out, a, b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as in `group_quad_contrib`.
        Dispatch::Avx2 => unsafe { sub_avx2(out, a, b) },
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn group_quad_avx2(
    alpha: &[f64],
    beta4: &[f64; LANES],
    tile: &[f64],
    range: Range<usize>,
    consts: &KernelConsts,
    grad_alpha: &mut [f64],
    quad: &mut [f64],
) -> ([f64; LANES], [f64; LANES]) {
    group_quad_generic::<super::lane::Avx2>(alpha, beta4, tile, range, consts, grad_alpha, quad)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn batch_quad_avx2(
    alphas: &[&[f64]; LANES],
    beta4: &[f64; LANES],
    c_seg: &[f64],
    range: Range<usize>,
    consts4: &[KernelConsts; LANES],
    quad: &mut [f64],
) -> ([f64; LANES], [f64; LANES], [bool; LANES]) {
    batch_quad_generic::<super::lane::Avx2>(alphas, beta4, c_seg, range, consts4, quad)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn snapshot_quad_avx2(
    alpha: &[f64],
    beta4: &[f64; LANES],
    tile: &[f64],
    range: Range<usize>,
) -> ([f64; LANES], [f64; LANES], [f64; LANES]) {
    snapshot_quad_generic::<super::lane::Avx2>(alpha, beta4, tile, range)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn sub_avx2(out: &mut [f64], a: &[f64], b: &[f64]) {
    sub_generic::<super::lane::Avx2>(out, a, b)
}

/// The generic quad kernel body. `#[inline(always)]` so the AVX2 entry
/// absorbs it (and the lane methods) under its target feature.
#[inline(always)]
fn group_quad_generic<V: Lanes>(
    alpha: &[f64],
    beta4: &[f64; LANES],
    tile: &[f64],
    range: Range<usize>,
    consts: &KernelConsts,
    grad_alpha: &mut [f64],
    quad: &mut [f64],
) -> ([f64; LANES], [f64; LANES]) {
    let start = range.start;
    let g = range.len();
    debug_assert_eq!(tile.len(), LANES * g);
    debug_assert!(quad.len() >= LANES * g);
    debug_assert!(grad_alpha.len() >= start + g);
    let beta_v = V::from_array(*beta4);
    let zero = V::splat(0.0);
    // Pass 1: per-lane f = α_i + β_j − c_ij, [f]₊ into `quad`, zsq
    // chains over ascending i — each lane is the scalar pass 1.
    let mut zsq_v = zero;
    for k in 0..g {
        let c4 = V::load(&tile[LANES * k..]);
        let f = V::splat(alpha[start + k]).add(beta_v).sub(c4);
        let fp = f.max(zero);
        fp.store(&mut quad[LANES * k..]);
        zsq_v = zsq_v.add(fp.mul(fp));
    }
    let zsq = zsq_v.to_array();
    let active: [bool; LANES] = std::array::from_fn(|t| zsq[t] > consts.tau_sq);
    let n_active = active.iter().filter(|&&a| a).count();
    let mut psi4 = [0.0; LANES];
    let mut mass4 = [0.0; LANES];
    if n_active == 0 {
        // Every lane is a zero group: the scalar kernel returns (0, 0)
        // for each and never touches grad_alpha.
        return (psi4, mass4);
    }
    if n_active == LANES {
        // Pass 2, all lanes active: t = scale·[f]₊ per lane, col_mass
        // chains per lane over ascending i; the fold into grad_alpha[i]
        // adds lanes in ascending column order — exactly the scalar
        // panel walk's association.
        let mut scale4 = [0.0; LANES];
        for t in 0..LANES {
            let z = zsq[t].sqrt();
            let slack = z - consts.tau;
            scale4[t] = slack * consts.inv_lq / z;
            psi4[t] = slack * slack * consts.half_inv_lq;
        }
        let scale_v = V::from_array(scale4);
        let mut mass_v = zero;
        let mut t4 = [0.0; LANES];
        for k in 0..g {
            let tv = scale_v.mul(V::load(&quad[LANES * k..]));
            mass_v = mass_v.add(tv);
            tv.store(&mut t4);
            let ga = &mut grad_alpha[start + k];
            *ga += t4[0];
            *ga += t4[1];
            *ga += t4[2];
            *ga += t4[3];
        }
        mass4 = mass_v.to_array();
        return (psi4, mass4);
    }
    // Mixed activity: scalar pass 2 per active lane, in ascending
    // column order (inactive lanes contribute nothing, exactly like the
    // scalar kernel's early return).
    for t in 0..LANES {
        if !active[t] {
            continue;
        }
        let z = zsq[t].sqrt();
        let slack = z - consts.tau;
        let scale = slack * consts.inv_lq / z;
        psi4[t] = slack * slack * consts.half_inv_lq;
        let mut mass = 0.0;
        for k in 0..g {
            let tv = scale * quad[LANES * k + t];
            grad_alpha[start + k] += tv;
            mass += tv;
        }
        mass4[t] = mass;
    }
    (psi4, mass4)
}

/// The generic batched-problem kernel body. Lane `t` runs problem `t`'s
/// scalar arithmetic; the cost element is splatted across lanes (one
/// read per element for all four problems — the whole point of the
/// batched oracle), and there is no cross-lane fold.
#[inline(always)]
fn batch_quad_generic<V: Lanes>(
    alphas: &[&[f64]; LANES],
    beta4: &[f64; LANES],
    c_seg: &[f64],
    range: Range<usize>,
    consts4: &[KernelConsts; LANES],
    quad: &mut [f64],
) -> ([f64; LANES], [f64; LANES], [bool; LANES]) {
    let start = range.start;
    let g = range.len();
    debug_assert_eq!(c_seg.len(), g);
    debug_assert!(quad.len() >= LANES * g);
    for a in alphas {
        debug_assert!(a.len() >= start + g);
    }
    let beta_v = V::from_array(*beta4);
    let zero = V::splat(0.0);
    // Pass 1: per-lane f = α_i + β_j − c_ij over the shared column, [f]₊
    // into `quad`, per-lane zsq chains over ascending i.
    let mut zsq_v = zero;
    for k in 0..g {
        let a4 = V::from_array(std::array::from_fn(|t| alphas[t][start + k]));
        let f = a4.add(beta_v).sub(V::splat(c_seg[k]));
        let fp = f.max(zero);
        fp.store(&mut quad[LANES * k..]);
        zsq_v = zsq_v.add(fp.mul(fp));
    }
    let zsq = zsq_v.to_array();
    let active: [bool; LANES] = std::array::from_fn(|t| zsq[t] > consts4[t].tau_sq);
    let n_active = active.iter().filter(|&&a| a).count();
    let mut psi4 = [0.0; LANES];
    let mut mass4 = [0.0; LANES];
    if n_active == 0 {
        return (psi4, mass4, active);
    }
    if n_active == LANES {
        // Pass 2, all lanes active: t = scale·[f]₊ per lane (per-lane
        // scale from each problem's own constants), written back into
        // `quad` for the caller's per-problem gradient adds; col_mass
        // chains per lane over ascending i.
        let mut scale4 = [0.0; LANES];
        for t in 0..LANES {
            let z = zsq[t].sqrt();
            let slack = z - consts4[t].tau;
            scale4[t] = slack * consts4[t].inv_lq / z;
            psi4[t] = slack * slack * consts4[t].half_inv_lq;
        }
        let scale_v = V::from_array(scale4);
        let mut mass_v = zero;
        for k in 0..g {
            let tv = scale_v.mul(V::load(&quad[LANES * k..]));
            mass_v = mass_v.add(tv);
            tv.store(&mut quad[LANES * k..]);
        }
        mass4 = mass_v.to_array();
        return (psi4, mass4, active);
    }
    // Mixed activity: scalar pass 2 per active lane (inactive lanes
    // contribute nothing, exactly like the scalar kernel's early
    // return — their `quad` entries are left as [f]₊ and must not be
    // read by the caller).
    for t in 0..LANES {
        if !active[t] {
            continue;
        }
        let z = zsq[t].sqrt();
        let slack = z - consts4[t].tau;
        let scale = slack * consts4[t].inv_lq / z;
        psi4[t] = slack * slack * consts4[t].half_inv_lq;
        let mut mass = 0.0;
        for k in 0..g {
            let tv = scale * quad[LANES * k + t];
            quad[LANES * k + t] = tv;
            mass += tv;
        }
        mass4[t] = mass;
    }
    (psi4, mass4, active)
}

#[inline(always)]
fn snapshot_quad_generic<V: Lanes>(
    alpha: &[f64],
    beta4: &[f64; LANES],
    tile: &[f64],
    range: Range<usize>,
) -> ([f64; LANES], [f64; LANES], [f64; LANES]) {
    let start = range.start;
    let g = range.len();
    debug_assert_eq!(tile.len(), LANES * g);
    let beta_v = V::from_array(*beta4);
    let zero = V::splat(0.0);
    let mut zsq = zero;
    let mut ksq = zero;
    let mut osq = zero;
    for k in 0..g {
        let c4 = V::load(&tile[LANES * k..]);
        let f = V::splat(alpha[start + k]).add(beta_v).sub(c4);
        ksq = ksq.add(f.mul(f));
        let fp = f.max(zero);
        zsq = zsq.add(fp.mul(fp));
        let fm = f.min(zero);
        osq = osq.add(fm.mul(fm));
    }
    (zsq.to_array(), ksq.to_array(), osq.to_array())
}

#[inline(always)]
fn sub_generic<V: Lanes>(out: &mut [f64], a: &[f64], b: &[f64]) {
    let n = out.len();
    let full = n - n % LANES;
    let mut i = 0;
    while i < full {
        V::load(&a[i..]).sub(V::load(&b[i..])).store(&mut out[i..]);
        i += LANES;
    }
    for k in full..n {
        out[k] = a[k] - b[k];
    }
}
