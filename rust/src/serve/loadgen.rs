//! Closed-loop load generator for the serving engine — the measurement
//! half of `grpot bench-serve` and `cargo bench --bench bench_serve`.
//!
//! N client threads each issue requests back-to-back (closed loop: a
//! client's next request waits for its previous response), cycling over
//! a (γ × ρ) grid on one dataset. Cycle 1 is cold; every later cycle
//! re-requests the same keys, so the warm-start cache must show hits —
//! the repeated-workload scenario a serving deployment lives in.
//!
//! The report carries throughput and latency percentiles computed over
//! *served* requests only (rejections return in microseconds and would
//! flatter both numbers), outcome counts for every request, and engine
//! counters (solves, batches, warm hit rate).

use super::engine::{Engine, RejectReason, SolveRequest};
use super::ServeConfig;
use crate::benchlib::percentile_sorted;
use crate::coordinator::config::{DatasetSpec, Method};
use crate::ot::regularizer::RegKind;
use crate::coordinator::metrics::Metrics;
use crate::jsonlite::Value;
use crate::rng::Pcg64;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Workload description.
#[derive(Clone, Debug)]
pub struct LoadScenario {
    pub spec: DatasetSpec,
    pub gammas: Vec<f64>,
    pub rhos: Vec<f64>,
    /// Passes over the grid per client (≥ 2 exercises warm starts).
    pub cycles: usize,
    /// Concurrent closed-loop clients.
    pub clients: usize,
    pub method: Method,
    /// Regularizer stamped on every request.
    pub regularizer: RegKind,
    /// Per-request deadline forwarded to the engine.
    pub deadline: Option<Duration>,
    /// Seeded chaos mode (`None` = well-behaved clients). With a seed,
    /// every third request per client is perturbed — a near-zero
    /// deadline, an invalid γ, or a poisoned dataset family, chosen by
    /// a PRNG derived from the seed — so rejections, mid-solve
    /// cancellations and circuit-breaker quarantines are exercised
    /// under real concurrency while staying reproducible.
    pub chaos_seed: Option<u64>,
}

impl Default for LoadScenario {
    fn default() -> Self {
        LoadScenario {
            spec: DatasetSpec::default(),
            gammas: vec![0.1, 1.0],
            rhos: vec![0.4, 0.8],
            cycles: 2,
            clients: 4,
            method: Method::Fast,
            regularizer: RegKind::GroupLasso,
            deadline: None,
            chaos_seed: None,
        }
    }
}

impl LoadScenario {
    /// Requests each client will issue.
    pub fn requests_per_client(&self) -> usize {
        self.cycles * self.gammas.len() * self.rhos.len()
    }

    /// Total requests across all clients.
    pub fn total_requests(&self) -> usize {
        self.clients * self.requests_per_client()
    }
}

/// Aggregated measurement of one load run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub requests: usize,
    pub ok: usize,
    pub rejected_queue_full: usize,
    pub rejected_deadline: usize,
    pub rejected_quarantined: usize,
    pub rejected_overloaded: usize,
    pub failed: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    pub solves: u64,
    pub batches: u64,
    pub warm_hits: u64,
    pub warm_misses: u64,
    /// `warm_hits / (warm_hits + warm_misses)`, 0 when no solves ran.
    pub warm_hit_rate: f64,
    /// Mean seconds a served request spent queued before its batch ran.
    pub mean_queue_wait_ms: f64,
    /// Mean seconds per engine solve (`serve.solve_seconds` histogram).
    pub mean_solve_ms: f64,
}

impl LoadReport {
    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("requests", self.requests)
            .set("ok", self.ok)
            .set("rejected_queue_full", self.rejected_queue_full)
            .set("rejected_deadline", self.rejected_deadline)
            .set("rejected_quarantined", self.rejected_quarantined)
            .set("rejected_overloaded", self.rejected_overloaded)
            .set("failed", self.failed)
            .set("wall_s", self.wall_s)
            .set("throughput_rps", self.throughput_rps)
            .set("p50_ms", self.p50_ms)
            .set("p95_ms", self.p95_ms)
            .set("p99_ms", self.p99_ms)
            .set("max_ms", self.max_ms)
            .set("solves", self.solves)
            .set("batches", self.batches)
            .set("warm_hits", self.warm_hits)
            .set("warm_misses", self.warm_misses)
            .set("warm_hit_rate", self.warm_hit_rate)
            .set("mean_queue_wait_ms", self.mean_queue_wait_ms)
            .set("mean_solve_ms", self.mean_solve_ms)
    }

    /// Human-readable multi-line summary.
    pub fn print_summary(&self) {
        println!(
            "requests   : {} ok, {} queue-full, {} deadline, {} quarantined, {} overloaded, {} failed (of {})",
            self.ok,
            self.rejected_queue_full,
            self.rejected_deadline,
            self.rejected_quarantined,
            self.rejected_overloaded,
            self.failed,
            self.requests
        );
        println!("throughput : {:.2} req/s over {:.2}s", self.throughput_rps, self.wall_s);
        println!(
            "latency    : p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms | max {:.2} ms",
            self.p50_ms, self.p95_ms, self.p99_ms, self.max_ms
        );
        println!(
            "engine     : {} solves in {} batches | warm hit rate {:.1}% ({} hits / {} misses)",
            self.solves,
            self.batches,
            100.0 * self.warm_hit_rate,
            self.warm_hits,
            self.warm_misses
        );
        println!(
            "spans      : mean time-in-queue {:.2} ms | mean time-in-solve {:.2} ms per request",
            self.mean_queue_wait_ms, self.mean_solve_ms
        );
    }
}

/// Run the closed loop: start an engine with `cfg`, drive it with the
/// scenario's clients, shut it down and report.
pub fn run_load(cfg: ServeConfig, scenario: &LoadScenario) -> LoadReport {
    let metrics = Arc::new(Metrics::new());
    let engine = Engine::start(cfg, Arc::clone(&metrics));

    let latencies = Mutex::new(Vec::with_capacity(scenario.total_requests()));
    let queue_waits = Mutex::new(Vec::with_capacity(scenario.total_requests()));
    // ok, queue_full, deadline, quarantined, overloaded, failed
    let counts = Mutex::new([0usize; 6]);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..scenario.clients {
            let engine = &engine;
            let latencies = &latencies;
            let queue_waits = &queue_waits;
            let counts = &counts;
            s.spawn(move || {
                let mut local_lat = Vec::with_capacity(scenario.requests_per_client());
                let mut local_wait = Vec::with_capacity(scenario.requests_per_client());
                let mut local = [0usize; 6];
                let mut chaos = scenario
                    .chaos_seed
                    .map(|s| Pcg64::new(s ^ (c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)));
                let mut issued = 0usize;
                // Offset each client's walk so concurrent clients mix
                // distinct and identical keys deterministically.
                let grid: Vec<(f64, f64)> = scenario
                    .gammas
                    .iter()
                    .flat_map(|&g| scenario.rhos.iter().map(move |&r| (g, r)))
                    .collect();
                for _cycle in 0..scenario.cycles {
                    for k in 0..grid.len() {
                        let (gamma, rho) = grid[(k + c) % grid.len()];
                        let mut request = SolveRequest {
                            spec: scenario.spec.clone(),
                            gamma,
                            rho,
                            method: scenario.method,
                            regularizer: scenario.regularizer,
                            deadline: scenario.deadline,
                            warm_start: true,
                        };
                        // Chaos: perturb every third request on a fixed
                        // cadence (so a run always disturbs something)
                        // with a fault mode chosen by the seeded PRNG.
                        if let Some(rng) = chaos.as_mut() {
                            if issued % 3 == 0 {
                                match (rng.uniform(0.0, 3.0)) as u32 {
                                    0 => request.deadline = Some(Duration::from_nanos(1)),
                                    1 => request.gamma = -1.0,
                                    _ => request.spec.family = "chaos-poison".into(),
                                }
                            }
                        }
                        issued += 1;
                        let t = Instant::now();
                        let out = engine.submit(request);
                        // Rejections return in microseconds; only served
                        // requests count toward latency and throughput,
                        // otherwise shed load would flatter the numbers.
                        let slot = match out {
                            Ok(reply) => {
                                local_lat.push(t.elapsed().as_secs_f64());
                                local_wait.push(reply.queue_wait_s);
                                0
                            }
                            Err(RejectReason::QueueFull { .. }) => 1,
                            Err(RejectReason::DeadlineExceeded { .. }) => 2,
                            Err(RejectReason::Quarantined { .. }) => 3,
                            Err(RejectReason::Overloaded { .. }) => 4,
                            Err(_) => 5,
                        };
                        local[slot] += 1;
                    }
                }
                latencies.lock().unwrap().extend(local_lat);
                queue_waits.lock().unwrap().extend(local_wait);
                let mut shared = counts.lock().unwrap();
                for (acc, v) in shared.iter_mut().zip(local) {
                    *acc += v;
                }
            });
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    engine.shutdown();

    let mut lats = latencies.into_inner().unwrap();
    // `total_cmp`, not `partial_cmp(..).unwrap()`: a NaN latency (it
    // cannot happen today, but Instant math is not worth betting on)
    // must not kill the report thread.
    lats.sort_by(f64::total_cmp);
    let waits = queue_waits.into_inner().unwrap();
    let mean_queue_wait_ms = if waits.is_empty() {
        0.0
    } else {
        waits.iter().sum::<f64>() / waits.len() as f64 * 1e3
    };
    let pct = |p: f64| -> f64 {
        if lats.is_empty() {
            0.0
        } else {
            percentile_sorted(&lats, p) * 1e3
        }
    };
    let [ok, queue_full, deadline, quarantined, overloaded, failed] =
        counts.into_inner().unwrap();
    let warm_hits = metrics.get("serve.warm_hits");
    let warm_misses = metrics.get("serve.warm_misses");
    let warm_total = warm_hits + warm_misses;
    let requests = scenario.total_requests();
    LoadReport {
        requests,
        ok,
        rejected_queue_full: queue_full,
        rejected_deadline: deadline,
        rejected_quarantined: quarantined,
        rejected_overloaded: overloaded,
        failed,
        wall_s,
        throughput_rps: if wall_s > 0.0 { ok as f64 / wall_s } else { 0.0 },
        p50_ms: pct(50.0),
        p95_ms: pct(95.0),
        p99_ms: pct(99.0),
        max_ms: lats.last().copied().unwrap_or(0.0) * 1e3,
        solves: metrics.get("serve.solves"),
        batches: metrics.get("serve.batches"),
        warm_hits,
        warm_misses,
        warm_hit_rate: if warm_total > 0 { warm_hits as f64 / warm_total as f64 } else { 0.0 },
        mean_queue_wait_ms,
        mean_solve_ms: metrics.hist_mean("serve.solve_seconds").unwrap_or(0.0) * 1e3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_scenario() -> LoadScenario {
        LoadScenario {
            spec: DatasetSpec {
                family: "synthetic".into(),
                param1: 3,
                param2: 4,
                seed: 9,
                ..Default::default()
            },
            gammas: vec![0.5, 1.0],
            rhos: vec![0.5],
            cycles: 2,
            clients: 3,
            method: Method::Fast,
            regularizer: RegKind::GroupLasso,
            deadline: None,
            chaos_seed: None,
        }
    }

    fn accounted(report: &LoadReport) -> usize {
        report.ok
            + report.rejected_queue_full
            + report.rejected_deadline
            + report.rejected_quarantined
            + report.rejected_overloaded
            + report.failed
    }

    #[test]
    fn closed_loop_accounts_for_every_request() {
        let scenario = tiny_scenario();
        let report = run_load(ServeConfig { workers: 2, ..Default::default() }, &scenario);
        assert_eq!(report.requests, scenario.total_requests());
        assert_eq!(accounted(&report), report.requests);
        // Generous queue + no deadlines: everything succeeds.
        assert_eq!(report.ok, report.requests);
        // Repeated workload must warm-start.
        assert!(report.warm_hits > 0, "no warm hits: {report:?}");
        assert!(report.warm_hit_rate > 0.0);
        // Batching can only deduplicate, never add solves.
        assert!(report.solves <= report.requests as u64);
        assert!(report.p50_ms <= report.p95_ms && report.p95_ms <= report.p99_ms);
        assert!(report.throughput_rps > 0.0);
        let v = report.to_json();
        assert_eq!(v.get("ok").and_then(Value::as_usize), Some(report.ok));
        // Span summary fields: queue waits are recorded per served
        // request, solve time comes from the engine histogram.
        assert!(report.mean_queue_wait_ms >= 0.0);
        assert!(report.mean_solve_ms > 0.0, "no solve time: {report:?}");
        assert!(v.get("mean_solve_ms").is_some());
    }

    #[test]
    fn chaos_mode_disturbs_but_accounts_for_every_request() {
        let mut scenario = tiny_scenario();
        scenario.chaos_seed = Some(7);
        scenario.cycles = 4;
        let report = run_load(ServeConfig { workers: 2, ..Default::default() }, &scenario);
        assert_eq!(report.requests, scenario.total_requests());
        // Every request — perturbed or not — lands in exactly one bucket.
        assert_eq!(accounted(&report), report.requests);
        // A third of requests are perturbed: at least one must have been
        // rejected or failed, and the engine must keep serving the rest.
        assert!(report.ok > 0, "chaos drowned every request: {report:?}");
        assert!(report.ok < report.requests, "chaos had no effect: {report:?}");
        // Perturbed requests never poison the report's JSON round-trip.
        let v = report.to_json();
        assert_eq!(v.get("failed").and_then(Value::as_usize), Some(report.failed));
        assert!(v.get("rejected_quarantined").is_some());
        assert!(v.get("rejected_overloaded").is_some());
    }
}
