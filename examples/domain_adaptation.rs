//! Unsupervised domain adaptation on the digits task (the application
//! motivating the paper): transport labeled USPS-like samples onto
//! MNIST-like samples and classify with 1-NN.
//!
//! Compares:
//! * no adaptation (1-NN straight across the gap),
//! * entropic OT (Cuturi 2013 — label-blind),
//! * entropic + ℓ1ℓ2 group lasso (Courty et al. 2017 — no true sparsity),
//! * group-sparse OT, ours == origin (Blondel et al. 2018 + this paper's
//!   screening; label-aware and group-sparse).
//!
//! Run: `cargo run --release --example domain_adaptation`

use grpot::data::cost::CostMatrix;
use grpot::eval;
use grpot::ot::plan::{recover_plan, TransportPlan};
use grpot::ot::sinkhorn::{gcg_group_lasso, sinkhorn_log, GcgOptions};
use grpot::prelude::*;

fn main() {
    let samples = 400; // per domain; paper uses 5000 (same 10 classes)
    let pair = grpot::data::digits::usps_to_mnist(samples, 0x0DD5);
    println!("task: {} ({} samples/domain, 10 classes)", pair.task_name(), samples);

    let base_acc = eval::no_adaptation_accuracy(&pair);
    println!("\n1-NN without adaptation          : {:.3}", base_acc);

    // ---- Entropic OT (label-blind). -----------------------------------
    let cm = CostMatrix::squared_euclidean(&pair);
    let m = pair.source.len();
    let n = pair.target.len();
    let a = vec![1.0 / m as f64; m];
    let b = vec![1.0 / n as f64; n];
    let ent = sinkhorn_log(&a, &b, &cm.c, 0.01, 500, 1e-9);
    let ent_acc = otda_with_plan(&pair, ent.plan.clone());
    println!("entropic OT (Sinkhorn)           : {:.3}", ent_acc);

    // ---- Entropic + ℓ1ℓ2 group lasso (Courty et al. 2017). ------------
    let groups = grpot::groups::GroupStructure::from_labels(&pair.source.labels);
    // Rows must be permuted into grouped order for the group regularizer.
    let cost_sorted = {
        let mut c = grpot::linalg::Mat::zeros(m, n);
        for (k, &orig) in groups.perm.iter().enumerate() {
            c.row_mut(k).copy_from_slice(cm.c.row(orig));
        }
        c
    };
    let gl = gcg_group_lasso(
        &a,
        &b,
        &cost_sorted,
        &groups,
        &GcgOptions { reg_entropy: 0.01, reg_group: 0.05, max_outer: 10, ..Default::default() },
    );
    // Un-permute rows for evaluation.
    let mut gl_plan = grpot::linalg::Mat::zeros(m, n);
    for (k, &orig) in groups.perm.iter().enumerate() {
        gl_plan.row_mut(orig).copy_from_slice(gl.plan.row(k));
    }
    let gl_acc = otda_with_plan(&pair, gl_plan);
    println!("entropic + ℓ1ℓ2 GL (Courty'17)   : {:.3}", gl_acc);

    // ---- Group-sparse OT (this paper). --------------------------------
    let prob = OtProblem::from_dataset(&pair);
    let cfg = FastOtConfig { gamma: 0.01, rho: 0.6, ..Default::default() };
    let fast = solve_fast_ot(&prob, &cfg);
    let origin = solve_origin(&prob, &cfg);
    assert_eq!(fast.dual_objective, origin.dual_objective, "Theorem 2");
    let plan = recover_plan(&prob, &cfg.params(), &fast.x);
    let gs_acc = eval::otda_accuracy(&pair, &prob, &plan);
    println!(
        "group-sparse OT (ours == origin) : {:.3}   [{:.2}x faster than origin: {:.3}s vs {:.3}s]",
        gs_acc,
        origin.wall_time_s / fast.wall_time_s.max(1e-9),
        fast.wall_time_s,
        origin.wall_time_s
    );
    println!(
        "  plan group sparsity {:.3} | entropic plan density 1.000 (never sparse)",
        plan.group_sparsity(&prob, 1e-12)
    );

    assert!(gs_acc >= base_acc - 0.05, "adaptation should not hurt much");
    println!("\ndomain_adaptation OK");
}

/// OTDA accuracy for a plan given in the *original* source row order.
fn otda_with_plan(pair: &grpot::data::DomainPair, plan: grpot::linalg::Mat) -> f64 {
    let tp = TransportPlan { t: plan };
    let mapped = tp.barycentric_map(&pair.target.x);
    let row_mass = tp.t.row_sums();
    let keep: Vec<usize> = (0..mapped.rows()).filter(|&i| row_mass[i] > 1e-12).collect();
    let mut refs = grpot::linalg::Mat::zeros(keep.len(), mapped.cols());
    let mut labels = Vec::with_capacity(keep.len());
    for (r, &i) in keep.iter().enumerate() {
        refs.row_mut(r).copy_from_slice(mapped.row(i));
        labels.push(pair.source.labels[i]);
    }
    let pred = eval::knn1_predict(&refs, &labels, &pair.target.x);
    eval::accuracy(&pred, &pair.target.labels)
}
