//! Plan-level integration tests: feasibility, sparsity structure,
//! duality identities and qualitative Figure-1 behaviour.

use grpot::data::synthetic;
use grpot::ot::dual::{DualParams, OtProblem};
use grpot::ot::fastot::{solve_fast_ot, FastOtConfig};
use grpot::ot::plan::recover_plan;
use grpot::ot::sinkhorn::sinkhorn_log;
use grpot::solvers::lbfgs::LbfgsOptions;
use grpot::testing::{check, Config};

fn tight_cfg(gamma: f64, rho: f64) -> FastOtConfig {
    FastOtConfig {
        gamma,
        rho,
        lbfgs: LbfgsOptions { max_iters: 2000, gtol: 1e-8, ftol: 1e-14, ..Default::default() },
        ..Default::default()
    }
}

#[test]
fn plan_nonnegative_and_marginal_feasible() {
    check("plan feasibility", &Config::cases(12), |rng| {
        let l = 2 + rng.below(4);
        let g = 2 + rng.below(5);
        let pair = synthetic::controlled(l, g, rng.next_u64());
        let prob = OtProblem::from_dataset(&pair);
        let gamma = [0.05, 0.5, 5.0][rng.below(3)];
        let rho = rng.uniform(0.1, 0.9);
        let res = solve_fast_ot(&prob, &tight_cfg(gamma, rho));
        let plan = recover_plan(&prob, &DualParams::new(gamma, rho), &res.x);
        if plan.t.as_slice().iter().any(|&v| v < 0.0) {
            return Err("negative plan entry".into());
        }
        let (va, vb) = plan.marginal_violation(&prob);
        if va > 0.02 || vb > 0.02 {
            return Err(format!("marginal violation too large: ({va}, {vb})"));
        }
        Ok(())
    });
}

#[test]
fn figure1_group_structure_vs_entropic() {
    // The paper's Figure 1: group-sparse OT sends each target's mass
    // from a single class; entropic OT mixes classes.
    let pair = synthetic::controlled(4, 8, 0xF1);
    let prob = OtProblem::from_dataset(&pair);
    let cfg = tight_cfg(0.1, 0.8);
    let res = solve_fast_ot(&prob, &cfg);
    let plan = recover_plan(&prob, &cfg.params(), &res.x);
    let pure = plan.single_class_columns(&prob, 1e-10);
    assert!(pure > 0.9, "group-sparse plan should be near-pure: {pure}");

    let ent = sinkhorn_log(&prob.a, &prob.b, &prob.cost(), 0.5, 500, 1e-9);
    // Entropic plans are strictly positive ⇒ zero pure columns.
    let mut ent_pure = 0;
    for j in 0..prob.n() {
        let mut active = 0;
        for l in 0..prob.groups.num_groups() {
            if prob.groups.range(l).any(|i| ent.plan[(i, j)] > 1e-10) {
                active += 1;
            }
        }
        if active == 1 {
            ent_pure += 1;
        }
    }
    assert_eq!(ent_pure, 0, "entropic plan should never be group-pure");
}

#[test]
fn group_sparsity_monotone_in_rho() {
    let pair = synthetic::controlled(5, 6, 0xF2);
    let prob = OtProblem::from_dataset(&pair);
    let mut last = -1.0;
    for rho in [0.1, 0.5, 0.9] {
        let cfg = tight_cfg(1.0, rho);
        let res = solve_fast_ot(&prob, &cfg);
        let s = recover_plan(&prob, &cfg.params(), &res.x).group_sparsity(&prob, 1e-12);
        assert!(
            s >= last - 0.02,
            "group sparsity should not decrease with rho: {last} -> {s}"
        );
        last = s;
    }
    assert!(last > 0.5, "strong rho must give group sparsity, got {last}");
}

#[test]
fn fenchel_duality_identity_at_optimum() {
    check("Fenchel identity", &Config::cases(8), |rng| {
        let pair = synthetic::controlled(3, 4, rng.next_u64());
        let prob = OtProblem::from_dataset(&pair);
        let gamma = rng.uniform(0.1, 2.0);
        let rho = rng.uniform(0.1, 0.8);
        let cfg = tight_cfg(gamma, rho);
        let res = solve_fast_ot(&prob, &cfg);
        let params = cfg.params();
        let plan = recover_plan(&prob, &params, &res.x);
        // At the optimum: primal = ⟨T,C⟩ + Ψ(T) and dual coincide when
        // the marginal residuals vanish; allow solver tolerance.
        let primal = plan.primal_objective(&prob, &params);
        let (va, vb) = plan.marginal_violation(&prob);
        let slack = 0.5 * (va + vb) + 1e-6; // residual-driven gap bound
        let gap = (primal - res.dual_objective).abs();
        if gap > slack + 1e-3 {
            return Err(format!(
                "duality gap {gap} too large (viol ({va}, {vb})) at gamma={gamma} rho={rho}"
            ));
        }
        Ok(())
    });
}

#[test]
fn transported_samples_match_class_clusters() {
    // Barycentric mapping of each source class lands near its target
    // class cluster (the synthetic construction aligns them on x).
    let pair = synthetic::controlled(4, 10, 0xF3);
    let prob = OtProblem::from_dataset(&pair);
    let cfg = tight_cfg(0.05, 0.6);
    let res = solve_fast_ot(&prob, &cfg);
    let plan = recover_plan(&prob, &cfg.params(), &res.x);
    let mapped = plan.barycentric_map(&pair.target.x);
    // Class c's target cluster mean-x ≈ 5c, mean-y ≈ +5.
    for l in 0..prob.groups.num_groups() {
        let range = prob.groups.range(l);
        let count = range.len() as f64;
        let mean_x: f64 = range.clone().map(|i| mapped[(i, 0)]).sum::<f64>() / count;
        let mean_y: f64 = range.map(|i| mapped[(i, 1)]).sum::<f64>() / count;
        assert!(
            (mean_x - 5.0 * l as f64).abs() < 1.5,
            "class {l} mapped mean-x {mean_x}"
        );
        assert!((mean_y - 5.0).abs() < 1.5, "class {l} mapped mean-y {mean_y}");
    }
}
