//! Color transfer via regularized OT (Pitié et al. 2007 — one of the
//! classic OT applications cited in the paper's introduction).
//!
//! Two synthetic "photographs" are generated as RGB pixel clouds drawn
//! from distinct palettes (sunset vs forest). Pixels of the source image
//! are clustered (k-means, built here) and the clusters become the
//! groups; group-sparse OT then maps each source color cluster onto the
//! target palette *coherently* — all pixels of a cluster move together,
//! which is exactly the anti-color-bleeding property group sparsity buys.
//!
//! Run: `cargo run --release --example color_transfer`

use grpot::linalg::Mat;
use grpot::ot::plan::recover_plan;
use grpot::prelude::*;
use grpot::rng::Pcg64;

/// Draw `n` pixels from a mixture of RGB Gaussians (palette).
fn image(palette: &[([f64; 3], f64)], n: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::new(seed);
    let weights: Vec<f64> = palette.iter().map(|&(_, w)| w).collect();
    let mut img = Mat::zeros(n, 3);
    for i in 0..n {
        let k = rng.categorical(&weights);
        for c in 0..3 {
            img[(i, c)] = (palette[k].0[c] + 0.06 * rng.normal()).clamp(0.0, 1.0);
        }
    }
    img
}

/// Plain k-means (k clusters on RGB); returns labels.
fn kmeans(x: &Mat, k: usize, iters: usize, seed: u64) -> Vec<usize> {
    let mut rng = Pcg64::new(seed);
    let n = x.rows();
    let mut centers: Vec<Vec<f64>> = rng
        .sample_indices(n, k)
        .into_iter()
        .map(|i| x.row(i).to_vec())
        .collect();
    let mut labels = vec![0usize; n];
    for _ in 0..iters {
        // Assign.
        for i in 0..n {
            let mut best = (0usize, f64::INFINITY);
            for (c, center) in centers.iter().enumerate() {
                let d: f64 = x
                    .row(i)
                    .iter()
                    .zip(center)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if d < best.1 {
                    best = (c, d);
                }
            }
            labels[i] = best.0;
        }
        // Update.
        for (c, center) in centers.iter_mut().enumerate() {
            let members: Vec<usize> = (0..n).filter(|&i| labels[i] == c).collect();
            if members.is_empty() {
                continue;
            }
            for (dim, v) in center.iter_mut().enumerate() {
                *v = members.iter().map(|&i| x[(i, dim)]).sum::<f64>() / members.len() as f64;
            }
        }
    }
    labels
}

fn mean_rgb(x: &Mat) -> [f64; 3] {
    let mut m = [0.0; 3];
    for i in 0..x.rows() {
        for c in 0..3 {
            m[c] += x[(i, c)];
        }
    }
    for v in m.iter_mut() {
        *v /= x.rows() as f64;
    }
    m
}

fn main() {
    let sunset: &[([f64; 3], f64)] = &[
        ([0.95, 0.55, 0.25], 0.4), // orange
        ([0.85, 0.30, 0.45], 0.3), // magenta
        ([0.30, 0.25, 0.50], 0.3), // dusk blue
    ];
    let forest: &[([f64; 3], f64)] = &[
        ([0.15, 0.45, 0.20], 0.5), // leaf green
        ([0.35, 0.25, 0.12], 0.3), // bark brown
        ([0.70, 0.80, 0.85], 0.2), // sky
    ];
    let n = 600;
    let src = image(sunset, n, 0x5015);
    let tgt = image(forest, n, 0xF04E);
    println!("source palette mean RGB: {:?}", mean_rgb(&src).map(|v| (v * 100.0).round() / 100.0));
    println!("target palette mean RGB: {:?}", mean_rgb(&tgt).map(|v| (v * 100.0).round() / 100.0));

    // Cluster source pixels into color groups.
    let k = 6;
    let labels = kmeans(&src, k, 25, 0xC1);
    let pair = grpot::data::DomainPair {
        source: grpot::data::Dataset { name: "sunset".into(), x: src.clone(), labels },
        target: grpot::data::Dataset {
            name: "forest".into(),
            x: tgt.clone(),
            labels: vec![0; n],
        },
    };
    let prob = OtProblem::from_dataset(&pair);
    let cfg = FastOtConfig { gamma: 0.02, rho: 0.7, ..Default::default() };
    let res = solve_fast_ot(&prob, &cfg);
    let plan = recover_plan(&prob, &cfg.params(), &res.x);
    println!(
        "solved in {:.3}s ({} iters); group sparsity {:.3}",
        res.wall_time_s,
        res.iterations,
        plan.group_sparsity(&prob, 1e-12)
    );

    // Transfer: map source pixels into the target palette.
    let transferred_sorted = plan.barycentric_map(&tgt);
    let transferred = {
        let mut out = Mat::zeros(n, 3);
        for (kk, &orig) in prob.groups.perm.iter().enumerate() {
            out.row_mut(orig).copy_from_slice(transferred_sorted.row(kk));
        }
        out
    };
    let out_mean = mean_rgb(&transferred);
    println!("transferred mean RGB   : {:?}", out_mean.map(|v| (v * 100.0).round() / 100.0));

    // The transferred palette must be much closer to the target's.
    let d = |a: [f64; 3], b: [f64; 3]| -> f64 {
        a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
    };
    let before = d(mean_rgb(&src), mean_rgb(&tgt));
    let after = d(out_mean, mean_rgb(&tgt));
    println!("palette distance to target: before={before:.3} after={after:.3}");
    assert!(after < 0.35 * before, "color transfer failed to move the palette");

    // Cluster coherence: pixels of one source cluster should land close
    // together (group sparsity ⇒ no color bleeding).
    let spread_of = |x: &Mat, labels: &[usize], cluster: usize| -> f64 {
        let members: Vec<usize> = (0..n).filter(|&i| labels[i] == cluster).collect();
        let mu: Vec<f64> = (0..3)
            .map(|c| members.iter().map(|&i| x[(i, c)]).sum::<f64>() / members.len() as f64)
            .collect();
        members
            .iter()
            .map(|&i| {
                (0..3)
                    .map(|c| (x[(i, c)] - mu[c]) * (x[(i, c)] - mu[c]))
                    .sum::<f64>()
                    .sqrt()
            })
            .sum::<f64>()
            / members.len() as f64
    };
    let avg_spread: f64 =
        (0..k).map(|c| spread_of(&transferred, &pair.source.labels, c)).sum::<f64>() / k as f64;
    println!("avg within-cluster spread after transfer: {avg_spread:.4}");
    println!("\ncolor_transfer OK");
}
