"""AOT lowering: JAX/Pallas dual oracle → HLO text + manifest.

Interchange format is HLO **text**, not serialized HloModuleProto: the
image's xla_extension 0.5.1 rejects jax≥0.5's 64-bit-instruction-id
protos, while the text parser reassigns ids cleanly (see
/opt/xla-example/README.md).

Each problem shape gets its own artifact (XLA programs are
shape-specialized); ``manifest.json`` indexes them so the Rust runtime
can pick the artifact matching a problem at load time. Hyperparameters
``tau``/``lambda_quad`` are *runtime scalar inputs*, so one artifact
serves the whole (γ, ρ) sweep grid.

Usage: ``python -m compile.aot --out ../artifacts`` (see Makefile).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from .model import dual_obj_grad

# Default shape set: matched to the Rust xla_backend bench and the
# quickstart example (synthetic controlled dataset, n = m = L·g).
DEFAULT_SHAPES = [
    # (num_groups, group_size, n)
    (4, 5, 20),
    (10, 10, 100),
    (20, 10, 200),
    (40, 10, 400),
]

DTYPE = jnp.float64


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_shape(num_groups: int, group_size: int, n: int) -> str:
    m = num_groups * group_size
    s = lambda *shape: jax.ShapeDtypeStruct(shape, DTYPE)  # noqa: E731
    lowered = jax.jit(
        lambda alpha, beta, a, b, cost, tau, lq: dual_obj_grad(
            alpha, beta, a, b, cost, tau, lq,
            num_groups=num_groups, group_size=group_size, use_pallas=True,
        )
    ).lower(s(m), s(n), s(m), s(n), s(m, n), s(), s())
    return to_hlo_text(lowered)


def build(out_dir: str, shapes) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for num_groups, group_size, n in shapes:
        m = num_groups * group_size
        name = f"dual_obj_grad_L{num_groups}_g{group_size}_n{n}"
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = lower_shape(num_groups, group_size, n)
        with open(path, "w") as f:
            f.write(text)
        entries.append(
            {
                "name": name,
                "kind": "dual_obj_grad",
                "num_groups": num_groups,
                "group_size": group_size,
                "m": m,
                "n": n,
                "dtype": "f64",
                "file": os.path.basename(path),
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                # Input order the Rust runtime must follow:
                "inputs": ["alpha[m]", "beta[n]", "a[m]", "b[n]", "cost[m,n]",
                           "tau[]", "lambda_quad[]"],
                "outputs": ["neg_obj[]", "grad_alpha[m]", "grad_beta[n]"],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")
    manifest = {"version": 1, "entries": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(out_dir, 'manifest.json')} ({len(entries)} entries)")
    return manifest


def parse_shapes(spec: str):
    """Parse 'L,g,n;L,g,n;…'."""
    shapes = []
    for part in spec.split(";"):
        l, g, n = (int(tok) for tok in part.split(","))
        shapes.append((l, g, n))
    return shapes


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="output directory")
    p.add_argument(
        "--shapes",
        default=None,
        help="semicolon-separated L,g,n triples (default: built-in set)",
    )
    args = p.parse_args()
    shapes = parse_shapes(args.shapes) if args.shapes else DEFAULT_SHAPES
    build(args.out, shapes)


if __name__ == "__main__":
    sys.exit(main())
